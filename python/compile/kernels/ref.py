"""Pure-jnp correctness oracles for the Pallas kernels — the CORE
correctness signal: pytest sweeps the kernels against these references
(hypothesis over shapes/dtypes) before anything is lowered."""

import jax.numpy as jnp


def matmul_ref(x, y):
    """Plain jnp matmul (f32)."""
    return jnp.matmul(x.astype(jnp.float32), y.astype(jnp.float32))


def quantize_ref(g, prev, beta: int = 8):
    """LAQ quantizer, paper eq. (15)–(17), straight-line jnp.

    Returns (radius, codes(f32), new_val)."""
    g = g.astype(jnp.float32)
    prev = prev.astype(jnp.float32)
    levels = (1 << beta) - 1
    tau = 1.0 / levels
    radius = jnp.max(jnp.abs(g - prev))
    step = 2.0 * tau * radius
    safe = jnp.where(step > 0.0, step, 1.0)
    t = (g - prev + radius) / safe + 0.5
    codes = jnp.clip(jnp.floor(t), 0.0, float(levels))
    codes = jnp.where(step > 0.0, codes, float(levels // 2))
    new_val = prev + step * codes - radius
    return radius, codes, new_val


def rangefinder_ref(a, omega):
    """Sketch Y = A @ Ω."""
    return matmul_ref(a, omega)
