"""Layer-1 Pallas kernels (interpret=True for CPU-PJRT execution) and
their pure-jnp oracles (``ref.py``)."""

from .matmul import matmul_pallas
from .quantize import quantize_pallas
from .rangefinder import rangefinder_pallas

__all__ = ["matmul_pallas", "quantize_pallas", "rangefinder_pallas"]
