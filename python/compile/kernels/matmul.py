"""Blocked Pallas matmul — the MXU-shaped GEMM behind the models' dense
layers (DESIGN.md §3 Hardware-Adaptation).

TPU mapping: (BM, BN) output tiles with a BK-deep accumulation loop;
BlockSpec expresses the HBM→VMEM schedule the paper's GPU formulation
did with thread blocks. Block sizes default to 128×128×128: one f32
output tile (64 KiB) + two input tiles fit comfortably in ~16 MiB VMEM
and feed the 128×128 MXU systolic array. ``interpret=True`` everywhere:
the CPU PJRT plugin cannot execute Mosaic custom-calls; numerics are
identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly default tile sizes.
BM, BK, BN = 128, 128, 128


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (i, j, k) grid step: O[i,j] += X[i,k] @ Y[k,j].

    The output tile is revisited along the k axis (its index_map ignores
    k), so it doubles as the VMEM accumulator — zeroed at k == 0.
    """

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_to(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q


def _matmul_pallas_impl(x, y, *, bm: int = BM, bk: int = BK, bn: int = BN):
    """C = x @ y for f32 matrices of any shape (internally padded to the
    block grid, result sliced back)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims {k} != {k2}"
    # shrink blocks for small operands so the grid is never empty
    bm_ = min(bm, _ceil_to(m, 8))
    bk_ = min(bk, _ceil_to(k, 8))
    bn_ = min(bn, _ceil_to(n, 8))
    mp, kp, np_ = _ceil_to(m, bm_), _ceil_to(k, bk_), _ceil_to(n, bn_)
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm_, np_ // bn_, kp // bk_),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


# pallas_call has no automatic differentiation rule; define the VJP with
# the same blocked kernel so the backward GEMMs (dX = dC·Yᵀ, dY = Xᵀ·dC)
# also run on the MXU-shaped Pallas path.
@jax.custom_vjp
def matmul_pallas(x, y):
    """Differentiable blocked Pallas matmul: C = x @ y (f32)."""
    return _matmul_pallas_impl(x, y)


def _matmul_fwd(x, y):
    return _matmul_pallas_impl(x, y), (x, y)


def _matmul_bwd(res, dc):
    x, y = res
    dx = _matmul_pallas_impl(dc, y.T)
    dy = _matmul_pallas_impl(x.T, dc)
    return dx, dy


matmul_pallas.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_footprint_bytes(bm: int = BM, bk: int = BK, bn: int = BN) -> int:
    """Estimated per-step VMEM residency of the kernel (DESIGN.md §7):
    one X tile, one Y tile and the resident O/accumulator tile, f32."""
    return 4 * (bm * bk + bk * bn + bm * bn)
