"""Pallas randomized-SVD range finder (DESIGN.md §3).

The GEMM-dominant core of randomized truncated SVD — the TPU-friendly
reformulation of the paper's per-layer SVD: sketch ``Y = A·Ω`` and
project ``B = Qᵀ·A``. Both are straight (tall×skinny / skinny×wide)
GEMMs over the blocked Pallas matmul kernel; the tiny ν×ν finishing
factorization stays on the host (L3).
"""

import jax

from .matmul import matmul_pallas


@jax.jit
def rangefinder_pallas(a, omega):
    """Sketch Y = A @ Ω (m×n · n×l)."""
    return matmul_pallas(a, omega)


@jax.jit
def project_pallas(q, a):
    """Project B = Qᵀ @ A (l×m · m×n)."""
    return matmul_pallas(q.T, a)
