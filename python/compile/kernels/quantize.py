"""Pallas LAQ grid quantizer (paper eq. (15)–(17)) — the elementwise
hot-spot of every upload, mapped to the TPU VPU.

Given the gradient ``g``, the previous quantized value ``prev`` and the
scalar radius ``R = max|g − prev|`` (computed by the caller — a global
reduction belongs in XLA, not inside a tile kernel), each block computes

    codes   = floor((g − prev + R) / (2τR) + 1/2)   clipped to [0, 2^β−1]
    new_val = prev + 2τR·codes − R

with τ = 1/(2^β − 1). Blocks are 1-D slices of the flattened tensor.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096


def _quantize_kernel(g_ref, p_ref, r_ref, o_codes, o_val, *, beta: int):
    levels = (1 << beta) - 1
    tau = 1.0 / levels
    r = r_ref[0]
    g = g_ref[...]
    p = p_ref[...]
    step = 2.0 * tau * r
    # degenerate grid (R == 0): center code, value = prev
    safe_step = jnp.where(step > 0.0, step, 1.0)
    t = (g - p + r) / safe_step + 0.5
    codes = jnp.clip(jnp.floor(t), 0.0, float(levels))
    codes = jnp.where(step > 0.0, codes, float(levels // 2))
    o_codes[...] = codes
    o_val[...] = p + step * codes - r


def _ceil_to(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q


@functools.partial(jax.jit, static_argnames=("beta", "block"))
def quantize_pallas(g, prev, *, beta: int = 8, block: int = BLOCK):
    """Quantize ``g`` against ``prev``; returns ``(radius, codes, new_val)``
    with ``codes`` as f32 integers in [0, 2^β−1].

    Works on any shape (flattened internally)."""
    shape = g.shape
    gf = g.reshape(-1).astype(jnp.float32)
    pf = prev.reshape(-1).astype(jnp.float32)
    n = gf.shape[0]
    radius = jnp.max(jnp.abs(gf - pf))
    blk = min(block, _ceil_to(n, 8))
    npad = _ceil_to(n, blk)
    gp = jnp.pad(gf, (0, npad - n))
    pp = jnp.pad(pf, (0, npad - n))
    r1 = radius.reshape(1)
    codes, val = pl.pallas_call(
        functools.partial(_quantize_kernel, beta=beta),
        grid=(npad // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            # the radius is a broadcast scalar: same (single) block everywhere
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad,), jnp.float32),
            jax.ShapeDtypeStruct((npad,), jnp.float32),
        ],
        interpret=True,
    )(gp, pp, r1)
    return radius, codes[:n].reshape(shape), val[:n].reshape(shape)
