"""Build-time JAX implementations of the paper's compression operator ℂ:
randomized truncated SVD (over the Pallas range-finder) and Tucker/HOSVD.

These mirror ``rust/src/compress`` and serve three purposes:
1. pytest cross-checks the two implementations' *behaviour* (reconstruction
   error bounds) so the Rust engine isn't self-certifying,
2. the ``qrr_compress`` artifacts let the Rust runtime run compression
   through PJRT for fixed shapes (integration test), and
3. they document how ℂ maps onto TPU GEMMs (DESIGN.md §3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.rangefinder import project_pallas, rangefinder_pallas


def randomized_svd(a, k: int, *, oversample: int = 8, power_iters: int = 2, seed: int = 0):
    """Truncated SVD via the randomized range finder (Halko et al.).

    Returns (u[m,k], s[k], v[n,k])."""
    m, n = a.shape
    l = min(k + oversample, min(m, n))
    key = jax.random.PRNGKey(seed)
    omega = jax.random.normal(key, (n, l), jnp.float32)
    y = rangefinder_pallas(a, omega)  # Pallas GEMM
    q, _ = jnp.linalg.qr(y)
    for _ in range(power_iters):
        z = project_pallas(q, a).T  # Aᵀ Q, n×l
        qz, _ = jnp.linalg.qr(z)
        y = rangefinder_pallas(a, qz)
        q, _ = jnp.linalg.qr(y)
    b = project_pallas(q, a)  # l×n
    ub, s, vt = jnp.linalg.svd(b, full_matrices=False)
    u = q @ ub
    return u[:, :k], s[:k], vt[:k, :].T


def svd_reconstruct(u, s, v):
    """U diag(s) Vᵀ."""
    return (u * s[None, :]) @ v.T


def _unfold(x, mode: int):
    """Mode-n matricization, row-major ordering of the other modes."""
    perm = (mode,) + tuple(i for i in range(x.ndim) if i != mode)
    return jnp.transpose(x, perm).reshape(x.shape[mode], -1)


def _fold(m, mode: int, shape):
    """Inverse of :func:`_unfold`."""
    other = tuple(s for i, s in enumerate(shape) if i != mode)
    full = m.reshape((shape[mode],) + other)
    inv = [0] * len(shape)
    src = 1
    for i in range(len(shape)):
        if i == mode:
            inv[i] = 0
        else:
            inv[i] = src
            src += 1
    return jnp.transpose(full, inv)


def mode_n_product(x, mode: int, f):
    """X ×_n F (paper eq. (10))."""
    unf = _unfold(x, mode)
    out = f @ unf
    shape = list(x.shape)
    shape[mode] = f.shape[0]
    return _fold(out, mode, shape)


def tucker_hosvd(x, ranks):
    """HOSVD: per-mode truncated factor matrices + core (paper eq. (9)).

    Returns (core, [F_1…F_N])."""
    factors = []
    for mode, r in enumerate(ranks):
        unf = _unfold(x, mode)
        u, _, _ = jnp.linalg.svd(unf, full_matrices=False)
        factors.append(u[:, :r])
    core = x
    for mode, f in enumerate(factors):
        core = mode_n_product(core, mode, f.T)
    return core, factors


def tucker_reconstruct(core, factors):
    """𝔊 ×₁ F₁ … ×_N F_N (paper eq. (25))."""
    out = core
    for mode, f in enumerate(factors):
        out = mode_n_product(out, mode, f)
    return out


def qrr_compress_matrix(g, prev_u, prev_s, prev_v, *, k: int, beta: int = 8, seed: int = 0):
    """One full client-side QRR step for a matrix gradient, as a single
    jittable computation: truncated SVD + LAQ quantization of each factor
    against its previous quantized state.

    Returns (radius_u, codes_u, qu, radius_s, codes_s, qs,
    radius_v, codes_v, qv)."""
    from .kernels.quantize import quantize_pallas

    u, s, v = randomized_svd(g, k, seed=seed)
    ru, cu, qu = quantize_pallas(u, prev_u, beta=beta)
    rs, cs, qs = quantize_pallas(s, prev_s, beta=beta)
    rv, cv, qv = quantize_pallas(v, prev_v, beta=beta)
    return (ru, cu, qu, rs, cs, qs, rv, cv, qv)
