"""Layer-2 JAX models — the paper's three architectures, with forward,
weighted-loss gradients and evaluation, matching the Rust-side
``ModelSpec`` layout exactly (names, shapes, traversal order).

Dense layers run on the Layer-1 Pallas matmul kernel so the blocked GEMM
lowers into the same HLO the Rust runtime executes; convolutions use
XLA's native conv (on TPU that is already an MXU op — DESIGN.md §3).

Calling convention shared with ``rust/src/runtime/model.rs``:

* ``grad``: ``(param_0…param_{P-1}, x[B,D], y_onehot[B,K], w[B])`` →
  ``(loss, grad_0…grad_{P-1})`` — w-weighted mean cross-entropy.
* ``eval``: same inputs → ``(loss_sum, correct)`` (w-weighted sums).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import matmul_pallas

# Set to False to lower the dense layers with plain jnp instead of the
# Pallas kernel (debug / ablation).
USE_PALLAS = True

NUM_CLASSES = 10

SPECS = {
    "mlp": {
        "input_shape": (784,),
        "params": [
            ("fc1.weight", (200, 784)),
            ("fc1.bias", (200,)),
            ("fc2.weight", (10, 200)),
            ("fc2.bias", (10,)),
        ],
    },
    "cnn": {
        "input_shape": (1, 28, 28),
        "params": [
            ("conv1.weight", (16, 1, 3, 3)),
            ("conv1.bias", (16,)),
            ("conv2.weight", (32, 16, 3, 3)),
            ("conv2.bias", (32,)),
            ("fc.weight", (10, 32 * 14 * 14)),
            ("fc.bias", (10,)),
        ],
    },
    "vgg": {
        "input_shape": (3, 32, 32),
        "params": [
            ("conv1.weight", (32, 3, 3, 3)),
            ("conv1.bias", (32,)),
            ("conv2.weight", (64, 32, 3, 3)),
            ("conv2.bias", (64,)),
            ("conv3.weight", (128, 64, 3, 3)),
            ("conv3.bias", (128,)),
            ("fc.weight", (10, 128 * 4 * 4)),
            ("fc.bias", (10,)),
        ],
    },
}


def param_shapes(model: str):
    """Ordered parameter shapes for a model."""
    return [shape for _, shape in SPECS[model]["params"]]


def input_dim(model: str) -> int:
    d = 1
    for s in SPECS[model]["input_shape"]:
        d *= s
    return d


def init_params(model: str, seed: int = 0):
    """He-style init (biases zero), mirroring the Rust initializer."""
    key = jax.random.PRNGKey(seed)
    params = []
    for _, shape in SPECS[model]["params"]:
        if len(shape) == 1:
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for s in shape[1:]:
                fan_in *= s
            key, sub = jax.random.split(key)
            std = (2.0 / fan_in) ** 0.5
            params.append(std * jax.random.normal(sub, shape, jnp.float32))
    return params


# ------------------------------------------------------------- layers


def dense(x, w, b):
    """y = x @ Wᵀ + b via the Pallas GEMM (W stored [out, in])."""
    if USE_PALLAS:
        return matmul_pallas(x, w.T) + b
    return x @ w.T + b


def conv2d_same(x, w, b):
    """3×3 stride-1 same-padding conv, NCHW."""
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y + b[None, :, None, None]


def maxpool2(x):
    """2×2 max-pool, stride 2, NCHW."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 1, 2, 2),
        window_strides=(1, 1, 2, 2),
        padding="VALID",
    )


# ------------------------------------------------------------ forward


def forward(model: str, params, x):
    """Logits [B, 10] from flat inputs [B, D]."""
    b = x.shape[0]
    if model == "mlp":
        w1, b1, w2, b2 = params
        h = jax.nn.relu(dense(x, w1, b1))
        return dense(h, w2, b2)
    if model == "cnn":
        w1, b1, w2, b2, wf, bf = params
        img = x.reshape(b, 1, 28, 28)
        h = jax.nn.relu(conv2d_same(img, w1, b1))
        h = jax.nn.relu(conv2d_same(h, w2, b2))
        h = maxpool2(h)
        return dense(h.reshape(b, -1), wf, bf)
    if model == "vgg":
        (w1, b1, w2, b2, w3, b3, wf, bf) = params
        img = x.reshape(b, 3, 32, 32)
        h = maxpool2(jax.nn.relu(conv2d_same(img, w1, b1)))
        h = maxpool2(jax.nn.relu(conv2d_same(h, w2, b2)))
        h = maxpool2(jax.nn.relu(conv2d_same(h, w3, b3)))
        return dense(h.reshape(b, -1), wf, bf)
    raise ValueError(f"unknown model {model!r}")


def _weighted_xent(logits, y_onehot, w):
    """(weighted loss sum, weight sum)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    per_row = -jnp.sum(y_onehot * logp, axis=-1)
    return jnp.sum(w * per_row), jnp.sum(w)


def loss_fn(model: str, params, x, y_onehot, w):
    """w-weighted mean cross-entropy (padding rows contribute nothing)."""
    logits = forward(model, params, x)
    s, n = _weighted_xent(logits, y_onehot, w)
    return s / jnp.maximum(n, 1.0)


def grad_fn(model: str):
    """The artifact body: (params…, x, y, w) → (loss, grads…)."""

    def f(*args):
        n_params = len(SPECS[model]["params"])
        params = list(args[:n_params])
        x, y_onehot, w = args[n_params:]
        loss, grads = jax.value_and_grad(
            lambda ps: loss_fn(model, ps, x, y_onehot, w)
        )(params)
        return (loss, *grads)

    return f


def eval_fn(model: str):
    """The eval artifact body: (params…, x, y, w) → (loss_sum, correct)."""

    def f(*args):
        n_params = len(SPECS[model]["params"])
        params = list(args[:n_params])
        x, y_onehot, w = args[n_params:]
        logits = forward(model, params, x)
        s, _ = _weighted_xent(logits, y_onehot, w)
        pred = jnp.argmax(logits, axis=-1)
        label = jnp.argmax(y_onehot, axis=-1)
        correct = jnp.sum(w * (pred == label).astype(jnp.float32))
        return (s, correct)

    return f


@functools.lru_cache(maxsize=None)
def jitted_grad(model: str):
    """Cached jitted grad fn (tests)."""
    return jax.jit(grad_fn(model))


@functools.lru_cache(maxsize=None)
def jitted_eval(model: str):
    """Cached jitted eval fn (tests)."""
    return jax.jit(eval_fn(model))
