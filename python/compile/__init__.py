"""Build-time python package: JAX models (L2) + Pallas kernels (L1) and
the AOT lowering pipeline that emits ``artifacts/*.hlo.txt`` for the Rust
runtime. Never imported on the request path."""
