"""AOT lowering: JAX/Pallas → HLO **text** → ``artifacts/``.

Python runs exactly once (``make artifacts``); the Rust runtime then
loads + compiles the HLO through PJRT and python never appears on the
request path.

HLO *text* (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids that xla_extension
0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md and aot_recipe.md).

Usage:
    python -m compile.aot --out-dir ../artifacts \
        [--models mlp,cnn,vgg] [--batches 32,512] [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.quantize import quantize_pallas
from .kernels.rangefinder import rangefinder_pallas


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the
    Rust side always unpacks one tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model_fn(model: str, fn_name: str, batch: int) -> str:
    """Lower <model>_{grad|eval} at a static batch size to HLO text."""
    fn = M.grad_fn(model) if fn_name == "grad" else M.eval_fn(model)
    d = M.input_dim(model)
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in M.param_shapes(model)]
    specs += [
        jax.ShapeDtypeStruct((batch, d), jnp.float32),
        jax.ShapeDtypeStruct((batch, M.NUM_CLASSES), jnp.float32),
        jax.ShapeDtypeStruct((batch,), jnp.float32),
    ]
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def lower_quantize(n: int, beta: int = 8) -> str:
    """Standalone LAQ quantize kernel artifact: (g[n], prev[n]) →
    (radius, codes[n], new_val[n])."""

    def fn(g, prev):
        return quantize_pallas(g, prev, beta=beta)

    spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec, spec))


def lower_rangefinder(m: int, n: int, l: int) -> str:
    """Standalone range-finder artifact: (a[m,n], omega[n,l]) → y[m,l]."""

    def fn(a, omega):
        return (rangefinder_pallas(a, omega),)

    return to_hlo_text(
        jax.jit(fn).lower(
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((n, l), jnp.float32),
        )
    )


def build(out_dir: str, models, batches, quick: bool) -> dict:
    """Lower everything; returns the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    artifacts = []

    def emit(name: str, text: str, **meta):
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entry = {"name": name, "file": fname, **meta}
        artifacts.append(entry)
        print(f"  {name:<24} {len(text) / 1024:8.1f} KiB")

    for model in models:
        for fn_name in ("grad", "eval"):
            for b in batches:
                # the big-batch VGG graphs are heavy to lower; skip in quick mode
                if quick and b > 64:
                    continue
                name = f"{model}_{fn_name}_b{b}"
                print(f"lowering {name} …", flush=True)
                emit(
                    name,
                    lower_model_fn(model, fn_name, b),
                    model=model,
                    fn=fn_name,
                    batch=b,
                )

    # standalone kernel artifacts (runtime integration tests + compress path)
    print("lowering kernel artifacts …", flush=True)
    emit("quantize_16384", lower_quantize(16384), fn="quantize", batch=16384)
    emit("rangefinder_256x192_l24", lower_rangefinder(256, 192, 24), fn="rangefinder")

    manifest = {
        "version": 1,
        "jax": jax.__version__,
        "artifacts": artifacts,
        "models": {
            m: {"params": [[n, list(s)] for n, s in M.SPECS[m]["params"]]} for m in models
        },
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(artifacts)} artifacts + manifest.json to {out_dir}")
    return manifest


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="mlp,cnn,vgg")
    ap.add_argument("--batches", default="32,512")
    ap.add_argument(
        "--quick", action="store_true", help="small batches only (CI / tests)"
    )
    args = ap.parse_args(argv)
    models = [m.strip() for m in args.models.split(",") if m.strip()]
    for m in models:
        if m not in M.SPECS:
            print(f"unknown model {m!r}", file=sys.stderr)
            return 2
    batches = sorted({int(b) for b in args.batches.split(",")})
    build(args.out_dir, models, batches, args.quick)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
