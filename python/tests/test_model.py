"""L2 correctness: model shapes, weighted-loss semantics, Pallas-vs-jnp
parity of the dense path, and gradient sanity for all architectures."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def make_batch(model, b, seed=0):
    r = np.random.RandomState(seed)
    d = M.input_dim(model)
    x = r.rand(b, d).astype(np.float32)
    labels = r.randint(0, 10, size=b)
    y = np.zeros((b, 10), np.float32)
    y[np.arange(b), labels] = 1.0
    w = np.ones(b, np.float32)
    return jnp.array(x), jnp.array(y), jnp.array(w)


@pytest.mark.parametrize("model", ["mlp", "cnn", "vgg"])
def test_grad_shapes_match_spec(model):
    params = M.init_params(model, 0)
    x, y, w = make_batch(model, 4)
    out = M.jitted_grad(model)(*params, x, y, w)
    loss, grads = out[0], out[1:]
    assert np.isfinite(float(loss)) and float(loss) > 0
    assert len(grads) == len(M.SPECS[model]["params"])
    for g, (_, shape) in zip(grads, M.SPECS[model]["params"]):
        assert g.shape == shape


@pytest.mark.parametrize("model", ["mlp", "cnn"])
def test_padding_rows_are_inert(model):
    # (x, y, w=1) on b rows == (x padded with garbage, w=0 on padding)
    params = M.init_params(model, 1)
    x, y, w = make_batch(model, 6, seed=2)
    out_a = M.jitted_grad(model)(*params, x, y, w)

    pad = 10
    r = np.random.RandomState(3)
    xp = jnp.concatenate([x, jnp.array(r.rand(pad, x.shape[1]).astype(np.float32))])
    yp = jnp.concatenate([y, jnp.zeros((pad, 10), jnp.float32)])
    wp = jnp.concatenate([w, jnp.zeros(pad, jnp.float32)])
    out_b = M.jitted_grad(model)(*params, xp, yp, wp)

    np.testing.assert_allclose(float(out_a[0]), float(out_b[0]), rtol=1e-5)
    for ga, gb in zip(out_a[1:], out_b[1:]):
        np.testing.assert_allclose(np.array(ga), np.array(gb), rtol=1e-4, atol=1e-5)


def test_pallas_dense_equals_jnp_dense():
    # flip the USE_PALLAS switch: identical logits
    params = M.init_params("mlp", 4)
    x, y, w = make_batch("mlp", 8, seed=5)
    logits_pallas = M.forward("mlp", params, x)
    old = M.USE_PALLAS
    try:
        M.USE_PALLAS = False
        logits_jnp = M.forward("mlp", params, x)
    finally:
        M.USE_PALLAS = old
    np.testing.assert_allclose(
        np.array(logits_pallas), np.array(logits_jnp), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("model", ["mlp", "cnn"])
def test_gradient_descent_reduces_loss(model):
    params = M.init_params(model, 6)
    x, y, w = make_batch(model, 16, seed=7)
    grad = M.jitted_grad(model)
    l0 = None
    for _ in range(12):
        out = grad(*params, x, y, w)
        if l0 is None:
            l0 = float(out[0])
        params = [p - 0.1 * g for p, g in zip(params, out[1:])]
    l1 = float(grad(*params, x, y, w)[0])
    assert l1 < l0 * 0.7, f"{l0} -> {l1}"


def test_eval_counts_correct():
    params = M.init_params("mlp", 8)
    x, y, w = make_batch("mlp", 32, seed=9)
    loss_sum, correct = M.jitted_eval("mlp")(*params, x, y, w)
    assert 0 <= float(correct) <= 32
    assert float(loss_sum) > 0


def test_eval_weighted_sum_semantics():
    params = M.init_params("mlp", 10)
    x, y, w = make_batch("mlp", 8, seed=11)
    l_full, c_full = M.jitted_eval("mlp")(*params, x, y, w)
    # half weights -> half the sums
    l_half, c_half = M.jitted_eval("mlp")(*params, x, y, 0.5 * w)
    np.testing.assert_allclose(float(l_half), 0.5 * float(l_full), rtol=1e-5)
    np.testing.assert_allclose(float(c_half), 0.5 * float(c_full), rtol=1e-5)


def test_grad_matches_finite_difference_on_bias():
    # cheap FD check on the last-layer bias (direct path to the loss)
    model = "mlp"
    params = M.init_params(model, 12)
    x, y, w = make_batch(model, 4, seed=13)
    out = M.jitted_grad(model)(*params, x, y, w)
    g_b2 = np.array(out[-1])  # fc2.bias grad
    eps = 1e-3
    for j in [0, 3, 9]:
        pp = [jnp.array(p) for p in params]
        pp[3] = pp[3].at[j].add(eps)
        lp = float(M.jitted_grad(model)(*pp, x, y, w)[0])
        pm = [jnp.array(p) for p in params]
        pm[3] = pm[3].at[j].add(-eps)
        lm = float(M.jitted_grad(model)(*pm, x, y, w)[0])
        fd = (lp - lm) / (2 * eps)
        assert abs(fd - g_b2[j]) < 5e-3, f"bias {j}: fd {fd} vs {g_b2[j]}"


def test_init_matches_rust_scheme():
    params = M.init_params("mlp", 0)
    # biases exactly zero
    assert float(jnp.abs(params[1]).max()) == 0.0
    # weights ~ N(0, 2/fan_in)
    std = float(jnp.std(params[0]))
    assert abs(std - (2 / 784) ** 0.5) / ((2 / 784) ** 0.5) < 0.05
