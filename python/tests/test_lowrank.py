"""Compression-operator correctness on the python side, cross-checking
behaviour with the Rust engine (same error bounds, eq. (7))."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.lowrank import (
    mode_n_product,
    qrr_compress_matrix,
    randomized_svd,
    svd_reconstruct,
    tucker_hosvd,
    tucker_reconstruct,
)

SET = settings(max_examples=15, deadline=None)


def lowrank_matrix(m, n, r, seed):
    rs = np.random.RandomState(seed)
    u = rs.randn(m, r).astype(np.float32)
    v = rs.randn(r, n).astype(np.float32)
    return jnp.array(u @ v)


@SET
@given(
    m=st.integers(20, 120),
    n=st.integers(20, 120),
    r=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_randomized_svd_recovers_lowrank(m, n, r, seed):
    a = lowrank_matrix(m, n, r, seed)
    u, s, v = randomized_svd(a, r, seed=seed)
    rec = svd_reconstruct(u, s, v)
    err = float(jnp.linalg.norm(a - rec) / jnp.maximum(jnp.linalg.norm(a), 1e-9))
    assert err < 1e-2, err


def test_svd_singular_values_descend():
    a = lowrank_matrix(50, 40, 8, 0)
    _, s, _ = randomized_svd(a, 8, seed=1)
    s = np.array(s)
    assert (np.diff(s) <= 1e-4).all()


def test_truncation_error_eq7():
    # build known spectrum, truncate, check ||err||_F^2 == tail energy
    rs = np.random.RandomState(2)
    qa, _ = np.linalg.qr(rs.randn(30, 5))
    qb, _ = np.linalg.qr(rs.randn(25, 5))
    sig = np.array([8.0, 4.0, 2.0, 1.0, 0.5], np.float32)
    a = jnp.array((qa * sig) @ qb.T, jnp.float32)
    u, s, v = randomized_svd(a, 2, oversample=3, power_iters=3, seed=3)
    rec = svd_reconstruct(u, s, v)
    err2 = float(jnp.sum((a - rec) ** 2))
    tail = float((sig[2:] ** 2).sum())
    assert abs(err2 - tail) / tail < 0.05, (err2, tail)


def test_mode_n_product_identity():
    rs = np.random.RandomState(4)
    x = jnp.array(rs.randn(4, 5, 3).astype(np.float32))
    for mode, dim in enumerate(x.shape):
        y = mode_n_product(x, mode, jnp.eye(dim, dtype=jnp.float32))
        np.testing.assert_allclose(np.array(y), np.array(x), rtol=1e-5)


def test_tucker_exact_rank_reconstruction():
    rs = np.random.RandomState(5)
    core = rs.randn(3, 2, 2, 2).astype(np.float32)
    factors = []
    dims = (8, 6, 3, 3)
    x = jnp.array(core)
    for mode, d in enumerate(dims):
        f, _ = np.linalg.qr(rs.randn(d, core.shape[mode]))
        f = jnp.array(f.astype(np.float32))
        factors.append(f)
        x = mode_n_product(x, mode, f)
    core2, factors2 = tucker_hosvd(x, [3, 2, 2, 2])
    rec = tucker_reconstruct(core2, factors2)
    err = float(jnp.linalg.norm(x - rec) / jnp.linalg.norm(x))
    assert err < 1e-3, err


def test_tucker_error_decreases_with_rank():
    rs = np.random.RandomState(6)
    x = jnp.array(rs.randn(12, 8, 3, 3).astype(np.float32))
    errs = []
    for p in (0.2, 0.5, 1.0):
        ranks = [max(1, int(np.ceil(p * d))) for d in x.shape]
        core, factors = tucker_hosvd(x, ranks)
        rec = tucker_reconstruct(core, factors)
        errs.append(float(jnp.linalg.norm(x - rec)))
    assert errs[0] >= errs[1] >= errs[2]
    assert errs[2] < 1e-3


def test_qrr_compress_matrix_pipeline():
    # full ℂ∘ℚ client step: factors quantized against zero state
    a = lowrank_matrix(40, 30, 3, 7)
    k = 6
    zu = jnp.zeros((40, k), jnp.float32)
    zs = jnp.zeros((k,), jnp.float32)
    zv = jnp.zeros((30, k), jnp.float32)
    (ru, cu, qu, rs_, cs, qs, rv, cv, qv) = qrr_compress_matrix(
        a, zu, zs, zv, k=k, beta=8
    )
    rec = svd_reconstruct(qu, qs, qv)
    err = float(jnp.linalg.norm(a - rec) / jnp.linalg.norm(a))
    # rank-3 signal, rank-6 kept, 8-bit factors: small reconstruction error
    assert err < 0.1, err
    for c in (cu, cs, cv):
        arr = np.array(c)
        assert arr.min() >= 0 and arr.max() <= 255
