"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes and value ranges; assert_allclose against the
reference is the core signal gating AOT lowering."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul_pallas, quantize_pallas, rangefinder_pallas
from compile.kernels.ref import matmul_ref, quantize_ref, rangefinder_ref

SET = settings(max_examples=25, deadline=None)


@SET
@given(
    m=st.integers(1, 80),
    k=st.integers(1, 80),
    n=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    r = np.random.RandomState(seed)
    x = r.randn(m, k).astype(np.float32)
    y = r.randn(k, n).astype(np.float32)
    got = np.array(matmul_pallas(jnp.array(x), jnp.array(y)))
    want = np.array(matmul_ref(jnp.array(x), jnp.array(y)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@SET
@given(
    mkn=st.tuples(st.integers(100, 300), st.integers(100, 300), st.integers(1, 64)),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_beyond_one_block(mkn, seed):
    # shapes larger than one 128-block: exercises the k-accumulation loop
    m, k, n = mkn
    r = np.random.RandomState(seed)
    x = r.randn(m, k).astype(np.float32)
    y = r.randn(k, n).astype(np.float32)
    got = np.array(matmul_pallas(jnp.array(x), jnp.array(y)))
    np.testing.assert_allclose(got, x @ y, rtol=2e-4, atol=2e-3)


def test_matmul_block_shape_ablation():
    # different tilings must give the same numbers
    from compile.kernels.matmul import _matmul_pallas_impl

    r = np.random.RandomState(0)
    x = jnp.array(r.randn(200, 150).astype(np.float32))
    y = jnp.array(r.randn(150, 90).astype(np.float32))
    base = np.array(_matmul_pallas_impl(x, y))
    for bm, bk, bn in [(32, 32, 32), (64, 128, 32), (128, 64, 128)]:
        other = np.array(_matmul_pallas_impl(x, y, bm=bm, bk=bk, bn=bn))
        np.testing.assert_allclose(base, other, rtol=1e-4, atol=1e-4)


def test_matmul_grad_flows_through_custom_vjp():
    import jax

    r = np.random.RandomState(1)
    x = jnp.array(r.randn(20, 30).astype(np.float32))
    y = jnp.array(r.randn(30, 10).astype(np.float32))

    def f(a, b):
        return jnp.sum(matmul_pallas(a, b) ** 2)

    gx, gy = jax.grad(f, argnums=(0, 1))(x, y)
    # reference gradients: d/dA sum((AB)^2) = 2(AB)Bᵀ
    c = np.array(x) @ np.array(y)
    np.testing.assert_allclose(np.array(gx), 2 * c @ np.array(y).T, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.array(gy), 2 * np.array(x).T @ c, rtol=1e-3, atol=1e-3)


@SET
@given(
    n=st.integers(1, 5000),
    beta=st.sampled_from([1, 2, 4, 8, 12]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_matches_ref(n, beta, seed):
    r = np.random.RandomState(seed)
    g = r.randn(n).astype(np.float32)
    prev = r.randn(n).astype(np.float32)
    rad_p, codes_p, val_p = quantize_pallas(jnp.array(g), jnp.array(prev), beta=beta)
    rad_r, codes_r, val_r = quantize_ref(jnp.array(g), jnp.array(prev), beta=beta)
    np.testing.assert_allclose(float(rad_p), float(rad_r), rtol=1e-6)
    np.testing.assert_allclose(np.array(val_p), np.array(val_r), rtol=1e-4, atol=1e-5)
    # codes may differ by 1 at exact grid boundaries; bound the fraction
    diff = np.abs(np.array(codes_p) - np.array(codes_r))
    assert (diff > 0.5).mean() < 1e-3


@SET
@given(
    n=st.integers(1, 2000),
    beta=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_error_bound_eq18(n, beta, seed):
    # paper eq. (18): ||g - Q(g)||_inf <= tau * R
    r = np.random.RandomState(seed)
    g = r.randn(n).astype(np.float32)
    prev = r.randn(n).astype(np.float32)
    rad, _, val = quantize_pallas(jnp.array(g), jnp.array(prev), beta=beta)
    tau = 1.0 / ((1 << beta) - 1)
    err = np.abs(np.array(val) - g).max()
    assert err <= tau * float(rad) * (1 + 1e-4) + 1e-7


def test_quantize_zero_innovation():
    g = jnp.array(np.array([1.0, -2.0, 3.0], np.float32))
    rad, codes, val = quantize_pallas(g, g, beta=8)
    assert float(rad) == 0.0
    np.testing.assert_allclose(np.array(val), np.array(g))
    assert set(np.array(codes).tolist()) == {127.0}


def test_quantize_codes_within_beta_bits():
    r = np.random.RandomState(3)
    g = jnp.array(r.randn(512).astype(np.float32))
    p = jnp.array(r.randn(512).astype(np.float32))
    for beta in (1, 4, 8):
        _, codes, _ = quantize_pallas(g, p, beta=beta)
        assert np.array(codes).max() <= (1 << beta) - 1
        assert np.array(codes).min() >= 0


@SET
@given(
    m=st.integers(1, 100),
    n=st.integers(1, 100),
    l=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_rangefinder_matches_ref(m, n, l, seed):
    r = np.random.RandomState(seed)
    a = r.randn(m, n).astype(np.float32)
    omega = r.randn(n, l).astype(np.float32)
    got = np.array(rangefinder_pallas(jnp.array(a), jnp.array(omega)))
    want = np.array(rangefinder_ref(jnp.array(a), jnp.array(omega)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_vmem_footprint_within_budget():
    # DESIGN.md §7: default tiles must fit VMEM (~16 MiB) comfortably
    from compile.kernels.matmul import vmem_footprint_bytes

    assert vmem_footprint_bytes() == 4 * 3 * 128 * 128
    assert vmem_footprint_bytes() < 1 << 20  # < 1 MiB: triple-buffer headroom
