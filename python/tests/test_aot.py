"""AOT pipeline: lowering produces parseable HLO text and a coherent
manifest (the Rust side's load path is tested in rust/tests/)."""

import json
import os

import pytest

from compile import aot
from compile import model as M


def test_lower_mlp_grad_has_hlo_text(tmp_path):
    text = aot.lower_model_fn("mlp", "grad", 4)
    assert "HloModule" in text
    assert len(text) > 1000
    # all parameters + x, y, w appear as entry parameters
    n_inputs = len(M.SPECS["mlp"]["params"]) + 3
    assert text.count("parameter(") >= n_inputs


def test_lower_eval_smaller_than_grad():
    g = aot.lower_model_fn("mlp", "grad", 4)
    e = aot.lower_model_fn("mlp", "eval", 4)
    assert "HloModule" in e
    assert len(e) < len(g)  # no backward pass


def test_quantize_artifact_lowering():
    text = aot.lower_quantize(64, beta=8)
    assert "HloModule" in text


def test_build_writes_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.build(out, ["mlp"], [8], quick=False)
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk["artifacts"] == manifest["artifacts"]
    names = {a["name"] for a in on_disk["artifacts"]}
    assert "mlp_grad_b8" in names
    assert "mlp_eval_b8" in names
    assert "quantize_16384" in names
    for a in on_disk["artifacts"]:
        path = os.path.join(out, a["file"])
        assert os.path.exists(path), path
        with open(path) as f:
            assert "HloModule" in f.read(200)
    # model param layout recorded for the Rust side
    assert on_disk["models"]["mlp"]["params"][0] == ["fc1.weight", [200, 784]]


def test_quick_mode_skips_big_batches(tmp_path):
    out = str(tmp_path / "q")
    manifest = aot.build(out, ["mlp"], [8, 512], quick=True)
    batches = {a["batch"] for a in manifest["artifacts"] if a.get("model") == "mlp"}
    assert 512 not in batches
    assert 8 in batches


def test_cli_rejects_unknown_model(capsys):
    rc = aot.main(["--models", "transformer", "--out-dir", "/tmp/x"])
    assert rc == 2
