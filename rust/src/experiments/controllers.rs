//! Adaptive-compression control-plane comparison (DESIGN.md §12).
//!
//! Runs the same FL workload — a [`LinkModel::spread`] cohort from
//! `link_slow_bps` to `link_fast_bps`, same seed, same (optional)
//! chaos plan — once per shipped controller policy (`fixed`,
//! `linkaware`, `aimd`) and reports what each policy spent per client.
//! The interesting contrast is the per-client bit allocation: a
//! link-oblivious `fixed` policy charges stragglers as much as
//! broadband clients, while `linkaware`/`aimd` shift bits toward the
//! fast links and keep the round deadline honest for the slow ones.
//!
//! Outputs per policy: `<out>/controllers_<policy>_{rounds,evals,
//! clients}.csv`, plus `<out>/controllers.md` with one summary row per
//! policy.

use anyhow::Result;

use crate::cli::Args;
use crate::config::ExperimentConfig;
use crate::control::ControllerConfig;
use crate::fl::session::FlSessionBuilder;

use super::{apply_overrides, slug, write_run_outputs};

/// One controller's summary line.
#[derive(Debug, Clone)]
pub struct ControllerRow {
    /// controller label (e.g. `aimd(target_ms=250,...)`)
    pub label: String,
    /// total uplink payload bits across the run
    pub bits: u64,
    /// uplink bits spent by the slowest client
    pub straggler_bits: u64,
    /// uplink bits spent by the fastest client
    pub broadband_bits: u64,
    /// uploads lost to the round deadline
    pub timed_out: u64,
    /// final test accuracy (NaN when never evaluated)
    pub accuracy: f64,
}

/// The policy lineup the scenario compares.
fn default_lineup() -> Vec<ControllerConfig> {
    vec![
        ControllerConfig::fixed(),
        ControllerConfig::linkaware(),
        ControllerConfig::aimd(),
    ]
}

/// Run the comparison; writes CSVs + `<out>/controllers.md`.
pub fn run(args: &Args, out_dir: &str) -> Result<()> {
    let mut base = ExperimentConfig::table1_default();
    base.name = "controllers".into();
    // light defaults so the scenario is interactive; --iters/--clients
    // and friends raise it back to paper scale
    base.clients = 6;
    base.iters = 40;
    base.batch = 32;
    base.train_n = 2_000;
    base.test_n = 500;
    base.eval_every = 10;
    apply_overrides(&mut base, args)?;

    let lineup = match args.get("controller") {
        // an explicit --controller narrows the lineup to that policy
        Some(v) => vec![ControllerConfig::parse(v)
            .map_err(|e| anyhow::anyhow!("--controller: {e}"))?],
        None => default_lineup(),
    };

    let rows = compare(&base, &lineup, out_dir)?;
    let md = markdown(&rows);
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(format!("{out_dir}/controllers.md"), &md)?;
    println!("\nCONTROLLER COMPARISON ({} clients, {} iters)\n{md}", base.clients, base.iters);
    println!("per-policy CSVs in {out_dir}/");
    Ok(())
}

/// Run `base` once per controller; identical cfg and seed otherwise.
pub fn compare(
    base: &ExperimentConfig,
    lineup: &[ControllerConfig],
    out_dir: &str,
) -> Result<Vec<ControllerRow>> {
    let mut rows = Vec::new();
    for ctrl in lineup {
        let mut cfg = base.clone();
        cfg.controller = Some(*ctrl);
        log::info!("=== controllers: {} ===", ctrl.format());
        let mut session = FlSessionBuilder::new(&cfg).build()?;
        let report = session.run()?;
        write_run_outputs(
            out_dir,
            &format!("controllers_{}", slug(ctrl.name())),
            &report,
        )?;
        let per_client = report.history.bits_per_client();
        rows.push(ControllerRow {
            label: ctrl.format(),
            bits: report.history.total_bits(),
            // builder orders links slow -> fast, so client 0 is the
            // straggler and the last client is broadband
            straggler_bits: per_client.first().copied().unwrap_or(0),
            broadband_bits: per_client.last().copied().unwrap_or(0),
            timed_out: report.history.total_timed_out(),
            accuracy: report
                .history
                .final_eval()
                .map(|e| e.accuracy)
                .unwrap_or(f64::NAN),
        });
    }
    Ok(rows)
}

/// Render the summary table.
fn markdown(rows: &[ControllerRow]) -> String {
    let mut md = String::from(
        "| Controller | Total bits | Straggler bits | Broadband bits | Timed out | Accuracy |\n|---|---|---|---|---|---|\n",
    );
    for r in rows {
        md.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} |\n",
            r.label,
            crate::util::fmt::bits_sci(r.bits),
            crate::util::fmt::bits_sci(r.straggler_bits),
            crate::util::fmt::bits_sci(r.broadband_bits),
            r.timed_out,
            if r.accuracy.is_finite() {
                format!("{:.2}%", 100.0 * r.accuracy)
            } else {
                "-".into()
            }
        ));
    }
    md
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_comparison_writes_outputs_and_orders_bits() {
        let dir = std::env::temp_dir().join("qrr_controllers_test");
        let mut base = ExperimentConfig::table1_default();
        base.clients = 3;
        base.iters = 4;
        base.batch = 8;
        base.train_n = 90;
        base.test_n = 30;
        base.eval_every = 2;
        let lineup = [ControllerConfig::fixed(), ControllerConfig::linkaware()];
        let rows = compare(&base, &lineup, dir.to_str().unwrap()).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(dir.join("controllers_fixed_rounds.csv").exists());
        assert!(dir.join("controllers_linkaware_clients.csv").exists());
        // fixed charges every link the same; linkaware compresses the
        // straggler harder than the broadband client
        let fixed = &rows[0];
        let la = &rows[1];
        assert_eq!(fixed.straggler_bits, fixed.broadband_bits);
        assert!(
            la.straggler_bits < la.broadband_bits,
            "linkaware should under-spend the straggler: {} vs {}",
            la.straggler_bits,
            la.broadband_bits
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
