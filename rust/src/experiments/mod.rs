//! Experiment drivers that regenerate every table and figure in the
//! paper's evaluation section (DESIGN.md §2):
//!
//! * `table1` — Table I + Figure 2 (MLP / MNIST)
//! * `table2` — Table II + Figure 3 (CNN / MNIST)
//! * `table3` — Table III + Figure 4 (VGG-like / CIFAR-10, adaptive p)
//! * `fig1`   — Figure 1 (singular-value spectrum of an FC gradient)
//! * `overhead` — §III-B client-side memory / compute overhead
//! * `controllers` — adaptive-compression control-plane comparison
//!   over a spread-link cohort (DESIGN.md §12)
//!
//! Each driver writes per-scheme CSV series (`<out>/<exp>_<scheme>_
//! rounds.csv`, `…_evals.csv`) for the "vs iterations" / "vs bits"
//! figures plus a markdown table mirroring the paper's columns.

pub mod controllers;
pub mod fig1;
pub mod overhead;
pub mod plot;
pub mod serve;

use anyhow::{bail, Result};

use crate::cli::Args;
use crate::compress::pipeline::PipelineSpec;
use crate::config::{
    AggregationConfig, Backend, ExperimentConfig, PPolicy, ParticipationConfig, QuorumConfig,
    SchemeConfig,
};
use crate::control::ControllerConfig;
use crate::net::faults::FaultPlan;
use crate::fl::metrics::{markdown_table, TableRow};
use crate::fl::session::{FlSessionBuilder, RunReport};

/// Dispatch `qrr exp <id>`.
pub fn run_cli(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("table1");
    let out = args.get("out").unwrap_or("results");
    match id {
        "table1" => run_table(1, args, out),
        "table2" => run_table(2, args, out),
        "table3" => run_table(3, args, out),
        "fig1" => fig1::run(args, out),
        "overhead" => overhead::run(args, out),
        "controllers" => controllers::run(args, out),
        "all" => {
            fig1::run(args, out)?;
            run_table(1, args, out)?;
            run_table(2, args, out)?;
            run_table(3, args, out)?;
            overhead::run(args, out)
        }
        other => bail!(
            "unknown experiment {other:?} (table1|table2|table3|fig1|overhead|controllers|all)"
        ),
    }
}

/// Apply common CLI overrides to a config.
pub fn apply_overrides(cfg: &mut ExperimentConfig, args: &Args) -> Result<()> {
    if let Some(v) = args.get_parsed::<u64>("iters")? {
        cfg.iters = v;
    }
    if let Some(v) = args.get_parsed::<usize>("clients")? {
        cfg.clients = v;
    }
    if let Some(v) = args.get_parsed::<usize>("batch")? {
        cfg.batch = v;
    }
    if let Some(v) = args.get_parsed::<usize>("train-n")? {
        cfg.train_n = v;
    }
    if let Some(v) = args.get_parsed::<usize>("test-n")? {
        cfg.test_n = v;
    }
    if let Some(v) = args.get_parsed::<u64>("eval-every")? {
        cfg.eval_every = v.max(1);
    }
    if let Some(v) = args.get_parsed::<u64>("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = args.get_parsed::<usize>("shards")? {
        anyhow::ensure!(v > 0, "--shards must be positive");
        cfg.shards = Some(v);
    }
    if let Some(v) = args.get("backend") {
        cfg.backend = match v {
            "native" => Backend::Native,
            "pjrt" => Backend::Pjrt,
            other => bail!("unknown backend {other:?}"),
        };
    }
    if let Some(v) = args.get("participation") {
        cfg.participation = ParticipationConfig::parse(v)?;
    }
    if let Some(v) = args.get("aggregation") {
        cfg.aggregation = AggregationConfig::parse(v)?;
    }
    if let Some(v) = args.get("uplink") {
        cfg.uplink = Some(
            PipelineSpec::parse(v).map_err(|e| anyhow::anyhow!("--uplink: {e}"))?,
        );
    }
    if let Some(v) = args.get("downlink") {
        let spec =
            PipelineSpec::parse(v).map_err(|e| anyhow::anyhow!("--downlink: {e}"))?;
        spec.validate_downlink()
            .map_err(|e| anyhow::anyhow!("--downlink: {e}"))?;
        cfg.downlink = Some(spec);
    }
    if let Some(v) = args.get("controller") {
        cfg.controller = Some(
            ControllerConfig::parse(v).map_err(|e| anyhow::anyhow!("--controller: {e}"))?,
        );
    }
    if let Some(v) = args.get("chaos") {
        cfg.chaos =
            Some(FaultPlan::parse(v).map_err(|e| anyhow::anyhow!("--chaos: {e}"))?);
    }
    if let Some(v) = args.get_parsed::<u64>("chaos-seed")? {
        // reseed the plan (creating an otherwise-empty one if --chaos
        // was absent, e.g. when the plan comes from the config file)
        cfg.chaos.get_or_insert_with(FaultPlan::default).seed = v;
    }
    if let Some(v) = args.get("quorum") {
        let q = QuorumConfig::parse(v).map_err(|e| anyhow::anyhow!("--quorum: {e}"))?;
        q.validate().map_err(|e| anyhow::anyhow!("--quorum: {e}"))?;
        cfg.quorum = Some(q);
    }
    if args.has_flag("streaming") {
        cfg.streaming = true;
    }
    Ok(())
}

/// Parse `--schemes sgd,slaq,qrr:0.3,qrr:adaptive` into configs.
pub fn parse_schemes(spec: &str) -> Result<Vec<SchemeConfig>> {
    let mut out = Vec::new();
    for tok in spec.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        out.push(match tok {
            "sgd" => SchemeConfig::Sgd,
            "slaq" => SchemeConfig::Slaq,
            "qrr:adaptive" => SchemeConfig::Qrr(PPolicy::Adaptive { lo: 0.1, hi: 0.3 }),
            "ef:adaptive" => SchemeConfig::QrrEf(PPolicy::Adaptive { lo: 0.1, hi: 0.3 }),
            t if t.starts_with("qrr:") => {
                let p: f64 = t[4..]
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad qrr p in {t:?}"))?;
                SchemeConfig::Qrr(PPolicy::Fixed(p))
            }
            t if t.starts_with("ef:") => {
                let p: f64 = t[3..]
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad ef p in {t:?}"))?;
                SchemeConfig::QrrEf(PPolicy::Fixed(p))
            }
            t => bail!("unknown scheme {t:?}"),
        });
    }
    if out.is_empty() {
        bail!("--schemes parsed to nothing");
    }
    Ok(out)
}

/// The paper's scheme lineup for each table.
fn default_schemes(table: u8) -> Vec<SchemeConfig> {
    match table {
        1 | 2 => vec![
            SchemeConfig::Sgd,
            SchemeConfig::Slaq,
            SchemeConfig::Qrr(PPolicy::Fixed(0.3)),
            SchemeConfig::Qrr(PPolicy::Fixed(0.2)),
            SchemeConfig::Qrr(PPolicy::Fixed(0.1)),
        ],
        _ => vec![
            SchemeConfig::Sgd,
            SchemeConfig::Slaq,
            SchemeConfig::Qrr(PPolicy::Adaptive { lo: 0.1, hi: 0.3 }),
        ],
    }
}

/// Run one of the three table experiments across its scheme lineup.
pub fn run_table(table: u8, args: &Args, out_dir: &str) -> Result<()> {
    let base = match table {
        1 => ExperimentConfig::table1_default(),
        2 => ExperimentConfig::table2_default(),
        3 => ExperimentConfig::table3_default(),
        _ => bail!("no table {table}"),
    };
    let schemes = match args.get("schemes") {
        Some(s) => parse_schemes(s)?,
        None => default_schemes(table),
    };

    let mut rows: Vec<TableRow> = Vec::new();
    let mut histories = Vec::new();
    for scheme in schemes {
        let mut cfg = base.clone();
        cfg.scheme = scheme;
        apply_overrides(&mut cfg, args)?;
        cfg.name = format!("table{table}");
        log::info!(
            "=== table{table}: {} ({:?}, {} iters, {} clients, participation {}) ===",
            scheme.label(),
            cfg.model,
            cfg.iters,
            cfg.clients,
            cfg.participation.label()
        );
        let mut session = FlSessionBuilder::new(&cfg).build()?;
        let report = session.run()?;
        write_run_outputs(out_dir, &format!("table{table}_{}", slug(&scheme.label())), &report)?;
        rows.push(report.history.table_row());
        histories.push(report.history);
    }

    // the figure panels (Figures 2/3/4) as ASCII plots
    let fig_num = table + 1; // Table I -> Figure 2, etc.
    let panels = plot::figure_panels(&histories);
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(format!("{out_dir}/figure{fig_num}.txt"), &panels)?;

    let md = markdown_table(&rows);
    let table_path = format!("{out_dir}/table{table}.md");
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(&table_path, &md)?;
    println!("\nTABLE {table} (paper: Table {})\n{md}", roman(table));
    println!("series CSVs + markdown in {out_dir}/");
    print_ratios(&rows);
    Ok(())
}

/// Print QRR-vs-baseline bit ratios (the paper's headline comparison).
fn print_ratios(rows: &[TableRow]) {
    let sgd = rows.iter().find(|r| r.algorithm == "SGD");
    let slaq = rows.iter().find(|r| r.algorithm == "SLAQ");
    for r in rows.iter().filter(|r| r.algorithm.starts_with("QRR")) {
        let mut line = format!("{}: ", r.algorithm);
        if let Some(s) = sgd {
            line.push_str(&format!(
                "{:.2}% of SGD bits",
                100.0 * r.bits as f64 / s.bits as f64
            ));
        }
        if let Some(s) = slaq {
            line.push_str(&format!(
                ", {:.2}% of SLAQ bits",
                100.0 * r.bits as f64 / s.bits as f64
            ));
        }
        if let (Some(s), true) = (sgd, r.accuracy.is_finite()) {
            line.push_str(&format!(
                ", accuracy {:+.2}% vs SGD",
                100.0 * (r.accuracy - s.accuracy)
            ));
        }
        println!("{line}");
    }
}

/// Write per-run CSV outputs.
pub fn write_run_outputs(out_dir: &str, name: &str, report: &RunReport) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(
        format!("{out_dir}/{name}_rounds.csv"),
        report.history.rounds_csv(),
    )?;
    std::fs::write(
        format!("{out_dir}/{name}_evals.csv"),
        report.history.evals_csv(),
    )?;
    if !report.history.client_rounds.is_empty() {
        std::fs::write(
            format!("{out_dir}/{name}_clients.csv"),
            report.history.clients_csv(),
        )?;
    }
    Ok(())
}

fn slug(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
        .collect()
}

fn roman(t: u8) -> &'static str {
    match t {
        1 => "I",
        2 => "II",
        3 => "III",
        _ => "?",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_parsing() {
        let s = parse_schemes("sgd,slaq,qrr:0.3,qrr:adaptive").unwrap();
        assert_eq!(s.len(), 4);
        assert_eq!(s[0], SchemeConfig::Sgd);
        assert_eq!(s[2], SchemeConfig::Qrr(PPolicy::Fixed(0.3)));
        assert!(matches!(s[3], SchemeConfig::Qrr(PPolicy::Adaptive { .. })));
        assert!(parse_schemes("nope").is_err());
        assert!(parse_schemes("").is_err());
        assert!(parse_schemes("qrr:abc").is_err());
    }

    #[test]
    fn default_lineups_match_paper() {
        assert_eq!(default_schemes(1).len(), 5);
        assert_eq!(default_schemes(3).len(), 3);
    }

    #[test]
    fn overrides_apply() {
        let mut cfg = ExperimentConfig::table1_default();
        let args = crate::cli::Args::parse(
            "exp table1 --iters 7 --clients 3 --seed 9 --shards 2"
                .split_whitespace()
                .map(String::from),
        );
        apply_overrides(&mut cfg, &args).unwrap();
        assert_eq!(cfg.iters, 7);
        assert_eq!(cfg.clients, 3);
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.shards, Some(2));
        assert!(!cfg.streaming, "--streaming must be opt-in");

        let mut cfg2 = ExperimentConfig::table1_default();
        let args = crate::cli::Args::parse(
            "exp table1 --streaming".split_whitespace().map(String::from),
        );
        apply_overrides(&mut cfg2, &args).unwrap();
        assert!(cfg2.streaming);

        let bad = crate::cli::Args::parse(
            "exp table1 --shards 0".split_whitespace().map(String::from),
        );
        let mut cfg = ExperimentConfig::table1_default();
        assert!(apply_overrides(&mut cfg, &bad).is_err());
    }

    #[test]
    fn participation_and_aggregation_overrides_apply() {
        let mut cfg = ExperimentConfig::table1_default();
        let args = crate::cli::Args::parse(
            "exp table1 --participation dropout:0.6:0.5 --aggregation weighted_mean"
                .split_whitespace()
                .map(String::from),
        );
        apply_overrides(&mut cfg, &args).unwrap();
        assert_eq!(
            cfg.participation,
            ParticipationConfig::Dropout { fraction: 0.6, drop_prob: 0.5 }
        );
        assert_eq!(cfg.aggregation, AggregationConfig::WeightedMean);

        let bad = crate::cli::Args::parse(
            "exp table1 --participation sometimes".split_whitespace().map(String::from),
        );
        let mut cfg = ExperimentConfig::table1_default();
        assert!(apply_overrides(&mut cfg, &bad).is_err());
    }

    #[test]
    fn uplink_downlink_overrides_apply() {
        let mut cfg = ExperimentConfig::table1_default();
        let args = crate::cli::Args::parse(
            "exp table1 --uplink qrr(p=0.2) --downlink svd(p=0.1)+laq(beta=8)"
                .split_whitespace()
                .map(String::from),
        );
        apply_overrides(&mut cfg, &args).unwrap();
        assert_eq!(cfg.uplink, Some(PipelineSpec::qrr(0.2, 8)));
        assert_eq!(
            cfg.downlink,
            Some(PipelineSpec::parse("svd(p=0.1)+laq(beta=8)").unwrap())
        );

        for bad in ["--downlink laq(beta=8)+lazy", "--uplink nonsense"] {
            let mut cfg = ExperimentConfig::table1_default();
            let args = crate::cli::Args::parse(
                format!("exp table1 {bad}").split_whitespace().map(String::from),
            );
            assert!(apply_overrides(&mut cfg, &args).is_err(), "{bad}");
        }
    }

    #[test]
    fn controller_override_applies() {
        let mut cfg = ExperimentConfig::table1_default();
        let args = crate::cli::Args::parse(
            "exp table1 --controller aimd(target_ms=100)"
                .split_whitespace()
                .map(String::from),
        );
        apply_overrides(&mut cfg, &args).unwrap();
        match cfg.controller {
            Some(ControllerConfig::Aimd { target_ms, .. }) => {
                assert!((target_ms - 100.0).abs() < 1e-12)
            }
            other => panic!("expected aimd controller, got {other:?}"),
        }

        let bad = crate::cli::Args::parse(
            "exp table1 --controller pid(kp=1)".split_whitespace().map(String::from),
        );
        let mut cfg = ExperimentConfig::table1_default();
        assert!(apply_overrides(&mut cfg, &bad).is_err());
    }

    #[test]
    fn tiny_end_to_end_table_run() {
        let dir = std::env::temp_dir().join("qrr_exp_test");
        let args = crate::cli::Args::parse(
            "exp table1 --iters 4 --clients 2 --batch 8 --train-n 100 --test-n 40 --eval-every 2 --schemes sgd,qrr:0.2"
                .split_whitespace()
                .map(String::from),
        );
        run_table(1, &args, dir.to_str().unwrap()).unwrap();
        assert!(dir.join("table1.md").exists());
        assert!(dir.join("table1_sgd_rounds.csv").exists());
        assert!(dir.join("table1_qrr_p_0_2__evals.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
