//! §III-B last paragraph: client-side memory and compute overhead of
//! QRR and SLAQ relative to plain SGD.
//!
//! The paper (VGG-like / CIFAR-10 setup) reports:
//! * QRR:  ~1.2× memory, ~3.82× compute time vs SGD
//! * SLAQ: ~13× memory, ~1.08× compute time vs SGD
//!
//! Memory here = scheme state bytes relative to one gradient copy
//! (SGD's working set). Compute = median wall-clock of one full client
//! step (gradient + encode).

use std::sync::Arc;

use anyhow::Result;

use crate::bench_util::Bench;
use crate::cli::Args;
use crate::data::synth;
use crate::fl::{make_client_scheme, FlClient, SchemeKind};
use crate::model::{native::NativeModel, ModelKind, ModelOps, ModelSpec};
use crate::net::LinkModel;

/// One scheme's overhead measurements.
#[derive(Debug, Clone)]
pub struct OverheadRow {
    /// scheme label
    pub scheme: String,
    /// client state bytes
    pub mem_bytes: usize,
    /// memory relative to one gradient copy
    pub mem_ratio: f64,
    /// median client-step seconds
    pub step_secs: f64,
    /// step time relative to SGD
    pub time_ratio: f64,
}

/// Run the overhead experiment; writes `<out>/overhead.md`.
pub fn run(args: &Args, out_dir: &str) -> Result<()> {
    let model_kind = args
        .get("model")
        .map(|m| crate::model::ModelKind::parse(m).ok_or_else(|| anyhow::anyhow!("bad model {m}")))
        .transpose()?
        .unwrap_or(ModelKind::Vgg);
    let batch: usize = args.get_parsed::<usize>("batch")?.unwrap_or(64);
    let rows = measure(model_kind, batch)?;

    let mut md = String::from("| Scheme | Memory (bytes) | Memory ×SGD | Step time | Time ×SGD |\n|---|---|---|---|---|\n");
    for r in &rows {
        md.push_str(&format!(
            "| {} | {} | {:.2}x | {:.1} ms | {:.2}x |\n",
            r.scheme,
            crate::util::fmt::bytes_human(r.mem_bytes as u64),
            r.mem_ratio,
            r.step_secs * 1e3,
            r.time_ratio
        ));
    }
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(format!("{out_dir}/overhead.md"), &md)?;
    println!("Client-side overhead ({:?}, batch {batch}) — paper: QRR 1.2x mem / 3.82x time, SLAQ 13x mem / 1.08x time\n{md}", model_kind);
    Ok(())
}

/// Measure memory + step time for SGD / SLAQ / QRR(0.2).
pub fn measure(kind: ModelKind, batch: usize) -> Result<Vec<OverheadRow>> {
    let spec = ModelSpec::new(kind);
    let shapes = spec.shapes();
    let grad_bytes: usize = spec.num_params() * 4; // one gradient copy
    let weights = spec.init_params(11);
    let bench = Bench::from_env();

    let schemes = [
        ("SGD", SchemeKind::Sgd),
        ("SLAQ", SchemeKind::Slaq),
        ("QRR(p=0.2)", SchemeKind::Qrr { p: 0.2 }),
    ];
    let mut rows = Vec::new();
    let mut sgd_time = None;
    for (label, sk) in schemes {
        let model: Arc<dyn ModelOps + Sync> = Arc::new(NativeModel::new(kind));
        let data = synth::stream_for_input(batch * 4, 13, spec.input_dim());
        let scheme = make_client_scheme(sk, &shapes, 8, 0.001, 10);
        let mut client = FlClient::new(
            0,
            data,
            model,
            scheme,
            LinkModel::broadband(),
            batch,
            17,
        );
        let r = bench.run(&format!("client_step/{label}"), None, || {
            client.round(&weights)
        });
        let mem = client.scheme_mem_bytes();
        let secs = r.median.as_secs_f64();
        if label == "SGD" {
            sgd_time = Some(secs);
        }
        rows.push(OverheadRow {
            scheme: label.to_string(),
            mem_bytes: mem,
            // SGD baseline working memory = one gradient copy
            mem_ratio: (grad_bytes + mem) as f64 / grad_bytes as f64,
            step_secs: secs,
            time_ratio: secs / sgd_time.unwrap_or(secs),
        });
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_matches_paper() {
        std::env::set_var("QRR_BENCH_FAST", "1");
        // small model for test speed
        let rows = measure(ModelKind::Mlp, 16).unwrap();
        let sgd = &rows[0];
        let slaq = &rows[1];
        let qrr = &rows[2];
        assert_eq!(sgd.mem_bytes, 0);
        // SLAQ keeps full-gradient state: much more memory than QRR
        assert!(slaq.mem_bytes > 3 * qrr.mem_bytes, "{} vs {}", slaq.mem_bytes, qrr.mem_bytes);
        // QRR pays compute for SVD: slower than SGD
        assert!(qrr.time_ratio >= 1.0);
        // SLAQ time close to SGD (within noise, generous bound)
        assert!(slaq.time_ratio < qrr.time_ratio * 2.0);
    }
}
