//! `qrr serve`: the FL round loop over a real TCP socket — updates leave
//! as framed wire bytes, cross a socket, and are decoded server-side,
//! proving the request path composes outside the in-process simulation.
//!
//! Since the session refactor this is a thin wrapper over
//! [`FlSessionBuilder`] with the [`TcpTransport`] binding plugged in:
//! every upload opens a connection, pushes its framed update and
//! disconnects (sensor-style duty cycle); the server side accepts and
//! drains frames with `recv_timeout`, so a vanished client cannot hang
//! a round.

use std::time::Duration;

use anyhow::Result;

use crate::cli::Args;
use crate::config::{ExperimentConfig, PPolicy, SchemeConfig};
use crate::fl::session::FlSessionBuilder;
use crate::model::ModelKind;
use crate::net::transport::TcpTransport;
use crate::util::fmt::bits_sci;

/// Run `qrr serve` from CLI args.
pub fn run_cli(args: &Args) -> Result<()> {
    let model = args
        .get("model")
        .map(|m| ModelKind::parse(m).ok_or_else(|| anyhow::anyhow!("bad model {m}")))
        .transpose()?
        .unwrap_or(ModelKind::Mlp);
    let clients: usize = args.get_parsed::<usize>("clients")?.unwrap_or(3);
    let iters: u64 = args.get_parsed::<u64>("iters")?.unwrap_or(5);
    let batch: usize = args.get_parsed::<usize>("batch")?.unwrap_or(32);
    let addr = args.get("addr").unwrap_or("127.0.0.1:0");
    let p: f64 = args.get_parsed::<f64>("p")?.unwrap_or(0.2);
    let report = serve(model, clients, iters, batch, addr, p)?;
    println!("{report}");
    Ok(())
}

/// Run the TCP round loop; returns a human-readable report.
pub fn serve(
    model_kind: ModelKind,
    n_clients: usize,
    iters: u64,
    batch: usize,
    addr: &str,
    p: f64,
) -> Result<String> {
    let cfg = {
        let mut c = ExperimentConfig::table1_default();
        c.model = model_kind;
        c.scheme = SchemeConfig::Qrr(PPolicy::Fixed(p));
        c.clients = n_clients;
        c.batch = batch;
        c.iters = iters;
        c.eval_every = iters.max(1);
        // small synthetic stream: serve demonstrates transport, not scale
        c.train_n = (batch * 8 * n_clients).max(n_clients);
        c.test_n = 64;
        c
    };

    let transport = TcpTransport::bind(addr)?;
    let srv_addr = transport.local_addr();
    log::info!("server listening on {srv_addr}");

    let mut session = FlSessionBuilder::new(&cfg)
        .transport(Box::new(transport))
        .recv_timeout(Duration::from_secs(5))
        .build()?;
    let report = session.run()?;

    Ok(format!(
        "served {iters} rounds x {n_clients} clients over TCP ({srv_addr}); \
         payload bits {} across {} communications",
        bits_sci(report.history.total_bits()),
        report.history.total_comms(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcp_round_loop_completes() {
        let report = serve(ModelKind::Mlp, 2, 2, 8, "127.0.0.1:0", 0.2).unwrap();
        assert!(report.contains("served 2 rounds"), "{report}");
        assert!(report.contains("across 4 communications"), "{report}");
    }
}
