//! `qrr serve`: the FL round over a real TCP socket — server and client
//! processes exchange the exact wire format, proving the request path
//! composes outside the in-process simulation.
//!
//! Topology: the server thread binds a listener; each simulated client
//! runs in its own thread, connects per round, pushes its framed update
//! and disconnects (sensor-style duty cycle). The server decodes,
//! aggregates and logs round metrics.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::cli::Args;
use crate::config::{ExperimentConfig, SchemeConfig};
use crate::data::synth;
use crate::fl::{make_client_scheme, make_server_scheme, FlClient, FlServer};
use crate::model::{native::NativeModel, ModelKind, ModelOps, ModelSpec};
use crate::net::transport::{TcpClient, TcpServerTransport};
use crate::net::LinkModel;
use crate::util::Rng;

/// Run `qrr serve` from CLI args.
pub fn run_cli(args: &Args) -> Result<()> {
    let model = args
        .get("model")
        .map(|m| ModelKind::parse(m).ok_or_else(|| anyhow::anyhow!("bad model {m}")))
        .transpose()?
        .unwrap_or(ModelKind::Mlp);
    let clients: usize = args.get_parsed::<usize>("clients")?.unwrap_or(3);
    let iters: u64 = args.get_parsed::<u64>("iters")?.unwrap_or(5);
    let batch: usize = args.get_parsed::<usize>("batch")?.unwrap_or(32);
    let addr = args.get("addr").unwrap_or("127.0.0.1:0");
    let p: f64 = args.get_parsed::<f64>("p")?.unwrap_or(0.2);
    let report = serve(model, clients, iters, batch, addr, p)?;
    println!("{report}");
    Ok(())
}

/// Run the TCP round loop; returns a human-readable report.
pub fn serve(
    model_kind: ModelKind,
    n_clients: usize,
    iters: u64,
    batch: usize,
    addr: &str,
    p: f64,
) -> Result<String> {
    let cfg = {
        let mut c = ExperimentConfig::table1_default();
        c.model = model_kind;
        c.scheme = SchemeConfig::Qrr(crate::config::PPolicy::Fixed(p));
        c.clients = n_clients;
        c.batch = batch;
        c
    };
    let spec = ModelSpec::new(model_kind);
    let shapes = spec.shapes();
    let model: Arc<dyn ModelOps + Sync> = Arc::new(NativeModel::new(model_kind));

    let listener = TcpServerTransport::bind(addr)?;
    let srv_addr = listener.local_addr()?;
    log::info!("server listening on {srv_addr}");

    // server state
    let per_client = (0..n_clients)
        .map(|_| make_server_scheme(crate::fl::SchemeKind::Qrr { p }, &shapes, cfg.beta))
        .collect();
    let mut server = FlServer::new(spec.init_params(cfg.seed), per_client, cfg.alpha0());

    // clients (threads); weights shared via a mutex "broadcast board"
    let board: Arc<Mutex<Vec<crate::tensor::Tensor>>> =
        Arc::new(Mutex::new(server.params().to_vec()));
    let mut handles = Vec::new();
    let mut seed_rng = Rng::new(cfg.seed);
    for i in 0..n_clients {
        let board = Arc::clone(&board);
        let model = Arc::clone(&model);
        let shapes = shapes.clone();
        let data = synth::stream_for_input(batch * 8, seed_rng.next_u64(), spec.input_dim());
        let seed = seed_rng.next_u64();
        let beta = cfg.beta;
        let alpha = cfg.alpha0();
        handles.push(std::thread::spawn(move || -> Result<u64> {
            let scheme = make_client_scheme(
                crate::fl::SchemeKind::Qrr { p },
                &shapes,
                beta,
                alpha,
                n_clients,
            );
            let mut client = FlClient::new(
                i as u32,
                data,
                model,
                scheme,
                LinkModel::broadband(),
                batch,
                seed,
            );
            let mut bits = 0u64;
            for _ in 0..iters {
                let weights = board.lock().unwrap().clone();
                let out = client.round(&weights);
                bits += out.payload_bits;
                if let Some(wire) = out.wire {
                    let mut conn = TcpClient::connect(srv_addr)?;
                    conn.send(&wire)?;
                }
            }
            Ok(bits)
        }));
    }

    // server loop: one round = n_clients frames
    let mut total_bits_wire = 0u64;
    for round in 0..iters {
        let mut frames: Vec<Vec<u8>> = Vec::with_capacity(n_clients);
        while frames.len() < n_clients {
            let before = frames.len();
            listener.serve_once(|f| frames.push(f))?;
            if frames.len() == before {
                anyhow::bail!("client disconnected without sending");
            }
        }
        // order by client id from the wire header
        let mut slots: Vec<Option<Vec<u8>>> = vec![None; n_clients];
        for f in frames {
            let d = crate::net::Decoder::decode(&f)?;
            total_bits_wire += 8 * f.len() as u64;
            slots[d.client_id as usize] = Some(f);
        }
        let grad_norm = server.aggregate_wire(&slots)?;
        *board.lock().unwrap() = server.params().to_vec();
        log::info!("round {round}: grad_norm {grad_norm:.4}");
    }

    let mut client_bits = 0u64;
    for h in handles {
        client_bits += h.join().map_err(|_| anyhow::anyhow!("client panicked"))??;
    }
    Ok(format!(
        "served {iters} rounds x {n_clients} clients over TCP ({srv_addr}); \
         payload bits {} (wire bytes x8: {})",
        crate::util::fmt::bits_sci(client_bits),
        crate::util::fmt::bits_sci(total_bits_wire),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcp_round_loop_completes() {
        let report = serve(ModelKind::Mlp, 2, 2, 8, "127.0.0.1:0", 0.2).unwrap();
        assert!(report.contains("served 2 rounds"), "{report}");
    }
}
