//! `qrr serve`: the FL round loop over a real TCP socket — updates leave
//! as framed wire bytes, cross a socket, and are decoded server-side,
//! proving the request path composes outside the in-process simulation.
//!
//! Since the session refactor this is a thin wrapper over
//! [`FlSessionBuilder`] with the [`TcpTransport`] binding plugged in:
//! every upload opens a connection, pushes its framed update and
//! disconnects (sensor-style duty cycle); the server's non-blocking
//! event loop reassembles frames incrementally and `recv_timeout`
//! bounds the round, so a vanished or stalled client cannot hang it.
//! Arriving frames are routed by a header peek to one of `--shards`
//! aggregation lanes (DESIGN.md §10).
//!
//! `--scale-clients N` switches to the scale smoke: N synthetic clients
//! push tiny pre-encoded SGD frames over loopback TCP into a
//! [`ShardedAggregator`], and the run fails unless the round completes
//! with every client delivered and the peak number of simultaneously
//! live decoded updates within the shard bound.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::cli::Args;
use crate::config::{ExperimentConfig, PPolicy, SchemeConfig};
use crate::fl::scheme::{make_server_scheme, SchemeKind};
use crate::fl::session::FlSessionBuilder;
use crate::fl::ShardedAggregator;
use crate::model::ModelKind;
use crate::net::transport::{TcpClient, TcpTransport, Transport, TransportError};
use crate::net::{ClientUpdate, Decoder, Encoder};
use crate::tensor::Tensor;
use crate::util::fmt::bits_sci;

/// Run `qrr serve` from CLI args.
pub fn run_cli(args: &Args) -> Result<()> {
    let addr = args.get("addr").unwrap_or("127.0.0.1:0");
    let shards: Option<usize> = args.get_parsed::<usize>("shards")?;

    if let Some(scale) = args.get_parsed::<usize>("scale-clients")? {
        let report = scale_smoke(scale, shards.unwrap_or(4), addr, args.has_flag("streaming"))?;
        println!("{report}");
        return Ok(());
    }

    let model = args
        .get("model")
        .map(|m| ModelKind::parse(m).ok_or_else(|| anyhow::anyhow!("bad model {m}")))
        .transpose()?
        .unwrap_or(ModelKind::Mlp);
    let clients: usize = args.get_parsed::<usize>("clients")?.unwrap_or(3);
    let iters: u64 = args.get_parsed::<u64>("iters")?.unwrap_or(5);
    let batch: usize = args.get_parsed::<usize>("batch")?.unwrap_or(32);
    let p: f64 = args.get_parsed::<f64>("p")?.unwrap_or(0.2);
    let report = serve(model, clients, iters, batch, addr, p, shards)?;
    println!("{report}");
    Ok(())
}

/// Run the TCP round loop; returns a human-readable report.
pub fn serve(
    model_kind: ModelKind,
    n_clients: usize,
    iters: u64,
    batch: usize,
    addr: &str,
    p: f64,
    shards: Option<usize>,
) -> Result<String> {
    let cfg = {
        let mut c = ExperimentConfig::table1_default();
        c.model = model_kind;
        c.scheme = SchemeConfig::Qrr(PPolicy::Fixed(p));
        c.clients = n_clients;
        c.batch = batch;
        c.iters = iters;
        c.eval_every = iters.max(1);
        c.shards = shards;
        // small synthetic stream: serve demonstrates transport, not scale
        c.train_n = (batch * 8 * n_clients).max(n_clients);
        c.test_n = 64;
        c
    };

    let transport = TcpTransport::bind(addr)?;
    let srv_addr = transport.local_addr();
    log::info!("server listening on {srv_addr}");

    let mut session = FlSessionBuilder::new(&cfg)
        .transport(Box::new(transport))
        .recv_timeout(Duration::from_secs(5))
        .build()?;
    let report = session.run()?;
    let (n_shards, peak) = (session.n_shards(), session.peak_live());

    Ok(format!(
        "served {iters} rounds x {n_clients} clients over TCP ({srv_addr}); \
         payload bits {} across {} communications; \
         {n_shards} aggregation shard(s), peak {peak} live decoded update(s)",
        bits_sci(report.history.total_bits()),
        report.history.total_comms(),
    ))
}

/// The `--scale-clients` loopback smoke: `n_clients` synthetic senders
/// push tiny SGD updates over real sockets; the server routes every
/// completed frame to its aggregation shard as it arrives. With
/// `streaming`, each update crosses as per-layer chunk frames and the
/// server reassembles decode-on-arrival (DESIGN.md §13): every sender
/// thread then holds one persistent connection whose clients all map to
/// the same shard, so per-connection TCP ordering keeps at most one
/// chunk assembly open per shard lane and the `peak_live <= shards`
/// bound stays sharp. Errors (non-zero exit from the CLI) if the round
/// does not complete or the peak count of live decoded updates exceeds
/// the shard count.
pub fn scale_smoke(
    n_clients: usize,
    n_shards: usize,
    addr: &str,
    streaming: bool,
) -> Result<String> {
    anyhow::ensure!(n_clients > 0, "need at least one client");
    let shapes: Vec<Vec<usize>> = vec![vec![32, 16], vec![32]];
    let n_layers = shapes.len();
    let schemes = (0..n_clients)
        .map(|_| make_server_scheme(SchemeKind::Sgd, &shapes, 8))
        .collect();
    let mut agg = ShardedAggregator::new(schemes, shapes.clone(), n_shards);

    let transport = TcpTransport::bind(addr)?;
    let srv_addr = transport.local_addr();
    log::info!(
        "scale smoke on {srv_addr}: {n_clients} clients -> {} shard(s){}",
        agg.n_shards(),
        if streaming { ", streamed chunks" } else { "" }
    );
    agg.begin_round(&vec![1.0f32; n_clients], true);

    // sender fleet: threads share the client id space. Whole-frame mode:
    // each id opens a connection, pushes its framed update and
    // disconnects — the sensor duty cycle at cohort scale. Streaming
    // mode: sender count equals the shard count so thread t's clients
    // (t, t+s, ...) all land in shard t, and one persistent connection
    // serializes their chunks.
    let senders = if streaming { agg.n_shards().min(n_clients) } else { 8.min(n_clients) };
    let started = Instant::now();
    let mut handles = Vec::with_capacity(senders);
    for t in 0..senders {
        let shapes = shapes.clone();
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut conn = if streaming { Some(TcpClient::connect(srv_addr)?) } else { None };
            let mut id = t;
            while id < n_clients {
                let mut rng = crate::util::Rng::new(0x5CA1E ^ id as u64);
                let grads: Vec<Tensor> =
                    shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
                let update = ClientUpdate::Sgd { grads };
                match conn.as_mut() {
                    Some(c) => {
                        for layer in 0..update.n_layers() {
                            c.send(&Encoder::chunk(&update, layer, id as u32, 0))?;
                        }
                    }
                    None => {
                        let bytes = Encoder::new(&update, id as u32, 0);
                        TcpClient::connect(srv_addr)?.send(&bytes)?;
                    }
                }
                id += senders;
            }
            Ok(())
        }));
    }

    // server loop: header-only peek routes each completed frame to its
    // shard lane; the body decode + absorb happen there
    let mut received = 0usize;
    let expected = if streaming { n_clients * n_layers } else { n_clients };
    let deadline = Instant::now() + Duration::from_secs(120);
    while received < expected && Instant::now() < deadline {
        match transport.recv_timeout(Duration::from_millis(500)) {
            Ok(frame) => {
                if streaming {
                    let header = match Decoder::peek_chunk_header(&frame) {
                        Ok(h) => h,
                        Err(e) => {
                            log::warn!("scale smoke: discarding undecodable chunk ({e})");
                            continue;
                        }
                    };
                    let id = header.client_id as usize;
                    if id >= n_clients {
                        log::warn!("scale smoke: discarding out-of-range client id {id}");
                        continue;
                    }
                    agg.dispatch_chunk(id, frame);
                    received += 1;
                    continue;
                }
                let header = match Decoder::peek_header(&frame) {
                    Ok(h) => h,
                    Err(e) => {
                        log::warn!("scale smoke: discarding undecodable frame ({e})");
                        continue;
                    }
                };
                let id = header.client_id as usize;
                if id >= n_clients {
                    log::warn!("scale smoke: discarding out-of-range client id {id}");
                    continue;
                }
                agg.dispatch_frame(id, frame);
                received += 1;
            }
            Err(TransportError::TimedOut(_)) => continue,
            Err(e) => return Err(e.into()),
        }
    }
    for h in handles {
        h.join().map_err(|_| anyhow::anyhow!("sender thread panicked"))??;
    }

    let digest = agg.close_round();
    let delivered = digest.delivered.iter().filter(|&&d| d).count();
    anyhow::ensure!(
        delivered == n_clients,
        "round incomplete: {delivered}/{n_clients} delivered ({} decode failures)",
        digest.decode_failures
    );
    anyhow::ensure!(
        digest.peak_live <= agg.n_shards(),
        "peak live decoded updates {} exceeds shard count {}",
        digest.peak_live,
        agg.n_shards()
    );
    Ok(format!(
        "scale smoke{}: {n_clients}/{n_clients} clients delivered through {} shard(s) \
         in {:.1}s; peak {} live decoded update(s) (bound {})",
        if streaming { " (streamed)" } else { "" },
        agg.n_shards(),
        started.elapsed().as_secs_f64(),
        digest.peak_live,
        agg.n_shards()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcp_round_loop_completes() {
        let report = serve(ModelKind::Mlp, 2, 2, 8, "127.0.0.1:0", 0.2, Some(2)).unwrap();
        assert!(report.contains("served 2 rounds"), "{report}");
        assert!(report.contains("across 4 communications"), "{report}");
        assert!(report.contains("2 aggregation shard(s)"), "{report}");
    }

    #[test]
    fn scale_smoke_bounds_peak_live() {
        // small cohort here; CI runs the 2k-client variant
        let report = scale_smoke(64, 4, "127.0.0.1:0", false).unwrap();
        assert!(report.contains("64/64 clients delivered"), "{report}");
        assert!(report.contains("through 4 shard(s)"), "{report}");
    }

    #[test]
    fn streamed_scale_smoke_bounds_peak_live() {
        // chunked frames over real sockets; CI runs the 2k-client variant
        let report = scale_smoke(64, 4, "127.0.0.1:0", true).unwrap();
        assert!(report.contains("scale smoke (streamed)"), "{report}");
        assert!(report.contains("64/64 clients delivered"), "{report}");
    }
}
