//! Figure 1: magnitude of the singular values of a fully connected
//! layer's gradient — the empirical justification for rank reduction.
//!
//! Reproduction: train the paper's MLP briefly, take ∂J/∂W₁ (200×784)
//! of one client batch, run an exact SVD and dump all 200 singular
//! values. The paper observes "only a few of the 128 singular values are
//! significantly larger than 0"; the same sharp decay appears here.

use anyhow::Result;

use crate::cli::Args;
use crate::data::synth;
use crate::linalg::svd_jacobi;
use crate::model::{native::NativeModel, ModelKind, ModelOps, ModelSpec};
use crate::util::Rng;

/// Run the figure-1 driver; writes `<out>/fig1_spectrum.csv`.
pub fn run(args: &Args, out_dir: &str) -> Result<()> {
    let warmup: u64 = args.get_parsed::<u64>("warmup-iters")?.unwrap_or(20);
    let batch: usize = args.get_parsed::<usize>("batch")?.unwrap_or(512);
    let seed: u64 = args.get_parsed::<u64>("seed")?.unwrap_or(42);

    let (sigmas, energy) = spectrum(warmup, batch, seed);

    std::fs::create_dir_all(out_dir)?;
    let mut csv = String::from("index,sigma,cumulative_energy\n");
    let total: f64 = sigmas.iter().map(|&s| (s as f64) * (s as f64)).sum();
    let mut cum = 0f64;
    for (i, &s) in sigmas.iter().enumerate() {
        cum += (s as f64) * (s as f64);
        csv.push_str(&format!("{},{},{}\n", i, s, cum / total.max(1e-30)));
    }
    let path = format!("{out_dir}/fig1_spectrum.csv");
    std::fs::write(&path, csv)?;

    println!("Figure 1: singular values of dJ/dW1 (200x784 MLP gradient)");
    println!("  sigma_0    = {:.5}", sigmas[0]);
    println!("  sigma_9    = {:.5}", sigmas[9]);
    println!("  sigma_49   = {:.5}", sigmas[49]);
    println!("  sigma_last = {:.5}", sigmas[sigmas.len() - 1]);
    println!(
        "  rank capturing 95% energy: {} of {}",
        energy, sigmas.len()
    );
    println!("  series -> {path}");
    Ok(())
}

/// Compute the spectrum; returns (singular values, rank at 95% energy).
pub fn spectrum(warmup: u64, batch: usize, seed: u64) -> (Vec<f32>, usize) {
    let model = NativeModel::new(ModelKind::Mlp);
    let spec = ModelSpec::new(ModelKind::Mlp);
    let mut params = spec.init_params(seed);
    let data = synth::mnist_like(batch * (warmup as usize + 1), seed);
    let mut rng = Rng::new(seed ^ 1);

    // brief warmup so the gradient reflects a mid-training state (as in
    // the paper, not the random-init state)
    for _ in 0..warmup {
        let (x, y) = data.sample_batch(batch, &mut rng);
        let (_, grads) = model.loss_grad(&params, &x, &y);
        for (p, g) in params.iter_mut().zip(grads.iter()) {
            p.axpy(-0.05, g);
        }
    }
    let (x, y) = data.sample_batch(batch, &mut rng);
    let (_, grads) = model.loss_grad(&params, &x, &y);
    let svd = svd_jacobi(&grads[0]); // dJ/dW1: 200x784

    let total: f64 = svd.s.iter().map(|&s| (s as f64) * (s as f64)).sum();
    let mut cum = 0f64;
    let mut rank95 = svd.s.len();
    for (i, &s) in svd.s.iter().enumerate() {
        cum += (s as f64) * (s as f64);
        if cum >= 0.95 * total {
            rank95 = i + 1;
            break;
        }
    }
    (svd.s, rank95)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradient_spectrum_is_sharply_decaying() {
        // the paper's Figure-1 claim: few dominant singular values
        let (sigmas, rank95) = spectrum(5, 64, 7);
        assert_eq!(sigmas.len(), 200);
        // 95% of the energy in a small fraction of the spectrum
        assert!(
            rank95 < 40,
            "rank95 = {rank95}, spectrum not low-rank; head {:?}",
            &sigmas[..5]
        );
        // decay: sigma_0 >> sigma_50
        assert!(sigmas[0] > 10.0 * sigmas[50].max(1e-9));
    }

    #[test]
    fn driver_writes_csv() {
        let dir = std::env::temp_dir().join("qrr_fig1_test");
        let args = crate::cli::Args::parse(
            "exp fig1 --warmup-iters 2 --batch 32 --seed 3"
                .split_whitespace()
                .map(String::from),
        );
        run(&args, dir.to_str().unwrap()).unwrap();
        let csv = std::fs::read_to_string(dir.join("fig1_spectrum.csv")).unwrap();
        assert!(csv.lines().count() > 100);
        std::fs::remove_dir_all(&dir).ok();
    }
}
