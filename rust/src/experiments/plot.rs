//! ASCII line plots — renders the paper's figure panels (loss /
//! gradient-norm / accuracy vs iterations and vs transmitted bits) as
//! text, so `qrr exp` reproduces the *figures* too, without a plotting
//! stack. Written alongside the CSV series.

use std::fmt::Write as _;

/// One named series of (x, y) points.
#[derive(Debug)]
pub struct Series {
    /// legend label
    pub label: String,
    /// sorted-by-x data points
    pub points: Vec<(f64, f64)>,
}

/// Render series into a `width`×`height` character grid with axes.
/// `log_x` plots x on a log10 scale (used for the vs-bits panels).
pub fn ascii_plot(title: &str, series: &[Series], width: usize, height: usize, log_x: bool) -> String {
    let width = width.max(20);
    let height = height.max(5);
    let tx = |x: f64| if log_x { x.max(1.0).log10() } else { x };

    let (mut x0, mut x1) = (f64::MAX, f64::MIN);
    let (mut y0, mut y1) = (f64::MAX, f64::MIN);
    for s in series {
        for &(x, y) in &s.points {
            if !x.is_finite() || !y.is_finite() {
                continue;
            }
            let x = tx(x);
            x0 = x0.min(x);
            x1 = x1.max(x);
            y0 = y0.min(y);
            y1 = y1.max(y);
        }
    }
    if x0 >= x1 {
        x1 = x0 + 1.0;
    }
    if y0 >= y1 {
        y1 = y0 + 1.0;
    }

    let marks = ['*', '+', 'o', 'x', '#', '@', '%', '&'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        // draw with linear interpolation between consecutive points
        let proj = |x: f64, y: f64| -> (usize, usize) {
            let cx = ((tx(x) - x0) / (x1 - x0) * (width - 1) as f64).round() as usize;
            let cy = ((y - y0) / (y1 - y0) * (height - 1) as f64).round() as usize;
            (cx.min(width - 1), height - 1 - cy.min(height - 1))
        };
        for w in s.points.windows(2) {
            let (xa, ya) = w[0];
            let (xb, yb) = w[1];
            if ![xa, ya, xb, yb].iter().all(|v| v.is_finite()) {
                continue;
            }
            let steps = width.max(16);
            for t in 0..=steps {
                let f = t as f64 / steps as f64;
                let (cx, cy) = proj(xa + f * (xb - xa), ya + f * (yb - ya));
                grid[cy][cx] = mark;
            }
        }
        if s.points.len() == 1 {
            let (cx, cy) = proj(s.points[0].0, s.points[0].1);
            grid[cy][cx] = mark;
        }
    }

    let mut out = String::new();
    let _ = writeln!(out, "{title}");
    let _ = writeln!(out, "{:>9.3} ┤", y1);
    for row in &grid {
        let line: String = row.iter().collect();
        let _ = writeln!(out, "          │{line}");
    }
    let _ = writeln!(out, "{:>9.3} └{}", y0, "─".repeat(width));
    let xl = if log_x { format!("10^{x0:.1}") } else { format!("{x0:.0}") };
    let xr = if log_x { format!("10^{x1:.1}") } else { format!("{x1:.0}") };
    let pad = width.saturating_sub(xl.len() + xr.len());
    let _ = writeln!(out, "           {xl}{}{xr}", " ".repeat(pad));
    for (si, s) in series.iter().enumerate() {
        let _ = writeln!(out, "           {} {}", marks[si % marks.len()], s.label);
    }
    out
}

/// Build the three paper panels (test loss, accuracy, gradient norm —
/// each vs iterations and vs cumulative bits) for a set of histories.
pub fn figure_panels(histories: &[crate::fl::History]) -> String {
    let mut out = String::new();
    let evals = |f: &dyn Fn(&crate::fl::EvalPoint) -> f64, vs_bits: bool| -> Vec<Series> {
        histories
            .iter()
            .map(|h| Series {
                label: h.label.clone(),
                points: h
                    .evals
                    .iter()
                    .map(|e| {
                        let x = if vs_bits { e.cum_bits as f64 } else { (e.iter + 1) as f64 };
                        (x, f(e))
                    })
                    .collect(),
            })
            .collect()
    };
    out += &ascii_plot(
        "test loss vs iterations",
        &evals(&|e| e.loss as f64, false),
        72,
        14,
        false,
    );
    out += "\n";
    out += &ascii_plot(
        "test loss vs transmitted bits (log x)",
        &evals(&|e| e.loss as f64, true),
        72,
        14,
        true,
    );
    out += "\n";
    out += &ascii_plot(
        "accuracy vs iterations",
        &evals(&|e| e.accuracy, false),
        72,
        14,
        false,
    );
    out += "\n";
    out += &ascii_plot(
        "accuracy vs transmitted bits (log x)",
        &evals(&|e| e.accuracy, true),
        72,
        14,
        true,
    );
    // gradient norm comes from the per-round series
    let grad_series: Vec<Series> = histories
        .iter()
        .map(|h| Series {
            label: h.label.clone(),
            points: h
                .rounds
                .iter()
                .map(|r| ((r.iter + 1) as f64, r.grad_norm))
                .collect(),
        })
        .collect();
    out += "\n";
    out += &ascii_plot("gradient l2 norm vs iterations", &grad_series, 72, 14, false);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plot_renders_axes_and_legend() {
        let s = vec![
            Series { label: "a".into(), points: (0..20).map(|i| (i as f64, (i * i) as f64)).collect() },
            Series { label: "b".into(), points: (0..20).map(|i| (i as f64, (20 * i) as f64)).collect() },
        ];
        let out = ascii_plot("demo", &s, 40, 10, false);
        assert!(out.contains("demo"));
        assert!(out.contains("* a"));
        assert!(out.contains("+ b"));
        assert!(out.lines().count() > 12);
        // marks actually drawn
        assert!(out.contains('*') && out.contains('+'));
    }

    #[test]
    fn log_x_labels() {
        let s = vec![Series {
            label: "bits".into(),
            points: vec![(1e6, 1.0), (1e9, 0.5), (1e10, 0.2)],
        }];
        let out = ascii_plot("loss vs bits", &s, 40, 8, true);
        assert!(out.contains("10^"));
    }

    #[test]
    fn degenerate_inputs_no_panic() {
        let out = ascii_plot("empty", &[], 30, 6, false);
        assert!(out.contains("empty"));
        let s = vec![Series { label: "one".into(), points: vec![(1.0, 2.0)] }];
        let _ = ascii_plot("single", &s, 30, 6, false);
        let s = vec![Series { label: "nan".into(), points: vec![(f64::NAN, 1.0), (2.0, 1.0)] }];
        let _ = ascii_plot("nan", &s, 30, 6, true);
    }
}
