//! The SLAQ baseline — stochastic Lazily Aggregated Quantized gradients
//! (Sun et al. [22]; paper §II-B and the experimental comparator).
//!
//! Each client LAQ-quantizes its raw per-parameter gradients (no rank
//! reduction) and *lazily skips* the upload whenever the innovation is
//! too small to matter:
//!
//! ‖δQ_c^k‖₂² ≤ 1/(α²C²) · Σ_{d=1}^{D} ξ_d ‖θ^{k+1−d} − θ^{k−d}‖₂²
//!               + 3·(ε_c^k + ε̂_c)²                       (LAQ criterion)
//!
//! where ε are the ℓ2 quantization-error bounds implied by eq. (18).
//! The server keeps each client's last communicated quantized gradient
//! and aggregates ∇^k = Σ_c Q_c(latest) (eq. (13)); a skipped round
//! simply reuses the stale Q_c.
//!
//! Paper settings: D = 10, ξ_d = 1/D, β = 8.

use std::collections::VecDeque;

use crate::quant::{quantize, QuantState, Quantized};
use crate::tensor::Tensor;

/// SLAQ hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct SlaqConfig {
    /// Quantization bits β.
    pub beta: u8,
    /// Memory depth D of the parameter-difference window.
    pub d: usize,
    /// Learning rate α (enters the skip threshold).
    pub alpha: f32,
    /// Number of clients C (enters the skip threshold).
    pub clients: usize,
    /// Calibration constant multiplying the weight-motion term of the
    /// skip rule. The LAQ criterion's constant depends on smoothness
    /// assumptions the paper does not report; this scale is calibrated so
    /// the observed communication rate matches the paper's (~86% of
    /// rounds sent on MNIST — see EXPERIMENTS.md). `QRR_SLAQ_SCALE`
    /// overrides.
    pub threshold_scale: f64,
}

impl SlaqConfig {
    /// Paper defaults: β=8, D=10, ξ_d=1/D.
    pub fn paper(alpha: f32, clients: usize) -> Self {
        let threshold_scale = crate::util::env::slaq_scale().unwrap_or(0.02);
        SlaqConfig { beta: 8, d: 10, alpha, clients, threshold_scale }
    }
}

/// One client's message: quantized innovations for every parameter.
#[derive(Debug, Clone)]
pub struct SlaqMsg {
    /// Per-parameter quantized payloads.
    pub params: Vec<Quantized>,
}

impl SlaqMsg {
    /// Exact wire size in bits.
    pub fn wire_bits(&self) -> u64 {
        self.params.iter().map(|q| q.wire_bits()).sum()
    }
}

/// Client-side SLAQ state.
#[derive(Debug, Clone)]
pub struct SlaqClient {
    cfg: SlaqConfig,
    states: Vec<QuantState>,
    /// ε̂_c: ℓ2 error bound at the last *communicated* round.
    eps_hat: f64,
    /// window of ‖θ^{k+1−d} − θ^{k−d}‖² values, most recent first.
    theta_diffs: VecDeque<f64>,
    prev_theta: Option<Vec<Tensor>>,
    skipped: u64,
    sent: u64,
}

impl SlaqClient {
    /// New client for a model with the given parameter shapes.
    pub fn new(shapes: &[Vec<usize>], cfg: SlaqConfig) -> Self {
        SlaqClient {
            cfg,
            states: shapes.iter().map(|s| QuantState::zeros(s)).collect(),
            eps_hat: 0.0,
            theta_diffs: VecDeque::with_capacity(cfg.d + 1),
            prev_theta: None,
            skipped: 0,
            sent: 0,
        }
    }

    /// State memory footprint in bytes (the client-side overhead the
    /// paper reports as ~13× SGD for SLAQ).
    pub fn mem_bytes(&self) -> usize {
        self.states.iter().map(|s| s.mem_bytes()).sum::<usize>()
            + self
                .prev_theta
                .as_ref()
                .map(|t| t.iter().map(|x| x.len() * 4).sum::<usize>())
                .unwrap_or(0)
            + self.theta_diffs.len() * std::mem::size_of::<f64>()
    }

    /// (skipped, sent) counters.
    pub fn skip_stats(&self) -> (u64, u64) {
        (self.skipped, self.sent)
    }

    /// Observe the broadcast weights (call once per round, before
    /// [`SlaqClient::step`]) to maintain the θ-difference window.
    pub fn observe_weights(&mut self, theta: &[Tensor]) {
        if let Some(prev) = &self.prev_theta {
            let diff: f64 = prev
                .iter()
                .zip(theta.iter())
                .map(|(a, b)| crate::tensor::sq_norm(&a.sub(b)))
                .sum();
            self.theta_diffs.push_front(diff);
            while self.theta_diffs.len() > self.cfg.d {
                self.theta_diffs.pop_back();
            }
        }
        self.prev_theta = Some(theta.to_vec());
    }

    /// Quantize this round's gradients; `None` means the upload is
    /// lazily skipped (the server keeps using the stale quantized
    /// gradient).
    pub fn step(&mut self, grads: &[Tensor]) -> Option<SlaqMsg> {
        assert_eq!(grads.len(), self.states.len(), "gradient count mismatch");
        let beta = self.cfg.beta;
        let tau = 1.0f64 / ((1u32 << beta) - 1) as f64;

        // Candidate quantization (not yet committed).
        let mut msgs = Vec::with_capacity(grads.len());
        let mut new_vals = Vec::with_capacity(grads.len());
        let mut dq_sq = 0f64; // ||delta Q||^2
        let mut eps_sq = 0f64; // (eps_c^k)^2 = sum tau^2 R_t^2 n_t
        for (st, g) in self.states.iter().zip(grads.iter()) {
            let (q, new_val) = quantize(g, st.value(), beta);
            dq_sq += crate::tensor::sq_norm(&new_val.sub(st.value()));
            eps_sq += (tau * q.radius as f64).powi(2) * g.len() as f64;
            msgs.push(q);
            new_vals.push(new_val);
        }
        let eps = eps_sq.sqrt();

        // LAQ skip criterion.
        let window: f64 = self
            .theta_diffs
            .iter()
            .map(|&d| d / self.cfg.d as f64) // xi_d = 1/D
            .sum();
        let thresh = self.cfg.threshold_scale * window
            / (self.cfg.alpha as f64 * self.cfg.clients as f64).powi(2)
            + 3.0 * (eps + self.eps_hat).powi(2);

        // Never skip before anything was communicated.
        let can_skip = !self.theta_diffs.is_empty() && self.sent > 0;
        if can_skip && dq_sq <= thresh {
            self.skipped += 1;
            return None;
        }

        // Commit: advance local quantized state.
        for (st, nv) in self.states.iter_mut().zip(new_vals.into_iter()) {
            *st = QuantState::from_value(nv);
        }
        self.eps_hat = eps;
        self.sent += 1;
        Some(SlaqMsg { params: msgs })
    }

    #[cfg(test)]
    fn states(&self) -> &[QuantState] {
        &self.states
    }
}

/// Server-side per-client mirror: reconstructs and stores each client's
/// latest quantized gradient.
#[derive(Debug, Clone)]
pub struct SlaqServerState {
    states: Vec<QuantState>,
}

impl SlaqServerState {
    /// New mirror for one client.
    pub fn new(shapes: &[Vec<usize>]) -> Self {
        SlaqServerState { states: shapes.iter().map(|s| QuantState::zeros(s)).collect() }
    }

    /// True when `msg` carries one payload per parameter with the
    /// expected lengths — the precondition for [`Self::apply`] on
    /// externally controlled input.
    // qrr-audit: no-panic
    pub fn accepts(&self, msg: &SlaqMsg) -> bool {
        msg.params.len() == self.states.len()
            && self
                .states
                .iter()
                .zip(msg.params.iter())
                .all(|(st, q)| q.wellformed(st.value().len()))
    }
    // qrr-audit: end

    /// Apply a received message; afterwards [`Self::latest`] returns the
    /// client's new quantized gradient.
    pub fn apply(&mut self, msg: &SlaqMsg) {
        assert_eq!(msg.params.len(), self.states.len());
        for (st, q) in self.states.iter_mut().zip(msg.params.iter()) {
            st.apply_update(q);
        }
    }

    /// The latest (possibly stale) quantized gradient for this client.
    pub fn latest(&self) -> Vec<&Tensor> {
        self.states.iter().map(|s| s.value()).collect()
    }

    /// Server-side memory held for this client.
    pub fn mem_bytes(&self) -> usize {
        self.states.iter().map(|s| s.mem_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn shapes() -> Vec<Vec<usize>> {
        vec![vec![20, 30], vec![20], vec![5, 20], vec![5]]
    }

    fn grads(rng: &mut Rng, scale: f32) -> Vec<Tensor> {
        shapes()
            .iter()
            .map(|s| {
                let mut t = Tensor::randn(s, rng);
                t.scale(scale);
                t
            })
            .collect()
    }

    #[test]
    fn first_round_always_sends() {
        let mut rng = Rng::new(80);
        let cfg = SlaqConfig::paper(0.001, 10);
        let mut client = SlaqClient::new(&shapes(), cfg);
        let theta = grads(&mut rng, 1.0);
        client.observe_weights(&theta);
        assert!(client.step(&grads(&mut rng, 1.0)).is_some());
    }

    #[test]
    fn client_server_sync_with_skips() {
        let mut rng = Rng::new(81);
        let cfg = SlaqConfig::paper(0.05, 3);
        let mut client = SlaqClient::new(&shapes(), cfg);
        let mut server = SlaqServerState::new(&shapes());
        let mut theta = grads(&mut rng, 1.0);
        for round in 0..30 {
            client.observe_weights(&theta);
            // gradients shrink over time -> later rounds should skip
            let g = grads(&mut rng, 1.0 / (1.0 + round as f32));
            if let Some(msg) = client.step(&g) {
                server.apply(&msg);
            }
            // server state must equal client's committed state always
            for (cs, ss) in client.states().iter().zip(server.states.iter()) {
                assert!(
                    cs.value().sub(ss.value()).max_norm() < 1e-5,
                    "diverged at round {round}"
                );
            }
            // emulate a slow drift of weights
            for t in theta.iter_mut() {
                t.scale(0.999);
            }
        }
    }

    #[test]
    fn small_innovations_get_skipped() {
        let mut rng = Rng::new(82);
        // large alpha makes the window term dominate -> skips happen
        let cfg = SlaqConfig::paper(1.0, 1);
        let mut client = SlaqClient::new(&shapes(), cfg);
        let mut theta = grads(&mut rng, 1.0);
        let g = grads(&mut rng, 1.0);
        for _ in 0..20 {
            client.observe_weights(&theta);
            // identical gradient every round: innovation -> 0
            let _ = client.step(&g);
            for t in theta.iter_mut() {
                t.scale(0.9);
            }
        }
        let (skipped, sent) = client.skip_stats();
        assert!(skipped > 0, "expected some skips, sent={sent}");
        assert!(sent >= 1);
    }

    #[test]
    fn wire_bits_count_32_plus_beta_n() {
        let mut rng = Rng::new(83);
        let cfg = SlaqConfig::paper(0.001, 10);
        let mut client = SlaqClient::new(&shapes(), cfg);
        client.observe_weights(&grads(&mut rng, 1.0));
        let msg = client.step(&grads(&mut rng, 1.0)).unwrap();
        let expect: u64 = shapes()
            .iter()
            .map(|s| 32 + 8 * s.iter().product::<usize>() as u64)
            .sum();
        assert_eq!(msg.wire_bits(), expect);
    }

    #[test]
    fn skipped_round_leaves_server_stale_but_consistent() {
        let mut rng = Rng::new(84);
        let cfg = SlaqConfig::paper(10.0, 1); // aggressive skipping
        let mut client = SlaqClient::new(&shapes(), cfg);
        let mut server = SlaqServerState::new(&shapes());
        let theta = grads(&mut rng, 1.0);
        client.observe_weights(&theta);
        let g1 = grads(&mut rng, 1.0);
        let msg = client.step(&g1).expect("first round sends");
        server.apply(&msg);
        let latest_before: Vec<Tensor> = server.latest().into_iter().cloned().collect();
        // tiny innovation now
        client.observe_weights(&theta);
        let res = client.step(&g1);
        if res.is_none() {
            let latest_after: Vec<Tensor> = server.latest().into_iter().cloned().collect();
            for (a, b) in latest_before.iter().zip(latest_after.iter()) {
                assert_eq!(a, b);
            }
        }
    }
}
