//! Micro-benchmark harness (the offline substitute for `criterion` —
//! DESIGN.md §4): warmup, fixed-duration sampling, median + MAD, and a
//! uniform report line so `cargo bench` output is comparable across
//! benches.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// case label
    pub name: String,
    /// number of timed iterations
    pub samples: usize,
    /// median per-iteration time
    pub median: Duration,
    /// median absolute deviation
    pub mad: Duration,
    /// optional throughput unit count per iteration (elements, bits, …)
    pub units_per_iter: Option<f64>,
}

impl BenchResult {
    /// One human-readable line: `name  median ± mad  (throughput)`.
    pub fn line(&self) -> String {
        let med = self.median.as_secs_f64();
        let mad = self.mad.as_secs_f64();
        let mut s = format!(
            "{:<44} {:>12} ± {:>10}  ({} samples)",
            self.name,
            fmt_time(med),
            fmt_time(mad),
            self.samples
        );
        if let Some(u) = self.units_per_iter {
            if med > 0.0 {
                s.push_str(&format!("  {:>12}/s", fmt_count(u / med)));
            }
        }
        s
    }
}

fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{secs:.3} s")
    }
}

fn fmt_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

/// Benchmark runner with a time budget per case.
pub struct Bench {
    /// warmup duration before sampling
    pub warmup: Duration,
    /// sampling budget
    pub budget: Duration,
    /// hard cap on samples
    pub max_samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            max_samples: 200,
        }
    }
}

impl Bench {
    /// Fast settings for CI (`QRR_BENCH_FAST=1`).
    pub fn from_env() -> Self {
        if std::env::var("QRR_BENCH_FAST").is_ok() {
            Bench {
                warmup: Duration::from_millis(20),
                budget: Duration::from_millis(200),
                max_samples: 20,
            }
        } else {
            Bench::default()
        }
    }

    /// Time `f` repeatedly; `units` (optional) is per-iteration work for
    /// throughput reporting. Prints and returns the result.
    pub fn run<T>(&self, name: &str, units: Option<f64>, mut f: impl FnMut() -> T) -> BenchResult {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // sample
        let mut times = Vec::with_capacity(64);
        let s0 = Instant::now();
        while s0.elapsed() < self.budget && times.len() < self.max_samples {
            let t = Instant::now();
            std::hint::black_box(f());
            times.push(t.elapsed());
        }
        if times.is_empty() {
            let t = Instant::now();
            std::hint::black_box(f());
            times.push(t.elapsed());
        }
        times.sort_unstable();
        let median = times[times.len() / 2];
        let mut devs: Vec<Duration> = times
            .iter()
            .map(|&t| if t > median { t - median } else { median - t })
            .collect();
        devs.sort_unstable();
        let mad = devs[devs.len() / 2];
        let result = BenchResult {
            name: name.to_string(),
            samples: times.len(),
            median,
            mad,
            units_per_iter: units,
        };
        println!("{}", result.line());
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bench {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(20),
            max_samples: 50,
        };
        let r = b.run("spin", Some(1000.0), || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(r.median > Duration::ZERO);
        assert!(r.samples > 0);
        assert!(r.line().contains("spin"));
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2e-9).contains("ns"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_time(2e-3).contains("ms"));
        assert!(fmt_time(2.0).contains("s"));
        assert_eq!(fmt_count(2_500_000.0), "2.50M");
    }
}
