//! Minimal JSON parser/serializer (serde is unavailable offline —
//! DESIGN.md §4). Supports the full JSON grammar except exotic number
//! forms; good enough for config files and the artifact manifest.

use std::collections::BTreeMap;
use std::fmt;

use thiserror::Error;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// null
    Null,
    /// true / false
    Bool(bool),
    /// any number (stored as f64)
    Num(f64),
    /// string
    Str(String),
    /// array
    Arr(Vec<Json>),
    /// object (ordered for deterministic serialization)
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Error)]
#[error("JSON parse error at byte {offset}: {msg}")]
pub struct JsonError {
    /// byte offset of the failure
    pub offset: usize,
    /// description
    pub msg: String,
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As u64 (must be a non-negative integer).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// As usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    /// As str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"y":true},"z":null}"#;
        let j = Json::parse(src).unwrap();
        let printed = j.to_string();
        let j2 = Json::parse(&printed).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn errors_carry_offsets() {
        let e = Json::parse("[1, 2").unwrap_err();
        assert!(e.offset >= 5);
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("[1] x").is_err());
    }

    #[test]
    fn accessor_helpers() {
        let j = Json::parse(r#"{"n": 42, "f": 1.5, "s": "x", "b": false}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(42));
        assert_eq!(j.get("f").unwrap().as_u64(), None);
        assert_eq!(j.get("f").unwrap().as_f64(), Some(1.5));
        assert_eq!(j.get("b").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("missing"), None);
    }
}
