//! Experiment configuration: programmatic presets for every paper
//! experiment plus JSON round-trip for config files.

pub mod json;

pub use json::{Json, JsonError};

use crate::compress::pipeline::PipelineSpec;
use crate::control::ControllerConfig;
use crate::data::DatasetKind;
use crate::fl::SchemeKind;
use crate::model::ModelKind;
use crate::net::faults::{FaultPlan, Partition};

/// How QRR's `p` is assigned across clients.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PPolicy {
    /// same p for every client (experiments 1–2)
    Fixed(f64),
    /// evenly spaced in [lo, hi] by client link speed (experiment 3)
    Adaptive {
        /// p for the slowest link
        lo: f64,
        /// p for the fastest link
        hi: f64,
    },
}

/// How the training data is distributed across clients.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sharding {
    /// equal random split (the paper's setup)
    Iid,
    /// label-sorted shards, `n` per client (McMahan-style pathological)
    LabelSkew(usize),
    /// Dirichlet(α) class proportions per client
    Dirichlet(f64),
}

/// Which scheme to run, with its parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SchemeConfig {
    /// full-precision FedAvg
    Sgd,
    /// SLAQ baseline
    Slaq,
    /// the paper's QRR
    Qrr(PPolicy),
    /// QRR with error feedback (extension — see `qrr::error_feedback`)
    QrrEf(PPolicy),
}

impl SchemeConfig {
    /// Display label ("QRR(p=0.3)", "QRR(adaptive)", …).
    pub fn label(&self) -> String {
        match self {
            SchemeConfig::Sgd => "SGD".into(),
            SchemeConfig::Slaq => "SLAQ".into(),
            SchemeConfig::Qrr(PPolicy::Fixed(p)) => format!("QRR(p={p})"),
            SchemeConfig::Qrr(PPolicy::Adaptive { .. }) => "QRR".into(),
            SchemeConfig::QrrEf(PPolicy::Fixed(p)) => format!("EF-QRR(p={p})"),
            SchemeConfig::QrrEf(PPolicy::Adaptive { .. }) => "EF-QRR".into(),
        }
    }

    /// The [`SchemeKind`] for client `i` of `n` given its link.
    pub fn kind_for_client(&self, link: &crate::net::LinkModel, slow: f64, fast: f64) -> SchemeKind {
        match self {
            SchemeConfig::Sgd => SchemeKind::Sgd,
            SchemeConfig::Slaq => SchemeKind::Slaq,
            SchemeConfig::Qrr(PPolicy::Fixed(p)) => SchemeKind::Qrr { p: *p },
            SchemeConfig::Qrr(PPolicy::Adaptive { lo, hi }) => {
                SchemeKind::Qrr { p: link.adaptive_p(slow, fast, *lo, *hi) }
            }
            SchemeConfig::QrrEf(PPolicy::Fixed(p)) => SchemeKind::QrrEf { p: *p },
            SchemeConfig::QrrEf(PPolicy::Adaptive { lo, hi }) => {
                SchemeKind::QrrEf { p: link.adaptive_p(slow, fast, *lo, *hi) }
            }
        }
    }
}

/// Which clients take part in a round, and whose updates survive it
/// (the scenario axis Konečný et al. and Qin et al. emphasize for
/// communication-efficient FL over unreliable links).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ParticipationConfig {
    /// every client, every round (the paper's synchronous setting)
    Full,
    /// uniformly sample `ceil(fraction · C)` clients per round
    Uniform {
        /// fraction of clients per round, in (0, 1]
        fraction: f64,
    },
    /// sample as [`ParticipationConfig::Uniform`], then lose each selected
    /// client's upload with probability `drop_prob` scaled by its link
    /// slowness (slowest link ⇒ full `drop_prob`, fastest ⇒ never)
    Dropout {
        /// fraction of clients sampled per round, in (0, 1]
        fraction: f64,
        /// upload-loss probability for the slowest link, in [0, 1]
        drop_prob: f64,
    },
    /// every client computes, but uploads whose simulated transmission
    /// time exceeds the deadline are discarded (straggler cutoff)
    Deadline {
        /// round deadline in (simulated) seconds
        secs: f64,
    },
}

impl ParticipationConfig {
    /// Display label ("full", "uniform(0.5)", …).
    pub fn label(&self) -> String {
        match self {
            ParticipationConfig::Full => "full".into(),
            ParticipationConfig::Uniform { fraction } => format!("uniform({fraction})"),
            ParticipationConfig::Dropout { fraction, drop_prob } => {
                format!("dropout({fraction},{drop_prob})")
            }
            ParticipationConfig::Deadline { secs } => format!("deadline({secs}s)"),
        }
    }

    /// Parse the CLI grammar: `full` | `<fraction>` |
    /// `dropout:<fraction>:<drop_prob>` | `deadline:<secs>`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let s = s.trim();
        if s == "full" {
            return Ok(ParticipationConfig::Full);
        }
        if let Some(rest) = s.strip_prefix("dropout:") {
            let (f, d) = rest
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("dropout needs dropout:<fraction>:<drop_prob>"))?;
            let fraction: f64 = f.parse().map_err(|_| anyhow::anyhow!("bad fraction {f:?}"))?;
            let drop_prob: f64 = d.parse().map_err(|_| anyhow::anyhow!("bad drop_prob {d:?}"))?;
            let cfg = ParticipationConfig::Dropout { fraction, drop_prob };
            cfg.validate()?;
            return Ok(cfg);
        }
        if let Some(rest) = s.strip_prefix("deadline:") {
            let secs: f64 = rest.parse().map_err(|_| anyhow::anyhow!("bad deadline {rest:?}"))?;
            let cfg = ParticipationConfig::Deadline { secs };
            cfg.validate()?;
            return Ok(cfg);
        }
        let fraction: f64 = s
            .parse()
            .map_err(|_| anyhow::anyhow!("bad participation {s:?} (full | <fraction> | dropout:<f>:<p> | deadline:<secs>)"))?;
        // same contract as the JSON numeric form: reject out-of-range
        // fractions instead of silently clamping a typo to full sync
        anyhow::ensure!(
            fraction > 0.0 && fraction <= 1.0,
            "participation fraction must be in (0,1], got {fraction}"
        );
        Ok(Self::from_fraction(fraction))
    }

    /// The numeric back-compat form: 1.0 ⇒ full sync, else uniform.
    pub fn from_fraction(fraction: f64) -> Self {
        if fraction >= 1.0 {
            ParticipationConfig::Full
        } else {
            ParticipationConfig::Uniform { fraction }
        }
    }

    /// Range checks; called by JSON/CLI entry points.
    pub fn validate(&self) -> anyhow::Result<()> {
        match *self {
            ParticipationConfig::Full => Ok(()),
            ParticipationConfig::Uniform { fraction } => {
                anyhow::ensure!(fraction > 0.0 && fraction <= 1.0, "fraction in (0,1]");
                Ok(())
            }
            ParticipationConfig::Dropout { fraction, drop_prob } => {
                anyhow::ensure!(fraction > 0.0 && fraction <= 1.0, "fraction in (0,1]");
                anyhow::ensure!((0.0..=1.0).contains(&drop_prob), "drop_prob in [0,1]");
                Ok(())
            }
            ParticipationConfig::Deadline { secs } => {
                anyhow::ensure!(secs > 0.0 && secs.is_finite(), "deadline secs must be positive");
                Ok(())
            }
        }
    }
}

/// How the server combines the per-client gradient contributions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggregationConfig {
    /// plain sum, paper eq. (2)
    Sum,
    /// shard-size-weighted mean over the round's participants (FedAvg)
    WeightedMean,
}

impl AggregationConfig {
    /// Display label.
    pub fn label(&self) -> &'static str {
        match self {
            AggregationConfig::Sum => "sum",
            AggregationConfig::WeightedMean => "weighted_mean",
        }
    }

    /// Parse the CLI/JSON name.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.trim() {
            "sum" => Ok(AggregationConfig::Sum),
            "weighted_mean" | "mean" => Ok(AggregationConfig::WeightedMean),
            o => anyhow::bail!("unknown aggregation {o:?} (sum | weighted_mean)"),
        }
    }
}

/// Quorum semantics for the resilient round loop (DESIGN.md §11): the
/// server proceeds once at least `ceil(fraction · selected)` uploads
/// have arrived; when the first collection deadline leaves the quorum
/// unmet it re-polls up to `max_repolls` times, window `k` waiting
/// `base_backoff_ms · 2^(k-1)` (plus a small seeded jitter).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuorumConfig {
    /// fraction of the round's selected cohort that must arrive, (0, 1]
    pub fraction: f64,
    /// bounded number of re-poll windows after the first deadline
    pub max_repolls: u32,
    /// first re-poll window length in milliseconds
    pub base_backoff_ms: u64,
}

impl Default for QuorumConfig {
    fn default() -> Self {
        QuorumConfig { fraction: 1.0, max_repolls: 2, base_backoff_ms: 50 }
    }
}

impl QuorumConfig {
    /// Parse the CLI grammar:
    /// `<fraction>[:<max_repolls>[:<base_backoff_ms>]]`, e.g. `0.8` or
    /// `0.8:3:50`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let mut q = QuorumConfig::default();
        let mut it = s.trim().split(':');
        let f = it.next().unwrap_or_default();
        q.fraction = f
            .parse()
            .map_err(|_| anyhow::anyhow!("bad quorum fraction {f:?} (want <f>[:<repolls>[:<ms>]])"))?;
        if let Some(r) = it.next() {
            q.max_repolls = r
                .parse()
                .map_err(|_| anyhow::anyhow!("bad quorum max_repolls {r:?}"))?;
        }
        if let Some(b) = it.next() {
            q.base_backoff_ms = b
                .parse()
                .map_err(|_| anyhow::anyhow!("bad quorum base_backoff_ms {b:?}"))?;
        }
        anyhow::ensure!(it.next().is_none(), "too many quorum fields in {s:?}");
        q.validate()?;
        Ok(q)
    }

    /// Canonical spec string; `parse` round-trips it.
    pub fn format(&self) -> String {
        format!("{}:{}:{}", self.fraction, self.max_repolls, self.base_backoff_ms)
    }

    /// Range checks; called by JSON/CLI entry points.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.fraction > 0.0 && self.fraction <= 1.0,
            "quorum fraction must be in (0,1], got {}",
            self.fraction
        );
        anyhow::ensure!(self.base_backoff_ms > 0, "quorum base_backoff_ms must be positive");
        Ok(())
    }
}

/// Which compute backend evaluates gradients.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// pure-Rust reference implementation
    Native,
    /// AOT-compiled JAX/Pallas artifacts through PJRT
    Pjrt,
}

/// A full experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// run label (used in file names)
    pub name: String,
    /// architecture
    pub model: ModelKind,
    /// data stream
    pub dataset: DatasetKind,
    /// scheme + parameters
    pub scheme: SchemeConfig,
    /// number of clients C
    pub clients: usize,
    /// FL iterations
    pub iters: u64,
    /// per-client batch size
    pub batch: usize,
    /// learning-rate schedule: (from_iteration, alpha) pairs, ascending
    pub lr_schedule: Vec<(u64, f32)>,
    /// quantization bits β
    pub beta: u8,
    /// RNG seed (data, init, batches)
    pub seed: u64,
    /// evaluate on the test set every this many iterations
    pub eval_every: u64,
    /// training samples (synthetic stream size / subset of real data)
    pub train_n: usize,
    /// test samples
    pub test_n: usize,
    /// gradient backend
    pub backend: Backend,
    /// slowest client uplink (bit/s)
    pub link_slow_bps: f64,
    /// fastest client uplink (bit/s)
    pub link_fast_bps: f64,
    /// data distribution across clients
    pub sharding: Sharding,
    /// who participates each round (full sync, sampling, dropout,
    /// straggler deadline — see `fl::session::ParticipationPolicy`)
    pub participation: ParticipationConfig,
    /// how the server combines client contributions
    pub aggregation: AggregationConfig,
    /// uplink compression-pipeline override: when set, every client runs
    /// this spec instead of the per-client resolution of `scheme`
    /// (see `compress::pipeline`)
    pub uplink: Option<PipelineSpec>,
    /// downlink compression pipeline: when set, the server broadcasts
    /// compressed parameter deltas instead of full-precision parameters
    pub downlink: Option<PipelineSpec>,
    /// adaptive compression control plane: when set, a
    /// [`control::CompressionController`](crate::control) re-plans each
    /// client's uplink pipeline from observed telemetry every round,
    /// overriding both `scheme` and `uplink`
    pub controller: Option<ControllerConfig>,
    /// number of server-side aggregation shards (`None` = auto:
    /// `min(clients, 8)`); see `fl::shard::ShardedAggregator`
    pub shards: Option<usize>,
    /// quorum semantics for the round loop (`None` = defaults: full
    /// quorum, two re-poll windows)
    pub quorum: Option<QuorumConfig>,
    /// seeded fault-injection plan (`None` = a faithful network); see
    /// `net::faults::FaultPlan`
    pub chaos: Option<FaultPlan>,
    /// streamed rounds (DESIGN.md §13): clients ship each layer as its
    /// own chunk frame, the server reassembles decode-on-arrival, and
    /// the downlink encode for round r+1 overlaps round r's eval.
    /// Bit-identical to the sequential path on clean networks.
    pub streaming: bool,
}

impl ExperimentConfig {
    /// Shared paper defaults: 10 clients, β=8, α=0.001, batch 512.
    fn paper_base(name: &str, model: ModelKind, dataset: DatasetKind) -> Self {
        ExperimentConfig {
            name: name.into(),
            model,
            dataset,
            scheme: SchemeConfig::Sgd,
            clients: 10,
            iters: 1000,
            batch: 512,
            lr_schedule: vec![(0, 0.001)],
            beta: 8,
            seed: 42,
            eval_every: 25,
            train_n: 60_000,
            test_n: 10_000,
            backend: Backend::Native,
            link_slow_bps: 250e3,
            link_fast_bps: 10e6,
            sharding: Sharding::Iid,
            participation: ParticipationConfig::Full,
            aggregation: AggregationConfig::Sum,
            uplink: None,
            downlink: None,
            controller: None,
            shards: None,
            quorum: None,
            chaos: None,
            streaming: false,
        }
    }

    /// Experiment 1 (Table I / Fig. 2): MLP on MNIST.
    pub fn table1_default() -> Self {
        Self::paper_base("table1", ModelKind::Mlp, DatasetKind::Mnist)
    }

    /// Experiment 2 (Table II / Fig. 3): CNN on MNIST.
    pub fn table2_default() -> Self {
        Self::paper_base("table2", ModelKind::Cnn, DatasetKind::Mnist)
    }

    /// Experiment 3 (Table III / Fig. 4): VGG-like on CIFAR-10,
    /// 2000 iterations, lr 0.01 → 0.001 at iteration 1000, per-client p.
    pub fn table3_default() -> Self {
        let mut c = Self::paper_base("table3", ModelKind::Vgg, DatasetKind::Cifar10);
        c.iters = 2000;
        c.lr_schedule = vec![(0, 0.01), (1000, 0.001)];
        c.train_n = 50_000;
        c
    }

    /// The learning rate in force at `iter`.
    pub fn alpha_at(&self, iter: u64) -> f32 {
        let mut a = self.lr_schedule.first().map(|x| x.1).unwrap_or(0.001);
        for &(from, alpha) in &self.lr_schedule {
            if iter >= from {
                a = alpha;
            }
        }
        a
    }

    /// Initial learning rate.
    pub fn alpha0(&self) -> f32 {
        self.alpha_at(0)
    }

    /// Serialize to JSON.
    pub fn to_json(&self) -> Json {
        let scheme = match self.scheme {
            SchemeConfig::Sgd => Json::obj(vec![("kind", Json::Str("sgd".into()))]),
            SchemeConfig::Slaq => Json::obj(vec![("kind", Json::Str("slaq".into()))]),
            SchemeConfig::Qrr(PPolicy::Fixed(p)) => Json::obj(vec![
                ("kind", Json::Str("qrr".into())),
                ("p", Json::Num(p)),
            ]),
            SchemeConfig::Qrr(PPolicy::Adaptive { lo, hi }) => Json::obj(vec![
                ("kind", Json::Str("qrr".into())),
                ("p_lo", Json::Num(lo)),
                ("p_hi", Json::Num(hi)),
            ]),
            SchemeConfig::QrrEf(PPolicy::Fixed(p)) => Json::obj(vec![
                ("kind", Json::Str("qrr_ef".into())),
                ("p", Json::Num(p)),
            ]),
            SchemeConfig::QrrEf(PPolicy::Adaptive { lo, hi }) => Json::obj(vec![
                ("kind", Json::Str("qrr_ef".into())),
                ("p_lo", Json::Num(lo)),
                ("p_hi", Json::Num(hi)),
            ]),
        };
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            ("model", Json::Str(self.model.name().into())),
            (
                "dataset",
                Json::Str(
                    match self.dataset {
                        DatasetKind::Mnist => "mnist",
                        DatasetKind::Cifar10 => "cifar10",
                    }
                    .into(),
                ),
            ),
            ("scheme", scheme),
            ("clients", Json::Num(self.clients as f64)),
            ("iters", Json::Num(self.iters as f64)),
            ("batch", Json::Num(self.batch as f64)),
            (
                "lr_schedule",
                Json::Arr(
                    self.lr_schedule
                        .iter()
                        .map(|&(i, a)| {
                            Json::Arr(vec![Json::Num(i as f64), Json::Num(a as f64)])
                        })
                        .collect(),
                ),
            ),
            ("beta", Json::Num(self.beta as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("eval_every", Json::Num(self.eval_every as f64)),
            ("train_n", Json::Num(self.train_n as f64)),
            ("test_n", Json::Num(self.test_n as f64)),
            (
                "backend",
                Json::Str(match self.backend {
                    Backend::Native => "native".into(),
                    Backend::Pjrt => "pjrt".into(),
                }),
            ),
            ("link_slow_bps", Json::Num(self.link_slow_bps)),
            ("link_fast_bps", Json::Num(self.link_fast_bps)),
            (
                "sharding",
                match self.sharding {
                    Sharding::Iid => Json::Str("iid".into()),
                    Sharding::LabelSkew(k) => Json::obj(vec![
                        ("kind", Json::Str("label_skew".into())),
                        ("shards_per_client", Json::Num(k as f64)),
                    ]),
                    Sharding::Dirichlet(a) => Json::obj(vec![
                        ("kind", Json::Str("dirichlet".into())),
                        ("alpha", Json::Num(a)),
                    ]),
                },
            ),
            (
                "participation",
                match self.participation {
                    ParticipationConfig::Full => Json::Num(1.0),
                    ParticipationConfig::Uniform { fraction } => Json::Num(fraction),
                    ParticipationConfig::Dropout { fraction, drop_prob } => Json::obj(vec![
                        ("kind", Json::Str("dropout".into())),
                        ("fraction", Json::Num(fraction)),
                        ("drop_prob", Json::Num(drop_prob)),
                    ]),
                    ParticipationConfig::Deadline { secs } => Json::obj(vec![
                        ("kind", Json::Str("deadline".into())),
                        ("secs", Json::Num(secs)),
                    ]),
                },
            ),
            ("aggregation", Json::Str(self.aggregation.label().into())),
        ];
        if let Some(spec) = &self.uplink {
            fields.push(("uplink", Json::Str(spec.format())));
        }
        if let Some(spec) = &self.downlink {
            fields.push(("downlink", Json::Str(spec.format())));
        }
        if let Some(c) = &self.controller {
            fields.push(("controller", Json::Str(c.format())));
        }
        if let Some(n) = self.shards {
            fields.push(("shards", Json::Num(n as f64)));
        }
        if let Some(q) = &self.quorum {
            fields.push((
                "quorum",
                Json::obj(vec![
                    ("fraction", Json::Num(q.fraction)),
                    ("max_repolls", Json::Num(q.max_repolls as f64)),
                    ("base_backoff_ms", Json::Num(q.base_backoff_ms as f64)),
                ]),
            ));
        }
        if let Some(p) = &self.chaos {
            // the rate/seed/window half uses the CLI spec grammar;
            // partitions have no CLI form and ride along as JSON
            let mut ch = vec![("spec", Json::Str(p.format()))];
            if !p.partitions.is_empty() {
                ch.push((
                    "partitions",
                    Json::Arr(
                        p.partitions
                            .iter()
                            .map(|pt| {
                                Json::obj(vec![
                                    (
                                        "clients",
                                        Json::Arr(
                                            pt.clients
                                                .iter()
                                                .map(|&c| Json::Num(c as f64))
                                                .collect(),
                                        ),
                                    ),
                                    (
                                        "rounds",
                                        Json::Arr(vec![
                                            Json::Num(pt.rounds.0 as f64),
                                            Json::Num(pt.rounds.1 as f64),
                                        ]),
                                    ),
                                ])
                            })
                            .collect(),
                    ),
                ));
            }
            fields.push(("chaos", Json::obj(ch)));
        }
        if self.streaming {
            fields.push(("streaming", Json::Bool(true)));
        }
        Json::obj(fields)
    }

    /// Parse from JSON (fields missing fall back to table1 defaults).
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let mut c = Self::table1_default();
        if let Some(v) = j.get("name").and_then(Json::as_str) {
            c.name = v.into();
        }
        if let Some(v) = j.get("model").and_then(Json::as_str) {
            c.model = ModelKind::parse(v)
                .ok_or_else(|| anyhow::anyhow!("unknown model {v:?}"))?;
        }
        if let Some(v) = j.get("dataset").and_then(Json::as_str) {
            c.dataset = DatasetKind::parse(v)
                .ok_or_else(|| anyhow::anyhow!("unknown dataset {v:?}"))?;
        }
        if let Some(s) = j.get("scheme") {
            let kind = s.get("kind").and_then(Json::as_str).unwrap_or("sgd");
            c.scheme = match kind {
                "sgd" => SchemeConfig::Sgd,
                "slaq" => SchemeConfig::Slaq,
                "qrr" | "qrr_ef" => {
                    let policy = if let Some(p) = s.get("p").and_then(Json::as_f64) {
                        PPolicy::Fixed(p)
                    } else {
                        let lo = s.get("p_lo").and_then(Json::as_f64).unwrap_or(0.1);
                        let hi = s.get("p_hi").and_then(Json::as_f64).unwrap_or(0.3);
                        PPolicy::Adaptive { lo, hi }
                    };
                    if kind == "qrr" {
                        SchemeConfig::Qrr(policy)
                    } else {
                        SchemeConfig::QrrEf(policy)
                    }
                }
                k => anyhow::bail!("unknown scheme {k:?}"),
            };
        }
        if let Some(v) = j.get("clients").and_then(Json::as_usize) {
            c.clients = v;
        }
        if let Some(v) = j.get("iters").and_then(Json::as_u64) {
            c.iters = v;
        }
        if let Some(v) = j.get("batch").and_then(Json::as_usize) {
            c.batch = v;
        }
        if let Some(arr) = j.get("lr_schedule").and_then(Json::as_arr) {
            c.lr_schedule = arr
                .iter()
                .filter_map(|pair| {
                    let p = pair.as_arr()?;
                    Some((p[0].as_u64()?, p[1].as_f64()? as f32))
                })
                .collect();
            anyhow::ensure!(!c.lr_schedule.is_empty(), "empty lr_schedule");
        }
        if let Some(v) = j.get("beta").and_then(Json::as_u64) {
            anyhow::ensure!((1..=16).contains(&v), "beta out of range");
            c.beta = v as u8;
        }
        if let Some(v) = j.get("seed").and_then(Json::as_u64) {
            c.seed = v;
        }
        if let Some(v) = j.get("eval_every").and_then(Json::as_u64) {
            c.eval_every = v.max(1);
        }
        if let Some(v) = j.get("train_n").and_then(Json::as_usize) {
            c.train_n = v;
        }
        if let Some(v) = j.get("test_n").and_then(Json::as_usize) {
            c.test_n = v;
        }
        if let Some(v) = j.get("backend").and_then(Json::as_str) {
            c.backend = match v {
                "native" => Backend::Native,
                "pjrt" => Backend::Pjrt,
                b => anyhow::bail!("unknown backend {b:?}"),
            };
        }
        if let Some(v) = j.get("link_slow_bps").and_then(Json::as_f64) {
            c.link_slow_bps = v;
        }
        if let Some(v) = j.get("link_fast_bps").and_then(Json::as_f64) {
            c.link_fast_bps = v;
        }
        if let Some(sh) = j.get("sharding") {
            c.sharding = if let Some(name) = sh.as_str() {
                match name {
                    "iid" => Sharding::Iid,
                    o => anyhow::bail!("unknown sharding {o:?}"),
                }
            } else {
                match sh.get("kind").and_then(Json::as_str) {
                    Some("label_skew") => Sharding::LabelSkew(
                        sh.get("shards_per_client").and_then(Json::as_usize).unwrap_or(2),
                    ),
                    Some("dirichlet") => Sharding::Dirichlet(
                        sh.get("alpha").and_then(Json::as_f64).unwrap_or(0.5),
                    ),
                    _ => anyhow::bail!("bad sharding object"),
                }
            };
        }
        if let Some(p) = j.get("participation") {
            c.participation = if let Some(v) = p.as_f64() {
                anyhow::ensure!((0.0..=1.0).contains(&v) && v > 0.0, "participation in (0,1]");
                ParticipationConfig::from_fraction(v)
            } else if let Some(name) = p.as_str() {
                ParticipationConfig::parse(name)?
            } else {
                // fields are required: a typo'd key must fail loudly,
                // not silently run a different scenario
                let req = |key: &str| -> anyhow::Result<f64> {
                    p.get(key).and_then(Json::as_f64).ok_or_else(|| {
                        anyhow::anyhow!("participation object missing numeric {key:?}")
                    })
                };
                match p.get("kind").and_then(Json::as_str) {
                    Some("full") => ParticipationConfig::Full,
                    Some("uniform") => ParticipationConfig::Uniform { fraction: req("fraction")? },
                    Some("dropout") => ParticipationConfig::Dropout {
                        fraction: req("fraction")?,
                        drop_prob: req("drop_prob")?,
                    },
                    Some("deadline") => ParticipationConfig::Deadline { secs: req("secs")? },
                    _ => anyhow::bail!("bad participation object"),
                }
            };
            c.participation.validate()?;
        }
        if let Some(v) = j.get("aggregation").and_then(Json::as_str) {
            c.aggregation = AggregationConfig::parse(v)?;
        }
        if let Some(v) = j.get("uplink") {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("uplink must be a pipeline spec string"))?;
            c.uplink = Some(
                PipelineSpec::parse(s).map_err(|e| anyhow::anyhow!("uplink spec: {e}"))?,
            );
        }
        if let Some(v) = j.get("downlink") {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("downlink must be a pipeline spec string"))?;
            let spec =
                PipelineSpec::parse(s).map_err(|e| anyhow::anyhow!("downlink spec: {e}"))?;
            spec.validate_downlink()
                .map_err(|e| anyhow::anyhow!("downlink spec: {e}"))?;
            c.downlink = Some(spec);
        }
        if let Some(v) = j.get("controller") {
            let s = v.as_str().ok_or_else(|| {
                anyhow::anyhow!("controller must be a policy spec string")
            })?;
            c.controller = Some(
                ControllerConfig::parse(s)
                    .map_err(|e| anyhow::anyhow!("controller spec: {e}"))?,
            );
        }
        if let Some(v) = j.get("shards") {
            let n = v
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("shards must be a positive integer"))?;
            anyhow::ensure!(n > 0, "shards must be positive");
            c.shards = Some(n);
        }
        if let Some(q) = j.get("quorum") {
            let quorum = if let Some(v) = q.as_f64() {
                QuorumConfig { fraction: v, ..QuorumConfig::default() }
            } else if let Some(s) = q.as_str() {
                QuorumConfig::parse(s)?
            } else {
                let mut qc = QuorumConfig::default();
                if let Some(v) = q.get("fraction").and_then(Json::as_f64) {
                    qc.fraction = v;
                }
                if let Some(v) = q.get("max_repolls").and_then(Json::as_u64) {
                    qc.max_repolls = v as u32;
                }
                if let Some(v) = q.get("base_backoff_ms").and_then(Json::as_u64) {
                    qc.base_backoff_ms = v;
                }
                qc
            };
            quorum.validate()?;
            c.quorum = Some(quorum);
        }
        if let Some(ch) = j.get("chaos") {
            let plan = if let Some(s) = ch.as_str() {
                FaultPlan::parse(s).map_err(|e| anyhow::anyhow!("chaos: {e}"))?
            } else {
                let spec = ch.get("spec").and_then(Json::as_str).ok_or_else(|| {
                    anyhow::anyhow!("chaos must be a spec string or an object with \"spec\"")
                })?;
                let mut p =
                    FaultPlan::parse(spec).map_err(|e| anyhow::anyhow!("chaos spec: {e}"))?;
                if let Some(parts) = ch.get("partitions").and_then(Json::as_arr) {
                    for pt in parts {
                        let clients = pt
                            .get("clients")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| anyhow::anyhow!("partition missing clients array"))?
                            .iter()
                            .map(|v| {
                                v.as_u64().map(|x| x as u32).ok_or_else(|| {
                                    anyhow::anyhow!("partition client ids must be integers")
                                })
                            })
                            .collect::<anyhow::Result<Vec<u32>>>()?;
                        let rounds = pt
                            .get("rounds")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| anyhow::anyhow!("partition missing rounds [lo, hi]"))?;
                        anyhow::ensure!(rounds.len() == 2, "partition rounds must be [lo, hi]");
                        let lo = rounds[0]
                            .as_u64()
                            .ok_or_else(|| anyhow::anyhow!("partition round bounds must be integers"))?;
                        let hi = rounds[1]
                            .as_u64()
                            .ok_or_else(|| anyhow::anyhow!("partition round bounds must be integers"))?;
                        p.partitions.push(Partition { clients, rounds: (lo, hi) });
                    }
                    p.validate().map_err(|e| anyhow::anyhow!("chaos partitions: {e}"))?;
                }
                p
            };
            c.chaos = Some(plan);
        }
        if let Some(v) = j.get("streaming").and_then(Json::as_bool) {
            c.streaming = v;
        }
        anyhow::ensure!(c.clients > 0, "need at least one client");
        anyhow::ensure!(c.batch > 0, "batch must be positive");
        Ok(c)
    }

    /// Load from a JSON file.
    pub fn from_file(path: &str) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper() {
        let t1 = ExperimentConfig::table1_default();
        assert_eq!(t1.clients, 10);
        assert_eq!(t1.batch, 512);
        assert_eq!(t1.beta, 8);
        assert_eq!(t1.alpha0(), 0.001);
        assert_eq!(t1.iters, 1000);

        let t3 = ExperimentConfig::table3_default();
        assert_eq!(t3.iters, 2000);
        assert_eq!(t3.alpha_at(0), 0.01);
        assert_eq!(t3.alpha_at(999), 0.01);
        assert_eq!(t3.alpha_at(1000), 0.001);
        assert_eq!(t3.model, ModelKind::Vgg);
    }

    #[test]
    fn json_roundtrip_all_schemes() {
        for scheme in [
            SchemeConfig::Sgd,
            SchemeConfig::Slaq,
            SchemeConfig::Qrr(PPolicy::Fixed(0.2)),
            SchemeConfig::Qrr(PPolicy::Adaptive { lo: 0.1, hi: 0.3 }),
        ] {
            let mut c = ExperimentConfig::table2_default();
            c.scheme = scheme;
            c.iters = 123;
            let j = c.to_json();
            let back = ExperimentConfig::from_json(&j).unwrap();
            assert_eq!(back.scheme, c.scheme);
            assert_eq!(back.iters, 123);
            assert_eq!(back.model, c.model);
            assert_eq!(back.lr_schedule, c.lr_schedule);
        }
    }

    #[test]
    fn from_json_validates() {
        let j = Json::parse(r#"{"beta": 99}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"clients": 0}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"model": "transformer"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn scheme_labels() {
        assert_eq!(SchemeConfig::Sgd.label(), "SGD");
        assert_eq!(SchemeConfig::Qrr(PPolicy::Fixed(0.1)).label(), "QRR(p=0.1)");
        assert_eq!(
            SchemeConfig::Qrr(PPolicy::Adaptive { lo: 0.1, hi: 0.3 }).label(),
            "QRR"
        );
    }

    #[test]
    fn participation_json_roundtrip() {
        for (part, agg) in [
            (ParticipationConfig::Full, AggregationConfig::Sum),
            (ParticipationConfig::Uniform { fraction: 0.5 }, AggregationConfig::WeightedMean),
            (
                ParticipationConfig::Dropout { fraction: 0.8, drop_prob: 0.3 },
                AggregationConfig::Sum,
            ),
            (ParticipationConfig::Deadline { secs: 2.5 }, AggregationConfig::Sum),
        ] {
            let mut c = ExperimentConfig::table1_default();
            c.participation = part;
            c.aggregation = agg;
            let back = ExperimentConfig::from_json(&c.to_json()).unwrap();
            assert_eq!(back.participation, part);
            assert_eq!(back.aggregation, agg);
        }
    }

    #[test]
    fn participation_from_json_objects_and_numbers() {
        let j = Json::parse(r#"{"participation": 0.4}"#).unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.participation, ParticipationConfig::Uniform { fraction: 0.4 });

        let j = Json::parse(
            r#"{"participation": {"kind":"dropout","fraction":0.6,"drop_prob":0.5},
                "aggregation": "weighted_mean"}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(
            c.participation,
            ParticipationConfig::Dropout { fraction: 0.6, drop_prob: 0.5 }
        );
        assert_eq!(c.aggregation, AggregationConfig::WeightedMean);

        let j = Json::parse(r#"{"participation": 1.5}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        // typo'd / missing fields must fail loudly, not default
        let j = Json::parse(r#"{"participation": {"kind":"dropout","fraction":0.6,"drop_pob":0.5}}"#)
            .unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"participation": {"kind":"dropout","fraction":0.5,"drop_prob":7}}"#)
            .unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"aggregation": "median"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn participation_cli_grammar() {
        assert_eq!(ParticipationConfig::parse("full").unwrap(), ParticipationConfig::Full);
        assert_eq!(
            ParticipationConfig::parse("0.5").unwrap(),
            ParticipationConfig::Uniform { fraction: 0.5 }
        );
        assert_eq!(
            ParticipationConfig::parse("1.0").unwrap(),
            ParticipationConfig::Full
        );
        assert_eq!(
            ParticipationConfig::parse("dropout:0.8:0.25").unwrap(),
            ParticipationConfig::Dropout { fraction: 0.8, drop_prob: 0.25 }
        );
        assert_eq!(
            ParticipationConfig::parse("deadline:3.5").unwrap(),
            ParticipationConfig::Deadline { secs: 3.5 }
        );
        assert!(ParticipationConfig::parse("dropout:0.8").is_err());
        assert!(ParticipationConfig::parse("deadline:-1").is_err());
        assert!(ParticipationConfig::parse("sometimes").is_err());
        assert!(ParticipationConfig::parse("5").is_err(), "fraction > 1 must not mean full");
        assert!(ParticipationConfig::parse("0").is_err());
        assert!(AggregationConfig::parse("sum").is_ok());
        assert!(AggregationConfig::parse("weighted_mean").is_ok());
        assert!(AggregationConfig::parse("median").is_err());
    }

    #[test]
    fn uplink_downlink_json_roundtrip() {
        let mut c = ExperimentConfig::table1_default();
        c.uplink = Some(PipelineSpec::parse("svd(p=0.2)+laq(beta=8)+ef").unwrap());
        c.downlink = Some(PipelineSpec::parse("svd(p=0.1)+laq(beta=8)").unwrap());
        let back = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.uplink, c.uplink);
        assert_eq!(back.downlink, c.downlink);

        // absent fields stay None
        let plain = ExperimentConfig::from_json(&ExperimentConfig::table1_default().to_json())
            .unwrap();
        assert_eq!(plain.uplink, None);
        assert_eq!(plain.downlink, None);
        assert_eq!(plain.shards, None);
        assert_eq!(plain.controller, None);
    }

    #[test]
    fn controller_json_roundtrip() {
        for spec in ["fixed(p=0.25,beta=6)", "linkaware()", "aimd(target_ms=100)"] {
            let mut c = ExperimentConfig::table1_default();
            c.controller = Some(ControllerConfig::parse(spec).unwrap());
            let back = ExperimentConfig::from_json(&c.to_json()).unwrap();
            assert_eq!(back.controller, c.controller, "round-trip of {spec}");
        }

        for bad in [
            r#"{"controller": "pid(kp=1)"}"#,
            r#"{"controller": "fixed(p=0)"}"#,
            r#"{"controller": 3}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ExperimentConfig::from_json(&j).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn shards_json_roundtrip_and_validation() {
        let mut c = ExperimentConfig::table1_default();
        c.shards = Some(4);
        let back = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.shards, Some(4));

        let j = Json::parse(r#"{"shards": 0}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"shards": "many"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn quorum_json_and_cli_roundtrip() {
        let mut c = ExperimentConfig::table1_default();
        c.quorum = Some(QuorumConfig { fraction: 0.8, max_repolls: 3, base_backoff_ms: 25 });
        let back = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.quorum, c.quorum);
        assert_eq!(ExperimentConfig::table1_default().quorum, None);

        // CLI grammar round-trips, partial forms fill defaults
        let q = QuorumConfig::parse("0.8:3:25").unwrap();
        assert_eq!(q, c.quorum.unwrap());
        assert_eq!(QuorumConfig::parse(&q.format()).unwrap(), q);
        let q = QuorumConfig::parse("0.5").unwrap();
        assert_eq!(q.fraction, 0.5);
        assert_eq!(q.max_repolls, QuorumConfig::default().max_repolls);

        // bare-number and bad JSON forms
        let j = Json::parse(r#"{"quorum": 0.7}"#).unwrap();
        assert_eq!(
            ExperimentConfig::from_json(&j).unwrap().quorum.unwrap().fraction,
            0.7
        );
        for bad in [r#"{"quorum": 0.0}"#, r#"{"quorum": 1.5}"#, r#"{"quorum": "0.8:1:0"}"#] {
            let j = Json::parse(bad).unwrap();
            assert!(ExperimentConfig::from_json(&j).is_err(), "accepted {bad}");
        }
        assert!(QuorumConfig::parse("0.8:1:50:9").is_err());
    }

    #[test]
    fn chaos_json_roundtrip_with_partitions() {
        let mut c = ExperimentConfig::table1_default();
        let mut plan = FaultPlan::parse("drop=0.02,corrupt=0.01,down.drop=0.05,seed=7").unwrap();
        plan.partitions.push(Partition { clients: vec![1, 2], rounds: (3, 8) });
        c.chaos = Some(plan.clone());
        let back = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.chaos, Some(plan));
        assert_eq!(ExperimentConfig::table1_default().chaos, None);

        // plain string form parses too
        let j = Json::parse(r#"{"chaos": "drop=0.1,seed=3"}"#).unwrap();
        let p = ExperimentConfig::from_json(&j).unwrap().chaos.unwrap();
        assert_eq!(p.seed, 3);
        assert_eq!(p.up.drop, 0.1);

        for bad in [
            r#"{"chaos": "drop=2.0"}"#,
            r#"{"chaos": 9}"#,
            r#"{"chaos": {"spec": "drop=0.1", "partitions": [{"clients": [], "rounds": [0, 5]}]}}"#,
            r#"{"chaos": {"spec": "drop=0.1", "partitions": [{"clients": [1], "rounds": [5]}]}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ExperimentConfig::from_json(&j).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn streaming_json_roundtrip() {
        let mut c = ExperimentConfig::table1_default();
        assert!(!c.streaming);
        // off is the default and is omitted from the JSON form
        assert_eq!(c.to_json().get("streaming"), None);
        c.streaming = true;
        let back = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert!(back.streaming);

        let j = Json::parse(r#"{"streaming": true}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).unwrap().streaming);
        let j = Json::parse(r#"{"streaming": false}"#).unwrap();
        assert!(!ExperimentConfig::from_json(&j).unwrap().streaming);
    }

    #[test]
    fn bad_pipeline_specs_fail_config_parse() {
        for (field, spec) in [
            ("uplink", r#""rle(p=0.1)""#),
            ("uplink", r#""svd(p=0.1)+""#),
            ("downlink", r#""laq(beta=99)""#),
            // downlink rejects the uplink-only wrappers
            ("downlink", r#""laq(beta=8)+lazy""#),
            ("downlink", r#""svd(p=0.1)+laq(beta=8)+ef""#),
            // spec must be a string
            ("downlink", "42"),
        ] {
            let j = Json::parse(&format!(r#"{{"{field}": {spec}}}"#)).unwrap();
            assert!(
                ExperimentConfig::from_json(&j).is_err(),
                "accepted {field}={spec}"
            );
        }
    }

    #[test]
    fn adaptive_kind_for_client_uses_link() {
        let cfg = SchemeConfig::Qrr(PPolicy::Adaptive { lo: 0.1, hi: 0.3 });
        let slow = crate::net::LinkModel { bandwidth_bps: 1e5, latency: std::time::Duration::ZERO };
        let fast = crate::net::LinkModel { bandwidth_bps: 1e7, latency: std::time::Duration::ZERO };
        match (cfg.kind_for_client(&slow, 1e5, 1e7), cfg.kind_for_client(&fast, 1e5, 1e7)) {
            (SchemeKind::Qrr { p: ps }, SchemeKind::Qrr { p: pf }) => {
                assert!((ps - 0.1).abs() < 1e-9);
                assert!((pf - 0.3).abs() < 1e-9);
            }
            _ => panic!("wrong kinds"),
        }
    }
}
