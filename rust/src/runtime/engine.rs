//! The PJRT executor thread and its `Send + Sync` handle.
//!
//! All `xla` crate objects (`PjRtClient` is `Rc`-based) live on one
//! dedicated thread; callers submit `(artifact, inputs)` jobs over a
//! channel and block on a reply channel. Executables are compiled
//! lazily on first use and cached for the life of the engine.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

// The real `xla` crate is absent from the offline cache; the stub keeps
// this module compiling and reports a clear error if the PJRT backend is
// actually requested (swap the import to restore the real binding).
use super::manifest::Manifest;
use super::stub_xla as xla;

/// A host tensor crossing the engine boundary: (shape, row-major f32).
pub type HostTensor = (Vec<usize>, Vec<f32>);

struct Job {
    /// artifact name in the manifest
    artifact: String,
    inputs: Vec<HostTensor>,
    reply: Sender<Result<Vec<HostTensor>>>,
}

/// Handle to the PJRT executor thread. Clone freely; drop all clones to
/// shut the thread down.
#[derive(Debug)]
pub struct PjrtEngine {
    tx: Sender<Job>,
    // JoinHandle kept by the first handle only; worker exits when all
    // senders drop.
    _worker: Option<std::sync::Arc<WorkerGuard>>,
}

#[derive(Debug)]
struct WorkerGuard {
    handle: Option<JoinHandle<()>>,
}

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Clone for PjrtEngine {
    fn clone(&self) -> Self {
        PjrtEngine { tx: self.tx.clone(), _worker: self._worker.clone() }
    }
}

impl PjrtEngine {
    /// Start the executor thread over a manifest directory.
    pub fn start(manifest: Manifest) -> Result<Self> {
        let (tx, rx) = channel::<Job>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("qrr-pjrt".into())
            .spawn(move || {
                // Everything xla-related stays on this thread.
                let client = match xla::PjRtClient::cpu() {
                    Ok(c) => {
                        let _ = ready_tx.send(Ok(()));
                        c
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(anyhow!("PJRT CPU client: {e}")));
                        return;
                    }
                };
                log::info!(
                    "PJRT ready: platform={} devices={}",
                    client.platform_name(),
                    client.device_count()
                );
                let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
                while let Ok(job) = rx.recv() {
                    let result = run_job(&client, &mut cache, &manifest, &job);
                    let _ = job.reply.send(result);
                }
            })
            .context("spawning pjrt thread")?;
        ready_rx
            .recv()
            .context("pjrt thread died during startup")??;
        Ok(PjrtEngine {
            tx,
            _worker: Some(std::sync::Arc::new(WorkerGuard { handle: Some(handle) })),
        })
    }

    /// Start from the default artifacts directory.
    pub fn start_default() -> Result<Self> {
        let dir = super::artifacts_dir();
        let manifest = Manifest::load(&dir)?;
        Self::start(manifest)
    }

    /// Execute one artifact synchronously.
    pub fn execute(&self, artifact: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let (reply, rx) = channel();
        self.tx
            .send(Job { artifact: artifact.to_string(), inputs, reply })
            .map_err(|_| anyhow!("pjrt thread gone"))?;
        rx.recv().map_err(|_| anyhow!("pjrt thread dropped reply"))?
    }
}

fn run_job(
    client: &xla::PjRtClient,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    manifest: &Manifest,
    job: &Job,
) -> Result<Vec<HostTensor>> {
    if !cache.contains_key(&job.artifact) {
        let entry = manifest
            .by_name(&job.artifact)
            .ok_or_else(|| anyhow!("artifact {:?} not in manifest", job.artifact))?;
        let path = manifest.path_of(entry);
        let t = crate::util::Timer::start();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", job.artifact))?;
        log::info!("compiled {} in {:.1} ms", job.artifact, t.millis());
        cache.insert(job.artifact.clone(), exe);
    }
    let exe = cache.get(&job.artifact).unwrap();

    // Host -> device literals.
    let mut literals = Vec::with_capacity(job.inputs.len());
    for (shape, data) in &job.inputs {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(data)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshaping input to {shape:?}: {e}"))?;
        literals.push(lit);
    }

    // Execute; artifacts are lowered with return_tuple=True so the single
    // output is a tuple of all results.
    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| anyhow!("executing {}: {e}", job.artifact))?;
    let out_lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetching output: {e}"))?;
    let parts = out_lit
        .to_tuple()
        .map_err(|e| anyhow!("untupling output: {e}"))?;
    let mut outs = Vec::with_capacity(parts.len());
    for p in parts {
        let shape = p
            .array_shape()
            .map_err(|e| anyhow!("output shape: {e}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = p
            .to_vec::<f32>()
            .map_err(|e| anyhow!("output to_vec: {e}"))?;
        outs.push((dims, data));
    }
    Ok(outs)
}

#[cfg(test)]
mod tests {
    // Engine tests that need real artifacts live in rust/tests/pjrt.rs
    // (integration), since unit tests must pass before `make artifacts`.

    #[test]
    fn missing_artifact_is_error() {
        let dir = std::env::temp_dir().join("qrr_engine_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"artifacts":[]}"#).unwrap();
        let manifest = super::Manifest::load(&dir).unwrap();
        let engine = super::PjrtEngine::start(manifest).unwrap();
        let err = engine.execute("nope", vec![]).unwrap_err();
        assert!(format!("{err}").contains("not in manifest"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
