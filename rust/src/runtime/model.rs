//! [`PjrtModel`]: the [`ModelOps`] implementation backed by AOT-compiled
//! JAX/Pallas artifacts.
//!
//! Artifact calling convention (see `python/compile/aot.py`):
//!
//! * `<model>_grad_b<B>`: inputs `(param_0, …, param_{P-1}, x[B,D],
//!   y_onehot[B,K], w[B])` → outputs `(loss[], grad_0, …, grad_{P-1})`
//!   where `loss` is the w-weighted mean cross-entropy and the grads are
//!   gradients of that weighted mean.
//! * `<model>_eval_b<B>`: same inputs → `(loss_sum[], correct[])`
//!   (w-weighted sums, so padding rows contribute nothing).
//!
//! Any request batch is served by chunking into the artifact's static
//! batch and zero-padding the tail with w=0; the weighted convention
//! makes the result exact, not approximate.

use anyhow::{anyhow, Context, Result};

use crate::model::{ModelKind, ModelOps, ModelSpec};
use crate::tensor::Tensor;

use super::engine::{HostTensor, PjrtEngine};
use super::manifest::Manifest;

/// PJRT-backed model (see module docs for the artifact contract).
#[derive(Debug)]
pub struct PjrtModel {
    spec: ModelSpec,
    engine: PjrtEngine,
    grad_batches: Vec<usize>,
    eval_batches: Vec<usize>,
}

impl PjrtModel {
    /// Load from the default artifacts directory (`QRR_ARTIFACTS` or
    /// `./artifacts`).
    pub fn load_default(kind: ModelKind) -> Result<Self> {
        let dir = super::artifacts_dir();
        let manifest = Manifest::load(&dir)?;
        let engine = PjrtEngine::start(manifest.clone())?;
        Self::new(kind, manifest, engine)
    }

    /// Build from an explicit manifest + engine (shared across models).
    pub fn new(kind: ModelKind, manifest: Manifest, engine: PjrtEngine) -> Result<Self> {
        let spec = ModelSpec::new(kind);
        let grad_batches: Vec<usize> = manifest
            .for_model_fn(kind.name(), "grad")
            .iter()
            .map(|e| e.batch)
            .collect();
        let eval_batches: Vec<usize> = manifest
            .for_model_fn(kind.name(), "eval")
            .iter()
            .map(|e| e.batch)
            .collect();
        if grad_batches.is_empty() || eval_batches.is_empty() {
            return Err(anyhow!(
                "no grad/eval artifacts for model {:?} — run `make artifacts`",
                kind.name()
            ));
        }
        Ok(PjrtModel { spec, engine, grad_batches, eval_batches })
    }

    /// Pick the smallest artifact batch ≥ n, or the largest available.
    fn pick_batch(batches: &[usize], n: usize) -> usize {
        batches
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .unwrap_or_else(|| *batches.last().unwrap())
    }

    /// Build the padded (x, y_onehot, w) chunk inputs.
    fn chunk_inputs(
        &self,
        x: &Tensor,
        y: &[u32],
        lo: usize,
        hi: usize,
        padded: usize,
    ) -> Vec<HostTensor> {
        let d = self.spec.input_dim();
        let k = self.spec.num_classes;
        let mut xc = vec![0f32; padded * d];
        let mut yc = vec![0f32; padded * k];
        let mut wc = vec![0f32; padded];
        for (row, i) in (lo..hi).enumerate() {
            xc[row * d..(row + 1) * d].copy_from_slice(&x.data()[i * d..(i + 1) * d]);
            yc[row * k + y[i] as usize] = 1.0;
            wc[row] = 1.0;
        }
        vec![
            (vec![padded, d], xc),
            (vec![padded, k], yc),
            (vec![padded], wc),
        ]
    }

    fn run(
        &self,
        func: &str,
        batch_choices: &[usize],
        params: &[Tensor],
        x: &Tensor,
        y: &[u32],
    ) -> Result<Vec<(f64, Vec<HostTensor>)>> {
        let n = y.len();
        let b = Self::pick_batch(batch_choices, n);
        let name_for = |bb: usize| format!("{}_{}_b{}", self.spec.kind.name(), func, bb);
        let mut out = Vec::new();
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + b).min(n);
            let mut inputs: Vec<HostTensor> = params
                .iter()
                .map(|p| (p.shape().to_vec(), p.data().to_vec()))
                .collect();
            inputs.extend(self.chunk_inputs(x, y, lo, hi, b));
            let res = self
                .engine
                .execute(&name_for(b), inputs)
                .with_context(|| format!("artifact {}", name_for(b)))?;
            out.push(((hi - lo) as f64, res));
            lo = hi;
        }
        Ok(out)
    }
}

impl ModelOps for PjrtModel {
    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn loss_grad(&self, params: &[Tensor], x: &Tensor, y: &[u32]) -> (f32, Vec<Tensor>) {
        let chunks = self
            .run("grad", &self.grad_batches, params, x, y)
            .expect("pjrt loss_grad");
        let total: f64 = chunks.iter().map(|(n, _)| n).sum();
        let mut loss = 0f64;
        let mut grads: Vec<Tensor> = self
            .spec
            .params
            .iter()
            .map(|p| Tensor::zeros(&p.shape))
            .collect();
        for (n, outs) in chunks {
            let w = (n / total) as f32;
            // outs[0] = loss scalar, outs[1..] = grads
            loss += outs[0].1[0] as f64 * (n / total);
            for (g, (shape, data)) in grads.iter_mut().zip(outs[1..].iter()) {
                debug_assert_eq!(g.shape(), &shape[..]);
                let chunk_grad = Tensor::from_vec(shape, data.clone());
                g.axpy(w, &chunk_grad);
            }
        }
        (loss as f32, grads)
    }

    fn eval(&self, params: &[Tensor], x: &Tensor, y: &[u32]) -> (f32, usize) {
        let chunks = self
            .run("eval", &self.eval_batches, params, x, y)
            .expect("pjrt eval");
        let mut loss_sum = 0f64;
        let mut correct = 0f64;
        let mut total = 0f64;
        for (n, outs) in chunks {
            loss_sum += outs[0].1[0] as f64;
            correct += outs[1].1[0] as f64;
            total += n;
        }
        ((loss_sum / total.max(1.0)) as f32, correct.round() as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_batch_prefers_smallest_fit() {
        assert_eq!(PjrtModel::pick_batch(&[32, 512], 16), 32);
        assert_eq!(PjrtModel::pick_batch(&[32, 512], 32), 32);
        assert_eq!(PjrtModel::pick_batch(&[32, 512], 100), 512);
        // nothing fits: chunk with the largest
        assert_eq!(PjrtModel::pick_batch(&[32, 512], 2000), 512);
    }
}
