//! The artifact manifest (`artifacts/manifest.json`), written by
//! `python/compile/aot.py` and read here — the contract between the
//! python build path and the Rust request path.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::json::Json;

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    /// unique name, e.g. `mlp_grad_b512`
    pub name: String,
    /// HLO text file, relative to the manifest
    pub file: String,
    /// owning model ("mlp" | "cnn" | "vgg"), empty for kernels
    pub model: String,
    /// function ("grad" | "eval" | kernel name)
    pub func: String,
    /// static batch size (0 for non-batched kernels)
    pub batch: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// all artifacts
    pub entries: Vec<ArtifactEntry>,
    /// directory the manifest lives in (file paths resolve against it)
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        let arr = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing 'artifacts' array")?;
        let mut entries = Vec::with_capacity(arr.len());
        for a in arr {
            entries.push(ArtifactEntry {
                name: a
                    .get("name")
                    .and_then(Json::as_str)
                    .context("artifact missing name")?
                    .to_string(),
                file: a
                    .get("file")
                    .and_then(Json::as_str)
                    .context("artifact missing file")?
                    .to_string(),
                model: a
                    .get("model")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
                func: a
                    .get("fn")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
                batch: a.get("batch").and_then(Json::as_usize).unwrap_or(0),
            });
        }
        Ok(Manifest { entries, dir: dir.to_path_buf() })
    }

    /// Find one artifact by exact name.
    pub fn by_name(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// All artifacts for (model, fn), sorted by batch ascending.
    pub fn for_model_fn(&self, model: &str, func: &str) -> Vec<&ArtifactEntry> {
        let mut v: Vec<&ArtifactEntry> = self
            .entries
            .iter()
            .filter(|e| e.model == model && e.func == func)
            .collect();
        v.sort_by_key(|e| e.batch);
        v
    }

    /// Absolute path of an entry's HLO file.
    pub fn path_of(&self, e: &ArtifactEntry) -> PathBuf {
        self.dir.join(&e.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_query() {
        let dir = std::env::temp_dir().join("qrr_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts":[
                {"name":"mlp_grad_b32","file":"a.hlo.txt","model":"mlp","fn":"grad","batch":32},
                {"name":"mlp_grad_b512","file":"b.hlo.txt","model":"mlp","fn":"grad","batch":512},
                {"name":"quantize_4096","file":"q.hlo.txt","fn":"quantize"}
            ]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 3);
        assert!(m.by_name("mlp_grad_b32").is_some());
        let grads = m.for_model_fn("mlp", "grad");
        assert_eq!(grads.len(), 2);
        assert_eq!(grads[0].batch, 32);
        assert_eq!(grads[1].batch, 512);
        assert!(m.path_of(grads[0]).ends_with("a.hlo.txt"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_helpful_error() {
        let err = Manifest::load(Path::new("/definitely/missing")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
