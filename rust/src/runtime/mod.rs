//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and
//! executes them from the Rust request path.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (single-threaded), so the
//! runtime wraps it in a dedicated **executor thread**; [`PjrtEngine`] is
//! a cheap `Send + Sync` handle that ships jobs to that thread over a
//! channel. The FL clients all share one engine — PJRT's CPU backend is
//! internally multi-threaded, so serializing submissions does not
//! serialize the math.
//!
//! [`PjrtModel`] implements [`ModelOps`](crate::model::ModelOps) on top
//! of the engine: `loss_grad` runs the `<model>_grad_b<B>` artifact,
//! `eval` the `<model>_eval_b<B>` artifact. Batches that don't match an
//! artifact's static shape are chunked and zero-padded with a sample
//! weight vector, so results are exact for any batch size.

mod engine;
mod manifest;
mod model;
pub mod stub_xla;

pub use engine::PjrtEngine;
pub use manifest::{ArtifactEntry, Manifest};
pub use model::PjrtModel;

/// Directory holding artifacts + manifest; `QRR_ARTIFACTS` overrides
/// (read once through [`crate::util::env`], the sanctioned seam).
pub fn artifacts_dir() -> std::path::PathBuf {
    crate::util::env::artifacts_dir()
}
