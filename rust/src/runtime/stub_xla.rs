//! Offline stand-in for the `xla` crate (DESIGN.md §4).
//!
//! The real PJRT binding (`xla-rs`) is not in the offline crate cache,
//! so [`engine`](super::engine) compiles against this API-compatible
//! stub instead: client construction succeeds (so the manifest and
//! executable-cache plumbing stays exercised by tests), while any
//! attempt to actually compile or run an HLO artifact reports a clear
//! error. Swapping the real crate back in is a one-line import change
//! in `runtime::engine` plus a `Cargo.toml` dependency.

use std::fmt;

const UNAVAILABLE: &str =
    "PJRT runtime unavailable: built against the offline xla stub (DESIGN.md §4); \
     use the native backend or rebuild with the real `xla` crate";

/// Display-only error mirroring `xla::Error`.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias mirroring `xla::Result`.
pub type Result<T> = std::result::Result<T, Error>;

/// Stub of `xla::PjRtClient` (CPU platform only).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Construction always succeeds so the executor thread starts.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    /// Platform label shown in startup logs.
    pub fn platform_name(&self) -> &'static str {
        "offline-stub"
    }

    /// The stub exposes no devices.
    pub fn device_count(&self) -> usize {
        0
    }

    /// Compilation is where the stub reports its absence.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// Stub of `xla::HloModuleProto`.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parsing is deferred to compile time, which always errors here.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Ok(HloModuleProto)
    }
}

/// Stub of `xla::XlaComputation`.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed proto.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of `xla::PjRtLoadedExecutable` (never actually constructed).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Unreachable in practice: `compile` never hands one out.
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// Stub of `xla::PjRtBuffer`.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Unreachable in practice.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// Stub of `xla::Literal`.
#[derive(Debug)]
pub struct Literal;

impl Literal {
    /// Wrap host data (no-op in the stub).
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Reshape (no-op in the stub).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    /// Unreachable in practice.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error(UNAVAILABLE.into()))
    }

    /// Unreachable in practice.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error(UNAVAILABLE.into()))
    }

    /// Unreachable in practice.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// Stub of `xla::ArrayShape`.
#[derive(Debug)]
pub struct ArrayShape;

impl ArrayShape {
    /// No dimensions in the stub.
    pub fn dims(&self) -> &[i64] {
        &[]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_starts_but_compile_errors() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.device_count(), 0);
        let proto = HloModuleProto::from_text_file("nope.hlo.txt").unwrap();
        let comp = XlaComputation::from_proto(&proto);
        let err = client.compile(&comp).unwrap_err();
        assert!(format!("{err}").contains("stub"));
    }
}
