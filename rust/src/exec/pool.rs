//! A fixed-size thread pool with scoped parallel-for.
//!
//! Design: long-lived workers block on an injector channel of boxed
//! closures. [`ThreadPool::for_each`] runs *borrowing* closures on those
//! persistent workers: the borrow is lifetime-erased for the duration of
//! the call and the caller blocks until every task has finished, so the
//! round loop pays the thread-spawn cost once per session instead of
//! once per round.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// First panic payload captured by a parallel section.
type PanicSlot = Mutex<Option<Box<dyn Any + Send>>>;

/// Name prefix of pool workers; used to detect (and serialize) nested
/// `for_each` calls so a task running on the pool can never deadlock by
/// waiting for the pool.
const WORKER_NAME_PREFIX: &str = "qrr-worker-";

/// Fixed-size pool of worker threads executing boxed jobs.
#[derive(Debug)]
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("{WORKER_NAME_PREFIX}{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                // a panicking job must not skip the
                                // pending decrement below, or wait_idle
                                // (and Drop) would block forever
                                let _ = catch_unwind(AssertUnwindSafe(job));
                                let (lock, cv) = &*pending;
                                let mut p = lock.lock().unwrap();
                                *p -= 1;
                                if *p == 0 {
                                    cv.notify_all();
                                }
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx: Some(tx), workers, pending }
    }

    /// Pool with [`super::default_threads`] workers.
    pub fn default_size() -> Self {
        Self::new(super::default_threads())
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(job))
            .expect("worker alive");
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p != 0 {
            p = cv.wait(p).unwrap();
        }
    }

    /// Run `f(i)` for `i in 0..n` across the **persistent** workers and
    /// wait. `f` may borrow from the caller: tasks reference it only
    /// while this call blocks, and the calling thread drains indices
    /// alongside the workers. A panic in any `f(i)` is re-raised here —
    /// with its original payload — after all tasks have drained (no
    /// deadlock, no lost worker).
    ///
    /// Called from inside a pool task, this degrades to a serial loop —
    /// a task must never block waiting on its own pool. The final wait
    /// uses the pool-wide idle latch, so interleaving `for_each` with
    /// long-running [`Self::submit`] jobs from other call sites extends
    /// the wait to those jobs too; keep a pool to one usage pattern.
    pub fn for_each<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Send + Sync,
    {
        if n == 0 {
            return;
        }
        let threads = self.size().min(n);
        let on_worker = std::thread::current()
            .name()
            .is_some_and(|name| name.starts_with(WORKER_NAME_PREFIX));
        if threads <= 1 || n == 1 || on_worker {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let next = Arc::new(AtomicUsize::new(0));
        let panicked = Arc::new(AtomicBool::new(false));
        let payload: Arc<PanicSlot> = Arc::new(Mutex::new(None));
        let f_ref: &(dyn Fn(usize) + Send + Sync) = &f;
        // SAFETY: the erased reference is only used by tasks submitted
        // below, and `wait_idle` blocks until every one of them has
        // completed before this frame (and therefore `f`) is released.
        let f_static: &'static (dyn Fn(usize) + Send + Sync) = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Send + Sync),
                &'static (dyn Fn(usize) + Send + Sync),
            >(f_ref)
        };
        // threads - 1 helper tasks; the calling thread works too.
        for _ in 1..threads {
            let next = Arc::clone(&next);
            let panicked = Arc::clone(&panicked);
            let payload = Arc::clone(&payload);
            self.submit(move || drain_indices(f_static, &next, n, &panicked, &payload));
        }
        drain_indices(f_ref, &next, n, &panicked, &payload);
        self.wait_idle();
        if let Some(p) = payload.lock().unwrap().take() {
            resume_unwind(p);
        }
    }
}

/// Claim indices from the shared counter until exhausted (or a sibling
/// panicked). Panics are caught so the worker survives and the latch in
/// the pool still reaches zero; the first payload is stashed for the
/// caller to re-raise.
fn drain_indices(
    f: &(dyn Fn(usize) + Send + Sync),
    next: &AtomicUsize,
    n: usize,
    panicked: &AtomicBool,
    payload: &PanicSlot,
) {
    loop {
        if panicked.load(Ordering::Relaxed) {
            break;
        }
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            break;
        }
        if let Err(p) = catch_unwind(AssertUnwindSafe(|| f(i))) {
            panicked.store(true, Ordering::SeqCst);
            payload.lock().unwrap().get_or_insert(p);
            break;
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.wait_idle();
        drop(self.tx.take()); // close the channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Standalone scoped parallel-for over `0..n` with up to `threads`
/// OS threads (spawned ad hoc; fine for one-off coarse-grained work —
/// hot-path kernels use [`crate::exec::global_pool`] instead).
pub fn parallel_for<F>(threads: usize, n: usize, f: F)
where
    F: Fn(usize) + Send + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn for_each_covers_all_indices() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU64> = (0..257).map(|_| AtomicU64::new(0)).collect();
        pool.for_each(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn for_each_reuses_workers_across_calls() {
        // the hot-path pattern: many small parallel sections on one pool
        let pool = ThreadPool::new(4);
        let total = AtomicU64::new(0);
        for _round in 0..50 {
            pool.for_each(16, |i| {
                total.fetch_add(i as u64 + 1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::SeqCst), 50 * (16 * 17 / 2));
    }

    #[test]
    #[should_panic]
    fn for_each_propagates_panics() {
        let pool = ThreadPool::new(2);
        pool.for_each(64, |i| {
            if i == 3 {
                panic!("task 3 failed");
            }
        });
    }

    #[test]
    fn for_each_preserves_panic_payload() {
        let pool = ThreadPool::new(2);
        let err = catch_unwind(AssertUnwindSafe(|| {
            pool.for_each(32, |i| {
                if i == 5 {
                    panic!("boom at {i}");
                }
            });
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("boom at 5"), "payload lost: {msg:?}");
    }

    #[test]
    fn submitted_job_panic_does_not_wedge_the_pool() {
        let pool = ThreadPool::new(2);
        pool.submit(|| panic!("job panic"));
        pool.wait_idle(); // must not hang
        let counter = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&counter);
        pool.submit(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 1, "worker died after panic");
    }

    #[test]
    fn nested_for_each_serializes_instead_of_deadlocking() {
        let pool = Arc::new(ThreadPool::new(2));
        let inner = Arc::clone(&pool);
        let count = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&count);
        pool.submit(move || {
            inner.for_each(10, |_| {
                c.fetch_add(1, Ordering::SeqCst);
            });
        });
        pool.wait_idle();
        assert_eq!(count.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_for_borrows() {
        let data: Vec<u64> = (0..1000).collect();
        let sum = AtomicU64::new(0);
        parallel_for(8, data.len(), |i| {
            sum.fetch_add(data[i], Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 499_500);
    }

    #[test]
    fn zero_items_is_noop() {
        let pool = ThreadPool::new(2);
        pool.for_each(0, |_| panic!("should not run"));
        parallel_for(4, 0, |_| panic!("should not run"));
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
