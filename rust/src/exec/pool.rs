//! A fixed-size thread pool with scoped parallel-for.
//!
//! Design: long-lived workers block on an injector channel of boxed
//! closures. `scope`-style safety is achieved the simple way — jobs are
//! `'static`, and `parallel_for` wraps borrowed data in `Arc` + index
//! partitioning, joining before return so borrows stay sound via
//! `std::thread::scope` instead when lifetimes are needed.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size pool of worker threads executing boxed jobs.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("qrr-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                let (lock, cv) = &*pending;
                                let mut p = lock.lock().unwrap();
                                *p -= 1;
                                if *p == 0 {
                                    cv.notify_all();
                                }
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx: Some(tx), workers, pending }
    }

    /// Pool with [`super::default_threads`] workers.
    pub fn default_size() -> Self {
        Self::new(super::default_threads())
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(job))
            .expect("worker alive");
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p != 0 {
            p = cv.wait(p).unwrap();
        }
    }

    /// Run `f(i)` for `i in 0..n` across the pool and wait. `f` may borrow
    /// from the caller: uses `std::thread::scope` internally when the pool
    /// is bypassed (n small), otherwise chunks indices over workers.
    pub fn for_each<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Send + Sync,
    {
        if n == 0 {
            return;
        }
        let threads = self.size().min(n);
        let next = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(i);
                });
            }
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.wait_idle();
        drop(self.tx.take()); // close the channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Standalone scoped parallel-for over `0..n` with up to `threads`
/// OS threads (spawned ad hoc; fine for coarse-grained work).
pub fn parallel_for<F>(threads: usize, n: usize, f: F)
where
    F: Fn(usize) + Send + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn for_each_covers_all_indices() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU64> = (0..257).map(|_| AtomicU64::new(0)).collect();
        pool.for_each(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn parallel_for_borrows() {
        let data: Vec<u64> = (0..1000).collect();
        let sum = AtomicU64::new(0);
        parallel_for(8, data.len(), |i| {
            sum.fetch_add(data[i], Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 499_500);
    }

    #[test]
    fn zero_items_is_noop() {
        let pool = ThreadPool::new(2);
        pool.for_each(0, |_| panic!("should not run"));
        parallel_for(4, 0, |_| panic!("should not run"));
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
