//! A fixed-size thread pool with scoped parallel-for.
//!
//! Design: long-lived workers block on an injector channel of boxed
//! closures. [`ThreadPool::for_each`] runs *borrowing* closures on those
//! persistent workers: the borrow is lifetime-erased for the duration of
//! the call and the caller blocks until every task has finished, so the
//! round loop pays the thread-spawn cost once per session instead of
//! once per round.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// First panic payload captured by a parallel section.
type PanicSlot = Mutex<Option<Box<dyn Any + Send>>>;

/// Name prefix of pool workers; used to detect (and serialize) nested
/// `for_each` calls so a task running on the pool can never deadlock by
/// waiting for the pool.
const WORKER_NAME_PREFIX: &str = "qrr-worker-";

/// Fixed-size pool of worker threads executing boxed jobs.
#[derive(Debug)]
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("{WORKER_NAME_PREFIX}{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                // a panicking job must not skip the
                                // pending decrement below, or wait_idle
                                // (and Drop) would block forever
                                let _ = catch_unwind(AssertUnwindSafe(job));
                                let (lock, cv) = &*pending;
                                let mut p = lock.lock().unwrap();
                                *p -= 1;
                                if *p == 0 {
                                    cv.notify_all();
                                }
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { tx: Some(tx), workers, pending }
    }

    /// Pool with [`super::default_threads`] workers.
    pub fn default_size() -> Self {
        Self::new(super::default_threads())
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(job))
            .expect("worker alive");
    }

    /// Block until every submitted job has finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p != 0 {
            p = cv.wait(p).unwrap();
        }
    }

    /// Run `f(i)` for `i in 0..n` across the **persistent** workers and
    /// wait. `f` may borrow from the caller: tasks reference it only
    /// while this call blocks, and the calling thread drains indices
    /// alongside the workers. A panic in any `f(i)` is re-raised here —
    /// with its original payload — after all tasks have drained (no
    /// deadlock, no lost worker).
    ///
    /// Called from inside a pool task, this degrades to a serial loop —
    /// a task must never block waiting on its own pool. The final wait
    /// uses the pool-wide idle latch, so interleaving `for_each` with
    /// long-running [`Self::submit`] jobs from other call sites extends
    /// the wait to those jobs too; keep a pool to one usage pattern.
    pub fn for_each<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Send + Sync,
    {
        if n == 0 {
            return;
        }
        let threads = self.size().min(n);
        let on_worker = std::thread::current()
            .name()
            .is_some_and(|name| name.starts_with(WORKER_NAME_PREFIX));
        if threads <= 1 || n == 1 || on_worker {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let next = Arc::new(AtomicUsize::new(0));
        let panicked = Arc::new(AtomicBool::new(false));
        let payload: Arc<PanicSlot> = Arc::new(Mutex::new(None));
        let f_ref: &(dyn Fn(usize) + Send + Sync) = &f;
        // SAFETY: the erased reference is only used by tasks submitted
        // below, and `wait_idle` blocks until every one of them has
        // completed before this frame (and therefore `f`) is released.
        let f_static: &'static (dyn Fn(usize) + Send + Sync) = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Send + Sync),
                &'static (dyn Fn(usize) + Send + Sync),
            >(f_ref)
        };
        // threads - 1 helper tasks; the calling thread works too.
        for _ in 1..threads {
            let next = Arc::clone(&next);
            let panicked = Arc::clone(&panicked);
            let payload = Arc::clone(&payload);
            self.submit(move || drain_indices(f_static, &next, n, &panicked, &payload));
        }
        drain_indices(f_ref, &next, n, &panicked, &payload);
        self.wait_idle();
        if let Some(p) = payload.lock().unwrap().take() {
            resume_unwind(p);
        }
    }
}

/// Claim indices from the shared counter until exhausted (or a sibling
/// panicked). Panics are caught so the worker survives and the latch in
/// the pool still reaches zero; the first payload is stashed for the
/// caller to re-raise.
fn drain_indices(
    f: &(dyn Fn(usize) + Send + Sync),
    next: &AtomicUsize,
    n: usize,
    panicked: &AtomicBool,
    payload: &PanicSlot,
) {
    loop {
        if panicked.load(Ordering::Relaxed) {
            break;
        }
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            break;
        }
        if let Err(p) = catch_unwind(AssertUnwindSafe(|| f(i))) {
            panicked.store(true, Ordering::SeqCst);
            payload.lock().unwrap().get_or_insert(p);
            break;
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.wait_idle();
        drop(self.tx.take()); // close the channel; workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// One shard lane: a FIFO queue of jobs plus a flag that is true while
/// a drain task for this lane is live on the shared pool.
struct Lane {
    queue: Mutex<LaneQueue>,
}

struct LaneQueue {
    jobs: VecDeque<Job>,
    /// True while a drain task for this lane is queued or running on
    /// the pool. Toggled only under the `queue` lock, so a dispatch
    /// either lands in front of a live drain (which will pop it) or
    /// observes `false` and submits a fresh drain — never neither.
    running: bool,
}

/// N serialized FIFO lanes multiplexed onto [`global_pool`](super::global_pool).
///
/// Each lane executes its jobs **in dispatch order, one at a time** —
/// the ownership discipline the sharded aggregation server relies on:
/// shard state is touched only from that shard's lane, so per-shard
/// partial sums need no locking discipline beyond lane membership.
/// Lanes run concurrently with each other, sharing the crate-wide pool
/// instead of pinning N extra OS threads; a lane only occupies a worker
/// while it has queued work (a *drain task*), so idle shards cost
/// nothing.
///
/// Jobs that panic are caught: the lane keeps draining, the executor
/// stays usable, and [`ShardExecutor::barrier`] re-raises the first
/// captured payload once every outstanding job has finished — the same
/// contract as [`ThreadPool::for_each`].
pub struct ShardExecutor {
    lanes: Vec<Arc<Lane>>,
    /// Outstanding-job latch: incremented at dispatch, decremented as
    /// each job completes (even by panic), zero means quiescent.
    pending: Arc<(Mutex<usize>, Condvar)>,
    payload: Arc<PanicSlot>,
}

impl std::fmt::Debug for ShardExecutor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardExecutor")
            .field("lanes", &self.lanes.len())
            .finish_non_exhaustive()
    }
}

impl ShardExecutor {
    /// Executor with `n` lanes (n >= 1) backed by the crate-wide pool.
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let lanes = (0..n)
            .map(|_| {
                Arc::new(Lane {
                    queue: Mutex::new(LaneQueue { jobs: VecDeque::new(), running: false }),
                })
            })
            .collect();
        ShardExecutor {
            lanes,
            pending: Arc::new((Mutex::new(0usize), Condvar::new())),
            payload: Arc::new(Mutex::new(None)),
        }
    }

    /// Number of lanes.
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Queue `job` on lane `shard % lanes()`.
    ///
    /// Jobs on the same lane run serially in dispatch order; jobs on
    /// different lanes may run concurrently. Returns immediately — use
    /// [`Self::barrier`] to wait for completion.
    pub fn dispatch(&self, shard: usize, job: impl FnOnce() + Send + 'static) {
        let lane = &self.lanes[shard % self.lanes.len()];
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        let spawn_drain = {
            let mut q = lane.queue.lock().unwrap();
            q.jobs.push_back(Box::new(job));
            if q.running {
                false
            } else {
                q.running = true;
                true
            }
        };
        if spawn_drain {
            let lane = Arc::clone(lane);
            let pending = Arc::clone(&self.pending);
            let payload = Arc::clone(&self.payload);
            super::global_pool().submit(move || drain_lane(&lane, &pending, &payload));
        }
    }

    /// Block until every dispatched job has finished, then re-raise the
    /// first panic payload captured since the last barrier (if any).
    ///
    /// Waits on this executor's own latch, so concurrent `for_each` /
    /// `submit` traffic on the shared pool does not extend the wait.
    pub fn barrier(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p != 0 {
            p = cv.wait(p).unwrap();
        }
        drop(p);
        if let Some(payload) = self.payload.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }
}

/// Pop-and-run jobs from `lane` until its queue is empty, then clear
/// `running` and return the worker to the pool. The empty-check and the
/// `running` reset happen under one lock acquisition, so a concurrent
/// dispatch can never leave a queued job with no drain task live.
fn drain_lane(lane: &Lane, pending: &(Mutex<usize>, Condvar), payload: &PanicSlot) {
    loop {
        let job = {
            let mut q = lane.queue.lock().unwrap();
            match q.jobs.pop_front() {
                Some(job) => job,
                None => {
                    q.running = false;
                    return;
                }
            }
        };
        // a panicking job must neither wedge the lane nor skip the
        // latch decrement — barrier() re-raises the stashed payload
        if let Err(p) = catch_unwind(AssertUnwindSafe(job)) {
            payload.lock().unwrap().get_or_insert(p);
        }
        let (lock, cv) = pending;
        let mut p = lock.lock().unwrap();
        *p -= 1;
        if *p == 0 {
            cv.notify_all();
        }
    }
}

/// Standalone scoped parallel-for over `0..n` with up to `threads`
/// OS threads (spawned ad hoc; fine for one-off coarse-grained work —
/// hot-path kernels use [`crate::exec::global_pool`] instead).
pub fn parallel_for<F>(threads: usize, n: usize, f: F)
where
    F: Fn(usize) + Send + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn for_each_covers_all_indices() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU64> = (0..257).map(|_| AtomicU64::new(0)).collect();
        pool.for_each(hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn for_each_reuses_workers_across_calls() {
        // the hot-path pattern: many small parallel sections on one pool
        let pool = ThreadPool::new(4);
        let total = AtomicU64::new(0);
        for _round in 0..50 {
            pool.for_each(16, |i| {
                total.fetch_add(i as u64 + 1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::SeqCst), 50 * (16 * 17 / 2));
    }

    #[test]
    #[should_panic]
    fn for_each_propagates_panics() {
        let pool = ThreadPool::new(2);
        pool.for_each(64, |i| {
            if i == 3 {
                panic!("task 3 failed");
            }
        });
    }

    #[test]
    fn for_each_preserves_panic_payload() {
        let pool = ThreadPool::new(2);
        let err = catch_unwind(AssertUnwindSafe(|| {
            pool.for_each(32, |i| {
                if i == 5 {
                    panic!("boom at {i}");
                }
            });
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("boom at 5"), "payload lost: {msg:?}");
    }

    #[test]
    fn submitted_job_panic_does_not_wedge_the_pool() {
        let pool = ThreadPool::new(2);
        pool.submit(|| panic!("job panic"));
        pool.wait_idle(); // must not hang
        let counter = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&counter);
        pool.submit(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 1, "worker died after panic");
    }

    #[test]
    fn nested_for_each_serializes_instead_of_deadlocking() {
        let pool = Arc::new(ThreadPool::new(2));
        let inner = Arc::clone(&pool);
        let count = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&count);
        pool.submit(move || {
            inner.for_each(10, |_| {
                c.fetch_add(1, Ordering::SeqCst);
            });
        });
        pool.wait_idle();
        assert_eq!(count.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_for_borrows() {
        let data: Vec<u64> = (0..1000).collect();
        let sum = AtomicU64::new(0);
        parallel_for(8, data.len(), |i| {
            sum.fetch_add(data[i], Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 499_500);
    }

    #[test]
    fn zero_items_is_noop() {
        let pool = ThreadPool::new(2);
        pool.for_each(0, |_| panic!("should not run"));
        parallel_for(4, 0, |_| panic!("should not run"));
    }

    #[test]
    fn shard_lane_preserves_fifo_order() {
        let ex = ShardExecutor::new(1);
        let seen = Arc::new(Mutex::new(Vec::new()));
        for i in 0..200u64 {
            let seen = Arc::clone(&seen);
            ex.dispatch(0, move || seen.lock().unwrap().push(i));
        }
        ex.barrier();
        let seen = seen.lock().unwrap();
        assert_eq!(*seen, (0..200).collect::<Vec<u64>>());
    }

    #[test]
    fn shard_lanes_run_independently() {
        let ex = ShardExecutor::new(4);
        let sums: Vec<AtomicU64> = (0..4).map(|_| AtomicU64::new(0)).collect();
        let sums = Arc::new(sums);
        for i in 0..400u64 {
            let sums = Arc::clone(&sums);
            ex.dispatch(i as usize % 4, move || {
                sums[i as usize % 4].fetch_add(i, Ordering::SeqCst);
            });
        }
        ex.barrier();
        for lane in 0..4u64 {
            let want: u64 = (0..400).filter(|i| i % 4 == lane).sum();
            assert_eq!(sums[lane as usize].load(Ordering::SeqCst), want, "lane {lane}");
        }
    }

    #[test]
    fn shard_jobs_on_one_lane_never_overlap() {
        // mutual exclusion per lane: a lane job observing another lane
        // job of the same lane in flight would break shard ownership
        let ex = ShardExecutor::new(2);
        let in_flight = Arc::new([AtomicU64::new(0), AtomicU64::new(0)]);
        let overlapped = Arc::new(AtomicBool::new(false));
        for i in 0..100usize {
            let in_flight = Arc::clone(&in_flight);
            let overlapped = Arc::clone(&overlapped);
            ex.dispatch(i % 2, move || {
                let lane = i % 2;
                if in_flight[lane].fetch_add(1, Ordering::SeqCst) != 0 {
                    overlapped.store(true, Ordering::SeqCst);
                }
                std::thread::yield_now();
                in_flight[lane].fetch_sub(1, Ordering::SeqCst);
            });
        }
        ex.barrier();
        assert!(!overlapped.load(Ordering::SeqCst), "two jobs ran on one lane at once");
    }

    #[test]
    fn shard_barrier_reraises_panic_and_lane_survives() {
        let ex = ShardExecutor::new(2);
        let count = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&count);
        ex.dispatch(0, move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        ex.dispatch(0, || panic!("shard job failed"));
        let c = Arc::clone(&count);
        ex.dispatch(0, move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        let err = catch_unwind(AssertUnwindSafe(|| ex.barrier())).unwrap_err();
        let msg = err.downcast_ref::<&str>().copied().unwrap_or_default();
        assert!(msg.contains("shard job failed"), "payload lost: {msg:?}");
        // the lane kept draining past the panic and stays usable
        assert_eq!(count.load(Ordering::SeqCst), 2);
        let c = Arc::clone(&count);
        ex.dispatch(1, move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        ex.barrier(); // payload already consumed: must not re-raise
        assert_eq!(count.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn shard_barrier_on_idle_executor_returns() {
        let ex = ShardExecutor::new(3);
        ex.barrier();
        ex.barrier();
    }

    #[test]
    fn shard_dispatch_after_barrier_reuses_lanes() {
        let ex = ShardExecutor::new(2);
        let total = Arc::new(AtomicU64::new(0));
        for round in 0..20u64 {
            for i in 0..8u64 {
                let total = Arc::clone(&total);
                ex.dispatch(i as usize, move || {
                    total.fetch_add(round * 8 + i, Ordering::Relaxed);
                });
            }
            ex.barrier();
        }
        let want: u64 = (0..160).sum();
        assert_eq!(total.load(Ordering::SeqCst), want);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
