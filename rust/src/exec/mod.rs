//! Execution substrate: a small work-stealing-free thread pool and
//! scoped parallel iteration.
//!
//! The offline crate cache has neither `tokio` nor `rayon`; FL rounds are
//! compute-bound fan-out/fan-in over ~10 clients, which this pool covers
//! with far less machinery (see DESIGN.md §4).

mod pool;

pub use pool::{parallel_for, ThreadPool};

/// Number of worker threads to use by default: `QRR_THREADS` env var or
/// available parallelism, capped at 16.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("QRR_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}
