//! Execution substrate: a small work-stealing-free thread pool, scoped
//! parallel iteration, and the runtime-dispatched SIMD kernel layer.
//!
//! The offline crate cache has neither `tokio` nor `rayon`; FL rounds are
//! compute-bound fan-out/fan-in over ~10 clients, which this pool covers
//! with far less machinery (see DESIGN.md §4). The [`simd`] module holds
//! the crate's vector kernels — AVX2+FMA with a portable scalar
//! fallback, selected once per process via CPU detection or `QRR_SIMD`
//! (DESIGN.md §8).

mod pool;
pub mod simd;

pub use pool::{parallel_for, ShardExecutor, ThreadPool};
pub use simd::SimdLevel;

use std::sync::OnceLock;

/// Number of worker threads to use by default: the `QRR_THREADS` env
/// override or available parallelism, capped at 16.
///
/// The environment is read **once per process** and cached — every
/// construction site (the session pool, the crate-wide [`global_pool`],
/// ad-hoc `parallel_for` calls) sees the same value, and the hot path
/// never pays for an env lookup (DESIGN.md §4).
pub fn default_threads() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        if let Ok(v) = std::env::var("QRR_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16)
    })
}

/// The crate-wide shared worker pool: created on first use with
/// [`default_threads`] workers and kept for the life of the process.
///
/// This is the pool the GEMM/matvec parallel paths split work on. A
/// **cached handle** means `QRR_THREADS` is honored once and
/// consistently — no per-call pool construction or thread spawning —
/// and because [`ThreadPool::for_each`] degrades to a serial loop when
/// the calling thread is itself a pool worker, kernels invoked from
/// inside a session's per-client fan-out (or a [`ShardExecutor`] lane's
/// decode + absorb) can never oversubscribe the machine with nested
/// parallelism (DESIGN.md §6).
pub fn global_pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(ThreadPool::default_size)
}
