//! Execution substrate: a small work-stealing-free thread pool and
//! scoped parallel iteration.
//!
//! The offline crate cache has neither `tokio` nor `rayon`; FL rounds are
//! compute-bound fan-out/fan-in over ~10 clients, which this pool covers
//! with far less machinery (see DESIGN.md §4).

mod pool;

pub use pool::{parallel_for, ThreadPool};

use std::sync::OnceLock;

/// Number of worker threads to use by default: the `QRR_THREADS` env
/// override or available parallelism, capped at 16.
///
/// The environment is read **once per process** and cached — every
/// construction site (the session pool, the GEMM row split, ad-hoc
/// `parallel_for` calls) sees the same value, and the hot path never
/// pays for an env lookup (DESIGN.md §4).
pub fn default_threads() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        if let Ok(v) = std::env::var("QRR_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16)
    })
}
