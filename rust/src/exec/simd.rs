//! Runtime-dispatched SIMD kernel layer (DESIGN.md §8).
//!
//! Every float hot loop in the crate — the GEMM micro-kernel, the LAQ
//! grid quantizer, the BLAS-1 updates under aggregation/error-feedback,
//! the `‖·‖∞` reduction scans — routes through this module. At first
//! use the process picks one dispatch [`level`]:
//!
//! * [`SimdLevel::Avx2`] — explicit AVX2+FMA kernels (x86-64 with both
//!   `avx2` and `fma` detected via `is_x86_feature_detected!`),
//! * [`SimdLevel::Scalar`] — the portable fallback in [`scalar`], which
//!   doubles as the parity oracle for the vector paths.
//!
//! `QRR_SIMD=scalar|avx2` overrides detection and — like `QRR_THREADS`
//! — is read **once per process**, so a run never mixes paths: the
//! mirrored client/server quantizer states and the per-element GEMM
//! summation order are deterministic for a given machine + env.
//!
//! Determinism contract (property-tested in `tests/simd_parity.rs` and
//! below):
//!
//! * **elementwise float kernels** ([`axpy`], [`sum_into`], [`scale`],
//!   [`mul`]) and the **reduction scans** ([`max_abs`],
//!   [`max_abs_diff`]) are bit-exact across dispatch levels — the AVX2
//!   paths deliberately use mul+add (no FMA contraction) and exact
//!   abs/max lanes;
//! * the **fused LAQ pass** ([`laq_quantize`], [`laq_dequantize`]) is
//!   bit-exact across levels: the grid math runs in f64 on both paths
//!   with identical rounding, so the wire codes never depend on the
//!   dispatch;
//! * **integer kernels** ([`pack_codes_into`], [`unpack_codes_into`])
//!   are bit-exact by construction (word-at-a-time u64 bit-buffer,
//!   specialized β∈{1,2,4,8,16} fast paths, tested byte-for-byte
//!   against the byte-at-a-time reference);
//! * [`dot`] and the GEMM tile accumulate with FMA on AVX2 and agree
//!   with the scalar path within floating-point tolerance only.

use std::sync::OnceLock;

/// Vector instruction level a process dispatches its kernels at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable scalar kernels (the fallback and parity oracle).
    Scalar,
    /// Explicit AVX2+FMA kernels (x86-64 only).
    Avx2,
}

impl SimdLevel {
    /// Lower-case label, matching the values `QRR_SIMD` accepts.
    pub fn label(&self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
        }
    }
}

/// The dispatch level in effect for this process: the `QRR_SIMD` env
/// override (`scalar` | `avx2`) or CPU detection, decided **once** and
/// cached — kernels branch on a cached value, never on the environment.
/// A `QRR_SIMD=avx2` request on a machine without avx2+fma falls back
/// to scalar (with a warning) instead of executing illegal instructions.
pub fn level() -> SimdLevel {
    static CACHED: OnceLock<SimdLevel> = OnceLock::new();
    *CACHED.get_or_init(|| match std::env::var("QRR_SIMD").ok().as_deref() {
        Some("scalar") => SimdLevel::Scalar,
        Some("avx2") => {
            if avx2_available() {
                SimdLevel::Avx2
            } else {
                eprintln!("warning: QRR_SIMD=avx2 set but avx2+fma not detected; using scalar");
                SimdLevel::Scalar
            }
        }
        Some(other) => {
            eprintln!("warning: unknown QRR_SIMD={other:?} (scalar|avx2); auto-detecting");
            detect()
        }
        None => detect(),
    })
}

/// True when this process dispatches to the AVX2+FMA kernels — the
/// cached branch the hot paths take.
#[inline]
pub fn avx2_enabled() -> bool {
    level() == SimdLevel::Avx2
}

fn detect() -> SimdLevel {
    if avx2_available() {
        SimdLevel::Avx2
    } else {
        SimdLevel::Scalar
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

/// The vector features the running CPU actually reports, independent of
/// any `QRR_SIMD` override — recorded in bench suite reports so
/// committed baselines say what machine produced them.
#[cfg(target_arch = "x86_64")]
pub fn cpu_features() -> &'static str {
    match (std::is_x86_feature_detected!("avx2"), std::is_x86_feature_detected!("fma")) {
        (true, true) => "avx2,fma",
        (true, false) => "avx2",
        (false, true) => "fma",
        (false, false) => "x86-64-baseline",
    }
}

/// The vector features the running CPU actually reports (non-x86-64
/// builds have no vector kernels and always dispatch scalar).
#[cfg(not(target_arch = "x86_64"))]
pub fn cpu_features() -> &'static str {
    "portable"
}

// -------------------------------------------------------- float kernels

/// Dot product `Σ a[i]·b[i]` with 8 independent partial sums (the
/// matvec row kernel). FMA-accumulated on AVX2; scalar and vector paths
/// agree within floating-point tolerance.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "dot length mismatch");
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_enabled() {
            // SAFETY: avx2_enabled() is true only when avx2+fma were
            // detected on this CPU.
            return unsafe { avx2::dot(a, b) };
        }
    }
    scalar::dot(a, b)
}

/// `y[i] += alpha · x[i]` — the BLAS-1 update under error feedback,
/// weighted aggregation and descent. Bit-exact across dispatch levels;
/// `alpha == 1.0` takes the multiply-free [`sum_into`] path.
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len(), "axpy length mismatch");
    if alpha == 1.0 {
        // 1.0 · x is exact: the plain sum is bit-identical and cheaper.
        sum_into_unchecked(y, x);
        return;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_enabled() {
            // SAFETY: avx2_enabled() implies avx2+fma were detected.
            unsafe { avx2::axpy(y, alpha, x) };
            return;
        }
    }
    scalar::axpy(y, alpha, x)
}

/// `acc[i] += x[i]` — the aggregation sum. Bit-exact across dispatch
/// levels.
// The aggregation inner loop: runs once per client per round over every
// parameter — dispatch and kernel must not allocate.
// qrr-audit: no-alloc
pub fn sum_into(acc: &mut [f32], x: &[f32]) {
    assert_eq!(acc.len(), x.len(), "sum_into length mismatch");
    sum_into_unchecked(acc, x);
}

fn sum_into_unchecked(acc: &mut [f32], x: &[f32]) {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_enabled() {
            // SAFETY: avx2_enabled() implies avx2+fma were detected.
            unsafe { avx2::sum_into(acc, x) };
            return;
        }
    }
    scalar::sum_into(acc, x)
}
// qrr-audit: end

/// `a[i] *= alpha` — factor/step scaling. Bit-exact across dispatch
/// levels.
pub fn scale(a: &mut [f32], alpha: f32) {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_enabled() {
            // SAFETY: avx2_enabled() implies avx2+fma were detected.
            unsafe { avx2::scale(a, alpha) };
            return;
        }
    }
    scalar::scale(a, alpha)
}

/// `a[i] *= b[i]` — elementwise multiply (the SVD `U·diag(s)` /
/// `V·diag(1/s)` row scaling). Bit-exact across dispatch levels.
pub fn mul(a: &mut [f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "mul length mismatch");
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_enabled() {
            // SAFETY: avx2_enabled() implies avx2+fma were detected.
            unsafe { avx2::mul(a, b) };
            return;
        }
    }
    scalar::mul(a, b)
}

/// `max_i |a[i]|` (0.0 for an empty slice) — the ℓ∞ norm scan.
/// Bit-exact across dispatch levels; NaN elements are skipped on both
/// paths (`f32::max` semantics).
pub fn max_abs(a: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_enabled() {
            // SAFETY: avx2_enabled() implies avx2+fma were detected.
            return unsafe { avx2::max_abs(a) };
        }
    }
    scalar::max_abs(a)
}

/// `max_i |a[i] − b[i]|` (0.0 for empty slices) — the LAQ grid-radius
/// scan `‖g − prev‖∞`. Bit-exact across dispatch levels; NaN diffs are
/// skipped on both paths (`f32::max` semantics), so even a poisoned
/// gradient yields the same radius — and thus the same wire bytes —
/// at every level.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "max_abs_diff length mismatch");
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_enabled() {
            // SAFETY: avx2_enabled() implies avx2+fma were detected.
            return unsafe { avx2::max_abs_diff(a, b) };
        }
    }
    scalar::max_abs_diff(a, b)
}

// ------------------------------------------------------ fused LAQ pass

/// Fused LAQ quantize sweep (paper eq. (15)–(17)): in one pass over
/// `g`/`prev`, compute the branchless grid code
/// `q = clamp(⌊(g−prev+R)/(2τR) + ½⌋, 0, 2^β−1)` into `codes` and the
/// reconstruction `prev + 2τR·q − R` into `out`. The grid math runs in
/// f64 on both dispatch paths with identical rounding, so codes and
/// reconstruction are bit-exact across levels.
///
/// `radius` must be finite and positive (the degenerate `R = 0` grid is
/// the caller's fast path); all slices must share one length.
// The fused quantize/dequantize sweeps run on every wire payload;
// callers pass reused buffers and the pass itself must not allocate.
// qrr-audit: no-alloc
pub fn laq_quantize(
    g: &[f32],
    prev: &[f32],
    radius: f32,
    beta: u8,
    codes: &mut [u32],
    out: &mut [f32],
) {
    assert!((1..=16).contains(&beta), "beta must be in 1..=16");
    assert!(
        radius.is_finite() && radius > 0.0,
        "laq_quantize requires a positive finite radius"
    );
    let n = g.len();
    assert!(
        prev.len() == n && codes.len() == n && out.len() == n,
        "laq_quantize length mismatch"
    );
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_enabled() {
            // SAFETY: avx2_enabled() implies avx2+fma were detected.
            unsafe { avx2::laq_quantize(g, prev, radius, beta, codes, out) };
            return;
        }
    }
    scalar::laq_quantize(g, prev, radius, beta, codes, out)
}

/// Fused LAQ dequantize sweep (paper eq. (17)): `out = prev + 2τR·q − R`
/// from unpacked codes. Accepts any finite radius (a zero radius
/// reproduces `prev`). Bit-exact across dispatch levels.
pub fn laq_dequantize(codes: &[u32], prev: &[f32], radius: f32, beta: u8, out: &mut [f32]) {
    assert!((1..=16).contains(&beta), "beta must be in 1..=16");
    let n = codes.len();
    assert!(
        prev.len() == n && out.len() == n,
        "laq_dequantize length mismatch"
    );
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_enabled() {
            // SAFETY: avx2_enabled() implies avx2+fma were detected.
            unsafe { avx2::laq_dequantize(codes, prev, radius, beta, out) };
            return;
        }
    }
    scalar::laq_dequantize(codes, prev, radius, beta, out)
}
// qrr-audit: end

// -------------------------------------------------------- bit packing

/// Pack `codes` (each < 2^β) LSB-first into `out` (cleared and sized to
/// exactly ⌈βn/8⌉ bytes): a u64 bit-buffer drained six bytes at a time,
/// with dedicated byte-aligned fast paths for β ∈ {1, 2, 4, 8, 16}.
/// Bit-exact with the byte-at-a-time reference for every β.
pub fn pack_codes_into(codes: &[u32], beta: u8, out: &mut Vec<u8>) {
    assert!((1..=16).contains(&beta), "beta must be in 1..=16");
    out.clear();
    out.resize((codes.len() * beta as usize).div_ceil(8), 0);
    match beta {
        8 => pack_beta8(codes, out),
        16 => pack_beta16(codes, out),
        1 => pack_pow2::<1>(codes, out),
        2 => pack_pow2::<2>(codes, out),
        4 => pack_pow2::<4>(codes, out),
        _ => pack_generic(codes, beta, out),
    }
}

/// Unpack `n` β-bit codes from `bytes` into `out` (cleared first),
/// mirroring [`pack_codes_into`]'s fast paths.
pub fn unpack_codes_into(bytes: &[u8], n: usize, beta: u8, out: &mut Vec<u32>) {
    assert!((1..=16).contains(&beta), "beta must be in 1..=16");
    let need = (n * beta as usize).div_ceil(8);
    assert!(
        bytes.len() >= need,
        "byte stream too short: {} < {need}",
        bytes.len()
    );
    out.clear();
    out.reserve(n);
    match beta {
        8 => out.extend(bytes[..n].iter().map(|&b| b as u32)),
        16 => out.extend(
            bytes[..2 * n]
                .chunks_exact(2)
                .map(|p| u16::from_le_bytes([p[0], p[1]]) as u32),
        ),
        1 => unpack_pow2::<1>(bytes, n, out),
        2 => unpack_pow2::<2>(bytes, n, out),
        4 => unpack_pow2::<4>(bytes, n, out),
        _ => unpack_generic(bytes, n, beta, out),
    }
}

// Word-at-a-time packing loops: the wrappers above size the buffers;
// the loops themselves only shift, mask and push.
// qrr-audit: no-alloc
/// β = 8: one code per byte.
fn pack_beta8(codes: &[u32], out: &mut [u8]) {
    for (o, &c) in out.iter_mut().zip(codes.iter()) {
        debug_assert!(c <= 0xFF, "code {c} exceeds 8 bits");
        *o = c as u8;
    }
}

/// β = 16: one little-endian u16 per code.
fn pack_beta16(codes: &[u32], out: &mut [u8]) {
    for (o, &c) in out.chunks_exact_mut(2).zip(codes.iter()) {
        debug_assert!(c <= 0xFFFF, "code {c} exceeds 16 bits");
        o.copy_from_slice(&(c as u16).to_le_bytes());
    }
}

/// β ∈ {1, 2, 4}: 8/β codes per byte, no code ever crosses a byte.
fn pack_pow2<const B: usize>(codes: &[u32], out: &mut [u8]) {
    let per = 8 / B;
    let mask = (1u32 << B) - 1;
    let full = codes.len() / per;
    for (i, byte) in out.iter_mut().enumerate().take(full) {
        let mut b = 0u32;
        for (j, &c) in codes[i * per..(i + 1) * per].iter().enumerate() {
            debug_assert!(c <= mask, "code {c} exceeds {B} bits");
            b |= (c & mask) << (j * B);
        }
        *byte = b as u8;
    }
    let rest = &codes[full * per..];
    if !rest.is_empty() {
        let mut b = 0u32;
        for (j, &c) in rest.iter().enumerate() {
            debug_assert!(c <= mask, "code {c} exceeds {B} bits");
            b |= (c & mask) << (j * B);
        }
        out[full] = b as u8;
    }
}

/// Any β in 1..=16: u64 bit-buffer, OR codes in at the fill level,
/// drain 48 bits (six whole bytes) at a time. The fill never exceeds
/// 47 + 16 = 63 bits, so the buffer cannot overflow.
fn pack_generic(codes: &[u32], beta: u8, out: &mut [u8]) {
    let b = beta as u32;
    let mask = (1u32 << b) - 1;
    let mut acc = 0u64;
    let mut fill = 0u32;
    let mut pos = 0usize;
    for &c in codes {
        debug_assert!(c <= mask, "code {c} exceeds {beta} bits");
        acc |= ((c & mask) as u64) << fill;
        fill += b;
        if fill >= 48 {
            out[pos..pos + 6].copy_from_slice(&acc.to_le_bytes()[..6]);
            acc >>= 48;
            fill -= 48;
            pos += 6;
        }
    }
    while fill > 0 {
        out[pos] = acc as u8;
        acc >>= 8;
        pos += 1;
        fill = fill.saturating_sub(8);
    }
    debug_assert_eq!(pos, out.len());
}

/// β ∈ {1, 2, 4}: expand 8/β codes out of each byte.
fn unpack_pow2<const B: usize>(bytes: &[u8], n: usize, out: &mut Vec<u32>) {
    let per = 8 / B;
    let mask = (1u32 << B) - 1;
    let full = n / per;
    for &byte in &bytes[..full] {
        let w = byte as u32;
        for j in 0..per {
            out.push((w >> (j * B)) & mask);
        }
    }
    let rest = n - full * per;
    if rest > 0 {
        let w = bytes[full] as u32;
        for j in 0..rest {
            out.push((w >> (j * B)) & mask);
        }
    }
}

/// Any β in 1..=16: refill the u64 bit-buffer byte-wise (at most two
/// reads per code since β ≤ 16), then mask the code off the bottom.
fn unpack_generic(bytes: &[u8], n: usize, beta: u8, out: &mut Vec<u32>) {
    let b = beta as u32;
    let mask = (1u64 << b) - 1;
    let mut acc = 0u64;
    let mut fill = 0u32;
    let mut pos = 0usize;
    for _ in 0..n {
        while fill < b {
            acc |= (bytes[pos] as u64) << fill;
            pos += 1;
            fill += 8;
        }
        out.push((acc & mask) as u32);
        acc >>= b;
        fill -= b;
    }
}
// qrr-audit: end

// ------------------------------------------------------------- scalar

/// Portable reference kernels: the dispatch fallback on machines (or
/// under `QRR_SIMD=scalar`) without AVX2+FMA, and the parity oracle the
/// vector paths are property-tested against.
pub mod scalar {
    /// Dot product with 8 independent partial sums, reduced pairwise —
    /// mirrors the AVX2 kernel's lane structure so the two paths agree
    /// closely (the vector path additionally contracts to FMA).
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = [0f32; 8];
        let chunks = a.len() / 8;
        for c in 0..chunks {
            let x = &a[c * 8..c * 8 + 8];
            let y = &b[c * 8..c * 8 + 8];
            for l in 0..8 {
                acc[l] += x[l] * y[l];
            }
        }
        let mut s =
            ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
        for j in chunks * 8..a.len() {
            s += a[j] * b[j];
        }
        s
    }

    /// `y[i] += alpha · x[i]`.
    pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        for (yi, &xi) in y.iter_mut().zip(x.iter()) {
            *yi += alpha * xi;
        }
    }

    /// `acc[i] += x[i]`.
    // qrr-audit: no-alloc
    pub fn sum_into(acc: &mut [f32], x: &[f32]) {
        debug_assert_eq!(acc.len(), x.len());
        for (a, &xi) in acc.iter_mut().zip(x.iter()) {
            *a += xi;
        }
    }
    // qrr-audit: end

    /// `a[i] *= alpha`.
    pub fn scale(a: &mut [f32], alpha: f32) {
        for x in a.iter_mut() {
            *x *= alpha;
        }
    }

    /// `a[i] *= b[i]`.
    pub fn mul(a: &mut [f32], b: &[f32]) {
        debug_assert_eq!(a.len(), b.len());
        for (x, &y) in a.iter_mut().zip(b.iter()) {
            *x *= y;
        }
    }

    /// `max_i |a[i]|` (0.0 when empty).
    pub fn max_abs(a: &[f32]) -> f32 {
        a.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// `max_i |a[i] − b[i]|` (0.0 when empty).
    pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        a.iter()
            .zip(b.iter())
            .fold(0.0f32, |m, (&x, &y)| m.max((x - y).abs()))
    }

    /// Fused LAQ quantize sweep; see [`super::laq_quantize`]. The grid
    /// math is f64 exactly as the paper-reproduction loop always was.
    // qrr-audit: no-alloc
    pub fn laq_quantize(
        g: &[f32],
        prev: &[f32],
        radius: f32,
        beta: u8,
        codes: &mut [u32],
        out: &mut [f32],
    ) {
        debug_assert!(g.len() == prev.len() && g.len() == codes.len() && g.len() == out.len());
        let levels = (1u32 << beta) - 1;
        let tau = 1.0f64 / levels as f64;
        let step = 2.0 * tau * radius as f64;
        let r = radius as f64;
        let it = g.iter().zip(prev.iter()).zip(codes.iter_mut()).zip(out.iter_mut());
        for (((x, p), c), o) in it {
            // eq. (15): branchless grid code
            let t = ((*x - *p) as f64 + r) / step + 0.5;
            let q = (t.floor() as i64).clamp(0, levels as i64) as u32;
            *c = q;
            // eq. (16)/(17): Q = prev + 2τR·q − R
            *o = *p + (step * q as f64 - r) as f32;
        }
    }

    /// Fused LAQ dequantize sweep; see [`super::laq_dequantize`].
    pub fn laq_dequantize(codes: &[u32], prev: &[f32], radius: f32, beta: u8, out: &mut [f32]) {
        debug_assert!(codes.len() == prev.len() && codes.len() == out.len());
        let levels = (1u32 << beta) - 1;
        let tau = 1.0f64 / levels as f64;
        let step = 2.0 * tau * radius as f64;
        let r = radius as f64;
        for ((&q, p), o) in codes.iter().zip(prev.iter()).zip(out.iter_mut()) {
            *o = *p + (step * q as f64 - r) as f32;
        }
    }
    // qrr-audit: end
}

// --------------------------------------------------------------- avx2

/// Explicit AVX2+FMA kernels. Every function here is `unsafe` with the
/// same contract: **the caller must have verified `avx2` and `fma` are
/// available on the running CPU** (the dispatch wrappers in the parent
/// module do; tests gate on `is_x86_feature_detected!`).
#[cfg(target_arch = "x86_64")]
pub mod avx2 {
    use std::arch::x86_64::*;

    /// FMA-accumulated dot product, 8 lanes, reduced pairwise in the
    /// scalar order.
    ///
    /// # Safety
    /// Requires avx2+fma (see the module contract).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: caller guarantees avx2+fma; loads stay within a/b (chunks*8 <= len).
        unsafe {
            debug_assert_eq!(a.len(), b.len());
            let n = a.len();
            let chunks = n / 8;
            let mut acc = _mm256_setzero_ps();
            for c in 0..chunks {
                let x = _mm256_loadu_ps(a.as_ptr().add(c * 8));
                let y = _mm256_loadu_ps(b.as_ptr().add(c * 8));
                acc = _mm256_fmadd_ps(x, y, acc);
            }
            let mut lanes = [0f32; 8];
            _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
            let mut s = ((lanes[0] + lanes[4]) + (lanes[1] + lanes[5]))
                + ((lanes[2] + lanes[6]) + (lanes[3] + lanes[7]));
            for j in chunks * 8..n {
                s += a[j] * b[j];
            }
            s
        }
    }

    /// `y[i] += alpha · x[i]`, deliberately mul+add (not FMA) so the
    /// result is bit-exact with the scalar path.
    ///
    /// # Safety
    /// Requires avx2+fma (see the module contract).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
        // SAFETY: caller guarantees avx2+fma; loads/stores stay within y/x (chunks*8 <= len).
        unsafe {
            debug_assert_eq!(y.len(), x.len());
            let n = y.len();
            let a = _mm256_set1_ps(alpha);
            let chunks = n / 8;
            for c in 0..chunks {
                let yp = y.as_mut_ptr().add(c * 8);
                let yv = _mm256_loadu_ps(yp);
                let xv = _mm256_loadu_ps(x.as_ptr().add(c * 8));
                _mm256_storeu_ps(yp, _mm256_add_ps(yv, _mm256_mul_ps(a, xv)));
            }
            for j in chunks * 8..n {
                y[j] += alpha * x[j];
            }
        }
    }

    /// `acc[i] += x[i]`, bit-exact with the scalar path.
    ///
    /// # Safety
    /// Requires avx2+fma (see the module contract).
    // qrr-audit: no-alloc
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn sum_into(acc: &mut [f32], x: &[f32]) {
        // SAFETY: caller guarantees avx2+fma; loads/stores stay within acc/x (chunks*8 <= len).
        unsafe {
            debug_assert_eq!(acc.len(), x.len());
            let n = acc.len();
            let chunks = n / 8;
            for c in 0..chunks {
                let ap = acc.as_mut_ptr().add(c * 8);
                let av = _mm256_loadu_ps(ap);
                let xv = _mm256_loadu_ps(x.as_ptr().add(c * 8));
                _mm256_storeu_ps(ap, _mm256_add_ps(av, xv));
            }
            for j in chunks * 8..n {
                acc[j] += x[j];
            }
        }
    }
    // qrr-audit: end

    /// `a[i] *= alpha`, bit-exact with the scalar path.
    ///
    /// # Safety
    /// Requires avx2+fma (see the module contract).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn scale(a: &mut [f32], alpha: f32) {
        // SAFETY: caller guarantees avx2+fma; loads/stores stay within a (chunks*8 <= len).
        unsafe {
            let n = a.len();
            let m = _mm256_set1_ps(alpha);
            let chunks = n / 8;
            for c in 0..chunks {
                let p = a.as_mut_ptr().add(c * 8);
                _mm256_storeu_ps(p, _mm256_mul_ps(_mm256_loadu_ps(p), m));
            }
            for j in chunks * 8..n {
                a[j] *= alpha;
            }
        }
    }

    /// `a[i] *= b[i]`, bit-exact with the scalar path.
    ///
    /// # Safety
    /// Requires avx2+fma (see the module contract).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn mul(a: &mut [f32], b: &[f32]) {
        // SAFETY: caller guarantees avx2+fma; loads/stores stay within a/b (chunks*8 <= len).
        unsafe {
            debug_assert_eq!(a.len(), b.len());
            let n = a.len();
            let chunks = n / 8;
            for c in 0..chunks {
                let p = a.as_mut_ptr().add(c * 8);
                let bv = _mm256_loadu_ps(b.as_ptr().add(c * 8));
                _mm256_storeu_ps(p, _mm256_mul_ps(_mm256_loadu_ps(p), bv));
            }
            for j in chunks * 8..n {
                a[j] *= b[j];
            }
        }
    }

    /// `max_i |a[i]|`, bit-exact with the scalar path — including NaN
    /// inputs: `vmaxps` returns its **second** operand when either is
    /// NaN, so keeping the accumulator second skips NaN lanes exactly
    /// like `f32::max` does in the scalar fold.
    ///
    /// # Safety
    /// Requires avx2+fma (see the module contract).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn max_abs(a: &[f32]) -> f32 {
        // SAFETY: caller guarantees avx2+fma; loads stay within a (chunks*8 <= len).
        unsafe {
            let n = a.len();
            let sign = _mm256_set1_ps(-0.0);
            let mut m = _mm256_setzero_ps();
            let chunks = n / 8;
            for c in 0..chunks {
                let v = _mm256_loadu_ps(a.as_ptr().add(c * 8));
                m = _mm256_max_ps(_mm256_andnot_ps(sign, v), m);
            }
            let mut lanes = [0f32; 8];
            _mm256_storeu_ps(lanes.as_mut_ptr(), m);
            let mut s = 0f32;
            for &l in &lanes {
                s = s.max(l);
            }
            for j in chunks * 8..n {
                s = s.max(a[j].abs());
            }
            s
        }
    }

    /// `max_i |a[i] − b[i]|`, bit-exact with the scalar path — NaN
    /// diffs are skipped like `f32::max` skips them (accumulator kept
    /// as `vmaxps`'s second operand; see [`max_abs`]).
    ///
    /// # Safety
    /// Requires avx2+fma (see the module contract).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: caller guarantees avx2+fma; loads stay within a/b (chunks*8 <= len).
        unsafe {
            debug_assert_eq!(a.len(), b.len());
            let n = a.len();
            let sign = _mm256_set1_ps(-0.0);
            let mut m = _mm256_setzero_ps();
            let chunks = n / 8;
            for c in 0..chunks {
                let x = _mm256_loadu_ps(a.as_ptr().add(c * 8));
                let y = _mm256_loadu_ps(b.as_ptr().add(c * 8));
                m = _mm256_max_ps(_mm256_andnot_ps(sign, _mm256_sub_ps(x, y)), m);
            }
            let mut lanes = [0f32; 8];
            _mm256_storeu_ps(lanes.as_mut_ptr(), m);
            let mut s = 0f32;
            for &l in &lanes {
                s = s.max(l);
            }
            for j in chunks * 8..n {
                s = s.max((a[j] - b[j]).abs());
            }
            s
        }
    }

    // qrr-audit: no-alloc
    /// One 4-lane f64 step of the LAQ grid: code + reconstruction for
    /// four pre-widened diffs. The op sequence (add, div, add, floor,
    /// clamp, mul, sub) matches the scalar path exactly, so the result
    /// is bit-identical lane-for-lane.
    ///
    /// # Safety
    /// Requires avx2+fma (see the module contract).
    #[inline]
    // on toolchains where value-only intrinsics are safe inside a
    // matching #[target_feature] fn, the body's unsafe block is redundant
    #[allow(unused_unsafe)]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn laq_lane4(
        d: __m256d,
        step: __m256d,
        r: __m256d,
        half: __m256d,
        zero: __m256d,
        levels: __m256d,
    ) -> (__m128i, __m128) {
        // SAFETY: caller guarantees avx2+fma; value-only intrinsics, no memory access.
        unsafe {
            let t = _mm256_add_pd(_mm256_div_pd(_mm256_add_pd(d, r), step), half);
            let q = _mm256_min_pd(_mm256_max_pd(_mm256_floor_pd(t), zero), levels);
            let rec = _mm256_sub_pd(_mm256_mul_pd(step, q), r);
            (_mm256_cvttpd_epi32(q), _mm256_cvtpd_ps(rec))
        }
    }

    /// Fused LAQ quantize sweep: the f32 innovation is widened to f64
    /// and pushed through [`laq_lane4`] eight elements per iteration;
    /// bit-exact with [`super::scalar::laq_quantize`].
    ///
    /// # Safety
    /// Requires avx2+fma (see the module contract).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn laq_quantize(
        g: &[f32],
        prev: &[f32],
        radius: f32,
        beta: u8,
        codes: &mut [u32],
        out: &mut [f32],
    ) {
        // SAFETY: caller guarantees avx2+fma; loads/stores stay within
        // the equal-length slices (chunks*8 <= n) and laq_lane4 shares
        // this fn's contract.
        unsafe {
            let n = g.len();
            debug_assert!(prev.len() == n && codes.len() == n && out.len() == n);
            let levels = (1u32 << beta) - 1;
            let tau = 1.0f64 / levels as f64;
            let step = 2.0 * tau * radius as f64;
            let step_pd = _mm256_set1_pd(step);
            let r_pd = _mm256_set1_pd(radius as f64);
            let half_pd = _mm256_set1_pd(0.5);
            let zero_pd = _mm256_setzero_pd();
            let lev_pd = _mm256_set1_pd(levels as f64);
            let chunks = n / 8;
            for c in 0..chunks {
                let gv = _mm256_loadu_ps(g.as_ptr().add(c * 8));
                let pv = _mm256_loadu_ps(prev.as_ptr().add(c * 8));
                // f32 subtraction first (one rounding, as in the scalar
                // path), then widen exactly to f64
                let d = _mm256_sub_ps(gv, pv);
                let d_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(d));
                let d_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(d));
                let (q_lo, rec_lo) = laq_lane4(d_lo, step_pd, r_pd, half_pd, zero_pd, lev_pd);
                let (q_hi, rec_hi) = laq_lane4(d_hi, step_pd, r_pd, half_pd, zero_pd, lev_pd);
                let cp = codes.as_mut_ptr().add(c * 8);
                _mm_storeu_si128(cp as *mut __m128i, q_lo);
                _mm_storeu_si128(cp.add(4) as *mut __m128i, q_hi);
                let op = out.as_mut_ptr().add(c * 8);
                let p_lo = _mm256_castps256_ps128(pv);
                let p_hi = _mm256_extractf128_ps::<1>(pv);
                _mm_storeu_ps(op, _mm_add_ps(p_lo, rec_lo));
                _mm_storeu_ps(op.add(4), _mm_add_ps(p_hi, rec_hi));
            }
            let done = chunks * 8;
            super::scalar::laq_quantize(
                &g[done..],
                &prev[done..],
                radius,
                beta,
                &mut codes[done..],
                &mut out[done..],
            );
        }
    }

    /// Fused LAQ dequantize sweep, four codes per iteration; bit-exact
    /// with [`super::scalar::laq_dequantize`].
    ///
    /// # Safety
    /// Requires avx2+fma (see the module contract).
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn laq_dequantize(
        codes: &[u32],
        prev: &[f32],
        radius: f32,
        beta: u8,
        out: &mut [f32],
    ) {
        // SAFETY: caller guarantees avx2+fma; loads/stores stay within
        // the equal-length slices (chunks*4 <= n).
        unsafe {
            let n = codes.len();
            debug_assert!(prev.len() == n && out.len() == n);
            let levels = (1u32 << beta) - 1;
            let tau = 1.0f64 / levels as f64;
            let step = 2.0 * tau * radius as f64;
            let step_pd = _mm256_set1_pd(step);
            let r_pd = _mm256_set1_pd(radius as f64);
            let chunks = n / 4;
            for c in 0..chunks {
                // codes are ≤ 2^16−1, so the i32 reinterpretation is exact
                let q = _mm_loadu_si128(codes.as_ptr().add(c * 4) as *const __m128i);
                let q_pd = _mm256_cvtepi32_pd(q);
                let rec = _mm256_sub_pd(_mm256_mul_pd(step_pd, q_pd), r_pd);
                let p = _mm_loadu_ps(prev.as_ptr().add(c * 4));
                _mm_storeu_ps(
                    out.as_mut_ptr().add(c * 4),
                    _mm_add_ps(p, _mm256_cvtpd_ps(rec)),
                );
            }
            let done = chunks * 4;
            let tail = &mut out[done..];
            super::scalar::laq_dequantize(&codes[done..], &prev[done..], radius, beta, tail);
        }
    }
    // qrr-audit: end

    /// The 8×8 f32 GEMM register tile:
    /// `acc[r][c] += Σ_p ap[p·8+r] · bp[p·8+c]`, held in eight YMM
    /// accumulators with one broadcast + FMA per (p, r). Panels follow
    /// the packed layout of `linalg::matmul` (k-major, zero-padded).
    ///
    /// # Safety
    /// Requires avx2+fma (see the module contract); `ap`/`bp` must hold
    /// at least `kc·8` elements.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn gemm_tile_8x8(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [[f32; 8]; 8]) {
        // SAFETY: caller guarantees avx2+fma and that ap/bp hold kc*8
        // elements (debug-asserted); acc rows are [f32; 8].
        unsafe {
            debug_assert!(ap.len() >= kc * 8 && bp.len() >= kc * 8);
            let mut c0 = _mm256_loadu_ps(acc[0].as_ptr());
            let mut c1 = _mm256_loadu_ps(acc[1].as_ptr());
            let mut c2 = _mm256_loadu_ps(acc[2].as_ptr());
            let mut c3 = _mm256_loadu_ps(acc[3].as_ptr());
            let mut c4 = _mm256_loadu_ps(acc[4].as_ptr());
            let mut c5 = _mm256_loadu_ps(acc[5].as_ptr());
            let mut c6 = _mm256_loadu_ps(acc[6].as_ptr());
            let mut c7 = _mm256_loadu_ps(acc[7].as_ptr());
            for p in 0..kc {
                let b = _mm256_loadu_ps(bp.as_ptr().add(p * 8));
                let a = ap.as_ptr().add(p * 8);
                c0 = _mm256_fmadd_ps(_mm256_set1_ps(*a), b, c0);
                c1 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(1)), b, c1);
                c2 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(2)), b, c2);
                c3 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(3)), b, c3);
                c4 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(4)), b, c4);
                c5 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(5)), b, c5);
                c6 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(6)), b, c6);
                c7 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(7)), b, c7);
            }
            _mm256_storeu_ps(acc[0].as_mut_ptr(), c0);
            _mm256_storeu_ps(acc[1].as_mut_ptr(), c1);
            _mm256_storeu_ps(acc[2].as_mut_ptr(), c2);
            _mm256_storeu_ps(acc[3].as_mut_ptr(), c3);
            _mm256_storeu_ps(acc[4].as_mut_ptr(), c4);
            _mm256_storeu_ps(acc[5].as_mut_ptr(), c5);
            _mm256_storeu_ps(acc[6].as_mut_ptr(), c6);
            _mm256_storeu_ps(acc[7].as_mut_ptr(), c7);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Lengths that straddle every lane/remainder boundary.
    const LENS: [usize; 15] = [0, 1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 33, 100, 1037];

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.range_f32(-3.0, 3.0)).collect()
    }

    #[test]
    fn level_is_cached_and_consistent() {
        let l = level();
        assert_eq!(l, level());
        assert_eq!(avx2_enabled(), l == SimdLevel::Avx2);
        assert!(matches!(l.label(), "scalar" | "avx2"));
        assert!(!cpu_features().is_empty());
    }

    #[test]
    fn dispatched_elementwise_kernels_match_scalar_bitwise() {
        // Whatever level this process dispatches at, the elementwise
        // kernels must be bit-exact with the scalar oracle.
        let mut rng = Rng::new(900);
        for &n in &LENS {
            let x = rand_vec(&mut rng, n);
            let y0 = rand_vec(&mut rng, n);

            let mut a = y0.clone();
            axpy(&mut a, 0.37, &x);
            let mut b = y0.clone();
            scalar::axpy(&mut b, 0.37, &x);
            assert_eq!(bits(&a), bits(&b), "axpy n={n}");

            let mut a = y0.clone();
            sum_into(&mut a, &x);
            let mut b = y0.clone();
            scalar::sum_into(&mut b, &x);
            assert_eq!(bits(&a), bits(&b), "sum_into n={n}");

            let mut a = y0.clone();
            scale(&mut a, -1.7);
            let mut b = y0.clone();
            scalar::scale(&mut b, -1.7);
            assert_eq!(bits(&a), bits(&b), "scale n={n}");

            let mut a = y0.clone();
            mul(&mut a, &x);
            let mut b = y0.clone();
            scalar::mul(&mut b, &x);
            assert_eq!(bits(&a), bits(&b), "mul n={n}");

            assert_eq!(max_abs(&x).to_bits(), scalar::max_abs(&x).to_bits(), "max_abs n={n}");
            assert_eq!(
                max_abs_diff(&x, &y0).to_bits(),
                scalar::max_abs_diff(&x, &y0).to_bits(),
                "max_abs_diff n={n}"
            );
        }
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn dispatched_dot_matches_scalar_within_tolerance() {
        let mut rng = Rng::new(901);
        for &n in &LENS {
            let x = rand_vec(&mut rng, n);
            let y = rand_vec(&mut rng, n);
            let d = dot(&x, &y);
            let s = scalar::dot(&x, &y);
            assert!(
                (d - s).abs() <= 1e-4 * s.abs().max(1.0),
                "dot n={n}: {d} vs {s}"
            );
        }
    }

    #[test]
    fn max_scans_skip_nan_like_scalar() {
        // a poisoned gradient must yield the same radius on every
        // dispatch level: NaN is skipped exactly like f32::max skips it
        let mut x = vec![0.5f32; 24];
        x[3] = 5.0;
        x[11] = f32::NAN; // same lane as the 5.0 (stride 8)
        x[19] = 1.0;
        assert_eq!(max_abs(&x).to_bits(), scalar::max_abs(&x).to_bits());
        assert_eq!(max_abs(&x), 5.0);
        let zeros = vec![0.0f32; 24];
        let d = max_abs_diff(&x, &zeros);
        assert_eq!(d.to_bits(), scalar::max_abs_diff(&x, &zeros).to_bits());
        assert_eq!(d, 5.0);
        #[cfg(target_arch = "x86_64")]
        {
            if std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma") {
                // SAFETY: avx2+fma detected above.
                let (va, vd) = unsafe { (avx2::max_abs(&x), avx2::max_abs_diff(&x, &zeros)) };
                assert_eq!(va, 5.0);
                assert_eq!(vd, 5.0);
            }
        }
    }

    #[test]
    fn axpy_alpha_one_is_plain_sum() {
        let mut rng = Rng::new(902);
        let x = rand_vec(&mut rng, 100);
        let y0 = rand_vec(&mut rng, 100);
        let mut a = y0.clone();
        axpy(&mut a, 1.0, &x);
        let mut b = y0.clone();
        sum_into(&mut b, &x);
        assert_eq!(bits(&a), bits(&b));
    }

    #[test]
    fn laq_fused_matches_scalar_bitwise() {
        let mut rng = Rng::new(903);
        for &n in &LENS {
            for beta in [1u8, 2, 3, 4, 7, 8, 11, 16] {
                let g = rand_vec(&mut rng, n);
                let prev = rand_vec(&mut rng, n);
                let radius = scalar::max_abs_diff(&g, &prev);
                if radius == 0.0 {
                    continue; // degenerate grid is the caller's path
                }
                let mut c_d = vec![0u32; n];
                let mut o_d = vec![0f32; n];
                laq_quantize(&g, &prev, radius, beta, &mut c_d, &mut o_d);
                let mut c_s = vec![0u32; n];
                let mut o_s = vec![0f32; n];
                scalar::laq_quantize(&g, &prev, radius, beta, &mut c_s, &mut o_s);
                assert_eq!(c_d, c_s, "codes n={n} beta={beta}");
                assert_eq!(bits(&o_d), bits(&o_s), "recon n={n} beta={beta}");

                let mut r_d = vec![0f32; n];
                laq_dequantize(&c_d, &prev, radius, beta, &mut r_d);
                let mut r_s = vec![0f32; n];
                scalar::laq_dequantize(&c_s, &prev, radius, beta, &mut r_s);
                assert_eq!(bits(&r_d), bits(&r_s), "dequant n={n} beta={beta}");
                // quantize's own reconstruction and dequantize agree
                assert_eq!(bits(&o_d), bits(&r_d), "paths n={n} beta={beta}");
            }
        }
    }

    #[test]
    fn laq_fused_respects_error_bound() {
        let mut rng = Rng::new(904);
        for beta in [1u8, 2, 4, 8, 12, 16] {
            let n = 257;
            let g = rand_vec(&mut rng, n);
            let prev = rand_vec(&mut rng, n);
            let radius = max_abs_diff(&g, &prev);
            let levels = (1u32 << beta) - 1;
            let tau = 1.0 / levels as f32;
            let mut codes = vec![0u32; n];
            let mut out = vec![0f32; n];
            laq_quantize(&g, &prev, radius, beta, &mut codes, &mut out);
            let hi = levels;
            assert!(codes.iter().all(|&c| c <= hi), "beta={beta}");
            let bound = tau * radius * (1.0 + 1e-4) + 1e-7;
            for i in 0..n {
                assert!(
                    (g[i] - out[i]).abs() <= bound,
                    "beta={beta} i={i}: err {} > {bound}",
                    (g[i] - out[i]).abs()
                );
            }
        }
    }

    /// The byte-at-a-time packers the word-at-a-time paths must match
    /// byte-for-byte (the pre-SIMD production code).
    mod reference {
        pub fn pack(codes: &[u32], beta: u8) -> Vec<u8> {
            let mask = (1u32 << beta) - 1;
            let mut out = vec![0u8; (codes.len() * beta as usize).div_ceil(8)];
            let mut bitpos = 0usize;
            for &c in codes {
                let c = (c & mask) as u64;
                let byte = bitpos / 8;
                let off = bitpos % 8;
                let merged = c << off;
                out[byte] |= (merged & 0xFF) as u8;
                if off + beta as usize > 8 {
                    out[byte + 1] |= ((merged >> 8) & 0xFF) as u8;
                }
                if off + beta as usize > 16 {
                    out[byte + 2] |= ((merged >> 16) & 0xFF) as u8;
                }
                bitpos += beta as usize;
            }
            out
        }

        pub fn unpack(bytes: &[u8], n: usize, beta: u8) -> Vec<u32> {
            let mask = (1u64 << beta) - 1;
            let mut out = Vec::with_capacity(n);
            let mut bitpos = 0usize;
            for _ in 0..n {
                let byte = bitpos / 8;
                let off = bitpos % 8;
                let mut window = bytes[byte] as u64;
                if byte + 1 < bytes.len() {
                    window |= (bytes[byte + 1] as u64) << 8;
                }
                if byte + 2 < bytes.len() {
                    window |= (bytes[byte + 2] as u64) << 16;
                }
                out.push(((window >> off) & mask) as u32);
                bitpos += beta as usize;
            }
            out
        }
    }

    #[test]
    fn pack_unpack_match_reference_byte_for_byte() {
        let mut rng = Rng::new(905);
        let mut packed = Vec::new();
        let mut codes_out = Vec::new();
        for beta in 1..=16u8 {
            let max = (1u64 << beta) as usize;
            for &n in &LENS {
                let codes: Vec<u32> = (0..n).map(|_| rng.below(max) as u32).collect();
                pack_codes_into(&codes, beta, &mut packed);
                let want = reference::pack(&codes, beta);
                assert_eq!(packed, want, "pack beta={beta} n={n}");
                unpack_codes_into(&packed, n, beta, &mut codes_out);
                assert_eq!(codes_out, codes, "unpack beta={beta} n={n}");
                assert_eq!(
                    reference::unpack(&packed, n, beta),
                    codes,
                    "ref unpack beta={beta} n={n}"
                );
            }
        }
    }

    #[test]
    fn pack_boundary_codes_all_betas() {
        for beta in 1..=16u8 {
            let hi = (1u32 << beta) - 1;
            let codes = vec![0, hi, hi, 0, hi, 0, 0, hi, hi];
            let mut packed = Vec::new();
            pack_codes_into(&codes, beta, &mut packed);
            assert_eq!(packed, reference::pack(&codes, beta), "beta={beta}");
            let mut back = Vec::new();
            unpack_codes_into(&packed, codes.len(), beta, &mut back);
            assert_eq!(back, codes, "beta={beta}");
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_kernels_match_scalar_directly() {
        // Stronger than the dispatched tests: exercise the vector
        // kernels explicitly whenever the CPU has them, even under
        // QRR_SIMD=scalar.
        if !(std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma")) {
            return;
        }
        let mut rng = Rng::new(906);
        for &n in &LENS {
            let x = rand_vec(&mut rng, n);
            let y0 = rand_vec(&mut rng, n);

            let mut a = y0.clone();
            // SAFETY: avx2+fma detected above.
            unsafe { avx2::axpy(&mut a, -0.61, &x) };
            let mut b = y0.clone();
            scalar::axpy(&mut b, -0.61, &x);
            assert_eq!(bits(&a), bits(&b), "axpy n={n}");

            let mut a = y0.clone();
            // SAFETY: avx2+fma detected above.
            unsafe { avx2::sum_into(&mut a, &x) };
            let mut b = y0.clone();
            scalar::sum_into(&mut b, &x);
            assert_eq!(bits(&a), bits(&b), "sum_into n={n}");

            let mut a = y0.clone();
            // SAFETY: avx2+fma detected above.
            unsafe { avx2::mul(&mut a, &x) };
            let mut b = y0.clone();
            scalar::mul(&mut b, &x);
            assert_eq!(bits(&a), bits(&b), "mul n={n}");

            let mut a = y0.clone();
            // SAFETY: avx2+fma detected above.
            unsafe { avx2::scale(&mut a, 2.5) };
            let mut b = y0.clone();
            scalar::scale(&mut b, 2.5);
            assert_eq!(bits(&a), bits(&b), "scale n={n}");

            // SAFETY: avx2+fma detected above.
            let (ma, md) = unsafe { (avx2::max_abs(&x), avx2::max_abs_diff(&x, &y0)) };
            assert_eq!(ma.to_bits(), scalar::max_abs(&x).to_bits(), "max_abs n={n}");
            assert_eq!(
                md.to_bits(),
                scalar::max_abs_diff(&x, &y0).to_bits(),
                "max_abs_diff n={n}"
            );

            // SAFETY: avx2+fma detected above.
            let d = unsafe { avx2::dot(&x, &y0) };
            let s = scalar::dot(&x, &y0);
            assert!((d - s).abs() <= 1e-4 * s.abs().max(1.0), "dot n={n}");

            let radius = scalar::max_abs_diff(&x, &y0);
            if radius > 0.0 {
                let mut c_v = vec![0u32; n];
                let mut o_v = vec![0f32; n];
                // SAFETY: avx2+fma detected above.
                unsafe { avx2::laq_quantize(&x, &y0, radius, 5, &mut c_v, &mut o_v) };
                let mut c_s = vec![0u32; n];
                let mut o_s = vec![0f32; n];
                scalar::laq_quantize(&x, &y0, radius, 5, &mut c_s, &mut o_s);
                assert_eq!(c_v, c_s, "laq codes n={n}");
                assert_eq!(bits(&o_v), bits(&o_s), "laq recon n={n}");
                let mut r_v = vec![0f32; n];
                // SAFETY: avx2+fma detected above.
                unsafe { avx2::laq_dequantize(&c_v, &y0, radius, 5, &mut r_v) };
                let mut r_s = vec![0f32; n];
                scalar::laq_dequantize(&c_s, &y0, radius, 5, &mut r_s);
                assert_eq!(bits(&r_v), bits(&r_s), "laq dequant n={n}");
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_gemm_tile_matches_naive() {
        if !(std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma")) {
            return;
        }
        let mut rng = Rng::new(907);
        for &kc in &[0usize, 1, 2, 7, 64, 200] {
            let ap = rand_vec(&mut rng, kc * 8);
            let bp = rand_vec(&mut rng, kc * 8);
            let mut acc = [[0f32; 8]; 8];
            // SAFETY: avx2+fma detected above.
            unsafe { avx2::gemm_tile_8x8(kc, &ap, &bp, &mut acc) };
            for r in 0..8 {
                for c in 0..8 {
                    let mut want = 0f64;
                    for p in 0..kc {
                        want += ap[p * 8 + r] as f64 * bp[p * 8 + c] as f64;
                    }
                    assert!(
                        (acc[r][c] as f64 - want).abs() <= 1e-3 * want.abs().max(1.0),
                        "kc={kc} ({r},{c}): {} vs {want}",
                        acc[r][c]
                    );
                }
            }
        }
    }

    #[test]
    #[should_panic]
    fn laq_quantize_rejects_zero_radius() {
        let g = [1.0f32];
        let p = [0.0f32];
        let mut c = [0u32];
        let mut o = [0f32];
        laq_quantize(&g, &p, 0.0, 8, &mut c, &mut o);
    }

    #[test]
    #[should_panic]
    fn pack_rejects_beta_zero() {
        let mut out = Vec::new();
        pack_codes_into(&[0], 0, &mut out);
    }
}
