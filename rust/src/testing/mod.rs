//! Test utilities: a small seeded property-testing harness
//! (the offline substitute for `proptest` — DESIGN.md §4).

pub mod prop;

pub use prop::{forall, Gen};

/// Case count for property sweeps, shrunk under Miri.
///
/// The interpreter runs ~two orders of magnitude slower than native
/// code, so the byte-level suites (`net::wire`, `quant::bitpack`) pass
/// their `forall` counts and heavy loop bounds through this: full
/// coverage natively, a handful of cases under `cargo miri test`.
/// Deliberately *not* folded into [`forall`] itself — its case count is
/// part of that harness' own contract (and tests).
pub fn cases(n: usize) -> usize {
    if cfg!(miri) {
        n.clamp(1, 3)
    } else {
        n
    }
}
