//! Test utilities: a small seeded property-testing harness
//! (the offline substitute for `proptest` — DESIGN.md §4).

pub mod prop;

pub use prop::{forall, Gen};
