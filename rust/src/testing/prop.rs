//! Seeded property sweeps.
//!
//! `forall(seed, cases, gen, prop)` draws `cases` random inputs from
//! `gen` and asserts `prop` on each; failures report the case index and
//! the per-case seed so a single case is exactly reproducible:
//!
//! ```no_run
//! use qrr::testing::{forall, Gen};
//! forall(0xFEED, 64, |g| g.vec_f32(10, -1.0, 1.0), |xs| {
//!     assert!(xs.iter().all(|x| x.abs() <= 1.0));
//! });
//! ```

use crate::tensor::Tensor;
use crate::util::Rng;

/// Random-input generator handed to property closures.
#[derive(Debug)]
pub struct Gen {
    rng: Rng,
}

impl Gen {
    /// Wrap a PRNG.
    pub fn new(rng: Rng) -> Self {
        Gen { rng }
    }

    /// Access the raw PRNG.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Uniform usize in [lo, hi].
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    /// Vector of uniform f32s.
    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Standard-normal tensor of a random shape with `ndim` dims, each in
    /// [1, max_dim].
    pub fn tensor(&mut self, ndim: usize, max_dim: usize) -> Tensor {
        let shape: Vec<usize> = (0..ndim).map(|_| self.usize_in(1, max_dim)).collect();
        Tensor::randn(&shape, &mut self.rng)
    }

    /// Standard-normal matrix with dims in [1, max_dim].
    pub fn matrix(&mut self, max_dim: usize) -> Tensor {
        self.tensor(2, max_dim)
    }

    /// Pick one of the slice's elements.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Run `prop` over `cases` inputs drawn by `gen`, deterministic in `seed`.
pub fn forall<T>(seed: u64, cases: usize, mut gen: impl FnMut(&mut Gen) -> T, mut prop: impl FnMut(T)) {
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let case_seed = root.next_u64();
        let mut g = Gen::new(Rng::new(case_seed));
        let input = gen(&mut g);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(input)));
        if let Err(e) = result {
            eprintln!(
                "property failed at case {case}/{cases} (case_seed={case_seed:#x}, seed={seed:#x})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        forall(1, 25, |g| g.usize_in(0, 10), |_| {});
        forall(1, 25, |g| g.usize_in(3, 5), |v| {
            assert!((3..=5).contains(&v));
        });
        // count side effect through gen
        forall(2, 10, |g| { count += 1; g.f32_in(0.0, 1.0) }, |v| {
            assert!((0.0..1.0).contains(&v));
        });
        assert_eq!(count, 10);
    }

    #[test]
    fn deterministic_inputs() {
        let mut a = Vec::new();
        forall(7, 5, |g| g.usize_in(0, 1000), |v| a.push(v));
        // same seed, same draws — gen closures mutate captured state, so
        // collect through the prop instead
        let mut b = Vec::new();
        forall(7, 5, |g| g.usize_in(0, 1000), |v| b.push(v));
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        forall(3, 10, |g| g.usize_in(0, 100), |v| {
            assert!(v < 5, "deliberate failure");
        });
    }

    #[test]
    fn tensor_gen_shapes() {
        forall(4, 20, |g| g.tensor(4, 5), |t| {
            assert_eq!(t.ndim(), 4);
            assert!(t.shape().iter().all(|&d| (1..=5).contains(&d)));
        });
    }
}
