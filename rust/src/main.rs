//! `qrr` — the command-line entry point.
//!
//! ```text
//! qrr exp <table1|table2|table3|fig1|overhead|all> [--iters N] […]
//! qrr train --config cfg.json [--out DIR]
//! qrr serve --addr 127.0.0.1:0 --model mlp --clients 3 --iters 5 [--shards N]
//! qrr serve --scale-clients 2000 --shards 4
//! qrr bench [kernels|round|all] [--fast] [--check] [--out DIR]
//! qrr audit [--check] [--list-rules]
//! qrr info
//! ```
//!
//! See `qrr help` for every option.

use anyhow::Result;

use qrr::cli::Args;

fn main() {
    qrr::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "exp" => qrr::experiments::run_cli(args),
        "train" => cmd_train(args),
        "serve" => qrr::experiments::serve::run_cli(args),
        "bench" => qrr::bench_util::suites::run_cli(args),
        "audit" => qrr::audit::run_cli(args),
        "schemes" => cmd_schemes(),
        "info" => cmd_info(),
        "" | "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            anyhow::bail!("unknown command {other:?}")
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let path = args
        .get("config")
        .ok_or_else(|| anyhow::anyhow!("train requires --config <file.json>"))?;
    let mut cfg = qrr::config::ExperimentConfig::from_file(path)?;
    qrr::experiments::apply_overrides(&mut cfg, args)?;
    let out_dir = args.get("out").unwrap_or("results");
    let mut session = qrr::fl::session::FlSessionBuilder::new(&cfg).build()?;
    let report = session.run()?;
    qrr::experiments::write_run_outputs(out_dir, &cfg.name, &report)?;
    println!("{}", report.markdown_table());
    Ok(())
}

/// `qrr schemes` — list the compression-pipeline registry: presets and
/// the stage grammar (smoke-tested in CI so the registry cannot drift).
fn cmd_schemes() -> Result<()> {
    use qrr::compress::pipeline;
    println!("presets (usable anywhere a pipeline spec is accepted):");
    for p in pipeline::presets() {
        println!("  {:<8} = {:<44} {}", p.name, p.spec, p.summary);
        // the registry must stay self-consistent: every listed preset and
        // its expansion parse back through the grammar
        pipeline::PipelineSpec::parse(p.name)?;
        pipeline::PipelineSpec::parse(&p.spec)?;
    }
    println!("\nstages (compose with '+', e.g. \"svd(p=0.1)+laq(beta=8)+ef\"):");
    for s in pipeline::stages() {
        println!("  {:<18} {}", s.signature, s.summary);
    }
    println!("\ncontroller policies (adaptive per-client compression, --controller SPEC):");
    for p in qrr::control::policies() {
        println!("  {:<10} = {:<48} {}", p.name, p.spec, p.summary);
        // same self-consistency contract as the pipeline presets
        qrr::control::ControllerConfig::parse(p.name)?;
        qrr::control::ControllerConfig::parse(&p.spec)?;
    }
    println!(
        "\nuplink:   --uplink SPEC   (per-experiment; overrides --schemes)\n\
         downlink: --downlink SPEC (dual-side; server broadcasts compressed deltas)\n\
         control:  --controller C  (re-plans uplinks per round from telemetry)\n\
         example:  qrr train --config cfg.json --downlink \"svd(p=0.1)+laq(beta=8)\""
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("qrr {} — Quantized Rank Reduction reproduction", env!("CARGO_PKG_VERSION"));
    println!("threads: {}", qrr::exec::default_threads());
    println!(
        "simd: {} (cpu: {})",
        qrr::exec::simd::level().label(),
        qrr::exec::simd::cpu_features()
    );
    println!("artifacts dir: {}", qrr::runtime::artifacts_dir().display());
    match qrr::runtime::Manifest::load(&qrr::runtime::artifacts_dir()) {
        Ok(m) => {
            println!("artifacts ({}):", m.entries.len());
            for e in &m.entries {
                println!("  {:<24} model={:<4} fn={:<6} batch={}", e.name, e.model, e.func, e.batch);
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(())
}

fn print_help() {
    println!(
        r#"qrr — Quantized Rank Reduction: communications-efficient FL (paper reproduction)

USAGE:
    qrr exp <id> [options]       regenerate a paper table/figure
                                 id: table1 | table2 | table3 | fig1 | overhead |
                                     controllers | all
                                 (controllers: adaptive-compression control-plane
                                 comparison over a spread-link cohort)
    qrr train --config <json>    run a single configured experiment
    qrr serve [options]          run the FL server+clients over real TCP
                                 --shards N routes uploads to N aggregation
                                 lanes (absorb-on-arrival, O(shards) memory);
                                 --scale-clients N runs the loopback scale
                                 smoke (N senders, asserts the memory bound);
                                 --streaming sends per-layer chunk frames
    qrr bench [suite] [options]  run the perf suites, write BENCH_*.json
                                 suite: kernels | round | all (default)
    qrr audit [--check]          static-analysis gate: SAFETY comments,
                                 no-alloc/no-panic fences, env hygiene
                                 (--list-rules prints the registry)
    qrr schemes                  list compression-pipeline presets + stages
    qrr info                     toolchain / artifact status

BENCH OPTIONS:
    --fast            reduced sampling (the CI smoke settings)
    --check           diff against the committed BENCH_*.json baseline
                      and fail on any case regressing past the threshold
    --threshold PCT   regression threshold in percent (default 25)
    --only SUBSTR     run only cases whose name contains SUBSTR; a
                      filtered run writes BENCH_*.partial.json and
                      never replaces the committed baseline
    --out DIR         where BENCH_*.json live — both the baseline read
                      by --check and the written output (default ".",
                      the repo root with its committed baselines)

COMMON OPTIONS (exp/train):
    --iters N         override iteration count (paper: 1000/2000)
    --clients N       override client count (paper: 10)
    --batch N         override batch size (paper: 512)
    --schemes LIST    comma list: sgd,slaq,qrr:0.3,qrr:0.2,qrr:0.1,qrr:adaptive
    --backend B       native | pjrt (default native; pjrt needs `make artifacts`)
    --train-n N       training samples (default 60000 / 50000)
    --test-n N        test samples (default 10000)
    --eval-every N    evaluation period (default 25)
    --seed N          RNG seed (default 42)
    --shards N        server-side aggregation shards (default min(clients, 8))
    --out DIR         output directory for CSV/markdown (default results/)
    --participation P who participates each round:
                      full | <fraction> | dropout:<fraction>:<drop_prob> | deadline:<secs>
    --aggregation A   sum (paper eq. (2)) | weighted_mean (FedAvg)
    --uplink SPEC     compression pipeline for every client's uplink
                      (preset or stage spec — see `qrr schemes`)
    --downlink SPEC   dual-side: broadcast compressed parameter deltas,
                      e.g. --downlink "svd(p=0.1)+laq(beta=8)"
    --controller C    adaptive compression control plane: re-plan each
                      client's uplink pipeline per round from observed
                      telemetry (overrides --schemes/--uplink), e.g.
                      --controller "aimd(target_ms=250)" — policies:
                      fixed | linkaware | aimd (see `qrr schemes`)
    --chaos SPEC      seeded fault-injection plan over the transport,
                      e.g. --chaos "drop=0.02,corrupt=0.01,down.drop=0.05"
                      (keys: drop|dup|corrupt|truncate|disconnect|delay,
                      up./down. prefixes, seed=N, rounds=LO..HI)
    --chaos-seed N    reseed the chaos plan (same plan + same seed ⇒
                      byte-identical fault schedule)
    --quorum Q        round quorum <fraction>[:<max_repolls>[:<backoff_ms>]],
                      e.g. --quorum 0.8:3:25 (default 1:2:50)
    --streaming       streamed rounds (DESIGN.md §13): ship each layer as
                      its own chunk frame with decode-on-arrival reassembly
                      and a double-buffered broadcast; bit-identical to the
                      sequential default on clean networks

ENVIRONMENT:
    QRR_THREADS       worker threads (default: cores, max 16; read once
                      per process — sizes the session pool and kernels)
    QRR_SIMD          kernel dispatch: scalar | avx2 (default: CPU
                      detection; read once per process — see `qrr info`)
    QRR_BENCH_FAST    reduced bench sampling (same as --fast)
    QRR_BENCH_ITERS   iterations for the table benches (default 40)
    QRR_BENCH_JSON    directory: cargo-bench binaries emit BENCH_*.json
    QRR_LOG           error|warn|info|debug|trace
    MNIST_DIR         real MNIST IDX files (else synthetic stream)
    CIFAR_DIR         real CIFAR-10 binaries (else synthetic stream)
    QRR_ARTIFACTS     artifacts directory (default ./artifacts)
"#
    );
}
