//! CIFAR-10 binary-format loader (used when `CIFAR_DIR` is set).
//!
//! Expects the standard `data_batch_{1..5}.bin` and `test_batch.bin`
//! (each record: 1 label byte + 3072 pixel bytes, CHW order).

use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

use super::Dataset;

const REC: usize = 1 + 3072;

/// Load (train, test) from a CIFAR-10 binary directory.
pub fn load_dir(dir: &str) -> Result<(Dataset, Dataset)> {
    let d = Path::new(dir);
    let mut train_parts = Vec::new();
    for i in 1..=5 {
        let p = d.join(format!("data_batch_{i}.bin"));
        if p.exists() {
            train_parts.push(read_batch(&p)?);
        }
    }
    if train_parts.is_empty() {
        bail!("no data_batch_*.bin found in {dir:?}");
    }
    let train = concat(train_parts);
    let test = read_batch(&d.join("test_batch.bin"))?;
    Ok((train, test))
}

/// Parse one batch file into a [`Dataset`].
pub fn read_batch(path: &Path) -> Result<Dataset> {
    let bytes = fs::read(path).with_context(|| format!("reading {path:?}"))?;
    if bytes.len() % REC != 0 {
        bail!("{path:?}: length {} not a multiple of {REC}", bytes.len());
    }
    let n = bytes.len() / REC;
    let mut x = Tensor::zeros(&[n, 3072]);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let rec = &bytes[i * REC..(i + 1) * REC];
        let label = rec[0];
        if label > 9 {
            bail!("{path:?}: record {i} has label {label} > 9");
        }
        y.push(label as u32);
        let row = &mut x.data_mut()[i * 3072..(i + 1) * 3072];
        for (dst, &b) in row.iter_mut().zip(rec[1..].iter()) {
            *dst = b as f32 / 255.0;
        }
    }
    Ok(Dataset { x, y, source: "cifar10".into() })
}

fn concat(parts: Vec<Dataset>) -> Dataset {
    let dim = parts[0].dim();
    let total: usize = parts.iter().map(|p| p.len()).sum();
    let mut x = Tensor::zeros(&[total, dim]);
    let mut y = Vec::with_capacity(total);
    let mut row = 0usize;
    for p in parts {
        let n = p.len();
        x.data_mut()[row * dim..(row + n) * dim].copy_from_slice(p.x.data());
        y.extend_from_slice(&p.y);
        row += n;
    }
    Dataset { x, y, source: "cifar10".into() }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_batch(path: &Path, labels: &[u8]) {
        let mut bytes = Vec::new();
        for (i, &l) in labels.iter().enumerate() {
            bytes.push(l);
            bytes.extend(std::iter::repeat_n((i * 10) as u8, 3072));
        }
        fs::write(path, bytes).unwrap();
    }

    #[test]
    fn roundtrip_tiny_batches() {
        let dir = std::env::temp_dir().join("qrr_cifar_test");
        fs::create_dir_all(&dir).unwrap();
        write_batch(&dir.join("data_batch_1.bin"), &[0, 1]);
        write_batch(&dir.join("data_batch_2.bin"), &[2]);
        write_batch(&dir.join("test_batch.bin"), &[9]);
        let (tr, te) = load_dir(dir.to_str().unwrap()).unwrap();
        assert_eq!(tr.len(), 3);
        assert_eq!(tr.y, vec![0, 1, 2]);
        assert_eq!(te.y, vec![9]);
        assert_eq!(tr.dim(), 3072);
        // second record's pixels are 10/255
        assert!((tr.x.data()[3072] - 10.0 / 255.0).abs() < 1e-6);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_length_rejected() {
        let dir = std::env::temp_dir().join("qrr_cifar_bad");
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("test_batch.bin");
        fs::write(&p, [0u8; 100]).unwrap();
        assert!(read_batch(&p).is_err());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_label_rejected() {
        let dir = std::env::temp_dir().join("qrr_cifar_bad2");
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("test_batch.bin");
        let mut bytes = vec![42u8]; // label 42 invalid
        bytes.extend(std::iter::repeat_n(0u8, 3072));
        fs::write(&p, bytes).unwrap();
        assert!(read_batch(&p).is_err());
        fs::remove_dir_all(&dir).ok();
    }
}
