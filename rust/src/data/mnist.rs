//! MNIST IDX-format loader (used when `MNIST_DIR` is set).
//!
//! Expects the standard four files (optionally without the `-idx?-ubyte`
//! suffix dots):
//! `train-images-idx3-ubyte`, `train-labels-idx1-ubyte`,
//! `t10k-images-idx3-ubyte`, `t10k-labels-idx1-ubyte`.

use std::fs;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

use super::Dataset;

/// Load (train, test) from a directory of IDX files.
pub fn load_dir(dir: &str) -> Result<(Dataset, Dataset)> {
    let d = Path::new(dir);
    let train = load_pair(
        &find(d, "train-images")?,
        &find(d, "train-labels")?,
    )?;
    let test = load_pair(&find(d, "t10k-images")?, &find(d, "t10k-labels")?)?;
    Ok((train, test))
}

fn find(dir: &Path, prefix: &str) -> Result<std::path::PathBuf> {
    for entry in fs::read_dir(dir).with_context(|| format!("reading {dir:?}"))? {
        let p = entry?.path();
        if let Some(name) = p.file_name().and_then(|n| n.to_str()) {
            if name.starts_with(prefix) && !name.ends_with(".gz") {
                return Ok(p);
            }
        }
    }
    bail!("no file starting with {prefix:?} in {dir:?}")
}

fn load_pair(images: &Path, labels: &Path) -> Result<Dataset> {
    let x = read_images(images)?;
    let y = read_labels(labels)?;
    if x.shape()[0] != y.len() {
        bail!(
            "image/label count mismatch: {} vs {}",
            x.shape()[0],
            y.len()
        );
    }
    Ok(Dataset { x, y, source: "mnist".into() })
}

fn read_u32be(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_be_bytes(b))
}

/// Parse an IDX3 image file into `[n, rows*cols]` with values in [0,1].
pub fn read_images(path: &Path) -> Result<Tensor> {
    let mut f = fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let magic = read_u32be(&mut f)?;
    if magic != 0x0000_0803 {
        bail!("bad IDX3 magic {magic:#x} in {path:?}");
    }
    let n = read_u32be(&mut f)? as usize;
    let rows = read_u32be(&mut f)? as usize;
    let cols = read_u32be(&mut f)? as usize;
    let mut buf = vec![0u8; n * rows * cols];
    f.read_exact(&mut buf)?;
    let data: Vec<f32> = buf.iter().map(|&b| b as f32 / 255.0).collect();
    Ok(Tensor::from_vec(&[n, rows * cols], data))
}

/// Parse an IDX1 label file.
pub fn read_labels(path: &Path) -> Result<Vec<u32>> {
    let mut f = fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let magic = read_u32be(&mut f)?;
    if magic != 0x0000_0801 {
        bail!("bad IDX1 magic {magic:#x} in {path:?}");
    }
    let n = read_u32be(&mut f)? as usize;
    let mut buf = vec![0u8; n];
    f.read_exact(&mut buf)?;
    Ok(buf.into_iter().map(|b| b as u32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_idx3(path: &Path, n: usize, rows: usize, cols: usize, pix: &[u8]) {
        let mut f = fs::File::create(path).unwrap();
        f.write_all(&0x0000_0803u32.to_be_bytes()).unwrap();
        f.write_all(&(n as u32).to_be_bytes()).unwrap();
        f.write_all(&(rows as u32).to_be_bytes()).unwrap();
        f.write_all(&(cols as u32).to_be_bytes()).unwrap();
        f.write_all(pix).unwrap();
    }

    fn write_idx1(path: &Path, labels: &[u8]) {
        let mut f = fs::File::create(path).unwrap();
        f.write_all(&0x0000_0801u32.to_be_bytes()).unwrap();
        f.write_all(&(labels.len() as u32).to_be_bytes()).unwrap();
        f.write_all(labels).unwrap();
    }

    #[test]
    fn roundtrip_tiny_idx() {
        let dir = std::env::temp_dir().join("qrr_mnist_test");
        fs::create_dir_all(&dir).unwrap();
        let pix: Vec<u8> = (0..2 * 4).map(|v| (v * 30) as u8).collect();
        write_idx3(&dir.join("train-images-idx3-ubyte"), 2, 2, 2, &pix);
        write_idx1(&dir.join("train-labels-idx1-ubyte"), &[3, 7]);
        write_idx3(&dir.join("t10k-images-idx3-ubyte"), 2, 2, 2, &pix);
        write_idx1(&dir.join("t10k-labels-idx1-ubyte"), &[1, 2]);
        let (tr, te) = load_dir(dir.to_str().unwrap()).unwrap();
        assert_eq!(tr.len(), 2);
        assert_eq!(tr.dim(), 4);
        assert_eq!(tr.y, vec![3, 7]);
        assert_eq!(te.y, vec![1, 2]);
        assert!((tr.x.data()[1] - 30.0 / 255.0).abs() < 1e-6);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("qrr_mnist_bad");
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("train-images-idx3-ubyte");
        fs::write(&p, [0u8; 16]).unwrap();
        assert!(read_images(&p).is_err());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_errors() {
        assert!(load_dir("/nonexistent/definitely/missing").is_err());
    }
}
