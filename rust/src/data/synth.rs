//! Deterministic synthetic stand-ins for MNIST and CIFAR-10.
//!
//! Substitution rationale (DESIGN.md §4): the experiments compare
//! SGD/SLAQ/QRR *relative to each other* on the same stream; what matters
//! is that the task is a learnable 10-class image problem producing
//! gradients with the low-rank structure the paper exploits. Class
//! structure is created by smooth per-class prototype images; samples are
//! prototypes plus localized elastic noise, clipped to [0, 1] like
//! normalized pixels.
//!
//! Generation is fully deterministic in the seed, so every client and
//! every scheme sees byte-identical data across runs and backends.

use crate::tensor::Tensor;
use crate::util::Rng;

use super::Dataset;

/// Number of classes in both streams.
pub const NUM_CLASSES: usize = 10;

/// 28×28 grayscale, MNIST geometry: `dim = 784`, values in [0, 1].
pub fn mnist_like(n: usize, seed: u64) -> Dataset {
    mnist_like_split(n, seed, 0)
}

/// MNIST-geometry stream where `family_seed` fixes the class prototypes
/// and `split` (0 = train, 1 = test, …) draws disjoint sample noise from
/// the SAME class distribution — train and test must share prototypes or
/// the task is unlearnable.
pub fn mnist_like_split(n: usize, family_seed: u64, split: u64) -> Dataset {
    image_stream(n, family_seed, split, 1, 28, "synth-mnist")
}

/// 32×32 RGB, CIFAR-10 geometry: `dim = 3072`, values in [0, 1].
pub fn cifar_like(n: usize, seed: u64) -> Dataset {
    cifar_like_split(n, seed, 0)
}

/// CIFAR-geometry analogue of [`mnist_like_split`].
pub fn cifar_like_split(n: usize, family_seed: u64, split: u64) -> Dataset {
    image_stream(n, family_seed, split, 3, 32, "synth-cifar10")
}

/// Pick the stream matching a model's flat input dimension (784 → MNIST
/// geometry, 3072 → CIFAR geometry).
pub fn stream_for_input(n: usize, seed: u64, input_dim: usize) -> Dataset {
    match input_dim {
        784 => mnist_like(n, seed),
        3072 => cifar_like(n, seed),
        other => panic!("no synthetic stream with input dim {other}"),
    }
}

/// (train, test) pair sharing class prototypes.
pub fn mnist_like_pair(train_n: usize, test_n: usize, seed: u64) -> (Dataset, Dataset) {
    (mnist_like_split(train_n, seed, 0), mnist_like_split(test_n, seed, 1))
}

/// (train, test) pair sharing class prototypes.
pub fn cifar_like_pair(train_n: usize, test_n: usize, seed: u64) -> (Dataset, Dataset) {
    (cifar_like_split(train_n, seed, 0), cifar_like_split(test_n, seed, 1))
}

/// Shared generator: smooth class prototypes (from `family_seed`) +
/// per-sample jitter (from `family_seed` + `split`).
fn image_stream(
    n: usize,
    family_seed: u64,
    split: u64,
    chans: usize,
    side: usize,
    source: &str,
) -> Dataset {
    let dim = chans * side * side;
    let mut proto_rng = Rng::new(family_seed ^ 0x50_50_50); // prototypes per family
    let protos: Vec<Vec<f32>> = (0..NUM_CLASSES)
        .map(|_| smooth_image(chans, side, &mut proto_rng))
        .collect();

    let mut rng = Rng::new(family_seed.wrapping_add(split.wrapping_mul(0x9E37_79B9_7F4A_7C15)));
    let mut x = Tensor::zeros(&[n, dim]);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let label = rng.below(NUM_CLASSES);
        y.push(label as u32);
        let row = &mut x.data_mut()[i * dim..(i + 1) * dim];
        row.copy_from_slice(&protos[label]);
        // localized elastic noise: smooth bumps large enough that classes
        // overlap (keeps the task from saturating at 100% accuracy, so
        // the paper's accuracy deltas remain visible)
        let bumps = 6 + rng.below(6);
        for _ in 0..bumps {
            let cy = rng.below(side) as f32;
            let cx = rng.below(side) as f32;
            let amp = rng.normal() * 0.55;
            let sig = 1.5 + 3.0 * rng.f32();
            let inv = 1.0 / (2.0 * sig * sig);
            for c in 0..chans {
                for yy in 0..side {
                    for xx in 0..side {
                        let d2 = (yy as f32 - cy).powi(2) + (xx as f32 - cx).powi(2);
                        row[c * side * side + yy * side + xx] += amp * (-d2 * inv).exp();
                    }
                }
            }
        }
        // pixel noise + clip
        for v in row.iter_mut() {
            *v = (*v + 0.15 * rng.normal()).clamp(0.0, 1.0);
        }
    }
    Dataset { x, y, source: source.to_string() }
}

/// Smooth random image in [0,1]: sum of random Gaussian blobs per channel.
fn smooth_image(chans: usize, side: usize, rng: &mut Rng) -> Vec<f32> {
    let mut img = vec![0f32; chans * side * side];
    for c in 0..chans {
        let blobs = 6 + rng.below(5);
        for _ in 0..blobs {
            let cy = rng.below(side) as f32;
            let cx = rng.below(side) as f32;
            let amp = 0.4 + 0.6 * rng.f32();
            let sig = 2.0 + 4.0 * rng.f32();
            let inv = 1.0 / (2.0 * sig * sig);
            for yy in 0..side {
                for xx in 0..side {
                    let d2 = (yy as f32 - cy).powi(2) + (xx as f32 - cx).powi(2);
                    img[c * side * side + yy * side + xx] += amp * (-d2 * inv).exp();
                }
            }
        }
    }
    // normalize to [0,1]
    let maxv = img.iter().fold(0f32, |a, &v| a.max(v)).max(1e-6);
    for v in img.iter_mut() {
        *v = (*v / maxv).clamp(0.0, 1.0);
    }
    img
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_matches_datasets() {
        let m = mnist_like(10, 1);
        assert_eq!(m.dim(), 784);
        assert_eq!(m.len(), 10);
        let c = cifar_like(5, 1);
        assert_eq!(c.dim(), 3072);
    }

    #[test]
    fn values_in_unit_range() {
        let m = mnist_like(50, 2);
        for &v in m.x.data() {
            assert!((0.0..=1.0).contains(&v));
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = mnist_like(20, 3);
        let b = mnist_like(20, 3);
        assert_eq!(a.x.data(), b.x.data());
        assert_eq!(a.y, b.y);
        let c = mnist_like(20, 4);
        assert_ne!(a.x.data(), c.x.data());
    }

    #[test]
    fn all_classes_present() {
        let m = mnist_like(500, 5);
        let mut seen = [false; NUM_CLASSES];
        for &l in &m.y {
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "labels {:?}", seen);
    }

    #[test]
    fn classes_are_separable_by_a_linear_probe() {
        // the stream must be learnable: nearest-prototype classification
        // on the *training* prototypes should beat chance by a wide margin
        let m = mnist_like(400, 6);
        // recover per-class means as prototype estimates
        let dim = m.dim();
        let mut means = vec![vec![0f32; dim]; NUM_CLASSES];
        let mut counts = vec![0usize; NUM_CLASSES];
        for i in 0..m.len() {
            let l = m.y[i] as usize;
            counts[l] += 1;
            for j in 0..dim {
                means[l][j] += m.x.data()[i * dim + j];
            }
        }
        for (mu, &c) in means.iter_mut().zip(counts.iter()) {
            for v in mu.iter_mut() {
                *v /= c.max(1) as f32;
            }
        }
        // classify fresh samples (same prototype family, disjoint split)
        let test = mnist_like_split(200, 6, 1);
        let mut correct = 0;
        for i in 0..test.len() {
            let row = &test.x.data()[i * dim..(i + 1) * dim];
            let mut best = (f32::MAX, 0usize);
            for (l, mu) in means.iter().enumerate() {
                let d: f32 = row.iter().zip(mu.iter()).map(|(a, b)| (a - b) * (a - b)).sum();
                if d < best.0 {
                    best = (d, l);
                }
            }
            if best.1 == test.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f32 / test.len() as f32;
        assert!(acc > 0.5, "nearest-prototype accuracy only {acc}");
    }
}
