//! Datasets: real MNIST/CIFAR-10 loaders plus deterministic synthetic
//! generators, IID sharding across clients and batch sampling.
//!
//! The build environment has no network access, so by default the
//! experiments run on the synthetic generators in [`synth`] — 10-class,
//! image-shaped streams that exercise the identical code paths (see
//! DESIGN.md §4). When `MNIST_DIR` / `CIFAR_DIR` point at the real files
//! the loaders in [`mnist`] and [`cifar`] are used instead.

pub mod cifar;
pub mod mnist;
pub mod synth;

use crate::tensor::Tensor;
use crate::util::Rng;

/// An in-memory labelled dataset (features flattened per sample).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// `[num_samples, feature_dim]`
    pub x: Tensor,
    /// one label per sample
    pub y: Vec<u32>,
    /// human-readable origin ("mnist", "synth-mnist", …)
    pub source: String,
}

impl Dataset {
    /// Number of samples.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Feature dimension per sample.
    pub fn dim(&self) -> usize {
        self.x.shape()[1]
    }

    /// Gather a subset by index.
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let dim = self.dim();
        let mut x = Tensor::zeros(&[idx.len(), dim]);
        let mut y = Vec::with_capacity(idx.len());
        for (r, &i) in idx.iter().enumerate() {
            assert!(i < self.len(), "index {i} out of range");
            x.data_mut()[r * dim..(r + 1) * dim]
                .copy_from_slice(&self.x.data()[i * dim..(i + 1) * dim]);
            y.push(self.y[i]);
        }
        Dataset { x, y, source: self.source.clone() }
    }

    /// Split into `n` equally sized IID shards (paper: 60k samples
    /// "randomly selected and equally distributed among the 10 clients").
    /// Deterministic in `seed`; drops the remainder like the paper's
    /// equal split.
    pub fn shard_iid(&self, n: usize, seed: u64) -> Vec<Dataset> {
        assert!(n > 0);
        let mut idx: Vec<usize> = (0..self.len()).collect();
        let mut rng = Rng::new(seed);
        rng.shuffle(&mut idx);
        let per = self.len() / n;
        (0..n)
            .map(|c| self.subset(&idx[c * per..(c + 1) * per]))
            .collect()
    }

    /// Label-skewed (non-IID) sharding: samples are sorted by label and
    /// dealt in contiguous runs so each client sees few classes — the
    /// pathological-heterogeneity regime of McMahan et al. Deterministic
    /// in `seed` (shard order shuffled).
    pub fn shard_label_skew(&self, n: usize, shards_per_client: usize, seed: u64) -> Vec<Dataset> {
        assert!(n > 0 && shards_per_client > 0);
        let mut idx: Vec<usize> = (0..self.len()).collect();
        idx.sort_by_key(|&i| self.y[i]);
        let total_shards = n * shards_per_client;
        let per = self.len() / total_shards;
        assert!(per > 0, "not enough samples for {total_shards} shards");
        let mut shard_ids: Vec<usize> = (0..total_shards).collect();
        let mut rng = Rng::new(seed);
        rng.shuffle(&mut shard_ids);
        (0..n)
            .map(|c| {
                let mut take = Vec::new();
                for s in 0..shards_per_client {
                    let sid = shard_ids[c * shards_per_client + s];
                    take.extend_from_slice(&idx[sid * per..(sid + 1) * per]);
                }
                self.subset(&take)
            })
            .collect()
    }

    /// Dirichlet(α) non-IID sharding: each class's samples are split
    /// across clients with Dirichlet-distributed proportions. Small α →
    /// heavy skew; α → ∞ approaches IID.
    pub fn shard_dirichlet(&self, n: usize, alpha: f64, seed: u64) -> Vec<Dataset> {
        assert!(n > 0 && alpha > 0.0);
        let mut rng = Rng::new(seed);
        let num_classes = self.y.iter().copied().max().map(|m| m as usize + 1).unwrap_or(1);
        let mut per_client: Vec<Vec<usize>> = vec![Vec::new(); n];
        for cls in 0..num_classes {
            let mut members: Vec<usize> =
                (0..self.len()).filter(|&i| self.y[i] as usize == cls).collect();
            rng.shuffle(&mut members);
            // Dirichlet via normalized Gamma(alpha, 1) draws
            // (Marsaglia-Tsang would be overkill: alpha is O(1), use the
            // sum-of-exponentials approximation for alpha>=1 and
            // Johnk-style for alpha<1 via powers of uniforms)
            let mut w: Vec<f64> = (0..n).map(|_| gamma_draw(alpha, &mut rng)).collect();
            let total: f64 = w.iter().sum::<f64>().max(1e-12);
            for v in w.iter_mut() {
                *v /= total;
            }
            let mut start = 0usize;
            for (c, &frac) in w.iter().enumerate() {
                let take = if c + 1 == n {
                    members.len() - start
                } else {
                    ((frac * members.len() as f64).round() as usize)
                        .min(members.len() - start)
                };
                per_client[c].extend_from_slice(&members[start..start + take]);
                start += take;
            }
        }
        per_client.into_iter().map(|idx| self.subset(&idx)).collect()
    }

    /// Sample a batch of `bsz` rows (with replacement across batches,
    /// without within one batch) — a stochastic mini-batch per FL round.
    pub fn sample_batch(&self, bsz: usize, rng: &mut Rng) -> (Tensor, Vec<u32>) {
        let bsz = bsz.min(self.len());
        let idx = rng.sample_indices(self.len(), bsz);
        let sub = self.subset(&idx);
        (sub.x, sub.y)
    }

    /// Iterate fixed-size evaluation chunks (last partial chunk kept).
    pub fn chunks(&self, size: usize) -> impl Iterator<Item = (Tensor, Vec<u32>)> + '_ {
        let n = self.len();
        let size = size.max(1);
        (0..n.div_ceil(size)).map(move |c| {
            let lo = c * size;
            let hi = ((c + 1) * size).min(n);
            let idx: Vec<usize> = (lo..hi).collect();
            let sub = self.subset(&idx);
            (sub.x, sub.y)
        })
    }
}

/// Which benchmark stream an experiment runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    /// 28×28×1 digits (experiments 1–2).
    Mnist,
    /// 32×32×3 natural images (experiment 3).
    Cifar10,
}

impl DatasetKind {
    /// Parse from CLI/config name.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "mnist" => Some(DatasetKind::Mnist),
            "cifar" | "cifar10" | "cifar-10" => Some(DatasetKind::Cifar10),
            _ => None,
        }
    }
}

/// Load train+test splits: real files when the corresponding env var
/// (`MNIST_DIR` / `CIFAR_DIR`) is set, the synthetic generator otherwise.
pub fn load(kind: DatasetKind, train_n: usize, test_n: usize, seed: u64) -> (Dataset, Dataset) {
    match kind {
        DatasetKind::Mnist => {
            if let Some(dir) = crate::util::env::mnist_dir() {
                match mnist::load_dir(&dir) {
                    Ok((tr, te)) => return (tr, te),
                    Err(e) => log::warn!("MNIST_DIR set but load failed ({e}); using synthetic"),
                }
            }
            synth::mnist_like_pair(train_n, test_n, seed)
        }
        DatasetKind::Cifar10 => {
            if let Some(dir) = crate::util::env::cifar_dir() {
                match cifar::load_dir(&dir) {
                    Ok((tr, te)) => return (tr, te),
                    Err(e) => log::warn!("CIFAR_DIR set but load failed ({e}); using synthetic"),
                }
            }
            synth::cifar_like_pair(train_n, test_n, seed)
        }
    }
}

/// Crude Gamma(alpha, 1) sampler adequate for Dirichlet splitting:
/// for alpha >= 1 use the Marsaglia–Tsang squeeze; for alpha < 1 boost
/// via Gamma(alpha+1) * U^(1/alpha).
fn gamma_draw(alpha: f64, rng: &mut Rng) -> f64 {
    if alpha < 1.0 {
        let u = rng.f64().max(1e-12);
        return gamma_draw(alpha + 1.0, rng) * u.powf(1.0 / alpha);
    }
    let d = alpha - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = rng.normal() as f64;
        let v = (1.0 + c * x).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u = rng.f64().max(1e-12);
        if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
            return d * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let x = Tensor::from_vec(&[6, 2], (0..12).map(|v| v as f32).collect());
        Dataset { x, y: vec![0, 1, 2, 0, 1, 2], source: "test".into() }
    }

    #[test]
    fn subset_gathers_rows() {
        let d = tiny();
        let s = d.subset(&[2, 0]);
        assert_eq!(s.len(), 2);
        assert_eq!(s.x.data(), &[4., 5., 0., 1.]);
        assert_eq!(s.y, vec![2, 0]);
    }

    #[test]
    fn shard_iid_partitions_evenly() {
        let d = tiny();
        let shards = d.shard_iid(3, 42);
        assert_eq!(shards.len(), 3);
        for s in &shards {
            assert_eq!(s.len(), 2);
        }
        // shards are disjoint: collect all (x0) values, must be 6 distinct
        let mut firsts: Vec<i64> = shards
            .iter()
            .flat_map(|s| (0..s.len()).map(|r| s.x.data()[r * 2] as i64).collect::<Vec<_>>())
            .collect();
        firsts.sort_unstable();
        firsts.dedup();
        assert_eq!(firsts.len(), 6);
    }

    #[test]
    fn shard_deterministic_in_seed() {
        let d = tiny();
        let a = d.shard_iid(2, 7);
        let b = d.shard_iid(2, 7);
        assert_eq!(a[0].y, b[0].y);
        assert_eq!(a[0].x.data(), b[0].x.data());
    }

    #[test]
    fn sample_batch_has_no_duplicates() {
        let d = tiny();
        let mut rng = Rng::new(1);
        let (x, y) = d.sample_batch(6, &mut rng);
        assert_eq!(y.len(), 6);
        let mut rows: Vec<i64> = (0..6).map(|r| x.data()[r * 2] as i64).collect();
        rows.sort_unstable();
        rows.dedup();
        assert_eq!(rows.len(), 6);
    }

    #[test]
    fn chunks_cover_everything() {
        let d = tiny();
        let total: usize = d.chunks(4).map(|(_, y)| y.len()).sum();
        assert_eq!(total, 6);
        let sizes: Vec<usize> = d.chunks(4).map(|(_, y)| y.len()).collect();
        assert_eq!(sizes, vec![4, 2]);
    }

    #[test]
    fn label_skew_concentrates_classes() {
        let d = synth::mnist_like(600, 9);
        let shards = d.shard_label_skew(3, 2, 1);
        assert_eq!(shards.len(), 3);
        for sh in &shards {
            let mut classes: Vec<u32> = sh.y.clone();
            classes.sort_unstable();
            classes.dedup();
            // 2 contiguous label shards -> far fewer than all 10 classes
            assert!(classes.len() <= 6, "shard saw {} classes", classes.len());
            assert!(!sh.is_empty());
        }
    }

    #[test]
    fn dirichlet_partitions_everything_once() {
        let d = synth::mnist_like(500, 10);
        let shards = d.shard_dirichlet(4, 0.5, 2);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, d.len());
        // skewed: client class histograms differ substantially
        let hist = |sh: &Dataset| {
            let mut h = [0usize; 10];
            for &l in &sh.y {
                h[l as usize] += 1;
            }
            h
        };
        let h0 = hist(&shards[0]);
        let h1 = hist(&shards[1]);
        let diff: usize = h0.iter().zip(h1.iter()).map(|(a, b)| a.abs_diff(*b)).sum();
        assert!(diff > 20, "dirichlet split looks IID: {h0:?} vs {h1:?}");
    }

    #[test]
    fn dirichlet_large_alpha_approaches_iid() {
        let d = synth::mnist_like(1000, 11);
        let shards = d.shard_dirichlet(4, 1000.0, 3);
        for sh in &shards {
            // every class present with alpha huge
            let mut seen = [false; 10];
            for &l in &sh.y {
                seen[l as usize] = true;
            }
            assert!(seen.iter().filter(|&&s| s).count() >= 9);
        }
    }

    #[test]
    fn load_synth_when_no_env() {
        std::env::remove_var("MNIST_DIR");
        let (tr, te) = load(DatasetKind::Mnist, 100, 50, 3);
        assert_eq!(tr.len(), 100);
        assert_eq!(te.len(), 50);
        assert_eq!(tr.dim(), 784);
    }
}
