//! Deterministic pseudo-random number generation.
//!
//! `xoshiro256**` seeded through `splitmix64` — the standard pairing
//! recommended by the xoshiro authors. Every stochastic component in the
//! crate (data synthesis, batch sampling, weight init, property tests)
//! derives its stream from an explicit seed so experiments are exactly
//! reproducible run-to-run.

/// splitmix64 step; used for seeding and as a cheap standalone generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator. Not cryptographic; fast, 256-bit state,
/// equidistributed in 4 dimensions — more than enough for simulation.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (e.g. one per client).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Next u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n). Unbiased via rejection (Lemire).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mulhilo(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (cached second value not kept:
    /// simplicity beats the 2x constant here).
    pub fn normal(&mut self) -> f32 {
        let u1 = (1.0 - self.f64()) as f64; // avoid log(0)
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Normal with given mean / std.
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Fill a slice with iid standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Fill a slice with uniforms in [lo, hi).
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.range_f32(lo, hi);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[inline]
fn mulhilo(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_smoke() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            // expected 10k, allow +-6%
            assert!((9_400..10_600).contains(&c), "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let mut sum = 0f64;
        let mut sq = 0f64;
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
