//! Small shared utilities: deterministic PRNGs, timers, logging and
//! human-readable formatting.
//!
//! The build environment has no network access, so widely used crates
//! (`rand`, `env_logger`, …) are replaced by the minimal, well-tested
//! implementations in this module (see DESIGN.md §4).

pub mod env;
pub mod fmt;
pub mod logging;
pub mod rng;
pub mod timer;

pub use rng::Rng;
pub use timer::{PhaseTimes, Timer};
