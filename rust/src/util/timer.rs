//! Wall-clock timing helpers used by metrics and the bench harness.

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start a new timer.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed time since start.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds as f64.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds as f64.
    pub fn millis(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    /// Reset the start point, returning the previous elapsed duration.
    pub fn lap(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Accumulates per-phase durations (e.g. grad / compress / quantize /
/// transmit) across many rounds; used for the overhead experiment.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimes {
    entries: Vec<(String, Duration, u64)>,
}

impl PhaseTimes {
    /// New, empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `d` to phase `name`.
    pub fn add(&mut self, name: &str, d: Duration) {
        if let Some(e) = self.entries.iter_mut().find(|e| e.0 == name) {
            e.1 += d;
            e.2 += 1;
        } else {
            self.entries.push((name.to_string(), d, 1));
        }
    }

    /// Time a closure and record it under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Timer::start();
        let out = f();
        self.add(name, t.elapsed());
        out
    }

    /// Total across all phases.
    pub fn total(&self) -> Duration {
        self.entries.iter().map(|e| e.1).sum()
    }

    /// Duration of one phase (zero if absent).
    pub fn get(&self, name: &str) -> Duration {
        self.entries
            .iter()
            .find(|e| e.0 == name)
            .map(|e| e.1)
            .unwrap_or_default()
    }

    /// Merge another accumulator into this one.
    pub fn merge(&mut self, other: &PhaseTimes) {
        for (n, d, c) in &other.entries {
            if let Some(e) = self.entries.iter_mut().find(|e| &e.0 == n) {
                e.1 += *d;
                e.2 += *c;
            } else {
                self.entries.push((n.clone(), *d, *c));
            }
        }
    }

    /// (name, total, count) rows in insertion order.
    pub fn rows(&self) -> &[(String, Duration, u64)] {
        &self.entries
    }

    /// Render a small aligned table.
    pub fn table(&self) -> String {
        let mut s = String::new();
        let total = self.total().as_secs_f64().max(1e-12);
        for (n, d, c) in &self.entries {
            let secs = d.as_secs_f64();
            s.push_str(&format!(
                "{:<14} {:>10.3} ms  {:>6.2}%  x{}\n",
                n,
                secs * 1e3,
                100.0 * secs / total,
                c
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.millis() >= 1.0);
    }

    #[test]
    fn phases_accumulate() {
        let mut p = PhaseTimes::new();
        p.add("a", Duration::from_millis(5));
        p.add("a", Duration::from_millis(5));
        p.add("b", Duration::from_millis(10));
        assert_eq!(p.get("a"), Duration::from_millis(10));
        assert_eq!(p.total(), Duration::from_millis(20));
        assert_eq!(p.rows().len(), 2);
    }

    #[test]
    fn phases_merge() {
        let mut p = PhaseTimes::new();
        p.add("a", Duration::from_millis(1));
        let mut q = PhaseTimes::new();
        q.add("a", Duration::from_millis(2));
        q.add("c", Duration::from_millis(3));
        p.merge(&q);
        assert_eq!(p.get("a"), Duration::from_millis(3));
        assert_eq!(p.get("c"), Duration::from_millis(3));
    }

    #[test]
    fn time_closure_returns_value() {
        let mut p = PhaseTimes::new();
        let v = p.time("work", || 42);
        assert_eq!(v, 42);
        assert_eq!(p.rows().len(), 1);
    }
}
