//! Minimal `log`-crate backend (env_logger is unavailable offline).
//!
//! Level is taken from `QRR_LOG` (`error|warn|info|debug|trace`),
//! defaulting to `info`. Output goes to stderr with elapsed-time stamps.

use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let t = self.start.elapsed().as_secs_f64();
            let lvl = match record.level() {
                Level::Error => "ERROR",
                Level::Warn => "WARN ",
                Level::Info => "INFO ",
                Level::Debug => "DEBUG",
                Level::Trace => "TRACE",
            };
            eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Install the stderr logger (idempotent).
pub fn init() {
    let logger = LOGGER.get_or_init(|| StderrLogger { start: Instant::now() });
    let level = match std::env::var("QRR_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    // set_logger fails if called twice; that's fine.
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::debug!("logging smoke");
    }
}
