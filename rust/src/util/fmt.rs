//! Human-readable formatting for bit counts, byte counts and scientific
//! notation matching the paper's tables (e.g. `5.088e10 bits`).

/// Format a bit count like the paper's tables: `5.088 x 10^10`.
pub fn bits_sci(bits: u64) -> String {
    if bits == 0 {
        return "0".to_string();
    }
    let b = bits as f64;
    let exp = b.log10().floor() as i32;
    let mant = b / 10f64.powi(exp);
    format!("{mant:.3}e{exp}")
}

/// Format bytes with binary suffixes.
pub fn bytes_human(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a count with thousands separators.
pub fn count_sep(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

/// Percentage string with 2 decimals.
pub fn pct(x: f64) -> String {
    format!("{:.2}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sci_matches_paper_style() {
        assert_eq!(bits_sci(50_880_000_000), "5.088e10");
        assert_eq!(bits_sci(0), "0");
        assert_eq!(bits_sci(1), "1.000e0");
        assert_eq!(bits_sci(999), "9.990e2");
    }

    #[test]
    fn bytes_units() {
        assert_eq!(bytes_human(512), "512 B");
        assert_eq!(bytes_human(2048), "2.00 KiB");
        assert_eq!(bytes_human(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn separators() {
        assert_eq!(count_sep(1_234_567), "1,234,567");
        assert_eq!(count_sep(12), "12");
        assert_eq!(count_sep(0), "0");
    }

    #[test]
    fn pct_format() {
        assert_eq!(pct(0.8992), "89.92%");
    }
}
