//! The sanctioned seam for environment configuration.
//!
//! `qrr-audit`'s **env-once** rule (DESIGN.md §9) forbids
//! `std::env::var` everywhere except the read-once dispatch seams
//! (`exec`, `exec::simd`, `util::logging`) and this module. Every other
//! module takes its knobs from the accessors here, which come in two
//! classes:
//!
//! * **cached** — process-invariant configuration: read once through a
//!   `OnceLock`, so every call site sees one consistent value and the
//!   hot path never pays an env lookup (the same contract as
//!   `QRR_THREADS`/`QRR_SIMD`, DESIGN.md §4/§8);
//! * **dynamic** — knobs that tests legitimately flip at runtime
//!   (`QRR_BENCH_FAST`, `MNIST_DIR`/`CIFAR_DIR`): re-read per call, by
//!   design — caching them would make `std::env::set_var` in a test a
//!   silent no-op. None of these sits on a hot path.

use std::path::PathBuf;
use std::sync::OnceLock;

// ------------------------------------------------------------- cached

/// Artifacts directory: `QRR_ARTIFACTS` or `./artifacts` (cached).
pub fn artifacts_dir() -> PathBuf {
    static CACHED: OnceLock<PathBuf> = OnceLock::new();
    CACHED
        .get_or_init(|| {
            std::env::var("QRR_ARTIFACTS")
                .map(PathBuf::from)
                .unwrap_or_else(|_| PathBuf::from("artifacts"))
        })
        .clone()
}

/// `QRR_SLAQ_SCALE` — the SLAQ skip-threshold calibration constant
/// (cached; `None` when unset or unparsable).
pub fn slaq_scale() -> Option<f64> {
    static CACHED: OnceLock<Option<f64>> = OnceLock::new();
    *CACHED.get_or_init(|| std::env::var("QRR_SLAQ_SCALE").ok().and_then(|v| v.parse().ok()))
}

/// `QRR_BENCH_ITERS` — iteration count for the reduced table benches
/// (cached; `None` when unset or unparsable).
pub fn bench_iters() -> Option<u64> {
    static CACHED: OnceLock<Option<u64>> = OnceLock::new();
    *CACHED.get_or_init(|| std::env::var("QRR_BENCH_ITERS").ok().and_then(|v| v.parse().ok()))
}

/// `QRR_BENCH_JSON` — directory the `cargo bench` binaries write their
/// `BENCH_*.json` trail into (cached; `None` = don't write).
pub fn bench_json_dir() -> Option<String> {
    static CACHED: OnceLock<Option<String>> = OnceLock::new();
    CACHED.get_or_init(|| std::env::var("QRR_BENCH_JSON").ok()).clone()
}

// ------------------------------------------------------------ dynamic

/// `QRR_BENCH_FAST` — reduced bench sampling. Dynamic: the overhead
/// experiment's tests set it mid-process to keep CI runs short.
pub fn bench_fast() -> bool {
    std::env::var("QRR_BENCH_FAST").is_ok()
}

/// `MNIST_DIR` — directory of real MNIST IDX files. Dynamic: the data
/// tests unset it to force the synthetic path.
pub fn mnist_dir() -> Option<String> {
    std::env::var("MNIST_DIR").ok()
}

/// `CIFAR_DIR` — directory of real CIFAR-10 binaries. Dynamic,
/// mirroring [`mnist_dir`].
pub fn cifar_dir() -> Option<String> {
    std::env::var("CIFAR_DIR").ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cached_accessors_are_stable() {
        // cached values must not change across calls even if the
        // environment does (the read-once contract)
        assert_eq!(artifacts_dir(), artifacts_dir());
        assert_eq!(slaq_scale(), slaq_scale());
        assert_eq!(bench_iters(), bench_iters());
        assert_eq!(bench_json_dir(), bench_json_dir());
    }

    #[test]
    fn artifacts_dir_has_a_default() {
        assert!(!artifacts_dir().as_os_str().is_empty());
    }
}
