//! The Layer-3 coordinator: assembles data shards, clients, schemes and
//! the server from an [`ExperimentConfig`] and drives the synchronous FL
//! round loop with parallel client execution.
//!
//! Responsibilities (DESIGN.md §1):
//! * IID sharding of the training stream across clients (paper setup),
//! * per-client link models and — for experiment 3 — the adaptive
//!   assignment of the compression fraction `p` from link speed,
//! * the round loop: broadcast → parallel client steps → wire decode →
//!   aggregate → descent step → metrics,
//! * periodic test-set evaluation (loss/accuracy columns and the
//!   vs-bits figure series),
//! * learning-rate schedule (experiment 3 decays α at iteration 1000).

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::config::{Backend, ExperimentConfig};
use crate::data::{self, Dataset};
use crate::fl::{
    make_client_scheme, make_server_scheme, EvalPoint, FlClient, FlServer, History, RoundMetrics,
};
use crate::model::{native::NativeModel, ModelOps, ModelSpec};
use crate::net::LinkModel;
use crate::util::{PhaseTimes, Rng};

/// Outcome of a coordinator run.
pub struct RunReport {
    /// metric history (table row + figure series)
    pub history: History,
    /// total client-side scheme memory, bytes
    pub client_mem_bytes: usize,
    /// total server-side scheme memory, bytes
    pub server_mem_bytes: usize,
    /// accumulated per-phase client compute time
    pub phases: PhaseTimes,
}

impl RunReport {
    /// The paper-style single-row markdown table for this run.
    pub fn markdown_table(&self) -> String {
        crate::fl::metrics::markdown_table(&[self.history.table_row()])
    }
}

/// The round-loop orchestrator.
pub struct Coordinator {
    cfg: ExperimentConfig,
    clients: Vec<FlClient>,
    server: FlServer,
    model: Arc<dyn ModelOps + Sync>,
    test: Dataset,
    history: History,
    phases: PhaseTimes,
    /// round-level RNG (client sampling under partial participation)
    round_rng: Rng,
}

impl Coordinator {
    /// Build everything from a config. Loads (or synthesizes) data,
    /// shards it IID, constructs the model backend, per-client links,
    /// schemes and the server.
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Self> {
        let spec = ModelSpec::new(cfg.model);
        let model: Arc<dyn ModelOps + Sync> = match cfg.backend {
            Backend::Native => Arc::new(NativeModel::new(cfg.model)),
            Backend::Pjrt => Arc::new(crate::runtime::PjrtModel::load_default(cfg.model)?),
        };
        Self::with_model(cfg, spec, model)
    }

    /// Like [`Coordinator::from_config`] but with an injected model
    /// backend (tests / custom runtimes).
    pub fn with_model(
        cfg: &ExperimentConfig,
        spec: ModelSpec,
        model: Arc<dyn ModelOps + Sync>,
    ) -> Result<Self> {
        let (train, test) = data::load(cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed);
        log::info!(
            "dataset {}: {} train / {} test ({}-dim)",
            train.source,
            train.len(),
            test.len(),
            train.dim()
        );
        let shards = match cfg.sharding {
            crate::config::Sharding::Iid => train.shard_iid(cfg.clients, cfg.seed ^ 0x5A5A),
            crate::config::Sharding::LabelSkew(k) => {
                train.shard_label_skew(cfg.clients, k, cfg.seed ^ 0x5A5A)
            }
            crate::config::Sharding::Dirichlet(a) => {
                train.shard_dirichlet(cfg.clients, a, cfg.seed ^ 0x5A5A)
            }
        };
        let links = LinkModel::spread(cfg.clients, cfg.link_slow_bps, cfg.link_fast_bps);
        let shapes = spec.shapes();
        let mut seed_rng = Rng::new(cfg.seed ^ 0xC11E);

        let mut clients = Vec::with_capacity(cfg.clients);
        let mut server_schemes = Vec::with_capacity(cfg.clients);
        for (i, (shard, link)) in shards.into_iter().zip(links.iter()).enumerate() {
            let kind = cfg
                .scheme
                .kind_for_client(link, cfg.link_slow_bps, cfg.link_fast_bps);
            log::debug!("client {i}: link {:.0} bps, scheme {}", link.bandwidth_bps, kind.name());
            clients.push(FlClient::new(
                i as u32,
                shard,
                Arc::clone(&model),
                make_client_scheme(kind, &shapes, cfg.beta, cfg.alpha0(), cfg.clients),
                *link,
                cfg.batch,
                seed_rng.next_u64(),
            ));
            server_schemes.push(make_server_scheme(kind, &shapes, cfg.beta));
        }

        let params = spec.init_params(cfg.seed ^ 0x1217);
        let server = FlServer::new(params, server_schemes, cfg.alpha0());
        Ok(Coordinator {
            cfg: cfg.clone(),
            clients,
            server,
            model,
            test,
            history: History::new(cfg.scheme.label()),
            phases: PhaseTimes::new(),
            round_rng: Rng::new(cfg.seed ^ 0xFAC7),
        })
    }

    /// Current central parameters.
    pub fn params(&self) -> &[crate::tensor::Tensor] {
        self.server.params()
    }

    /// Run the configured number of iterations, returning the report.
    pub fn run(&mut self) -> Result<RunReport> {
        let iters = self.cfg.iters;
        for it in 0..iters {
            self.step(it)?;
        }
        // final evaluation if the last round wasn't an eval round
        if self
            .history
            .evals
            .last()
            .map(|e| e.iter + 1 != iters)
            .unwrap_or(true)
        {
            self.evaluate(iters.saturating_sub(1));
        }
        Ok(RunReport {
            history: self.history.clone(),
            client_mem_bytes: self.clients.iter().map(|c| c.scheme_mem_bytes()).sum(),
            server_mem_bytes: self.server.scheme_mem_bytes(),
            phases: self.phases.clone(),
        })
    }

    /// Execute a single FL iteration.
    pub fn step(&mut self, it: u64) -> Result<()> {
        // learning-rate schedule
        let alpha = self.cfg.alpha_at(it);
        if self.server.alpha() != alpha {
            log::info!("iteration {it}: learning rate -> {alpha}");
            self.server.set_alpha(alpha);
        }

        // broadcast: clients read the current central parameters
        let weights: Vec<crate::tensor::Tensor> = self.server.params().to_vec();

        // partial participation: sample the active subset for this round
        let n = self.clients.len();
        let active: Vec<bool> = if self.cfg.participation >= 1.0 {
            vec![true; n]
        } else {
            let k = ((self.cfg.participation * n as f64).ceil() as usize).clamp(1, n);
            let chosen = self.round_rng.sample_indices(n, k);
            let mut mask = vec![false; n];
            for c in chosen {
                mask[c] = true;
            }
            mask
        };

        // parallel client execution (participants only)
        let outputs: Vec<Option<crate::fl::ClientRoundOutput>> = {
            let mut slots: Vec<Option<crate::fl::ClientRoundOutput>> =
                (0..n).map(|_| None).collect();
            let weights = &weights;
            let slot_cells: Vec<Mutex<&mut Option<crate::fl::ClientRoundOutput>>> =
                slots.iter_mut().map(Mutex::new).collect();
            let client_cells: Vec<Mutex<&mut FlClient>> =
                self.clients.iter_mut().map(Mutex::new).collect();
            let active = &active;
            crate::exec::parallel_for(crate::exec::default_threads(), n, |i| {
                if !active[i] {
                    return;
                }
                let mut client = client_cells[i].lock().unwrap();
                let out = client.round(weights);
                **slot_cells[i].lock().unwrap() = Some(out);
            });
            drop(client_cells);
            slots
        };

        // metrics + wire collection
        let mut bits = 0u64;
        let mut comms = 0u32;
        let mut loss_sum = 0f64;
        let mut participants = 0usize;
        let mut net_time = std::time::Duration::ZERO;
        let mut wires: Vec<Option<Vec<u8>>> = Vec::with_capacity(n);
        for out in outputs {
            let Some(out) = out else {
                wires.push(None);
                continue;
            };
            participants += 1;
            bits += out.payload_bits;
            if out.wire.is_some() {
                comms += 1;
            }
            loss_sum += out.train_loss as f64;
            net_time = net_time.max(out.net_time); // synchronous round: slowest client
            self.phases.merge(&out.phases);
            wires.push(out.wire);
        }

        // server: decode + aggregate + descent step
        let grad_norm = self.server.aggregate_wire(&wires)?;

        self.history.rounds.push(RoundMetrics {
            iter: it,
            train_loss: (loss_sum / participants.max(1) as f64) as f32,
            bits,
            comms,
            grad_norm,
            net_time,
        });

        if (it + 1) % self.cfg.eval_every == 0 {
            self.evaluate(it);
        }
        Ok(())
    }

    /// Evaluate the central model on the test set and record the point.
    fn evaluate(&mut self, it: u64) {
        let params = self.server.params().to_vec();
        let chunk = 512usize;
        let chunks: Vec<(crate::tensor::Tensor, Vec<u32>)> = self.test.chunks(chunk).collect();
        let results: Vec<Mutex<(f64, usize, usize)>> =
            chunks.iter().map(|_| Mutex::new((0.0, 0, 0))).collect();
        let model = &self.model;
        crate::exec::parallel_for(crate::exec::default_threads(), chunks.len(), |i| {
            let (x, y) = &chunks[i];
            let (loss, correct) = model.eval(&params, x, y);
            *results[i].lock().unwrap() = (loss as f64 * y.len() as f64, correct, y.len());
        });
        let (mut loss_sum, mut correct, mut total) = (0f64, 0usize, 0usize);
        for r in results {
            let (l, c, t) = r.into_inner().unwrap();
            loss_sum += l;
            correct += c;
            total += t;
        }
        let cum_bits: u64 = self.history.rounds.iter().map(|r| r.bits).sum();
        let point = EvalPoint {
            iter: it,
            cum_bits,
            loss: (loss_sum / total.max(1) as f64) as f32,
            accuracy: correct as f64 / total.max(1) as f64,
        };
        log::info!(
            "[{}] iter {:>5}  test loss {:.4}  acc {:.2}%  bits {}",
            self.history.label,
            it + 1,
            point.loss,
            100.0 * point.accuracy,
            crate::util::fmt::bits_sci(cum_bits)
        );
        self.history.evals.push(point);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PPolicy, SchemeConfig};

    fn tiny_cfg(scheme: SchemeConfig) -> ExperimentConfig {
        let mut c = ExperimentConfig::table1_default();
        c.scheme = scheme;
        c.clients = 3;
        c.iters = 6;
        c.batch = 16;
        c.train_n = 300;
        c.test_n = 100;
        c.eval_every = 3;
        c.lr_schedule = vec![(0, 0.05)];
        c
    }

    #[test]
    fn sgd_run_reduces_loss_and_counts_bits() {
        let cfg = tiny_cfg(SchemeConfig::Sgd);
        let mut coord = Coordinator::from_config(&cfg).unwrap();
        let report = coord.run().unwrap();
        let h = &report.history;
        assert_eq!(h.iterations(), 6);
        // 3 clients × 159,010 params × 32 bits × 6 rounds
        assert_eq!(h.total_bits(), 3 * 159_010 * 32 * 6);
        assert_eq!(h.total_comms(), 18);
        assert!(h.evals.len() >= 2);
        let first = h.evals.first().unwrap().loss;
        let last = h.evals.last().unwrap().loss;
        assert!(last < first, "no learning: {first} -> {last}");
    }

    #[test]
    fn qrr_run_uses_fewer_bits_than_sgd() {
        let sgd = {
            let cfg = tiny_cfg(SchemeConfig::Sgd);
            Coordinator::from_config(&cfg).unwrap().run().unwrap()
        };
        let qrr = {
            let cfg = tiny_cfg(SchemeConfig::Qrr(PPolicy::Fixed(0.1)));
            Coordinator::from_config(&cfg).unwrap().run().unwrap()
        };
        assert!(
            qrr.history.total_bits() * 5 < sgd.history.total_bits(),
            "qrr {} vs sgd {}",
            qrr.history.total_bits(),
            sgd.history.total_bits()
        );
        assert!(qrr.client_mem_bytes > 0);
        assert!(qrr.server_mem_bytes > 0);
    }

    #[test]
    fn slaq_may_skip_but_stays_consistent() {
        let cfg = tiny_cfg(SchemeConfig::Slaq);
        let mut coord = Coordinator::from_config(&cfg).unwrap();
        let report = coord.run().unwrap();
        // comms <= clients * iters
        assert!(report.history.total_comms() <= 18);
        assert!(report.history.evals.last().unwrap().loss.is_finite());
    }

    #[test]
    fn adaptive_p_assigns_different_ranks() {
        let cfg = tiny_cfg(SchemeConfig::Qrr(PPolicy::Adaptive { lo: 0.1, hi: 0.3 }));
        let coord = Coordinator::from_config(&cfg).unwrap();
        let mems: Vec<usize> = coord.clients.iter().map(|c| c.scheme_mem_bytes()).collect();
        // different p -> different factor state sizes
        assert!(mems.windows(2).any(|w| w[0] != w[1]), "mems {mems:?}");
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = tiny_cfg(SchemeConfig::Qrr(PPolicy::Fixed(0.2)));
        let r1 = Coordinator::from_config(&cfg).unwrap().run().unwrap();
        let r2 = Coordinator::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(r1.history.total_bits(), r2.history.total_bits());
        let a = r1.history.evals.last().unwrap();
        let b = r2.history.evals.last().unwrap();
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.accuracy, b.accuracy);
    }

    #[test]
    fn lr_schedule_transitions() {
        let mut cfg = tiny_cfg(SchemeConfig::Sgd);
        cfg.lr_schedule = vec![(0, 0.05), (3, 0.01)];
        let mut coord = Coordinator::from_config(&cfg).unwrap();
        coord.step(0).unwrap();
        assert_eq!(coord.server.alpha(), 0.05);
        coord.step(3).unwrap();
        assert_eq!(coord.server.alpha(), 0.01);
    }
}
