//! The Layer-3 coordinator — now a thin shim over the composable
//! [`fl::session`](crate::fl::session) API (DESIGN.md §1).
//!
//! Historically this module owned the whole synchronous round loop:
//! sharding, per-client links, scheme construction, parallel client
//! execution, wire decode, aggregation and metrics were all hard-wired
//! here. That loop now lives in [`FlSession`], assembled by
//! [`FlSessionBuilder`](crate::fl::session::FlSessionBuilder) with
//! pluggable participation / aggregation / transport / metrics seams.
//! [`Coordinator`] remains as the stable convenience entry point:
//! config in, report out, every seam at its config default.

use std::sync::Arc;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::fl::session::{FlSession, FlSessionBuilder};
use crate::model::{ModelOps, ModelSpec};

pub use crate::fl::session::RunReport;

/// Config-in / report-out shim over [`FlSession`].
pub struct Coordinator {
    session: FlSession,
}

impl Coordinator {
    /// Build a session from a config with every seam at its default.
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Self> {
        Ok(Coordinator { session: FlSessionBuilder::new(cfg).build()? })
    }

    /// Like [`Coordinator::from_config`] but with an injected model
    /// backend (tests / custom runtimes).
    pub fn with_model(
        cfg: &ExperimentConfig,
        spec: ModelSpec,
        model: Arc<dyn ModelOps + Sync>,
    ) -> Result<Self> {
        Ok(Coordinator { session: FlSessionBuilder::new(cfg).model(spec, model).build()? })
    }

    /// Current central parameters.
    pub fn params(&self) -> &[crate::tensor::Tensor] {
        self.session.params()
    }

    /// The underlying session (for seam-level access).
    pub fn session(&self) -> &FlSession {
        &self.session
    }

    /// Run the configured number of iterations, returning the report.
    pub fn run(&mut self) -> Result<RunReport> {
        self.session.run()
    }

    /// Execute a single FL iteration.
    pub fn step(&mut self, it: u64) -> Result<()> {
        self.session.step(it)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PPolicy, SchemeConfig};

    fn tiny_cfg(scheme: SchemeConfig) -> ExperimentConfig {
        let mut c = ExperimentConfig::table1_default();
        c.scheme = scheme;
        c.clients = 3;
        c.iters = 6;
        c.batch = 16;
        c.train_n = 300;
        c.test_n = 100;
        c.eval_every = 3;
        c.lr_schedule = vec![(0, 0.05)];
        c
    }

    #[test]
    fn shim_runs_and_reports_like_the_session() {
        let cfg = tiny_cfg(SchemeConfig::Sgd);
        let report = Coordinator::from_config(&cfg).unwrap().run().unwrap();
        let h = &report.history;
        assert_eq!(h.iterations(), 6);
        assert_eq!(h.total_bits(), 3 * 159_010 * 32 * 6);
        assert_eq!(h.total_comms(), 18);
        let first = h.evals.first().unwrap().loss;
        let last = h.evals.last().unwrap().loss;
        assert!(last < first, "no learning: {first} -> {last}");
    }

    #[test]
    fn qrr_run_uses_fewer_bits_than_sgd() {
        let sgd = {
            let cfg = tiny_cfg(SchemeConfig::Sgd);
            Coordinator::from_config(&cfg).unwrap().run().unwrap()
        };
        let qrr = {
            let cfg = tiny_cfg(SchemeConfig::Qrr(PPolicy::Fixed(0.1)));
            Coordinator::from_config(&cfg).unwrap().run().unwrap()
        };
        assert!(
            qrr.history.total_bits() * 5 < sgd.history.total_bits(),
            "qrr {} vs sgd {}",
            qrr.history.total_bits(),
            sgd.history.total_bits()
        );
        assert!(qrr.client_mem_bytes > 0);
        assert!(qrr.server_mem_bytes > 0);
    }

    #[test]
    fn slaq_may_skip_but_stays_consistent() {
        let cfg = tiny_cfg(SchemeConfig::Slaq);
        let mut coord = Coordinator::from_config(&cfg).unwrap();
        let report = coord.run().unwrap();
        // comms <= clients * iters
        assert!(report.history.total_comms() <= 18);
        assert!(report.history.evals.last().unwrap().loss.is_finite());
    }

    #[test]
    fn adaptive_p_assigns_different_ranks() {
        let cfg = tiny_cfg(SchemeConfig::Qrr(PPolicy::Adaptive { lo: 0.1, hi: 0.3 }));
        let coord = Coordinator::from_config(&cfg).unwrap();
        let mems: Vec<usize> = coord
            .session()
            .clients()
            .iter()
            .map(|c| c.scheme_mem_bytes())
            .collect();
        // different p -> different factor state sizes
        assert!(mems.windows(2).any(|w| w[0] != w[1]), "mems {mems:?}");
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = tiny_cfg(SchemeConfig::Qrr(PPolicy::Fixed(0.2)));
        let r1 = Coordinator::from_config(&cfg).unwrap().run().unwrap();
        let r2 = Coordinator::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(r1.history.total_bits(), r2.history.total_bits());
        let a = r1.history.evals.last().unwrap();
        let b = r2.history.evals.last().unwrap();
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.accuracy, b.accuracy);
    }

    #[test]
    fn lr_schedule_transitions() {
        let mut cfg = tiny_cfg(SchemeConfig::Sgd);
        cfg.lr_schedule = vec![(0, 0.05), (3, 0.01)];
        let mut coord = Coordinator::from_config(&cfg).unwrap();
        coord.step(0).unwrap();
        assert_eq!(coord.session().server().alpha(), 0.05);
        coord.step(3).unwrap();
        assert_eq!(coord.session().server().alpha(), 0.01);
    }
}
