//! Elementwise and reduction helpers shared across the crate.

use super::Tensor;

/// Sum of all elements.
pub fn sum(t: &Tensor) -> f32 {
    t.data().iter().sum()
}

/// Mean of all elements.
pub fn mean(t: &Tensor) -> f32 {
    if t.is_empty() {
        0.0
    } else {
        sum(t) / t.len() as f32
    }
}

/// Dot product of two same-shaped tensors viewed as flat vectors.
pub fn dot(a: &Tensor, b: &Tensor) -> f32 {
    assert_eq!(a.len(), b.len(), "dot length mismatch");
    a.data()
        .iter()
        .zip(b.data().iter())
        .map(|(x, y)| (*x as f64) * (*y as f64))
        .sum::<f64>() as f32
}

/// Squared ℓ2 norm as f64 (stable accumulation).
pub fn sq_norm(t: &Tensor) -> f64 {
    t.data().iter().map(|x| (*x as f64) * (*x as f64)).sum()
}

/// Elementwise map into a new tensor.
pub fn map(t: &Tensor, f: impl Fn(f32) -> f32) -> Tensor {
    Tensor::from_vec(t.shape(), t.data().iter().map(|&x| f(x)).collect())
}

/// Elementwise binary zip into a new tensor.
pub fn zip(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
    assert_eq!(a.shape(), b.shape(), "zip shape mismatch");
    Tensor::from_vec(
        a.shape(),
        a.data()
            .iter()
            .zip(b.data().iter())
            .map(|(&x, &y)| f(x, y))
            .collect(),
    )
}

/// argmax over the last axis of a 2-D tensor; returns one index per row.
pub fn argmax_rows(t: &Tensor) -> Vec<usize> {
    assert_eq!(t.ndim(), 2);
    let (m, n) = (t.shape()[0], t.shape()[1]);
    let mut out = Vec::with_capacity(m);
    for r in 0..m {
        let row = &t.data()[r * n..(r + 1) * n];
        let mut best = 0;
        for (i, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = i;
            }
        }
        out.push(best);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reductions() {
        let t = Tensor::vector(vec![1., 2., 3., 4.]);
        assert_eq!(sum(&t), 10.0);
        assert_eq!(mean(&t), 2.5);
        assert_eq!(dot(&t, &t), 30.0);
        assert_eq!(sq_norm(&t), 30.0);
    }

    #[test]
    fn map_zip() {
        let a = Tensor::vector(vec![1., -2.]);
        let b = Tensor::vector(vec![3., 5.]);
        assert_eq!(map(&a, f32::abs).data(), &[1., 2.]);
        assert_eq!(zip(&a, &b, |x, y| x * y).data(), &[3., -10.]);
    }

    #[test]
    fn argmax() {
        let t = Tensor::matrix(2, 3, vec![0.1, 0.9, 0.0, 0.3, 0.2, 0.5]);
        assert_eq!(argmax_rows(&t), vec![1, 2]);
    }

    #[test]
    fn mean_empty_is_zero() {
        let t = Tensor::zeros(&[0]);
        assert_eq!(mean(&t), 0.0);
    }
}
