//! Mode-n unfolding (matricization), folding and mode-n products —
//! the tensor algebra behind the Tucker decomposition (paper eq. (9)-(10)).
//!
//! Convention: the mode-n unfolding X_(n) of X ∈ R^{I_1 × … × I_N} is the
//! I_n × (∏_{k≠n} I_k) matrix whose columns enumerate the remaining
//! indices in **row-major (lexicographic) order of the other modes**.
//! Folding is the exact inverse for the same convention, so
//! `fold(unfold(x, n), n, shape) == x` for every n.

use super::Tensor;

/// Mode-n unfolding: returns an `I_n × (len / I_n)` matrix.
pub fn unfold(x: &Tensor, mode: usize) -> Tensor {
    let shape = x.shape();
    let ndim = shape.len();
    assert!(mode < ndim, "mode {mode} out of range for ndim {ndim}");
    let i_n = shape[mode];
    let cols = x.len() / i_n;
    let strides = x.strides();
    let mut out = vec![0f32; x.len()];

    // Enumerate the "other" modes in row-major order.
    let other: Vec<usize> = (0..ndim).filter(|&d| d != mode).collect();
    let other_dims: Vec<usize> = other.iter().map(|&d| shape[d]).collect();

    let data = x.data();
    let mut idx = vec![0usize; other.len()];
    for col in 0..cols {
        // offset contributed by the other modes
        let mut base = 0usize;
        for (k, &d) in other.iter().enumerate() {
            base += idx[k] * strides[d];
        }
        for r in 0..i_n {
            out[r * cols + col] = data[base + r * strides[mode]];
        }
        // increment multi-index (row-major: last varies fastest)
        for k in (0..idx.len()).rev() {
            idx[k] += 1;
            if idx[k] < other_dims[k] {
                break;
            }
            idx[k] = 0;
        }
    }
    Tensor::matrix(i_n, cols, out)
}

/// Inverse of [`unfold`]: reconstruct a tensor of `shape` from its mode-n
/// unfolding.
pub fn fold(m: &Tensor, mode: usize, shape: &[usize]) -> Tensor {
    assert_eq!(m.ndim(), 2, "fold expects a matrix");
    let ndim = shape.len();
    assert!(mode < ndim);
    let i_n = shape[mode];
    assert_eq!(m.shape()[0], i_n, "fold: row count must equal shape[mode]");
    let cols: usize = shape.iter().product::<usize>() / i_n;
    assert_eq!(m.shape()[1], cols, "fold: column count mismatch");

    let mut out = Tensor::zeros(shape);
    let strides = out.strides();
    let other: Vec<usize> = (0..ndim).filter(|&d| d != mode).collect();
    let other_dims: Vec<usize> = other.iter().map(|&d| shape[d]).collect();

    let mdata = m.data().to_vec();
    let odata = out.data_mut();
    let mut idx = vec![0usize; other.len()];
    for col in 0..cols {
        let mut base = 0usize;
        for (k, &d) in other.iter().enumerate() {
            base += idx[k] * strides[d];
        }
        for r in 0..i_n {
            odata[base + r * strides[mode]] = mdata[r * cols + col];
        }
        for k in (0..idx.len()).rev() {
            idx[k] += 1;
            if idx[k] < other_dims[k] {
                break;
            }
            idx[k] = 0;
        }
    }
    out
}

/// Mode-n product Y = X ×_n F, where F is J × I_n (paper eq. (10)).
///
/// Implemented as fold(F · unfold(X, n), n, new_shape).
pub fn mode_n_product(x: &Tensor, mode: usize, f: &Tensor) -> Tensor {
    assert_eq!(f.ndim(), 2, "factor must be a matrix");
    let (j, i_n) = (f.shape()[0], f.shape()[1]);
    assert_eq!(
        x.shape()[mode],
        i_n,
        "mode-{mode} product: factor cols {} != tensor dim {}",
        i_n,
        x.shape()[mode]
    );
    let unf = unfold(x, mode);
    let prod = crate::linalg::matmul(f, &unf);
    let mut new_shape = x.shape().to_vec();
    new_shape[mode] = j;
    fold(&prod, mode, &new_shape)
}

/// Mode-n product with the transposed factor, Y = X ×_n Fᵀ, where F is
/// I_n × J — the HOSVD core projection (𝔊 = 𝔛 ×ᵢ Fᵢᵀ). The packed GEMM
/// reads F through a strided view, so no transposed copy of the factor
/// is materialized.
pub fn mode_n_product_t(x: &Tensor, mode: usize, f: &Tensor) -> Tensor {
    assert_eq!(f.ndim(), 2, "factor must be a matrix");
    let (i_n, j) = (f.shape()[0], f.shape()[1]);
    assert_eq!(
        x.shape()[mode],
        i_n,
        "mode-{mode} product: factor rows {} != tensor dim {}",
        i_n,
        x.shape()[mode]
    );
    let unf = unfold(x, mode);
    let prod = crate::linalg::matmul_tn(f, &unf);
    let mut new_shape = x.shape().to_vec();
    new_shape[mode] = j;
    fold(&prod, mode, &new_shape)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn unfold_fold_roundtrip_all_modes() {
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[3, 4, 2, 5], &mut rng);
        for mode in 0..4 {
            let u = unfold(&x, mode);
            assert_eq!(u.shape(), &[x.shape()[mode], x.len() / x.shape()[mode]]);
            let back = fold(&u, mode, x.shape());
            assert_eq!(x, back, "mode {mode}");
        }
    }

    #[test]
    fn unfold_matrix_mode0_is_identity() {
        let x = Tensor::matrix(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let u = unfold(&x, 0);
        assert_eq!(u, x);
    }

    #[test]
    fn unfold_matrix_mode1_is_transpose() {
        let x = Tensor::matrix(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let u = unfold(&x, 1);
        assert_eq!(u, x.transpose());
    }

    #[test]
    fn mode_product_known_values() {
        // X = [[1,2],[3,4]] (2x2), F = [[1,1]] (1x2):
        // X x_0 F sums rows -> shape (1,2): [4, 6]
        let x = Tensor::matrix(2, 2, vec![1., 2., 3., 4.]);
        let f = Tensor::matrix(1, 2, vec![1., 1.]);
        let y = mode_n_product(&x, 0, &f);
        assert_eq!(y.shape(), &[1, 2]);
        assert_eq!(y.data(), &[4., 6.]);
        // X x_1 F sums cols -> shape (2,1): [3, 7]
        let y = mode_n_product(&x, 1, &f);
        assert_eq!(y.shape(), &[2, 1]);
        assert_eq!(y.data(), &[3., 7.]);
    }

    #[test]
    fn mode_product_t_matches_explicit_transpose() {
        let mut rng = Rng::new(5);
        let x = Tensor::randn(&[5, 4, 3], &mut rng);
        for mode in 0..3 {
            let f = Tensor::randn(&[x.shape()[mode], 2], &mut rng);
            let fast = mode_n_product_t(&x, mode, &f);
            let slow = mode_n_product(&x, mode, &f.transpose());
            assert!(fast.rel_err(&slow) < 1e-5, "mode {mode}");
        }
    }

    #[test]
    fn mode_product_identity_is_noop() {
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[4, 3, 2], &mut rng);
        for mode in 0..3 {
            let i = Tensor::eye(x.shape()[mode]);
            let y = mode_n_product(&x, mode, &i);
            assert!(x.rel_err(&y) < 1e-6, "mode {mode}");
        }
    }

    #[test]
    fn mode_product_composes_like_matrix_mult() {
        // (X x_n A) x_n B == X x_n (BA)
        let mut rng = Rng::new(4);
        let x = Tensor::randn(&[5, 4], &mut rng);
        let a = Tensor::randn(&[3, 5], &mut rng);
        let b = Tensor::randn(&[2, 3], &mut rng);
        let lhs = mode_n_product(&mode_n_product(&x, 0, &a), 0, &b);
        let ba = crate::linalg::matmul(&b, &a);
        let rhs = mode_n_product(&x, 0, &ba);
        assert!(lhs.rel_err(&rhs) < 1e-4);
    }
}
