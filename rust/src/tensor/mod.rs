//! Dense f32 tensor substrate.
//!
//! Everything the compression engine needs from a tensor library:
//! contiguous row-major storage, reshape, mode-n unfolding/folding
//! (matricization) and mode-n products — the operations behind the
//! Tucker decomposition (paper eq. (9)–(10)).

mod dense;
mod ops;
mod unfold;

pub use dense::Tensor;
pub use ops::*;
pub use unfold::{fold, mode_n_product, mode_n_product_t, unfold};
