//! The dense row-major f32 tensor type.

use std::fmt;

/// Dense, contiguous, row-major f32 tensor of arbitrary rank.
///
/// Gradients in the FL pipeline are matrices (fully connected layers),
/// 4-D tensors (convolution kernels) or vectors (biases); `Tensor`
/// covers all of them with explicit shape metadata.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Tensor from existing data; `data.len()` must equal the shape product.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} incompatible with data length {}",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    /// 2-D convenience constructor.
    pub fn matrix(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        Self::from_vec(&[rows, cols], data)
    }

    /// 1-D convenience constructor.
    pub fn vector(data: Vec<f32>) -> Self {
        let n = data.len();
        Self::from_vec(&[n], data)
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut t = Self::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    /// Tensor filled with iid standard normals.
    pub fn randn(shape: &[usize], rng: &mut crate::util::Rng) -> Self {
        let mut t = Self::zeros(shape);
        rng.fill_normal(&mut t.data);
        t
    }

    /// Shape slice.
    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Number of dimensions.
    #[inline]
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Total number of elements.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if the tensor has no elements.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Raw data slice (row-major).
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable raw data slice.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the raw data vector.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reshape in place (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {:?}: element count mismatch",
            self.shape,
            shape
        );
        self.shape = shape.to_vec();
        self
    }

    /// Row-major strides for the current shape.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    /// Element accessor by multi-index (debug-checked).
    #[inline]
    pub fn at(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(idx.len(), self.shape.len());
        let strides = self.strides();
        let mut off = 0;
        for (i, &ix) in idx.iter().enumerate() {
            debug_assert!(ix < self.shape[i]);
            off += ix * strides[i];
        }
        self.data[off]
    }

    /// Mutable element accessor by multi-index.
    #[inline]
    pub fn at_mut(&mut self, idx: &[usize]) -> &mut f32 {
        debug_assert_eq!(idx.len(), self.shape.len());
        let strides = self.strides();
        let mut off = 0;
        for (i, &ix) in idx.iter().enumerate() {
            debug_assert!(ix < self.shape[i]);
            off += ix * strides[i];
        }
        &mut self.data[off]
    }

    /// 2-D accessor (rows-major).
    #[inline]
    pub fn get2(&self, r: usize, c: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[r * self.shape[1] + c]
    }

    /// Mutable 2-D accessor.
    #[inline]
    pub fn set2(&mut self, r: usize, c: usize, v: f32) {
        debug_assert_eq!(self.ndim(), 2);
        self.data[r * self.shape[1] + c] = v;
    }

    /// Matrix transpose (2-D only).
    pub fn transpose(&self) -> Tensor {
        assert_eq!(self.ndim(), 2, "transpose expects a matrix");
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[n, m]);
        // blocked transpose for cache friendliness
        const B: usize = 32;
        for bi in (0..m).step_by(B) {
            for bj in (0..n).step_by(B) {
                for i in bi..(bi + B).min(m) {
                    for j in bj..(bj + B).min(n) {
                        out.data[j * m + i] = self.data[i * n + j];
                    }
                }
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
    }

    /// Max (ℓ∞) norm — the SIMD [`crate::exec::simd::max_abs`] scan.
    pub fn max_norm(&self) -> f32 {
        crate::exec::simd::max_abs(&self.data)
    }

    /// Elementwise a += alpha * b — the SIMD
    /// [`crate::exec::simd::axpy`] kernel (bit-exact across dispatch
    /// levels; `alpha == 1.0` takes the multiply-free sum path).
    pub fn axpy(&mut self, alpha: f32, b: &Tensor) {
        assert_eq!(self.shape, b.shape, "axpy shape mismatch");
        crate::exec::simd::axpy(&mut self.data, alpha, &b.data);
    }

    /// Elementwise scale — the SIMD [`crate::exec::simd::scale`] kernel.
    pub fn scale(&mut self, alpha: f32) {
        crate::exec::simd::scale(&mut self.data, alpha);
    }

    /// a - b as a new tensor.
    pub fn sub(&self, b: &Tensor) -> Tensor {
        assert_eq!(self.shape, b.shape, "sub shape mismatch");
        let data = self
            .data
            .iter()
            .zip(b.data.iter())
            .map(|(x, y)| x - y)
            .collect();
        Tensor { shape: self.shape.clone(), data }
    }

    /// a + b as a new tensor.
    pub fn add(&self, b: &Tensor) -> Tensor {
        assert_eq!(self.shape, b.shape, "add shape mismatch");
        let data = self
            .data
            .iter()
            .zip(b.data.iter())
            .map(|(x, y)| x + y)
            .collect();
        Tensor { shape: self.shape.clone(), data }
    }

    /// Relative Frobenius error ‖a−b‖F / max(‖a‖F, ε).
    pub fn rel_err(&self, b: &Tensor) -> f32 {
        let denom = self.fro_norm().max(1e-12);
        self.sub(b).fro_norm() / denom
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.len() <= 16 {
            write!(f, " {:?}", self.data)
        } else {
            write!(f, " [{} elements]", self.len())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn construct_and_index() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.get2(0, 2), 3.0);
        assert_eq!(t.get2(1, 0), 4.0);
        assert_eq!(t.at(&[1, 2]), 6.0);
        assert_eq!(t.strides(), vec![3, 1]);
    }

    #[test]
    fn reshape_roundtrip() {
        let t = Tensor::from_vec(&[2, 6], (0..12).map(|x| x as f32).collect());
        let t = t.reshape(&[3, 4]).reshape(&[2, 2, 3]);
        assert_eq!(t.shape(), &[2, 2, 3]);
        assert_eq!(t.at(&[1, 1, 2]), 11.0);
    }

    #[test]
    #[should_panic]
    fn reshape_bad_count_panics() {
        let t = Tensor::zeros(&[2, 3]);
        let _ = t.reshape(&[4, 2]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[17, 31], &mut rng);
        let att = a.transpose().transpose();
        assert_eq!(a, att);
    }

    #[test]
    fn transpose_values() {
        let a = Tensor::matrix(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let at = a.transpose();
        assert_eq!(at.shape(), &[3, 2]);
        assert_eq!(at.get2(2, 1), 6.0);
        assert_eq!(at.get2(0, 1), 4.0);
    }

    #[test]
    fn norms() {
        let a = Tensor::vector(vec![3.0, -4.0]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-6);
        assert_eq!(a.max_norm(), 4.0);
    }

    #[test]
    fn axpy_and_sub() {
        let mut a = Tensor::vector(vec![1., 2.]);
        let b = Tensor::vector(vec![10., 20.]);
        a.axpy(0.5, &b);
        assert_eq!(a.data(), &[6., 12.]);
        let d = a.sub(&b);
        assert_eq!(d.data(), &[-4., -8.]);
    }

    #[test]
    fn eye_identity() {
        let i = Tensor::eye(3);
        assert_eq!(i.get2(0, 0), 1.0);
        assert_eq!(i.get2(0, 1), 0.0);
        assert_eq!(i.fro_norm(), 3.0f32.sqrt());
    }
}
