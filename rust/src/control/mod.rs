//! Adaptive per-client compression control plane (DESIGN.md §12).
//!
//! The paper's premise is that network-critical deployments should
//! spend as few bits as each link can afford — yet a static config
//! freezes `(p, beta)` at round 0 for the whole cohort. This module
//! closes the loop: a [`CompressionController`] maps per-client
//! *observed* telemetry — estimated link bandwidth from
//! [`crate::net::link`], measured uplink bits, the delivery outcome the
//! fault/quorum layer reported, and deadline slack — to next round's
//! uplink [`PipelineSpec`] for that client (and optionally a new shared
//! downlink spec).
//!
//! Three policies ship behind a spec grammar + preset registry
//! mirroring [`crate::compress::pipeline`]:
//!
//! | policy | behaviour |
//! |---|---|
//! | `fixed(p,beta)` | the same QRR spec every round (frontier anchor) |
//! | `linkaware(p_min,p_max,beta_min,beta_max)` | interpolates `(p, beta)` in log-bandwidth across the cohort |
//! | `aimd(target_ms,p_min,p_max,beta,cut,grow)` | multiplicative cut of a straggler's budget on timeout/late/over-deadline, additive recovery on on-time delivery |
//!
//! Every decision is a **pure function of (policy state, observations)**
//! — no wall clock, no RNG — so a chaos-seeded run replans identically
//! on every re-run and the per-round fault counters stay reproducible
//! (the bar the chaos suite enforces). [`crate::fl::session`] diffs the
//! returned specs against the ones in force and recompiles/swaps the
//! mirrored `PipelineClient`/`PipelineServer` halves only for clients
//! whose spec actually changed.

use std::time::Duration;

use anyhow::{bail, ensure, Result};

use crate::compress::pipeline::PipelineSpec;

// ------------------------------------------------------------ telemetry

/// What happened to a client's previous-round upload, as the session's
/// collection loop and fault accounting observed it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Outcome {
    /// no upload to observe: first round, not selected, or lazy-skipped
    #[default]
    Idle,
    /// arrived before the round's first deadline and decoded
    Delivered,
    /// arrived only in a quorum re-poll window, past the first deadline
    Late,
    /// sent but never arrived before the round closed
    TimedOut,
    /// never admitted to the wire (send/admission failure)
    Dropped,
    /// arrived but failed decode (corrupted frame)
    Corrupt,
}

impl Outcome {
    /// Single-letter CSV code: `i`/`d`/`l`/`t`/`x`/`c`.
    pub fn code(self) -> char {
        match self {
            Outcome::Idle => 'i',
            Outcome::Delivered => 'd',
            Outcome::Late => 'l',
            Outcome::TimedOut => 't',
            Outcome::Dropped => 'x',
            Outcome::Corrupt => 'c',
        }
    }

    /// True when the upload was sent but the server never absorbed it.
    pub fn is_loss(self) -> bool {
        matches!(self, Outcome::TimedOut | Outcome::Dropped | Outcome::Corrupt)
    }
}

/// One client's telemetry from the previous round, the controller's
/// entire view of the world (keeping decisions reproducible).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClientObservation {
    /// client id
    pub client: u32,
    /// estimated link bandwidth (bits/s) from the client's [`crate::net::link::LinkModel`]
    pub bandwidth_bps: f64,
    /// uplink payload bits actually shipped last round (0 when idle)
    pub up_bits: u64,
    /// modeled uplink transmit time for those bits
    pub net_time: Duration,
    /// the server's collection deadline for the round
    pub deadline: Duration,
    /// what happened to the upload
    pub outcome: Outcome,
}

impl ClientObservation {
    /// Deadline slack in seconds: positive = finished with room to
    /// spare, negative = the modeled transmit time overran the deadline.
    pub fn slack(&self) -> f64 {
        self.deadline.as_secs_f64() - self.net_time.as_secs_f64()
    }
}

// ------------------------------------------------------------ trait

/// A per-round policy mapping cohort observations to per-client uplink
/// specs (and optionally a shared downlink spec).
///
/// Contract: `plan` must return exactly one spec per observation, in
/// the same order, and must be deterministic — a pure function of the
/// policy's configuration, its own accumulated state, and the
/// observation sequence. Policies must not consult clocks or RNGs;
/// that is what keeps chaos-seeded runs bit-reproducible.
pub trait CompressionController: Send {
    /// Choose each client's uplink spec for `round` from last round's
    /// observations.
    fn plan(&mut self, round: u64, obs: &[ClientObservation]) -> Vec<PipelineSpec>;

    /// Optionally replace the shared downlink spec for `round`.
    /// `None` (the default) keeps the downlink as configured.
    fn plan_downlink(&mut self, _round: u64, _obs: &[ClientObservation]) -> Option<PipelineSpec> {
        None
    }

    /// The canonical spec string of the policy driving this controller.
    fn label(&self) -> String;
}

// ------------------------------------------------------------ config

/// A parsed, validated controller policy description.
///
/// Build one from the grammar with [`ControllerConfig::parse`];
/// [`format`](Self::format) renders the canonical spec string and
/// `parse ∘ format` is the identity for every shipped policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ControllerConfig {
    /// the same `qrr(p, beta)` uplink for every client, every round
    Fixed {
        /// retained rank fraction
        p: f64,
        /// LAQ bits per element
        beta: u8,
    },
    /// interpolate `(p, beta)` in log-bandwidth across the observed cohort
    LinkAware {
        /// rank fraction assigned to the slowest observed link
        p_min: f64,
        /// rank fraction assigned to the fastest observed link
        p_max: f64,
        /// quantizer bits at the slowest link
        beta_min: u8,
        /// quantizer bits at the fastest link
        beta_max: u8,
    },
    /// additive-increase / multiplicative-decrease on each client's bit budget
    Aimd {
        /// modeled uplink transmit time a round should fit in (ms)
        target_ms: f64,
        /// floor of the rank-fraction budget
        p_min: f64,
        /// ceiling of the rank-fraction budget (every client starts here)
        p_max: f64,
        /// LAQ bits per element (AIMD moves rank, not precision)
        beta: u8,
        /// multiplicative budget factor on timeout/late/over-target, in (0,1)
        cut: f64,
        /// additive budget recovery per on-time round, in (0,1]
        grow: f64,
    },
}

impl ControllerConfig {
    /// The `fixed` policy with the registry defaults (`qrr` preset knobs).
    pub fn fixed() -> Self {
        ControllerConfig::Fixed { p: 0.3, beta: 8 }
    }

    /// The `linkaware` policy with the registry defaults.
    pub fn linkaware() -> Self {
        ControllerConfig::LinkAware { p_min: 0.05, p_max: 0.3, beta_min: 4, beta_max: 8 }
    }

    /// The `aimd` policy with the registry defaults.
    pub fn aimd() -> Self {
        ControllerConfig::Aimd {
            target_ms: 250.0,
            p_min: 0.05,
            p_max: 0.3,
            beta: 8,
            cut: 0.5,
            grow: 0.05,
        }
    }

    /// Parse a controller spec string: a policy name (`fixed`,
    /// `linkaware`, `aimd`), optionally with `(key=value,…)` arguments;
    /// omitted arguments take the registry defaults.
    pub fn parse(s: &str) -> Result<Self> {
        let (name, args) = split_call(s)?;
        let mut args = ArgMap::new(args);
        let cfg = match name {
            "fixed" => ControllerConfig::Fixed {
                p: args.float("p", 0.3)?,
                beta: args.bits("beta", 8)?,
            },
            "linkaware" => ControllerConfig::LinkAware {
                p_min: args.float("p_min", 0.05)?,
                p_max: args.float("p_max", 0.3)?,
                beta_min: args.bits("beta_min", 4)?,
                beta_max: args.bits("beta_max", 8)?,
            },
            "aimd" => ControllerConfig::Aimd {
                target_ms: args.float("target_ms", 250.0)?,
                p_min: args.float("p_min", 0.05)?,
                p_max: args.float("p_max", 0.3)?,
                beta: args.bits("beta", 8)?,
                cut: args.float("cut", 0.5)?,
                grow: args.float("grow", 0.05)?,
            },
            other => bail!("unknown controller policy {other:?} (fixed | linkaware | aimd)"),
        };
        args.finish(name)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Range checks (also run by [`parse`](Self::parse)).
    pub fn validate(&self) -> Result<()> {
        let frac = |what: &str, p: f64| -> Result<()> {
            ensure!(p > 0.0 && p <= 1.0 && p.is_finite(), "{what} must be in (0,1], got {p}");
            Ok(())
        };
        let bits = |what: &str, b: u8| -> Result<()> {
            ensure!((1..=16).contains(&b), "{what} must be in 1..=16, got {b}");
            Ok(())
        };
        match *self {
            ControllerConfig::Fixed { p, beta } => {
                frac("p", p)?;
                bits("beta", beta)?;
            }
            ControllerConfig::LinkAware { p_min, p_max, beta_min, beta_max } => {
                frac("p_min", p_min)?;
                frac("p_max", p_max)?;
                ensure!(p_min <= p_max, "p_min ({p_min}) must be <= p_max ({p_max})");
                bits("beta_min", beta_min)?;
                bits("beta_max", beta_max)?;
                ensure!(
                    beta_min <= beta_max,
                    "beta_min ({beta_min}) must be <= beta_max ({beta_max})"
                );
            }
            ControllerConfig::Aimd { target_ms, p_min, p_max, beta, cut, grow } => {
                ensure!(
                    target_ms > 0.0 && target_ms.is_finite(),
                    "target_ms must be positive, got {target_ms}"
                );
                frac("p_min", p_min)?;
                frac("p_max", p_max)?;
                ensure!(p_min <= p_max, "p_min ({p_min}) must be <= p_max ({p_max})");
                bits("beta", beta)?;
                ensure!(cut > 0.0 && cut < 1.0, "cut must be in (0,1), got {cut}");
                frac("grow", grow)?;
            }
        }
        Ok(())
    }

    /// The canonical spec string; [`parse`](Self::parse) inverts it.
    pub fn format(&self) -> String {
        match *self {
            ControllerConfig::Fixed { p, beta } => format!("fixed(p={p},beta={beta})"),
            ControllerConfig::LinkAware { p_min, p_max, beta_min, beta_max } => format!(
                "linkaware(p_min={p_min},p_max={p_max},beta_min={beta_min},beta_max={beta_max})"
            ),
            ControllerConfig::Aimd { target_ms, p_min, p_max, beta, cut, grow } => format!(
                "aimd(target_ms={target_ms},p_min={p_min},p_max={p_max},beta={beta},\
                 cut={cut},grow={grow})"
            ),
        }
    }

    /// The bare policy name (`fixed` / `linkaware` / `aimd`).
    pub fn name(&self) -> &'static str {
        match self {
            ControllerConfig::Fixed { .. } => "fixed",
            ControllerConfig::LinkAware { .. } => "linkaware",
            ControllerConfig::Aimd { .. } => "aimd",
        }
    }

    /// Instantiate the policy behind this config.
    pub fn build(&self) -> Box<dyn CompressionController> {
        match *self {
            ControllerConfig::Fixed { p, beta } => Box::new(Fixed { p, beta }),
            ControllerConfig::LinkAware { p_min, p_max, beta_min, beta_max } => {
                Box::new(LinkAware { p_min, p_max, beta_min, beta_max })
            }
            ControllerConfig::Aimd { target_ms, p_min, p_max, beta, cut, grow } => Box::new(Aimd {
                target_ms,
                p_min,
                p_max,
                beta,
                cut,
                grow,
                level: Vec::new(),
            }),
        }
    }
}

// ------------------------------------------------------------ registry

/// One registered controller policy.
#[derive(Debug)]
pub struct PolicyInfo {
    /// registry name (what configs/CLI write)
    pub name: &'static str,
    /// the canonical spec the name resolves to (default parameters)
    pub spec: String,
    /// one-line description
    pub summary: &'static str,
}

/// The policy registry: every shipped controller as a named preset,
/// mirroring [`crate::compress::pipeline::presets`].
pub fn policies() -> Vec<PolicyInfo> {
    vec![
        PolicyInfo {
            name: "fixed",
            spec: ControllerConfig::fixed().format(),
            summary: "same qrr(p,beta) uplink for every client every round; args p, beta",
        },
        PolicyInfo {
            name: "linkaware",
            spec: ControllerConfig::linkaware().format(),
            summary: "interpolate (p,beta) in log-bandwidth across the cohort; \
                      args p_min, p_max, beta_min, beta_max",
        },
        PolicyInfo {
            name: "aimd",
            spec: ControllerConfig::aimd().format(),
            summary: "multiplicative budget cut on timeout/late/over-target, additive \
                      recovery on time; args target_ms, p_min, p_max, beta, cut, grow",
        },
    ]
}

// ------------------------------------------------------------ policies

/// `fixed`: every client runs the same QRR spec every round.
#[derive(Debug, Clone)]
pub struct Fixed {
    p: f64,
    beta: u8,
}

/// `linkaware`: interpolate `(p, beta)` in log-bandwidth between the
/// slowest and fastest link observed in the cohort.
#[derive(Debug, Clone)]
pub struct LinkAware {
    p_min: f64,
    p_max: f64,
    beta_min: u8,
    beta_max: u8,
}

/// `aimd`: per-client budget level in `[0,1]` mapped onto
/// `[p_min, p_max]`; cut multiplicatively when the upload timed out,
/// arrived late, was lost, or its modeled transmit time overran
/// `target_ms`; recover additively on on-time delivery.
#[derive(Debug, Clone)]
pub struct Aimd {
    target_ms: f64,
    p_min: f64,
    p_max: f64,
    beta: u8,
    cut: f64,
    grow: f64,
    /// per-client budget level, lazily sized to the cohort
    level: Vec<f64>,
}

// The observation→spec decide path must never panic: it runs inside
// every round of a live session, fed by telemetry the fault layer may
// have mangled. Guarded by the qrr-audit no-panic gate.
// qrr-audit: no-panic

impl CompressionController for Fixed {
    fn plan(&mut self, _round: u64, obs: &[ClientObservation]) -> Vec<PipelineSpec> {
        obs.iter().map(|_| PipelineSpec::qrr(self.p, self.beta)).collect()
    }

    fn label(&self) -> String {
        ControllerConfig::Fixed { p: self.p, beta: self.beta }.format()
    }
}

/// Position of `bw` in `[lo, hi]` on a log scale, clamped to `[0,1]`.
/// A degenerate cohort (`hi <= lo`, e.g. uniform links) maps everyone
/// to the midpoint rather than letting the 0/0 turn into NaN.
fn log_position(bw: f64, lo: f64, hi: f64) -> f64 {
    if !(hi > lo) || lo <= 0.0 {
        return 0.5;
    }
    let t = (bw.max(f64::MIN_POSITIVE).ln() - lo.ln()) / (hi.ln() - lo.ln());
    if t.is_finite() {
        t.clamp(0.0, 1.0)
    } else {
        0.5
    }
}

impl CompressionController for LinkAware {
    fn plan(&mut self, _round: u64, obs: &[ClientObservation]) -> Vec<PipelineSpec> {
        let lo = obs.iter().map(|o| o.bandwidth_bps).fold(f64::INFINITY, f64::min);
        let hi = obs.iter().map(|o| o.bandwidth_bps).fold(0.0, f64::max);
        obs.iter()
            .map(|o| {
                let t = log_position(o.bandwidth_bps, lo, hi);
                let p = self.p_min + t * (self.p_max - self.p_min);
                let span = f64::from(self.beta_max) - f64::from(self.beta_min);
                let beta = (f64::from(self.beta_min) + t * span).round() as u8;
                PipelineSpec::qrr(p, beta)
            })
            .collect()
    }

    fn label(&self) -> String {
        ControllerConfig::LinkAware {
            p_min: self.p_min,
            p_max: self.p_max,
            beta_min: self.beta_min,
            beta_max: self.beta_max,
        }
        .format()
    }
}

impl CompressionController for Aimd {
    fn plan(&mut self, _round: u64, obs: &[ClientObservation]) -> Vec<PipelineSpec> {
        if self.level.len() < obs.len() {
            self.level.resize(obs.len(), 1.0);
        }
        obs.iter()
            .enumerate()
            .map(|(i, o)| {
                let over_target = o.net_time.as_secs_f64() * 1e3 > self.target_ms;
                let level = &mut self.level[i];
                match o.outcome {
                    Outcome::Idle => {}
                    Outcome::Delivered if !over_target => {
                        *level = (*level + self.grow).min(1.0);
                    }
                    // late, lost, or delivered only by overrunning the
                    // transmit-time target: this client is a straggler
                    _ => *level *= self.cut,
                }
                let p = self.p_min + *level * (self.p_max - self.p_min);
                PipelineSpec::qrr(p.clamp(self.p_min, self.p_max), self.beta)
            })
            .collect()
    }

    fn label(&self) -> String {
        ControllerConfig::Aimd {
            target_ms: self.target_ms,
            p_min: self.p_min,
            p_max: self.p_max,
            beta: self.beta,
            cut: self.cut,
            grow: self.grow,
        }
        .format()
    }
}

// qrr-audit: end

// ------------------------------------------------------------ grammar

/// Split `name` or `name(k=v,…)` into the name and its argument pairs.
fn split_call(s: &str) -> Result<(&str, Vec<(&str, &str)>)> {
    let s = s.trim();
    ensure!(!s.is_empty(), "empty controller spec");
    let (name, body) = match s.find('(') {
        None => (s, None),
        Some(open) => {
            ensure!(s.ends_with(')'), "unbalanced parens in controller spec {s:?}");
            (s[..open].trim(), Some(&s[open + 1..s.len() - 1]))
        }
    };
    ensure!(
        !name.is_empty() && name.chars().all(|c| c.is_ascii_lowercase() || c == '_'),
        "bad controller policy name {name:?}"
    );
    let mut args = Vec::new();
    if let Some(body) = body {
        for kv in body.split(',') {
            let kv = kv.trim();
            ensure!(!kv.is_empty(), "empty argument in controller spec {s:?}");
            let Some((k, v)) = kv.split_once('=') else {
                bail!("controller argument {kv:?} is not key=value");
            };
            args.push((k.trim(), v.trim()));
        }
    }
    Ok((name, args))
}

/// Tracks which arguments a policy consumed so leftovers are rejected.
struct ArgMap<'a> {
    args: Vec<(&'a str, &'a str)>,
    used: Vec<bool>,
}

impl<'a> ArgMap<'a> {
    fn new(args: Vec<(&'a str, &'a str)>) -> Self {
        let used = vec![false; args.len()];
        ArgMap { args, used }
    }

    fn take(&mut self, key: &str) -> Result<Option<&'a str>> {
        let mut found = None;
        for (i, (k, v)) in self.args.iter().enumerate() {
            if *k == key {
                ensure!(found.is_none(), "duplicate controller argument {key:?}");
                self.used[i] = true;
                found = Some(*v);
            }
        }
        Ok(found)
    }

    fn float(&mut self, key: &str, default: f64) -> Result<f64> {
        match self.take(key)? {
            None => Ok(default),
            Some(v) => {
                v.parse::<f64>().map_err(|_| anyhow::anyhow!("bad {key} value {v:?} (number)"))
            }
        }
    }

    fn bits(&mut self, key: &str, default: u8) -> Result<u8> {
        match self.take(key)? {
            None => Ok(default),
            Some(v) => {
                v.parse::<u8>().map_err(|_| anyhow::anyhow!("bad {key} value {v:?} (integer)"))
            }
        }
    }

    fn finish(self, policy: &str) -> Result<()> {
        for (i, (k, _)) in self.args.iter().enumerate() {
            ensure!(self.used[i], "unknown argument {k:?} for controller policy {policy:?}");
        }
        Ok(())
    }
}

// -------------------------------------------------------------- tests

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(client: u32, bw: f64, outcome: Outcome, net_ms: u64) -> ClientObservation {
        ClientObservation {
            client,
            bandwidth_bps: bw,
            up_bits: 1_000,
            net_time: Duration::from_millis(net_ms),
            deadline: Duration::from_millis(250),
            outcome,
        }
    }

    #[test]
    fn every_shipped_policy_round_trips_through_parse_and_format() {
        for info in policies() {
            // the canonical default spec round-trips
            let cfg = ControllerConfig::parse(&info.spec).unwrap();
            assert_eq!(cfg.format(), info.spec, "{} registry spec not canonical", info.name);
            // and so does the bare name
            let bare = ControllerConfig::parse(info.name).unwrap();
            assert_eq!(bare, cfg, "{}: bare name != default spec", info.name);
        }
        // non-default arguments survive the trip too
        for s in [
            "fixed(p=0.12,beta=6)",
            "linkaware(p_min=0.02,p_max=0.4,beta_min=2,beta_max=12)",
            "aimd(target_ms=80,p_min=0.01,p_max=0.5,beta=6,cut=0.25,grow=0.1)",
        ] {
            let cfg = ControllerConfig::parse(s).unwrap();
            assert_eq!(ControllerConfig::parse(&cfg.format()).unwrap(), cfg, "{s}");
        }
    }

    #[test]
    fn parse_rejects_unknown_policies_args_and_ranges() {
        assert!(ControllerConfig::parse("pid").is_err());
        assert!(ControllerConfig::parse("").is_err());
        assert!(ControllerConfig::parse("fixed(q=0.3)").is_err(), "unknown key");
        assert!(ControllerConfig::parse("fixed(p=0.3,p=0.2)").is_err(), "duplicate key");
        assert!(ControllerConfig::parse("fixed(p=0.3").is_err(), "unbalanced parens");
        assert!(ControllerConfig::parse("fixed(p)").is_err(), "missing value");
        assert!(ControllerConfig::parse("fixed(p=0)").is_err(), "p out of range");
        assert!(ControllerConfig::parse("fixed(beta=32)").is_err(), "beta out of range");
        assert!(ControllerConfig::parse("linkaware(p_min=0.4,p_max=0.1)").is_err());
        assert!(ControllerConfig::parse("aimd(cut=1.5)").is_err());
        assert!(ControllerConfig::parse("aimd(target_ms=0)").is_err());
    }

    #[test]
    fn fixed_assigns_the_same_spec_to_every_client() {
        let mut c = ControllerConfig::parse("fixed(p=0.2,beta=8)").unwrap().build();
        let cohort =
            vec![obs(0, 250e3, Outcome::Delivered, 900), obs(1, 10e6, Outcome::TimedOut, 20)];
        let specs = c.plan(1, &cohort);
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0], PipelineSpec::qrr(0.2, 8));
        assert_eq!(specs[0], specs[1]);
        assert!(c.plan_downlink(1, &cohort).is_none());
    }

    #[test]
    fn linkaware_orders_p_by_bandwidth_and_pins_the_extremes() {
        let mut c = ControllerConfig::linkaware().build();
        let cohort = vec![
            obs(0, 250e3, Outcome::Idle, 0),
            obs(1, 1.5e6, Outcome::Idle, 0),
            obs(2, 10e6, Outcome::Idle, 0),
        ];
        let specs = c.plan(0, &cohort);
        let ps: Vec<f64> = specs.iter().map(|s| s.knobs().0).collect();
        assert!(ps[0] < ps[1] && ps[1] < ps[2], "p not monotone in bandwidth: {ps:?}");
        assert!((ps[0] - 0.05).abs() < 1e-12, "slowest link must get p_min");
        assert!((ps[2] - 0.3).abs() < 1e-12, "fastest link must get p_max");
        assert_eq!(specs[0].knobs().1, 4);
        assert_eq!(specs[2].knobs().1, 8);
    }

    #[test]
    fn linkaware_uniform_cohort_takes_the_midpoint_not_nan() {
        let mut c = ControllerConfig::linkaware().build();
        let cohort = vec![obs(0, 1e6, Outcome::Idle, 0), obs(1, 1e6, Outcome::Idle, 0)];
        for spec in c.plan(0, &cohort) {
            let (p, beta) = spec.knobs();
            assert!(p.is_finite(), "uniform cohort produced non-finite p");
            assert!((p - 0.175).abs() < 1e-12, "expected midpoint p, got {p}");
            assert_eq!(beta, 6);
        }
    }

    #[test]
    fn aimd_cuts_stragglers_and_recovers_on_time_delivery() {
        let mut c = ControllerConfig::parse("aimd(target_ms=250,cut=0.5,grow=0.05)")
            .unwrap()
            .build();
        // round 1: client 0 overran the target, client 1 was on time
        let specs = c.plan(
            1,
            &[obs(0, 250e3, Outcome::Delivered, 900), obs(1, 10e6, Outcome::Delivered, 20)],
        );
        let slow_p = specs[0].knobs().0;
        let fast_p = specs[1].knobs().0;
        assert!(slow_p < fast_p, "straggler not cut: {slow_p} vs {fast_p}");
        assert!((fast_p - 0.3).abs() < 1e-12, "on-time client must stay at p_max");
        // an explicit timeout cuts again
        let specs = c.plan(
            2,
            &[obs(0, 250e3, Outcome::TimedOut, 900), obs(1, 10e6, Outcome::Delivered, 20)],
        );
        assert!(specs[0].knobs().0 < slow_p, "timeout did not cut further");
        // sustained on-time delivery recovers additively, never past p_max
        let mut last = specs[0].knobs().0;
        for round in 3..40 {
            let specs = c.plan(
                round,
                &[obs(0, 250e3, Outcome::Delivered, 10), obs(1, 10e6, Outcome::Delivered, 10)],
            );
            let p = specs[0].knobs().0;
            assert!(p >= last && p <= 0.3 + 1e-12, "recovery not monotone: {last} -> {p}");
            last = p;
        }
        assert!((last - 0.3).abs() < 1e-9, "recovery never reached p_max: {last}");
    }

    #[test]
    fn aimd_budget_is_floored_at_p_min() {
        let mut c = ControllerConfig::parse("aimd(p_min=0.1,p_max=0.3,cut=0.01)")
            .unwrap()
            .build();
        let mut specs = Vec::new();
        for round in 0..8 {
            specs = c.plan(round, &[obs(0, 250e3, Outcome::TimedOut, 900)]);
        }
        let (p, _) = specs[0].knobs();
        assert!(p >= 0.1 - 1e-12, "p fell through the floor: {p}");
        assert!(PipelineSpec::qrr(p, 8).validate().is_ok());
    }

    #[test]
    fn decisions_are_pure_functions_of_the_observation_sequence() {
        // two independently built controllers fed the identical
        // observation stream must emit identical spec sequences
        for cfg in [ControllerConfig::linkaware(), ControllerConfig::aimd()] {
            let (mut a, mut b) = (cfg.build(), cfg.build());
            for round in 0..12 {
                let cohort = vec![
                    obs(0, 250e3, if round % 3 == 0 { Outcome::TimedOut } else { Outcome::Delivered }, 700),
                    obs(1, 2e6, Outcome::Delivered, 120),
                    obs(2, 10e6, if round % 5 == 0 { Outcome::Dropped } else { Outcome::Delivered }, 15),
                ];
                assert_eq!(a.plan(round, &cohort), b.plan(round, &cohort), "round {round}");
            }
        }
    }

    #[test]
    fn outcome_codes_are_distinct() {
        let all = [
            Outcome::Idle,
            Outcome::Delivered,
            Outcome::Late,
            Outcome::TimedOut,
            Outcome::Dropped,
            Outcome::Corrupt,
        ];
        let codes: Vec<char> = all.iter().map(|o| o.code()).collect();
        let mut dedup = codes.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), codes.len());
        assert!(Outcome::TimedOut.is_loss() && !Outcome::Late.is_loss());
    }

    #[test]
    fn slack_is_signed() {
        assert!(obs(0, 1e6, Outcome::Delivered, 20).slack() > 0.0);
        assert!(obs(0, 1e6, Outcome::Delivered, 900).slack() < 0.0);
    }
}
