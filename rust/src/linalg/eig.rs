//! Symmetric eigendecomposition via cyclic Jacobi rotations — used to
//! factorize the small Gram matrices of the randomized SVD path
//! (EXPERIMENTS.md §Perf: replaces one-sided Jacobi on l×n with an l×l
//! eigenproblem, an ~8× win on the QRR encode hot path).

use crate::tensor::Tensor;

/// Eigendecomposition of a symmetric matrix: returns (eigenvalues,
/// eigenvectors) with eigenvalues descending and eigenvectors in the
/// corresponding columns.
pub fn sym_eig_jacobi(a: &Tensor) -> (Vec<f32>, Tensor) {
    assert_eq!(a.ndim(), 2, "eig expects a matrix");
    let n = a.shape()[0];
    assert_eq!(a.shape()[1], n, "eig expects a square matrix");

    // Work in f64 for stability of the small problem.
    let mut m: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    let mut v = vec![0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    let max_sweeps = 30;
    for _sweep in 0..max_sweeps {
        // off-diagonal Frobenius mass
        let mut off = 0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                off += m[p * n + q] * m[p * n + q];
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // rows/cols p and q of M
                for i in 0..n {
                    let mip = m[i * n + p];
                    let miq = m[i * n + q];
                    m[i * n + p] = c * mip - s * miq;
                    m[i * n + q] = s * mip + c * miq;
                }
                for j in 0..n {
                    let mpj = m[p * n + j];
                    let mqj = m[q * n + j];
                    m[p * n + j] = c * mpj - s * mqj;
                    m[q * n + j] = s * mpj + c * mqj;
                }
                // accumulate eigenvectors
                for i in 0..n {
                    let vip = v[i * n + p];
                    let viq = v[i * n + q];
                    v[i * n + p] = c * vip - s * viq;
                    v[i * n + q] = s * vip + c * viq;
                }
            }
        }
    }

    // extract + sort descending
    let mut order: Vec<usize> = (0..n).collect();
    let evals: Vec<f64> = (0..n).map(|i| m[i * n + i]).collect();
    order.sort_by(|&i, &j| evals[j].total_cmp(&evals[i])); // NaN-safe
    let mut out_vals = Vec::with_capacity(n);
    let mut out_vecs = Tensor::zeros(&[n, n]);
    for (new_j, &old_j) in order.iter().enumerate() {
        out_vals.push(evals[old_j] as f32);
        for i in 0..n {
            out_vecs.set2(i, new_j, v[i * n + old_j] as f32);
        }
    }
    (out_vals, out_vecs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_nt, matmul_tn};
    use crate::util::Rng;

    fn random_symmetric(n: usize, rng: &mut Rng) -> Tensor {
        let a = Tensor::randn(&[n, n], rng);
        let at = a.transpose();
        crate::tensor::zip(&a, &at, |x, y| 0.5 * (x + y))
    }

    #[test]
    fn reconstructs_symmetric_matrix() {
        let mut rng = Rng::new(200);
        for n in [1usize, 2, 5, 16, 40] {
            let a = random_symmetric(n, &mut rng);
            let (vals, vecs) = sym_eig_jacobi(&a);
            // A = V diag(vals) Vt
            let mut vd = vecs.clone();
            for i in 0..n {
                for j in 0..n {
                    let x = vd.get2(i, j) * vals[j];
                    vd.set2(i, j, x);
                }
            }
            let rec = matmul_nt(&vd, &vecs.transpose().transpose());
            // matmul_nt(vd, vecs) computes vd * vecs^T directly:
            let rec = if true { matmul_nt(&vd, &vecs) } else { rec };
            assert!(a.rel_err(&rec) < 1e-4, "n={n} err {}", a.rel_err(&rec));
            // orthonormal eigenvectors
            let vtv = matmul_tn(&vecs, &vecs);
            assert!(vtv.rel_err(&Tensor::eye(n)) < 1e-4, "n={n}");
            // descending
            for w in vals.windows(2) {
                assert!(w[0] >= w[1] - 1e-5);
            }
        }
    }

    #[test]
    fn psd_gram_has_nonnegative_eigenvalues() {
        let mut rng = Rng::new(201);
        let b = Tensor::randn(&[12, 30], &mut rng);
        let g = matmul_nt(&b, &b); // B Bt, PSD
        let (vals, _) = sym_eig_jacobi(&g);
        for &l in &vals {
            assert!(l > -1e-3, "negative eigenvalue {l}");
        }
    }

    #[test]
    fn diagonal_matrix_is_trivial() {
        let mut d = Tensor::zeros(&[3, 3]);
        d.set2(0, 0, 1.0);
        d.set2(1, 1, 5.0);
        d.set2(2, 2, 3.0);
        let (vals, vecs) = sym_eig_jacobi(&d);
        assert_eq!(vals, vec![5.0, 3.0, 1.0]);
        // eigenvectors are signed unit vectors
        let i = matmul(&vecs, &vecs.transpose());
        assert!(i.rel_err(&Tensor::eye(3)) < 1e-6);
    }
}
