//! Thin (economy) QR decomposition via blocked Householder reflections.
//!
//! Used by the randomized SVD range-finder: Q is an orthonormal basis of
//! the sketch Y = AΩ. For m ≥ n, returns Q (m×n) with orthonormal
//! columns and upper-triangular R (n×n) with A = QR.
//!
//! [`qr_thin`] is blocked (compact-WY, DESIGN.md §6): each NB-column
//! panel is factored with scalar reflections, the block reflector
//! H₁…H_nb = I − V·T·Vᵀ is accumulated into a small upper-triangular T,
//! and the trailing-matrix update and the thin-Q build are applied as
//! pairs of GEMMs through the packed kernel — turning the inner loop of
//! randomized SVD's power iteration into level-3 BLAS.
//! [`qr_thin_unblocked`] keeps the scalar per-reflector path as the
//! parity oracle and micro-benchmark reference.

use super::matmul::{gemm_strided, MatRef};
use crate::tensor::Tensor;

/// Panel width of the blocked factorization.
const NB: usize = 32;

/// Result of a thin QR factorization.
#[derive(Debug, Clone)]
pub struct QrThin {
    /// m×n with orthonormal columns.
    pub q: Tensor,
    /// n×n upper triangular.
    pub r: Tensor,
}

/// Thin QR of an m×n matrix with m ≥ n — blocked Householder
/// (compact WY). Same reflector sign convention as
/// [`qr_thin_unblocked`], so the factors of the two paths agree to
/// floating-point reordering.
pub fn qr_thin(a: &Tensor) -> QrThin {
    assert_eq!(a.ndim(), 2, "qr expects a matrix");
    let (m, n) = (a.shape()[0], a.shape()[1]);
    assert!(m >= n, "qr_thin requires m >= n (got {m}x{n})");

    let mut r = a.data().to_vec();
    // Per panel: (k0, nb, V, T). V is (m−k0)×nb row-major with the unit
    // diagonal stored explicitly (zeros above it); T is nb×nb upper
    // triangular with H₁…H_nb = I − V·T·Vᵀ.
    let mut panel_store: Vec<(usize, usize, Vec<f32>, Vec<f32>)> = Vec::new();

    let mut k0 = 0usize;
    while k0 < n {
        let nb = NB.min(n - k0);
        let rows = m - k0;
        let mut v = vec![0f32; rows * nb];
        let mut tau = vec![0f32; nb];

        // Factor the panel with scalar reflections, updating only the
        // panel's own columns.
        for j in 0..nb {
            let col = k0 + j;
            let xlen = rows - j;
            let mut norm2 = 0f64;
            for i in 0..xlen {
                let t = r[(k0 + j + i) * n + col] as f64;
                norm2 += t * t;
            }
            if norm2 == 0.0 {
                continue; // tau stays 0: H_j = I
            }
            let norm = norm2.sqrt();
            let x0 = r[(k0 + j) * n + col] as f64;
            let alpha = if x0 >= 0.0 { -norm } else { norm };
            let v0 = x0 - alpha;
            // ‖v‖² = (x0 − α)² + Σ_{i>0} xᵢ², with v the unnormalized
            // reflector; normalizing to a unit diagonal (u = v/v0)
            // rescales β = 2/‖v‖² into τ = β·v0².
            let vnorm2 = norm2 - 2.0 * x0 * alpha + alpha * alpha;
            tau[j] = (2.0 * v0 * v0 / vnorm2) as f32;
            v[j * nb + j] = 1.0;
            for i in 1..xlen {
                v[(j + i) * nb + j] = (r[(k0 + j + i) * n + col] as f64 / v0) as f32;
            }
            // Apply H_j = I − τ·u·uᵀ to the panel columns j..nb (the
            // pivot column itself collapses to α·e₁).
            for jj in j..nb {
                let cc = k0 + jj;
                let mut dot = 0f64;
                for i in 0..xlen {
                    dot += v[(j + i) * nb + j] as f64 * r[(k0 + j + i) * n + cc] as f64;
                }
                let s = tau[j] as f64 * dot;
                for i in 0..xlen {
                    r[(k0 + j + i) * n + cc] -= (s * v[(j + i) * nb + j] as f64) as f32;
                }
            }
        }

        // Accumulate T: T[j][j] = τ_j, T[0..j][j] = −τ_j·T[0..j][0..j]·w
        // with w = V(:, 0..j)ᵀ·v_j.
        let mut t = vec![0f32; nb * nb];
        for j in 0..nb {
            t[j * nb + j] = tau[j];
            if j == 0 || tau[j] == 0.0 {
                continue;
            }
            let mut w = vec![0f64; j];
            for i in j..rows {
                let vij = v[i * nb + j] as f64;
                for (l, wl) in w.iter_mut().enumerate() {
                    *wl += v[i * nb + l] as f64 * vij;
                }
            }
            for row in 0..j {
                let mut s = 0f64;
                for (l, &wl) in w.iter().enumerate().skip(row) {
                    s += t[row * nb + l] as f64 * wl;
                }
                t[row * nb + j] = (-(tau[j] as f64) * s) as f32;
            }
        }

        // Trailing update: A[k0.., k0+nb..] −= V·(Tᵀ·(Vᵀ·A)) — two big
        // GEMMs around a small one, all through the packed kernel.
        let ntrail = n - (k0 + nb);
        if ntrail > 0 {
            let off = k0 * n + k0 + nb;
            let mut w = vec![0f32; nb * ntrail];
            gemm_strided(
                nb,
                rows,
                ntrail,
                MatRef::transposed(&v, nb),
                MatRef::strided(&r[off..], n, 1),
                &mut w,
                ntrail,
                1.0,
            );
            let mut w2 = vec![0f32; nb * ntrail];
            gemm_strided(
                nb,
                nb,
                ntrail,
                MatRef::transposed(&t, nb),
                MatRef::dense(&w, ntrail),
                &mut w2,
                ntrail,
                1.0,
            );
            gemm_strided(
                rows,
                nb,
                ntrail,
                MatRef::dense(&v, nb),
                MatRef::dense(&w2, ntrail),
                &mut r[off..],
                n,
                -1.0,
            );
        }
        panel_store.push((k0, nb, v, t));
        k0 += nb;
    }

    // Zero the strictly-lower part of R and truncate to n×n.
    let mut r_out = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in i..n {
            r_out.set2(i, j, r[i * n + j]);
        }
    }

    // Thin Q: apply the block reflectors to the first n columns of I,
    // innermost panel first — Q ← (I − V·T·Vᵀ)·Q per panel in reverse.
    let mut q = vec![0f32; m * n];
    for j in 0..n {
        q[j * n + j] = 1.0;
    }
    for (k0, nb, v, t) in panel_store.iter().rev() {
        let (k0, nb) = (*k0, *nb);
        let rows = m - k0;
        let off = k0 * n;
        let mut w = vec![0f32; nb * n];
        gemm_strided(
            nb,
            rows,
            n,
            MatRef::transposed(v, nb),
            MatRef::strided(&q[off..], n, 1),
            &mut w,
            n,
            1.0,
        );
        let mut w2 = vec![0f32; nb * n];
        gemm_strided(
            nb,
            nb,
            n,
            MatRef::dense(t, nb),
            MatRef::dense(&w, n),
            &mut w2,
            n,
            1.0,
        );
        gemm_strided(
            rows,
            nb,
            n,
            MatRef::dense(v, nb),
            MatRef::dense(&w2, n),
            &mut q[off..],
            n,
            -1.0,
        );
    }
    QrThin { q: Tensor::matrix(m, n, q), r: r_out }
}

/// Thin QR via scalar per-reflector Householder updates — the reference
/// path the blocked factorization is checked against (and the
/// `qr/thin_unblocked_*` benchmark baseline).
pub fn qr_thin_unblocked(a: &Tensor) -> QrThin {
    assert_eq!(a.ndim(), 2, "qr expects a matrix");
    let (m, n) = (a.shape()[0], a.shape()[1]);
    assert!(m >= n, "qr_thin requires m >= n (got {m}x{n})");

    // Work on a mutable copy of A; accumulate Householder vectors in-place
    // below the diagonal, R above.
    let mut r = a.data().to_vec();
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(n); // householder vectors
    let mut betas = Vec::with_capacity(n);

    for k in 0..n {
        // column k, rows k..m
        let mut x = vec![0f32; m - k];
        for i in k..m {
            x[i - k] = r[i * n + k];
        }
        let norm_x = x.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt() as f32;
        if norm_x == 0.0 {
            vs.push(vec![0.0; m - k]);
            betas.push(0.0);
            continue;
        }
        let alpha = if x[0] >= 0.0 { -norm_x } else { norm_x };
        let mut v = x;
        v[0] -= alpha;
        let vnorm2 = v.iter().map(|t| (*t as f64).powi(2)).sum::<f64>() as f32;
        let beta = if vnorm2 == 0.0 { 0.0 } else { 2.0 / vnorm2 };

        // Apply H = I - beta v vᵀ to R[k.., k..]
        if beta != 0.0 {
            for j in k..n {
                let mut dot = 0f64;
                for i in k..m {
                    dot += v[i - k] as f64 * r[i * n + j] as f64;
                }
                let s = (beta as f64 * dot) as f32;
                for i in k..m {
                    r[i * n + j] -= s * v[i - k];
                }
            }
        }
        vs.push(v);
        betas.push(beta);
    }

    // Build thin Q by applying reflections to the first n columns of I.
    let mut q = vec![0f32; m * n];
    for j in 0..n {
        q[j * n + j] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let beta = betas[k];
        if beta == 0.0 {
            continue;
        }
        for j in 0..n {
            let mut dot = 0f64;
            for i in k..m {
                dot += v[i - k] as f64 * q[i * n + j] as f64;
            }
            let s = (beta as f64 * dot) as f32;
            for i in k..m {
                q[i * n + j] -= s * v[i - k];
            }
        }
    }

    // Zero the strictly-lower part of R and truncate to n×n.
    let mut r_out = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in i..n {
            r_out.set2(i, j, r[i * n + j]);
        }
    }
    QrThin { q: Tensor::matrix(m, n, q), r: r_out }
}

/// Orthonormal basis of the columns of `y` via **CholeskyQR2** — the
/// GEMM-dominant orthonormalization used on the randomized-SVD hot path
/// (EXPERIMENTS.md §Perf: ~6× faster than Householder at 784×68, and the
/// formulation that maps to the MXU). Falls back to (blocked)
/// Householder when the Gram matrix is numerically rank-deficient.
pub fn orthonormalize(y: &Tensor) -> Tensor {
    match chol_qr(y).and_then(|q1| chol_qr(&q1)) {
        Some(q) => q,
        None => qr_thin(y).q,
    }
}

/// One CholeskyQR pass: Q = Y · R⁻¹ with R = chol(YᵀY)ᵀ. None if the
/// Cholesky breaks down (rank deficiency / conditioning).
fn chol_qr(y: &Tensor) -> Option<Tensor> {
    let (m, n) = (y.shape()[0], y.shape()[1]);
    let gram = super::matmul::matmul_tn(y, y); // n×n
    // Cholesky in f64: gram = L Lᵀ
    let mut l = vec![0f64; n * n];
    let g = gram.data();
    for j in 0..n {
        let mut d = g[j * n + j] as f64;
        for k in 0..j {
            d -= l[j * n + k] * l[j * n + k];
        }
        if d <= 1e-20 {
            return None;
        }
        let dj = d.sqrt();
        l[j * n + j] = dj;
        for i in (j + 1)..n {
            let mut s = g[i * n + j] as f64;
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            l[i * n + j] = s / dj;
        }
    }
    // Q rows: solve q_r · Lᵀ = y_r  (forward substitution, contiguous rows)
    let mut q = Tensor::zeros(&[m, n]);
    let yd = y.data();
    let qd = q.data_mut();
    for r in 0..m {
        let yrow = &yd[r * n..(r + 1) * n];
        let qrow = &mut qd[r * n..(r + 1) * n];
        for j in 0..n {
            let mut s = yrow[j] as f64;
            for i in 0..j {
                s -= qrow[i] as f64 * l[j * n + i];
            }
            qrow[j] = (s / l[j * n + j]) as f32;
        }
    }
    Some(q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_tn};
    use crate::util::Rng;

    fn check_qr(a: &Tensor, tol: f32) {
        let QrThin { q, r } = qr_thin(a);
        let (m, n) = (a.shape()[0], a.shape()[1]);
        assert_eq!(q.shape(), &[m, n]);
        assert_eq!(r.shape(), &[n, n]);
        // A = QR
        let qr = matmul(&q, &r);
        assert!(a.rel_err(&qr) < tol, "reconstruction err {}", a.rel_err(&qr));
        // QᵀQ = I
        let qtq = matmul_tn(&q, &q);
        let eye = Tensor::eye(n);
        assert!(qtq.rel_err(&eye) < tol, "orthonormality err {}", qtq.rel_err(&eye));
        // R upper triangular
        for i in 0..n {
            for j in 0..i {
                assert_eq!(r.get2(i, j), 0.0);
            }
        }
    }

    #[test]
    fn qr_random_shapes() {
        let mut rng = Rng::new(10);
        for &(m, n) in &[(4, 4), (10, 3), (50, 20), (128, 16), (7, 1)] {
            let a = Tensor::randn(&[m, n], &mut rng);
            check_qr(&a, 1e-4);
        }
    }

    #[test]
    fn qr_multi_panel_shapes() {
        // widths past NB exercise the T accumulation and the blocked
        // trailing/Q updates across several panels
        let mut rng = Rng::new(14);
        for &(m, n) in &[(90, 70), (100, 64), (65, 33), (40, 40)] {
            let a = Tensor::randn(&[m, n], &mut rng);
            check_qr(&a, 1e-3);
        }
    }

    #[test]
    fn blocked_matches_unblocked_factors() {
        // same sign convention ⇒ the factors agree directly, not just
        // up to column signs
        let mut rng = Rng::new(15);
        for &(m, n) in &[(4, 4), (10, 3), (50, 20), (90, 70), (64, 33), (7, 1)] {
            let a = Tensor::randn(&[m, n], &mut rng);
            let blk = qr_thin(&a);
            let scl = qr_thin_unblocked(&a);
            assert!(blk.r.rel_err(&scl.r) < 1e-3, "{m}x{n} R err {}", blk.r.rel_err(&scl.r));
            assert!(blk.q.rel_err(&scl.q) < 1e-3, "{m}x{n} Q err {}", blk.q.rel_err(&scl.q));
        }
    }

    #[test]
    fn unblocked_reference_invariants() {
        let mut rng = Rng::new(16);
        for &(m, n) in &[(10, 3), (50, 20), (64, 33)] {
            let a = Tensor::randn(&[m, n], &mut rng);
            let QrThin { q, r } = qr_thin_unblocked(&a);
            let qr = matmul(&q, &r);
            assert!(a.rel_err(&qr) < 1e-4);
            let qtq = matmul_tn(&q, &q);
            assert!(qtq.rel_err(&Tensor::eye(n)) < 1e-4);
        }
    }

    #[test]
    fn qr_rank_deficient() {
        // two identical columns
        let mut rng = Rng::new(11);
        let col = Tensor::randn(&[6, 1], &mut rng);
        let mut data = Vec::new();
        for i in 0..6 {
            data.push(col.data()[i]);
            data.push(col.data()[i]);
        }
        let a = Tensor::matrix(6, 2, data);
        let QrThin { q, r } = qr_thin(&a);
        let qr = matmul(&q, &r);
        assert!(a.rel_err(&qr) < 1e-4);
    }

    #[test]
    fn qr_zero_matrix() {
        let a = Tensor::zeros(&[5, 3]);
        let QrThin { q, r } = qr_thin(&a);
        assert_eq!(q.shape(), &[5, 3]);
        assert!(r.fro_norm() < 1e-12);
    }

    #[test]
    fn orthonormalize_matches_householder_span() {
        let mut rng = Rng::new(12);
        for &(m, n) in &[(784usize, 68usize), (200, 68), (50, 50), (10, 1)] {
            let y = Tensor::randn(&[m, n], &mut rng);
            let q = orthonormalize(&y);
            assert_eq!(q.shape(), &[m, n]);
            let qtq = matmul_tn(&q, &q);
            assert!(
                qtq.rel_err(&Tensor::eye(n)) < 1e-4,
                "{m}x{n} orthonormality err {}",
                qtq.rel_err(&Tensor::eye(n))
            );
            // same column span: Q Qt y == y
            let proj = matmul(&q, &matmul_tn(&q, &y));
            assert!(y.rel_err(&proj) < 1e-3, "{m}x{n} span err {}", y.rel_err(&proj));
        }
    }

    #[test]
    fn orthonormalize_rank_deficient_falls_back() {
        // two identical columns: cholesky breaks, householder handles it
        let mut rng = Rng::new(13);
        let col = Tensor::randn(&[20, 1], &mut rng);
        let mut data = Vec::new();
        for i in 0..20 {
            data.push(col.data()[i]);
            data.push(col.data()[i]);
        }
        let y = Tensor::matrix(20, 2, data);
        let q = orthonormalize(&y);
        assert_eq!(q.shape(), &[20, 2]);
        // first column is a unit vector spanning col
        let proj = matmul(&q, &matmul_tn(&q, &col));
        assert!(col.rel_err(&proj) < 1e-3);
    }

    #[test]
    #[should_panic]
    fn qr_wide_panics() {
        let a = Tensor::zeros(&[2, 5]);
        let _ = qr_thin(&a);
    }
}
