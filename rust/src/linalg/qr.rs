//! Thin (economy) QR decomposition via Householder reflections.
//!
//! Used by the randomized SVD range-finder: Q is an orthonormal basis of
//! the sketch Y = AΩ. For m ≥ n, returns Q (m×n) with orthonormal
//! columns and upper-triangular R (n×n) with A = QR.

use crate::tensor::Tensor;

/// Result of a thin QR factorization.
#[derive(Debug, Clone)]
pub struct QrThin {
    /// m×n with orthonormal columns.
    pub q: Tensor,
    /// n×n upper triangular.
    pub r: Tensor,
}

/// Thin QR of an m×n matrix with m ≥ n (Householder).
pub fn qr_thin(a: &Tensor) -> QrThin {
    assert_eq!(a.ndim(), 2, "qr expects a matrix");
    let (m, n) = (a.shape()[0], a.shape()[1]);
    assert!(m >= n, "qr_thin requires m >= n (got {m}x{n})");

    // Work on a mutable copy of A; accumulate Householder vectors in-place
    // below the diagonal, R above.
    let mut r = a.data().to_vec();
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(n); // householder vectors
    let mut betas = Vec::with_capacity(n);

    for k in 0..n {
        // column k, rows k..m
        let mut x = vec![0f32; m - k];
        for i in k..m {
            x[i - k] = r[i * n + k];
        }
        let norm_x = x.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt() as f32;
        if norm_x == 0.0 {
            vs.push(vec![0.0; m - k]);
            betas.push(0.0);
            continue;
        }
        let alpha = if x[0] >= 0.0 { -norm_x } else { norm_x };
        let mut v = x;
        v[0] -= alpha;
        let vnorm2 = v.iter().map(|t| (*t as f64).powi(2)).sum::<f64>() as f32;
        let beta = if vnorm2 == 0.0 { 0.0 } else { 2.0 / vnorm2 };

        // Apply H = I - beta v vᵀ to R[k.., k..]
        if beta != 0.0 {
            for j in k..n {
                let mut dot = 0f64;
                for i in k..m {
                    dot += v[i - k] as f64 * r[i * n + j] as f64;
                }
                let s = (beta as f64 * dot) as f32;
                for i in k..m {
                    r[i * n + j] -= s * v[i - k];
                }
            }
        }
        vs.push(v);
        betas.push(beta);
    }

    // Build thin Q by applying reflections to the first n columns of I.
    let mut q = vec![0f32; m * n];
    for j in 0..n {
        q[j * n + j] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let beta = betas[k];
        if beta == 0.0 {
            continue;
        }
        for j in 0..n {
            let mut dot = 0f64;
            for i in k..m {
                dot += v[i - k] as f64 * q[i * n + j] as f64;
            }
            let s = (beta as f64 * dot) as f32;
            for i in k..m {
                q[i * n + j] -= s * v[i - k];
            }
        }
    }

    // Zero the strictly-lower part of R and truncate to n×n.
    let mut r_out = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in i..n {
            r_out.set2(i, j, r[i * n + j]);
        }
    }
    QrThin { q: Tensor::matrix(m, n, q), r: r_out }
}

/// Orthonormal basis of the columns of `y` via **CholeskyQR2** — the
/// GEMM-dominant orthonormalization used on the randomized-SVD hot path
/// (EXPERIMENTS.md §Perf: ~6× faster than Householder at 784×68, and the
/// formulation that maps to the MXU). Falls back to Householder when the
/// Gram matrix is numerically rank-deficient.
pub fn orthonormalize(y: &Tensor) -> Tensor {
    match chol_qr(y).and_then(|q1| chol_qr(&q1)) {
        Some(q) => q,
        None => qr_thin(y).q,
    }
}

/// One CholeskyQR pass: Q = Y · R⁻¹ with R = chol(YᵀY)ᵀ. None if the
/// Cholesky breaks down (rank deficiency / conditioning).
fn chol_qr(y: &Tensor) -> Option<Tensor> {
    let (m, n) = (y.shape()[0], y.shape()[1]);
    let gram = super::matmul::matmul_tn(y, y); // n×n
    // Cholesky in f64: gram = L Lᵀ
    let mut l = vec![0f64; n * n];
    let g = gram.data();
    for j in 0..n {
        let mut d = g[j * n + j] as f64;
        for k in 0..j {
            d -= l[j * n + k] * l[j * n + k];
        }
        if d <= 1e-20 {
            return None;
        }
        let dj = d.sqrt();
        l[j * n + j] = dj;
        for i in (j + 1)..n {
            let mut s = g[i * n + j] as f64;
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            l[i * n + j] = s / dj;
        }
    }
    // Q rows: solve q_r · Lᵀ = y_r  (forward substitution, contiguous rows)
    let mut q = Tensor::zeros(&[m, n]);
    let yd = y.data();
    let qd = q.data_mut();
    for r in 0..m {
        let yrow = &yd[r * n..(r + 1) * n];
        let qrow = &mut qd[r * n..(r + 1) * n];
        for j in 0..n {
            let mut s = yrow[j] as f64;
            for i in 0..j {
                s -= qrow[i] as f64 * l[j * n + i];
            }
            qrow[j] = (s / l[j * n + j]) as f32;
        }
    }
    Some(q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_tn};
    use crate::util::Rng;

    fn check_qr(a: &Tensor, tol: f32) {
        let QrThin { q, r } = qr_thin(a);
        let (m, n) = (a.shape()[0], a.shape()[1]);
        assert_eq!(q.shape(), &[m, n]);
        assert_eq!(r.shape(), &[n, n]);
        // A = QR
        let qr = matmul(&q, &r);
        assert!(a.rel_err(&qr) < tol, "reconstruction err {}", a.rel_err(&qr));
        // QᵀQ = I
        let qtq = matmul_tn(&q, &q);
        let eye = Tensor::eye(n);
        assert!(qtq.rel_err(&eye) < tol, "orthonormality err {}", qtq.rel_err(&eye));
        // R upper triangular
        for i in 0..n {
            for j in 0..i {
                assert_eq!(r.get2(i, j), 0.0);
            }
        }
    }

    #[test]
    fn qr_random_shapes() {
        let mut rng = Rng::new(10);
        for &(m, n) in &[(4, 4), (10, 3), (50, 20), (128, 16), (7, 1)] {
            let a = Tensor::randn(&[m, n], &mut rng);
            check_qr(&a, 1e-4);
        }
    }

    #[test]
    fn qr_rank_deficient() {
        // two identical columns
        let mut rng = Rng::new(11);
        let col = Tensor::randn(&[6, 1], &mut rng);
        let mut data = Vec::new();
        for i in 0..6 {
            data.push(col.data()[i]);
            data.push(col.data()[i]);
        }
        let a = Tensor::matrix(6, 2, data);
        let QrThin { q, r } = qr_thin(&a);
        let qr = matmul(&q, &r);
        assert!(a.rel_err(&qr) < 1e-4);
    }

    #[test]
    fn qr_zero_matrix() {
        let a = Tensor::zeros(&[5, 3]);
        let QrThin { q, r } = qr_thin(&a);
        assert_eq!(q.shape(), &[5, 3]);
        assert!(r.fro_norm() < 1e-12);
    }

    #[test]
    fn orthonormalize_matches_householder_span() {
        let mut rng = Rng::new(12);
        for &(m, n) in &[(784usize, 68usize), (200, 68), (50, 50), (10, 1)] {
            let y = Tensor::randn(&[m, n], &mut rng);
            let q = orthonormalize(&y);
            assert_eq!(q.shape(), &[m, n]);
            let qtq = matmul_tn(&q, &q);
            assert!(
                qtq.rel_err(&Tensor::eye(n)) < 1e-4,
                "{m}x{n} orthonormality err {}",
                qtq.rel_err(&Tensor::eye(n))
            );
            // same column span: Q Qt y == y
            let proj = matmul(&q, &matmul_tn(&q, &y));
            assert!(y.rel_err(&proj) < 1e-3, "{m}x{n} span err {}", y.rel_err(&proj));
        }
    }

    #[test]
    fn orthonormalize_rank_deficient_falls_back() {
        // two identical columns: cholesky breaks, householder handles it
        let mut rng = Rng::new(13);
        let col = Tensor::randn(&[20, 1], &mut rng);
        let mut data = Vec::new();
        for i in 0..20 {
            data.push(col.data()[i]);
            data.push(col.data()[i]);
        }
        let y = Tensor::matrix(20, 2, data);
        let q = orthonormalize(&y);
        assert_eq!(q.shape(), &[20, 2]);
        // first column is a unit vector spanning col
        let proj = matmul(&q, &matmul_tn(&q, &col));
        assert!(col.rel_err(&proj) < 1e-3);
    }

    #[test]
    #[should_panic]
    fn qr_wide_panics() {
        let a = Tensor::zeros(&[2, 5]);
        let _ = qr_thin(&a);
    }
}
