//! Packed micro-kernel GEMM on the crate's persistent thread pool.
//!
//! BLIS-style structure: three loops of cache blocking (NC × KC × MC)
//! around an MR×NR register-tiled micro-kernel. Operand panels are
//! packed into contiguous, zero-padded buffers once per cache block, so
//! the inner kernel reads only unit-stride memory; the 8×8 f32 tile is
//! an explicit AVX2+FMA register kernel when the process dispatches at
//! that level (`exec::simd`, DESIGN.md §8) and the auto-vectorized
//! scalar tile otherwise — no data-dependent branches in the hot loop
//! either way. Packing reads through strided [`MatRef`]
//! views, so the transpose variants ([`matmul_tn`], [`matmul_nt`]) pack
//! straight from the strided source instead of materializing a
//! `transpose()` copy, and the blocked QR updates sub-matrices in place
//! through the same entry ([`gemm_strided`]).
//!
//! Pack buffers are thread-local scratch reused across calls. Large
//! products split across the crate-wide shared pool
//! ([`crate::exec::global_pool`]) as a 2-D grid of C row-bands ×
//! N-panels via `ThreadPool::for_each`; called from inside a pool worker
//! the split degrades to serial, so GEMMs nested under the session's
//! per-client fan-out can never oversubscribe the machine
//! (DESIGN.md §6).

use std::cell::RefCell;

use crate::tensor::Tensor;

/// Micro-kernel tile rows: one tile is MR×NR f32 accumulators, small
/// enough for the compiler to keep in SIMD registers.
const MR: usize = 8;
/// Micro-kernel tile columns.
const NR: usize = 8;
/// Rows of A packed per cache block (the L2-resident panel).
const MC: usize = 128;
/// Shared k-depth of the packed A/B blocks.
const KC: usize = 256;
/// Columns of B packed per cache block.
const NC: usize = 512;
/// Products with at least this many MACs split over the shared pool.
const PAR_THRESHOLD: usize = 1 << 20;
/// `matvec`s with at least this many MACs split rows over the pool.
const MATVEC_PAR_THRESHOLD: usize = 1 << 20;

// -------------------------------------------------------------- views

/// Read-only strided matrix view: element (i, j) is
/// `data[i * rs + j * cs]`. One packing routine walks A, Aᵀ, B, Bᵀ and
/// the QR sub-blocks uniformly, without intermediate copies.
#[derive(Clone, Copy)]
pub(crate) struct MatRef<'a> {
    data: &'a [f32],
    /// row stride
    rs: usize,
    /// column stride
    cs: usize,
}

impl<'a> MatRef<'a> {
    /// Row-major view of a dense matrix with `cols` columns.
    pub(crate) fn dense(data: &'a [f32], cols: usize) -> Self {
        MatRef { data, rs: cols, cs: 1 }
    }

    /// Transposed view of a dense matrix stored with `cols` columns:
    /// the logical (i, j) element is `data[j * cols + i]`.
    pub(crate) fn transposed(data: &'a [f32], cols: usize) -> Self {
        MatRef { data, rs: 1, cs: cols }
    }

    /// Arbitrary strides (sub-matrix views, e.g. the QR trailing block).
    pub(crate) fn strided(data: &'a [f32], rs: usize, cs: usize) -> Self {
        MatRef { data, rs, cs }
    }
}

/// Raw output pointer handed to the 2-D tile grid. Each task owns a
/// disjoint row-band × column-panel region of C, and `for_each` joins
/// every task before the owning frame returns.
struct SendPtr(*mut f32);

// SAFETY: the wrapped pointer is only dereferenced inside pool tasks
// that each write a disjoint region of the output, and the owning
// frame outlives every task (for_each joins before returning).
unsafe impl Send for SendPtr {}
// SAFETY: shared references to SendPtr only copy the address; all
// writes through it target task-disjoint regions (see Send above).
unsafe impl Sync for SendPtr {}

// ------------------------------------------------------------ packing

/// Thread-local pack-buffer scratch, reused across GEMM calls so the
/// steady state allocates nothing.
struct PackScratch {
    a: Vec<f32>,
    b: Vec<f32>,
}

thread_local! {
    static SCRATCH: RefCell<PackScratch> =
        const { RefCell::new(PackScratch { a: Vec::new(), b: Vec::new() }) };
}

/// Pack the `mc`×`kc` block of A at (i0, p0) into MR-row panels,
/// k-major within each panel (`out[panel][p * MR + r]`), zero-padding
/// the last panel to the full MR so the micro-kernel never branches.
fn pack_a(a: MatRef, i0: usize, p0: usize, mc: usize, kc: usize, out: &mut [f32]) {
    let mut panel_base = 0usize;
    let mut ir = 0usize;
    while ir < mc {
        let rows = MR.min(mc - ir);
        let dst = &mut out[panel_base..panel_base + MR * kc];
        for p in 0..kc {
            let col = &mut dst[p * MR..p * MR + MR];
            let src = (i0 + ir) * a.rs + (p0 + p) * a.cs;
            if rows == MR {
                for (r, slot) in col.iter_mut().enumerate() {
                    *slot = a.data[src + r * a.rs];
                }
            } else {
                for (r, slot) in col.iter_mut().enumerate() {
                    *slot = if r < rows { a.data[src + r * a.rs] } else { 0.0 };
                }
            }
        }
        panel_base += MR * kc;
        ir += MR;
    }
}

/// Pack the `kc`×`nc` block of B at (p0, j0) into NR-column panels,
/// k-major within each panel (`out[panel][p * NR + j]`), zero-padded
/// like [`pack_a`].
fn pack_b(b: MatRef, p0: usize, j0: usize, kc: usize, nc: usize, out: &mut [f32]) {
    let mut panel_base = 0usize;
    let mut jr = 0usize;
    while jr < nc {
        let cols = NR.min(nc - jr);
        let dst = &mut out[panel_base..panel_base + NR * kc];
        for p in 0..kc {
            let row = &mut dst[p * NR..p * NR + NR];
            let src = (p0 + p) * b.rs + (j0 + jr) * b.cs;
            if cols == NR {
                for (j, slot) in row.iter_mut().enumerate() {
                    *slot = b.data[src + j * b.cs];
                }
            } else {
                for (j, slot) in row.iter_mut().enumerate() {
                    *slot = if j < cols { b.data[src + j * b.cs] } else { 0.0 };
                }
            }
        }
        panel_base += NR * kc;
        jr += NR;
    }
}

// ------------------------------------------------------- micro-kernel

// The AVX2 register tile in `exec::simd::avx2` is hard-wired to the
// 8×8 shape; changing MR/NR requires a matching vector kernel.
const _: () = assert!(MR == 8 && NR == 8);

/// The register tile: `acc[r][c] += Σ_p ap[p·MR+r] · bp[p·NR+c]`.
/// Dispatches to the explicit AVX2+FMA tile
/// ([`crate::exec::simd::avx2::gemm_tile_8x8`]) when the process runs
/// at that level, else to the scalar tile below (DESIGN.md §8).
//
// Innermost GEMM code: tiles live entirely in registers and panel
// slices; any allocation here would dominate the kernel.
// qrr-audit: no-alloc
#[inline(always)]
fn micro_kernel(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    #[cfg(target_arch = "x86_64")]
    {
        if crate::exec::simd::avx2_enabled() {
            // SAFETY: avx2_enabled() is true only when avx2+fma were
            // detected on this CPU at first dispatch.
            unsafe { crate::exec::simd::avx2::gemm_tile_8x8(kc, ap, bp, acc) };
            return;
        }
    }
    micro_kernel_scalar(kc, ap, bp, acc);
}

/// The portable tile — the fallback and the parity oracle for the AVX2
/// kernel. Both panels are zero-padded, so the tile is always full
/// MR×NR: the loop body is branch-free and auto-vectorizes to 8-lane
/// FMAs on targets whose baseline has them.
#[inline(always)]
fn micro_kernel_scalar(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    debug_assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
    for p in 0..kc {
        let a = &ap[p * MR..p * MR + MR];
        let b = &bp[p * NR..p * NR + NR];
        for r in 0..MR {
            let ar = a[r];
            let row = &mut acc[r];
            for (c, &bc) in b.iter().enumerate() {
                row[c] += ar * bc;
            }
        }
    }
}

/// `c[r·ldc + j] += alpha · acc[r][j]` over the real mr×nr extent of an
/// edge tile. `c` points at the tile's top-left element.
///
/// # Safety
/// The mr×nr region (row stride `ldc`) must be in bounds, and no other
/// task may touch it concurrently — guaranteed by the disjoint 2-D tile
/// grid in [`gemm_driver`].
unsafe fn write_tile(
    acc: &[[f32; NR]; MR],
    c: *mut f32,
    ldc: usize,
    mr: usize,
    nr: usize,
    alpha: f32,
) {
    // SAFETY: the caller guarantees the mr×nr region behind `c` is in
    // bounds and exclusively owned (the fn-level # Safety contract).
    unsafe {
        for (r, arow) in acc.iter().enumerate().take(mr) {
            let crow = c.add(r * ldc);
            for (j, &v) in arow.iter().enumerate().take(nr) {
                *crow.add(j) += alpha * v;
            }
        }
    }
}
// qrr-audit: end

// ------------------------------------------------------------ drivers

/// Serial packed GEMM over the C region rows [i0, i1) × cols [j0, j1):
/// `C[i·ldc + j] += alpha · (A·B)[i, j]` with the full k extent.
#[allow(clippy::too_many_arguments)]
fn gemm_region(
    a: MatRef,
    b: MatRef,
    c: *mut f32,
    ldc: usize,
    k: usize,
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    alpha: f32,
) {
    SCRATCH.with(|s| {
        let mut s = s.borrow_mut();
        let a_need = MC.div_ceil(MR) * MR * KC;
        let b_need = NC.div_ceil(NR) * NR * KC;
        if s.a.len() < a_need {
            s.a.resize(a_need, 0.0);
        }
        if s.b.len() < b_need {
            s.b.resize(b_need, 0.0);
        }
        let PackScratch { a: apack, b: bpack } = &mut *s;
        // Steady-state blocked loops: after the scratch grow above,
        // packing and tiling must reuse buffers only.
        // qrr-audit: no-alloc
        for jc in (j0..j1).step_by(NC) {
            let nc = NC.min(j1 - jc);
            for pc in (0..k).step_by(KC) {
                let kc = KC.min(k - pc);
                pack_b(b, pc, jc, kc, nc, bpack);
                for ic in (i0..i1).step_by(MC) {
                    let mc = MC.min(i1 - ic);
                    pack_a(a, ic, pc, mc, kc, apack);
                    let mut jr = 0usize;
                    let mut bpanel_base = 0usize;
                    while jr < nc {
                        let nr_eff = NR.min(nc - jr);
                        let bpanel = &bpack[bpanel_base..bpanel_base + NR * kc];
                        let mut ir = 0usize;
                        let mut apanel_base = 0usize;
                        while ir < mc {
                            let mr_eff = MR.min(mc - ir);
                            let apanel = &apack[apanel_base..apanel_base + MR * kc];
                            let mut acc = [[0f32; NR]; MR];
                            micro_kernel(kc, apanel, bpanel, &mut acc);
                            let base = (ic + ir) * ldc + jc + jr;
                            // SAFETY: the tile lies inside this call's
                            // [i0,i1)×[j0,j1) region of C (bounds checked
                            // by the driver), disjoint from other tasks.
                            unsafe {
                                write_tile(&acc, c.add(base), ldc, mr_eff, nr_eff, alpha);
                            }
                            apanel_base += MR * kc;
                            ir += MR;
                        }
                        bpanel_base += NR * kc;
                        jr += NR;
                    }
                }
            }
        }
        // qrr-audit: end
    });
}

/// Accumulating GEMM core: `C[i·ldc + j] += alpha · (A·B)[i, j]` for an
/// m×k · k×n product. Splits over the shared pool above
/// [`PAR_THRESHOLD`]; every element sums its k terms in the same order
/// regardless of the split, so results are bit-identical across thread
/// counts.
#[allow(clippy::too_many_arguments)]
fn gemm_driver(
    m: usize,
    k: usize,
    n: usize,
    a: MatRef,
    b: MatRef,
    c: &mut [f32],
    ldc: usize,
    alpha: f32,
) {
    if m == 0 || n == 0 || k == 0 {
        return; // accumulate semantics: nothing to add
    }
    assert!(c.len() >= (m - 1) * ldc + n, "gemm output region out of bounds");
    if m * k * n < PAR_THRESHOLD {
        gemm_region(a, b, c.as_mut_ptr(), ldc, k, 0, m, 0, n, alpha);
        return;
    }
    let pool = crate::exec::global_pool();
    let threads = pool.size().max(1);
    // ~2 row bands per worker, rounded to the tile height; column
    // panels at the pack width. Each grid cell runs the full k loop
    // serially, so the tiling never changes the summation order.
    let band = m.div_ceil(2 * threads).div_ceil(MR) * MR;
    let nbands = m.div_ceil(band);
    let npanels = n.div_ceil(NC);
    let cptr = SendPtr(c.as_mut_ptr());
    let cref = &cptr;
    pool.for_each(nbands * npanels, |t| {
        let bi = t / npanels;
        let pj = t % npanels;
        let i0 = bi * band;
        let i1 = m.min(i0 + band);
        let j0 = pj * NC;
        let j1 = n.min(j0 + NC);
        gemm_region(a, b, cref.0, ldc, k, i0, i1, j0, j1, alpha);
    });
}

/// Strided-output accumulate entry for in-crate callers (the blocked QR
/// panel updates): `c[i·ldc + j] += alpha · (A·B)[i, j]`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_strided(
    m: usize,
    k: usize,
    n: usize,
    a: MatRef,
    b: MatRef,
    c: &mut [f32],
    ldc: usize,
    alpha: f32,
) {
    gemm_driver(m, k, n, a, b, c, ldc, alpha);
}

// --------------------------------------------------------- public API

/// C = A · B for row-major matrices (m×k)·(k×n).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2, "matmul lhs must be 2-D");
    assert_eq!(b.ndim(), 2, "matmul rhs must be 2-D");
    let (m, ka) = (a.shape()[0], a.shape()[1]);
    let (kb, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(ka, kb, "matmul inner dims {ka} != {kb}");
    let mut c = Tensor::zeros(&[m, n]);
    gemm_driver(
        m,
        ka,
        n,
        MatRef::dense(a.data(), ka),
        MatRef::dense(b.data(), n),
        c.data_mut(),
        n,
        1.0,
    );
    c
}

/// C = Aᵀ · B where A is (k×m) — packs directly from the strided
/// source; no transpose copy is materialized.
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (k, m) = (a.shape()[0], a.shape()[1]);
    let (kb, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, kb, "matmul_tn inner dims {k} != {kb}");
    let mut c = Tensor::zeros(&[m, n]);
    gemm_driver(
        m,
        k,
        n,
        MatRef::transposed(a.data(), m),
        MatRef::dense(b.data(), n),
        c.data_mut(),
        n,
        1.0,
    );
    c
}

/// C = A · Bᵀ where B is (n×k) — packs directly from the strided
/// source; no transpose copy is materialized.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, kb) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, kb, "matmul_nt inner dims {k} != {kb}");
    let mut c = Tensor::zeros(&[m, n]);
    gemm_driver(
        m,
        k,
        n,
        MatRef::dense(a.data(), k),
        MatRef::transposed(b.data(), k),
        c.data_mut(),
        n,
        1.0,
    );
    c
}

/// C += A · B — the accumulate entry point: callers with a live output
/// (bias-initialized activations, QR panel updates) skip the
/// allocate-and-zero of an intermediate product tensor.
pub fn gemm_acc(c: &mut Tensor, a: &Tensor, b: &Tensor) {
    assert_eq!(a.ndim(), 2, "gemm_acc lhs must be 2-D");
    assert_eq!(b.ndim(), 2, "gemm_acc rhs must be 2-D");
    let (m, ka) = (a.shape()[0], a.shape()[1]);
    let (kb, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(ka, kb, "gemm_acc inner dims {ka} != {kb}");
    assert_eq!(c.shape(), &[m, n], "gemm_acc output shape mismatch");
    gemm_driver(
        m,
        ka,
        n,
        MatRef::dense(a.data(), ka),
        MatRef::dense(b.data(), n),
        c.data_mut(),
        n,
        1.0,
    );
}

/// C += Aᵀ · B where A is (k×m).
pub fn gemm_acc_tn(c: &mut Tensor, a: &Tensor, b: &Tensor) {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (k, m) = (a.shape()[0], a.shape()[1]);
    let (kb, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, kb, "gemm_acc_tn inner dims {k} != {kb}");
    assert_eq!(c.shape(), &[m, n], "gemm_acc_tn output shape mismatch");
    gemm_driver(
        m,
        k,
        n,
        MatRef::transposed(a.data(), m),
        MatRef::dense(b.data(), n),
        c.data_mut(),
        n,
        1.0,
    );
}

/// C += A · Bᵀ where B is (n×k).
pub fn gemm_acc_nt(c: &mut Tensor, a: &Tensor, b: &Tensor) {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, kb) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, kb, "gemm_acc_nt inner dims {k} != {kb}");
    assert_eq!(c.shape(), &[m, n], "gemm_acc_nt output shape mismatch");
    gemm_driver(
        m,
        k,
        n,
        MatRef::dense(a.data(), k),
        MatRef::transposed(b.data(), k),
        c.data_mut(),
        n,
        1.0,
    );
}

// ------------------------------------------------------------- matvec

/// y = A · x for a matrix (m×n) and vector (n): each row is one
/// [`crate::exec::simd::dot`] (8-lane FMA on AVX2, 8 partial sums on
/// the scalar path), with rows split over the shared pool for large m
/// (the serve/inference path).
pub fn matvec(a: &Tensor, x: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(x.ndim(), 1);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    assert_eq!(x.len(), n, "matvec dim mismatch");
    let mut y = vec![0f32; m];
    let ad = a.data();
    let xd = x.data();
    if m * n >= MATVEC_PAR_THRESHOLD && m > 1 {
        let pool = crate::exec::global_pool();
        let chunk = m.div_ceil(pool.size().max(1) * 4).max(1);
        let tasks = m.div_ceil(chunk);
        let yptr = SendPtr(y.as_mut_ptr());
        let yref = &yptr;
        pool.for_each(tasks, |t| {
            let r0 = t * chunk;
            let r1 = m.min(r0 + chunk);
            for i in r0..r1 {
                let v = crate::exec::simd::dot(&ad[i * n..(i + 1) * n], xd);
                // SAFETY: each row index belongs to exactly one task.
                unsafe {
                    *yref.0.add(i) = v;
                }
            }
        });
    } else {
        for (i, yi) in y.iter_mut().enumerate() {
            *yi = crate::exec::simd::dot(&ad[i * n..(i + 1) * n], xd);
        }
    }
    Tensor::vector(y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f64;
                for kk in 0..k {
                    acc += a.get2(i, kk) as f64 * b.get2(kk, j) as f64;
                }
                c.set2(i, j, acc as f32);
            }
        }
        c
    }

    #[test]
    fn matches_naive_small() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (2, 3, 4), (5, 7, 3), (16, 16, 16)] {
            let a = Tensor::randn(&[m, k], &mut rng);
            let b = Tensor::randn(&[k, n], &mut rng);
            let c = matmul(&a, &b);
            assert!(c.rel_err(&naive(&a, &b)) < 1e-5, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matches_naive_tile_edges() {
        // every combination of exactly-on / one-off the MR/NR/KC tile
        // boundaries, plus degenerate m=1 / n=1 / k=1 strips
        let mut rng = Rng::new(7);
        for &(m, k, n) in &[
            (8, 8, 8),
            (9, 9, 9),
            (7, 16, 9),
            (8, 1, 17),
            (17, 3, 8),
            (1, 9, 1),
            (1, 300, 1),
            (64, 64, 64),
            (65, 129, 67),
        ] {
            let a = Tensor::randn(&[m, k], &mut rng);
            let b = Tensor::randn(&[k, n], &mut rng);
            assert!(matmul(&a, &b).rel_err(&naive(&a, &b)) < 1e-4, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matches_naive_unaligned_sizes() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[65, 130], &mut rng);
        let b = Tensor::randn(&[130, 67], &mut rng);
        assert!(matmul(&a, &b).rel_err(&naive(&a, &b)) < 1e-4);
    }

    #[test]
    fn parallel_path_correct() {
        let mut rng = Rng::new(3);
        // 128*128*128 > PAR_THRESHOLD? 2^21 > 2^20: yes
        let a = Tensor::randn(&[128, 128], &mut rng);
        let b = Tensor::randn(&[128, 128], &mut rng);
        assert!(matmul(&a, &b).rel_err(&naive(&a, &b)) < 1e-4);
    }

    #[test]
    fn empty_inner_dim_is_zero() {
        // k = 0: the product is the zero matrix, and the accumulate
        // entry leaves C untouched
        let a = Tensor::zeros(&[3, 0]);
        let b = Tensor::zeros(&[0, 4]);
        let c = matmul(&a, &b);
        assert_eq!(c.shape(), &[3, 4]);
        assert_eq!(c.fro_norm(), 0.0);
        let mut rng = Rng::new(8);
        let mut acc = Tensor::randn(&[3, 4], &mut rng);
        let before = acc.clone();
        gemm_acc(&mut acc, &a, &b);
        assert_eq!(acc, before);
    }

    #[test]
    fn tn_and_nt_variants() {
        let mut rng = Rng::new(4);
        let a = Tensor::randn(&[20, 12], &mut rng);
        let b = Tensor::randn(&[20, 9], &mut rng);
        let c1 = matmul_tn(&a, &b); // (12x9)
        let c2 = matmul(&a.transpose(), &b);
        assert!(c1.rel_err(&c2) < 1e-5);

        let d = Tensor::randn(&[12, 20], &mut rng);
        let e = Tensor::randn(&[9, 20], &mut rng);
        let c3 = matmul_nt(&d, &e); // (12x9)
        let c4 = matmul(&d, &e.transpose());
        assert!(c3.rel_err(&c4) < 1e-5);
    }

    #[test]
    fn gemm_acc_adds_onto_existing_output() {
        let mut rng = Rng::new(9);
        let a = Tensor::randn(&[13, 21], &mut rng);
        let b = Tensor::randn(&[21, 17], &mut rng);
        let c0 = Tensor::randn(&[13, 17], &mut rng);
        let want = c0.add(&naive(&a, &b));

        let mut c = c0.clone();
        gemm_acc(&mut c, &a, &b);
        assert!(c.rel_err(&want) < 1e-4);

        let mut c = c0.clone();
        gemm_acc_tn(&mut c, &a.transpose(), &b);
        assert!(c.rel_err(&want) < 1e-4);

        let mut c = c0.clone();
        gemm_acc_nt(&mut c, &a, &b.transpose());
        assert!(c.rel_err(&want) < 1e-4);
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(5);
        let a = Tensor::randn(&[33, 33], &mut rng);
        let i = Tensor::eye(33);
        assert!(matmul(&a, &i).rel_err(&a) < 1e-6);
        assert!(matmul(&i, &a).rel_err(&a) < 1e-6);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(6);
        let a = Tensor::randn(&[13, 7], &mut rng);
        let x = Tensor::randn(&[7], &mut rng);
        let y = matvec(&a, &x);
        let xm = Tensor::matrix(7, 1, x.data().to_vec());
        let ym = matmul(&a, &xm);
        for i in 0..13 {
            assert!((y.data()[i] - ym.data()[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn matvec_parallel_path_matches_serial_math() {
        // 1100 * 1000 > MATVEC_PAR_THRESHOLD: rows split over the pool
        let mut rng = Rng::new(10);
        let a = Tensor::randn(&[1100, 1000], &mut rng);
        let x = Tensor::randn(&[1000], &mut rng);
        let y = matvec(&a, &x);
        for i in (0..1100).step_by(97) {
            let mut want = 0f64;
            for j in 0..1000 {
                want += a.get2(i, j) as f64 * x.data()[j] as f64;
            }
            assert!((y.data()[i] as f64 - want).abs() < 1e-2, "row {i}");
        }
    }

    #[test]
    fn micro_kernel_dispatch_matches_scalar_tile() {
        // whatever the process dispatches at, the tile must agree with
        // the scalar oracle on full and edge k-depths
        let mut rng = Rng::new(11);
        for &kc in &[0usize, 1, 3, 32, 256] {
            let ap = Tensor::randn(&[kc * MR], &mut rng).into_vec();
            let bp = Tensor::randn(&[kc * NR], &mut rng).into_vec();
            let mut got = [[0f32; NR]; MR];
            micro_kernel(kc, &ap, &bp, &mut got);
            let mut want = [[0f32; NR]; MR];
            micro_kernel_scalar(kc, &ap, &bp, &mut want);
            for r in 0..MR {
                for c in 0..NR {
                    assert!(
                        (got[r][c] - want[r][c]).abs() <= 1e-4 * want[r][c].abs().max(1.0),
                        "kc={kc} ({r},{c}): {} vs {}",
                        got[r][c],
                        want[r][c]
                    );
                }
            }
        }
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        let _ = matmul(&a, &b);
    }
}
