//! Blocked, cache-friendly matrix multiplication.
//!
//! A micro-kernel-free but register-blocked GEMM: loop order i-k-j with
//! 64×64×64 cache blocking and an 8-wide inner accumulation the compiler
//! auto-vectorizes. Large products are split row-wise across threads.

use crate::tensor::Tensor;

const BLOCK: usize = 64;
/// Products larger than this many MACs go parallel.
const PAR_THRESHOLD: usize = 1 << 20;

/// C = A · B for row-major matrices (m×k)·(k×n).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2, "matmul lhs must be 2-D");
    assert_eq!(b.ndim(), 2, "matmul rhs must be 2-D");
    let (m, ka) = (a.shape()[0], a.shape()[1]);
    let (kb, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(ka, kb, "matmul inner dims {ka} != {kb}");
    let mut c = Tensor::zeros(&[m, n]);
    gemm(a.data(), b.data(), c.data_mut(), m, ka, n);
    c
}

/// C = Aᵀ · B where A is (k×m) — avoids materializing the transpose.
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (k, m) = (a.shape()[0], a.shape()[1]);
    let (kb, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, kb, "matmul_tn inner dims {k} != {kb}");
    // Aᵀ(m×k) row i = A column i (stride m). Transposing A up front and
    // running the blocked kernel is faster than strided access.
    let at = a.transpose();
    let mut c = Tensor::zeros(&[m, n]);
    gemm(at.data(), b.data(), c.data_mut(), m, k, n);
    c
}

/// C = A · Bᵀ where B is (n×k).
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, kb) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, kb, "matmul_nt inner dims {k} != {kb}");
    let bt = b.transpose();
    let mut c = Tensor::zeros(&[m, n]);
    gemm(a.data(), bt.data(), c.data_mut(), m, k, n);
    c
}

/// y = A · x for a matrix (m×n) and vector (n).
pub fn matvec(a: &Tensor, x: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(x.ndim(), 1);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    assert_eq!(x.len(), n, "matvec dim mismatch");
    let mut y = vec![0f32; m];
    let ad = a.data();
    let xd = x.data();
    for i in 0..m {
        let row = &ad[i * n..(i + 1) * n];
        let mut acc = 0f32;
        for j in 0..n {
            acc += row[j] * xd[j];
        }
        y[i] = acc;
    }
    Tensor::vector(y)
}

/// Core blocked kernel: c(m×n) += a(m×k) · b(k×n); c must be zeroed.
fn gemm(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    if m * k * n >= PAR_THRESHOLD {
        gemm_parallel(a, b, c, m, k, n);
    } else {
        gemm_serial(a, b, c, m, k, n, 0, m);
    }
}

fn gemm_parallel(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    let threads = crate::exec::default_threads().min(m).max(1);
    let rows_per = m.div_ceil(threads);
    // Split C into disjoint row bands, one per thread.
    let bands: Vec<(usize, &mut [f32])> = {
        let mut bands = Vec::new();
        let mut rest = c;
        let mut row = 0usize;
        while row < m {
            let take = rows_per.min(m - row);
            let (head, tail) = rest.split_at_mut(take * n);
            bands.push((row, head));
            rest = tail;
            row += take;
        }
        bands
    };
    std::thread::scope(|s| {
        for (row0, band) in bands {
            let rows = band.len() / n;
            s.spawn(move || {
                gemm_serial(a, b, band, m, k, n, row0, row0 + rows);
            });
        }
    });
}

/// Serial blocked kernel over rows [r0, r1). `c` holds only those rows.
fn gemm_serial(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    _m: usize,
    k: usize,
    n: usize,
    r0: usize,
    r1: usize,
) {
    for bi in (r0..r1).step_by(BLOCK) {
        let bi_end = (bi + BLOCK).min(r1);
        for bk in (0..k).step_by(BLOCK) {
            let bk_end = (bk + BLOCK).min(k);
            for bj in (0..n).step_by(BLOCK) {
                let bj_end = (bj + BLOCK).min(n);
                for i in bi..bi_end {
                    let arow = &a[i * k..(i + 1) * k];
                    let crow = &mut c[(i - r0) * n..(i - r0 + 1) * n];
                    for kk in bk..bk_end {
                        let aik = arow[kk];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &b[kk * n..(kk + 1) * n];
                        // contiguous j loop: auto-vectorizes
                        for j in bj..bj_end {
                            crow[j] += aik * brow[j];
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f64;
                for kk in 0..k {
                    acc += a.get2(i, kk) as f64 * b.get2(kk, j) as f64;
                }
                c.set2(i, j, acc as f32);
            }
        }
        c
    }

    #[test]
    fn matches_naive_small() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (2, 3, 4), (5, 7, 3), (16, 16, 16)] {
            let a = Tensor::randn(&[m, k], &mut rng);
            let b = Tensor::randn(&[k, n], &mut rng);
            let c = matmul(&a, &b);
            assert!(c.rel_err(&naive(&a, &b)) < 1e-5, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matches_naive_unaligned_sizes() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(&[65, 130], &mut rng);
        let b = Tensor::randn(&[130, 67], &mut rng);
        assert!(matmul(&a, &b).rel_err(&naive(&a, &b)) < 1e-4);
    }

    #[test]
    fn parallel_path_correct() {
        let mut rng = Rng::new(3);
        // 128*128*128 > PAR_THRESHOLD? 2^21 > 2^20: yes
        let a = Tensor::randn(&[128, 128], &mut rng);
        let b = Tensor::randn(&[128, 128], &mut rng);
        assert!(matmul(&a, &b).rel_err(&naive(&a, &b)) < 1e-4);
    }

    #[test]
    fn tn_and_nt_variants() {
        let mut rng = Rng::new(4);
        let a = Tensor::randn(&[20, 12], &mut rng);
        let b = Tensor::randn(&[20, 9], &mut rng);
        let c1 = matmul_tn(&a, &b); // (12x9)
        let c2 = matmul(&a.transpose(), &b);
        assert!(c1.rel_err(&c2) < 1e-5);

        let d = Tensor::randn(&[12, 20], &mut rng);
        let e = Tensor::randn(&[9, 20], &mut rng);
        let c3 = matmul_nt(&d, &e); // (12x9)
        let c4 = matmul(&d, &e.transpose());
        assert!(c3.rel_err(&c4) < 1e-5);
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(5);
        let a = Tensor::randn(&[33, 33], &mut rng);
        let i = Tensor::eye(33);
        assert!(matmul(&a, &i).rel_err(&a) < 1e-6);
        assert!(matmul(&i, &a).rel_err(&a) < 1e-6);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(6);
        let a = Tensor::randn(&[13, 7], &mut rng);
        let x = Tensor::randn(&[7], &mut rng);
        let y = matvec(&a, &x);
        let xm = Tensor::matrix(7, 1, x.data().to_vec());
        let ym = matmul(&a, &xm);
        for i in 0..13 {
            assert!((y.data()[i] - ym.data()[i]).abs() < 1e-5);
        }
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        let _ = matmul(&a, &b);
    }
}
