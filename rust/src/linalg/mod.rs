//! Dense linear algebra substrate: blocked matmul, Householder QR and
//! truncated SVD (exact one-sided Jacobi + randomized subspace
//! iteration).
//!
//! This is the engine behind the paper's compression operator ℂ:
//! truncated SVD for matrix gradients (eq. (5)-(8)) and the per-mode
//! SVDs of the Tucker/HOSVD factorization (eq. (9)).

mod eig;
mod matmul;
mod qr;
mod svd;

pub use eig::sym_eig_jacobi;
pub use matmul::{matmul, matmul_nt, matmul_tn, matvec};
pub use qr::{orthonormalize, qr_thin, QrThin};
pub use svd::{svd_jacobi, svd_truncated, Svd, SvdMethod};
