//! Dense linear algebra substrate: packed micro-kernel GEMM, blocked
//! Householder QR and truncated SVD (exact one-sided Jacobi +
//! randomized subspace iteration).
//!
//! This is the engine behind the paper's compression operator ℂ:
//! truncated SVD for matrix gradients (eq. (5)-(8)) and the per-mode
//! SVDs of the Tucker/HOSVD factorization (eq. (9)). The GEMM
//! subsystem (DESIGN.md §6) is the single hottest kernel in the crate —
//! every SVD, QR, mode-n product and model forward/backward bottoms
//! out in it.

mod eig;
mod matmul;
mod qr;
mod svd;

pub use eig::sym_eig_jacobi;
pub use matmul::{gemm_acc, gemm_acc_nt, gemm_acc_tn, matmul, matmul_nt, matmul_tn, matvec};
pub use qr::{orthonormalize, qr_thin, qr_thin_unblocked, QrThin};
pub use svd::{svd_jacobi, svd_truncated, Svd, SvdMethod};
