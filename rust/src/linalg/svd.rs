//! Truncated singular value decomposition.
//!
//! Two engines:
//! * [`svd_jacobi`] — exact thin SVD via one-sided Jacobi rotations.
//!   Robust and simple; O(mn²) per sweep. Used for small matrices and as
//!   the finishing step of the randomized path.
//! * [`svd_truncated`] with [`SvdMethod::Randomized`] — Halko-style
//!   randomized range finder with subspace (power) iteration: sketch
//!   Y = A·Ω, orthonormalize Q, project B = Qᵀ·A, exact SVD of the small
//!   B, then U = Q·U_B. This is the GEMM-dominant formulation that maps
//!   onto the Pallas `rangefinder` kernel on TPU (DESIGN.md §3).
//!
//! The paper truncates to ν = ⌈p·min(m,n)⌉ singular values (eq. (22)).

use crate::tensor::Tensor;
use crate::util::Rng;

use super::matmul::{matmul, matmul_tn};
use super::qr::orthonormalize;

/// Thin SVD result: `a ≈ u · diag(s) · vᵀ`.
#[derive(Debug, Clone)]
pub struct Svd {
    /// m×k, orthonormal columns (left singular vectors).
    pub u: Tensor,
    /// k singular values, descending.
    pub s: Vec<f32>,
    /// n×k, orthonormal columns (right singular vectors).
    pub v: Tensor,
}

impl Svd {
    /// Reconstruct the (possibly truncated) matrix U·diag(s)·Vᵀ.
    pub fn reconstruct(&self) -> Tensor {
        let k = self.s.len();
        let (m, n) = (self.u.shape()[0], self.v.shape()[0]);
        if k == 0 {
            // rank-0 factorization: the zero matrix
            return Tensor::zeros(&[m, n]);
        }
        // scale columns of U by s row-wise on the raw slice (one SIMD
        // multiply per row), then multiply by Vᵀ
        let mut us = self.u.clone();
        for row in us.data_mut().chunks_exact_mut(k) {
            crate::exec::simd::mul(row, &self.s);
        }
        super::matmul_nt(&us, &self.v).reshape(&[m, n])
    }

    /// Truncate to the leading `k` components. Row-sliced copies: the
    /// leading `k` columns of a row-major factor are a contiguous prefix
    /// of each row.
    pub fn truncate(mut self, k: usize) -> Svd {
        let k = k.min(self.s.len());
        let old_k = self.s.len();
        if k == old_k {
            return self;
        }
        let take_cols = |t: &Tensor, rows: usize| -> Tensor {
            let mut out = Tensor::zeros(&[rows, k]);
            {
                let src = t.data();
                let dst = out.data_mut();
                for i in 0..rows {
                    dst[i * k..(i + 1) * k].copy_from_slice(&src[i * old_k..i * old_k + k]);
                }
            }
            out
        };
        let (m, n) = (self.u.shape()[0], self.v.shape()[0]);
        let u = take_cols(&self.u, m);
        let v = take_cols(&self.v, n);
        self.s.truncate(k);
        Svd { u, s: self.s, v }
    }
}

/// Algorithm selector for [`svd_truncated`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SvdMethod {
    /// Exact one-sided Jacobi, then truncate. Cost O(mn·min(m,n)).
    Jacobi,
    /// Randomized range finder + power iteration. Cost O(mnk).
    Randomized {
        /// extra sketch columns beyond the target rank (default 8)
        oversample: usize,
        /// number of power iterations (default 2)
        power_iters: usize,
        /// PRNG seed for the Gaussian test matrix
        seed: u64,
    },
    /// Randomized for large matrices, Jacobi for small ones.
    Auto,
}

impl Default for SvdMethod {
    fn default() -> Self {
        SvdMethod::Auto
    }
}

/// Default randomized parameters.
pub const DEFAULT_OVERSAMPLE: usize = 8;
/// Default power iterations for the randomized path.
pub const DEFAULT_POWER_ITERS: usize = 2;
/// Below this element count, Auto uses exact Jacobi.
const AUTO_JACOBI_LIMIT: usize = 64 * 64;

/// Truncated SVD keeping the `k` leading components.
pub fn svd_truncated(a: &Tensor, k: usize, method: SvdMethod) -> Svd {
    assert_eq!(a.ndim(), 2, "svd expects a matrix");
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let k = k.min(m.min(n)).max(1);
    match method {
        SvdMethod::Jacobi => svd_jacobi(a).truncate(k),
        SvdMethod::Randomized { oversample, power_iters, seed } => {
            svd_randomized(a, k, oversample, power_iters, seed)
        }
        SvdMethod::Auto => {
            // Exact Jacobi only for small problems; the randomized path
            // (GEMM-dominant, the TPU mapping) handles everything else,
            // including near-full-rank targets — power iteration keeps it
            // accurate there.
            if m * n <= AUTO_JACOBI_LIMIT {
                svd_jacobi(a).truncate(k)
            } else {
                svd_randomized(a, k, DEFAULT_OVERSAMPLE, DEFAULT_POWER_ITERS, 0x5EED)
            }
        }
    }
}

/// Exact thin SVD via one-sided Jacobi (Hestenes). Returns all
/// min(m,n) components in descending order.
pub fn svd_jacobi(a: &Tensor) -> Svd {
    let (m, n) = (a.shape()[0], a.shape()[1]);
    if m < n {
        // SVD(Aᵀ) = (V, S, U)
        let t = svd_jacobi(&a.transpose());
        return Svd { u: t.v, s: t.s, v: t.u };
    }
    // Work on columns of W = A (m×n); V accumulates rotations (n×n).
    let mut w = a.data().to_vec();
    let mut v = Tensor::eye(n).into_vec();

    let max_sweeps = 30;
    let tol = 1e-9f64;
    for _sweep in 0..max_sweeps {
        let mut off = 0f64;
        let mut rotations = 0usize;
        for p in 0..n {
            for q in (p + 1)..n {
                // Gram entries for columns p, q
                let (mut app, mut aqq, mut apq) = (0f64, 0f64, 0f64);
                for i in 0..m {
                    let wp = w[i * n + p] as f64;
                    let wq = w[i * n + q] as f64;
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                if apq.abs() <= tol * (app * aqq).sqrt().max(1e-300) {
                    continue;
                }
                off += apq * apq;
                rotations += 1;
                // Jacobi rotation angle
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let (cf, sf) = (c as f32, s as f32);
                // rotate columns p,q of W
                for i in 0..m {
                    let wp = w[i * n + p];
                    let wq = w[i * n + q];
                    w[i * n + p] = cf * wp - sf * wq;
                    w[i * n + q] = sf * wp + cf * wq;
                }
                // rotate columns p,q of V
                for i in 0..n {
                    let vp = v[i * n + p];
                    let vq = v[i * n + q];
                    v[i * n + p] = cf * vp - sf * vq;
                    v[i * n + q] = sf * vp + cf * vq;
                }
            }
        }
        if rotations == 0 || off.sqrt() < tol {
            break;
        }
    }

    // Column norms of W are the singular values.
    let mut order: Vec<usize> = (0..n).collect();
    let mut sigmas = vec![0f32; n];
    for (j, sig) in sigmas.iter_mut().enumerate() {
        let mut nrm = 0f64;
        for i in 0..m {
            nrm += (w[i * n + j] as f64).powi(2);
        }
        *sig = nrm.sqrt() as f32;
    }
    order.sort_by(|&i, &j| sigmas[j].total_cmp(&sigmas[i])); // NaN-safe

    let mut u = Tensor::zeros(&[m, n]);
    let mut vv = Tensor::zeros(&[n, n]);
    let mut s = vec![0f32; n];
    for (new_j, &old_j) in order.iter().enumerate() {
        let sig = sigmas[old_j];
        s[new_j] = sig;
        let inv = if sig > 1e-20 { 1.0 / sig } else { 0.0 };
        for i in 0..m {
            u.set2(i, new_j, w[i * n + old_j] * inv);
        }
        for i in 0..n {
            vv.set2(i, new_j, v[i * n + old_j]);
        }
    }
    Svd { u, s, v: vv }
}

/// Randomized truncated SVD (Halko-Martinsson-Tropp alg. 4.4 + 5.1).
fn svd_randomized(a: &Tensor, k: usize, oversample: usize, power_iters: usize, seed: u64) -> Svd {
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let l = (k + oversample).min(m.min(n));
    let mut rng = Rng::new(seed ^ (m as u64) << 32 ^ n as u64);

    // Sketch: Y = A Ω,  Ω ∈ R^{n×l}
    let omega = Tensor::randn(&[n, l], &mut rng);
    let mut y = matmul(a, &omega); // m×l
    // Power iteration with re-orthonormalization: Y <- A (Aᵀ Q).
    // CholeskyQR2 keeps every step GEMM-dominant (§Perf).
    let mut q = orthonormalize(&y);
    for _ in 0..power_iters {
        let z = matmul_tn(a, &q); // n×l
        let qz = orthonormalize(&z);
        y = matmul(a, &qz); // m×l
        q = orthonormalize(&y);
    }
    // Project: B = Qᵀ A  (l×n)
    let b = matmul_tn(&q, a);
    // SVD of the small B via its l×l Gram matrix: eig(B·Bᵀ) = (σ², U_B),
    // then V = Bᵀ·U_B·diag(1/σ). O(l²n + l³) instead of one-sided Jacobi
    // on l×n — the dominant cost of the QRR encode path before this
    // change (EXPERIMENTS.md §Perf).
    let sb = svd_small_lhs(&b, k);
    // U = Q · U_B
    let u = matmul(&q, &sb.u);
    Svd { u, s: sb.s, v: sb.v }
}

/// Thin SVD of a short-and-wide matrix (l ≤ n) through the l×l Gram
/// eigenproblem. Accurate for the dominant components (all we keep);
/// tiny σ lose relative precision, which truncation discards anyway.
fn svd_small_lhs(b: &Tensor, k: usize) -> Svd {
    let (l, n) = (b.shape()[0], b.shape()[1]);
    debug_assert!(l <= n, "svd_small_lhs expects l <= n");
    let k = k.min(l);
    let gram = super::matmul::matmul_nt(b, b); // l×l
    let (vals, vecs) = super::eig::sym_eig_jacobi(&gram);
    // keep k leading
    let mut u = Tensor::zeros(&[l, k]);
    let mut s = Vec::with_capacity(k);
    for j in 0..k {
        s.push(vals[j].max(0.0).sqrt());
        for i in 0..l {
            u.set2(i, j, vecs.get2(i, j));
        }
    }
    // V = Bᵀ U diag(1/s)   (zero columns where sigma ~ 0)
    let mut v = matmul_tn(b, &u); // n×k
    let inv_s: Vec<f32> = s
        .iter()
        .map(|&sig| if sig > 1e-12 { 1.0 / sig } else { 0.0 })
        .collect();
    for row in v.data_mut().chunks_exact_mut(k) {
        crate::exec::simd::mul(row, &inv_s);
    }
    Svd { u, s, v }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul_tn, qr_thin};
    use crate::util::Rng;

    /// Build an m×n matrix with prescribed singular values.
    fn with_spectrum(m: usize, n: usize, sigmas: &[f32], rng: &mut Rng) -> Tensor {
        let k = sigmas.len().min(m.min(n));
        let qa = qr_thin(&Tensor::randn(&[m, k], rng)).q;
        let qb = qr_thin(&Tensor::randn(&[n, k], rng)).q;
        let mut us = qa.clone();
        for i in 0..m {
            for j in 0..k {
                let v = us.get2(i, j) * sigmas[j];
                us.set2(i, j, v);
            }
        }
        super::super::matmul_nt(&us, &qb)
    }

    fn check_svd(a: &Tensor, svd: &Svd, tol: f32) {
        let (m, n) = (a.shape()[0], a.shape()[1]);
        let k = svd.s.len();
        assert_eq!(svd.u.shape(), &[m, k]);
        assert_eq!(svd.v.shape(), &[n, k]);
        // descending
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-5, "not descending: {:?}", svd.s);
        }
        // orthonormal columns
        let utu = matmul_tn(&svd.u, &svd.u);
        assert!(utu.rel_err(&Tensor::eye(k)) < tol, "UtU err");
        let vtv = matmul_tn(&svd.v, &svd.v);
        assert!(vtv.rel_err(&Tensor::eye(k)) < tol, "VtV err");
    }

    #[test]
    fn jacobi_exact_reconstruction() {
        let mut rng = Rng::new(20);
        for &(m, n) in &[(6, 6), (10, 4), (4, 10), (31, 17)] {
            let a = Tensor::randn(&[m, n], &mut rng);
            let svd = svd_jacobi(&a);
            check_svd(&a, &svd, 1e-4);
            let rec = svd.reconstruct();
            assert!(a.rel_err(&rec) < 1e-4, "{m}x{n} err {}", a.rel_err(&rec));
        }
    }

    #[test]
    fn jacobi_known_singular_values() {
        let mut rng = Rng::new(21);
        let sig = vec![10.0, 5.0, 1.0, 0.1];
        let a = with_spectrum(12, 8, &sig, &mut rng);
        let svd = svd_jacobi(&a);
        for (i, &expect) in sig.iter().enumerate() {
            assert!(
                (svd.s[i] - expect).abs() / expect < 1e-3,
                "sigma_{i}: got {}, want {}",
                svd.s[i],
                expect
            );
        }
        // the rest are ~0
        for &s in &svd.s[4..] {
            assert!(s < 1e-3);
        }
    }

    #[test]
    fn truncation_error_matches_tail_eq7() {
        // paper eq. (7): ||A - A_v||_F^2 = sum_{j>v} sigma_j^2
        let mut rng = Rng::new(22);
        let sig = vec![8.0, 4.0, 2.0, 1.0, 0.5];
        let a = with_spectrum(20, 10, &sig, &mut rng);
        let svd = svd_jacobi(&a).truncate(2);
        let rec = svd.reconstruct();
        let err2 = a.sub(&rec).fro_norm().powi(2);
        let tail: f32 = sig[2..].iter().map(|s| s * s).sum();
        assert!(
            (err2 - tail).abs() / tail < 1e-2,
            "err^2 {err2} vs tail {tail}"
        );
    }

    #[test]
    fn randomized_close_to_exact_on_lowrank() {
        let mut rng = Rng::new(23);
        let sig = vec![20.0, 10.0, 5.0, 0.01, 0.005];
        let a = with_spectrum(100, 60, &sig, &mut rng);
        let r = svd_truncated(
            &a,
            3,
            SvdMethod::Randomized { oversample: 8, power_iters: 2, seed: 7 },
        );
        check_svd(&a, &r, 1e-3);
        for i in 0..3 {
            assert!(
                (r.s[i] - sig[i]).abs() / sig[i] < 1e-2,
                "sigma_{i}: {} vs {}",
                r.s[i],
                sig[i]
            );
        }
        let rec = r.reconstruct();
        // remaining mass is tiny, reconstruction should be near-perfect
        assert!(a.rel_err(&rec) < 1e-2);
    }

    #[test]
    fn auto_dispatches_and_truncates() {
        let mut rng = Rng::new(24);
        let a = Tensor::randn(&[16, 12], &mut rng);
        let svd = svd_truncated(&a, 5, SvdMethod::Auto);
        assert_eq!(svd.s.len(), 5);
        check_svd(&a, &svd, 1e-4);
        let big = Tensor::randn(&[200, 100], &mut rng);
        let svd = svd_truncated(&big, 10, SvdMethod::Auto);
        assert_eq!(svd.s.len(), 10);
        check_svd(&big, &svd, 1e-3);
    }

    #[test]
    fn rank1_matrix() {
        let mut rng = Rng::new(25);
        let u = Tensor::randn(&[30, 1], &mut rng);
        let v = Tensor::randn(&[20, 1], &mut rng);
        let a = super::super::matmul_nt(&u, &v);
        let svd = svd_truncated(&a, 1, SvdMethod::Jacobi);
        assert!(a.rel_err(&svd.reconstruct()) < 1e-4);
    }

    #[test]
    fn zero_matrix_is_fine() {
        let a = Tensor::zeros(&[8, 5]);
        let svd = svd_jacobi(&a);
        assert!(svd.s.iter().all(|&s| s == 0.0));
        assert!(svd.reconstruct().fro_norm() < 1e-12);
    }

    #[test]
    fn rank_zero_truncation_reconstructs_zeros() {
        let mut rng = Rng::new(27);
        let a = Tensor::randn(&[6, 4], &mut rng);
        let svd = svd_jacobi(&a).truncate(0);
        assert!(svd.s.is_empty());
        let rec = svd.reconstruct();
        assert_eq!(rec.shape(), &[6, 4]);
        assert_eq!(rec.fro_norm(), 0.0);
    }

    #[test]
    fn best_rank_k_beats_any_other_rank_k() {
        // Eckart–Young sanity: truncated SVD error <= error of a random
        // rank-k factorization.
        let mut rng = Rng::new(26);
        let a = Tensor::randn(&[24, 18], &mut rng);
        let k = 4;
        let svd = svd_truncated(&a, k, SvdMethod::Jacobi);
        let best = a.sub(&svd.reconstruct()).fro_norm();
        for trial in 0..5 {
            let x = Tensor::randn(&[24, k], &mut rng);
            let y = Tensor::randn(&[k, 18], &mut rng);
            let approx = matmul(&x, &y);
            let err = a.sub(&approx).fro_norm();
            assert!(best <= err + 1e-3, "trial {trial}: {best} > {err}");
        }
    }
}
