//! `qrr-audit` — the crate's own static-analysis gate (DESIGN.md §9).
//!
//! A zero-dependency lexical analyzer that walks `src/**/*.rs` and
//! enforces the correctness contracts this codebase leans on but the
//! compiler cannot check:
//!
//! * **unsafe-audit** — every `unsafe` carries an immediately
//!   preceding `// SAFETY:` comment (or `/// # Safety` doc section),
//!   and `unsafe` only appears in the allowlisted kernel modules
//!   ([`rules::UNSAFE_MODULES`]).
//! * **no-alloc** — regions fenced with `// qrr-audit: no-alloc` …
//!   `// qrr-audit: end` (GEMM micro-kernels, the fused LAQ sweeps,
//!   bit-pack word loops, `Encoder::encode_into`) must not allocate:
//!   no `vec!`/`format!`, `.to_vec()`/`.clone()`/`.collect()`,
//!   `Vec::new`/`Box::new`/`String::from`.
//! * **no-panic** — regions fenced with `// qrr-audit: no-panic`
//!   (the wire-format decode half, quantizer well-formedness and
//!   `accepts` precondition checks) must not contain `.unwrap()`,
//!   `.expect()`, or panicking macros; `debug_assert*` stays legal.
//! * **env-once** — `std::env::var`/`var_os` only in the sanctioned
//!   seams ([`rules::ENV_MODULES`]); everything else goes through the
//!   cached accessors in [`crate::util::env`].
//!
//! The tree check additionally requires the *anchor* fences to exist
//! (e.g. `net::wire` must fence its decoder), so deleting a pragma
//! cannot silently disable a rule.
//!
//! Run it as `qrr audit [--check]` or via the dedicated binary
//! `cargo run --bin qrr_audit -- --check` (CI's audit job). Without
//! `--check` it reports and exits 0; with `--check` any finding is
//! fatal. `--list-rules` prints the registry, `--root DIR` overrides
//! the scanned tree (used by the CLI self-tests).

pub mod lexer;
pub mod rules;

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::cli::Args;
use rules::{FenceKind, FileCtx};

/// One finding, addressed `file:line` with its rule name.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Display path of the offending file.
    pub file: String,
    /// 1-indexed line.
    pub line: u32,
    /// Rule name (one of [`rules::KNOWN_RULES`]).
    pub rule: &'static str,
    /// Human-readable description.
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Map a path relative to `src/` onto the crate module path:
/// `net/wire.rs` → `net::wire`, `exec/mod.rs` → `exec`,
/// `lib.rs` → `""` (crate root), `bin/qrr_audit.rs` → `bin::qrr_audit`.
pub fn module_path(rel: &Path) -> String {
    let mut parts: Vec<&str> = rel
        .iter()
        .filter_map(|c| c.to_str())
        .collect();
    if let Some(last) = parts.last_mut() {
        *last = last.strip_suffix(".rs").unwrap_or(last);
    }
    match parts.last().copied() {
        Some("mod") => {
            parts.pop();
        }
        Some("lib") if parts.len() == 1 => {
            parts.pop();
        }
        _ => {}
    }
    parts.join("::")
}

/// Check a single source text (the fixture-friendly entry point: the
/// self-tests feed synthetic sources through this). `file` is only
/// used for diagnostics; `module` decides allowlist membership.
pub fn check_source(file: &str, module: &str, src: &str) -> Vec<Diagnostic> {
    rules::run_rules(&FileCtx::new(file, module, src))
}

/// Result of [`check_tree`].
#[derive(Debug)]
pub struct TreeReport {
    /// All findings, per-file order then line order.
    pub diagnostics: Vec<Diagnostic>,
    /// How many `.rs` files were scanned.
    pub files_scanned: usize,
}

/// Modules that must contain at least one `no-panic` fence. These are
/// the decode/precondition surfaces the crate promises stay panic-free
/// on attacker-controlled bytes; the anchor check stops a pragma
/// deletion from silently disabling the rule.
const NO_PANIC_ANCHORS: &[&str] =
    &["net::wire", "quant::laq", "net::faults", "compress::pipeline", "control", "fl::shard"];

/// Modules that must contain at least one `no-alloc` fence (the hot
/// kernel loops and the encoder hot path).
const NO_ALLOC_ANCHORS: &[&str] = &["exec::simd", "linalg::matmul", "net::wire"];

/// Walk every `.rs` file under `src_root`, run the registry on each,
/// and verify the anchor fences exist.
pub fn check_tree(src_root: &Path) -> anyhow::Result<TreeReport> {
    let mut files = Vec::new();
    collect_rs(src_root, &mut files)
        .with_context(|| format!("walking {}", src_root.display()))?;
    files.sort();
    let mut diagnostics = Vec::new();
    let mut fences_by_module: Vec<(String, FenceKind)> = Vec::new();
    let mut module_file: BTreeMap<String, String> = BTreeMap::new();
    for path in &files {
        let src = fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let rel = path.strip_prefix(src_root).unwrap_or(path);
        let module = module_path(rel);
        let display = path.display().to_string();
        module_file.entry(module.clone()).or_insert_with(|| display.clone());
        let ctx = FileCtx::new(&display, &module, &src);
        for fence in &ctx.pragmas.fences {
            fences_by_module.push((module.clone(), fence.kind));
        }
        diagnostics.extend(rules::run_rules(&ctx));
    }
    for (kind, anchors) in
        [(FenceKind::NoPanic, NO_PANIC_ANCHORS), (FenceKind::NoAlloc, NO_ALLOC_ANCHORS)]
    {
        for module in anchors {
            let present = fences_by_module.iter().any(|(m, k)| m == module && *k == kind);
            if !present {
                diagnostics.push(Diagnostic {
                    file: module_file.get(*module).cloned().unwrap_or_else(|| module.to_string()),
                    line: 1,
                    rule: rules::RULE_PRAGMA,
                    msg: format!(
                        "module `{module}` must contain at least one `// qrr-audit: {}` fence \
                         (anchor check)",
                        kind.label()
                    ),
                });
            }
        }
    }
    Ok(TreeReport { diagnostics, files_scanned: files.len() })
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    for entry in fs::read_dir(dir).with_context(|| format!("reading dir {}", dir.display()))? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// `qrr audit` / `qrr_audit` entry point.
pub fn run_cli(args: &Args) -> anyhow::Result<()> {
    if args.has_flag("list-rules") {
        print_rules();
        return Ok(());
    }
    let root = args
        .get("root")
        .map(PathBuf::from)
        .unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("src"));
    let report = check_tree(&root)?;
    for d in &report.diagnostics {
        println!("{d}");
    }
    println!(
        "qrr-audit: {} file(s) scanned, {} finding(s)",
        report.files_scanned,
        report.diagnostics.len()
    );
    if args.has_flag("check") && !report.diagnostics.is_empty() {
        anyhow::bail!("qrr-audit --check failed with {} finding(s)", report.diagnostics.len());
    }
    Ok(())
}

fn print_rules() {
    println!("qrr-audit rules:");
    for rule in rules::REGISTRY {
        println!("  {:<14} {}", rule.name, rule.summary);
    }
    println!("  {:<14} malformed fence/allow pragmas are findings themselves", rules::RULE_PRAGMA);
    println!("\npragmas (plain `//` comments):");
    println!("  // qrr-audit: no-alloc | no-panic    open a fence");
    println!("  // qrr-audit: end                    close it");
    println!("  // qrr-audit: allow(<rule>)          suppress <rule> on this line and the next");
}

#[cfg(test)]
mod tests {
    use super::*;

    fn by_rule<'d>(diags: &'d [Diagnostic], rule: &str) -> Vec<&'d Diagnostic> {
        diags.iter().filter(|d| d.rule == rule).collect()
    }

    // ---- unsafe-audit -------------------------------------------------

    #[test]
    fn unsafe_without_safety_fires_twice_outside_allowlist() {
        let src = "fn f(p: *const u8) {\n    unsafe { p.read_volatile() };\n}\n";
        let out = check_source("fixture.rs", "fixture", src);
        let hits = by_rule(&out, rules::RULE_UNSAFE);
        // one finding for the missing SAFETY comment, one for the module
        assert_eq!(hits.len(), 2);
        assert!(hits.iter().all(|d| d.line == 2 && d.file == "fixture.rs"));
        assert!(hits.iter().any(|d| d.msg.contains("SAFETY")));
        assert!(hits.iter().any(|d| d.msg.contains("allowlist")));
    }

    #[test]
    fn unsafe_with_safety_in_allowlisted_module_is_clean() {
        let src = "fn f(p: *const u8) {\n    // SAFETY: p is valid for reads by contract.\n    unsafe { p.read_volatile() };\n}\n";
        assert!(check_source("fixture.rs", "exec::simd", src).is_empty());
    }

    #[test]
    fn trailing_safety_comment_on_the_same_line_counts() {
        let src = "unsafe impl Send for X {} // SAFETY: no shared state.\n";
        assert!(check_source("fixture.rs", "linalg::matmul", src).is_empty());
    }

    #[test]
    fn allow_pragma_suppresses_unsafe_findings_on_next_line() {
        let src = "// qrr-audit: allow(unsafe-audit)\nunsafe impl Send for X {}\n";
        assert!(check_source("fixture.rs", "fixture", src).is_empty());
        // but not two lines down
        let src = "// qrr-audit: allow(unsafe-audit)\nfn g() {}\nunsafe fn f() {}\n";
        let out = check_source("fixture.rs", "fixture", src);
        assert!(out.iter().all(|d| d.line == 3));
        assert!(!out.is_empty());
    }

    // ---- no-alloc -----------------------------------------------------

    #[test]
    fn no_alloc_fence_catches_every_denied_form() {
        let src = r#"fn f() {
    // qrr-audit: no-alloc
    let a = vec![1];
    let b = a.to_vec();
    let c = b.clone();
    let d: Vec<i32> = c.iter().copied().collect();
    let e: Vec<i32> = Vec::new();
    let f = Box::new(0);
    let g = String::from("x");
    let h = format!("{}", 1);
    // qrr-audit: end
    let outside = vec![2];
}
"#;
        let out = check_source("fixture.rs", "fixture", src);
        let hits = by_rule(&out, rules::RULE_NO_ALLOC);
        let lines: Vec<u32> = hits.iter().map(|d| d.line).collect();
        assert_eq!(lines, vec![3, 4, 5, 6, 7, 8, 9, 10]);
        assert!(hits[0].msg.contains("`vec!`"));
        assert!(hits[1].msg.contains("`.to_vec()`"));
        assert!(hits[4].msg.contains("`Vec::new`"));
        assert!(hits[6].msg.contains("`String::from`"));
        // line 12 (`outside`) is past the fence — no finding there
        assert!(out.iter().all(|d| d.line <= 10));
    }

    #[test]
    fn no_alloc_permits_the_borrowed_forms() {
        let src = "fn f(buf: &mut Vec<u8>, s: &[u8]) {\n    // qrr-audit: no-alloc\n    buf.copy_from_slice(s);\n    let x = s.len().min(4);\n    // qrr-audit: end\n}\n";
        assert!(check_source("fixture.rs", "fixture", src).is_empty());
    }

    #[test]
    fn allow_pragma_suppresses_one_alloc_line() {
        let src = "fn f() {\n    // qrr-audit: no-alloc\n    // qrr-audit: allow(no-alloc)\n    let a = vec![1];\n    let b = vec![2];\n    // qrr-audit: end\n}\n";
        let out = check_source("fixture.rs", "fixture", src);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].line, 5);
    }

    // ---- no-panic -----------------------------------------------------

    #[test]
    fn no_panic_fence_catches_unwrap_expect_and_macros() {
        let src = r#"fn f(o: Option<u8>) -> u8 {
    // qrr-audit: no-panic
    let a = o.unwrap();
    let b = o.expect("boom");
    assert!(a == b);
    assert_eq!(a, b);
    if a > 9 { panic!("no"); }
    if b > 9 { unreachable!(); }
    debug_assert!(a <= 9);
    // qrr-audit: end
    o.unwrap()
}
"#;
        let out = check_source("fixture.rs", "fixture", src);
        let hits = by_rule(&out, rules::RULE_NO_PANIC);
        let lines: Vec<u32> = hits.iter().map(|d| d.line).collect();
        // debug_assert! on line 9 is allowed; the unwrap on line 11 is
        // outside the fence
        assert_eq!(lines, vec![3, 4, 5, 6, 7, 8]);
        assert!(hits[0].msg.contains("`.unwrap()`"));
        assert!(hits[2].msg.contains("`assert!`"));
    }

    #[test]
    fn words_in_strings_and_comments_never_fire() {
        let src = "fn f() {\n    // qrr-audit: no-panic\n    let s = \"x.unwrap() panic! vec![]\"; // .unwrap() in prose\n    let t = s.len();\n    // qrr-audit: end\n}\n";
        assert!(check_source("fixture.rs", "fixture", src).is_empty());
    }

    // ---- env-once -----------------------------------------------------

    #[test]
    fn env_var_outside_sanctioned_modules_fires() {
        let src = "fn f() -> Option<String> {\n    std::env::var(\"QRR_X\").ok()\n}\n";
        let out = check_source("fixture.rs", "fl::session", src);
        assert_eq!(out.len(), 1);
        assert_eq!((out[0].line, out[0].rule), (2, rules::RULE_ENV_ONCE));
        assert!(out[0].msg.contains("sanctioned seams"));
        // var_os too
        let src2 = "fn f() { let _ = std::env::var_os(\"X\"); }\n";
        assert_eq!(check_source("fixture.rs", "fl::session", src2).len(), 1);
    }

    #[test]
    fn env_var_in_sanctioned_module_and_env_macro_are_clean() {
        let src = "fn f() -> Option<String> { std::env::var(\"QRR_X\").ok() }\n";
        assert!(check_source("fixture.rs", "util::env", src).is_empty());
        // env!("...") is the compile-time macro, not a process read;
        // set_var/remove_var (test-only mutations) are not reads
        let src2 = "fn f() { let _ = env!(\"CARGO_PKG_VERSION\"); std::env::remove_var(\"X\"); }\n";
        assert!(check_source("fixture.rs", "fl::session", src2).is_empty());
    }

    // ---- pragmas + plumbing -------------------------------------------

    #[test]
    fn unclosed_fence_is_a_finding_and_still_enforced() {
        let src = "fn f(o: Option<u8>) {\n    // qrr-audit: no-panic\n    o.unwrap();\n}\n";
        let out = check_source("fixture.rs", "fixture", src);
        assert!(out.iter().any(|d| d.rule == rules::RULE_PRAGMA && d.line == 2));
        assert!(out.iter().any(|d| d.rule == rules::RULE_NO_PANIC && d.line == 3));
    }

    #[test]
    fn diagnostic_display_is_file_line_rule() {
        let d = Diagnostic {
            file: "src/net/wire.rs".into(),
            line: 42,
            rule: rules::RULE_NO_PANIC,
            msg: "panic path in a no-panic region: `.unwrap()`".into(),
        };
        assert_eq!(
            d.to_string(),
            "src/net/wire.rs:42: [no-panic] panic path in a no-panic region: `.unwrap()`"
        );
    }

    #[test]
    fn module_paths_map_like_the_crate() {
        let m = |s: &str| module_path(Path::new(s));
        assert_eq!(m("net/wire.rs"), "net::wire");
        assert_eq!(m("exec/mod.rs"), "exec");
        assert_eq!(m("lib.rs"), "");
        assert_eq!(m("main.rs"), "main");
        assert_eq!(m("bin/qrr_audit.rs"), "bin::qrr_audit");
    }

    #[test]
    fn the_crate_itself_passes_the_audit() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let report = check_tree(&root).expect("walk src tree");
        assert!(report.files_scanned > 20, "expected the full tree, got {}", report.files_scanned);
        let rendered: Vec<String> =
            report.diagnostics.iter().map(|d| d.to_string()).collect();
        assert!(rendered.is_empty(), "audit findings:\n{}", rendered.join("\n"));
    }
}
