//! The rule registry and pragma/fence machinery for `qrr-audit`.
//!
//! Rules are lexical checks over a [`FileCtx`] — the tokenized source
//! plus per-line classification tables. Regions of interest are marked
//! in the source itself with pragma comments (plain `//` comments, not
//! doc comments):
//!
//! ```text
//! // qrr-audit: no-alloc      open an allocation-free fence
//! // qrr-audit: no-panic      open a panic-free fence
//! // qrr-audit: end           close the open fence
//! // qrr-audit: allow(rule)   suppress `rule` on this line and the next
//! ```
//!
//! Fences do not nest; an unclosed fence is itself a finding (and is
//! still enforced to end-of-file, so forgetting `end` fails closed).
//! The four rules and what they deny are documented on [`REGISTRY`].

use super::lexer::{lex, Tok, Token};
use super::Diagnostic;

/// Rule name: `unsafe` hygiene (SAFETY comments + module allowlist).
pub const RULE_UNSAFE: &str = "unsafe-audit";
/// Rule name: allocation-free fenced regions.
pub const RULE_NO_ALLOC: &str = "no-alloc";
/// Rule name: panic-free fenced regions.
pub const RULE_NO_PANIC: &str = "no-panic";
/// Rule name: environment reads only in sanctioned modules.
pub const RULE_ENV_ONCE: &str = "env-once";
/// Pseudo-rule for malformed pragmas (stray `end`, unclosed fences,
/// unknown directives).
pub const RULE_PRAGMA: &str = "pragma";

/// Every rule name `allow(...)` accepts.
pub const KNOWN_RULES: &[&str] =
    &[RULE_UNSAFE, RULE_NO_ALLOC, RULE_NO_PANIC, RULE_ENV_ONCE, RULE_PRAGMA];

/// Modules allowed to contain `unsafe` at all. Everything else must
/// stay safe Rust — the point is that a reviewer knows exactly where
/// to look.
pub const UNSAFE_MODULES: &[&str] = &["exec::simd", "exec::pool", "linalg::matmul"];

/// Modules allowed to read process environment variables
/// (`std::env::var` / `var_os`). The cached accessors live in
/// `util::env`; the exec seams read their knobs once at dispatch/pool
/// init; `util::logging` reads `QRR_LOG` once.
pub const ENV_MODULES: &[&str] =
    &["exec", "exec::simd", "exec::pool", "util::env", "util::logging"];

/// What kind of fence a pragma opened.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FenceKind {
    /// `// qrr-audit: no-alloc`
    NoAlloc,
    /// `// qrr-audit: no-panic`
    NoPanic,
}

impl FenceKind {
    /// The pragma spelling (also the rule name that polices the fence).
    pub fn label(self) -> &'static str {
        match self {
            FenceKind::NoAlloc => RULE_NO_ALLOC,
            FenceKind::NoPanic => RULE_NO_PANIC,
        }
    }
}

/// One fenced region, inclusive of the pragma lines themselves.
#[derive(Debug, Clone, Copy)]
pub struct Fence {
    /// Fence kind.
    pub kind: FenceKind,
    /// Line of the opening pragma.
    pub start: u32,
    /// Line of the closing pragma (`u32::MAX` when unclosed — the
    /// fence is still enforced to end-of-file).
    pub end: u32,
}

/// Parsed pragma comments of one file.
#[derive(Debug, Default)]
pub struct Pragmas {
    /// Closed (or EOF-truncated) fenced regions.
    pub fences: Vec<Fence>,
    /// `(line, rule)` suppressions: rule findings on `line` and
    /// `line + 1` are dropped.
    pub allows: Vec<(u32, String)>,
    /// Malformed-pragma findings (reported under [`RULE_PRAGMA`]).
    pub errors: Vec<Diagnostic>,
}

/// Tokenized source plus the per-line tables the rules consult.
#[derive(Debug)]
pub struct FileCtx<'a> {
    /// Display path used in diagnostics.
    pub file: String,
    /// `::`-separated module path (`""` for the crate root).
    pub module: String,
    /// Raw source lines (for attribute-line detection).
    pub lines: Vec<&'a str>,
    /// The token stream.
    pub tokens: Vec<Token>,
    /// Parsed pragmas.
    pub pragmas: Pragmas,
    line_code: Vec<bool>,
    line_comment: Vec<bool>,
    line_safety: Vec<bool>,
}

impl<'a> FileCtx<'a> {
    /// Lex `src` and build the line tables and pragmas.
    pub fn new(file: &str, module: &str, src: &'a str) -> FileCtx<'a> {
        let tokens = lex(src);
        let lines: Vec<&str> = src.lines().collect();
        let n = lines
            .len()
            .max(tokens.last().map(|t| t.end_line as usize).unwrap_or(0));
        let mut line_code = vec![false; n];
        let mut line_comment = vec![false; n];
        let mut line_safety = vec![false; n];
        for t in &tokens {
            let span = (t.line as usize - 1)..(t.end_line as usize).min(n);
            let (is_comment, safety) = match &t.tok {
                Tok::LineComment(s) | Tok::BlockComment(s) => {
                    (true, s.contains("SAFETY:") || s.contains("# Safety"))
                }
                _ => (false, false),
            };
            for l in span {
                if is_comment {
                    line_comment[l] = true;
                    line_safety[l] |= safety;
                } else {
                    line_code[l] = true;
                }
            }
        }
        let pragmas = parse_pragmas(file, &tokens);
        FileCtx {
            file: file.to_string(),
            module: module.to_string(),
            lines,
            tokens,
            pragmas,
            line_code,
            line_comment,
            line_safety,
        }
    }

    fn diag(&self, rule: &'static str, line: u32, msg: String) -> Diagnostic {
        Diagnostic { file: self.file.clone(), line, rule, msg }
    }

    fn flag(&self, table: &[bool], line: u32) -> bool {
        table.get(line as usize - 1).copied().unwrap_or(false)
    }

    /// Is the `unsafe` on `line` covered by a SAFETY comment? True when
    /// a comment on the same line, or on the contiguous run of
    /// comment/attribute lines immediately above, contains `SAFETY:` or
    /// `# Safety`. Blank lines and ordinary code lines break the run.
    fn safety_covered(&self, line: u32) -> bool {
        if self.flag(&self.line_safety, line) {
            return true;
        }
        let mut l = line;
        while l > 1 {
            l -= 1;
            if self.flag(&self.line_safety, l) {
                return true;
            }
            if self.flag(&self.line_code, l) {
                let raw = self.lines.get(l as usize - 1).map_or("", |s| s.trim_start());
                if raw.starts_with("#[") || raw.starts_with("#![") {
                    continue; // attributes sit between the comment and the item
                }
                return false;
            }
            if self.flag(&self.line_comment, l) {
                continue; // a multi-line comment: keep looking for its SAFETY line
            }
            return false; // blank line: not "immediately preceding"
        }
        false
    }

    /// Code tokens only (comments stripped), for adjacency matching.
    fn code_tokens(&self) -> Vec<&Token> {
        self.tokens
            .iter()
            .filter(|t| !matches!(t.tok, Tok::LineComment(_) | Tok::BlockComment(_)))
            .collect()
    }

    fn in_fence(&self, kind: FenceKind, line: u32) -> bool {
        self.pragmas
            .fences
            .iter()
            .any(|f| f.kind == kind && f.start <= line && line <= f.end)
    }
}

fn parse_pragmas(file: &str, tokens: &[Token]) -> Pragmas {
    let mut p = Pragmas::default();
    let mut open: Option<(FenceKind, u32)> = None;
    let err = |line: u32, msg: String| Diagnostic {
        file: file.to_string(),
        line,
        rule: RULE_PRAGMA,
        msg,
    };
    for t in tokens {
        let Tok::LineComment(text) = &t.tok else { continue };
        let Some(rest) = text.trim_start().strip_prefix("qrr-audit:") else {
            continue;
        };
        let directive = rest.trim();
        match directive {
            "no-alloc" | "no-panic" => {
                let kind = if directive == "no-alloc" {
                    FenceKind::NoAlloc
                } else {
                    FenceKind::NoPanic
                };
                if let Some((prev, start)) = open.take() {
                    p.errors.push(err(
                        t.line,
                        format!(
                            "fence opened while the `{}` fence from line {start} is still open \
                             (fences do not nest)",
                            prev.label()
                        ),
                    ));
                    p.fences.push(Fence { kind: prev, start, end: t.line });
                }
                open = Some((kind, t.line));
            }
            "end" => match open.take() {
                Some((kind, start)) => p.fences.push(Fence { kind, start, end: t.line }),
                None => {
                    p.errors.push(err(t.line, "`qrr-audit: end` with no open fence".to_string()))
                }
            },
            _ => {
                if let Some(rule) =
                    directive.strip_prefix("allow(").and_then(|s| s.strip_suffix(')'))
                {
                    let rule = rule.trim();
                    if KNOWN_RULES.contains(&rule) {
                        p.allows.push((t.line, rule.to_string()));
                    } else {
                        p.errors.push(err(
                            t.line,
                            format!(
                                "allow({rule}) names an unknown rule (known: {})",
                                KNOWN_RULES.join(", ")
                            ),
                        ));
                    }
                } else {
                    p.errors.push(err(
                        t.line,
                        format!(
                            "unknown qrr-audit directive `{directive}` \
                             (expected no-alloc, no-panic, end, or allow(<rule>))"
                        ),
                    ));
                }
            }
        }
    }
    if let Some((kind, start)) = open {
        p.errors.push(err(
            start,
            format!("`{}` fence is never closed with `qrr-audit: end`", kind.label()),
        ));
        // fail closed: enforce the fence to end-of-file anyway
        p.fences.push(Fence { kind, start, end: u32::MAX });
    }
    p
}

/// One registered rule.
#[derive(Debug)]
pub struct Rule {
    /// Stable rule name (used in diagnostics and `allow(...)`).
    pub name: &'static str,
    /// One-line description for `--list-rules`.
    pub summary: &'static str,
    /// The check itself.
    pub check: fn(&FileCtx) -> Vec<Diagnostic>,
}

/// The rule registry, in reporting order.
pub const REGISTRY: &[Rule] = &[
    Rule {
        name: RULE_UNSAFE,
        summary: "every `unsafe` needs an immediately preceding SAFETY comment, and may \
                  only appear in exec::simd, exec::pool, linalg::matmul",
        check: check_unsafe,
    },
    Rule {
        name: RULE_NO_ALLOC,
        summary: "inside `// qrr-audit: no-alloc` fences: no vec!/format!, .to_vec/.clone/\
                  .collect, Vec::new/Box::new/String::from",
        check: check_no_alloc,
    },
    Rule {
        name: RULE_NO_PANIC,
        summary: "inside `// qrr-audit: no-panic` fences: no .unwrap/.expect or panicking \
                  macros (panic!/assert!/unreachable!/todo!); debug_assert* is allowed",
        check: check_no_panic,
    },
    Rule {
        name: RULE_ENV_ONCE,
        summary: "std::env::var / var_os only in the sanctioned seams (util::env, \
                  util::logging, exec dispatch/pool init)",
        check: check_env_once,
    },
];

fn check_unsafe(ctx: &FileCtx) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let allowed_module = UNSAFE_MODULES.contains(&ctx.module.as_str());
    for t in &ctx.tokens {
        let Tok::Ident(name) = &t.tok else { continue };
        if name != "unsafe" {
            continue;
        }
        if !allowed_module {
            out.push(ctx.diag(
                RULE_UNSAFE,
                t.line,
                format!(
                    "`unsafe` in module `{}`, which is not on the unsafe allowlist ({})",
                    ctx.module,
                    UNSAFE_MODULES.join(", ")
                ),
            ));
        }
        if !ctx.safety_covered(t.line) {
            out.push(ctx.diag(
                RULE_UNSAFE,
                t.line,
                "`unsafe` without an immediately preceding `// SAFETY:` comment \
                 (or `/// # Safety` doc section)"
                    .to_string(),
            ));
        }
    }
    out
}

/// Shared scanner for the two fence rules: flag macro calls
/// (`name!`), method calls (`.name(`), and two-segment paths
/// (`First::second`) inside fences of `kind`.
fn scan_fence(
    ctx: &FileCtx,
    kind: FenceKind,
    rule: &'static str,
    what: &str,
    macros: &[&str],
    methods: &[&str],
    paths: &[(&str, &str)],
) -> Vec<Diagnostic> {
    let code = ctx.code_tokens();
    let punct_at = |i: usize, c: char| matches!(code.get(i), Some(t) if t.tok == Tok::Punct(c));
    let ident_at = |i: usize| match code.get(i) {
        Some(t) => match &t.tok {
            Tok::Ident(s) => Some(s.as_str()),
            _ => None,
        },
        None => None,
    };
    let mut out = Vec::new();
    for (i, t) in code.iter().enumerate() {
        let Tok::Ident(name) = &t.tok else { continue };
        if !ctx.in_fence(kind, t.line) {
            continue;
        }
        let name = name.as_str();
        if macros.contains(&name) && punct_at(i + 1, '!') {
            out.push(ctx.diag(rule, t.line, format!("{what}: `{name}!`")));
        } else if methods.contains(&name) && i > 0 && punct_at(i - 1, '.') {
            out.push(ctx.diag(rule, t.line, format!("{what}: `.{name}()`")));
        } else if let Some((_, second)) = paths.iter().find(|(first, _)| *first == name) {
            if punct_at(i + 1, ':') && punct_at(i + 2, ':') && ident_at(i + 3) == Some(second) {
                out.push(ctx.diag(rule, t.line, format!("{what}: `{name}::{second}`")));
            }
        }
    }
    out
}

fn check_no_alloc(ctx: &FileCtx) -> Vec<Diagnostic> {
    scan_fence(
        ctx,
        FenceKind::NoAlloc,
        RULE_NO_ALLOC,
        "allocation in a no-alloc region",
        &["vec", "format"],
        &["to_vec", "clone", "collect"],
        &[("Vec", "new"), ("Box", "new"), ("String", "from")],
    )
}

fn check_no_panic(ctx: &FileCtx) -> Vec<Diagnostic> {
    scan_fence(
        ctx,
        FenceKind::NoPanic,
        RULE_NO_PANIC,
        "panic path in a no-panic region",
        &["panic", "assert", "assert_eq", "assert_ne", "unreachable", "todo", "unimplemented"],
        &["unwrap", "expect"],
        &[],
    )
}

fn check_env_once(ctx: &FileCtx) -> Vec<Diagnostic> {
    if ENV_MODULES.contains(&ctx.module.as_str()) {
        return Vec::new();
    }
    let code = ctx.code_tokens();
    let mut out = Vec::new();
    for i in 0..code.len() {
        let Tok::Ident(name) = &code[i].tok else { continue };
        if name != "env" {
            continue;
        }
        let is = |j: usize, want: &Tok| matches!(code.get(j), Some(t) if t.tok == *want);
        let reader = match code.get(i + 3).map(|t| &t.tok) {
            Some(Tok::Ident(m)) if m == "var" || m == "var_os" => m.clone(),
            _ => continue,
        };
        if is(i + 1, &Tok::Punct(':')) && is(i + 2, &Tok::Punct(':')) {
            out.push(ctx.diag(
                RULE_ENV_ONCE,
                code[i].line,
                format!(
                    "`std::env::{reader}` in module `{}` — environment reads belong in the \
                     sanctioned seams ({})",
                    ctx.module,
                    ENV_MODULES.join(", ")
                ),
            ));
        }
    }
    out
}

/// Run every registered rule plus the pragma-error findings, apply
/// `allow(...)` suppressions, and return the findings sorted by line.
pub fn run_rules(ctx: &FileCtx) -> Vec<Diagnostic> {
    let mut out: Vec<Diagnostic> = ctx.pragmas.errors.clone();
    for rule in REGISTRY {
        out.extend((rule.check)(ctx));
    }
    out.retain(|d| {
        !ctx.pragmas
            .allows
            .iter()
            .any(|(line, rule)| rule == d.rule && (d.line == *line || d.line == *line + 1))
    });
    out.sort_by(|a, b| (a.line, a.rule, &a.msg).cmp(&(b.line, b.rule, &b.msg)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fences_parse_with_lines() {
        let src = "fn f() {\n// qrr-audit: no-alloc\nlet x = 1;\n// qrr-audit: end\n}\n";
        let ctx = FileCtx::new("t.rs", "m", src);
        assert!(ctx.pragmas.errors.is_empty());
        assert_eq!(ctx.pragmas.fences.len(), 1);
        let f = ctx.pragmas.fences[0];
        assert_eq!((f.kind, f.start, f.end), (FenceKind::NoAlloc, 2, 4));
    }

    #[test]
    fn unclosed_fence_fails_closed() {
        let src = "// qrr-audit: no-panic\nfn f() {}\n";
        let ctx = FileCtx::new("t.rs", "m", src);
        assert_eq!(ctx.pragmas.errors.len(), 1);
        assert!(ctx.pragmas.errors[0].msg.contains("never closed"));
        // the fence still covers the rest of the file
        assert!(ctx.in_fence(FenceKind::NoPanic, 2));
    }

    #[test]
    fn stray_end_and_unknown_directive_are_reported() {
        let src = "// qrr-audit: end\n// qrr-audit: frobnicate\n// qrr-audit: allow(nope)\n";
        let ctx = FileCtx::new("t.rs", "m", src);
        let msgs: Vec<&str> = ctx.pragmas.errors.iter().map(|d| d.msg.as_str()).collect();
        assert_eq!(ctx.pragmas.errors.len(), 3);
        assert!(msgs[0].contains("no open fence"));
        assert!(msgs[1].contains("unknown qrr-audit directive"));
        assert!(msgs[2].contains("unknown rule"));
    }

    #[test]
    fn nested_fence_open_is_reported_and_split() {
        let src = "// qrr-audit: no-alloc\nlet a = 1;\n// qrr-audit: no-panic\nlet b = 2;\n// qrr-audit: end\n";
        let ctx = FileCtx::new("t.rs", "m", src);
        assert_eq!(ctx.pragmas.errors.len(), 1);
        assert!(ctx.pragmas.errors[0].msg.contains("do not nest"));
        // both regions survive: the first truncated at the second open
        assert!(ctx.in_fence(FenceKind::NoAlloc, 2));
        assert!(ctx.in_fence(FenceKind::NoPanic, 4));
        assert!(!ctx.in_fence(FenceKind::NoAlloc, 4));
    }

    #[test]
    fn pragmas_in_strings_and_doc_comments_are_inert() {
        let src = "let s = \"// qrr-audit: no-alloc\";\n/// qrr-audit: no-panic\nfn f() {}\n";
        let ctx = FileCtx::new("t.rs", "m", src);
        assert!(ctx.pragmas.fences.is_empty());
        assert!(ctx.pragmas.errors.is_empty());
    }

    #[test]
    fn safety_walk_skips_attributes_and_stops_at_blank_lines() {
        let covered = "/// # Safety\n/// caller upholds x\n#[inline]\npub unsafe fn f() {}\n";
        let ctx = FileCtx::new("t.rs", "exec::simd", covered);
        assert!(run_rules(&ctx).is_empty());

        let gap = "// SAFETY: stale\n\nunsafe fn f() {}\n";
        let ctx = FileCtx::new("t.rs", "exec::simd", gap);
        let out = run_rules(&ctx);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].line, 3);
        assert_eq!(out[0].rule, RULE_UNSAFE);
    }
}
