//! A minimal, comment- and string-aware Rust tokenizer.
//!
//! `qrr-audit`'s rules are lexical: they match token shapes
//! (`.unwrap` as punct + ident, `vec!` as ident + punct, `env::var` as
//! a path) rather than parsing Rust. What makes that sound is this
//! lexer's classification — the word `unsafe` inside a string literal,
//! a `// comment`, or a doc example must never look like code. The
//! lexer therefore handles the full literal grammar the crate uses:
//! line and (nested) block comments, plain/byte strings with escapes,
//! raw strings with arbitrary `#` fences, char literals vs. lifetimes,
//! and numeric literals.
//!
//! It deliberately does **not** interpret `#[cfg]`, macros, or modules:
//! every token in the file is audited, test code included. Exceptions
//! are expressed in the source via `// qrr-audit: allow(<rule>)`
//! pragmas (see [`super::rules`]), not by the lexer.

/// One lexeme with its classification.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    /// Identifier or keyword.
    Ident(String),
    /// Single punctuation character (operators are not glued).
    Punct(char),
    /// String/char/byte/numeric literal — contents are opaque to rules.
    Lit,
    /// `// …` comment; the payload is everything after the `//`, so a
    /// doc comment `/// x` arrives as `"/ x"` and `//! x` as `"! x"`.
    LineComment(String),
    /// `/* … */` comment (nesting folded into one token).
    BlockComment(String),
}

/// A token plus the 1-indexed source lines it spans.
#[derive(Debug, Clone)]
pub struct Token {
    /// The lexeme.
    pub tok: Tok,
    /// First line of the token.
    pub line: u32,
    /// Last line (differs from `line` only for multi-line literals and
    /// block comments).
    pub end_line: u32,
}

impl Token {
    fn at(tok: Tok, line: u32) -> Self {
        Token { tok, line, end_line: line }
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Tokenize `src`. The lexer never fails: unterminated literals or
/// comments simply end at EOF (the audited tree is compiler-checked
/// anyway, so malformed input only arises in fixtures).
pub fn lex(src: &str) -> Vec<Token> {
    Lexer { chars: src.chars().collect(), pos: 0, line: 1, out: Vec::new() }.run()
}

struct Lexer {
    chars: Vec<char>,
    pos: usize,
    line: u32,
    out: Vec<Token>,
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.pos + ahead).copied()
    }

    /// Advance one char, tracking the line counter.
    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.pos).copied()?;
        self.pos += 1;
        if c == '\n' {
            self.line += 1;
        }
        Some(c)
    }

    fn run(mut self) -> Vec<Token> {
        while let Some(c) = self.peek(0) {
            if c.is_whitespace() {
                self.bump();
            } else if c == '/' && self.peek(1) == Some('/') {
                self.line_comment();
            } else if c == '/' && self.peek(1) == Some('*') {
                self.block_comment();
            } else if c == '"' {
                self.string(false);
            } else if c == '\'' {
                self.char_or_lifetime();
            } else if c.is_ascii_digit() {
                self.number();
            } else if is_ident_start(c) {
                self.ident_or_prefixed_literal();
            } else {
                let line = self.line;
                self.bump();
                self.out.push(Token::at(Tok::Punct(c), line));
            }
        }
        self.out
    }

    fn line_comment(&mut self) {
        let line = self.line;
        self.bump();
        self.bump(); // the two slashes
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                break;
            }
            text.push(c);
            self.bump();
        }
        self.out.push(Token::at(Tok::LineComment(text), line));
    }

    fn block_comment(&mut self) {
        let line = self.line;
        self.bump();
        self.bump(); // "/*"
        let mut depth = 1usize;
        let mut text = String::new();
        while depth > 0 {
            match (self.peek(0), self.peek(1)) {
                (Some('/'), Some('*')) => {
                    depth += 1;
                    text.push_str("/*");
                    self.bump();
                    self.bump();
                }
                (Some('*'), Some('/')) => {
                    depth -= 1;
                    self.bump();
                    self.bump();
                    if depth > 0 {
                        text.push_str("*/");
                    }
                }
                (Some(c), _) => {
                    text.push(c);
                    self.bump();
                }
                (None, _) => break, // unterminated: end at EOF
            }
        }
        self.out.push(Token {
            tok: Tok::BlockComment(text),
            line,
            end_line: self.line,
        });
    }

    /// A `"…"` literal with `\` escapes; `raw` disables escapes (the
    /// body of a no-hash raw string).
    fn string(&mut self, raw: bool) {
        let line = self.line;
        self.bump(); // opening quote
        while let Some(c) = self.bump() {
            if c == '"' {
                break;
            }
            if c == '\\' && !raw {
                self.bump(); // the escaped char (possibly a quote)
            }
        }
        self.out.push(Token { tok: Tok::Lit, line, end_line: self.line });
    }

    /// A raw string body after its `#` fence has been counted: runs to
    /// `"` followed by `hashes` `#` characters.
    fn raw_string(&mut self, hashes: usize) {
        let line = self.line;
        self.bump(); // opening quote
        'outer: while let Some(c) = self.bump() {
            if c == '"' {
                for k in 0..hashes {
                    if self.peek(k) != Some('#') {
                        continue 'outer;
                    }
                }
                for _ in 0..hashes {
                    self.bump();
                }
                break;
            }
        }
        self.out.push(Token { tok: Tok::Lit, line, end_line: self.line });
    }

    /// `'a'` / `'\n'` are char literals; `'a` (no closing quote after
    /// one ident char) is a lifetime, which lexes as punct + ident so
    /// rules never see a phantom literal.
    fn char_or_lifetime(&mut self) {
        let line = self.line;
        match (self.peek(1), self.peek(2)) {
            // escape: always a char literal
            (Some('\\'), _) => {
                self.bump(); // '
                self.bump(); // backslash
                self.bump(); // escaped char
                while let Some(c) = self.bump() {
                    if c == '\'' {
                        break;
                    }
                }
                self.out.push(Token { tok: Tok::Lit, line, end_line: self.line });
            }
            // 'x' — single char closed by a quote
            (Some(_), Some('\'')) => {
                self.bump();
                self.bump();
                self.bump();
                self.out.push(Token::at(Tok::Lit, line));
            }
            // lifetime: consume the quote, let the ident lex normally
            _ => {
                self.bump();
                self.out.push(Token::at(Tok::Punct('\''), line));
            }
        }
    }

    /// Numeric literal: digits plus the alphanumeric soup of suffixes
    /// and bases (`0xFF`, `1_000u64`, `1e9`). A decimal point is part of
    /// the literal only when followed by a digit, so ranges (`0..n`) and
    /// method calls on integers lex as separate tokens.
    fn number(&mut self) {
        let line = self.line;
        while let Some(c) = self.peek(0) {
            if is_ident_continue(c) {
                self.bump();
            } else if c == '.' && self.peek(1).is_some_and(|d| d.is_ascii_digit()) {
                self.bump();
            } else {
                break;
            }
        }
        self.out.push(Token::at(Tok::Lit, line));
    }

    /// An identifier — unless it is a raw/byte string prefix (`r"`,
    /// `r#"`, `b"`, `br#"`, `c"`), in which case the whole literal is
    /// consumed.
    fn ident_or_prefixed_literal(&mut self) {
        let line = self.line;
        let mut name = String::new();
        while let Some(c) = self.peek(0) {
            if is_ident_continue(c) {
                name.push(c);
                self.bump();
            } else {
                break;
            }
        }
        let raw_capable = matches!(name.as_str(), "r" | "br" | "cr");
        let plain_prefix = matches!(name.as_str(), "b" | "c");
        match self.peek(0) {
            Some('"') if raw_capable => self.raw_string(0),
            Some('"') if plain_prefix => self.string(false),
            Some('#') if raw_capable => {
                let mut hashes = 0usize;
                while self.peek(hashes) == Some('#') {
                    hashes += 1;
                }
                if self.peek(hashes) == Some('"') {
                    for _ in 0..hashes {
                        self.bump();
                    }
                    self.raw_string(hashes);
                } else {
                    // r#ident (raw identifier): emit the ident without
                    // the fence
                    self.out.push(Token::at(Tok::Ident(name), line));
                }
            }
            _ => self.out.push(Token::at(Tok::Ident(name), line)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .into_iter()
            .filter_map(|t| match t.tok {
                Tok::Ident(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn code_words_in_comments_and_strings_are_not_idents() {
        let src = "let x = \"unsafe unwrap\"; // unsafe in a comment\n/* unwrap */ call();";
        assert_eq!(idents(src), vec!["let", "x", "call"]);
    }

    #[test]
    fn raw_strings_with_hash_fences() {
        let src = "let s = r#\"unsafe \" still \"# ; next";
        assert_eq!(idents(src), vec!["let", "s", "next"]);
        let src = "let s = r\"unwrap\"; after";
        assert_eq!(idents(src), vec!["let", "s", "after"]);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }";
        let ids = idents(src);
        assert!(ids.iter().filter(|s| s.as_str() == "a").count() >= 3);
        // and a real char literal swallows its quotes
        let ids = idents("let c = 'x'; let q = '\\''; done");
        assert_eq!(ids, vec!["let", "c", "let", "q", "done"]);
    }

    #[test]
    fn line_numbers_track_newlines() {
        let toks = lex("a\nb\n  c");
        let lines: Vec<(String, u32)> = toks
            .into_iter()
            .filter_map(|t| match t.tok {
                Tok::Ident(s) => Some((s, t.line)),
                _ => None,
            })
            .collect();
        assert_eq!(lines, vec![("a".into(), 1), ("b".into(), 2), ("c".into(), 3)]);
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let toks = lex("/* outer /* inner */ still */ after");
        assert!(matches!(toks[0].tok, Tok::BlockComment(_)));
        assert_eq!(idents("/* x */ after"), vec!["after"]);
        let toks = lex("/* a\nb\nc */ z");
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[0].end_line, 3);
    }

    #[test]
    fn doc_comment_payload_keeps_marker() {
        let toks = lex("/// # Safety\nfn f() {}");
        match &toks[0].tok {
            Tok::LineComment(text) => assert_eq!(text, "/ # Safety"),
            other => panic!("expected comment, got {other:?}"),
        }
    }

    #[test]
    fn numbers_do_not_swallow_ranges_or_methods() {
        let src = "for i in 0..n { let y = 1.5; x.max(2) }";
        let ids = idents(src);
        assert!(ids.contains(&"n".to_string()));
        assert!(ids.contains(&"max".to_string()));
    }
}
