//! Gradient quantization (paper §II-B).
//!
//! Implements the LAQ grid quantizer of Sun et al. [22] used by both the
//! SLAQ baseline and the QRR scheme: each tensor is projected onto a
//! 2^β-point evenly-spaced grid centered at the *previous* quantized
//! value, and only the β-bit integer codes plus one f32 radius travel
//! over the wire (32 + βn bits per tensor, eq. (16)).

mod bitpack;
mod laq;

pub use bitpack::{
    pack_codes, pack_codes_into, packed_len_bytes, unpack_codes, unpack_codes_into,
};
pub use laq::{dequantize, quantize, wire_bits, QuantState, Quantized};
