//! The LAQ grid quantizer (paper eq. (13)–(18)).
//!
//! Quantization of a value vector `g` against the previous quantized
//! state `prev`:
//!
//! 1. radius `R = ‖g − prev‖∞` (eq. radius of the grid),
//! 2. codes `q_i = ⌊ (g_i − prev_i + R) / (2τR) + 1/2 ⌋` with
//!    `τ = 1/(2^β − 1)` (eq. (15)), integers in `{0, …, 2^β−1}`,
//! 3. new quantized value `Q_i = prev_i + 2τR·q_i − R` (eq. (16)/(17)).
//!
//! The guarantee `‖g − Q‖∞ ≤ τR` (eq. (18)) is property-tested below.
//!
//! The sweeps are the fused SIMD kernels in [`crate::exec::simd`]
//! (DESIGN.md §8): a vectorized `‖g − prev‖∞` radius scan, then one
//! branchless pass computing grid codes and reconstruction together.
//! The grid math is f64 on every dispatch level with identical
//! rounding, so wire codes do not depend on the level.

use std::cell::RefCell;

use crate::exec::simd;
use crate::tensor::Tensor;

use super::bitpack::{pack_codes_into, packed_len_bytes, unpack_codes, unpack_codes_into};

thread_local! {
    /// Per-thread integer-code scratch shared by [`quantize`] and
    /// [`dequantize`]: the codes are an intermediate (only their packed
    /// form leaves `quantize`; only the reconstruction leaves
    /// `dequantize`), so the round loop re-quantizing the same shapes
    /// every round allocates no code buffer after warm-up.
    static CODE_SCRATCH: RefCell<Vec<u32>> = const { RefCell::new(Vec::new()) };
}

/// A quantized tensor as it travels over the wire: one f32 radius plus
/// β-bit packed codes (32 + βn bits, eq. (16)).
#[derive(Debug, Clone, PartialEq)]
pub struct Quantized {
    /// Grid radius R (f32 on the wire).
    pub radius: f32,
    /// Bits per code.
    pub beta: u8,
    /// Number of elements.
    pub len: usize,
    /// Packed β-bit codes, LSB-first.
    pub packed: Vec<u8>,
}

impl Quantized {
    /// Exact payload size in bits: 32 for the radius + β per element.
    pub fn wire_bits(&self) -> u64 {
        32 + self.beta as u64 * self.len as u64
    }

    /// Unpack the integer codes.
    pub fn codes(&self) -> Vec<u32> {
        unpack_codes(&self.packed, self.len, self.beta)
    }

    /// True when this payload is internally consistent and carries
    /// exactly `expect_len` elements: β on the supported grid, a finite
    /// radius, and packed bytes sized exactly for (len, β). This is the
    /// precondition for dequantizing **peer-controlled** input — the
    /// wire decoder checks syntax only, so servers gate on this before
    /// letting a payload near the asserting dequantize path.
    // This is the gate peer-controlled payloads pass through before
    // the asserting dequantize path — the gate itself must not panic.
    // qrr-audit: no-panic
    pub fn wellformed(&self, expect_len: usize) -> bool {
        self.len == expect_len
            && (1..=16).contains(&self.beta)
            && self.radius.is_finite()
            && self.packed.len() == packed_len_bytes(self.len, self.beta)
    }
    // qrr-audit: end
}

/// Exact wire size of quantizing `n` elements at `beta` bits (eq. (16)).
pub fn wire_bits(n: usize, beta: u8) -> u64 {
    32 + beta as u64 * n as u64
}

/// Per-tensor quantizer state: the previous quantized values `Q_c(θ^{k−1})`
/// kept identically by the client (to center the next grid) and by the
/// server (to apply the innovation, eq. (17)).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantState {
    value: Tensor,
}

impl QuantState {
    /// Initial state: zeros of the given shape (both sides agree on it).
    pub fn zeros(shape: &[usize]) -> Self {
        QuantState { value: Tensor::zeros(shape) }
    }

    /// State from an already-computed quantized tensor (used by callers
    /// that stage a candidate quantization before committing, e.g. the
    /// SLAQ skip rule).
    pub fn from_value(value: Tensor) -> Self {
        QuantState { value }
    }

    /// Current dequantized value.
    pub fn value(&self) -> &Tensor {
        &self.value
    }

    /// Bytes of memory held by this state.
    pub fn mem_bytes(&self) -> usize {
        self.value.len() * std::mem::size_of::<f32>()
    }

    /// Client side: quantize `g` against this state and advance the state
    /// to the new quantized value. Returns the wire message.
    pub fn quantize_update(&mut self, g: &Tensor, beta: u8) -> Quantized {
        let (q, new_val) = quantize(g, &self.value, beta);
        self.value = new_val;
        q
    }

    /// Server side: apply a received message to reproduce the client's new
    /// quantized value (eq. (17)). Returns a reference to it.
    pub fn apply_update(&mut self, msg: &Quantized) -> &Tensor {
        let new_val = dequantize(msg, &self.value);
        self.value = new_val;
        &self.value
    }
}

/// Quantize `g` against `prev`; returns (wire message, new quantized tensor).
///
/// Panics if shapes differ or β ∉ 1..=16.
pub fn quantize(g: &Tensor, prev: &Tensor, beta: u8) -> (Quantized, Tensor) {
    assert_eq!(g.shape(), prev.shape(), "quantize shape mismatch");
    assert!((1..=16).contains(&beta), "beta must be in 1..=16");
    let n = g.len();
    let levels = (1u32 << beta) - 1; // 2^beta - 1

    // R = ||g - prev||_inf — the vectorized radius scan
    let radius = simd::max_abs_diff(g.data(), prev.data());

    CODE_SCRATCH.with(|cell| {
        let mut codes = cell.borrow_mut();
        codes.clear();

        if radius == 0.0 || !radius.is_finite() {
            // Degenerate grid: g == prev exactly (or non-finite input
            // clamped). All codes map to the center; new value = prev.
            let radius = if radius.is_finite() { radius } else { 0.0 };
            let center = levels / 2;
            codes.resize(n, center);
            let mut packed = Vec::new();
            pack_codes_into(&codes, beta, &mut packed);
            return (
                Quantized { radius, beta, len: n, packed },
                prev.clone(),
            );
        }

        // eq. (15)–(17) in one fused sweep: codes + reconstruction
        let mut new_val = Tensor::zeros(g.shape());
        codes.resize(n, 0);
        simd::laq_quantize(
            g.data(),
            prev.data(),
            radius,
            beta,
            &mut codes,
            new_val.data_mut(),
        );
        let mut packed = Vec::new();
        pack_codes_into(&codes, beta, &mut packed);
        debug_assert_eq!(packed.len(), packed_len_bytes(n, beta));
        (
            Quantized { radius, beta, len: n, packed },
            new_val,
        )
    })
}

/// Server-side reconstruction (eq. (17)): previous quantized value plus
/// the decoded innovation.
pub fn dequantize(msg: &Quantized, prev: &Tensor) -> Tensor {
    assert_eq!(msg.len, prev.len(), "dequantize length mismatch");
    let mut out = Tensor::zeros(prev.shape());
    CODE_SCRATCH.with(|cell| {
        let mut codes = cell.borrow_mut();
        unpack_codes_into(&msg.packed, msg.len, msg.beta, &mut codes);
        simd::laq_dequantize(&codes, prev.data(), msg.radius, msg.beta, out.data_mut());
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn error_bound_eq18() {
        // ||g - Q(g)||_inf <= tau * R for random tensors and betas
        let mut rng = Rng::new(40);
        for beta in [1u8, 2, 4, 8, 12] {
            for trial in 0..20 {
                let g = Tensor::randn(&[37], &mut rng);
                let prev = Tensor::randn(&[37], &mut rng);
                let (msg, q) = quantize(&g, &prev, beta);
                let tau = 1.0 / ((1u32 << beta) - 1) as f32;
                let bound = tau * msg.radius * (1.0 + 1e-4) + 1e-7;
                let err = g.sub(&q).max_norm();
                assert!(
                    err <= bound,
                    "beta={beta} trial={trial}: err {err} > bound {bound}"
                );
            }
        }
    }

    #[test]
    fn client_server_state_stay_in_sync() {
        let mut rng = Rng::new(41);
        let shape = [13, 7];
        let mut client = QuantState::zeros(&shape);
        let mut server = QuantState::zeros(&shape);
        for _round in 0..50 {
            let g = Tensor::randn(&shape, &mut rng);
            let msg = client.quantize_update(&g, 8);
            server.apply_update(&msg);
            assert!(
                client.value().rel_err(server.value()) < 1e-6,
                "state diverged"
            );
        }
    }

    #[test]
    fn quantize_dequantize_roundtrip_value() {
        let mut rng = Rng::new(42);
        let g = Tensor::randn(&[100], &mut rng);
        let prev = Tensor::zeros(&[100]);
        let (msg, q_client) = quantize(&g, &prev, 8);
        let q_server = dequantize(&msg, &prev);
        assert!(q_client.rel_err(&q_server) < 1e-7);
    }

    #[test]
    fn zero_innovation_zero_radius() {
        let g = Tensor::vector(vec![1.0, -2.0, 3.0]);
        let (msg, q) = quantize(&g, &g, 8);
        assert_eq!(msg.radius, 0.0);
        assert!(g.rel_err(&q) < 1e-7);
        // dequantize against same prev reproduces prev
        let back = dequantize(&msg, &g);
        assert!(g.rel_err(&back) < 1e-7);
    }

    #[test]
    fn wire_bits_formula() {
        let g = Tensor::zeros(&[1000]);
        let prev = Tensor::zeros(&[1000]);
        let (msg, _) = quantize(&g, &prev, 8);
        assert_eq!(msg.wire_bits(), 32 + 8 * 1000);
        assert_eq!(wire_bits(1000, 8), 8032);
        // vs 32 bits/elem uncompressed: 4x saving at beta=8
        assert!(msg.wire_bits() * 4 < 32 * 1000 + 200);
    }

    #[test]
    fn codes_within_beta_bits() {
        let mut rng = Rng::new(43);
        for beta in [1u8, 3, 8] {
            let g = Tensor::randn(&[64], &mut rng);
            let prev = Tensor::randn(&[64], &mut rng);
            let (msg, _) = quantize(&g, &prev, beta);
            let hi = (1u32 << beta) - 1;
            assert!(msg.codes().iter().all(|&c| c <= hi));
        }
    }

    #[test]
    fn error_shrinks_with_beta() {
        let mut rng = Rng::new(44);
        let g = Tensor::randn(&[512], &mut rng);
        let prev = Tensor::zeros(&[512]);
        let mut last = f32::MAX;
        for beta in [2u8, 4, 8, 12] {
            let (_, q) = quantize(&g, &prev, beta);
            let err = g.sub(&q).fro_norm();
            assert!(err < last, "beta={beta}: {err} !< {last}");
            last = err;
        }
        // at 12 bits the reconstruction is essentially exact
        assert!(last / g.fro_norm() < 1e-3);
    }

    #[test]
    fn repeated_quantization_converges_to_signal() {
        // Quantizing the SAME gradient repeatedly must converge: the grid
        // re-centers on the previous estimate and R shrinks geometrically.
        let mut rng = Rng::new(45);
        let g = Tensor::randn(&[64], &mut rng);
        let mut st = QuantState::zeros(&[64]);
        for _ in 0..20 {
            st.quantize_update(&g, 4);
        }
        assert!(g.rel_err(st.value()) < 1e-4);
    }
}
