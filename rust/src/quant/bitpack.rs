//! β-bit integer packing.
//!
//! The paper's accounting charges β bits per element (eq. (16)); this
//! module makes that real: codes in `{0, …, 2^β−1}` are packed LSB-first
//! into a byte stream, so the serialized payload is exactly
//! ⌈βn/8⌉ bytes.
//!
//! The packers are the word-at-a-time kernels in [`crate::exec::simd`]
//! (u64 bit-buffer, specialized β ∈ {1, 2, 4, 8, 16} fast paths); this
//! module owns the sizing contract. The byte-at-a-time reference the
//! fast paths are property-tested against byte-for-byte lives with the
//! kernels (`exec::simd` tests and `tests/simd_parity.rs`).

use crate::exec::simd;

/// Number of bytes needed to pack `n` codes of `beta` bits each.
pub fn packed_len_bytes(n: usize, beta: u8) -> usize {
    (n * beta as usize).div_ceil(8)
}

/// Pack `codes` (each < 2^beta) into a byte vector, LSB-first.
pub fn pack_codes(codes: &[u32], beta: u8) -> Vec<u8> {
    let mut out = Vec::new();
    pack_codes_into(codes, beta, &mut out);
    out
}

/// [`pack_codes`] into a reusable buffer: `out` is cleared, zero-filled
/// to the packed length and written in place, so steady-state encodes
/// allocate nothing. Delegates to the word-at-a-time kernel
/// ([`crate::exec::simd::pack_codes_into`]).
pub fn pack_codes_into(codes: &[u32], beta: u8, out: &mut Vec<u8>) {
    simd::pack_codes_into(codes, beta, out);
    debug_assert_eq!(out.len(), packed_len_bytes(codes.len(), beta));
}

/// Unpack `n` codes of `beta` bits each from `bytes`.
pub fn unpack_codes(bytes: &[u8], n: usize, beta: u8) -> Vec<u32> {
    let mut out = Vec::new();
    unpack_codes_into(bytes, n, beta, &mut out);
    out
}

/// [`unpack_codes`] into a reusable buffer (cleared first). Delegates to
/// the word-at-a-time kernel
/// ([`crate::exec::simd::unpack_codes_into`]).
pub fn unpack_codes_into(bytes: &[u8], n: usize, beta: u8, out: &mut Vec<u32>) {
    assert!(
        bytes.len() >= packed_len_bytes(n, beta),
        "byte stream too short: {} < {}",
        bytes.len(),
        packed_len_bytes(n, beta)
    );
    simd::unpack_codes_into(bytes, n, beta, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_all_betas() {
        let mut rng = Rng::new(30);
        // long enough to cross several u64 bit-buffer words natively;
        // shrunk under Miri where every load is interpreted
        let n = crate::testing::cases(1000).max(40);
        for beta in 1..=16u8 {
            let max = (1u64 << beta) as usize;
            let codes: Vec<u32> = (0..n).map(|_| rng.below(max) as u32).collect();
            let packed = pack_codes(&codes, beta);
            assert_eq!(packed.len(), packed_len_bytes(codes.len(), beta));
            let back = unpack_codes(&packed, codes.len(), beta);
            assert_eq!(codes, back, "beta={beta}");
        }
    }

    #[test]
    fn exact_sizes() {
        assert_eq!(packed_len_bytes(8, 8), 8);
        assert_eq!(packed_len_bytes(8, 1), 1);
        assert_eq!(packed_len_bytes(9, 1), 2);
        assert_eq!(packed_len_bytes(3, 5), 2); // 15 bits -> 2 bytes
        assert_eq!(packed_len_bytes(0, 8), 0);
    }

    #[test]
    fn into_variants_reuse_buffers() {
        let mut rng = Rng::new(31);
        let mut packed = Vec::new();
        let mut codes_out = Vec::new();
        for beta in [1u8, 7, 8, 13] {
            let max = (1u64 << beta) as usize;
            let n = crate::testing::cases(257).max(33);
            let codes: Vec<u32> = (0..n).map(|_| rng.below(max) as u32).collect();
            pack_codes_into(&codes, beta, &mut packed);
            assert_eq!(packed, pack_codes(&codes, beta), "beta={beta}");
            unpack_codes_into(&packed, codes.len(), beta, &mut codes_out);
            assert_eq!(codes_out, codes, "beta={beta}");
        }
    }

    #[test]
    fn empty_roundtrip() {
        let packed = pack_codes(&[], 8);
        assert!(packed.is_empty());
        assert!(unpack_codes(&packed, 0, 8).is_empty());
    }

    #[test]
    fn boundary_values() {
        for beta in [1u8, 4, 8, 12, 16] {
            let hi = (1u32 << beta) - 1;
            let codes = vec![0, hi, 0, hi, hi];
            let back = unpack_codes(&pack_codes(&codes, beta), codes.len(), beta);
            assert_eq!(codes, back);
        }
    }

    #[test]
    #[should_panic]
    fn beta_zero_rejected() {
        let _ = pack_codes(&[0], 0);
    }
}
