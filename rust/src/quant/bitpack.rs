//! β-bit integer packing.
//!
//! The paper's accounting charges β bits per element (eq. (16)); this
//! module makes that real: codes in `{0, …, 2^β−1}` are packed LSB-first
//! into a byte stream, so the serialized payload is exactly
//! ⌈βn/8⌉ bytes.

/// Number of bytes needed to pack `n` codes of `beta` bits each.
pub fn packed_len_bytes(n: usize, beta: u8) -> usize {
    (n * beta as usize).div_ceil(8)
}

/// Pack `codes` (each < 2^beta) into a byte vector, LSB-first.
pub fn pack_codes(codes: &[u32], beta: u8) -> Vec<u8> {
    let mut out = Vec::new();
    pack_codes_into(codes, beta, &mut out);
    out
}

/// [`pack_codes`] into a reusable buffer: `out` is cleared, zero-filled
/// to the packed length and written in place, so steady-state encodes
/// allocate nothing.
pub fn pack_codes_into(codes: &[u32], beta: u8, out: &mut Vec<u8>) {
    assert!((1..=16).contains(&beta), "beta must be in 1..=16");
    let mask = if beta == 32 { u32::MAX } else { (1u32 << beta) - 1 };
    out.clear();
    out.resize(packed_len_bytes(codes.len(), beta), 0);
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!(c <= mask, "code {c} exceeds {beta} bits");
        let c = (c & mask) as u64;
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let merged = c << off;
        out[byte] |= (merged & 0xFF) as u8;
        if off + beta as usize > 8 {
            out[byte + 1] |= ((merged >> 8) & 0xFF) as u8;
        }
        if off + beta as usize > 16 {
            out[byte + 2] |= ((merged >> 16) & 0xFF) as u8;
        }
        bitpos += beta as usize;
    }
}

/// Unpack `n` codes of `beta` bits each from `bytes`.
pub fn unpack_codes(bytes: &[u8], n: usize, beta: u8) -> Vec<u32> {
    let mut out = Vec::new();
    unpack_codes_into(bytes, n, beta, &mut out);
    out
}

/// [`unpack_codes`] into a reusable buffer (cleared first).
pub fn unpack_codes_into(bytes: &[u8], n: usize, beta: u8, out: &mut Vec<u32>) {
    assert!((1..=16).contains(&beta), "beta must be in 1..=16");
    assert!(
        bytes.len() >= packed_len_bytes(n, beta),
        "byte stream too short: {} < {}",
        bytes.len(),
        packed_len_bytes(n, beta)
    );
    let mask = (1u64 << beta) - 1;
    out.clear();
    out.reserve(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut window = bytes[byte] as u64;
        if byte + 1 < bytes.len() {
            window |= (bytes[byte + 1] as u64) << 8;
        }
        if byte + 2 < bytes.len() {
            window |= (bytes[byte + 2] as u64) << 16;
        }
        out.push(((window >> off) & mask) as u32);
        bitpos += beta as usize;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_all_betas() {
        let mut rng = Rng::new(30);
        for beta in 1..=16u8 {
            let max = (1u64 << beta) as usize;
            let codes: Vec<u32> = (0..1000).map(|_| rng.below(max) as u32).collect();
            let packed = pack_codes(&codes, beta);
            assert_eq!(packed.len(), packed_len_bytes(codes.len(), beta));
            let back = unpack_codes(&packed, codes.len(), beta);
            assert_eq!(codes, back, "beta={beta}");
        }
    }

    #[test]
    fn exact_sizes() {
        assert_eq!(packed_len_bytes(8, 8), 8);
        assert_eq!(packed_len_bytes(8, 1), 1);
        assert_eq!(packed_len_bytes(9, 1), 2);
        assert_eq!(packed_len_bytes(3, 5), 2); // 15 bits -> 2 bytes
        assert_eq!(packed_len_bytes(0, 8), 0);
    }

    #[test]
    fn into_variants_reuse_buffers() {
        let mut rng = Rng::new(31);
        let mut packed = Vec::new();
        let mut codes_out = Vec::new();
        for beta in [1u8, 7, 8, 13] {
            let max = (1u64 << beta) as usize;
            let codes: Vec<u32> = (0..257).map(|_| rng.below(max) as u32).collect();
            pack_codes_into(&codes, beta, &mut packed);
            assert_eq!(packed, pack_codes(&codes, beta), "beta={beta}");
            unpack_codes_into(&packed, codes.len(), beta, &mut codes_out);
            assert_eq!(codes_out, codes, "beta={beta}");
        }
    }

    #[test]
    fn empty_roundtrip() {
        let packed = pack_codes(&[], 8);
        assert!(packed.is_empty());
        assert!(unpack_codes(&packed, 0, 8).is_empty());
    }

    #[test]
    fn boundary_values() {
        for beta in [1u8, 4, 8, 12, 16] {
            let hi = (1u32 << beta) - 1;
            let codes = vec![0, hi, 0, hi, hi];
            let back = unpack_codes(&pack_codes(&codes, beta), codes.len(), beta);
            assert_eq!(codes, back);
        }
    }

    #[test]
    #[should_panic]
    fn beta_zero_rejected() {
        let _ = pack_codes(&[0], 0);
    }
}
