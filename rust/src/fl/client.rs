//! An FL client: local data shard + model backend + update scheme +
//! simulated uplink.

use std::sync::Arc;
use std::time::Duration;

use crate::data::Dataset;
use crate::model::ModelOps;
use crate::net::{ClientUpdate, Encoder, LinkModel};
use crate::util::{PhaseTimes, Rng, Timer};

use super::scheme::ClientScheme;

/// Everything a client reports back for one round.
#[derive(Debug)]
pub struct ClientRoundOutput {
    /// serialized wire message (None = lazily skipped round, or
    /// streaming mode — see `chunks`)
    pub wire: Option<Vec<u8>>,
    /// streamed chunk frames, one per layer, in layer order (streaming
    /// mode only; `wire` is None). The frames carry byte-identical
    /// entry encodings, so `payload_bits` is the same either way.
    pub chunks: Option<Vec<Vec<u8>>>,
    /// the paper's `#bits` for this upload (0 when skipped)
    pub payload_bits: u64,
    /// local mean training loss on this round's batch
    pub train_loss: f32,
    /// simulated uplink transmission time
    pub net_time: Duration,
    /// wall-clock compute time split by phase (grad / encode / serialize)
    pub phases: PhaseTimes,
}

/// One simulated client.
pub struct FlClient {
    /// stable id (also the wire client_id)
    pub id: u32,
    data: Dataset,
    model: Arc<dyn ModelOps + Sync>,
    scheme: Box<dyn ClientScheme>,
    link: LinkModel,
    rng: Rng,
    batch: usize,
    round: u64,
    streaming: bool,
}

impl std::fmt::Debug for FlClient {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FlClient")
            .field("id", &self.id)
            .field("samples", &self.data.len())
            .field("scheme_mem_bytes", &self.scheme.mem_bytes())
            .field("batch", &self.batch)
            .field("round", &self.round)
            .finish_non_exhaustive()
    }
}

impl FlClient {
    /// Assemble a client.
    pub fn new(
        id: u32,
        data: Dataset,
        model: Arc<dyn ModelOps + Sync>,
        scheme: Box<dyn ClientScheme>,
        link: LinkModel,
        batch: usize,
        seed: u64,
    ) -> Self {
        FlClient {
            id,
            data,
            model,
            scheme,
            link,
            rng: Rng::new(seed),
            batch,
            round: 0,
            streaming: false,
        }
    }

    /// Switch the uplink to chunked per-layer framing (DESIGN.md §13):
    /// `round` then fills `chunks` instead of `wire`.
    pub fn set_streaming(&mut self, on: bool) {
        self.streaming = on;
    }

    /// Samples in this client's shard.
    pub fn data_len(&self) -> usize {
        self.data.len()
    }

    /// Scheme state bytes held by this client.
    pub fn scheme_mem_bytes(&self) -> usize {
        self.scheme.mem_bytes()
    }

    /// The client's uplink model.
    pub fn link(&self) -> LinkModel {
        self.link
    }

    /// Swap in a new update scheme (the control plane re-planned this
    /// client's pipeline). The wire round counter is deliberately left
    /// untouched: the server's stale-frame rejection tracks it, and a
    /// spec change must not make fresh frames look like replays.
    pub fn set_scheme(&mut self, scheme: Box<dyn ClientScheme>) {
        self.scheme = scheme;
    }

    /// Run one FL round: sample a batch, compute the local mean gradient,
    /// encode it with the scheme, serialize for the wire.
    pub fn round(&mut self, weights: &[crate::tensor::Tensor]) -> ClientRoundOutput {
        let mut phases = PhaseTimes::new();
        let t = Timer::start();
        let (x, y) = self.data.sample_batch(self.batch, &mut self.rng);
        phases.add("sample", t.elapsed());

        let t = Timer::start();
        let (loss, grads) = self.model.loss_grad(weights, &x, &y);
        phases.add("grad", t.elapsed());

        let t = Timer::start();
        let update: Option<ClientUpdate> = self.scheme.produce(weights, &grads);
        phases.add("encode", t.elapsed());

        let t = Timer::start();
        let (wire, chunks, payload_bits) = match &update {
            Some(u) => {
                let bits = u.payload_bits();
                if self.streaming {
                    (None, Some(Encoder::chunk_frames(u, self.id, self.round)), bits)
                } else {
                    (Some(Encoder::new(u, self.id, self.round)), None, bits)
                }
            }
            None => (None, None, 0),
        };
        phases.add("serialize", t.elapsed());

        let net_time = if payload_bits > 0 {
            self.link.transmit_time(payload_bits)
        } else {
            Duration::ZERO
        };
        self.round += 1;
        ClientRoundOutput { wire, chunks, payload_bits, train_loss: loss, net_time, phases }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::fl::scheme::{make_client_scheme, SchemeKind};
    use crate::model::{native::NativeModel, ModelKind, ModelSpec};

    fn mk_client(kind: SchemeKind) -> (FlClient, Vec<crate::tensor::Tensor>) {
        let spec = ModelSpec::new(ModelKind::Mlp);
        let model: Arc<dyn ModelOps + Sync> = Arc::new(NativeModel::new(ModelKind::Mlp));
        let scheme = make_client_scheme(kind, &spec.shapes(), 8, 0.001, 10);
        let data = synth::mnist_like(64, 1);
        let c = FlClient::new(0, data, model, scheme, LinkModel::broadband(), 16, 2);
        let w = spec.init_params(3);
        (c, w)
    }

    #[test]
    fn round_produces_wire_and_bits_sgd() {
        let (mut c, w) = mk_client(SchemeKind::Sgd);
        let out = c.round(&w);
        assert!(out.wire.is_some());
        // MLP has 159,010 params -> 32 bits each
        assert_eq!(out.payload_bits, 32 * 159_010);
        assert!(out.train_loss.is_finite());
        assert!(out.net_time > Duration::ZERO);
    }

    #[test]
    fn qrr_bits_much_smaller_than_sgd() {
        let (mut c, w) = mk_client(SchemeKind::Qrr { p: 0.1 });
        let out = c.round(&w);
        assert!(out.payload_bits < 32 * 159_010 / 10);
        assert!(out.wire.is_some());
    }

    #[test]
    fn wire_decodes_with_client_id_and_round() {
        let (mut c, w) = mk_client(SchemeKind::Sgd);
        let out1 = c.round(&w);
        let out2 = c.round(&w);
        let d1 = crate::net::Decoder::decode(out1.wire.as_ref().unwrap()).unwrap();
        let d2 = crate::net::Decoder::decode(out2.wire.as_ref().unwrap()).unwrap();
        assert_eq!(d1.client_id, 0);
        assert_eq!(d1.round, 0);
        assert_eq!(d2.round, 1);
    }

    #[test]
    fn streaming_round_ships_chunks_with_identical_bits() {
        let (mut c, w) = mk_client(SchemeKind::Qrr { p: 0.2 });
        let seq = c.round(&w);
        let (mut c2, _) = mk_client(SchemeKind::Qrr { p: 0.2 });
        c2.set_streaming(true);
        let streamed = c2.round(&w);
        assert!(streamed.wire.is_none());
        let chunks = streamed.chunks.unwrap();
        assert!(!chunks.is_empty());
        assert_eq!(streamed.payload_bits, seq.payload_bits);
        // the chunks reassemble to the exact whole-message bytes
        let mut bodies = Vec::new();
        let mut scheme = 0;
        for f in &chunks {
            let (h, b) = crate::net::Decoder::decode_chunk(f).unwrap();
            scheme = h.scheme;
            bodies.push(b);
        }
        let back = crate::net::Decoder::assemble_update(scheme, bodies).unwrap();
        assert_eq!(Encoder::new(&back, 0, 0), seq.wire.unwrap());
    }

    #[test]
    fn phases_recorded() {
        let (mut c, w) = mk_client(SchemeKind::Qrr { p: 0.2 });
        let out = c.round(&w);
        assert!(out.phases.get("grad") > Duration::ZERO);
        assert!(out.phases.rows().len() >= 3);
    }
}
