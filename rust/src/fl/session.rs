//! The composable FL session (DESIGN.md §1): one round loop, five
//! pluggable seams.
//!
//! [`FlSessionBuilder`] → [`FlSession`] composes
//!
//! * a [`ParticipationPolicy`] — who computes each round and whose
//!   upload survives it (full sync, uniform sampling, link-driven
//!   dropout, straggler deadline),
//! * an [`Aggregation`] — how the server combines client contributions
//!   (paper eq. (2) sum, or shard-size-weighted FedAvg mean), applied
//!   in streaming form by the sharded aggregator
//!   ([`crate::fl::shard::ShardedAggregator`], DESIGN.md §10): each
//!   arriving frame is decoded and absorbed on its shard's lane the
//!   moment it completes, so server memory for decoded updates is
//!   O(shards), not O(cohort),
//! * a [`Transport`] binding — how update bytes reach the server
//!   (in-process channel or real TCP, both from
//!   [`crate::net::transport`]); the round loop receives with
//!   [`Transport::recv_timeout`], so a dropped client can never hang a
//!   round,
//! * any number of [`MetricsSink`]s — observers of round/eval metrics
//!   (replacing the old hard-wired `History` plumbing),
//! * per-client compression pipelines (DESIGN.md §7): the uplink spec
//!   resolves from the experiment's
//!   [`SchemeConfig`](crate::config::SchemeConfig) preset or a
//!   [`FlSessionBuilder::uplink`] override, and an optional
//!   [`FlSessionBuilder::downlink`] pipeline makes the session
//!   dual-side — the server broadcasts delta-encoded
//!   [`ServerUpdate`](crate::net::ServerUpdate)s instead of
//!   full-precision parameters.
//!
//! Experiments, examples and `qrr serve` all go through the builder
//! (the old `Coordinator` shim is gone).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::compress::pipeline::{
    BuildCtx, CompressionPipeline, DownlinkDecoder, DownlinkEncoder, PipelineSpec,
};
use crate::config::{
    AggregationConfig, Backend, ExperimentConfig, ParticipationConfig, QuorumConfig,
};
use crate::control::{ClientObservation, CompressionController, ControllerConfig, Outcome};
use crate::data::{self, Dataset};
use crate::exec::ThreadPool;
use crate::model::{native::NativeModel, ModelOps, ModelSpec};
use crate::net::faults::{FaultAction, FaultPlan, FaultyTransport};
use crate::net::transport::{InProcTransport, Transport, TransportError};
use crate::net::{Decoder, Encoder, LinkModel, ServerUpdate};
use crate::tensor::Tensor;
use crate::util::{PhaseTimes, Rng};

use super::metrics::ClientRound;
use super::{
    ClientRoundOutput, EvalPoint, FlClient, FlServer, History, RoundMetrics, ShardedAggregator,
};

/// Byte length of the server-frame header (`SERVER_MAGIC` layout):
/// downlink corruption is injected past it so the frame still routes
/// but the body decode fails, exactly like bit-rot on the wire.
const SERVER_HEADER_LEN: usize = 25;

// ------------------------------------------------------- participation

/// Per-round participation decisions: who computes ([`select`]) and
/// whose computed upload is admitted to the server ([`admit`] — the
/// dropout / straggler axis driven by each client's [`LinkModel`]).
///
/// [`select`]: ParticipationPolicy::select
/// [`admit`]: ParticipationPolicy::admit
pub trait ParticipationPolicy: Send {
    /// Mask of clients that run this round (`true` = participates).
    fn select(&mut self, round: u64, links: &[LinkModel], rng: &mut Rng) -> Vec<bool>;

    /// Whether a computed update survives the uplink. `net_time` is the
    /// client's simulated transmission time for this upload.
    fn admit(
        &mut self,
        client: usize,
        links: &[LinkModel],
        net_time: Duration,
        rng: &mut Rng,
    ) -> bool {
        let _ = (client, links, net_time, rng);
        true
    }

    /// Display label for logs.
    fn label(&self) -> String;
}

/// Every client, every round — the paper's synchronous setting.
#[derive(Debug)]
pub struct FullSync;

impl ParticipationPolicy for FullSync {
    fn select(&mut self, _round: u64, links: &[LinkModel], _rng: &mut Rng) -> Vec<bool> {
        vec![true; links.len()]
    }

    fn label(&self) -> String {
        "full".into()
    }
}

/// Uniformly sample `ceil(fraction · C)` clients per round (partial
/// participation à la Konečný et al.).
#[derive(Debug)]
pub struct UniformSampling {
    /// fraction of clients per round, in (0, 1]
    pub fraction: f64,
}

impl UniformSampling {
    fn sample_mask(fraction: f64, n: usize, rng: &mut Rng) -> Vec<bool> {
        let k = ((fraction * n as f64).ceil() as usize).clamp(1, n);
        let mut mask = vec![false; n];
        for i in rng.sample_indices(n, k) {
            mask[i] = true;
        }
        mask
    }
}

impl ParticipationPolicy for UniformSampling {
    fn select(&mut self, _round: u64, links: &[LinkModel], rng: &mut Rng) -> Vec<bool> {
        Self::sample_mask(self.fraction, links.len(), rng)
    }

    fn label(&self) -> String {
        format!("uniform({})", self.fraction)
    }
}

/// Partial participation plus link-driven upload loss: sampled clients
/// compute, but each upload is lost with probability `drop_prob` scaled
/// by the client's relative link slowness (slowest link in the cohort ⇒
/// the full `drop_prob`, fastest ⇒ never dropped).
#[derive(Debug)]
pub struct LinkDropout {
    /// fraction of clients sampled per round, in (0, 1]
    pub fraction: f64,
    /// upload-loss probability for the slowest link, in [0, 1]
    pub drop_prob: f64,
}

/// Relative slowness of `links[i]` within the cohort, in [0, 1]
/// (1 = slowest, 0 = fastest; 1 when all links are equal).
///
/// Same log-bandwidth normalization as [`LinkModel::adaptive_p`], kept
/// separate because an equal-bandwidth cohort needs a defined value
/// (`adaptive_p` divides by ln(hi/lo) = 0 there). Recomputing the
/// cohort min/max per call is O(C) with C ≈ tens — not worth caching
/// at the cost of policy structs no longer being plain literals.
fn link_slowness(links: &[LinkModel], i: usize) -> f64 {
    let lo = links.iter().map(|l| l.bandwidth_bps).fold(f64::INFINITY, f64::min);
    let hi = links.iter().map(|l| l.bandwidth_bps).fold(0.0f64, f64::max);
    if hi <= lo {
        return 1.0;
    }
    let t = ((links[i].bandwidth_bps.ln() - lo.ln()) / (hi.ln() - lo.ln())).clamp(0.0, 1.0);
    1.0 - t
}

impl ParticipationPolicy for LinkDropout {
    fn select(&mut self, _round: u64, links: &[LinkModel], rng: &mut Rng) -> Vec<bool> {
        UniformSampling::sample_mask(self.fraction, links.len(), rng)
    }

    fn admit(
        &mut self,
        client: usize,
        links: &[LinkModel],
        _net_time: Duration,
        rng: &mut Rng,
    ) -> bool {
        let p_drop = self.drop_prob * link_slowness(links, client);
        rng.f64() >= p_drop
    }

    fn label(&self) -> String {
        format!("dropout({},{})", self.fraction, self.drop_prob)
    }
}

/// Straggler cutoff: every client computes, but uploads whose simulated
/// transmission time exceeds the deadline are discarded.
#[derive(Debug)]
pub struct DeadlineCutoff {
    /// round deadline on the simulated uplink
    pub deadline: Duration,
}

impl ParticipationPolicy for DeadlineCutoff {
    fn select(&mut self, _round: u64, links: &[LinkModel], _rng: &mut Rng) -> Vec<bool> {
        vec![true; links.len()]
    }

    fn admit(
        &mut self,
        _client: usize,
        _links: &[LinkModel],
        net_time: Duration,
        _rng: &mut Rng,
    ) -> bool {
        net_time <= self.deadline
    }

    fn label(&self) -> String {
        format!("deadline({:?})", self.deadline)
    }
}

/// Build the policy an [`ExperimentConfig`] asks for.
pub fn participation_from_config(cfg: &ParticipationConfig) -> Box<dyn ParticipationPolicy> {
    match *cfg {
        ParticipationConfig::Full => Box::new(FullSync),
        ParticipationConfig::Uniform { fraction } => Box::new(UniformSampling { fraction }),
        ParticipationConfig::Dropout { fraction, drop_prob } => {
            Box::new(LinkDropout { fraction, drop_prob })
        }
        ParticipationConfig::Deadline { secs } => {
            Box::new(DeadlineCutoff { deadline: Duration::from_secs_f64(secs) })
        }
    }
}

// --------------------------------------------------------- aggregation

/// How the server combines the per-client gradient contributions into
/// the step direction. `contribs` holds one entry per client (schemes
/// substitute zeros or stale state for clients without a delivered
/// update); `delivered[i]` says whether client `i`'s upload arrived this
/// round; `shard_sizes[i]` is its local dataset size.
pub trait Aggregation: Send {
    /// Combine contributions into the aggregate gradient (the batch
    /// form — unit tests and external callers with all contributions in
    /// hand).
    fn combine(
        &self,
        contribs: Vec<Vec<Tensor>>,
        delivered: &[bool],
        shard_sizes: &[usize],
    ) -> Vec<Tensor>;

    /// Streaming form, used by the sharded round loop: the weight
    /// client `i`'s contribution carries as it is absorbed into its
    /// shard's partial sum (default 1 — plain summation).
    fn client_weight(&self, client: usize, shard_sizes: &[usize]) -> f32 {
        let _ = (client, shard_sizes);
        1.0
    }

    /// Streaming form: whether scheme contributions for clients whose
    /// upload did not arrive (zeros, or SLAQ's stale gradients) enter
    /// the sum. Default `true` — eq. (2) reuses stale state.
    fn include_undelivered(&self) -> bool {
        true
    }

    /// Streaming form: scalar applied once to the tree-reduced
    /// aggregate after the round closes (default 1).
    fn finalize_scale(&self, delivered: &[bool], shard_sizes: &[usize]) -> f32 {
        let _ = (delivered, shard_sizes);
        1.0
    }

    /// Display label.
    fn label(&self) -> &'static str;
}

/// Plain sum over clients — paper eq. (2).
#[derive(Debug)]
pub struct SumAggregation;

/// Sum a non-empty set of per-client gradient lists elementwise.
/// `axpy(1.0, ·)` routes to the SIMD [`crate::exec::simd::sum_into`]
/// kernel (the multiply-free α = 1 fast path) while keeping the
/// per-tensor shape assert.
pub(crate) fn sum_contribs(contribs: Vec<Vec<Tensor>>) -> Vec<Tensor> {
    let mut it = contribs.into_iter();
    let mut acc = it.next().expect("at least one client");
    for grads in it {
        for (a, g) in acc.iter_mut().zip(grads.iter()) {
            a.axpy(1.0, g);
        }
    }
    acc
}

impl Aggregation for SumAggregation {
    fn combine(
        &self,
        contribs: Vec<Vec<Tensor>>,
        _delivered: &[bool],
        _shard_sizes: &[usize],
    ) -> Vec<Tensor> {
        sum_contribs(contribs)
    }

    fn label(&self) -> &'static str {
        "sum"
    }
}

/// Shard-size-weighted mean over the round's **delivered** updates
/// (FedAvg): Σ_{delivered} nᵢ gᵢ / Σ_{delivered} nⱼ, so the weights
/// always sum to 1. Undelivered contributions — including SLAQ's stale
/// gradients, which eq. (2) summation would reuse — are excluded;
/// a round with no deliveries aggregates to zeros (no step).
#[derive(Debug)]
pub struct WeightedMeanAggregation;

impl Aggregation for WeightedMeanAggregation {
    fn combine(
        &self,
        contribs: Vec<Vec<Tensor>>,
        delivered: &[bool],
        shard_sizes: &[usize],
    ) -> Vec<Tensor> {
        let mut denom = 0.0f64;
        for (i, &s) in shard_sizes.iter().enumerate() {
            if delivered[i] {
                denom += s as f64;
            }
        }
        let zero_shapes: Vec<Vec<usize>> = contribs
            .first()
            .map(|grads| grads.iter().map(|t| t.shape().to_vec()).collect())
            .unwrap_or_default();
        let mut acc: Option<Vec<Tensor>> = None;
        for (i, grads) in contribs.into_iter().enumerate() {
            if !delivered[i] || denom <= 0.0 {
                continue;
            }
            let w = (shard_sizes[i] as f64 / denom) as f32;
            match &mut acc {
                None => {
                    let mut g0 = grads;
                    for t in g0.iter_mut() {
                        t.scale(w);
                    }
                    acc = Some(g0);
                }
                Some(a) => {
                    for (t, g) in a.iter_mut().zip(grads.iter()) {
                        t.axpy(w, g);
                    }
                }
            }
        }
        acc.unwrap_or_else(|| zero_shapes.iter().map(|s| Tensor::zeros(s)).collect())
    }

    fn client_weight(&self, client: usize, shard_sizes: &[usize]) -> f32 {
        shard_sizes[client] as f32
    }

    fn include_undelivered(&self) -> bool {
        false
    }

    fn finalize_scale(&self, delivered: &[bool], shard_sizes: &[usize]) -> f32 {
        let mut denom = 0.0f64;
        for (i, &s) in shard_sizes.iter().enumerate() {
            if delivered[i] {
                denom += s as f64;
            }
        }
        if denom > 0.0 {
            (1.0 / denom) as f32
        } else {
            0.0
        }
    }

    fn label(&self) -> &'static str {
        "weighted_mean"
    }
}

/// Build the aggregation an [`ExperimentConfig`] asks for.
pub fn aggregation_from_config(cfg: AggregationConfig) -> Box<dyn Aggregation> {
    match cfg {
        AggregationConfig::Sum => Box::new(SumAggregation),
        AggregationConfig::WeightedMean => Box::new(WeightedMeanAggregation),
    }
}

// ------------------------------------------------------------- metrics

/// Observer of session metrics. All hooks default to no-ops so sinks
/// implement only what they care about.
pub trait MetricsSink: Send {
    /// Called after every round with that round's metrics.
    fn on_round(&mut self, label: &str, m: &RoundMetrics) {
        let _ = (label, m);
    }

    /// Called after every test-set evaluation.
    fn on_eval(&mut self, label: &str, e: &EvalPoint) {
        let _ = (label, e);
    }

    /// Called once when the run finishes, with the full history.
    fn on_finish(&mut self, label: &str, history: &History) {
        let _ = (label, history);
    }
}

/// A [`History`] is itself a sink — hand one in to collect metrics into
/// your own copy.
impl MetricsSink for History {
    fn on_round(&mut self, _label: &str, m: &RoundMetrics) {
        self.rounds.push(m.clone());
    }

    fn on_eval(&mut self, _label: &str, e: &EvalPoint) {
        self.evals.push(e.clone());
    }
}

/// Logs each evaluation point (the default sink; silence with
/// [`FlSessionBuilder::quiet`]).
#[derive(Debug)]
pub struct LogSink;

impl MetricsSink for LogSink {
    fn on_eval(&mut self, label: &str, e: &EvalPoint) {
        log::info!(
            "[{label}] iter {:>5}  test loss {:.4}  acc {:.2}%  bits {}",
            e.iter + 1,
            e.loss,
            100.0 * e.accuracy,
            crate::util::fmt::bits_sci(e.cum_bits)
        );
    }
}

/// Writes the round/eval CSV series when the run finishes (same files
/// as `experiments::write_run_outputs`).
#[derive(Debug)]
pub struct CsvSink {
    dir: String,
    name: String,
}

impl CsvSink {
    /// Emit `<dir>/<name>_rounds.csv`, `<dir>/<name>_evals.csv` and
    /// `<dir>/<name>_clients.csv`.
    pub fn new(dir: impl Into<String>, name: impl Into<String>) -> Self {
        CsvSink { dir: dir.into(), name: name.into() }
    }
}

impl MetricsSink for CsvSink {
    fn on_finish(&mut self, _label: &str, history: &History) {
        let write = || -> std::io::Result<()> {
            std::fs::create_dir_all(&self.dir)?;
            std::fs::write(
                format!("{}/{}_rounds.csv", self.dir, self.name),
                history.rounds_csv(),
            )?;
            if !history.client_rounds.is_empty() {
                std::fs::write(
                    format!("{}/{}_clients.csv", self.dir, self.name),
                    history.clients_csv(),
                )?;
            }
            std::fs::write(
                format!("{}/{}_evals.csv", self.dir, self.name),
                history.evals_csv(),
            )
        };
        if let Err(e) = write() {
            log::warn!("csv sink {}/{}: {e}", self.dir, self.name);
        }
    }
}

// -------------------------------------------------------------- report

/// Outcome of a session run.
#[derive(Debug)]
pub struct RunReport {
    /// metric history (table row + figure series)
    pub history: History,
    /// total client-side scheme memory, bytes
    pub client_mem_bytes: usize,
    /// total server-side scheme memory, bytes
    pub server_mem_bytes: usize,
    /// accumulated per-phase client compute time
    pub phases: PhaseTimes,
}

impl RunReport {
    /// The paper-style single-row markdown table for this run.
    pub fn markdown_table(&self) -> String {
        crate::fl::metrics::markdown_table(&[self.history.table_row()])
    }
}

// ------------------------------------------------------------- builder

/// Builder for [`FlSession`]: starts from an [`ExperimentConfig`] and
/// lets every seam be overridden before [`build`](Self::build).
pub struct FlSessionBuilder {
    cfg: ExperimentConfig,
    model: Option<(ModelSpec, Arc<dyn ModelOps + Sync>)>,
    participation: Option<Box<dyn ParticipationPolicy>>,
    aggregation: Option<Box<dyn Aggregation>>,
    transport: Option<Box<dyn Transport>>,
    recv_timeout: Duration,
    sinks: Vec<Box<dyn MetricsSink>>,
    quiet: bool,
    threads: Option<usize>,
    shards: Option<usize>,
    quorum: Option<QuorumConfig>,
    chaos: Option<FaultPlan>,
    controller: Option<Box<dyn CompressionController>>,
}

impl std::fmt::Debug for FlSessionBuilder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FlSessionBuilder")
            .field("cfg", &self.cfg)
            .field("recv_timeout", &self.recv_timeout)
            .field("sinks", &self.sinks.len())
            .field("quiet", &self.quiet)
            .field("threads", &self.threads)
            .field("shards", &self.shards)
            .finish_non_exhaustive()
    }
}

impl FlSessionBuilder {
    /// Start from an experiment config; every seam defaults from it.
    pub fn new(cfg: &ExperimentConfig) -> Self {
        FlSessionBuilder {
            cfg: cfg.clone(),
            model: None,
            participation: None,
            aggregation: None,
            transport: None,
            recv_timeout: Duration::from_millis(250),
            sinks: Vec::new(),
            quiet: false,
            threads: None,
            shards: None,
            quorum: None,
            chaos: None,
            controller: None,
        }
    }

    /// Inject a model backend (tests / custom runtimes) instead of
    /// constructing one from `cfg.backend`.
    pub fn model(mut self, spec: ModelSpec, model: Arc<dyn ModelOps + Sync>) -> Self {
        self.model = Some((spec, model));
        self
    }

    /// Override the participation policy.
    pub fn participation(mut self, policy: Box<dyn ParticipationPolicy>) -> Self {
        self.participation = Some(policy);
        self
    }

    /// Override the aggregation rule.
    pub fn aggregation(mut self, agg: Box<dyn Aggregation>) -> Self {
        self.aggregation = Some(agg);
        self
    }

    /// Override the transport binding (default: in-process channel).
    pub fn transport(mut self, transport: Box<dyn Transport>) -> Self {
        self.transport = Some(transport);
        self
    }

    /// How long the round loop waits for a missing update before
    /// declaring it lost (default 250 ms).
    pub fn recv_timeout(mut self, timeout: Duration) -> Self {
        self.recv_timeout = timeout;
        self
    }

    /// Attach an additional metrics sink.
    pub fn metrics_sink(mut self, sink: Box<dyn MetricsSink>) -> Self {
        self.sinks.push(sink);
        self
    }

    /// Drop the default [`LogSink`].
    pub fn quiet(mut self) -> Self {
        self.quiet = true;
        self
    }

    /// Size of the session's worker pool (client fan-out, server decode,
    /// evaluation). Default: [`crate::exec::default_threads`], i.e. the
    /// `QRR_THREADS` env override or available parallelism.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n.max(1));
        self
    }

    /// Number of server-side aggregation shards (default: the config's
    /// `shards`, else `min(clients, 8)`). Shard count is independent of
    /// the thread count, so results never depend on parallelism.
    pub fn shards(mut self, n: usize) -> Self {
        self.shards = Some(n.max(1));
        self
    }

    /// Run every client's uplink through this compression pipeline,
    /// overriding the per-client resolution of `cfg.scheme`.
    pub fn uplink(mut self, spec: PipelineSpec) -> Self {
        self.cfg.uplink = Some(spec);
        self
    }

    /// Compress the server broadcast through this pipeline (dual-side
    /// compression): each round ships a delta-encoded
    /// [`ServerUpdate`](crate::net::ServerUpdate) instead of
    /// full-precision parameters, and clients locally reconstruct.
    pub fn downlink(mut self, spec: PipelineSpec) -> Self {
        self.cfg.downlink = Some(spec);
        self
    }

    /// Override the quorum policy: proceed once `fraction` of the
    /// round's selected cohort arrived, re-polling a bounded number of
    /// times with exponential backoff when the first deadline leaves
    /// the quorum unmet (default: the config's `quorum`, else
    /// [`QuorumConfig::default`]).
    pub fn quorum(mut self, q: QuorumConfig) -> Self {
        self.quorum = Some(q);
        self
    }

    /// Run the session under a seeded fault-injection plan: the uplink
    /// transport is wrapped in a [`FaultyTransport`] and the plan's
    /// downlink half is applied to the broadcast bytes each round
    /// (default: the config's `chaos`, else a faithful network).
    pub fn chaos(mut self, plan: FaultPlan) -> Self {
        self.chaos = Some(plan);
        self
    }

    /// Streamed, overlapped rounds (DESIGN.md §13): clients ship each
    /// layer as its own chunk frame the moment it serializes, the
    /// server reassembles decode-on-arrival on its shard lanes, and
    /// round r+1's downlink encode overlaps round r's metrics and
    /// eval on a prefetch thread. Bit-identical to the sequential
    /// default on clean networks — same final parameters, same
    /// `RoundMetrics`, same bit totals.
    pub fn streaming(mut self, on: bool) -> Self {
        self.cfg.streaming = on;
        self
    }

    /// Drive per-client uplink specs through an adaptive compression
    /// controller policy (DESIGN.md §12): each round the policy maps
    /// observed telemetry to `(p, beta)` per client, and the session
    /// swaps the affected pipeline halves between rounds. Takes
    /// precedence over both `cfg.uplink` and the per-client scheme
    /// resolution.
    pub fn controller(mut self, cfg: ControllerConfig) -> Self {
        self.cfg.controller = Some(cfg);
        self
    }

    /// Install a custom [`CompressionController`] implementation instead
    /// of a registry policy (the extensibility seam mirror of
    /// [`Self::participation`] / [`Self::aggregation`]).
    pub fn custom_controller(mut self, controller: Box<dyn CompressionController>) -> Self {
        self.controller = Some(controller);
        self
    }

    /// Assemble the session: load + shard data, build links, per-client
    /// schemes, the server, and wire up the pluggable seams.
    pub fn build(self) -> Result<FlSession> {
        let cfg = self.cfg;
        let (spec, model) = match self.model {
            Some(pair) => pair,
            None => {
                let spec = ModelSpec::new(cfg.model);
                let model: Arc<dyn ModelOps + Sync> = match cfg.backend {
                    Backend::Native => Arc::new(NativeModel::new(cfg.model)),
                    Backend::Pjrt => Arc::new(crate::runtime::PjrtModel::load_default(cfg.model)?),
                };
                (spec, model)
            }
        };

        let (train, test) = data::load(cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed);
        log::info!(
            "dataset {}: {} train / {} test ({}-dim)",
            train.source,
            train.len(),
            test.len(),
            train.dim()
        );
        let shards = match cfg.sharding {
            crate::config::Sharding::Iid => train.shard_iid(cfg.clients, cfg.seed ^ 0x5A5A),
            crate::config::Sharding::LabelSkew(k) => {
                train.shard_label_skew(cfg.clients, k, cfg.seed ^ 0x5A5A)
            }
            crate::config::Sharding::Dirichlet(a) => {
                train.shard_dirichlet(cfg.clients, a, cfg.seed ^ 0x5A5A)
            }
        };
        let links = LinkModel::spread(cfg.clients, cfg.link_slow_bps, cfg.link_fast_bps);
        let shapes = spec.shapes();
        let mut seed_rng = Rng::new(cfg.seed ^ 0xC11E);

        // uplink resolution, in precedence order: a controller policy
        // plans per client from initial (idle) observations; an explicit
        // pipeline spec applies to every client; otherwise the scheme
        // preset resolves per client (adaptive p)
        let mut controller = self
            .controller
            .or_else(|| cfg.controller.map(|c| c.build()));
        let client_specs: Vec<PipelineSpec> = match controller.as_mut() {
            Some(ctrl) => {
                let obs = initial_observations(&links, self.recv_timeout);
                let planned = ctrl.plan(0, &obs);
                ensure!(
                    planned.len() == cfg.clients,
                    "controller planned {} specs for {} clients",
                    planned.len(),
                    cfg.clients
                );
                planned
            }
            None => links
                .iter()
                .map(|link| match &cfg.uplink {
                    Some(s) => s.clone(),
                    None => cfg
                        .scheme
                        .kind_for_client(link, cfg.link_slow_bps, cfg.link_fast_bps)
                        .to_spec(cfg.beta),
                })
                .collect(),
        };

        let mut clients = Vec::with_capacity(cfg.clients);
        let mut shard_sizes = Vec::with_capacity(cfg.clients);
        let mut server_schemes = Vec::with_capacity(cfg.clients);
        let mut pipe_cache: HashMap<String, CompressionPipeline> = HashMap::new();
        let ctx = BuildCtx { alpha: cfg.alpha0(), clients: cfg.clients };
        for (i, (shard, link)) in shards.into_iter().zip(links.iter()).enumerate() {
            let uplink_spec = client_specs[i].clone();
            log::debug!(
                "client {i}: link {:.0} bps, pipeline {}",
                link.bandwidth_bps,
                uplink_spec.format()
            );
            let pipe = pipeline_for(&mut pipe_cache, &uplink_spec, &shapes)?;
            shard_sizes.push(shard.len());
            clients.push(FlClient::new(
                i as u32,
                shard,
                Arc::clone(&model),
                Box::new(pipe.client(&ctx)),
                *link,
                cfg.batch,
                seed_rng.next_u64(),
            ));
            server_schemes.push(Box::new(pipe.server()) as Box<dyn super::ServerScheme>);
        }
        if cfg.streaming {
            for c in &mut clients {
                c.set_streaming(true);
            }
        }

        let params = spec.init_params(cfg.seed ^ 0x1217);
        let model_len: usize = shapes.iter().map(|s| s.iter().product::<usize>()).sum();
        // dual-side: both downlink halves start from the init parameters
        // (agreed out of band), mirrored exactly like the uplink codecs
        let downlink = match &cfg.downlink {
            None => None,
            Some(dl_spec) => {
                log::debug!("downlink pipeline {}", dl_spec.format());
                Some(DownlinkState {
                    encoder: DownlinkEncoder::new(dl_spec, &shapes, &params)?,
                    decoder: DownlinkDecoder::new(dl_spec, &shapes, &params)?,
                })
            }
        };
        // server side splits in two: the slim FlServer owns the central
        // parameters and the descent step, while the sharded aggregator
        // owns the per-client scheme mirrors and the O(shards) streaming
        // absorb (DESIGN.md §10). The shard count is deliberately
        // decoupled from the thread count: it fixes the summation order,
        // so it must not drift with available parallelism.
        let n_shards = self.shards.or(cfg.shards).unwrap_or_else(|| cfg.clients.min(8));
        let aggregator = ShardedAggregator::new(server_schemes, shapes.clone(), n_shards);
        let server = FlServer::new(params, cfg.alpha0());

        let participation = self
            .participation
            .unwrap_or_else(|| participation_from_config(&cfg.participation));
        let aggregation = self
            .aggregation
            .unwrap_or_else(|| aggregation_from_config(cfg.aggregation));
        let quorum = self.quorum.or(cfg.quorum).unwrap_or_default();
        quorum.validate()?;
        let chaos = self.chaos.or_else(|| cfg.chaos.clone());
        let mut transport = self
            .transport
            .unwrap_or_else(|| Box::new(InProcTransport::new()));
        if let Some(plan) = &chaos {
            plan.validate()?;
            log::info!("chaos plan active: {}", plan.format());
            transport = Box::new(FaultyTransport::new(transport, plan.clone()));
        }
        let mut sinks = self.sinks;
        if !self.quiet {
            sinks.insert(0, Box::new(LogSink));
        }
        log::debug!(
            "session: participation={} aggregation={} timeout={:?} quorum={}",
            participation.label(),
            aggregation.label(),
            self.recv_timeout,
            quorum.format()
        );

        let label = match &controller {
            Some(c) => c.label(),
            None => cfg
                .uplink
                .as_ref()
                .map(|s| s.format())
                .unwrap_or_else(|| cfg.scheme.label()),
        };
        let history = History::new(label);
        let round_rng = Rng::new(cfg.seed ^ 0xFAC7);
        let cfg_clients = cfg.clients;
        let streaming = cfg.streaming;
        let downlink_spec = cfg.downlink.clone();
        let pool = ThreadPool::new(self.threads.unwrap_or_else(crate::exec::default_threads));
        Ok(FlSession {
            cfg,
            clients,
            links,
            shard_sizes,
            server,
            aggregator,
            peak_live_max: 0,
            model,
            test,
            participation,
            aggregation,
            transport,
            recv_timeout: self.recv_timeout,
            quorum,
            chaos,
            sinks,
            history,
            phases: PhaseTimes::new(),
            round_rng,
            cum_bits: 0,
            cum_down_bits: 0,
            model_len,
            downlink,
            streaming,
            downlink_prefetch: None,
            client_rounds: vec![0; cfg_clients],
            controller,
            client_specs,
            pipe_cache,
            shapes,
            downlink_spec,
            last_outcomes: vec![Outcome::Idle; cfg_clients],
            last_bits: vec![0; cfg_clients],
            last_net: vec![Duration::ZERO; cfg_clients],
            pool,
        })
    }
}

/// Initial (round-0) controller observations: nothing has been sent
/// yet, so every client reports idle with its static link estimate.
fn initial_observations(links: &[LinkModel], deadline: Duration) -> Vec<ClientObservation> {
    links
        .iter()
        .enumerate()
        .map(|(i, l)| ClientObservation {
            client: i as u32,
            bandwidth_bps: l.bandwidth_bps,
            up_bits: 0,
            net_time: Duration::ZERO,
            deadline,
            outcome: Outcome::Idle,
        })
        .collect()
}

/// Compile-once cache keyed by the canonical spec string: a cohort
/// usually converges on a handful of distinct specs, so spec changes
/// swap pipeline halves without recompiling per client.
fn pipeline_for<'a>(
    cache: &'a mut HashMap<String, CompressionPipeline>,
    spec: &PipelineSpec,
    shapes: &[Vec<usize>],
) -> Result<&'a CompressionPipeline> {
    let key = spec.format();
    if !cache.contains_key(&key) {
        let pipe = CompressionPipeline::compile(spec.clone(), shapes)?;
        cache.insert(key.clone(), pipe);
    }
    Ok(&cache[&key])
}

/// The mirrored downlink codec pair: the server-side delta encoder and
/// the (shared, broadcast) client-side reconstruction.
struct DownlinkState {
    encoder: DownlinkEncoder,
    decoder: DownlinkDecoder,
}

// ------------------------------------------------------------- session

/// The round-loop orchestrator behind every experiment, example and the
/// TCP server. Construct through [`FlSessionBuilder`].
pub struct FlSession {
    cfg: ExperimentConfig,
    clients: Vec<FlClient>,
    links: Vec<LinkModel>,
    shard_sizes: Vec<usize>,
    server: FlServer,
    /// sharded streaming aggregation: scheme mirrors, shard partials
    /// and the absorb-on-complete lanes (DESIGN.md §10)
    aggregator: ShardedAggregator,
    /// session-wide high-water mark of simultaneously live decoded
    /// updates on the server (bounded by the shard count)
    peak_live_max: usize,
    model: Arc<dyn ModelOps + Sync>,
    test: Dataset,
    participation: Box<dyn ParticipationPolicy>,
    aggregation: Box<dyn Aggregation>,
    transport: Box<dyn Transport>,
    recv_timeout: Duration,
    /// quorum semantics: arrival target and bounded re-poll windows
    quorum: QuorumConfig,
    /// seeded fault plan; the uplink half lives in the wrapped
    /// transport, the downlink half is applied to broadcast bytes
    chaos: Option<FaultPlan>,
    sinks: Vec<Box<dyn MetricsSink>>,
    history: History,
    phases: PhaseTimes,
    /// round-level RNG (participation sampling / dropout draws)
    round_rng: Rng,
    cum_bits: u64,
    cum_down_bits: u64,
    /// total parameter count (downlink accounting baseline)
    model_len: usize,
    /// dual-side compression state; `None` = full-precision broadcast
    downlink: Option<DownlinkState>,
    /// streamed rounds (DESIGN.md §13): chunked uplink framing plus the
    /// double-buffered downlink prefetch
    streaming: bool,
    /// the downlink codec state running ahead on a prefetch thread,
    /// carrying round r+1's already-encoded broadcast; joined (and the
    /// state restored) at the next broadcast
    downlink_prefetch: Option<std::thread::JoinHandle<(DownlinkState, ServerUpdate)>>,
    /// how many rounds each client has computed (mirrors the client's
    /// wire `round` counter, used to reject stale/duplicate frames)
    client_rounds: Vec<u64>,
    /// adaptive compression control plane; `None` = specs frozen at build
    controller: Option<Box<dyn CompressionController>>,
    /// the uplink spec currently in force per client (what the metrics
    /// CSV reports and what controller replans diff against)
    client_specs: Vec<PipelineSpec>,
    /// compiled pipelines keyed by canonical spec string, shared across
    /// clients and rounds so replans don't recompile identical specs
    pipe_cache: HashMap<String, CompressionPipeline>,
    /// model parameter shapes (pipeline compilation input)
    shapes: Vec<Vec<usize>>,
    /// the downlink spec currently in force (controller replans diff
    /// against it before rebuilding the mirrored codec pair)
    downlink_spec: Option<PipelineSpec>,
    /// previous round's per-client delivery outcome (controller input)
    last_outcomes: Vec<Outcome>,
    /// previous round's per-client uplink payload bits (controller input)
    last_bits: Vec<u64>,
    /// previous round's per-client modeled transmit time (controller input)
    last_net: Vec<Duration>,
    /// long-lived workers shared by the client fan-out and evaluation —
    /// spawned once per session, not per round (server-side decode runs
    /// on the aggregator's shard lanes instead)
    pool: ThreadPool,
}

impl std::fmt::Debug for FlSession {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FlSession")
            .field("cfg", &self.cfg)
            .field("clients", &self.clients.len())
            .field("server", &self.server)
            .field("model_len", &self.model_len)
            .field("cum_bits", &self.cum_bits)
            .field("cum_down_bits", &self.cum_down_bits)
            .finish_non_exhaustive()
    }
}

impl FlSession {
    /// Session with every seam at its config default.
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Self> {
        FlSessionBuilder::new(cfg).build()
    }

    /// Current central parameters.
    pub fn params(&self) -> &[Tensor] {
        self.server.params()
    }

    /// The simulated clients (read-only).
    pub fn clients(&self) -> &[FlClient] {
        &self.clients
    }

    /// The aggregation server (read-only).
    pub fn server(&self) -> &FlServer {
        &self.server
    }

    /// Number of server-side aggregation shards.
    pub fn n_shards(&self) -> usize {
        self.aggregator.n_shards()
    }

    /// Highest number of decoded client updates simultaneously alive on
    /// the server across all rounds so far. Structurally bounded by
    /// [`Self::n_shards`] — the O(shards) memory claim, observable.
    pub fn peak_live(&self) -> usize {
        self.peak_live_max
    }

    /// Metric history so far.
    pub fn history(&self) -> &History {
        &self.history
    }

    /// Run the configured number of iterations, returning the report.
    pub fn run(&mut self) -> Result<RunReport> {
        let iters = self.cfg.iters;
        for it in 0..iters {
            self.step(it)?;
        }
        // final evaluation if the last round wasn't an eval round
        if self
            .history
            .evals
            .last()
            .map(|e| e.iter + 1 != iters)
            .unwrap_or(true)
        {
            self.evaluate(iters.saturating_sub(1));
        }
        for s in &mut self.sinks {
            s.on_finish(&self.history.label, &self.history);
        }
        Ok(RunReport {
            history: self.history.clone(),
            client_mem_bytes: self.clients.iter().map(|c| c.scheme_mem_bytes()).sum(),
            server_mem_bytes: self.aggregator.mem_bytes(),
            phases: self.phases.clone(),
        })
    }

    /// Send one uplink frame, retrying with exponential backoff plus
    /// jitter when the transport reports [`TransportError::Closed`] —
    /// the client-side reconnect path (DESIGN.md §11). Returns whether
    /// the frame was accepted; non-`Closed` errors propagate.
    fn send_with_retry(&mut self, wire: &[u8]) -> Result<bool> {
        const MAX_SEND_RETRIES: u32 = 3;
        const BASE_RETRY_MS: u64 = 2;
        let mut attempt = 0u32;
        loop {
            match self.transport.send(wire) {
                Ok(()) => return Ok(true),
                Err(e) => {
                    let closed = matches!(
                        e.downcast_ref::<TransportError>(),
                        Some(TransportError::Closed)
                    );
                    if !closed {
                        return Err(e);
                    }
                    attempt += 1;
                    if attempt > MAX_SEND_RETRIES {
                        return Ok(false);
                    }
                    let backoff = BASE_RETRY_MS << (attempt - 1);
                    let jitter = self.round_rng.below(BASE_RETRY_MS as usize) as u64;
                    log::debug!(
                        "send hit closed transport, retry {attempt}/{MAX_SEND_RETRIES} \
                         in {}ms",
                        backoff + jitter
                    );
                    std::thread::sleep(Duration::from_millis(backoff + jitter));
                }
            }
        }
    }

    /// The uplink spec currently in force for each client.
    pub fn client_specs(&self) -> &[PipelineSpec] {
        &self.client_specs
    }

    /// Feed last round's per-client observations to the controller and
    /// swap the pipeline halves of every client whose planned spec
    /// differs from the one in force. Runs strictly between rounds —
    /// after the previous [`ShardedAggregator::close_round`] and before
    /// this round's broadcast/compute — so client and mirror always
    /// change in lockstep and no in-flight frame straddles a swap
    /// (including across quorum re-polls, which live inside a round).
    fn replan(&mut self, it: u64) -> Result<()> {
        if self.controller.is_none() {
            return Ok(());
        }
        let n = self.clients.len();
        let obs: Vec<ClientObservation> = (0..n)
            .map(|i| ClientObservation {
                client: i as u32,
                bandwidth_bps: self.links[i].bandwidth_bps,
                up_bits: self.last_bits[i],
                net_time: self.last_net[i],
                deadline: self.recv_timeout,
                outcome: self.last_outcomes[i],
            })
            .collect();
        let Some(ctrl) = self.controller.as_mut() else { return Ok(()) };
        let specs = ctrl.plan(it, &obs);
        let dl_spec = ctrl.plan_downlink(it, &obs);
        ensure!(
            specs.len() == n,
            "controller planned {} specs for {n} clients at round {it}",
            specs.len()
        );
        let ctx = BuildCtx { alpha: self.cfg.alpha0(), clients: n };
        for (i, spec) in specs.into_iter().enumerate() {
            if spec == self.client_specs[i] {
                continue;
            }
            let pipe = pipeline_for(&mut self.pipe_cache, &spec, &self.shapes)?;
            self.clients[i].set_scheme(Box::new(pipe.client(&ctx)));
            self.aggregator.replace_scheme(i, Box::new(pipe.server()));
            log::debug!(
                "round {it}: client {i} pipeline {} -> {}",
                self.client_specs[i].format(),
                spec.format()
            );
            self.client_specs[i] = spec;
        }
        if let Some(dl) = dl_spec {
            if self.downlink_spec.as_ref() != Some(&dl) {
                dl.validate_downlink()?;
                // both halves restart from the current central
                // parameters, agreed out of band exactly like the
                // build-time pair — no stale shadow state survives
                let params = self.server.params();
                self.downlink = Some(DownlinkState {
                    encoder: DownlinkEncoder::new(&dl, &self.shapes, params)?,
                    decoder: DownlinkDecoder::new(&dl, &self.shapes, params)?,
                });
                log::info!("round {it}: downlink pipeline -> {}", dl.format());
                self.downlink_spec = Some(dl);
            }
        }
        Ok(())
    }

    /// Execute a single FL iteration: select → parallel client compute →
    /// transport → decode → aggregate → descent step → metrics.
    pub fn step(&mut self, it: u64) -> Result<()> {
        // learning-rate schedule
        let alpha = self.cfg.alpha_at(it);
        if self.server.alpha() != alpha {
            log::info!("iteration {it}: learning rate -> {alpha}");
            self.server.set_alpha(alpha);
        }

        // adaptive compression: re-plan per-client specs from last
        // round's observations (round 0 was planned at build time)
        if it > 0 {
            self.replan(it)?;
        }

        // broadcast. Without a downlink pipeline, clients share a handle
        // to the central parameters — a refcount bump, not a model copy —
        // and the accounting charges the full-precision parameter size.
        // With one, the server delta-encodes through its pipeline into a
        // versioned ServerUpdate, the bytes cross the real wire codec,
        // and the clients' (shared) decoder locally reconstructs. The
        // downlink half of the chaos plan acts here: a dropped or
        // corrupted broadcast leaves the clients on last round's
        // parameters, and the sequence gap the next delta reveals is
        // healed by a full snapshot resync (DESIGN.md §11).
        let mut down_bits = 32 * self.model_len as u64;
        let mut resyncs = 0u32;
        let down_action = self
            .chaos
            .as_ref()
            .map_or(FaultAction::Deliver, |p| p.down_action(it));
        // streamed rounds: this round's broadcast may already be encoded
        // on the prefetch thread (spawned after last round's descent
        // step, overlapping its metrics and eval). Join it and restore
        // the codec state first — the thread saw the exact parameters
        // the sequential path would encode and the encode-then-snapshot
        // order is preserved, so the bytes are bit-identical.
        let mut prefetched: Option<ServerUpdate> = None;
        if let Some(handle) = self.downlink_prefetch.take() {
            let (state, upd) = handle
                .join()
                .map_err(|_| anyhow::anyhow!("downlink prefetch thread panicked"))?;
            self.downlink = Some(state);
            prefetched = Some(upd);
        }
        let weights: Arc<Vec<Tensor>> = match &mut self.downlink {
            // downlink faults need a downlink pipeline to matter: with a
            // full-precision broadcast the clients hold no decoder state
            // a lost frame could desynchronize
            None => self.server.params_shared(),
            Some(dl) => {
                let upd = match prefetched.take() {
                    Some(u) => u,
                    None => dl.encoder.encode(self.server.params(), it),
                };
                down_bits = upd.payload_bits();
                if down_action == FaultAction::Drop {
                    // broadcast lost in flight: train on stale params
                    log::debug!("round {it}: broadcast dropped by chaos plan");
                    Arc::new(dl.decoder.params().to_vec())
                } else {
                    let mut bytes = Encoder::server(&upd);
                    if down_action == FaultAction::Corrupt {
                        FaultPlan::corrupt_in_place(&mut bytes, SERVER_HEADER_LEN);
                    }
                    match Decoder::decode_server(&bytes) {
                        Ok(decoded) if dl.decoder.needs_resync(&decoded) => {
                            // the shared decoder saw a sequence gap (an
                            // earlier broadcast never landed): ship a full
                            // snapshot instead of the gap-revealing delta,
                            // charging its bits to the downlink
                            let snap = dl.encoder.snapshot(it);
                            let snap_bytes = Encoder::server(&snap);
                            let snap_dec = Decoder::decode_server(&snap_bytes)?;
                            down_bits += snap.payload_bits();
                            resyncs += 1;
                            log::info!(
                                "round {it}: downlink gap detected, snapshot resync ({} bits)",
                                snap.payload_bits()
                            );
                            Arc::new(dl.decoder.apply_snapshot(&snap_dec)?.to_vec())
                        }
                        Ok(decoded) => Arc::new(dl.decoder.apply(&decoded)?.to_vec()),
                        Err(e) => {
                            // corrupted in flight: the decoder never sees
                            // the frame, clients stay on stale params; the
                            // seq gap triggers the snapshot path next round
                            log::debug!("round {it}: broadcast undecodable in flight ({e})");
                            Arc::new(dl.decoder.params().to_vec())
                        }
                    }
                }
            }
        };

        // participation: who computes this round
        let n = self.clients.len();
        let active = self.participation.select(it, &self.links, &mut self.round_rng);
        debug_assert_eq!(active.len(), n);

        // parallel client execution (selected clients only) on the
        // session's persistent worker pool
        let outputs: Vec<Option<ClientRoundOutput>> = {
            let mut slots: Vec<Option<ClientRoundOutput>> = (0..n).map(|_| None).collect();
            let weights = &weights;
            let slot_cells: Vec<Mutex<&mut Option<ClientRoundOutput>>> =
                slots.iter_mut().map(Mutex::new).collect();
            let client_cells: Vec<Mutex<&mut FlClient>> =
                self.clients.iter_mut().map(Mutex::new).collect();
            let active = &active;
            self.pool.for_each(n, |i| {
                if !active[i] {
                    return;
                }
                let mut client = client_cells[i].lock().unwrap();
                let out = client.round(weights.as_slice());
                **slot_cells[i].lock().unwrap() = Some(out);
            });
            drop(client_cells);
            slots
        };
        // release the broadcast handle so the descent step below mutates
        // the parameters in place instead of copy-on-write cloning them
        drop(weights);

        // the wire `round` each produced frame will carry: the client's
        // local round counter before this round's increment (it drifts
        // from `it` under partial participation)
        let mut expected_round: Vec<Option<u64>> = vec![None; n];
        for (i, out) in outputs.iter().enumerate() {
            if out.is_some() {
                expected_round[i] = Some(self.client_rounds[i]);
                self.client_rounds[i] += 1;
            }
        }

        // open the sharded aggregation round: per-client weights and the
        // silent-member policy come from the aggregation seam, so the
        // streaming absorb computes the same sum `combine` would
        let agg_weights: Vec<f32> = (0..n)
            .map(|i| self.aggregation.client_weight(i, &self.shard_sizes))
            .collect();
        self.aggregator
            .begin_round(&agg_weights, self.aggregation.include_undelivered());

        // uplink: admitted updates enter the transport; a policy-dropped
        // upload is simply never sent and is not waited for. A send that
        // hits a closed transport retries with backoff (the reconnect
        // path); exhausting the retries drops the upload like a policy
        // loss, so one dead client can never abort the round.
        let mut sent = 0usize;
        let mut sent_mask = vec![false; n];
        let mut clients_dropped = 0u32;
        for (i, out) in outputs.iter().enumerate() {
            let Some(out) = out else { continue };
            if out.wire.is_none() && out.chunks.is_none() {
                continue; // lazily skipped round: nothing to ship
            }
            if self
                .participation
                .admit(i, &self.links, out.net_time, &mut self.round_rng)
            {
                let accepted = if let Some(wire) = &out.wire {
                    self.send_with_retry(wire)?
                } else {
                    // streamed upload: the layer chunks leave in order;
                    // a mid-stream transport loss drops the remainder
                    // and the server's gap discipline leaves the update
                    // undelivered — all-or-nothing, like the whole frame
                    let mut all = true;
                    for f in out.chunks.as_deref().unwrap_or(&[]) {
                        if !self.send_with_retry(f)? {
                            all = false;
                            break;
                        }
                    }
                    all
                };
                if accepted {
                    sent += 1;
                    sent_mask[i] = true;
                } else {
                    log::debug!(
                        "round {it}: client {i} upload lost (transport closed after retries)"
                    );
                    clients_dropped += 1;
                }
            } else {
                log::debug!("round {it}: client {i} upload lost (participation policy)");
                clients_dropped += 1;
            }
        }

        // server side: collect what actually arrived. Deadlines bound
        // the collection — discarded junk frames must not refresh the
        // budget, or a misbehaving peer re-sending garbage could hold
        // the round open forever. The quorum policy decides what a
        // shortfall at the deadline costs: the round proceeds once the
        // arrival target is met, and a shortfall below it buys at most
        // `max_repolls` exponentially backed-off extra windows before
        // the round proceeds without the stragglers (DESIGN.md §11).
        // Routing is header-only (`peek_header`): the body decode and
        // the scheme absorb run on the frame's shard lane while this
        // loop keeps draining the transport, so at most `n_shards`
        // decoded updates are ever alive at once.
        let n_selected = active.iter().filter(|a| **a).count();
        let min_arrivals = (self.quorum.fraction * n_selected as f64).ceil() as usize;
        let quorum_target = min_arrivals.min(sent);
        // streamed mode: per-client layer bitsets — a client counts as
        // received once every distinct layer's chunk has landed. The
        // shard assembly tracks its own gaps; this mirror only drives
        // the quorum / deadline accounting up here.
        let n_layers = self.shapes.len();
        let mut seen_layers: Vec<Vec<bool>> = if self.streaming {
            vec![vec![false; n_layers]; n]
        } else {
            Vec::new()
        };
        let mut seen_count = vec![0usize; n];
        let mut dispatched = vec![false; n];
        let mut late = vec![false; n];
        let mut received = 0usize;
        let mut clients_late = 0u32;
        let mut repolls = 0u32;
        let first_deadline = Instant::now() + self.recv_timeout;
        let mut deadline = first_deadline;
        while received < sent {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                if received >= quorum_target || repolls >= self.quorum.max_repolls {
                    log::debug!(
                        "round {it}: {} upload(s) missing after {} re-poll(s); \
                         proceeding without them",
                        sent - received,
                        repolls
                    );
                    break;
                }
                repolls += 1;
                let base = self.quorum.base_backoff_ms << (repolls - 1).min(16);
                let jitter_span = (self.quorum.base_backoff_ms / 4).max(1) as usize;
                let jitter = self.round_rng.below(jitter_span) as u64;
                let window = Duration::from_millis(base + jitter);
                log::debug!(
                    "round {it}: {received}/{sent} uploads at deadline (quorum target \
                     {quorum_target}), re-poll {repolls} for {window:?}"
                );
                deadline = Instant::now() + window;
                continue;
            }
            match self.transport.recv_timeout(remaining) {
                Ok(frame) => {
                    // a frame only an external peer controls must never
                    // abort the run: garbage, unknown senders, stale
                    // rounds and duplicates are all discarded, exactly
                    // like a lost frame
                    if self.streaming {
                        let header = match Decoder::peek_chunk_header(&frame) {
                            Ok(h) => h,
                            Err(e) => {
                                log::warn!("round {it}: discarding undecodable chunk ({e})");
                                continue;
                            }
                        };
                        let id = header.client_id as usize;
                        if id >= n {
                            log::warn!(
                                "round {it}: discarding chunk with out-of-range client id {id}"
                            );
                            continue;
                        }
                        if expected_round[id] != Some(header.round) || dispatched[id] {
                            log::warn!(
                                "round {it}: discarding unexpected chunk from client {id} \
                                 (frame round {}, expected {:?})",
                                header.round,
                                expected_round[id]
                            );
                            continue;
                        }
                        let layer = header.layer as usize;
                        if layer >= n_layers {
                            log::warn!(
                                "round {it}: discarding chunk with out-of-spec layer {layer} \
                                 from client {id}"
                            );
                            continue;
                        }
                        if !seen_layers[id][layer] {
                            seen_layers[id][layer] = true;
                            seen_count[id] += 1;
                        }
                        // every admitted chunk reaches the client's shard
                        // lane: reassembly there absorbs on the last gap
                        // fill, tolerates out-of-order arrival, and counts
                        // duplicates once per (client, layer)
                        self.aggregator.dispatch_chunk(id, frame);
                        if seen_count[id] == n_layers {
                            received += 1;
                            if Instant::now() >= first_deadline {
                                clients_late += 1;
                                late[id] = true;
                            }
                            dispatched[id] = true;
                        }
                        continue;
                    }
                    let header = match Decoder::peek_header(&frame) {
                        Ok(h) => h,
                        Err(e) => {
                            log::warn!("round {it}: discarding undecodable frame ({e})");
                            continue;
                        }
                    };
                    let id = header.client_id as usize;
                    if id >= n {
                        log::warn!(
                            "round {it}: discarding frame with out-of-range client id {id}"
                        );
                        continue;
                    }
                    // a late frame from a past round (straggler drained
                    // by a later accept) or a duplicate must not enter
                    // this round's aggregate or scheme mirrors
                    if expected_round[id] != Some(header.round) || dispatched[id] {
                        log::warn!(
                            "round {it}: discarding unexpected frame from client {id} \
                             (frame round {}, expected {:?})",
                            header.round,
                            expected_round[id]
                        );
                        continue;
                    }
                    received += 1;
                    if Instant::now() >= first_deadline {
                        clients_late += 1;
                        late[id] = true;
                    }
                    dispatched[id] = true;
                    self.aggregator.dispatch_frame(id, frame);
                }
                // an empty window is not the end of the round: the
                // deadline check at the loop top decides whether to
                // proceed or open a re-poll window
                Err(TransportError::TimedOut(_)) => continue,
                Err(e) => return Err(e.into()),
            }
        }
        // close the round: in-flight absorbs drain, silent members
        // advance their mirrors, shard partials tree-reduce. `delivered`
        // comes from the digest — a frame that passed the header peek
        // but failed the body decode on its lane stays undelivered.
        let digest = self.aggregator.close_round();
        let delivered = digest.delivered;
        let failed = digest.failed;
        self.peak_live_max = self.peak_live_max.max(digest.peak_live);

        // a streamed client can be both gappy (never completed up here)
        // and corrupt (a bad chunk failed it on its shard lane); count
        // it corrupt, not timed out, so the outcome partition stays
        // exact. In whole-message mode failed ⊆ dispatched, so this is
        // bit-identical to the old `sent - received`.
        let clients_timed_out = (0..n)
            .filter(|&i| sent_mask[i] && !dispatched[i] && !failed[i])
            .count() as u32;

        // metrics: bits/comms count what the server actually received;
        // the synchronous round time is the slowest delivered upload
        let mut bits = 0u64;
        let mut comms = 0u32;
        let mut loss_sum = 0f64;
        let mut participants = 0usize;
        let mut net_time = Duration::ZERO;
        for (i, out) in outputs.iter().enumerate() {
            let Some(out) = out else { continue };
            participants += 1;
            loss_sum += out.train_loss as f64;
            self.phases.merge(&out.phases);
            if delivered[i] {
                bits += out.payload_bits;
                comms += 1;
                net_time = net_time.max(out.net_time);
            }
        }

        // per-client telemetry: classify each upload's outcome, record
        // the (p, beta, bits) series behind the per-policy frontier, and
        // stash the observations the controller replans from next round
        for i in 0..n {
            let (payload_bits, client_net, computed) = match &outputs[i] {
                Some(o) => (o.payload_bits, o.net_time, o.wire.is_some() || o.chunks.is_some()),
                None => (0, Duration::ZERO, false),
            };
            let outcome = if !computed {
                Outcome::Idle
            } else if !sent_mask[i] {
                Outcome::Dropped
            } else if delivered[i] {
                if late[i] {
                    Outcome::Late
                } else {
                    Outcome::Delivered
                }
            } else if dispatched[i] || failed[i] {
                Outcome::Corrupt
            } else {
                Outcome::TimedOut
            };
            let (p, beta) = self.client_specs[i].knobs();
            self.history.client_rounds.push(ClientRound {
                iter: it,
                client: i as u32,
                p,
                beta,
                bits: payload_bits,
                outcome,
            });
            self.last_outcomes[i] = outcome;
            self.last_bits[i] = payload_bits;
            self.last_net[i] = client_net;
        }

        // finalize: the aggregation seam's closing scalar (1 for sum,
        // 1/Σ delivered shard sizes for the weighted mean) → descent step
        let scale = self.aggregation.finalize_scale(&delivered, &self.shard_sizes);
        let mut agg = digest.aggregate;
        if scale != 1.0 {
            for t in agg.iter_mut() {
                t.scale(scale);
            }
        }
        let grad_norm = self.server.apply_aggregate(&agg);

        // streamed rounds: kick round it+1's downlink encode onto a
        // prefetch thread so it overlaps this round's metrics and eval
        // (double-buffered broadcast, DESIGN.md §13). Gated off under a
        // controller, whose replan may rebuild the codec pair before
        // the next broadcast would consume this work.
        if self.streaming && self.controller.is_none() {
            if let Some(dl) = self.downlink.take() {
                let params = self.server.params_shared();
                let next = it + 1;
                self.downlink_prefetch = Some(std::thread::spawn(move || {
                    let mut dl = dl;
                    let upd = dl.encoder.encode(params.as_slice(), next);
                    (dl, upd)
                }));
            }
        }

        self.cum_bits += bits;
        self.cum_down_bits += down_bits;
        // total compression ratio: this round's shipped bits vs the
        // full-precision cost of the same traffic pattern (comms uploads
        // + one broadcast) — 1.0 for the uncompressed baseline
        let full_bits = 32 * self.model_len as u64;
        let m = RoundMetrics {
            iter: it,
            train_loss: (loss_sum / participants.max(1) as f64) as f32,
            bits,
            down_bits,
            ratio: (bits + down_bits) as f64 / ((comms as u64 + 1) * full_bits) as f64,
            comms,
            grad_norm,
            net_time,
            clients_dropped,
            clients_timed_out,
            clients_corrupt: digest.decode_failures as u32,
            clients_late,
            resyncs,
        };
        for s in &mut self.sinks {
            s.on_round(&self.history.label, &m);
        }
        self.history.rounds.push(m);

        if (it + 1) % self.cfg.eval_every == 0 {
            self.evaluate(it);
        }
        Ok(())
    }

    /// Evaluate the central model on the test set and record the point.
    fn evaluate(&mut self, it: u64) {
        let params = self.server.params_shared();
        let chunk = 512usize;
        let chunks: Vec<(Tensor, Vec<u32>)> = self.test.chunks(chunk).collect();
        let results: Vec<Mutex<(f64, usize, usize)>> =
            chunks.iter().map(|_| Mutex::new((0.0, 0, 0))).collect();
        let model = &self.model;
        let params = &params;
        self.pool.for_each(chunks.len(), |i| {
            let (x, y) = &chunks[i];
            let (loss, correct) = model.eval(params.as_slice(), x, y);
            *results[i].lock().unwrap() = (loss as f64 * y.len() as f64, correct, y.len());
        });
        let (mut loss_sum, mut correct, mut total) = (0f64, 0usize, 0usize);
        for r in results {
            let (l, c, t) = r.into_inner().unwrap();
            loss_sum += l;
            correct += c;
            total += t;
        }
        let point = EvalPoint {
            iter: it,
            cum_bits: self.cum_bits,
            cum_down_bits: self.cum_down_bits,
            loss: (loss_sum / total.max(1) as f64) as f32,
            accuracy: correct as f64 / total.max(1) as f64,
        };
        for s in &mut self.sinks {
            s.on_eval(&self.history.label, &point);
        }
        self.history.evals.push(point);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PPolicy, SchemeConfig};

    fn tiny_cfg(scheme: SchemeConfig) -> ExperimentConfig {
        let mut c = ExperimentConfig::table1_default();
        c.scheme = scheme;
        c.clients = 3;
        c.iters = 6;
        c.batch = 16;
        c.train_n = 300;
        c.test_n = 100;
        c.eval_every = 3;
        c.lr_schedule = vec![(0, 0.05)];
        c
    }

    #[test]
    fn full_sync_selects_everyone() {
        let links = LinkModel::spread(4, 1e5, 1e7);
        let mut rng = Rng::new(1);
        assert_eq!(FullSync.select(0, &links, &mut rng), vec![true; 4]);
    }

    #[test]
    fn uniform_sampling_selects_k() {
        let links = LinkModel::spread(10, 1e5, 1e7);
        let mut rng = Rng::new(2);
        let mut p = UniformSampling { fraction: 0.3 };
        for round in 0..20 {
            let mask = p.select(round, &links, &mut rng);
            assert_eq!(mask.iter().filter(|&&b| b).count(), 3, "round {round}");
        }
    }

    #[test]
    fn link_dropout_extremes() {
        let links = vec![LinkModel::iot(); 4]; // equal links -> slowness 1
        let mut rng = Rng::new(3);
        let mut never = LinkDropout { fraction: 1.0, drop_prob: 0.0 };
        let mut always = LinkDropout { fraction: 1.0, drop_prob: 1.0 };
        for i in 0..4 {
            assert!(never.admit(i, &links, Duration::ZERO, &mut rng));
            assert!(!always.admit(i, &links, Duration::ZERO, &mut rng));
        }
        // fastest link in a spread is never dropped
        let spread = LinkModel::spread(3, 1e5, 1e7);
        let mut p = LinkDropout { fraction: 1.0, drop_prob: 1.0 };
        assert!(p.admit(2, &spread, Duration::ZERO, &mut rng));
        assert!(!p.admit(0, &spread, Duration::ZERO, &mut rng));
    }

    #[test]
    fn deadline_cutoff_filters_slow_uploads() {
        let links = LinkModel::spread(3, 1e5, 1e7);
        let mut rng = Rng::new(4);
        let mut p = DeadlineCutoff { deadline: Duration::from_secs(2) };
        assert_eq!(p.select(0, &links, &mut rng), vec![true; 3]);
        assert!(p.admit(0, &links, Duration::from_millis(1500), &mut rng));
        assert!(!p.admit(0, &links, Duration::from_secs(3), &mut rng));
    }

    #[test]
    fn sum_aggregation_matches_manual_sum() {
        let mut rng = Rng::new(5);
        let shapes = [vec![4, 3], vec![4]];
        let a: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
        let b: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
        let agg = SumAggregation.combine(vec![a.clone(), b.clone()], &[true, true], &[10, 10]);
        for (i, t) in agg.iter().enumerate() {
            let expect = crate::tensor::zip(&a[i], &b[i], |x, y| x + y);
            assert!(t.rel_err(&expect) < 1e-6);
        }
    }

    #[test]
    fn weighted_mean_weights_by_shard_size() {
        let mut rng = Rng::new(6);
        let shapes = [vec![5]];
        let a: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
        let b: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
        // sizes 30/10: w = 0.75 / 0.25
        let agg = WeightedMeanAggregation.combine(
            vec![a.clone(), b.clone()],
            &[true, true],
            &[30, 10],
        );
        let expect = crate::tensor::zip(&a[0], &b[0], |x, y| 0.75 * x + 0.25 * y);
        assert!(agg[0].rel_err(&expect) < 1e-5);

        // non-delivered clients don't enter the denominator
        let zeros = vec![Tensor::zeros(&[5])];
        let agg = WeightedMeanAggregation.combine(
            vec![a.clone(), zeros],
            &[true, false],
            &[30, 10],
        );
        assert!(agg[0].rel_err(&a[0]) < 1e-5);
    }

    #[test]
    fn session_sgd_run_reduces_loss_and_counts_bits() {
        let cfg = tiny_cfg(SchemeConfig::Sgd);
        let report = FlSession::from_config(&cfg).unwrap().run().unwrap();
        let h = &report.history;
        assert_eq!(h.iterations(), 6);
        // 3 clients × 159,010 params × 32 bits × 6 rounds
        assert_eq!(h.total_bits(), 3 * 159_010 * 32 * 6);
        // full-precision broadcast: one model per round on the downlink
        assert_eq!(h.total_down_bits(), 159_010 * 32 * 6);
        // the SGD baseline ships exactly the full-precision traffic
        for r in &h.rounds {
            assert!((r.ratio - 1.0).abs() < 1e-12, "sgd ratio {}", r.ratio);
        }
        assert_eq!(h.total_comms(), 18);
        assert!(h.evals.len() >= 2);
        let first = h.evals.first().unwrap().loss;
        let last = h.evals.last().unwrap().loss;
        assert!(last < first, "no learning: {first} -> {last}");
    }

    #[test]
    fn dual_side_session_compresses_downlink_and_learns() {
        let cfg = tiny_cfg(SchemeConfig::Qrr(PPolicy::Fixed(0.2)));
        let dl = crate::compress::pipeline::PipelineSpec::parse("svd(p=0.1)+laq(beta=8)").unwrap();
        let report = FlSessionBuilder::new(&cfg)
            .downlink(dl)
            .quiet()
            .build()
            .unwrap()
            .run()
            .unwrap();
        let h = &report.history;
        assert_eq!(h.iterations(), 6);
        // strictly fewer downlink bits than the full-precision broadcast
        assert!(
            h.total_down_bits() < 159_010 * 32 * 6,
            "downlink not compressed: {}",
            h.total_down_bits()
        );
        assert!(h.total_down_bits() > 0);
        for r in &h.rounds {
            assert!(r.ratio < 1.0, "dual-side round ratio {} not < 1", r.ratio);
        }
        // lossy broadcast must still learn
        let first = h.evals.first().unwrap().loss;
        let last = h.evals.last().unwrap().loss;
        assert!(last < first, "no learning under dual-side: {first} -> {last}");
        assert_eq!(
            h.evals.last().unwrap().cum_down_bits,
            h.total_down_bits(),
            "eval points must carry the downlink accounting"
        );
    }

    #[test]
    fn dual_side_session_deterministic_given_seed() {
        let cfg = tiny_cfg(SchemeConfig::Qrr(PPolicy::Fixed(0.2)));
        let dl = crate::compress::pipeline::PipelineSpec::parse("svd(p=0.1)+laq(beta=8)").unwrap();
        let run = || {
            FlSessionBuilder::new(&cfg)
                .downlink(dl.clone())
                .quiet()
                .build()
                .unwrap()
                .run()
                .unwrap()
        };
        let (r1, r2) = (run(), run());
        assert_eq!(r1.history.total_bits(), r2.history.total_bits());
        assert_eq!(r1.history.total_down_bits(), r2.history.total_down_bits());
        let a = r1.history.evals.last().unwrap();
        let b = r2.history.evals.last().unwrap();
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.accuracy, b.accuracy);
    }

    #[test]
    fn streamed_session_bit_identical_to_sequential() {
        // the tentpole parity oracle: chunked per-layer framing,
        // decode-on-arrival reassembly, and the double-buffered
        // broadcast must reproduce the sequential path bit for bit on
        // a clean network — same metrics, same bit totals, same evals
        let cfg = tiny_cfg(SchemeConfig::Qrr(PPolicy::Fixed(0.2)));
        let dl = crate::compress::pipeline::PipelineSpec::parse("svd(p=0.1)+laq(beta=8)").unwrap();
        let run = |streaming: bool| {
            FlSessionBuilder::new(&cfg)
                .downlink(dl.clone())
                .streaming(streaming)
                .quiet()
                .build()
                .unwrap()
                .run()
                .unwrap()
        };
        let (seq, st) = (run(false), run(true));
        assert_eq!(seq.history.rounds.len(), st.history.rounds.len());
        for (a, b) in seq.history.rounds.iter().zip(&st.history.rounds) {
            assert_eq!(a.bits, b.bits, "round {} uplink bits differ", a.iter);
            assert_eq!(a.down_bits, b.down_bits, "round {} downlink bits differ", a.iter);
            assert_eq!(a.comms, b.comms, "round {} comms differ", a.iter);
            assert_eq!(a.grad_norm, b.grad_norm, "round {} aggregate differs", a.iter);
            assert_eq!(a.train_loss, b.train_loss);
            assert_eq!(a.clients_timed_out, b.clients_timed_out);
            assert_eq!(a.clients_corrupt, b.clients_corrupt);
        }
        for (a, b) in seq.history.evals.iter().zip(&st.history.evals) {
            assert_eq!(a.loss, b.loss);
            assert_eq!(a.accuracy, b.accuracy);
            assert_eq!(a.cum_bits, b.cum_bits);
            assert_eq!(a.cum_down_bits, b.cum_down_bits);
        }
    }

    #[test]
    fn streamed_session_matches_sequential_without_downlink() {
        let mut cfg = tiny_cfg(SchemeConfig::Qrr(PPolicy::Fixed(0.2)));
        let seq = FlSession::from_config(&cfg).unwrap().run().unwrap();
        cfg.streaming = true;
        let mut s = FlSession::from_config(&cfg).unwrap();
        let st = s.run().unwrap();
        assert_eq!(seq.history.total_bits(), st.history.total_bits());
        let a = seq.history.evals.last().unwrap();
        let b = st.history.evals.last().unwrap();
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.accuracy, b.accuracy);
        // decode-on-arrival kept at most one live update per shard
        assert!(s.peak_live() >= 1);
        assert!(s.peak_live() <= s.n_shards(), "peak {} > shards", s.peak_live());
    }

    #[test]
    fn uplink_spec_override_applies_to_every_client() {
        let mut cfg = tiny_cfg(SchemeConfig::Sgd);
        cfg.uplink =
            Some(crate::compress::pipeline::PipelineSpec::parse("qrr(p=0.2)").unwrap());
        let report = FlSession::from_config(&cfg).unwrap().run().unwrap();
        // the uplink actually compressed (scheme said SGD, spec won)
        assert!(report.history.total_bits() < 3 * 159_010 * 32 * 6 / 5);
        assert_eq!(report.history.label, "svd(p=0.2)+tucker(p=0.2)+laq(beta=8)");
        assert!(report.client_mem_bytes > 0, "pipeline state not accounted");
    }

    #[test]
    fn session_deterministic_given_seed() {
        let cfg = tiny_cfg(SchemeConfig::Qrr(PPolicy::Fixed(0.2)));
        let r1 = FlSession::from_config(&cfg).unwrap().run().unwrap();
        let r2 = FlSession::from_config(&cfg).unwrap().run().unwrap();
        assert_eq!(r1.history.total_bits(), r2.history.total_bits());
        let a = r1.history.evals.last().unwrap();
        let b = r2.history.evals.last().unwrap();
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.accuracy, b.accuracy);
    }

    #[test]
    fn session_results_independent_of_thread_count() {
        // the pooled fan-out writes into per-client slots and aggregates
        // in slot order, so timings must never change the math
        let cfg = tiny_cfg(SchemeConfig::Qrr(PPolicy::Fixed(0.2)));
        let r1 = FlSessionBuilder::new(&cfg)
            .threads(1)
            .quiet()
            .build()
            .unwrap()
            .run()
            .unwrap();
        let r4 = FlSessionBuilder::new(&cfg)
            .threads(4)
            .quiet()
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(r1.history.total_bits(), r4.history.total_bits());
        let a = r1.history.evals.last().unwrap();
        let b = r4.history.evals.last().unwrap();
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.accuracy, b.accuracy);
    }

    #[test]
    fn session_shard_count_resolves_and_bounds_peak_live() {
        // builder override wins; peak live decoded updates never exceed
        // the shard count (the O(shards) memory bound, observed)
        let mut cfg = tiny_cfg(SchemeConfig::Sgd);
        cfg.iters = 2;
        cfg.eval_every = 2;
        let mut session = FlSessionBuilder::new(&cfg)
            .shards(2)
            .quiet()
            .build()
            .unwrap();
        assert_eq!(session.n_shards(), 2);
        session.run().unwrap();
        assert!(session.peak_live() >= 1, "no decoded update ever live");
        assert!(
            session.peak_live() <= session.n_shards(),
            "peak live {} exceeds shard count {}",
            session.peak_live(),
            session.n_shards()
        );

        // config knob flows through when the builder doesn't override
        cfg.shards = Some(1);
        let session = FlSessionBuilder::new(&cfg).quiet().build().unwrap();
        assert_eq!(session.n_shards(), 1);
        // default: min(clients, 8)
        cfg.shards = None;
        let session = FlSessionBuilder::new(&cfg).quiet().build().unwrap();
        assert_eq!(session.n_shards(), 3);
    }

    #[test]
    fn dropout_all_lost_still_completes_without_hanging() {
        // equal links + drop_prob 1 ⇒ every upload is lost before the
        // transport; the round loop proceeds with zero comms and must
        // not wait on frames that were never sent
        let mut cfg = tiny_cfg(SchemeConfig::Sgd);
        cfg.iters = 3;
        cfg.eval_every = 3;
        cfg.link_slow_bps = 1e6;
        cfg.link_fast_bps = 1e6;
        cfg.participation = ParticipationConfig::Dropout { fraction: 1.0, drop_prob: 1.0 };
        let mut session = FlSessionBuilder::new(&cfg)
            .recv_timeout(Duration::from_millis(10))
            .quiet()
            .build()
            .unwrap();
        let report = session.run().unwrap();
        assert_eq!(report.history.total_comms(), 0);
        assert_eq!(report.history.total_bits(), 0);
        assert_eq!(report.history.iterations(), 3);
        assert!(report.history.evals.last().unwrap().loss.is_finite());
    }

    #[test]
    fn dropout_session_counts_dropped_clients_in_metrics() {
        // every upload lost before the transport (policy drop): the
        // fault-layer counters must attribute all three clients per
        // round to `clients_dropped`, none to `clients_timed_out`
        let mut cfg = tiny_cfg(SchemeConfig::Sgd);
        cfg.iters = 3;
        cfg.eval_every = 3;
        cfg.link_slow_bps = 1e6;
        cfg.link_fast_bps = 1e6;
        cfg.participation = ParticipationConfig::Dropout { fraction: 1.0, drop_prob: 1.0 };
        let mut session = FlSessionBuilder::new(&cfg)
            .recv_timeout(Duration::from_millis(10))
            .quiet()
            .build()
            .unwrap();
        let report = session.run().unwrap();
        assert_eq!(report.history.total_dropped(), 9);
        assert_eq!(report.history.total_timed_out(), 0);
        assert_eq!(report.history.total_resyncs(), 0);
        for r in &report.history.rounds {
            assert_eq!(r.clients_dropped, 3);
            assert_eq!(r.clients_timed_out, 0);
            assert_eq!(r.clients_corrupt, 0);
            assert_eq!(r.comms, 0);
        }
    }

    #[test]
    fn deadline_drops_slowest_client_deterministically() {
        // SGD upload = 159,010 × 32 ≈ 5.09 Mbit. Links spread 250 kbit/s
        // → 10 Mbit/s: the slowest client needs >20 s, the others <2 s.
        let mut cfg = tiny_cfg(SchemeConfig::Sgd);
        cfg.iters = 4;
        cfg.eval_every = 4;
        cfg.participation = ParticipationConfig::Deadline { secs: 5.0 };
        let mut session = FlSessionBuilder::new(&cfg)
            .recv_timeout(Duration::from_millis(10))
            .quiet()
            .build()
            .unwrap();
        let report = session.run().unwrap();
        // 2 of 3 clients admitted every round
        assert_eq!(report.history.total_comms(), 2 * 4);
        assert_eq!(report.history.total_bits(), 2 * 4 * 159_010 * 32);
    }

    #[test]
    fn weighted_mean_session_still_learns() {
        let mut cfg = tiny_cfg(SchemeConfig::Sgd);
        cfg.aggregation = AggregationConfig::WeightedMean;
        // mean scales the step by ~1/C vs sum; compensate the LR
        cfg.lr_schedule = vec![(0, 0.15)];
        let report = FlSession::from_config(&cfg).unwrap().run().unwrap();
        let first = report.history.evals.first().unwrap().loss;
        let last = report.history.evals.last().unwrap().loss;
        assert!(last < first, "no learning under weighted mean: {first} -> {last}");
    }

    #[test]
    fn metrics_sinks_observe_rounds_and_evals() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        #[derive(Default)]
        struct Counts {
            rounds: AtomicUsize,
            evals: AtomicUsize,
            finishes: AtomicUsize,
        }
        struct CountSink(Arc<Counts>);
        impl MetricsSink for CountSink {
            fn on_round(&mut self, _l: &str, _m: &RoundMetrics) {
                self.0.rounds.fetch_add(1, Ordering::Relaxed);
            }
            fn on_eval(&mut self, _l: &str, _e: &EvalPoint) {
                self.0.evals.fetch_add(1, Ordering::Relaxed);
            }
            fn on_finish(&mut self, _l: &str, _h: &History) {
                self.0.finishes.fetch_add(1, Ordering::Relaxed);
            }
        }

        let counts = Arc::new(Counts::default());
        let collected = History::new("copy");
        let cfg = tiny_cfg(SchemeConfig::Sgd);
        let mut session = FlSessionBuilder::new(&cfg)
            .quiet()
            .metrics_sink(Box::new(CountSink(Arc::clone(&counts))))
            .metrics_sink(Box::new(collected))
            .build()
            .unwrap();
        let report = session.run().unwrap();
        assert_eq!(counts.rounds.load(Ordering::Relaxed), 6);
        assert_eq!(
            counts.evals.load(Ordering::Relaxed),
            report.history.evals.len()
        );
        assert_eq!(counts.finishes.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn tcp_transport_session_round_trips_real_sockets() {
        use crate::net::transport::TcpTransport;
        let mut cfg = tiny_cfg(SchemeConfig::Qrr(PPolicy::Fixed(0.2)));
        cfg.iters = 2;
        cfg.eval_every = 2;
        let transport = TcpTransport::bind("127.0.0.1:0").unwrap();
        let mut session = FlSessionBuilder::new(&cfg)
            .transport(Box::new(transport))
            .recv_timeout(Duration::from_secs(5))
            .quiet()
            .build()
            .unwrap();
        let report = session.run().unwrap();
        assert_eq!(report.history.total_comms(), 3 * 2);
        assert!(report.history.total_bits() > 0);
    }
}
