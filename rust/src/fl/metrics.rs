//! Metric collection matching the paper's tables and figures: per-round
//! loss / bits / communications / gradient ℓ2 norm and periodic test
//! loss + accuracy, with CSV and markdown emitters.

use std::fmt::Write as _;
use std::time::Duration;

/// Per-iteration record.
#[derive(Debug, Clone)]
pub struct RoundMetrics {
    /// iteration index (0-based)
    pub iter: u64,
    /// mean local training loss across clients this round
    pub train_loss: f32,
    /// bits uploaded by all clients this round
    pub bits: u64,
    /// bits broadcast by the server this round (the downlink; the
    /// full-precision parameter size when no downlink pipeline runs)
    pub down_bits: u64,
    /// total compression ratio for the round: (uplink + downlink bits) ÷
    /// what full-precision traffic of the same shape would cost — 1.0
    /// for the SGD baseline, < 1 when either direction compresses
    pub ratio: f64,
    /// number of client→server communications this round
    pub comms: u32,
    /// ℓ2 norm of the aggregated gradient
    pub grad_norm: f64,
    /// simulated network time of the slowest client (round is synchronous)
    pub net_time: Duration,
    /// uploads lost before the server could wait on them: participation
    /// policy drops plus sends whose transport reported `Closed` after
    /// the reconnect/backoff retries were exhausted
    pub clients_dropped: u32,
    /// uploads that were sent but never arrived before the round's
    /// final collection deadline (`TimedOut`, as opposed to `Closed`)
    pub clients_timed_out: u32,
    /// frames that passed header routing but failed the body decode on
    /// their shard lane (corrupted in flight)
    pub clients_corrupt: u32,
    /// frames that arrived only after the first collection deadline,
    /// i.e. inside a quorum re-poll window
    pub clients_late: u32,
    /// downlink snapshot resyncs this round (0 or 1: the broadcast
    /// decoder is shared)
    pub resyncs: u32,
}

/// Per-client, per-round record of what the compression control plane
/// chose and what it cost — the series behind the accuracy-vs-bits
/// frontier per controller policy.
#[derive(Debug, Clone, PartialEq)]
pub struct ClientRound {
    /// iteration index (0-based)
    pub iter: u64,
    /// client id
    pub client: u32,
    /// rank fraction in force on this client's uplink (1.0 = dense)
    pub p: f64,
    /// quantizer bits in force (32 = raw f32)
    pub beta: u8,
    /// uplink payload bits this client shipped (0 = idle/skipped)
    pub bits: u64,
    /// delivery outcome the collection loop observed
    pub outcome: crate::control::Outcome,
}

/// Periodic test-set evaluation.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    /// iteration at which the evaluation ran
    pub iter: u64,
    /// cumulative bits uploaded up to this iteration
    pub cum_bits: u64,
    /// cumulative bits broadcast up to this iteration
    pub cum_down_bits: u64,
    /// test loss
    pub loss: f32,
    /// test accuracy in [0,1]
    pub accuracy: f64,
}

/// Full run history for one scheme.
#[derive(Debug, Clone, Default)]
pub struct History {
    /// scheme label, e.g. `QRR(p=0.1)`
    pub label: String,
    /// per-round records
    pub rounds: Vec<RoundMetrics>,
    /// per-client per-round records (chosen (p, beta), bits, outcome)
    pub client_rounds: Vec<ClientRound>,
    /// periodic test evaluations
    pub evals: Vec<EvalPoint>,
}

impl History {
    /// New history for a labelled run.
    pub fn new(label: impl Into<String>) -> Self {
        History { label: label.into(), ..Default::default() }
    }

    /// Total bits uploaded (paper's `# Bits` column).
    pub fn total_bits(&self) -> u64 {
        self.rounds.iter().map(|r| r.bits).sum()
    }

    /// Total bits broadcast by the server (the downlink direction).
    pub fn total_down_bits(&self) -> u64 {
        self.rounds.iter().map(|r| r.down_bits).sum()
    }

    /// Total communications (paper's `# Communications` column).
    pub fn total_comms(&self) -> u64 {
        self.rounds.iter().map(|r| r.comms as u64).sum()
    }

    /// Number of iterations recorded.
    pub fn iterations(&self) -> u64 {
        self.rounds.len() as u64
    }

    /// Final-round gradient norm (paper's `Gradient ℓ2 norm` column).
    pub fn final_grad_norm(&self) -> f64 {
        self.rounds.last().map(|r| r.grad_norm).unwrap_or(0.0)
    }

    /// Last evaluation point (loss/accuracy columns).
    pub fn final_eval(&self) -> Option<&EvalPoint> {
        self.evals.last()
    }

    /// Total simulated network time (sum of per-round slowest uplink).
    pub fn total_net_time(&self) -> Duration {
        self.rounds.iter().map(|r| r.net_time).sum()
    }

    /// Total uploads lost before collection (policy drops + dead sends).
    pub fn total_dropped(&self) -> u64 {
        self.rounds.iter().map(|r| r.clients_dropped as u64).sum()
    }

    /// Total uploads that missed every collection deadline.
    pub fn total_timed_out(&self) -> u64 {
        self.rounds.iter().map(|r| r.clients_timed_out as u64).sum()
    }

    /// Total downlink snapshot resyncs across the run.
    pub fn total_resyncs(&self) -> u64 {
        self.rounds.iter().map(|r| r.resyncs as u64).sum()
    }

    /// One row of the paper's result tables.
    pub fn table_row(&self) -> TableRow {
        TableRow {
            algorithm: self.label.clone(),
            iterations: self.iterations(),
            bits: self.total_bits(),
            down_bits: self.total_down_bits(),
            comms: self.total_comms(),
            loss: self.final_eval().map(|e| e.loss).unwrap_or(f32::NAN),
            accuracy: self.final_eval().map(|e| e.accuracy).unwrap_or(f64::NAN),
            grad_norm: self.final_grad_norm(),
            dropped: self.total_dropped(),
            timed_out: self.total_timed_out(),
        }
    }

    /// CSV of the per-round series (for the "vs iterations" figures).
    pub fn rounds_csv(&self) -> String {
        let mut s = String::from(
            "iter,train_loss,bits,cum_bits,down_bits,cum_down_bits,ratio,comms,grad_norm,net_time_s,dropped,timed_out,corrupt,late,resyncs\n",
        );
        let mut cum = 0u64;
        let mut cum_down = 0u64;
        for r in &self.rounds {
            cum += r.bits;
            cum_down += r.down_bits;
            let _ = writeln!(
                s,
                "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
                r.iter,
                r.train_loss,
                r.bits,
                cum,
                r.down_bits,
                cum_down,
                r.ratio,
                r.comms,
                r.grad_norm,
                r.net_time.as_secs_f64(),
                r.clients_dropped,
                r.clients_timed_out,
                r.clients_corrupt,
                r.clients_late,
                r.resyncs
            );
        }
        s
    }

    /// CSV of the per-client series: the control plane's chosen
    /// `(p, beta)` and the bits/outcome each client produced, one row
    /// per (round, client). Outcome codes: `i`dle, `d`elivered, `l`ate,
    /// `t`imed out, `x` dropped, `c`orrupt.
    pub fn clients_csv(&self) -> String {
        let mut s = String::from("iter,client,p,beta,bits,outcome\n");
        for c in &self.client_rounds {
            let _ = writeln!(
                s,
                "{},{},{},{},{},{}",
                c.iter,
                c.client,
                c.p,
                c.beta,
                c.bits,
                c.outcome.code()
            );
        }
        s
    }

    /// Per-client bits summed over the run, indexed by client id
    /// (empty when no per-client records were collected).
    pub fn bits_per_client(&self) -> Vec<u64> {
        let n = self.client_rounds.iter().map(|c| c.client as usize + 1).max().unwrap_or(0);
        let mut out = vec![0u64; n];
        for c in &self.client_rounds {
            out[c.client as usize] += c.bits;
        }
        out
    }

    /// CSV of evaluation points (for the "vs bits" figures).
    pub fn evals_csv(&self) -> String {
        let mut s = String::from("iter,cum_bits,cum_down_bits,test_loss,test_accuracy\n");
        for e in &self.evals {
            let _ = writeln!(
                s,
                "{},{},{},{},{}",
                e.iter, e.cum_bits, e.cum_down_bits, e.loss, e.accuracy
            );
        }
        s
    }
}

/// One row of a paper-style results table.
#[derive(Debug, Clone)]
pub struct TableRow {
    /// scheme label
    pub algorithm: String,
    /// iterations run
    pub iterations: u64,
    /// total uploaded bits
    pub bits: u64,
    /// total broadcast (downlink) bits
    pub down_bits: u64,
    /// total communications
    pub comms: u64,
    /// final test loss
    pub loss: f32,
    /// final test accuracy in [0,1]
    pub accuracy: f64,
    /// final aggregated-gradient ℓ2 norm
    pub grad_norm: f64,
    /// total uploads lost before collection (policy + dead transports)
    pub dropped: u64,
    /// total uploads that missed every collection deadline
    pub timed_out: u64,
}

/// Render rows as the paper's markdown table (plus the downlink column
/// the dual-side pipelines add and the loss columns the fault layer
/// tracks).
pub fn markdown_table(rows: &[TableRow]) -> String {
    let mut s = String::new();
    s.push_str(
        "| Algorithm | # Iterations | # Bits | # Down Bits | # Communications | # Dropped | # Timed out | Loss | Accuracy | Gradient l2 norm |\n",
    );
    s.push_str("|---|---|---|---|---|---|---|---|---|---|\n");
    for r in rows {
        let _ = writeln!(
            s,
            "| {} | {} | {} | {} | {} | {} | {} | {:.3} | {} | {:.3} |",
            r.algorithm,
            r.iterations,
            crate::util::fmt::bits_sci(r.bits),
            crate::util::fmt::bits_sci(r.down_bits),
            r.comms,
            r.dropped,
            r.timed_out,
            r.loss,
            crate::util::fmt::pct(r.accuracy),
            r.grad_norm
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hist() -> History {
        let mut h = History::new("QRR(p=0.1)");
        for i in 0..3 {
            h.rounds.push(RoundMetrics {
                iter: i,
                train_loss: 1.0 / (i + 1) as f32,
                bits: 100,
                down_bits: 40,
                ratio: 0.25,
                comms: 10,
                grad_norm: 2.0,
                net_time: Duration::from_millis(5),
                clients_dropped: 2,
                clients_timed_out: 1,
                clients_corrupt: 0,
                clients_late: 1,
                resyncs: if i == 1 { 1 } else { 0 },
            });
        }
        h.evals.push(EvalPoint {
            iter: 2,
            cum_bits: 300,
            cum_down_bits: 120,
            loss: 0.5,
            accuracy: 0.9,
        });
        h
    }

    #[test]
    fn totals() {
        let h = hist();
        assert_eq!(h.total_bits(), 300);
        assert_eq!(h.total_down_bits(), 120);
        assert_eq!(h.total_comms(), 30);
        assert_eq!(h.iterations(), 3);
        assert_eq!(h.final_grad_norm(), 2.0);
        assert_eq!(h.total_net_time(), Duration::from_millis(15));
        assert_eq!(h.total_dropped(), 6);
        assert_eq!(h.total_timed_out(), 3);
        assert_eq!(h.total_resyncs(), 1);
    }

    #[test]
    fn table_row_and_markdown() {
        let h = hist();
        let row = h.table_row();
        assert_eq!(row.algorithm, "QRR(p=0.1)");
        assert_eq!(row.bits, 300);
        assert_eq!(row.down_bits, 120);
        assert_eq!(row.dropped, 6);
        assert_eq!(row.timed_out, 3);
        let md = markdown_table(&[row]);
        assert!(md.contains("# Down Bits"));
        assert!(md.contains("# Dropped"));
        assert!(md.contains("# Timed out"));
        assert!(md.contains("| QRR(p=0.1) |"));
        assert!(md.contains("90.00%"));
        assert!(md.contains("3.000e2"));
        assert!(md.contains("1.200e2"));
        assert!(md.contains("| 6 | 3 |"));
    }

    #[test]
    fn clients_csv_rows_and_totals() {
        use crate::control::Outcome;
        let mut h = hist();
        for (i, outcome) in
            [Outcome::Delivered, Outcome::TimedOut, Outcome::Late].into_iter().enumerate()
        {
            h.client_rounds.push(ClientRound {
                iter: i as u64,
                client: i as u32 % 2,
                p: 0.1 + 0.1 * i as f64,
                beta: 8,
                bits: 50 * (i as u64 + 1),
                outcome,
            });
        }
        let csv = h.clients_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "iter,client,p,beta,bits,outcome");
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[1], "0,0,0.1,8,50,d");
        assert_eq!(lines[2], "1,1,0.2,8,100,t");
        assert_eq!(h.bits_per_client(), vec![50 + 150, 100]);
        // an empty series still renders a parseable header
        assert_eq!(hist().clients_csv().lines().count(), 1);
        assert!(hist().bits_per_client().is_empty());
    }

    #[test]
    fn csv_has_cumulative_bits() {
        let h = hist();
        let csv = h.rounds_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 4); // header + 3 rows
        assert!(lines[0].contains("down_bits"));
        assert!(lines[0].contains("ratio"));
        assert!(lines[0].ends_with("dropped,timed_out,corrupt,late,resyncs"));
        assert!(lines[2].ends_with(",2,1,0,1,1")); // round 1 resynced
        assert!(lines[3].contains(",300,")); // cumulative uplink
        assert!(lines[3].contains(",120,")); // cumulative downlink
        let ecsv = h.evals_csv();
        assert!(ecsv.lines().count() == 2);
        assert!(ecsv.starts_with("iter,cum_bits,cum_down_bits,"));
    }
}
