//! Update schemes: SGD (FedAvg baseline), SLAQ and QRR behind a common
//! client/server trait pair, so the round loop is scheme-agnostic.

use crate::net::ClientUpdate;
use crate::qrr::{ClientCodec, EfClientCodec, QrrConfig, ServerCodec};
use crate::slaq::{SlaqClient, SlaqConfig, SlaqServerState};
use crate::tensor::Tensor;

/// Which scheme an experiment runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SchemeKind {
    /// full-precision federated averaging (paper's SGD baseline)
    Sgd,
    /// lazily aggregated quantized gradients (paper's SLAQ comparator)
    Slaq,
    /// the paper's contribution, with compression fraction `p`
    Qrr {
        /// fraction of the original rank retained
        p: f64,
    },
    /// QRR + error feedback (extension; same wire format and server)
    QrrEf {
        /// fraction of the original rank retained
        p: f64,
    },
}

impl SchemeKind {
    /// Short display name.
    pub fn name(&self) -> String {
        match self {
            SchemeKind::Sgd => "SGD".into(),
            SchemeKind::Slaq => "SLAQ".into(),
            SchemeKind::Qrr { p } => format!("QRR(p={p})"),
            SchemeKind::QrrEf { p } => format!("EF-QRR(p={p})"),
        }
    }
}

/// Client side of a scheme: gradients in, wire update out.
pub trait ClientScheme: Send {
    /// Produce this round's update; `None` = lazily skipped (nothing is
    /// transmitted). `weights` are the freshly broadcast parameters.
    fn produce(&mut self, weights: &[Tensor], grads: &[Tensor]) -> Option<ClientUpdate>;

    /// Scheme state held client-side, in bytes (overhead experiment).
    fn mem_bytes(&self) -> usize;
}

/// Server side of a scheme, one instance per client: updates in,
/// reconstructed gradient contribution out.
pub trait ServerScheme: Send {
    /// Absorb the client's update (or its absence) and return the
    /// gradient contribution to sum into the descent step.
    fn absorb(&mut self, update: Option<&ClientUpdate>) -> Vec<Tensor>;

    /// Scheme state held server-side for this client, in bytes.
    fn mem_bytes(&self) -> usize;
}

/// Build the client half for `kind` over a model with `shapes`.
pub fn make_client_scheme(
    kind: SchemeKind,
    shapes: &[Vec<usize>],
    beta: u8,
    alpha: f32,
    clients: usize,
) -> Box<dyn ClientScheme> {
    match kind {
        SchemeKind::Sgd => Box::new(SgdClient),
        SchemeKind::Slaq => Box::new(SlaqClientScheme {
            inner: SlaqClient::new(shapes, SlaqConfig { beta, ..SlaqConfig::paper(alpha, clients) }),
        }),
        SchemeKind::Qrr { p } => Box::new(QrrClientScheme {
            codec: ClientCodec::new(shapes, QrrConfig { p, beta, ..QrrConfig::default() }),
        }),
        SchemeKind::QrrEf { p } => Box::new(EfClientScheme {
            codec: EfClientCodec::new(shapes, QrrConfig { p, beta, ..QrrConfig::default() }),
        }),
    }
}

/// Build the matching server half (must mirror the client's config).
pub fn make_server_scheme(
    kind: SchemeKind,
    shapes: &[Vec<usize>],
    beta: u8,
) -> Box<dyn ServerScheme> {
    match kind {
        SchemeKind::Sgd => Box::new(SgdServer { shapes: shapes.to_vec() }),
        SchemeKind::Slaq => Box::new(SlaqServerScheme { inner: SlaqServerState::new(shapes) }),
        // EF-QRR is server-transparent: same decoder as plain QRR.
        SchemeKind::Qrr { p } | SchemeKind::QrrEf { p } => Box::new(QrrServerScheme {
            codec: ServerCodec::new(shapes, QrrConfig { p, beta, ..QrrConfig::default() }),
            shapes: shapes.to_vec(),
        }),
    }
}

// ------------------------------------------------------------------ SGD

struct SgdClient;

impl ClientScheme for SgdClient {
    fn produce(&mut self, _weights: &[Tensor], grads: &[Tensor]) -> Option<ClientUpdate> {
        Some(ClientUpdate::Sgd { grads: grads.to_vec() })
    }

    fn mem_bytes(&self) -> usize {
        0
    }
}

struct SgdServer {
    shapes: Vec<Vec<usize>>,
}

impl ServerScheme for SgdServer {
    fn absorb(&mut self, update: Option<&ClientUpdate>) -> Vec<Tensor> {
        match update {
            Some(ClientUpdate::Sgd { grads }) => grads.clone(),
            Some(_) => panic!("SGD server got non-SGD update"),
            // SGD never skips; treat absence as zero contribution
            None => self.shapes.iter().map(|s| Tensor::zeros(s)).collect(),
        }
    }

    fn mem_bytes(&self) -> usize {
        0
    }
}

// ----------------------------------------------------------------- SLAQ

struct SlaqClientScheme {
    inner: SlaqClient,
}

impl ClientScheme for SlaqClientScheme {
    fn produce(&mut self, weights: &[Tensor], grads: &[Tensor]) -> Option<ClientUpdate> {
        self.inner.observe_weights(weights);
        self.inner.step(grads).map(|msg| ClientUpdate::Slaq { msg })
    }

    fn mem_bytes(&self) -> usize {
        self.inner.mem_bytes()
    }
}

struct SlaqServerScheme {
    inner: SlaqServerState,
}

impl ServerScheme for SlaqServerScheme {
    fn absorb(&mut self, update: Option<&ClientUpdate>) -> Vec<Tensor> {
        if let Some(u) = update {
            match u {
                ClientUpdate::Slaq { msg } => self.inner.apply(msg),
                _ => panic!("SLAQ server got non-SLAQ update"),
            }
        }
        // skipped or not: contribute the latest (possibly stale) gradient
        self.inner.latest().into_iter().cloned().collect()
    }

    fn mem_bytes(&self) -> usize {
        self.inner.mem_bytes()
    }
}

// ------------------------------------------------------------------ QRR

struct QrrClientScheme {
    codec: ClientCodec,
}

impl ClientScheme for QrrClientScheme {
    fn produce(&mut self, _weights: &[Tensor], grads: &[Tensor]) -> Option<ClientUpdate> {
        Some(ClientUpdate::Qrr { msgs: self.codec.encode(grads) })
    }

    fn mem_bytes(&self) -> usize {
        self.codec.mem_bytes()
    }
}

struct QrrServerScheme {
    codec: ServerCodec,
    shapes: Vec<Vec<usize>>,
}

impl ServerScheme for QrrServerScheme {
    fn absorb(&mut self, update: Option<&ClientUpdate>) -> Vec<Tensor> {
        match update {
            Some(ClientUpdate::Qrr { msgs }) => self.codec.decode(msgs),
            Some(_) => panic!("QRR server got non-QRR update"),
            // partial participation: no upload, no state change, zero
            // contribution this round
            None => self.shapes.iter().map(|s| Tensor::zeros(s)).collect(),
        }
    }

    fn mem_bytes(&self) -> usize {
        self.codec.mem_bytes()
    }
}

struct EfClientScheme {
    codec: EfClientCodec,
}

impl ClientScheme for EfClientScheme {
    fn produce(&mut self, _weights: &[Tensor], grads: &[Tensor]) -> Option<ClientUpdate> {
        Some(ClientUpdate::Qrr { msgs: self.codec.encode(grads) })
    }

    fn mem_bytes(&self) -> usize {
        self.codec.mem_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn shapes() -> Vec<Vec<usize>> {
        vec![vec![10, 20], vec![10]]
    }

    fn grads(rng: &mut Rng) -> Vec<Tensor> {
        shapes().iter().map(|s| Tensor::randn(s, rng)).collect()
    }

    #[test]
    fn sgd_is_lossless() {
        let mut rng = Rng::new(110);
        let mut c = make_client_scheme(SchemeKind::Sgd, &shapes(), 8, 0.001, 10);
        let mut s = make_server_scheme(SchemeKind::Sgd, &shapes(), 8);
        let g = grads(&mut rng);
        let up = c.produce(&[], &g).unwrap();
        let back = s.absorb(Some(&up));
        for (a, b) in g.iter().zip(back.iter()) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn qrr_roundtrips_with_bounded_error() {
        let mut rng = Rng::new(111);
        let mut c = make_client_scheme(SchemeKind::Qrr { p: 1.0 }, &shapes(), 12, 0.001, 10);
        let mut s = make_server_scheme(SchemeKind::Qrr { p: 1.0 }, &shapes(), 12);
        let g = grads(&mut rng);
        let up = c.produce(&[], &g).unwrap();
        let back = s.absorb(Some(&up));
        // p=1, beta=12: near-lossless
        for (a, b) in g.iter().zip(back.iter()) {
            assert!(a.rel_err(b) < 0.05, "err {}", a.rel_err(b));
        }
    }

    #[test]
    fn slaq_skip_returns_stale() {
        let mut rng = Rng::new(112);
        let mut c = make_client_scheme(SchemeKind::Slaq, &shapes(), 8, 0.001, 10);
        let mut s = make_server_scheme(SchemeKind::Slaq, &shapes(), 8);
        let w = grads(&mut rng);
        let g = grads(&mut rng);
        let up = c.produce(&w, &g).expect("first round sends");
        let first = s.absorb(Some(&up));
        // absorbing None (skip) must return the same stale gradient
        let stale = s.absorb(None);
        for (a, b) in first.iter().zip(stale.iter()) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn mem_bytes_ordering_matches_paper() {
        // SLAQ holds full-gradient state; QRR holds factor state (smaller);
        // SGD holds nothing.
        let shapes = vec![vec![200, 784], vec![200], vec![10, 200], vec![10]];
        let sgd = make_client_scheme(SchemeKind::Sgd, &shapes, 8, 0.001, 10);
        let slaq = make_client_scheme(SchemeKind::Slaq, &shapes, 8, 0.001, 10);
        let qrr = make_client_scheme(SchemeKind::Qrr { p: 0.2 }, &shapes, 8, 0.001, 10);
        assert_eq!(sgd.mem_bytes(), 0);
        assert!(slaq.mem_bytes() > qrr.mem_bytes());
        assert!(qrr.mem_bytes() > 0);
    }
}
