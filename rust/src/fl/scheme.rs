//! Update schemes: thin adapters over the composable
//! [`compress::pipeline`](crate::compress::pipeline) API (DESIGN.md §7).
//!
//! The round loop stays scheme-agnostic behind the
//! [`ClientScheme`]/[`ServerScheme`] trait pair; what used to be four
//! hard-wired scheme structs is now one pair of pipeline adapters.
//! [`SchemeKind`] survives as the legacy preset enum — each kind
//! resolves to a [`PipelineSpec`] through the same registry the spec
//! grammar uses, and produces wire output bit-identical to the
//! pre-pipeline scheme layer (a property the tests below pin down).

use crate::compress::pipeline::{
    BuildCtx, CompressionPipeline, PipelineClient, PipelineServer, PipelineSpec,
};
use crate::net::ClientUpdate;
use crate::tensor::Tensor;

/// Which legacy preset an experiment runs (sugar over [`PipelineSpec`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SchemeKind {
    /// full-precision federated averaging (paper's SGD baseline)
    Sgd,
    /// lazily aggregated quantized gradients (paper's SLAQ comparator)
    Slaq,
    /// the paper's contribution, with compression fraction `p`
    Qrr {
        /// fraction of the original rank retained
        p: f64,
    },
    /// QRR + error feedback (extension; same wire format and server)
    QrrEf {
        /// fraction of the original rank retained
        p: f64,
    },
}

impl SchemeKind {
    /// Short display name.
    pub fn name(&self) -> String {
        match self {
            SchemeKind::Sgd => "SGD".into(),
            SchemeKind::Slaq => "SLAQ".into(),
            SchemeKind::Qrr { p } => format!("QRR(p={p})"),
            SchemeKind::QrrEf { p } => format!("EF-QRR(p={p})"),
        }
    }

    /// The pipeline spec this preset resolves to at `beta` bits.
    ///
    /// The pre-pipeline codecs accepted any `p` (the rank rules clamp:
    /// p ≥ 1 is full rank, p ≤ 0 is rank 1), so the legacy enum keeps
    /// that tolerance by clamping into the spec grammar's (0, 1] — the
    /// resulting ranks are identical to what the old codecs computed,
    /// and the no-`Result` constructors below stay panic-free.
    pub fn to_spec(&self, beta: u8) -> PipelineSpec {
        let clamp = |p: f64| {
            if p.is_finite() {
                p.clamp(f64::MIN_POSITIVE, 1.0)
            } else {
                1.0
            }
        };
        match *self {
            SchemeKind::Sgd => PipelineSpec::sgd(),
            SchemeKind::Slaq => PipelineSpec::slaq(beta),
            SchemeKind::Qrr { p } => PipelineSpec::qrr(clamp(p), beta),
            SchemeKind::QrrEf { p } => PipelineSpec::qrr_ef(clamp(p), beta),
        }
    }
}

/// Client side of a scheme: gradients in, wire update out.
pub trait ClientScheme: Send {
    /// Produce this round's update; `None` = lazily skipped (nothing is
    /// transmitted). `weights` are the freshly broadcast parameters.
    fn produce(&mut self, weights: &[Tensor], grads: &[Tensor]) -> Option<ClientUpdate>;

    /// Scheme state held client-side, in bytes (overhead experiment).
    fn mem_bytes(&self) -> usize;
}

/// Server side of a scheme, one instance per client: updates in,
/// reconstructed gradient contribution out.
pub trait ServerScheme: Send {
    /// Absorb the client's update (or its absence) and return the
    /// gradient contribution to sum into the descent step.
    fn absorb(&mut self, update: Option<&ClientUpdate>) -> Vec<Tensor>;

    /// Scheme state held server-side for this client, in bytes.
    fn mem_bytes(&self) -> usize;
}

impl ClientScheme for PipelineClient {
    fn produce(&mut self, weights: &[Tensor], grads: &[Tensor]) -> Option<ClientUpdate> {
        PipelineClient::produce(self, weights, grads)
    }

    fn mem_bytes(&self) -> usize {
        PipelineClient::mem_bytes(self)
    }
}

impl ServerScheme for PipelineServer {
    fn absorb(&mut self, update: Option<&ClientUpdate>) -> Vec<Tensor> {
        PipelineServer::absorb(self, update)
    }

    fn mem_bytes(&self) -> usize {
        PipelineServer::mem_bytes(self)
    }
}

/// Build the client half of a pipeline spec over a model's `shapes`.
/// `alpha`/`clients` feed the SLAQ lazy rule when the spec carries it.
pub fn make_client_scheme_spec(
    spec: &PipelineSpec,
    shapes: &[Vec<usize>],
    alpha: f32,
    clients: usize,
) -> anyhow::Result<Box<dyn ClientScheme>> {
    let pipe = CompressionPipeline::compile(spec.clone(), shapes)?;
    Ok(Box::new(pipe.client(&BuildCtx { alpha, clients })))
}

/// Build the matching server half (must mirror the client's spec).
pub fn make_server_scheme_spec(
    spec: &PipelineSpec,
    shapes: &[Vec<usize>],
) -> anyhow::Result<Box<dyn ServerScheme>> {
    let pipe = CompressionPipeline::compile(spec.clone(), shapes)?;
    Ok(Box::new(pipe.server()))
}

/// Build the client half for the legacy preset `kind` over a model with
/// `shapes` — resolves through the pipeline registry.
pub fn make_client_scheme(
    kind: SchemeKind,
    shapes: &[Vec<usize>],
    beta: u8,
    alpha: f32,
    clients: usize,
) -> Box<dyn ClientScheme> {
    make_client_scheme_spec(&kind.to_spec(beta), shapes, alpha, clients)
        .expect("legacy presets always compile")
}

/// Build the matching server half (must mirror the client's config).
pub fn make_server_scheme(
    kind: SchemeKind,
    shapes: &[Vec<usize>],
    beta: u8,
) -> Box<dyn ServerScheme> {
    make_server_scheme_spec(&kind.to_spec(beta), shapes)
        .expect("legacy presets always compile")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Encoder;
    use crate::qrr::{ClientCodec, EfClientCodec, QrrConfig};
    use crate::slaq::{SlaqClient, SlaqConfig};
    use crate::util::Rng;

    fn shapes() -> Vec<Vec<usize>> {
        vec![vec![10, 20], vec![10]]
    }

    fn grads(rng: &mut Rng) -> Vec<Tensor> {
        shapes().iter().map(|s| Tensor::randn(s, rng)).collect()
    }

    #[test]
    fn sgd_is_lossless() {
        let mut rng = Rng::new(110);
        let mut c = make_client_scheme(SchemeKind::Sgd, &shapes(), 8, 0.001, 10);
        let mut s = make_server_scheme(SchemeKind::Sgd, &shapes(), 8);
        let g = grads(&mut rng);
        let up = c.produce(&[], &g).unwrap();
        let back = s.absorb(Some(&up));
        for (a, b) in g.iter().zip(back.iter()) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn qrr_roundtrips_with_bounded_error() {
        let mut rng = Rng::new(111);
        let mut c = make_client_scheme(SchemeKind::Qrr { p: 1.0 }, &shapes(), 12, 0.001, 10);
        let mut s = make_server_scheme(SchemeKind::Qrr { p: 1.0 }, &shapes(), 12);
        let g = grads(&mut rng);
        let up = c.produce(&[], &g).unwrap();
        let back = s.absorb(Some(&up));
        // p=1, beta=12: near-lossless
        for (a, b) in g.iter().zip(back.iter()) {
            assert!(a.rel_err(b) < 0.05, "err {}", a.rel_err(b));
        }
    }

    #[test]
    fn slaq_skip_returns_stale() {
        let mut rng = Rng::new(112);
        let mut c = make_client_scheme(SchemeKind::Slaq, &shapes(), 8, 0.001, 10);
        let mut s = make_server_scheme(SchemeKind::Slaq, &shapes(), 8);
        let w = grads(&mut rng);
        let g = grads(&mut rng);
        let up = c.produce(&w, &g).expect("first round sends");
        let first = s.absorb(Some(&up));
        // absorbing None (skip) must return the same stale gradient
        let stale = s.absorb(None);
        for (a, b) in first.iter().zip(stale.iter()) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn mem_bytes_ordering_matches_paper() {
        // SLAQ holds full-gradient state; QRR holds factor state (smaller);
        // SGD holds nothing.
        let shapes = vec![vec![200, 784], vec![200], vec![10, 200], vec![10]];
        let sgd = make_client_scheme(SchemeKind::Sgd, &shapes, 8, 0.001, 10);
        let slaq = make_client_scheme(SchemeKind::Slaq, &shapes, 8, 0.001, 10);
        let qrr = make_client_scheme(SchemeKind::Qrr { p: 0.2 }, &shapes, 8, 0.001, 10);
        assert_eq!(sgd.mem_bytes(), 0);
        assert!(slaq.mem_bytes() > qrr.mem_bytes());
        assert!(qrr.mem_bytes() > 0);
    }

    #[test]
    fn out_of_range_p_keeps_legacy_clamping_behavior() {
        // the old codecs accepted any p (rank rules clamp); the legacy
        // enum must not start panicking on the same inputs
        let mut rng = Rng::new(115);
        let g = grads(&mut rng);
        for (p, equiv) in [(1.5, 1.0), (0.0, f64::MIN_POSITIVE), (f64::NAN, 1.0)] {
            let mut c = make_client_scheme(SchemeKind::Qrr { p }, &shapes(), 8, 0.001, 10);
            let mut e = make_client_scheme(SchemeKind::Qrr { p: equiv }, &shapes(), 8, 0.001, 10);
            assert_eq!(
                Encoder::new(&c.produce(&[], &g).unwrap(), 0, 0),
                Encoder::new(&e.produce(&[], &g).unwrap(), 0, 0),
                "p={p} did not clamp to {equiv}"
            );
        }
        // EF variant takes the same clamp path
        let _ = make_client_scheme(SchemeKind::QrrEf { p: 2.0 }, &shapes(), 8, 0.001, 10);
        let _ = make_server_scheme(SchemeKind::Qrr { p: -1.0 }, &shapes(), 8);
    }

    #[test]
    fn spec_built_scheme_matches_preset() {
        let mut rng = Rng::new(113);
        let spec = PipelineSpec::parse("qrr(p=0.2)").unwrap();
        let mut by_spec = make_client_scheme_spec(&spec, &shapes(), 0.001, 10).unwrap();
        let mut by_kind = make_client_scheme(SchemeKind::Qrr { p: 0.2 }, &shapes(), 8, 0.001, 10);
        let g = grads(&mut rng);
        let a = Encoder::new(&by_spec.produce(&[], &g).unwrap(), 0, 0);
        let b = Encoder::new(&by_kind.produce(&[], &g).unwrap(), 0, 0);
        assert_eq!(a, b);
    }

    /// The acceptance-criterion pin: every legacy preset resolved
    /// through the pipeline registry emits wire bytes identical to the
    /// pre-redesign codecs it replaced (driven directly here).
    #[test]
    fn legacy_presets_are_bit_identical_to_legacy_codecs() {
        let shapes = shapes();
        let mut rng = Rng::new(114);
        let rounds: Vec<(Vec<Tensor>, Vec<Tensor>)> = (0..4)
            .map(|_| (grads(&mut rng), grads(&mut rng)))
            .collect();
        let wire = |up: &ClientUpdate, round: u64| Encoder::new(up, 3, round);

        // SGD: raw gradients
        let mut c = make_client_scheme(SchemeKind::Sgd, &shapes, 8, 0.05, 3);
        for (round, (_, g)) in rounds.iter().enumerate() {
            let expect = ClientUpdate::Sgd { grads: g.clone() };
            assert_eq!(
                wire(&c.produce(&[], g).unwrap(), round as u64),
                wire(&expect, round as u64),
                "sgd drifted at round {round}"
            );
        }

        // SLAQ: the lazy LAQ client, observing weights each round
        let mut c = make_client_scheme(SchemeKind::Slaq, &shapes, 8, 0.05, 3);
        let mut legacy = SlaqClient::new(&shapes, SlaqConfig { beta: 8, ..SlaqConfig::paper(0.05, 3) });
        for (round, (w, g)) in rounds.iter().enumerate() {
            let got = c.produce(w, g);
            legacy.observe_weights(w);
            let expect = legacy.step(g).map(|msg| ClientUpdate::Slaq { msg });
            match (got, expect) {
                (None, None) => {}
                (Some(a), Some(b)) => assert_eq!(
                    wire(&a, round as u64),
                    wire(&b, round as u64),
                    "slaq drifted at round {round}"
                ),
                (a, b) => panic!("slaq skip decision drifted: {:?} vs {:?}", a.is_some(), b.is_some()),
            }
        }

        // QRR / EF-QRR: the differential factor codecs
        let cfg = QrrConfig::with_p(0.2);
        let mut c = make_client_scheme(SchemeKind::Qrr { p: 0.2 }, &shapes, 8, 0.05, 3);
        let mut legacy = ClientCodec::new(&shapes, cfg);
        for (round, (_, g)) in rounds.iter().enumerate() {
            let expect = ClientUpdate::Qrr { msgs: legacy.encode(g) };
            assert_eq!(
                wire(&c.produce(&[], g).unwrap(), round as u64),
                wire(&expect, round as u64),
                "qrr drifted at round {round}"
            );
        }
        let mut c = make_client_scheme(SchemeKind::QrrEf { p: 0.2 }, &shapes, 8, 0.05, 3);
        let mut legacy = EfClientCodec::new(&shapes, cfg);
        for (round, (_, g)) in rounds.iter().enumerate() {
            let expect = ClientUpdate::Qrr { msgs: legacy.encode(g) };
            assert_eq!(
                wire(&c.produce(&[], g).unwrap(), round as u64),
                wire(&expect, round as u64),
                "ef-qrr drifted at round {round}"
            );
        }
    }
}
