//! The FL server: holds the central model and applies the distributed
//! gradient-descent step (paper eq. (2)).
//!
//! Per-client scheme mirrors and the round's streaming absorb live in
//! [`crate::fl::shard::ShardedAggregator`] (DESIGN.md §10); this type
//! only owns the parameters, the learning rate and the step.

use std::sync::Arc;

use crate::tensor::Tensor;

/// Aggregation server.
///
/// Parameters live behind an [`Arc`] so the per-round broadcast is a
/// reference-count bump instead of a full model copy; the descent step
/// mutates in place once the round's readers have dropped their handles
/// (DESIGN.md §5).
pub struct FlServer {
    params: Arc<Vec<Tensor>>,
    alpha: f32,
}

impl std::fmt::Debug for FlServer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FlServer")
            .field("params", &self.params.len())
            .field("alpha", &self.alpha)
            .finish_non_exhaustive()
    }
}

impl FlServer {
    /// New server with initial parameters and learning rate.
    pub fn new(params: Vec<Tensor>, alpha: f32) -> Self {
        FlServer { params: Arc::new(params), alpha }
    }

    /// Current central parameters (broadcast to clients each round).
    pub fn params(&self) -> &[Tensor] {
        self.params.as_slice()
    }

    /// Shared handle to the central parameters — the zero-copy broadcast.
    /// Drop it before the next [`Self::apply_aggregate`], or that step
    /// pays a copy-on-write clone of the whole model.
    pub fn params_shared(&self) -> Arc<Vec<Tensor>> {
        Arc::clone(&self.params)
    }

    /// Change the learning rate (experiment 3 decays it mid-run).
    pub fn set_alpha(&mut self, alpha: f32) {
        self.alpha = alpha;
    }

    /// Current learning rate.
    pub fn alpha(&self) -> f32 {
        self.alpha
    }

    /// Apply the descent step θ^{k+1} = θ^k − α·agg (paper eq. (2) once
    /// `agg` is the eq.-(2) sum). Returns the ℓ2 norm of `agg` (a column
    /// in the paper's tables).
    pub fn apply_aggregate(&mut self, agg: &[Tensor]) -> f64 {
        let norm2: f64 = agg.iter().map(crate::tensor::sq_norm).sum();
        // uniquely owned between rounds -> in-place, no copy
        let params = Arc::make_mut(&mut self.params);
        for (p, g) in params.iter_mut().zip(agg.iter()) {
            p.axpy(-self.alpha, g);
        }
        norm2.sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn shapes() -> Vec<Vec<usize>> {
        vec![vec![6, 4], vec![6]]
    }

    #[test]
    fn apply_aggregate_steps_by_alpha_times_sum() {
        let shapes = shapes();
        let params: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
        let mut server = FlServer::new(params, 0.5);
        let mut rng = Rng::new(120);
        let g1: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
        let g2: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
        let agg: Vec<Tensor> = g1
            .iter()
            .zip(g2.iter())
            .map(|(a, b)| crate::tensor::zip(a, b, |x, y| x + y))
            .collect();
        let norm = server.apply_aggregate(&agg);
        assert!(norm > 0.0);
        // params = -alpha*(g1+g2)
        for (i, p) in server.params().iter().enumerate() {
            let expect = crate::tensor::zip(&g1[i], &g2[i], |a, b| -0.5 * (a + b));
            assert!(p.rel_err(&expect) < 1e-6);
        }
    }

    #[test]
    fn step_norm_is_aggregate_l2_norm() {
        let shapes = shapes();
        let params: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
        let mut server = FlServer::new(params, 0.1);
        let mut rng = Rng::new(121);
        let agg: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
        let norm = server.apply_aggregate(&agg);
        let expect: f64 = agg.iter().map(crate::tensor::sq_norm).sum::<f64>().sqrt();
        assert!((norm - expect).abs() < 1e-9);
    }

    #[test]
    fn broadcast_handle_is_zero_copy_until_step() {
        let shapes = shapes();
        let params: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
        let mut server = FlServer::new(params, 0.5);
        let handle = server.params_shared();
        assert!(std::ptr::eq(handle.as_slice().as_ptr(), server.params().as_ptr()));
        // stepping while a reader holds the broadcast clones instead of
        // mutating under it
        let mut rng = Rng::new(123);
        let g: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
        server.apply_aggregate(&g);
        assert_eq!(handle[0].fro_norm(), 0.0, "reader saw the step");
        assert!(server.params()[0].fro_norm() > 0.0, "server did not step");
        drop(handle);
        // with no readers the next step mutates in place (same slice)
        let before = server.params().as_ptr();
        server.apply_aggregate(&g);
        assert!(std::ptr::eq(before, server.params().as_ptr()));
    }

    #[test]
    fn lr_schedule_applied() {
        let shapes = shapes();
        let params: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
        let mut server = FlServer::new(params, 0.01);
        assert_eq!(server.alpha(), 0.01);
        server.set_alpha(0.001);
        assert_eq!(server.alpha(), 0.001);
    }
}
