//! The FL server: holds the central model, per-client scheme mirrors and
//! applies the distributed gradient-descent step (paper eq. (2)).

use std::sync::{Arc, Mutex};

use crate::exec::ThreadPool;
use crate::net::{ClientUpdate, Decoder};
use crate::tensor::Tensor;

use super::scheme::ServerScheme;

/// Aggregation server.
///
/// Parameters live behind an [`Arc`] so the per-round broadcast is a
/// reference-count bump instead of a full model copy; the descent step
/// mutates in place once the round's readers have dropped their handles
/// (DESIGN.md §5).
pub struct FlServer {
    params: Arc<Vec<Tensor>>,
    per_client: Vec<Box<dyn ServerScheme>>,
    alpha: f32,
}

impl std::fmt::Debug for FlServer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FlServer")
            .field("params", &self.params.len())
            .field("clients", &self.per_client.len())
            .field("alpha", &self.alpha)
            .finish_non_exhaustive()
    }
}

impl FlServer {
    /// New server with initial parameters and one scheme mirror per client.
    pub fn new(params: Vec<Tensor>, per_client: Vec<Box<dyn ServerScheme>>, alpha: f32) -> Self {
        FlServer { params: Arc::new(params), per_client, alpha }
    }

    /// Current central parameters (broadcast to clients each round).
    pub fn params(&self) -> &[Tensor] {
        self.params.as_slice()
    }

    /// Shared handle to the central parameters — the zero-copy broadcast.
    /// Drop it before the next [`Self::apply_aggregate`], or that step
    /// pays a copy-on-write clone of the whole model.
    pub fn params_shared(&self) -> Arc<Vec<Tensor>> {
        Arc::clone(&self.params)
    }

    /// Change the learning rate (experiment 3 decays it mid-run).
    pub fn set_alpha(&mut self, alpha: f32) {
        self.alpha = alpha;
    }

    /// Current learning rate.
    pub fn alpha(&self) -> f32 {
        self.alpha
    }

    /// Server-side scheme memory across all clients, in bytes.
    pub fn scheme_mem_bytes(&self) -> usize {
        self.per_client.iter().map(|s| s.mem_bytes()).sum()
    }

    /// Feed each client's update (or its absence) through that client's
    /// scheme mirror, returning one reconstructed gradient contribution
    /// per client. How the contributions are combined is the session's
    /// [`Aggregation`](crate::fl::session::Aggregation) seam.
    pub fn absorb_updates(&mut self, updates: &[Option<ClientUpdate>]) -> Vec<Vec<Tensor>> {
        assert_eq!(updates.len(), self.per_client.len(), "one slot per client");
        self.per_client
            .iter_mut()
            .zip(updates.iter())
            .map(|(scheme, up)| scheme.absorb(up.as_ref()))
            .collect()
    }

    /// [`Self::absorb_updates`] fanned out over `pool`: each client's
    /// decode + reconstruction (the SVD/Tucker ℂ⁻¹ matmuls) runs as its
    /// own task. Scheme mirrors are independent per client, so this is
    /// exactly the serial result in a deterministic slot order.
    pub fn absorb_updates_on(
        &mut self,
        updates: &[Option<ClientUpdate>],
        pool: &ThreadPool,
    ) -> Vec<Vec<Tensor>> {
        assert_eq!(updates.len(), self.per_client.len(), "one slot per client");
        let n = self.per_client.len();
        let mut out: Vec<Vec<Tensor>> = (0..n).map(|_| Vec::new()).collect();
        {
            let slots: Vec<Mutex<&mut Vec<Tensor>>> = out.iter_mut().map(Mutex::new).collect();
            let schemes: Vec<Mutex<&mut Box<dyn ServerScheme>>> =
                self.per_client.iter_mut().map(Mutex::new).collect();
            pool.for_each(n, |i| {
                let mut scheme = schemes[i].lock().unwrap();
                **slots[i].lock().unwrap() = scheme.absorb(updates[i].as_ref());
            });
        }
        out
    }

    /// Apply the descent step θ^{k+1} = θ^k − α·agg (paper eq. (2) once
    /// `agg` is the eq.-(2) sum). Returns the ℓ2 norm of `agg` (a column
    /// in the paper's tables).
    pub fn apply_aggregate(&mut self, agg: &[Tensor]) -> f64 {
        let norm2: f64 = agg.iter().map(crate::tensor::sq_norm).sum();
        // uniquely owned between rounds -> in-place, no copy
        let params = Arc::make_mut(&mut self.params);
        for (p, g) in params.iter_mut().zip(agg.iter()) {
            p.axpy(-self.alpha, g);
        }
        norm2.sqrt()
    }

    /// Decode raw wire messages (order: one slot per client, `None` for
    /// skipped uploads), reconstruct per-client gradients, sum them and
    /// take the descent step. Returns the ℓ2 norm of the aggregated
    /// gradient.
    pub fn aggregate_wire(&mut self, wires: &[Option<Vec<u8>>]) -> anyhow::Result<f64> {
        assert_eq!(wires.len(), self.per_client.len(), "one slot per client");
        let updates: Vec<Option<ClientUpdate>> = wires
            .iter()
            .map(|w| {
                w.as_ref()
                    .map(|bytes| Decoder::decode(bytes).map(|d| d.update))
                    .transpose()
            })
            .collect::<Result<_, _>>()?;
        Ok(self.aggregate(&updates))
    }

    /// Same as [`Self::aggregate_wire`] but with already-decoded updates:
    /// absorb every client's update, sum (eq. (2)) and step.
    pub fn aggregate(&mut self, updates: &[Option<ClientUpdate>]) -> f64 {
        let contribs = self.absorb_updates(updates);
        let agg = super::session::sum_contribs(contribs);
        self.apply_aggregate(&agg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::scheme::{make_client_scheme, make_server_scheme, SchemeKind};
    use crate::net::Encoder;
    use crate::util::Rng;

    fn shapes() -> Vec<Vec<usize>> {
        vec![vec![6, 4], vec![6]]
    }

    #[test]
    fn sgd_aggregate_is_sum_times_alpha() {
        let shapes = shapes();
        let params: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
        let per_client = vec![
            make_server_scheme(SchemeKind::Sgd, &shapes, 8),
            make_server_scheme(SchemeKind::Sgd, &shapes, 8),
        ];
        let mut server = FlServer::new(params, per_client, 0.5);
        let mut rng = Rng::new(120);
        let g1: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
        let g2: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
        let norm = server.aggregate(&[
            Some(ClientUpdate::Sgd { grads: g1.clone() }),
            Some(ClientUpdate::Sgd { grads: g2.clone() }),
        ]);
        assert!(norm > 0.0);
        // params = -alpha*(g1+g2)
        for (i, p) in server.params().iter().enumerate() {
            let expect = crate::tensor::zip(&g1[i], &g2[i], |a, b| -0.5 * (a + b));
            assert!(p.rel_err(&expect) < 1e-6);
        }
    }

    #[test]
    fn aggregate_wire_roundtrip() {
        let shapes = shapes();
        let params: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
        let mut client = make_client_scheme(SchemeKind::Qrr { p: 0.5 }, &shapes, 8, 0.1, 1);
        let per_client = vec![make_server_scheme(SchemeKind::Qrr { p: 0.5 }, &shapes, 8)];
        let mut server = FlServer::new(params, per_client, 0.1);
        let mut rng = Rng::new(121);
        let grads: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
        let up = client.produce(&[], &grads).unwrap();
        let wire = Encoder::new(&up, 0, 0);
        let norm = server.aggregate_wire(&[Some(wire)]).unwrap();
        assert!(norm.is_finite() && norm > 0.0);
        // params moved
        assert!(server.params()[0].fro_norm() > 0.0);
    }

    #[test]
    fn garbage_wire_is_error_not_panic() {
        let shapes = shapes();
        let params: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
        let per_client = vec![make_server_scheme(SchemeKind::Sgd, &shapes, 8)];
        let mut server = FlServer::new(params, per_client, 0.1);
        let res = server.aggregate_wire(&[Some(vec![1, 2, 3])]);
        assert!(res.is_err());
    }

    #[test]
    fn parallel_absorb_matches_serial() {
        let shapes = shapes();
        let mk = || {
            FlServer::new(
                shapes.iter().map(|s| Tensor::zeros(s)).collect(),
                vec![
                    make_server_scheme(SchemeKind::Sgd, &shapes, 8),
                    make_server_scheme(SchemeKind::Sgd, &shapes, 8),
                    make_server_scheme(SchemeKind::Sgd, &shapes, 8),
                ],
                0.1,
            )
        };
        let mut rng = Rng::new(122);
        let grads = |rng: &mut Rng| -> Vec<Tensor> {
            shapes.iter().map(|s| Tensor::randn(s, rng)).collect()
        };
        let updates = vec![
            Some(ClientUpdate::Sgd { grads: grads(&mut rng) }),
            None,
            Some(ClientUpdate::Sgd { grads: grads(&mut rng) }),
        ];
        let serial = mk().absorb_updates(&updates);
        let pool = crate::exec::ThreadPool::new(4);
        let parallel = mk().absorb_updates_on(&updates, &pool);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(parallel.iter()) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b.iter()) {
                assert!(x.rel_err(y) < 1e-7);
            }
        }
    }

    #[test]
    fn broadcast_handle_is_zero_copy_until_step() {
        let shapes = shapes();
        let params: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
        let per_client = vec![make_server_scheme(SchemeKind::Sgd, &shapes, 8)];
        let mut server = FlServer::new(params, per_client, 0.5);
        let handle = server.params_shared();
        assert!(std::ptr::eq(handle.as_slice().as_ptr(), server.params().as_ptr()));
        // stepping while a reader holds the broadcast clones instead of
        // mutating under it
        let mut rng = Rng::new(123);
        let g: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
        server.apply_aggregate(&g);
        assert_eq!(handle[0].fro_norm(), 0.0, "reader saw the step");
        assert!(server.params()[0].fro_norm() > 0.0, "server did not step");
        drop(handle);
        // with no readers the next step mutates in place (same slice)
        let before = server.params().as_ptr();
        server.apply_aggregate(&g);
        assert!(std::ptr::eq(before, server.params().as_ptr()));
    }

    #[test]
    fn lr_schedule_applied() {
        let shapes = shapes();
        let params: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
        let per_client = vec![make_server_scheme(SchemeKind::Sgd, &shapes, 8)];
        let mut server = FlServer::new(params, per_client, 0.01);
        assert_eq!(server.alpha(), 0.01);
        server.set_alpha(0.001);
        assert_eq!(server.alpha(), 0.001);
    }
}
