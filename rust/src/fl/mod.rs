//! Federated-learning core: update schemes, clients, the aggregation
//! server and metrics.
//!
//! One **iteration** (paper terminology) = the server broadcasts weights
//! → every client computes its local mean gradient over one batch →
//! clients upload (scheme-encoded) updates → the server reconstructs,
//! sums (eq. (2)) and applies the gradient-descent step.

pub mod client;
pub mod metrics;
pub mod scheme;
pub mod server;
pub mod session;
pub mod shard;

pub use client::{ClientRoundOutput, FlClient};
pub use metrics::{EvalPoint, History, RoundMetrics};
pub use scheme::{
    make_client_scheme, make_client_scheme_spec, make_server_scheme, make_server_scheme_spec,
    ClientScheme, SchemeKind, ServerScheme,
};
pub use server::FlServer;
pub use shard::{RoundDigest, ShardedAggregator};
pub use session::{
    Aggregation, CsvSink, DeadlineCutoff, FlSession, FlSessionBuilder, FullSync, LinkDropout,
    LogSink, MetricsSink, ParticipationPolicy, RunReport, SumAggregation, UniformSampling,
    WeightedMeanAggregation,
};
