//! Sharded streaming aggregation: absorb-on-complete with O(shards)
//! live memory (DESIGN.md §10).
//!
//! The pre-shard server held every decoded [`ClientUpdate`] of a round
//! before summing — O(cohort) memory, which defeats the paper's point
//! of compressing updates so one server can sustain thousands of
//! agents. Here each client is owned by one of N **shards**; the moment
//! a client's frame completes, the session routes it (by a cheap
//! [`Decoder::peek_header`]) to the owning shard's lane on
//! [`ShardExecutor`], where it is decoded, fed through that client's
//! [`ServerScheme`] mirror, and summed into the shard's **partial sum**
//! via the SIMD-dispatched `sum_into`/`axpy` — then the decoded update
//! is dropped. At round close the shards tree-reduce their partials in
//! a fixed pairing, so live decoded-update state never exceeds one
//! in-flight update per shard ([`RoundDigest::peak_live`] asserts it).
//!
//! Determinism: a client's shard is `id % n_shards` (independent of
//! `QRR_THREADS`), frames absorb in dispatch order within a lane, and
//! the reduce pairing is fixed — so a round's aggregate is a pure
//! function of the frame arrival order, bit-equal across runs and
//! thread counts.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::exec::ShardExecutor;
use crate::net::wire::ChunkBody;
use crate::net::Decoder;
use crate::tensor::Tensor;

use super::scheme::ServerScheme;

/// What a closed round hands back to the session.
#[derive(Debug)]
pub struct RoundDigest {
    /// The weighted sum of contributions (eq. (2) up to the
    /// aggregation's final scale), one tensor per parameter.
    pub aggregate: Vec<Tensor>,
    /// Per client: did a frame decode and absorb this round?
    pub delivered: Vec<bool>,
    /// Peak number of decoded updates alive at once — the O(shards)
    /// memory bound, structurally ≤ the shard count.
    pub peak_live: usize,
    /// Frames that reached a shard but failed the full body decode —
    /// in streaming mode, counted at most once per client per round
    /// (the first bad chunk fails the member's whole update).
    pub decode_failures: usize,
    /// Duplicate deliveries dropped at a lane: whole frames whose
    /// client had already absorbed one this round, and duplicated
    /// *chunks* — counted exactly once per (client, layer) however
    /// many extra copies land.
    pub duplicates: usize,
    /// Per client: did this round reject one of its frames as a decode
    /// failure? In streaming mode a client can be *both* corrupt and
    /// gappy — this flag lets the session classify such a client as
    /// corrupt rather than timed out, keeping the per-round outcome
    /// partition exact. (A hostile client can be delivered *and*
    /// flagged: a stray chunk after an absorbed whole frame; delivery
    /// wins in the session's classification.)
    pub failed: Vec<bool>,
}

/// Per-member uplink mode for the open round: the first frame fixes
/// it, and a client mixing chunked and whole-message frames within a
/// round is rejected (DESIGN.md §13).
#[derive(Clone, Copy, PartialEq, Eq)]
enum Mode {
    Unset,
    Whole,
    Chunked,
}

/// Per-(client, round) chunk reassembly state (streaming mode): the
/// decoded per-layer bodies of one update, gathered out-of-order until
/// every gap fills, at which point the update absorbs atomically —
/// exactly what the sequential path absorbs after a whole-message
/// decode, so a bad chunk can never half-apply an update.
struct ChunkAssembly {
    /// scheme tag fixed by the first chunk; later chunks must agree
    scheme: u8,
    /// decoded bodies by layer (`None` = gap); freed on completion or
    /// rejection so only in-flight assemblies hold memory
    bodies: Vec<Option<ChunkBody>>,
    /// distinct layers decoded so far
    received: usize,
    /// layers whose duplicate delivery has been counted — exactly once
    /// per (client, layer), however many copies land; retained after
    /// completion so late copies still count once
    dup_counted: Vec<bool>,
    /// update rejected (bad chunk bytes, layer-count/scheme mismatch,
    /// mode mixing): the member stays undelivered and further chunks
    /// are discarded silently
    failed: bool,
    /// every layer landed and the update absorbed into the partial
    complete: bool,
}

impl ChunkAssembly {
    fn new(scheme: u8, n_layers: usize) -> Self {
        ChunkAssembly {
            scheme,
            bodies: vec![None; n_layers],
            received: 0,
            dup_counted: vec![false; n_layers],
            failed: false,
            complete: false,
        }
    }
}

/// Per-shard state: touched only from that shard's executor lane while
/// a round is open, so the mutex is uncontended — it exists to move the
/// state across threads, not to arbitrate them.
struct ShardState {
    /// Global client ids owned by this shard, ascending. Client `c`
    /// (with `c % n_shards == shard`) sits at position `c / n_shards`.
    members: Vec<usize>,
    /// Scheme mirrors, parallel to `members`.
    schemes: Vec<Box<dyn ServerScheme>>,
    /// Running weighted sum of absorbed contributions (lazy: `None`
    /// until the first contribution lands).
    partial: Option<Vec<Tensor>>,
    /// Parallel to `members`: absorbed a frame this round.
    absorbed: Vec<bool>,
    /// Per-member aggregation weight for this round.
    weights: Vec<f32>,
    /// Sum `absorb(None)` contributions of silent members into the
    /// partial (Sum semantics) or advance their mirrors without
    /// summing (WeightedMean semantics).
    include_undelivered: bool,
    /// Frames whose body decode failed on this shard this round.
    decode_failures: usize,
    /// Parallel to `members`: a frame of theirs failed this round.
    failed: Vec<bool>,
    /// Frames dropped because their client had already absorbed.
    duplicates: usize,
    /// Parallel to `members`: this round's uplink mode per member.
    modes: Vec<Mode>,
    /// Parallel to `members`: streaming reassembly state, `None` until
    /// the member's first chunk of the round.
    chunks: Vec<Option<ChunkAssembly>>,
}

impl ShardState {
    /// Weighted-sum `contrib` into the partial (axpy dispatches to the
    /// SIMD `sum_into` when the weight is 1).
    fn accumulate(&mut self, contrib: Vec<Tensor>, weight: f32) {
        match &mut self.partial {
            Some(acc) => {
                for (a, c) in acc.iter_mut().zip(contrib.iter()) {
                    a.axpy(weight, c);
                }
            }
            None => {
                let mut first = contrib;
                if weight != 1.0 {
                    for t in &mut first {
                        t.scale(weight);
                    }
                }
                self.partial = Some(first);
            }
        }
    }

    // The chunk reassembly path runs on attacker-controlled bytes like
    // the wire decoder itself (the TCP server feeds it raw peer input):
    // every malformed chunk must surface as a counted reject, never a
    // panic, so panicking constructs are banned here.
    // qrr-audit: no-panic

    /// Reject member `pos`'s streamed round: drop any gathered bodies,
    /// count one decode failure the first time, and leave a failed
    /// marker so further chunks (and mixing evidence) are discarded
    /// silently. Returns whether this call closed an open,
    /// body-holding assembly (for the caller's live accounting).
    fn fail_chunk_round(&mut self, pos: usize, expected_layers: usize) -> bool {
        if self.chunks[pos].is_none() {
            self.chunks[pos] = Some(ChunkAssembly::new(0, expected_layers));
        }
        let mut closed = false;
        if let Some(a) = self.chunks[pos].as_mut() {
            if a.failed || a.complete {
                return false;
            }
            a.failed = true;
            closed = a.received > 0;
            a.bodies = Vec::new();
            a.received = 0;
        }
        self.decode_failures += 1;
        if let Some(f) = self.failed.get_mut(pos) {
            *f = true;
        }
        closed
    }

    /// One chunk frame for member `pos` (global id `client`): decode
    /// on arrival, dedup per (client, layer), and absorb the update
    /// atomically the moment its last gap fills. Returns `(opened,
    /// closed)` — whether this call opened / closed the member's live
    /// assembly — for the lane job's live/peak accounting.
    fn chunk_frame(
        &mut self,
        pos: usize,
        client: usize,
        frame: &[u8],
        expected_layers: usize,
    ) -> (bool, bool) {
        if self.modes[pos] == Mode::Whole {
            // chunked frames mixed into a whole-message round
            log::warn!("client {client} mixed chunked and whole-message frames");
            return (false, self.fail_chunk_round(pos, expected_layers));
        }
        self.modes[pos] = Mode::Chunked;
        if matches!(&self.chunks[pos], Some(a) if a.failed) {
            // round already rejected for this member
            return (false, false);
        }
        let (header, body) = match Decoder::decode_chunk(frame) {
            Ok(hb) => hb,
            Err(e) => {
                log::warn!("chunk decode failed for client {client}: {e}");
                return (false, self.fail_chunk_round(pos, expected_layers));
            }
        };
        if header.n_layers as usize != expected_layers {
            // `n_layers` is attacker data until checked against the
            // model spec — this also caps reassembly allocation at the
            // spec's layer count, never a declared u32::MAX
            log::warn!(
                "client {client} declared {} layers, model has {expected_layers}",
                header.n_layers
            );
            return (false, self.fail_chunk_round(pos, expected_layers));
        }
        if matches!(&self.chunks[pos], Some(a) if a.scheme != header.scheme) {
            log::warn!("client {client} switched schemes mid-update");
            return (false, self.fail_chunk_round(pos, expected_layers));
        }
        let opened = self.chunks[pos].is_none();
        // peek validated layer < n_layers == expected_layers
        let layer = header.layer as usize;
        let a = match self.chunks[pos].as_mut() {
            Some(a) => a,
            None => {
                self.chunks[pos] = Some(ChunkAssembly::new(header.scheme, expected_layers));
                match self.chunks[pos].as_mut() {
                    Some(a) => a,
                    None => return (false, false), // unreachable: just stored
                }
            }
        };
        if a.complete || a.bodies.get(layer).map(Option::is_some).unwrap_or(false) {
            // duplicate delivery, counted once per (client, layer)
            if !a.dup_counted[layer] {
                a.dup_counted[layer] = true;
                self.duplicates += 1;
            }
            return (opened, false);
        }
        a.bodies[layer] = Some(body);
        a.received += 1;
        if a.received < expected_layers {
            return (opened, false);
        }
        // last gap filled: gather in layer order and absorb whole
        a.complete = true;
        let scheme = a.scheme;
        let gathered = std::mem::take(&mut a.bodies);
        let mut bodies = Vec::with_capacity(expected_layers);
        for b in gathered {
            if let Some(b) = b {
                bodies.push(b);
            }
        }
        if bodies.len() != expected_layers {
            // unreachable: received == expected_layers implies no gaps
            self.decode_failures += 1;
            if let Some(f) = self.failed.get_mut(pos) {
                *f = true;
            }
            return (opened, true);
        }
        match Decoder::assemble_update(scheme, bodies) {
            Ok(update) => {
                let contrib = self.schemes[pos].absorb(Some(&update));
                let w = self.weights[pos];
                self.accumulate(contrib, w);
                self.absorbed[pos] = true;
            }
            Err(e) => {
                log::warn!("chunk reassembly failed for client {client}: {e}");
                self.decode_failures += 1;
                if let Some(f) = self.failed.get_mut(pos) {
                    *f = true;
                }
            }
        }
        (opened, true)
    }
    // qrr-audit: end
}

/// N-shard streaming aggregator over the full cohort's scheme mirrors.
///
/// Lifecycle per round: [`Self::begin_round`] → any number of
/// [`Self::dispatch_frame`] (non-blocking; decode + absorb run on the
/// owning shard's lane) → [`Self::close_round`] (drains the lanes,
/// absorbs `None` for silent members, tree-reduces the partials).
pub struct ShardedAggregator {
    shards: Vec<Arc<Mutex<ShardState>>>,
    exec: ShardExecutor,
    /// Parameter shapes, for the all-silent zero aggregate.
    shapes: Vec<Vec<usize>>,
    n_members: usize,
    /// Decoded updates currently alive across all lanes.
    live: Arc<AtomicUsize>,
    /// High-water mark of `live` since `begin_round`.
    peak_live: Arc<AtomicUsize>,
}

impl std::fmt::Debug for ShardedAggregator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardedAggregator")
            .field("shards", &self.shards.len())
            .field("members", &self.n_members)
            .finish_non_exhaustive()
    }
}

impl ShardedAggregator {
    /// Partition `schemes` (one mirror per client, index = client id)
    /// across `n_shards` lanes. `shapes` are the model's parameter
    /// shapes (the zero aggregate when every member stays silent).
    pub fn new(
        schemes: Vec<Box<dyn ServerScheme>>,
        shapes: Vec<Vec<usize>>,
        n_shards: usize,
    ) -> Self {
        let n_members = schemes.len();
        let n_shards = n_shards.clamp(1, n_members.max(1));
        let mut buckets: Vec<ShardState> = (0..n_shards)
            .map(|_| ShardState {
                members: Vec::new(),
                schemes: Vec::new(),
                partial: None,
                absorbed: Vec::new(),
                weights: Vec::new(),
                include_undelivered: true,
                decode_failures: 0,
                failed: Vec::new(),
                duplicates: 0,
                modes: Vec::new(),
                chunks: Vec::new(),
            })
            .collect();
        for (id, scheme) in schemes.into_iter().enumerate() {
            let b = &mut buckets[id % n_shards];
            b.members.push(id);
            b.schemes.push(scheme);
            b.absorbed.push(false);
            b.weights.push(1.0);
            b.failed.push(false);
            b.modes.push(Mode::Unset);
            b.chunks.push(None);
        }
        ShardedAggregator {
            shards: buckets.into_iter().map(|b| Arc::new(Mutex::new(b))).collect(),
            exec: ShardExecutor::new(n_shards),
            shapes,
            n_members,
            live: Arc::new(AtomicUsize::new(0)),
            peak_live: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// Number of aggregation shards.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Number of clients (scheme mirrors) across all shards.
    pub fn n_members(&self) -> usize {
        self.n_members
    }

    /// Replace one client's scheme mirror (the control plane re-planned
    /// that client's pipeline; the session swaps the client half and
    /// this mirror in lockstep). Must be called between rounds — after
    /// [`Self::close_round`]'s barrier and before the next
    /// [`Self::begin_round`] — which `&mut self` enforces structurally:
    /// no `dispatch_frame` borrow can be live across this call.
    pub fn replace_scheme(&mut self, client: usize, scheme: Box<dyn ServerScheme>) {
        let n_shards = self.shards.len();
        assert!(client < self.n_members, "client id out of range");
        let mut s = self.shards[client % n_shards].lock().unwrap();
        s.schemes[client / n_shards] = scheme;
    }

    /// Open a round: reset partials, flags and the peak-live counter,
    /// and install this round's per-client `weights` (index = client
    /// id) and silent-member policy. Must not be called with a round
    /// still open (i.e. before the matching [`Self::close_round`]).
    pub fn begin_round(&mut self, weights: &[f32], include_undelivered: bool) {
        assert_eq!(weights.len(), self.n_members, "one weight per client");
        for shard in &self.shards {
            let mut s = shard.lock().unwrap();
            s.partial = None;
            s.decode_failures = 0;
            s.duplicates = 0;
            s.include_undelivered = include_undelivered;
            for pos in 0..s.members.len() {
                s.absorbed[pos] = false;
                let id = s.members[pos];
                s.weights[pos] = weights[id];
                s.failed[pos] = false;
                s.modes[pos] = Mode::Unset;
                s.chunks[pos] = None;
            }
        }
        self.peak_live.store(0, Ordering::SeqCst);
    }

    /// Hand a completed frame for `client` to its owning shard's lane
    /// and return immediately. The lane job decodes the body, absorbs
    /// it through the client's mirror, sums the contribution into the
    /// shard partial, and drops the decoded update — so at most one
    /// decoded update per shard is ever alive. A frame that fails the
    /// body decode counts as a decode failure and the client stays
    /// undelivered; a duplicate (client already absorbed this round)
    /// is dropped.
    pub fn dispatch_frame(&self, client: usize, frame: Vec<u8>) {
        let n_shards = self.shards.len();
        debug_assert!(client < self.n_members, "client id out of range");
        let shard = Arc::clone(&self.shards[client % n_shards]);
        let live = Arc::clone(&self.live);
        let peak = Arc::clone(&self.peak_live);
        let expected_layers = self.shapes.len();
        self.exec.dispatch(client % n_shards, move || {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            let pos = client / n_shards;
            let mut assembly_closed = false;
            {
                let mut s = shard.lock().unwrap();
                if s.absorbed[pos] {
                    s.duplicates += 1;
                } else if s.modes[pos] == Mode::Chunked {
                    // a whole-message frame mixed into a chunked round
                    log::warn!("client {client} mixed whole-message and chunked frames");
                    assembly_closed = s.fail_chunk_round(pos, expected_layers);
                } else {
                    s.modes[pos] = Mode::Whole;
                    match Decoder::decode(&frame) {
                        Ok(msg) => {
                            let contrib = s.schemes[pos].absorb(Some(&msg.update));
                            let w = s.weights[pos];
                            s.accumulate(contrib, w);
                            s.absorbed[pos] = true;
                        }
                        Err(e) => {
                            log::warn!("shard decode failed for client {client}: {e}");
                            s.decode_failures += 1;
                            s.failed[pos] = true;
                        }
                    }
                }
            }
            if assembly_closed {
                live.fetch_sub(1, Ordering::SeqCst);
            }
            live.fetch_sub(1, Ordering::SeqCst);
        });
    }

    /// Hand one **chunk** frame for `client` to its owning shard's
    /// lane (streaming mode) and return immediately. The lane job
    /// decodes the body on arrival and merges it into the member's
    /// per-round [`ChunkAssembly`]; the moment the last gap fills, the
    /// reassembled update absorbs through the client's mirror exactly
    /// like a whole-message frame — all-or-nothing, so a bad chunk can
    /// never half-apply an update. Out-of-order and duplicate chunks
    /// are tolerated (a duplicated chunk counts toward
    /// [`RoundDigest::duplicates`] exactly once per (client, layer));
    /// gaps leave the member undelivered at round close; a client
    /// mixing chunked and whole-message frames within one round is
    /// rejected as a decode failure.
    ///
    /// Live accounting: an open assembly counts as one live decoded
    /// update from its first chunk until it absorbs or fails, so when
    /// each client's chunks are dispatched contiguously (the session's
    /// send order) peak live memory stays O(shards), as
    /// [`RoundDigest::peak_live`] asserts. The caller routes by
    /// (client, round) admission — like [`Self::dispatch_frame`], a
    /// stale round's frames must not reach this method.
    pub fn dispatch_chunk(&self, client: usize, frame: Vec<u8>) {
        let n_shards = self.shards.len();
        debug_assert!(client < self.n_members, "client id out of range");
        let shard = Arc::clone(&self.shards[client % n_shards]);
        let live = Arc::clone(&self.live);
        let peak = Arc::clone(&self.peak_live);
        let expected_layers = self.shapes.len();
        self.exec.dispatch(client % n_shards, move || {
            let pos = client / n_shards;
            let mut s = shard.lock().unwrap();
            let (opened, closed) = s.chunk_frame(pos, client, &frame, expected_layers);
            drop(s);
            if opened {
                let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
            }
            if closed {
                live.fetch_sub(1, Ordering::SeqCst);
            }
        });
    }

    /// Close the round: wait for in-flight frames, absorb `None` for
    /// every silent member (advancing lazy mirrors; summed only under
    /// Sum semantics), tree-reduce the shard partials in a fixed
    /// pairing, and return the digest.
    pub fn close_round(&mut self) -> RoundDigest {
        // drain in-flight dispatches
        self.exec.barrier();

        // silent members: one lane job per shard, member order
        for (idx, shard) in self.shards.iter().enumerate() {
            let shard = Arc::clone(shard);
            self.exec.dispatch(idx, move || {
                let mut s = shard.lock().unwrap();
                for pos in 0..s.members.len() {
                    if s.absorbed[pos] {
                        continue;
                    }
                    let contrib = s.schemes[pos].absorb(None);
                    if s.include_undelivered {
                        let w = s.weights[pos];
                        s.accumulate(contrib, w);
                    }
                }
            });
        }
        self.exec.barrier();

        // tree reduce: stride-doubling merge of partials into shard 0
        let n = self.shards.len();
        let mut stride = 1;
        while stride < n {
            for left in (0..n).step_by(2 * stride) {
                let right = left + stride;
                if right >= n {
                    continue;
                }
                let dst = Arc::clone(&self.shards[left]);
                let src = Arc::clone(&self.shards[right]);
                self.exec.dispatch(left, move || {
                    let moved = src.lock().unwrap().partial.take();
                    if let Some(p) = moved {
                        let mut d = dst.lock().unwrap();
                        match &mut d.partial {
                            Some(acc) => {
                                for (a, b) in acc.iter_mut().zip(p.iter()) {
                                    crate::exec::simd::sum_into(a.data_mut(), b.data());
                                }
                            }
                            None => d.partial = Some(p),
                        }
                    }
                });
            }
            self.exec.barrier();
            stride *= 2;
        }

        let aggregate = self.shards[0]
            .lock()
            .unwrap()
            .partial
            .take()
            .unwrap_or_else(|| self.shapes.iter().map(|s| Tensor::zeros(s)).collect());
        let mut delivered = vec![false; self.n_members];
        let mut failed = vec![false; self.n_members];
        let mut decode_failures = 0usize;
        let mut duplicates = 0usize;
        for shard in &self.shards {
            let mut s = shard.lock().unwrap();
            decode_failures += s.decode_failures;
            duplicates += s.duplicates;
            for (pos, &id) in s.members.iter().enumerate() {
                delivered[id] = s.absorbed[pos];
                failed[id] = s.failed[pos];
            }
            // free incomplete (gappy) assemblies — their members stay
            // undelivered — and reconcile the live counter for them
            for pos in 0..s.chunks.len() {
                if let Some(a) = s.chunks[pos].take() {
                    if !a.failed && !a.complete && a.received > 0 {
                        self.live.fetch_sub(1, Ordering::SeqCst);
                    }
                }
            }
        }
        RoundDigest {
            aggregate,
            delivered,
            peak_live: self.peak_live.load(Ordering::SeqCst),
            decode_failures,
            duplicates,
            failed,
        }
    }

    /// Server-side memory: scheme mirrors, any live partials, plus
    /// in-flight chunk reassembly bodies (streaming mode).
    pub fn mem_bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|shard| {
                let s = shard.lock().unwrap();
                let mirrors: usize = s.schemes.iter().map(|m| m.mem_bytes()).sum();
                let partial: usize = s
                    .partial
                    .as_ref()
                    .map(|p| p.iter().map(|t| 4 * t.len()).sum())
                    .unwrap_or(0);
                let assemblies: usize = s
                    .chunks
                    .iter()
                    .flatten()
                    .map(|a| {
                        a.bodies
                            .iter()
                            .flatten()
                            .map(|b| (b.payload_bits() / 8) as usize)
                            .sum::<usize>()
                    })
                    .sum();
                mirrors + partial + assemblies
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::scheme::{make_client_scheme, make_server_scheme, SchemeKind};
    use crate::net::{ClientUpdate, Encoder};
    use crate::util::Rng;

    fn shapes() -> Vec<Vec<usize>> {
        vec![vec![6, 4], vec![6]]
    }

    fn sgd_frame(shapes: &[Vec<usize>], id: u32, round: u64, rng: &mut Rng) -> (Vec<u8>, Vec<Tensor>) {
        let grads: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, rng)).collect();
        let up = ClientUpdate::Sgd { grads: grads.clone() };
        (Encoder::new(&up, id, round), grads)
    }

    fn sgd_aggregator(shapes: &[Vec<usize>], clients: usize, n_shards: usize) -> ShardedAggregator {
        let schemes: Vec<_> = (0..clients)
            .map(|_| make_server_scheme(SchemeKind::Sgd, shapes, 8))
            .collect();
        ShardedAggregator::new(schemes, shapes.to_vec(), n_shards)
    }

    #[test]
    fn sharded_sum_matches_serial_reference() {
        let shapes = shapes();
        let mut rng = Rng::new(700);
        let n_clients = 7;
        let frames: Vec<(Vec<u8>, Vec<Tensor>)> = (0..n_clients)
            .map(|i| sgd_frame(&shapes, i as u32, 0, &mut rng))
            .collect();
        // serial reference: plain left-fold sum of the gradients
        let mut want: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
        for (_, grads) in &frames {
            for (a, g) in want.iter_mut().zip(grads.iter()) {
                a.axpy(1.0, g);
            }
        }
        for n_shards in [1, 2, 3, 7] {
            let mut agg = sgd_aggregator(&shapes, n_clients, n_shards);
            agg.begin_round(&vec![1.0; n_clients], true);
            for (i, (frame, _)) in frames.iter().enumerate() {
                agg.dispatch_frame(i, frame.clone());
            }
            let digest = agg.close_round();
            assert_eq!(digest.delivered, vec![true; n_clients]);
            assert_eq!(digest.decode_failures, 0);
            for (a, w) in digest.aggregate.iter().zip(want.iter()) {
                assert!(a.rel_err(w) < 1e-5, "shards={n_shards}");
            }
        }
    }

    #[test]
    fn sharded_rounds_are_run_to_run_deterministic() {
        // same frames, same dispatch order => bit-equal aggregate,
        // independent of how lanes interleave across pool workers
        let shapes = shapes();
        let mut rng = Rng::new(701);
        let n_clients = 9;
        let frames: Vec<Vec<u8>> = (0..n_clients)
            .map(|i| sgd_frame(&shapes, i as u32, 0, &mut rng).0)
            .collect();
        let run = || {
            let mut agg = sgd_aggregator(&shapes, n_clients, 4);
            agg.begin_round(&vec![1.0; n_clients], true);
            for (i, frame) in frames.iter().enumerate() {
                agg.dispatch_frame(i, frame.clone());
            }
            agg.close_round().aggregate
        };
        let a = run();
        let b = run();
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.data(), y.data(), "aggregate not bit-stable");
        }
    }

    #[test]
    fn ten_thousand_clients_peak_live_bounded_by_shards() {
        // the ISSUE's O(shards) memory claim, asserted: 10k clients
        // stream through 8 shards and at no instant are more than 8
        // decoded updates alive
        let shapes = vec![vec![16, 8], vec![16]];
        let n_clients = 10_000;
        let n_shards = 8;
        let mut rng = Rng::new(702);
        let grads: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
        let up = ClientUpdate::Sgd { grads: grads.clone() };
        let mut agg = sgd_aggregator(&shapes, n_clients, n_shards);
        agg.begin_round(&vec![1.0; n_clients], true);
        for i in 0..n_clients {
            agg.dispatch_frame(i, Encoder::new(&up, i as u32, 0));
        }
        let digest = agg.close_round();
        assert!(
            digest.peak_live <= n_shards,
            "peak {} live decoded updates > {} shards",
            digest.peak_live,
            n_shards
        );
        assert!(digest.peak_live >= 1);
        assert_eq!(digest.delivered.iter().filter(|&&d| d).count(), n_clients);
        // every client sent the same gradient: aggregate = n * g
        for (a, g) in digest.aggregate.iter().zip(grads.iter()) {
            let want = crate::tensor::zip(g, g, |x, _| x * n_clients as f32);
            assert!(a.rel_err(&want) < 1e-3);
        }
    }

    #[test]
    fn decode_failure_leaves_member_undelivered() {
        let shapes = shapes();
        let mut rng = Rng::new(703);
        let n_clients = 3;
        let mut agg = sgd_aggregator(&shapes, n_clients, 2);
        agg.begin_round(&vec![1.0; n_clients], true);
        let (f0, g0) = sgd_frame(&shapes, 0, 0, &mut rng);
        let (f2, g2) = sgd_frame(&shapes, 2, 0, &mut rng);
        agg.dispatch_frame(0, f0);
        agg.dispatch_frame(1, vec![0xDE, 0xAD, 0xBE, 0xEF]); // garbage body
        agg.dispatch_frame(2, f2);
        let digest = agg.close_round();
        assert_eq!(digest.delivered, vec![true, false, true]);
        assert_eq!(digest.decode_failures, 1);
        // aggregate = g0 + g2 (client 1 contributed zeros via absorb(None))
        for (i, a) in digest.aggregate.iter().enumerate() {
            let want = crate::tensor::zip(&g0[i], &g2[i], |x, y| x + y);
            assert!(a.rel_err(&want) < 1e-5);
        }
    }

    #[test]
    fn duplicate_frames_absorb_once() {
        let shapes = shapes();
        let mut rng = Rng::new(704);
        let mut agg = sgd_aggregator(&shapes, 2, 2);
        agg.begin_round(&[1.0, 1.0], true);
        let (f0, g0) = sgd_frame(&shapes, 0, 0, &mut rng);
        agg.dispatch_frame(0, f0.clone());
        agg.dispatch_frame(0, f0);
        let digest = agg.close_round();
        assert_eq!(digest.delivered, vec![true, false]);
        assert_eq!(digest.duplicates, 1, "dropped copy not counted");
        assert_eq!(digest.decode_failures, 0);
        for (a, g) in digest.aggregate.iter().zip(g0.iter()) {
            assert!(a.rel_err(g) < 1e-6, "duplicate frame double-counted");
        }
    }

    #[test]
    fn weights_and_exclusion_apply() {
        // WeightedMean-style round: silent members excluded, weights
        // scale the delivered contribution
        let shapes = shapes();
        let mut rng = Rng::new(705);
        let mut agg = sgd_aggregator(&shapes, 2, 2);
        agg.begin_round(&[2.0, 3.0], false);
        let (f1, g1) = sgd_frame(&shapes, 1, 0, &mut rng);
        agg.dispatch_frame(1, f1);
        let digest = agg.close_round();
        assert_eq!(digest.delivered, vec![false, true]);
        for (a, g) in digest.aggregate.iter().zip(g1.iter()) {
            let want = crate::tensor::zip(g, g, |x, _| 3.0 * x);
            assert!(a.rel_err(&want) < 1e-6);
        }
    }

    #[test]
    fn all_silent_round_yields_zero_aggregate() {
        let shapes = shapes();
        let mut agg = sgd_aggregator(&shapes, 4, 2);
        agg.begin_round(&[1.0; 4], true);
        let digest = agg.close_round();
        assert_eq!(digest.delivered, vec![false; 4]);
        for (a, s) in digest.aggregate.iter().zip(shapes.iter()) {
            assert_eq!(a.shape(), &s[..]);
            assert_eq!(a.fro_norm(), 0.0);
        }
    }

    #[test]
    fn rounds_reset_cleanly() {
        let shapes = shapes();
        let mut rng = Rng::new(706);
        let mut agg = sgd_aggregator(&shapes, 3, 2);
        for round in 0..3u64 {
            agg.begin_round(&[1.0; 3], true);
            let (f, g) = sgd_frame(&shapes, 1, round, &mut rng);
            agg.dispatch_frame(1, f);
            let digest = agg.close_round();
            assert_eq!(digest.delivered, vec![false, true, false], "round {round}");
            for (a, gi) in digest.aggregate.iter().zip(g.iter()) {
                assert!(a.rel_err(gi) < 1e-6, "stale partial leaked into round {round}");
            }
        }
    }

    #[test]
    fn lazy_mirror_advances_even_when_silent() {
        // SLAQ mirrors carry stale state: under Sum semantics a silent
        // round must still contribute the mirror's absorb(None) output,
        // matching the legacy one-mirror-per-client absorb loop
        let shapes = shapes();
        let mut rng = Rng::new(707);
        let mut client = make_client_scheme(SchemeKind::Slaq, &shapes, 8, 0.1, 2);
        let weights: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
        let grads: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
        let up = client.produce(&weights, &grads).unwrap();
        let frame = Encoder::new(&up, 0, 0);

        // reference: serial mirror
        let mut serial = make_server_scheme(SchemeKind::Slaq, &shapes, 8);
        let mut want = serial.absorb(Some(&up));
        let follow = serial.absorb(None);
        for (w, f) in want.iter_mut().zip(follow.iter()) {
            let sum = crate::tensor::zip(w, f, |a, b| a + b);
            *w = sum;
        }

        // sharded: round 1 delivers, round 2 is silent; the two
        // aggregates must sum to the serial two-round total
        let schemes = vec![
            make_server_scheme(SchemeKind::Slaq, &shapes, 8),
            make_server_scheme(SchemeKind::Sgd, &shapes, 8),
        ];
        let mut agg = ShardedAggregator::new(schemes, shapes.clone(), 2);
        agg.begin_round(&[1.0, 1.0], true);
        agg.dispatch_frame(0, frame);
        let d1 = agg.close_round();
        agg.begin_round(&[1.0, 1.0], true);
        let d2 = agg.close_round();
        for i in 0..shapes.len() {
            let got = crate::tensor::zip(&d1.aggregate[i], &d2.aggregate[i], |a, b| a + b);
            assert!(got.rel_err(&want[i]) < 1e-5, "param {i}");
        }
    }

    #[test]
    fn replaced_mirror_decodes_the_new_wire_format() {
        // a control-plane spec change swaps both halves between rounds:
        // frames encoded by the new client half must decode through the
        // replaced mirror with no stale per-client server state
        let shapes = shapes();
        let mut rng = Rng::new(708);
        let mut agg = sgd_aggregator(&shapes, 3, 2);
        agg.begin_round(&[1.0; 3], true);
        let (f1, _) = sgd_frame(&shapes, 1, 0, &mut rng);
        agg.dispatch_frame(1, f1);
        agg.close_round();

        // client 1 switches SGD -> QRR between rounds
        agg.replace_scheme(1, make_server_scheme(SchemeKind::Qrr { p: 0.5 }, &shapes, 8));
        let mut client = make_client_scheme(SchemeKind::Qrr { p: 0.5 }, &shapes, 8, 0.1, 3);
        let weights: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
        let grads: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
        let up = client.produce(&weights, &grads).unwrap();

        agg.begin_round(&[1.0; 3], true);
        agg.dispatch_frame(1, Encoder::new(&up, 1, 1));
        let digest = agg.close_round();
        assert_eq!(digest.delivered, vec![false, true, false]);
        assert_eq!(digest.decode_failures, 0, "stale mirror rejected the new format");
        // rank-0.5 SVD of a random matrix is lossy but close in direction;
        // the decoded contribution must at least be finite and non-zero
        assert!(digest.aggregate[0].fro_norm() > 0.0);
        assert!(digest.aggregate[0].data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn mem_bytes_counts_mirrors() {
        let shapes = shapes();
        let agg = sgd_aggregator(&shapes, 4, 2);
        // SGD mirrors are stateless and no partials are live
        assert_eq!(agg.mem_bytes(), 0);
    }

    // ------------------------- chunked (streaming) dispatch ------------

    use crate::net::faults::{FaultAction, FaultPlan};

    fn chunk_frames(
        shapes: &[Vec<usize>],
        id: u32,
        round: u64,
        rng: &mut Rng,
    ) -> (Vec<Vec<u8>>, Vec<Tensor>) {
        let grads: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, rng)).collect();
        let up = ClientUpdate::Sgd { grads: grads.clone() };
        (Encoder::chunk_frames(&up, id, round), grads)
    }

    #[test]
    fn chunked_dispatch_matches_whole_frame_aggregate_bit_for_bit() {
        let shapes = shapes();
        let mut rng = Rng::new(709);
        let n_clients = 5;
        let updates: Vec<Vec<Tensor>> = (0..n_clients)
            .map(|_| shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect())
            .collect();
        let run = |chunked: bool, reverse_layers: bool| {
            let mut agg = sgd_aggregator(&shapes, n_clients, 2);
            agg.begin_round(&vec![1.0; n_clients], true);
            for (i, grads) in updates.iter().enumerate() {
                let up = ClientUpdate::Sgd { grads: grads.clone() };
                if chunked {
                    let mut frames = Encoder::chunk_frames(&up, i as u32, 0);
                    if reverse_layers {
                        frames.reverse(); // out-of-order arrival
                    }
                    for f in frames {
                        agg.dispatch_chunk(i, f);
                    }
                } else {
                    agg.dispatch_frame(i, Encoder::new(&up, i as u32, 0));
                }
            }
            agg.close_round()
        };
        let whole = run(false, false);
        for digest in [run(true, false), run(true, true)] {
            assert_eq!(digest.delivered, vec![true; n_clients]);
            assert_eq!(digest.decode_failures, 0);
            assert_eq!(digest.duplicates, 0);
            assert!(digest.peak_live <= 2, "peak {} > shard count", digest.peak_live);
            for (a, b) in digest.aggregate.iter().zip(whole.aggregate.iter()) {
                assert_eq!(a.data(), b.data(), "chunked aggregate must be bit-identical");
            }
        }
    }

    #[test]
    fn duplicated_chunks_count_once_per_client_layer() {
        // regression (ISSUE 10): a FaultPlan-duplicated chunk must bump
        // `duplicates` exactly once per (client, layer), however many
        // copies land — including copies after the update completed
        let shapes = shapes();
        let mut rng = Rng::new(710);
        let plan = FaultPlan::parse("dup=1.0,seed=9").unwrap();
        let mut agg = sgd_aggregator(&shapes, 2, 2);
        agg.begin_round(&[1.0, 1.0], true);
        let (frames, g0) = chunk_frames(&shapes, 0, 0, &mut rng);
        let mut expected_dups = 0;
        for (layer, f) in frames.iter().enumerate() {
            agg.dispatch_chunk(0, f.clone());
            if matches!(plan.chunk_action(0, 0, layer as u32), FaultAction::Duplicate) {
                agg.dispatch_chunk(0, f.clone());
                expected_dups += 1;
            }
        }
        // a third copy of layer 0 lands after the update absorbed
        agg.dispatch_chunk(0, frames[0].clone());
        let digest = agg.close_round();
        assert_eq!(digest.delivered, vec![true, false]);
        assert_eq!(expected_dups, shapes.len(), "dup=1.0 must duplicate every chunk");
        assert_eq!(digest.duplicates, expected_dups, "each (client, layer) counted once");
        assert_eq!(digest.decode_failures, 0);
        for (a, g) in digest.aggregate.iter().zip(g0.iter()) {
            assert!(a.rel_err(g) < 1e-6, "duplicate chunk double-counted");
        }
    }

    #[test]
    fn gappy_chunks_leave_member_undelivered_and_reset_cleanly() {
        let shapes = shapes();
        let mut rng = Rng::new(711);
        let mut agg = sgd_aggregator(&shapes, 3, 2);
        agg.begin_round(&[1.0; 3], true);
        let (frames, _) = chunk_frames(&shapes, 1, 0, &mut rng);
        agg.dispatch_chunk(1, frames[0].clone()); // layer 1 never arrives
        let d1 = agg.close_round();
        assert_eq!(d1.delivered, vec![false; 3]);
        assert_eq!(d1.decode_failures, 0, "a gap is a timeout, not a decode failure");
        for a in &d1.aggregate {
            assert_eq!(a.fro_norm(), 0.0, "partial update leaked into the aggregate");
        }
        // next round: the same client streams a full update cleanly
        agg.begin_round(&[1.0; 3], true);
        let (frames, g) = chunk_frames(&shapes, 1, 1, &mut rng);
        for f in frames {
            agg.dispatch_chunk(1, f);
        }
        let d2 = agg.close_round();
        assert_eq!(d2.delivered, vec![false, true, false]);
        assert_eq!(d2.peak_live, 1, "leftover assembly leaked into the live count");
        for (a, gi) in d2.aggregate.iter().zip(g.iter()) {
            assert!(a.rel_err(gi) < 1e-6, "stale chunk state leaked across rounds");
        }
    }

    #[test]
    fn corrupt_chunk_rejects_the_whole_update() {
        let shapes = shapes();
        let mut rng = Rng::new(712);
        let mut agg = sgd_aggregator(&shapes, 2, 2);
        agg.begin_round(&[1.0, 1.0], true);
        let (frames, _) = chunk_frames(&shapes, 0, 0, &mut rng);
        agg.dispatch_chunk(0, frames[0].clone());
        let mut bad = frames[1].clone();
        bad[crate::net::wire::CHUNK_HEADER_LEN] ^= 0x40; // body corruption, header intact
        agg.dispatch_chunk(0, bad);
        // a late good copy cannot resurrect the rejected round
        agg.dispatch_chunk(0, frames[1].clone());
        let digest = agg.close_round();
        assert_eq!(digest.delivered, vec![false, false]);
        assert_eq!(digest.decode_failures, 1, "one failure per client, not per chunk");
        assert_eq!(digest.duplicates, 0);
        for a in &digest.aggregate {
            assert_eq!(a.fro_norm(), 0.0, "corrupt update half-applied");
        }
    }

    #[test]
    fn mode_mixing_within_a_round_is_rejected() {
        let shapes = shapes();
        let mut rng = Rng::new(713);
        // chunks first, then a whole frame: the member's round fails
        let mut agg = sgd_aggregator(&shapes, 2, 2);
        agg.begin_round(&[1.0, 1.0], true);
        let (frames, _) = chunk_frames(&shapes, 0, 0, &mut rng);
        let (whole, _) = sgd_frame(&shapes, 0, 0, &mut rng);
        agg.dispatch_chunk(0, frames[0].clone());
        agg.dispatch_frame(0, whole);
        // further chunks are discarded silently
        agg.dispatch_chunk(0, frames[1].clone());
        let digest = agg.close_round();
        assert_eq!(digest.delivered, vec![false, false]);
        assert_eq!(digest.decode_failures, 1);

        // whole frame first, then chunks: the stray chunk is rejected
        // without un-delivering the already-absorbed update
        let mut agg = sgd_aggregator(&shapes, 2, 2);
        agg.begin_round(&[1.0, 1.0], true);
        let (whole, g1) = sgd_frame(&shapes, 1, 0, &mut rng);
        agg.dispatch_frame(1, whole);
        let (frames, _) = chunk_frames(&shapes, 1, 0, &mut rng);
        agg.dispatch_chunk(1, frames[0].clone());
        let digest = agg.close_round();
        assert_eq!(digest.delivered, vec![false, true]);
        assert_eq!(digest.decode_failures, 1);
        for (a, g) in digest.aggregate.iter().zip(g1.iter()) {
            assert!(a.rel_err(g) < 1e-6);
        }
    }

    #[test]
    fn hostile_layer_count_is_rejected_not_allocated() {
        // a declared n_layers disagreeing with the model spec fails the
        // member's round; reassembly allocation is capped by the spec's
        // layer count, never an attacker-declared one
        let shapes = shapes(); // 2 layers
        let mut rng = Rng::new(714);
        let mut agg = sgd_aggregator(&shapes, 2, 2);
        agg.begin_round(&[1.0, 1.0], true);
        // an update with 5 layers against a 2-layer model
        let grads: Vec<Tensor> = (0..5).map(|_| Tensor::randn(&[3], &mut rng)).collect();
        let up = ClientUpdate::Sgd { grads };
        agg.dispatch_chunk(0, Encoder::chunk(&up, 0, 0, 0));
        let digest = agg.close_round();
        assert_eq!(digest.delivered, vec![false, false]);
        assert_eq!(digest.decode_failures, 1);
    }

    #[test]
    fn two_thousand_streamed_clients_peak_live_bounded_by_shards() {
        // the O(shards) bound holds in streaming mode when each
        // client's chunks are dispatched contiguously (the send order
        // the session and the scale harness both use)
        let shapes = vec![vec![16, 8], vec![16]];
        let n_clients = 2000;
        let n_shards = 8;
        let mut rng = Rng::new(715);
        let grads: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
        let up = ClientUpdate::Sgd { grads: grads.clone() };
        let mut agg = sgd_aggregator(&shapes, n_clients, n_shards);
        agg.begin_round(&vec![1.0; n_clients], true);
        for i in 0..n_clients {
            for f in Encoder::chunk_frames(&up, i as u32, 0) {
                agg.dispatch_chunk(i, f);
            }
        }
        let digest = agg.close_round();
        assert!(
            digest.peak_live <= n_shards,
            "peak {} live assemblies > {} shards",
            digest.peak_live,
            n_shards
        );
        assert!(digest.peak_live >= 1);
        assert_eq!(digest.delivered.iter().filter(|&&d| d).count(), n_clients);
        assert_eq!(digest.duplicates, 0);
        for (a, g) in digest.aggregate.iter().zip(grads.iter()) {
            let want = crate::tensor::zip(g, g, |x, _| x * n_clients as f32);
            assert!(a.rel_err(&want) < 1e-3);
        }
    }
}
