//! Byte transports: in-process channels (simulation) and TCP
//! (cross-process serving / integration tests).
//!
//! Framing over TCP: `u32 LE length || payload`. The server side is a
//! hand-rolled **non-blocking readiness loop** (DESIGN.md §10): the
//! listener and every accepted socket run in non-blocking mode, each
//! connection owns a [`FrameAssembler`] that accumulates partial reads,
//! and one poll pass services every connection — a stalled or trickling
//! peer can never block the others.
//!
//! Every [`Transport`] supports both blocking [`Transport::recv`] and
//! deadline-bounded [`Transport::recv_timeout`]; the session round loop
//! uses the latter so a dropped client (or a lost frame) can never hang
//! a round — see `fl::session` (DESIGN.md §1).

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};
use thiserror::Error;

/// Errors produced by deadline-bounded receives.
#[derive(Debug, Error)]
pub enum TransportError {
    /// The peer is gone for good; no more frames will ever arrive.
    #[error("transport closed")]
    Closed,
    /// No frame arrived within the deadline; later frames may still come.
    #[error("receive timed out after {0:?}")]
    TimedOut(Duration),
    /// Underlying socket error.
    #[error("transport i/o: {0}")]
    Io(#[from] std::io::Error),
}

/// A bidirectional message transport between clients and the server.
pub trait Transport: Send {
    /// Client side: send one framed message to the server.
    fn send(&self, payload: &[u8]) -> Result<()>;

    /// Server side: receive the next framed message (blocking).
    fn recv(&self) -> Result<Vec<u8>>;

    /// Server side: receive the next framed message, waiting at most
    /// `timeout`. Distinguishes a dead peer ([`TransportError::Closed`])
    /// from a slow one ([`TransportError::TimedOut`]).
    fn recv_timeout(&self, timeout: Duration) -> std::result::Result<Vec<u8>, TransportError>;
}

// ------------------------------------------------------------- in-proc

/// mpsc-channel transport for the single-process simulation.
#[derive(Debug)]
pub struct InProcTransport {
    tx: Sender<Vec<u8>>,
    rx: Mutex<Receiver<Vec<u8>>>,
}

impl InProcTransport {
    /// Create a connected pair view (same object is used by both sides).
    pub fn new() -> Self {
        let (tx, rx) = channel();
        InProcTransport { tx, rx: Mutex::new(rx) }
    }

    /// A cloneable sender handle for client threads.
    pub fn sender(&self) -> Sender<Vec<u8>> {
        self.tx.clone()
    }
}

impl Default for InProcTransport {
    fn default() -> Self {
        Self::new()
    }
}

impl Transport for InProcTransport {
    fn send(&self, payload: &[u8]) -> Result<()> {
        self.tx.send(payload.to_vec()).context("channel closed")
    }

    fn recv(&self) -> Result<Vec<u8>> {
        self.rx
            .lock()
            .unwrap()
            .recv()
            .context("channel closed")
    }

    fn recv_timeout(&self, timeout: Duration) -> std::result::Result<Vec<u8>, TransportError> {
        self.rx
            .lock()
            .unwrap()
            .recv_timeout(timeout)
            .map_err(|e| match e {
                RecvTimeoutError::Timeout => TransportError::TimedOut(timeout),
                RecvTimeoutError::Disconnected => TransportError::Closed,
            })
    }
}

// ------------------------------------------------------------------ tcp

/// Write one length-prefixed frame.
pub fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> Result<()> {
    stream.write_all(&(payload.len() as u32).to_le_bytes())?;
    stream.write_all(payload)?;
    Ok(())
}

/// Read one length-prefixed frame.
pub fn read_frame(stream: &mut TcpStream) -> Result<Vec<u8>> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    let mut buf = vec![0u8; n];
    stream.read_exact(&mut buf)?;
    Ok(buf)
}

// --------------------------------------------------- frame assembler

/// Hard cap on a declared frame length ([`FrameAssembler::new`] default):
/// a hostile 4-byte header must not be able to commission an allocation.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// A partial-frame error: the connection carrying it must be dropped.
#[derive(Debug, Error, PartialEq, Eq)]
pub enum FrameError {
    /// The 4-byte header declared a length above the assembler's cap.
    #[error("declared frame length {declared} exceeds cap {max}")]
    Oversized {
        /// length the header declared
        declared: usize,
        /// the assembler's configured cap
        max: usize,
    },
}

/// Incremental state machine over `u32 LE length || payload` framing.
///
/// Bytes arrive in whatever chunks the socket produces; [`Self::push`]
/// appends them and drains every frame that has become complete, in
/// order. The declared length is validated against the cap as soon as
/// the four header bytes are present — *before* any payload allocation —
/// so a hostile header cannot commission memory (the transport-level
/// twin of the wire decoder's `sized` guard, DESIGN.md §9).
#[derive(Debug)]
pub struct FrameAssembler {
    buf: Vec<u8>,
    max_frame: usize,
}

impl FrameAssembler {
    /// Assembler with a declared-length cap of `max_frame` bytes.
    pub fn new(max_frame: usize) -> Self {
        FrameAssembler { buf: Vec::new(), max_frame }
    }

    /// Append `bytes` and return every frame completed by them, in
    /// arrival order. An [`FrameError::Oversized`] declaration poisons
    /// the stream — the caller should drop the connection (frames
    /// completed earlier in the same call are discarded with it: the
    /// peer is hostile, nothing it sent is trusted).
    pub fn push(&mut self, bytes: &[u8]) -> std::result::Result<Vec<Vec<u8>>, FrameError> {
        self.buf.extend_from_slice(bytes);
        let mut done = Vec::new();
        let mut at = 0usize;
        while self.buf.len() - at >= 4 {
            let declared = u32::from_le_bytes([
                self.buf[at],
                self.buf[at + 1],
                self.buf[at + 2],
                self.buf[at + 3],
            ]) as usize;
            if declared > self.max_frame {
                self.buf.drain(..at);
                return Err(FrameError::Oversized { declared, max: self.max_frame });
            }
            if self.buf.len() - at < 4 + declared {
                break;
            }
            done.push(self.buf[at + 4..at + 4 + declared].to_vec());
            at += 4 + declared;
        }
        self.buf.drain(..at);
        Ok(done)
    }

    /// True if a frame is in flight: header or payload bytes have
    /// arrived that no completed frame consumed. EOF in this state
    /// means the peer truncated a frame mid-send.
    pub fn mid_frame(&self) -> bool {
        !self.buf.is_empty()
    }

    /// Bytes currently buffered (in-flight frame prefix).
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }
}

// --------------------------------------------------------- event loop

/// One registered connection of the readiness loop.
#[derive(Debug)]
struct Conn {
    stream: TcpStream,
    asm: FrameAssembler,
    peer: std::net::SocketAddr,
}

/// A recorded connection teardown from the readiness loop — the clean
/// per-client disconnect signal chaos tooling and the session's
/// resilience layer observe (a dropped peer must never be silent).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Disconnect {
    /// the peer's socket address
    pub peer: std::net::SocketAddr,
    /// `true` if the connection died while a frame was in flight — the
    /// partial buffer was discarded with the connection, never leaked
    /// into any other stream
    pub mid_frame: bool,
    /// partial-frame bytes discarded at teardown
    pub bytes_dropped: usize,
}

/// Loopback TCP binding implementing [`Transport`] on a single object:
/// `send` opens a fresh connection to the bound listener and pushes one
/// frame (the sensor-style duty cycle of `qrr serve`), `recv` /
/// `recv_timeout` poll a non-blocking readiness loop that services
/// every registered connection.
///
/// This is what `fl::session` plugs in for the TCP scenario: the exact
/// wire bytes cross a real socket while the round loop stays unchanged.
/// The listener and every accepted socket are non-blocking; each
/// connection accumulates partial reads in its own [`FrameAssembler`],
/// so thousands of concurrently trickling clients interleave fairly and
/// a stalled peer holds up nobody (DESIGN.md §10).
#[derive(Debug)]
pub struct TcpTransport {
    listener: TcpListener,
    addr: std::net::SocketAddr,
    /// registered connections with partial-frame state
    conns: Mutex<Vec<Conn>>,
    /// frames completed by the poll loop but not yet handed out
    pending: Mutex<VecDeque<Vec<u8>>>,
    /// connection teardowns observed by the poll loop, drained by
    /// [`Self::take_disconnects`]
    disconnects: Mutex<Vec<Disconnect>>,
}

impl TcpTransport {
    /// Bind on an address (e.g. "127.0.0.1:0" to pick a free port).
    pub fn bind(addr: impl ToSocketAddrs) -> Result<Self> {
        let listener = TcpListener::bind(addr).context("binding")?;
        listener.set_nonblocking(true).context("listener nonblocking")?;
        let addr = listener.local_addr()?;
        Ok(TcpTransport {
            listener,
            addr,
            conns: Mutex::new(Vec::new()),
            pending: Mutex::new(VecDeque::new()),
            disconnects: Mutex::new(Vec::new()),
        })
    }

    /// Drain the connection teardowns the poll loop has recorded since
    /// the last call (EOF, poisoned framing, or read error — with
    /// whether a partial frame was discarded).
    pub fn take_disconnects(&self) -> Vec<Disconnect> {
        std::mem::take(&mut self.disconnects.lock().unwrap())
    }

    /// The bound address (for out-of-process clients to connect to).
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Number of currently registered (live) connections.
    pub fn live_conns(&self) -> usize {
        self.conns.lock().unwrap().len()
    }

    /// One pass of the readiness loop: accept every pending connection,
    /// then give each registered socket one read turn — drain available
    /// bytes into its assembler, queue completed frames, unregister on
    /// EOF or error. Never blocks. Returns `true` if any frame was
    /// queued (so callers can back off with a sleep only when idle).
    pub fn poll_once(&self) -> std::result::Result<bool, TransportError> {
        let mut conns = self.conns.lock().unwrap();
        // accept phase: register every connection the backlog holds
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    stream.set_nonblocking(true)?;
                    conns.push(Conn {
                        stream,
                        asm: FrameAssembler::new(MAX_FRAME_BYTES),
                        peer,
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(TransportError::Io(e)),
            }
        }

        // read phase: one turn per connection; WouldBlock = not ready,
        // move on — a stalled peer costs one syscall, not a timeout
        let mut progressed = false;
        let mut buf = [0u8; 8192];
        let mut i = 0;
        while i < conns.len() {
            let mut keep = true;
            loop {
                match conns[i].stream.read(&mut buf) {
                    Ok(0) => {
                        // EOF: a frame in flight at close is hostile
                        // truncation — drop the tail, keep the loop alive
                        if conns[i].asm.mid_frame() {
                            log::warn!(
                                "tcp transport: peer closed mid-frame ({} bytes dropped)",
                                conns[i].asm.buffered()
                            );
                        }
                        keep = false;
                        break;
                    }
                    Ok(n) => match conns[i].asm.push(&buf[..n]) {
                        Ok(frames) => {
                            let mut q = self.pending.lock().unwrap();
                            for f in frames {
                                q.push_back(f);
                                progressed = true;
                            }
                        }
                        Err(e) => {
                            log::warn!("tcp transport: dropping connection ({e})");
                            keep = false;
                            break;
                        }
                    },
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(e) => {
                        log::warn!("tcp transport: read error, dropping connection ({e})");
                        keep = false;
                        break;
                    }
                }
            }
            if keep {
                i += 1;
            } else {
                // surface a clean per-client disconnect: the partial
                // buffer dies with the connection (it can never leak
                // into another stream — assemblers are per-connection)
                // and the teardown is observable, not just a log line
                self.disconnects.lock().unwrap().push(Disconnect {
                    peer: conns[i].peer,
                    mid_frame: conns[i].asm.mid_frame(),
                    bytes_dropped: conns[i].asm.buffered(),
                });
                conns.swap_remove(i);
            }
        }
        Ok(progressed)
    }
}

impl Transport for TcpTransport {
    /// Queue one frame for delivery. The write happens on a detached
    /// thread: the session round loop sends every frame *before* it
    /// starts accepting, so a blocking write to this object's own
    /// not-yet-accepting listener would deadlock once a frame outgrows
    /// the loopback socket buffers. A failed write surfaces as a recv
    /// timeout on the other side — the same as any lost frame.
    fn send(&self, payload: &[u8]) -> Result<()> {
        let addr = self.addr;
        let payload = payload.to_vec();
        std::thread::Builder::new()
            .name("qrr-tcp-send".into())
            .spawn(move || {
                let push = || -> Result<()> {
                    let mut stream = TcpStream::connect(addr).context("connecting")?;
                    write_frame(&mut stream, &payload)
                };
                if let Err(e) = push() {
                    log::warn!("tcp transport: frame lost ({e:#})");
                }
            })
            .context("spawning tcp send thread")?;
        Ok(())
    }

    fn recv(&self) -> Result<Vec<u8>> {
        loop {
            match self.recv_timeout(Duration::from_secs(60)) {
                Ok(frame) => return Ok(frame),
                Err(TransportError::TimedOut(_)) => continue,
                Err(e) => return Err(e.into()),
            }
        }
    }

    fn recv_timeout(&self, timeout: Duration) -> std::result::Result<Vec<u8>, TransportError> {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(frame) = self.pending.lock().unwrap().pop_front() {
                return Ok(frame);
            }
            let progressed = self.poll_once()?;
            if !progressed {
                if Instant::now() >= deadline {
                    return Err(TransportError::TimedOut(timeout));
                }
                // nothing ready anywhere: park briefly instead of
                // spinning the accept/read syscalls at full speed
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }
}

/// Server-side TCP transport: accepts connections lazily and yields
/// frames from any connected client.
#[derive(Debug)]
pub struct TcpServerTransport {
    listener: TcpListener,
    conns: Mutex<HashMap<std::net::SocketAddr, TcpStream>>,
}

impl TcpServerTransport {
    /// Bind on an address (e.g. "127.0.0.1:0" to pick a free port).
    pub fn bind(addr: impl ToSocketAddrs) -> Result<Self> {
        let listener = TcpListener::bind(addr).context("binding")?;
        Ok(TcpServerTransport { listener, conns: Mutex::new(HashMap::new()) })
    }

    /// The bound address (for clients to connect to).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept one connection and read frames from it until EOF, passing
    /// each to `f`. Simple one-connection-at-a-time server loop used by
    /// `qrr serve` (clients connect, push an update, disconnect).
    pub fn serve_once(&self, mut f: impl FnMut(Vec<u8>)) -> Result<()> {
        let (mut stream, peer) = self.listener.accept()?;
        loop {
            match read_frame(&mut stream) {
                Ok(frame) => f(frame),
                Err(_) => break, // EOF / closed
            }
        }
        self.conns.lock().unwrap().remove(&peer);
        Ok(())
    }
}

/// Client-side TCP sender.
#[derive(Debug)]
pub struct TcpClient {
    stream: TcpStream,
}

impl TcpClient {
    /// Connect to the server.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self> {
        Ok(TcpClient { stream: TcpStream::connect(addr).context("connecting")? })
    }

    /// Send one framed message.
    pub fn send(&mut self, payload: &[u8]) -> Result<()> {
        write_frame(&mut self.stream, payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{cases, forall};

    /// Encode one `u32 LE length || payload` frame.
    fn framed(payload: &[u8]) -> Vec<u8> {
        let mut out = (payload.len() as u32).to_le_bytes().to_vec();
        out.extend_from_slice(payload);
        out
    }

    #[test]
    fn assembler_whole_frame_in_one_push() {
        let mut asm = FrameAssembler::new(1024);
        let frames = asm.push(&framed(b"hello")).unwrap();
        assert_eq!(frames, vec![b"hello".to_vec()]);
        assert!(!asm.mid_frame());
        assert_eq!(asm.buffered(), 0);
    }

    #[test]
    fn assembler_byte_by_byte_trickle() {
        // the frame must complete exactly when the last byte lands,
        // and never earlier
        let payload = b"trickled-frame-payload";
        let bytes = framed(payload);
        let mut asm = FrameAssembler::new(1024);
        for (i, b) in bytes.iter().enumerate() {
            let frames = asm.push(std::slice::from_ref(b)).unwrap();
            if i + 1 < bytes.len() {
                assert!(frames.is_empty(), "frame completed early at byte {i}");
                assert!(asm.mid_frame());
            } else {
                assert_eq!(frames, vec![payload.to_vec()]);
                assert!(!asm.mid_frame());
            }
        }
    }

    #[test]
    fn assembler_many_frames_one_push() {
        let mut bytes = Vec::new();
        for i in 0..5u8 {
            bytes.extend_from_slice(&framed(&vec![i; i as usize + 1]));
        }
        let mut asm = FrameAssembler::new(1024);
        let frames = asm.push(&bytes).unwrap();
        assert_eq!(frames.len(), 5);
        for (i, f) in frames.iter().enumerate() {
            assert_eq!(*f, vec![i as u8; i + 1]);
        }
    }

    #[test]
    fn assembler_empty_frame_is_legal() {
        let mut asm = FrameAssembler::new(16);
        let frames = asm.push(&framed(b"")).unwrap();
        assert_eq!(frames, vec![Vec::<u8>::new()]);
    }

    #[test]
    fn assembler_oversized_header_rejected_before_payload() {
        // the cap triggers on the 4 header bytes alone: no payload has
        // to arrive (or be allocated) for the poison verdict
        let mut asm = FrameAssembler::new(100);
        let err = asm.push(&101u32.to_le_bytes()).unwrap_err();
        assert_eq!(err, FrameError::Oversized { declared: 101, max: 100 });
        assert!(asm.mid_frame(), "poisoned header should count as in-flight");
    }

    #[test]
    fn assembler_oversized_after_good_frame() {
        let mut asm = FrameAssembler::new(100);
        let mut bytes = framed(b"fine");
        bytes.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(asm.push(&bytes).is_err());
    }

    #[test]
    fn prop_assembler_random_splits_reassemble_exactly() {
        // any chunking of a frame stream must yield the same frames in
        // the same order — the state machine is split-invariant
        forall(
            0x7C1E,
            cases(200),
            |g| {
                let n_frames = g.usize_in(1, 6);
                let frames: Vec<Vec<u8>> = (0..n_frames)
                    .map(|_| {
                        let len = g.usize_in(0, 300);
                        (0..len).map(|_| g.usize_in(0, 255) as u8).collect()
                    })
                    .collect();
                let mut stream = Vec::new();
                for f in &frames {
                    stream.extend_from_slice(&framed(f));
                }
                // random cut points
                let n_cuts = g.usize_in(0, 12);
                let mut cuts: Vec<usize> =
                    (0..n_cuts).map(|_| g.usize_in(0, stream.len())).collect();
                cuts.sort_unstable();
                (frames, stream, cuts)
            },
            |(frames, stream, cuts)| {
                let mut asm = FrameAssembler::new(1024);
                let mut got = Vec::new();
                let mut prev = 0usize;
                for cut in cuts.iter().copied().chain(std::iter::once(stream.len())) {
                    got.extend(asm.push(&stream[prev..cut]).unwrap());
                    prev = cut;
                }
                assert_eq!(got, frames);
                assert!(!asm.mid_frame());
            },
        );
    }

    #[test]
    fn assembler_single_byte_splits_reassemble_multi_frame_stream() {
        // the exhaustive worst case: every byte of a multi-frame stream
        // arrives in its own push. Complements the random-split property
        // test with the finest possible chunking, deterministically.
        let frames: Vec<Vec<u8>> = vec![
            Vec::new(),                        // empty frame
            vec![0x11],                        // one byte
            (0..=255u8).collect(),             // every byte value
            vec![0xEE; 300],                   // longer than any chunk
        ];
        let mut stream = Vec::new();
        for f in &frames {
            stream.extend_from_slice(&framed(f));
        }
        let mut asm = FrameAssembler::new(1024);
        let mut got = Vec::new();
        for (i, b) in stream.iter().enumerate() {
            got.extend(asm.push(std::slice::from_ref(b)).unwrap());
            // mid-frame must be reported truthfully at every boundary
            let done = got.iter().map(|f: &Vec<u8>| f.len() + 4).sum::<usize>();
            assert_eq!(asm.mid_frame(), i + 1 != done, "byte {i}");
        }
        assert_eq!(got, frames);
        assert!(!asm.mid_frame());
    }

    #[test]
    fn chunk_frames_trickle_through_assembler_and_reassemble_bit_exact() {
        // a streamed upload crossing a real socket one byte at a time:
        // each per-layer chunk frame completes exactly at its last
        // byte, decodes on arrival, and the reassembled update is
        // bit-identical to the whole-message wire encoding
        use crate::net::wire::{ClientUpdate, Decoder, Encoder};
        let mut rng = crate::util::Rng::new(0x7C1F);
        let grads: Vec<crate::tensor::Tensor> = [vec![5usize, 4], vec![5]]
            .iter()
            .map(|s| crate::tensor::Tensor::randn(s, &mut rng))
            .collect();
        let update = ClientUpdate::Sgd { grads };
        let whole = Encoder::new(&update, 9, 4);
        let mut stream = Vec::new();
        for f in Encoder::chunk_frames(&update, 9, 4) {
            stream.extend_from_slice(&framed(&f));
        }
        let mut asm = FrameAssembler::new(MAX_FRAME_BYTES);
        let mut bodies = Vec::new();
        let mut scheme = 0u8;
        for b in &stream {
            for frame in asm.push(std::slice::from_ref(b)).unwrap() {
                let (h, body) = Decoder::decode_chunk(&frame).unwrap();
                assert_eq!(h.client_id, 9);
                assert_eq!(h.round, 4);
                assert_eq!(h.layer as usize, bodies.len());
                assert_eq!(h.last, bodies.len() + 1 == update.n_layers());
                scheme = h.scheme;
                bodies.push(body);
            }
        }
        assert!(!asm.mid_frame());
        let back = Decoder::assemble_update(scheme, bodies).unwrap();
        assert_eq!(Encoder::new(&back, 9, 4), whole);
    }

    #[test]
    fn inproc_roundtrip() {
        let t = InProcTransport::new();
        t.send(b"hello").unwrap();
        t.send(b"world").unwrap();
        assert_eq!(t.recv().unwrap(), b"hello");
        assert_eq!(t.recv().unwrap(), b"world");
    }

    #[test]
    fn inproc_cross_thread() {
        let t = std::sync::Arc::new(InProcTransport::new());
        let t2 = std::sync::Arc::clone(&t);
        let h = std::thread::spawn(move || {
            for i in 0..10u8 {
                t2.send(&[i]).unwrap();
            }
        });
        let mut got = Vec::new();
        for _ in 0..10 {
            got.push(t.recv().unwrap()[0]);
        }
        h.join().unwrap();
        got.sort_unstable();
        assert_eq!(got, (0..10).collect::<Vec<u8>>());
    }

    #[test]
    fn inproc_recv_timeout_times_out_not_hangs() {
        let t = InProcTransport::new();
        let t0 = Instant::now();
        let err = t.recv_timeout(Duration::from_millis(20)).unwrap_err();
        assert!(matches!(err, TransportError::TimedOut(_)), "{err}");
        assert!(t0.elapsed() < Duration::from_secs(5));
        // a frame that is present comes back immediately
        t.send(b"late").unwrap();
        assert_eq!(t.recv_timeout(Duration::from_millis(20)).unwrap(), b"late");
    }

    #[test]
    fn tcp_roundtrip() {
        let server = TcpServerTransport::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let mut frames = Vec::new();
            server.serve_once(|f| frames.push(f)).unwrap();
            frames
        });
        let mut client = TcpClient::connect(addr).unwrap();
        client.send(b"abc").unwrap();
        client.send(&vec![7u8; 100_000]).unwrap(); // big frame
        drop(client);
        let frames = h.join().unwrap();
        assert_eq!(frames.len(), 2);
        assert_eq!(frames[0], b"abc");
        assert_eq!(frames[1].len(), 100_000);
    }

    #[test]
    fn tcp_transport_send_recv_same_object() {
        let t = TcpTransport::bind("127.0.0.1:0").unwrap();
        t.send(b"one").unwrap();
        t.send(b"two").unwrap();
        let a = t.recv_timeout(Duration::from_secs(5)).unwrap();
        let b = t.recv_timeout(Duration::from_secs(5)).unwrap();
        let mut got = vec![a, b];
        got.sort();
        assert_eq!(got, vec![b"one".to_vec(), b"two".to_vec()]);
    }

    #[test]
    fn tcp_transport_recv_timeout_times_out() {
        let t = TcpTransport::bind("127.0.0.1:0").unwrap();
        let t0 = Instant::now();
        let err = t.recv_timeout(Duration::from_millis(30)).unwrap_err();
        assert!(matches!(err, TransportError::TimedOut(_)), "{err}");
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn tcp_transport_cross_thread_sender() {
        let t = std::sync::Arc::new(TcpTransport::bind("127.0.0.1:0").unwrap());
        let addr = t.local_addr();
        let h = std::thread::spawn(move || {
            let mut c = TcpClient::connect(addr).unwrap();
            c.send(b"from-afar").unwrap();
        });
        let frame = t.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(frame, b"from-afar");
        h.join().unwrap();
    }

    /// Raw socket helper: connect and write exactly `bytes`, keeping
    /// the connection open for the returned stream's lifetime.
    fn raw_send(addr: std::net::SocketAddr, bytes: &[u8]) -> TcpStream {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(bytes).unwrap();
        s.flush().unwrap();
        s
    }

    #[test]
    fn tcp_event_loop_reassembles_trickled_frame() {
        let t = TcpTransport::bind("127.0.0.1:0").unwrap();
        let addr = t.local_addr();
        let payload = b"slow-and-steady".to_vec();
        let bytes = framed(&payload);
        let h = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            for b in bytes {
                s.write_all(&[b]).unwrap();
                s.flush().unwrap();
                std::thread::sleep(Duration::from_millis(1));
            }
            s
        });
        let frame = t.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(frame, payload);
        drop(h.join().unwrap());
    }

    #[test]
    fn tcp_event_loop_interleaves_partial_frames_across_clients() {
        // two clients send their frames half-at-a-time, interleaved:
        // per-connection assemblers must keep the streams separate
        let t = TcpTransport::bind("127.0.0.1:0").unwrap();
        let addr = t.local_addr();
        let a = framed(b"frame-from-client-a");
        let b = framed(b"frame-from-client-b");
        let mut sa = raw_send(addr, &a[..a.len() / 2]);
        let mut sb = raw_send(addr, &b[..b.len() / 2]);
        // let the loop observe both half-frames before the tails arrive
        t.poll_once().unwrap();
        sa.write_all(&a[a.len() / 2..]).unwrap();
        sb.write_all(&b[b.len() / 2..]).unwrap();
        let mut got = vec![
            t.recv_timeout(Duration::from_secs(5)).unwrap(),
            t.recv_timeout(Duration::from_secs(5)).unwrap(),
        ];
        got.sort();
        assert_eq!(got, vec![b"frame-from-client-a".to_vec(), b"frame-from-client-b".to_vec()]);
        drop((sa, sb));
    }

    #[test]
    fn tcp_event_loop_stalled_connection_does_not_block_others() {
        let t = TcpTransport::bind("127.0.0.1:0").unwrap();
        let addr = t.local_addr();
        // stalled peer: half a frame, then silence (socket stays open)
        let full = framed(&[0xAB; 64]);
        let stalled = raw_send(addr, &full[..10]);
        // healthy peer sends a complete frame afterwards
        let healthy = raw_send(addr, &framed(b"healthy"));
        let t0 = Instant::now();
        let frame = t.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(frame, b"healthy");
        assert!(
            t0.elapsed() < Duration::from_secs(4),
            "stalled peer delayed delivery: {:?}",
            t0.elapsed()
        );
        // the stalled connection is still registered, not dropped
        assert_eq!(t.live_conns(), 2);
        // ... and can still finish its frame later
        let mut s = stalled;
        s.write_all(&full[10..]).unwrap();
        assert_eq!(t.recv_timeout(Duration::from_secs(5)).unwrap(), vec![0xAB; 64]);
        drop((s, healthy));
    }

    #[test]
    fn tcp_event_loop_survives_hostile_truncation_mid_frame() {
        let t = TcpTransport::bind("127.0.0.1:0").unwrap();
        let addr = t.local_addr();
        // declare 1000 bytes, deliver 12, vanish
        let mut hostile = (1000u32).to_le_bytes().to_vec();
        hostile.extend_from_slice(&[0u8; 12]);
        drop(raw_send(addr, &hostile));
        // the loop must shed the truncated stream and keep serving
        let err = t.recv_timeout(Duration::from_millis(200)).unwrap_err();
        assert!(matches!(err, TransportError::TimedOut(_)), "{err}");
        assert_eq!(t.live_conns(), 0, "truncated connection not shed");
        let mut c = TcpClient::connect(addr).unwrap();
        c.send(b"after-the-storm").unwrap();
        assert_eq!(t.recv_timeout(Duration::from_secs(5)).unwrap(), b"after-the-storm");
    }

    #[test]
    fn tcp_mid_frame_kill_surfaces_clean_disconnect_and_no_stale_bytes() {
        // a client trickles half a frame byte-by-byte, then dies. The
        // loop must (a) discard the partial buffer, (b) record an
        // observable per-client disconnect with the dropped byte count,
        // and (c) deliver the next client's frame untainted.
        let t = TcpTransport::bind("127.0.0.1:0").unwrap();
        let addr = t.local_addr();
        let full = framed(&[0xCD; 96]);
        let partial = &full[..full.len() / 2];
        let h = std::thread::spawn({
            let partial = partial.to_vec();
            move || {
                let mut s = TcpStream::connect(addr).unwrap();
                for b in partial {
                    s.write_all(&[b]).unwrap();
                    s.flush().unwrap();
                    std::thread::sleep(Duration::from_millis(1));
                }
                // socket dropped here: kill mid-frame
            }
        });
        h.join().unwrap();
        let err = t.recv_timeout(Duration::from_millis(300)).unwrap_err();
        assert!(matches!(err, TransportError::TimedOut(_)), "{err}");
        let disc = t.take_disconnects();
        assert_eq!(disc.len(), 1, "exactly one teardown: {disc:?}");
        assert!(disc[0].mid_frame, "kill happened mid-frame");
        assert_eq!(disc[0].bytes_dropped, partial.len());
        // drained: a second take sees nothing
        assert!(t.take_disconnects().is_empty());
        // the partial buffer died with the connection — the next frame
        // arrives intact, not prefixed by stale bytes
        let mut c = TcpClient::connect(addr).unwrap();
        c.send(b"clean-slate").unwrap();
        assert_eq!(t.recv_timeout(Duration::from_secs(5)).unwrap(), b"clean-slate");
    }

    #[test]
    fn tcp_event_loop_drops_oversized_declaration() {
        let t = TcpTransport::bind("127.0.0.1:0").unwrap();
        let addr = t.local_addr();
        // header declares more than MAX_FRAME_BYTES: connection must be
        // dropped without any payload arriving (or being allocated)
        let s = raw_send(addr, &(u32::MAX).to_le_bytes());
        let err = t.recv_timeout(Duration::from_millis(200)).unwrap_err();
        assert!(matches!(err, TransportError::TimedOut(_)), "{err}");
        assert_eq!(t.live_conns(), 0, "oversized connection not shed");
        let mut c = TcpClient::connect(addr).unwrap();
        c.send(b"still-alive").unwrap();
        assert_eq!(t.recv_timeout(Duration::from_secs(5)).unwrap(), b"still-alive");
        drop(s);
    }
}
