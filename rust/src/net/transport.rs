//! Byte transports: in-process channels (simulation) and TCP
//! (cross-process serving / integration tests).
//!
//! Framing over TCP: `u32 LE length || payload`.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;

use anyhow::{Context, Result};

/// A bidirectional message transport between clients and the server.
pub trait Transport: Send {
    /// Client side: send one framed message to the server.
    fn send(&self, payload: &[u8]) -> Result<()>;
    /// Server side: receive the next framed message (blocking).
    fn recv(&self) -> Result<Vec<u8>>;
}

// ------------------------------------------------------------- in-proc

/// mpsc-channel transport for the single-process simulation.
pub struct InProcTransport {
    tx: Sender<Vec<u8>>,
    rx: Mutex<Receiver<Vec<u8>>>,
}

impl InProcTransport {
    /// Create a connected pair view (same object is used by both sides).
    pub fn new() -> Self {
        let (tx, rx) = channel();
        InProcTransport { tx, rx: Mutex::new(rx) }
    }

    /// A cloneable sender handle for client threads.
    pub fn sender(&self) -> Sender<Vec<u8>> {
        self.tx.clone()
    }
}

impl Default for InProcTransport {
    fn default() -> Self {
        Self::new()
    }
}

impl Transport for InProcTransport {
    fn send(&self, payload: &[u8]) -> Result<()> {
        self.tx.send(payload.to_vec()).context("channel closed")
    }

    fn recv(&self) -> Result<Vec<u8>> {
        self.rx
            .lock()
            .unwrap()
            .recv()
            .context("channel closed")
    }
}

// ------------------------------------------------------------------ tcp

/// Write one length-prefixed frame.
pub fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> Result<()> {
    stream.write_all(&(payload.len() as u32).to_le_bytes())?;
    stream.write_all(payload)?;
    Ok(())
}

/// Read one length-prefixed frame.
pub fn read_frame(stream: &mut TcpStream) -> Result<Vec<u8>> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    let mut buf = vec![0u8; n];
    stream.read_exact(&mut buf)?;
    Ok(buf)
}

/// Server-side TCP transport: accepts connections lazily and yields
/// frames from any connected client.
pub struct TcpServerTransport {
    listener: TcpListener,
    conns: Mutex<HashMap<std::net::SocketAddr, TcpStream>>,
}

impl TcpServerTransport {
    /// Bind on an address (e.g. "127.0.0.1:0" to pick a free port).
    pub fn bind(addr: impl ToSocketAddrs) -> Result<Self> {
        let listener = TcpListener::bind(addr).context("binding")?;
        Ok(TcpServerTransport { listener, conns: Mutex::new(HashMap::new()) })
    }

    /// The bound address (for clients to connect to).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept one connection and read frames from it until EOF, passing
    /// each to `f`. Simple one-connection-at-a-time server loop used by
    /// `qrr serve` (clients connect, push an update, disconnect).
    pub fn serve_once(&self, mut f: impl FnMut(Vec<u8>)) -> Result<()> {
        let (mut stream, peer) = self.listener.accept()?;
        loop {
            match read_frame(&mut stream) {
                Ok(frame) => f(frame),
                Err(_) => break, // EOF / closed
            }
        }
        self.conns.lock().unwrap().remove(&peer);
        Ok(())
    }
}

/// Client-side TCP sender.
pub struct TcpClient {
    stream: TcpStream,
}

impl TcpClient {
    /// Connect to the server.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self> {
        Ok(TcpClient { stream: TcpStream::connect(addr).context("connecting")? })
    }

    /// Send one framed message.
    pub fn send(&mut self, payload: &[u8]) -> Result<()> {
        write_frame(&mut self.stream, payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inproc_roundtrip() {
        let t = InProcTransport::new();
        t.send(b"hello").unwrap();
        t.send(b"world").unwrap();
        assert_eq!(t.recv().unwrap(), b"hello");
        assert_eq!(t.recv().unwrap(), b"world");
    }

    #[test]
    fn inproc_cross_thread() {
        let t = std::sync::Arc::new(InProcTransport::new());
        let t2 = std::sync::Arc::clone(&t);
        let h = std::thread::spawn(move || {
            for i in 0..10u8 {
                t2.send(&[i]).unwrap();
            }
        });
        let mut got = Vec::new();
        for _ in 0..10 {
            got.push(t.recv().unwrap()[0]);
        }
        h.join().unwrap();
        got.sort_unstable();
        assert_eq!(got, (0..10).collect::<Vec<u8>>());
    }

    #[test]
    fn tcp_roundtrip() {
        let server = TcpServerTransport::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let mut frames = Vec::new();
            server.serve_once(|f| frames.push(f)).unwrap();
            frames
        });
        let mut client = TcpClient::connect(addr).unwrap();
        client.send(b"abc").unwrap();
        client.send(&vec![7u8; 100_000]).unwrap(); // big frame
        drop(client);
        let frames = h.join().unwrap();
        assert_eq!(frames.len(), 2);
        assert_eq!(frames[0], b"abc");
        assert_eq!(frames[1].len(), 100_000);
    }
}
