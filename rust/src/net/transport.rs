//! Byte transports: in-process channels (simulation) and TCP
//! (cross-process serving / integration tests).
//!
//! Framing over TCP: `u32 LE length || payload`.
//!
//! Every [`Transport`] supports both blocking [`Transport::recv`] and
//! deadline-bounded [`Transport::recv_timeout`]; the session round loop
//! uses the latter so a dropped client (or a lost frame) can never hang
//! a round — see `fl::session` (DESIGN.md §1).

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};
use thiserror::Error;

/// Errors produced by deadline-bounded receives.
#[derive(Debug, Error)]
pub enum TransportError {
    /// The peer is gone for good; no more frames will ever arrive.
    #[error("transport closed")]
    Closed,
    /// No frame arrived within the deadline; later frames may still come.
    #[error("receive timed out after {0:?}")]
    TimedOut(Duration),
    /// Underlying socket error.
    #[error("transport i/o: {0}")]
    Io(#[from] std::io::Error),
}

/// A bidirectional message transport between clients and the server.
pub trait Transport: Send {
    /// Client side: send one framed message to the server.
    fn send(&self, payload: &[u8]) -> Result<()>;

    /// Server side: receive the next framed message (blocking).
    fn recv(&self) -> Result<Vec<u8>>;

    /// Server side: receive the next framed message, waiting at most
    /// `timeout`. Distinguishes a dead peer ([`TransportError::Closed`])
    /// from a slow one ([`TransportError::TimedOut`]).
    fn recv_timeout(&self, timeout: Duration) -> std::result::Result<Vec<u8>, TransportError>;
}

// ------------------------------------------------------------- in-proc

/// mpsc-channel transport for the single-process simulation.
#[derive(Debug)]
pub struct InProcTransport {
    tx: Sender<Vec<u8>>,
    rx: Mutex<Receiver<Vec<u8>>>,
}

impl InProcTransport {
    /// Create a connected pair view (same object is used by both sides).
    pub fn new() -> Self {
        let (tx, rx) = channel();
        InProcTransport { tx, rx: Mutex::new(rx) }
    }

    /// A cloneable sender handle for client threads.
    pub fn sender(&self) -> Sender<Vec<u8>> {
        self.tx.clone()
    }
}

impl Default for InProcTransport {
    fn default() -> Self {
        Self::new()
    }
}

impl Transport for InProcTransport {
    fn send(&self, payload: &[u8]) -> Result<()> {
        self.tx.send(payload.to_vec()).context("channel closed")
    }

    fn recv(&self) -> Result<Vec<u8>> {
        self.rx
            .lock()
            .unwrap()
            .recv()
            .context("channel closed")
    }

    fn recv_timeout(&self, timeout: Duration) -> std::result::Result<Vec<u8>, TransportError> {
        self.rx
            .lock()
            .unwrap()
            .recv_timeout(timeout)
            .map_err(|e| match e {
                RecvTimeoutError::Timeout => TransportError::TimedOut(timeout),
                RecvTimeoutError::Disconnected => TransportError::Closed,
            })
    }
}

// ------------------------------------------------------------------ tcp

/// Write one length-prefixed frame.
pub fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> Result<()> {
    stream.write_all(&(payload.len() as u32).to_le_bytes())?;
    stream.write_all(payload)?;
    Ok(())
}

/// Read one length-prefixed frame.
pub fn read_frame(stream: &mut TcpStream) -> Result<Vec<u8>> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    let mut buf = vec![0u8; n];
    stream.read_exact(&mut buf)?;
    Ok(buf)
}

/// Loopback TCP binding implementing [`Transport`] on a single object:
/// `send` opens a fresh connection to the bound listener and pushes one
/// frame (the sensor-style duty cycle of `qrr serve`), `recv` /
/// `recv_timeout` accept pending connections and drain their frames.
///
/// This is what `fl::session` plugs in for the TCP scenario: the exact
/// wire bytes cross a real socket while the round loop stays unchanged.
#[derive(Debug)]
pub struct TcpTransport {
    listener: TcpListener,
    addr: std::net::SocketAddr,
    /// frames read from accepted connections but not yet handed out
    pending: Mutex<VecDeque<Vec<u8>>>,
}

impl TcpTransport {
    /// Bind on an address (e.g. "127.0.0.1:0" to pick a free port).
    pub fn bind(addr: impl ToSocketAddrs) -> Result<Self> {
        let listener = TcpListener::bind(addr).context("binding")?;
        let addr = listener.local_addr()?;
        Ok(TcpTransport { listener, addr, pending: Mutex::new(VecDeque::new()) })
    }

    /// The bound address (for out-of-process clients to connect to).
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Accept one connection before `deadline` and queue every frame it
    /// carries. Returns `Ok(true)` if at least one frame was queued.
    fn accept_into_queue(
        &self,
        deadline: Instant,
        timeout: Duration,
    ) -> std::result::Result<bool, TransportError> {
        self.listener.set_nonblocking(true)?;
        let accepted = loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => break stream,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        self.listener.set_nonblocking(false).ok();
                        return Err(TransportError::TimedOut(timeout));
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => {
                    self.listener.set_nonblocking(false).ok();
                    return Err(TransportError::Io(e));
                }
            }
        };
        self.listener.set_nonblocking(false).ok();

        let mut stream = accepted;
        // accepted sockets must not inherit the listener's non-blocking
        // mode, and a half-sent frame must not hang past the deadline
        stream.set_nonblocking(false)?;

        let mut got = 0usize;
        let mut q = self.pending.lock().unwrap();
        // the drain loop is deadline-bounded too: a peer trickling
        // frames must not hold the queue (and its mutex) open past the
        // caller's budget
        loop {
            if Instant::now() >= deadline && got > 0 {
                break;
            }
            let budget = deadline
                .saturating_duration_since(Instant::now())
                .max(Duration::from_millis(10));
            if stream.set_read_timeout(Some(budget)).is_err() {
                break;
            }
            match read_frame(&mut stream) {
                Ok(frame) => {
                    q.push_back(frame);
                    got += 1;
                }
                Err(_) => break, // EOF / peer closed / read timeout
            }
        }
        Ok(got > 0)
    }
}

impl Transport for TcpTransport {
    /// Queue one frame for delivery. The write happens on a detached
    /// thread: the session round loop sends every frame *before* it
    /// starts accepting, so a blocking write to this object's own
    /// not-yet-accepting listener would deadlock once a frame outgrows
    /// the loopback socket buffers. A failed write surfaces as a recv
    /// timeout on the other side — the same as any lost frame.
    fn send(&self, payload: &[u8]) -> Result<()> {
        let addr = self.addr;
        let payload = payload.to_vec();
        std::thread::Builder::new()
            .name("qrr-tcp-send".into())
            .spawn(move || {
                let push = || -> Result<()> {
                    let mut stream = TcpStream::connect(addr).context("connecting")?;
                    write_frame(&mut stream, &payload)
                };
                if let Err(e) = push() {
                    log::warn!("tcp transport: frame lost ({e:#})");
                }
            })
            .context("spawning tcp send thread")?;
        Ok(())
    }

    fn recv(&self) -> Result<Vec<u8>> {
        loop {
            match self.recv_timeout(Duration::from_secs(60)) {
                Ok(frame) => return Ok(frame),
                Err(TransportError::TimedOut(_)) => continue,
                Err(e) => return Err(e.into()),
            }
        }
    }

    fn recv_timeout(&self, timeout: Duration) -> std::result::Result<Vec<u8>, TransportError> {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(frame) = self.pending.lock().unwrap().pop_front() {
                return Ok(frame);
            }
            // empty connections (a peer that connected and vanished) are
            // skipped; keep accepting until a frame shows up or time runs out
            self.accept_into_queue(deadline, timeout)?;
        }
    }
}

/// Server-side TCP transport: accepts connections lazily and yields
/// frames from any connected client.
#[derive(Debug)]
pub struct TcpServerTransport {
    listener: TcpListener,
    conns: Mutex<HashMap<std::net::SocketAddr, TcpStream>>,
}

impl TcpServerTransport {
    /// Bind on an address (e.g. "127.0.0.1:0" to pick a free port).
    pub fn bind(addr: impl ToSocketAddrs) -> Result<Self> {
        let listener = TcpListener::bind(addr).context("binding")?;
        Ok(TcpServerTransport { listener, conns: Mutex::new(HashMap::new()) })
    }

    /// The bound address (for clients to connect to).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept one connection and read frames from it until EOF, passing
    /// each to `f`. Simple one-connection-at-a-time server loop used by
    /// `qrr serve` (clients connect, push an update, disconnect).
    pub fn serve_once(&self, mut f: impl FnMut(Vec<u8>)) -> Result<()> {
        let (mut stream, peer) = self.listener.accept()?;
        loop {
            match read_frame(&mut stream) {
                Ok(frame) => f(frame),
                Err(_) => break, // EOF / closed
            }
        }
        self.conns.lock().unwrap().remove(&peer);
        Ok(())
    }
}

/// Client-side TCP sender.
#[derive(Debug)]
pub struct TcpClient {
    stream: TcpStream,
}

impl TcpClient {
    /// Connect to the server.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Self> {
        Ok(TcpClient { stream: TcpStream::connect(addr).context("connecting")? })
    }

    /// Send one framed message.
    pub fn send(&mut self, payload: &[u8]) -> Result<()> {
        write_frame(&mut self.stream, payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inproc_roundtrip() {
        let t = InProcTransport::new();
        t.send(b"hello").unwrap();
        t.send(b"world").unwrap();
        assert_eq!(t.recv().unwrap(), b"hello");
        assert_eq!(t.recv().unwrap(), b"world");
    }

    #[test]
    fn inproc_cross_thread() {
        let t = std::sync::Arc::new(InProcTransport::new());
        let t2 = std::sync::Arc::clone(&t);
        let h = std::thread::spawn(move || {
            for i in 0..10u8 {
                t2.send(&[i]).unwrap();
            }
        });
        let mut got = Vec::new();
        for _ in 0..10 {
            got.push(t.recv().unwrap()[0]);
        }
        h.join().unwrap();
        got.sort_unstable();
        assert_eq!(got, (0..10).collect::<Vec<u8>>());
    }

    #[test]
    fn inproc_recv_timeout_times_out_not_hangs() {
        let t = InProcTransport::new();
        let t0 = Instant::now();
        let err = t.recv_timeout(Duration::from_millis(20)).unwrap_err();
        assert!(matches!(err, TransportError::TimedOut(_)), "{err}");
        assert!(t0.elapsed() < Duration::from_secs(5));
        // a frame that is present comes back immediately
        t.send(b"late").unwrap();
        assert_eq!(t.recv_timeout(Duration::from_millis(20)).unwrap(), b"late");
    }

    #[test]
    fn tcp_roundtrip() {
        let server = TcpServerTransport::bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let mut frames = Vec::new();
            server.serve_once(|f| frames.push(f)).unwrap();
            frames
        });
        let mut client = TcpClient::connect(addr).unwrap();
        client.send(b"abc").unwrap();
        client.send(&vec![7u8; 100_000]).unwrap(); // big frame
        drop(client);
        let frames = h.join().unwrap();
        assert_eq!(frames.len(), 2);
        assert_eq!(frames[0], b"abc");
        assert_eq!(frames[1].len(), 100_000);
    }

    #[test]
    fn tcp_transport_send_recv_same_object() {
        let t = TcpTransport::bind("127.0.0.1:0").unwrap();
        t.send(b"one").unwrap();
        t.send(b"two").unwrap();
        let a = t.recv_timeout(Duration::from_secs(5)).unwrap();
        let b = t.recv_timeout(Duration::from_secs(5)).unwrap();
        let mut got = vec![a, b];
        got.sort();
        assert_eq!(got, vec![b"one".to_vec(), b"two".to_vec()]);
    }

    #[test]
    fn tcp_transport_recv_timeout_times_out() {
        let t = TcpTransport::bind("127.0.0.1:0").unwrap();
        let t0 = Instant::now();
        let err = t.recv_timeout(Duration::from_millis(30)).unwrap_err();
        assert!(matches!(err, TransportError::TimedOut(_)), "{err}");
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn tcp_transport_cross_thread_sender() {
        let t = std::sync::Arc::new(TcpTransport::bind("127.0.0.1:0").unwrap());
        let addr = t.local_addr();
        let h = std::thread::spawn(move || {
            let mut c = TcpClient::connect(addr).unwrap();
            c.send(b"from-afar").unwrap();
        });
        let frame = t.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(frame, b"from-afar");
        h.join().unwrap();
    }
}
