//! Per-client link models for the network-critical deployments the paper
//! targets (remote sensors on very slow connections).
//!
//! A [`LinkModel`] converts payload bits into simulated transmission
//! time; the coordinator uses it both for the reported network time and
//! to derive each client's adaptive `p` (experiment 3: "p can be chosen
//! based on the client's connection speed").

use std::time::Duration;

/// A (bandwidth, latency) link abstraction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkModel {
    /// uplink bandwidth, bits per second
    pub bandwidth_bps: f64,
    /// fixed per-message latency
    pub latency: Duration,
}

impl LinkModel {
    /// A comfortable broadband link (100 Mbit/s, 10 ms).
    pub fn broadband() -> Self {
        LinkModel { bandwidth_bps: 100e6, latency: Duration::from_millis(10) }
    }

    /// A constrained IoT/LTE-M-class link (250 kbit/s, 120 ms) — the
    /// paper's "network-critical" regime.
    pub fn iot() -> Self {
        LinkModel { bandwidth_bps: 250e3, latency: Duration::from_millis(120) }
    }

    /// Evenly interpolate `n` links between `slow` and `fast` bandwidths
    /// (used to hand experiment 3 its spread of client speeds).
    pub fn spread(n: usize, slow_bps: f64, fast_bps: f64) -> Vec<LinkModel> {
        assert!(n > 0);
        (0..n)
            .map(|i| {
                let t = if n == 1 { 0.0 } else { i as f64 / (n - 1) as f64 };
                LinkModel {
                    bandwidth_bps: slow_bps + t * (fast_bps - slow_bps),
                    latency: Duration::from_millis(120 - (t * 100.0) as u64),
                }
            })
            .collect()
    }

    /// Simulated wall-clock time to push `bits` through this link.
    pub fn transmit_time(&self, bits: u64) -> Duration {
        let secs = bits as f64 / self.bandwidth_bps;
        self.latency + Duration::from_secs_f64(secs)
    }

    /// Map link speed to the paper's compression fraction `p ∈ [p_min,
    /// p_max]`: slowest link gets `p_min` (most compression), fastest
    /// gets `p_max`. Linear in log-bandwidth between `slow` and `fast`.
    /// A degenerate cohort (`slow_bps >= fast_bps`) has no spread to
    /// interpolate over; the midpoint is returned rather than letting
    /// the 0/0 produce a NaN that would survive `clamp` and poison `p`.
    pub fn adaptive_p(&self, slow_bps: f64, fast_bps: f64, p_min: f64, p_max: f64) -> f64 {
        let lo = slow_bps.ln();
        let hi = fast_bps.ln();
        if hi <= lo {
            return 0.5 * (p_min + p_max);
        }
        let t = ((self.bandwidth_bps.ln() - lo) / (hi - lo)).clamp(0.0, 1.0);
        p_min + t * (p_max - p_min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transmit_time_scales_with_bits() {
        let l = LinkModel { bandwidth_bps: 1e6, latency: Duration::ZERO };
        assert_eq!(l.transmit_time(1_000_000), Duration::from_secs(1));
        assert_eq!(l.transmit_time(500_000), Duration::from_millis(500));
    }

    #[test]
    fn latency_added() {
        let l = LinkModel { bandwidth_bps: 1e6, latency: Duration::from_millis(50) };
        assert_eq!(l.transmit_time(0), Duration::from_millis(50));
    }

    #[test]
    fn spread_monotone() {
        let links = LinkModel::spread(5, 1e5, 1e7);
        for w in links.windows(2) {
            assert!(w[1].bandwidth_bps > w[0].bandwidth_bps);
        }
        assert_eq!(links.len(), 5);
    }

    #[test]
    fn adaptive_p_maps_slow_to_pmin() {
        let links = LinkModel::spread(3, 1e5, 1e7);
        let p0 = links[0].adaptive_p(1e5, 1e7, 0.1, 0.3);
        let p2 = links[2].adaptive_p(1e5, 1e7, 0.1, 0.3);
        assert!((p0 - 0.1).abs() < 1e-9);
        assert!((p2 - 0.3).abs() < 1e-9);
        let pm = links[1].adaptive_p(1e5, 1e7, 0.1, 0.3);
        assert!(pm > 0.1 && pm < 0.3);
    }

    #[test]
    fn adaptive_p_equal_cohort_bounds_returns_midpoint_not_nan() {
        // regression: slow_bps == fast_bps made (hi - lo) zero and the
        // resulting NaN survived clamp, poisoning p downstream
        let l = LinkModel::iot();
        let p = l.adaptive_p(250e3, 250e3, 0.1, 0.3);
        assert!(p.is_finite(), "degenerate cohort produced NaN p");
        assert!((p - 0.2).abs() < 1e-12, "expected midpoint, got {p}");
        // an inverted range is equally degenerate
        let p = l.adaptive_p(1e7, 1e5, 0.1, 0.3);
        assert!(p.is_finite() && (p - 0.2).abs() < 1e-12);
        // the fix must not disturb a healthy cohort
        assert!((l.adaptive_p(250e3, 1e7, 0.1, 0.3) - 0.1).abs() < 1e-9);
    }

    #[test]
    fn iot_much_slower_than_broadband() {
        let bits = 1_000_000u64;
        assert!(LinkModel::iot().transmit_time(bits) > 10 * LinkModel::broadband().transmit_time(bits));
    }
}
