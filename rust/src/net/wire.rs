//! Wire format for client→server updates and the server→client
//! broadcast.
//!
//! Client update layout (little-endian):
//!
//! ```text
//! header:  magic u32 = 0x51525257 ("QRRW") | version u8 | scheme u8 |
//!          client_id u32 | round u64 | n_entries u32
//! entry:   kind u8 | payload…
//!   kind 0 dense-f32  : ndim u8, dims u32×ndim, f32×n
//!   kind 1 quantized  : radius f32, beta u8, len u64, packed bytes
//!   kind 2 svd        : 3 × quantized (U, Σ, V)
//!   kind 3 tucker     : core quantized + n_factors u8 + factors
//!   kind 4 raw svd    : 3 × dense-f32 (U, σ vector, V)
//!   kind 5 raw tucker : dense-f32 core + n_factors u8 + dense-f32×n
//! ```
//!
//! Kinds 4/5 (and kind 0 inside a pipeline update) carry the factors of
//! identity-quantizer pipelines at full precision; the legacy schemes
//! never emit them, so their byte layout is untouched.
//!
//! The downlink broadcast is a [`ServerUpdate`]: its own magic
//! (`"QRRB"`), a version byte, a dense `seq` counter (the downlink
//! decoder enforces exactly-once in-order delivery), the round label,
//! and the same entry encoding — sized exactly by
//! [`ServerUpdate::wire_len`] like [`ClientUpdate`].
//!
//! A broadcast may instead be a **snapshot** frame (magic `"QRRS"`,
//! same layout otherwise): full state rather than a delta, carried as
//! raw-dense entries. Snapshots are the resync path — a decoder that
//! detected a sequence gap re-primes from one instead of staying
//! desynced forever (see
//! [`crate::compress::pipeline::DownlinkDecoder::apply_snapshot`]).
//!
//! `payload_bits` (what the experiments count) excludes the fixed header
//! and the shape/rank metadata: exactly the paper's accounting of
//! factor/code payloads — 32 bits per f32 and β bits per code.

use thiserror::Error;

use crate::qrr::ParamMsg;
use crate::quant::Quantized;
use crate::slaq::SlaqMsg;
use crate::tensor::Tensor;

const MAGIC: u32 = 0x5152_5257;
const VERSION: u8 = 1;
/// "QRRB" — the server→client broadcast stream.
const SERVER_MAGIC: u32 = 0x5152_5242;
const SERVER_VERSION: u8 = 1;
/// "QRRS" — a broadcast **snapshot** (full state, not a delta): same
/// layout as `"QRRB"` after the magic, distinguished so a delta can
/// never be mistaken for a resync (or vice versa) by a bit flip in the
/// body.
const SNAPSHOT_MAGIC: u32 = 0x5152_5253;
/// "QRRC" — a chunked per-layer uplink frame (streaming mode): one
/// wire entry per frame so the server can decode-and-absorb layer *l*
/// while layer *l+1* is still in flight.
const CHUNK_MAGIC: u32 = 0x5152_5243;
const CHUNK_VERSION: u8 = 1;
/// Chunk flag bit 0: this frame carries the final layer. Redundant
/// with `layer + 1 == n_layers` and validated against it, so a bit
/// flip in either encoding is caught at peek time.
const CHUNK_FLAG_LAST: u8 = 1;
/// Fixed chunk header: magic u32 | version u8 | scheme u8 | flags u8 |
/// client_id u32 | round u64 | layer u32 | n_layers u32.
pub const CHUNK_HEADER_LEN: usize = 4 + 1 + 1 + 1 + 4 + 8 + 4 + 4;

/// Errors produced when decoding a wire message.
#[derive(Debug, Error)]
pub enum WireError {
    /// magic/version mismatch
    #[error("bad magic or version")]
    BadHeader,
    /// message truncated
    #[error("unexpected end of message at byte {0}")]
    Truncated(usize),
    /// unknown entry kind tag
    #[error("unknown entry kind {0}")]
    UnknownKind(u8),
    /// scheme tag not recognised
    #[error("unknown scheme tag {0}")]
    UnknownScheme(u8),
    /// chunk header internally inconsistent (layer out of range, zero
    /// layer count, last-flag disagreeing with the indices, unknown
    /// flag bits) or a chunk body whose kind disagrees with its scheme
    #[error("invalid chunk frame")]
    BadChunk,
}

/// A client update, scheme-tagged.
#[derive(Debug, Clone)]
pub enum ClientUpdate {
    /// Full-precision gradients (the SGD / FedAvg baseline).
    Sgd {
        /// gradient tensors in spec order
        grads: Vec<Tensor>,
    },
    /// SLAQ quantized innovations (None = lazily skipped round; skipped
    /// rounds transmit nothing and don't appear on the wire at all).
    Slaq {
        /// quantized payloads per parameter
        msg: SlaqMsg,
    },
    /// QRR compressed + quantized factors.
    Qrr {
        /// per-parameter factor messages
        msgs: Vec<ParamMsg>,
    },
}

impl ClientUpdate {
    /// Scheme tag byte.
    fn scheme_tag(&self) -> u8 {
        match self {
            ClientUpdate::Sgd { .. } => 0,
            ClientUpdate::Slaq { .. } => 1,
            ClientUpdate::Qrr { .. } => 2,
        }
    }

    /// The paper's `#bits` for this update: payload only (f32 values at
    /// 32 bits, quantized tensors at 32 + βn).
    pub fn payload_bits(&self) -> u64 {
        match self {
            ClientUpdate::Sgd { grads } => grads.iter().map(|g| 32 * g.len() as u64).sum(),
            ClientUpdate::Slaq { msg } => msg.wire_bits(),
            ClientUpdate::Qrr { msgs } => msgs.iter().map(|m| m.wire_bits()).sum(),
        }
    }

    /// Exact serialized size in bytes, mirroring [`Encoder::new`] byte
    /// for byte. The encoder allocates this up front so a round's
    /// serialize phase is a single allocation, never a growth series.
    pub fn wire_len(&self) -> usize {
        // magic u32 | version u8 | scheme u8 | client_id u32 | round u64
        // | n_entries u32
        const HEADER: usize = 4 + 1 + 1 + 4 + 8 + 4;
        let body: usize = match self {
            ClientUpdate::Sgd { grads } => grads.iter().map(|g| 1 + dense_len(g)).sum(),
            ClientUpdate::Slaq { msg } => msg.params.iter().map(|q| 1 + q_len(q)).sum(),
            ClientUpdate::Qrr { msgs } => msgs.iter().map(param_msg_len).sum(),
        };
        HEADER + body
    }

    /// Number of per-layer chunk frames this update splits into — one
    /// wire entry per frame, so it equals the whole-message
    /// `n_entries`.
    pub fn n_layers(&self) -> usize {
        match self {
            ClientUpdate::Sgd { grads } => grads.len(),
            ClientUpdate::Slaq { msg } => msg.params.len(),
            ClientUpdate::Qrr { msgs } => msgs.len(),
        }
    }

    /// Exact serialized size of the chunk frame carrying `layer`,
    /// mirroring [`Encoder::chunk`] byte for byte: the fixed chunk
    /// header plus that layer's whole-message entry encoding,
    /// unchanged.
    pub fn chunk_wire_len(&self, layer: usize) -> usize {
        CHUNK_HEADER_LEN
            + match self {
                ClientUpdate::Sgd { grads } => 1 + dense_len(&grads[layer]),
                ClientUpdate::Slaq { msg } => 1 + q_len(&msg.params[layer]),
                ClientUpdate::Qrr { msgs } => param_msg_len(&msgs[layer]),
            }
    }
}

/// The fixed header of one per-layer **chunk** frame (streaming mode).
///
/// Chunks carry the same per-entry encoding as the sequential frame —
/// one entry per chunk — so reassembling every layer reproduces the
/// whole-message [`ClientUpdate`] bit for bit, and per-layer
/// `payload_bits` sum to the whole-message total by construction.
/// Internal consistency (layer within range, last-flag agreeing with
/// the indices) is validated at peek time; the body stays attacker
/// data until [`Decoder::decode_chunk`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkHeader {
    /// scheme tag (0 = SGD, 1 = SLAQ, 2 = QRR)
    pub scheme: u8,
    /// sending client
    pub client_id: u32,
    /// FL round index
    pub round: u64,
    /// 0-based layer index within the update (`< n_layers`)
    pub layer: u32,
    /// total layer count the sender declares (same in every chunk of
    /// one update; untrusted until the receiver checks it against the
    /// model spec)
    pub n_layers: u32,
    /// `true` ⇔ the final layer (`layer + 1 == n_layers`)
    pub last: bool,
}

/// The decoded body of one chunk frame: exactly one layer, in the same
/// representation the whole-message decoder produces for that entry.
#[derive(Debug, Clone)]
pub enum ChunkBody {
    /// scheme 0 (SGD): one dense-f32 gradient
    Dense(Tensor),
    /// scheme 1 (SLAQ): one quantized innovation
    Quantized(Quantized),
    /// scheme 2 (QRR): one per-parameter factor message
    Msg(ParamMsg),
}

impl ChunkBody {
    /// Paper bits accounting for this layer alone; summed over an
    /// update's chunks this equals [`ClientUpdate::payload_bits`].
    pub fn payload_bits(&self) -> u64 {
        match self {
            ChunkBody::Dense(t) => 32 * t.len() as u64,
            ChunkBody::Quantized(q) => 32 + q.beta as u64 * q.len as u64,
            ChunkBody::Msg(m) => m.wire_bits(),
        }
    }
}

/// Serialized bytes of one quantized factor: radius f32 | beta u8 |
/// len u64 | packed bytes.
fn q_len(q: &Quantized) -> usize {
    4 + 1 + 8 + q.packed.len()
}

/// Serialized bytes of one dense-f32 tensor: ndim u8 | dims u32×ndim |
/// f32×n.
fn dense_len(t: &Tensor) -> usize {
    1 + 4 * t.ndim() + 4 * t.len()
}

/// Exact serialized bytes of one per-parameter entry (kind byte
/// included), shared by [`ClientUpdate::wire_len`] and
/// [`ServerUpdate::wire_len`].
fn param_msg_len(m: &ParamMsg) -> usize {
    match m {
        ParamMsg::Dense { q } => 1 + q_len(q),
        ParamMsg::Svd { u, s, v } => 1 + q_len(u) + q_len(s) + q_len(v),
        ParamMsg::Tucker { core, factors } => {
            1 + q_len(core) + 1 + factors.iter().map(q_len).sum::<usize>()
        }
        ParamMsg::RawDense { t } => 1 + dense_len(t),
        ParamMsg::RawSvd { u, s, v } => 1 + dense_len(u) + dense_len(s) + dense_len(v),
        ParamMsg::RawTucker { core, factors } => {
            1 + dense_len(core) + 1 + factors.iter().map(dense_len).sum::<usize>()
        }
    }
}

/// The server→client broadcast: the compressed parameter delta for one
/// round, encoded with the same per-parameter entries as a pipeline
/// [`ClientUpdate`] (see [`crate::compress::pipeline::DownlinkEncoder`]).
#[derive(Debug, Clone)]
pub struct ServerUpdate {
    /// dense per-broadcast counter (0, 1, 2, …): the differential
    /// downlink codec must apply every broadcast exactly once in order,
    /// so the decoder rejects any `seq` that is not the next expected —
    /// unlike `round`, which is a free-form label and may jump
    pub seq: u64,
    /// FL round index this broadcast opens
    pub round: u64,
    /// per-parameter delta messages in spec order
    pub msgs: Vec<ParamMsg>,
    /// `true` ⇒ this frame is a resync **snapshot**: `msgs` carry the
    /// full model state (raw-dense entries), not a delta, and `seq` is
    /// the sequence number the decoder must expect *next* rather than
    /// the one being consumed. Encoded under its own magic so the two
    /// frame families can never be confused on the wire.
    pub snapshot: bool,
}

impl ServerUpdate {
    /// The `#bits` the downlink accounting charges: factor payloads
    /// only, same rules as [`ClientUpdate::payload_bits`].
    pub fn payload_bits(&self) -> u64 {
        self.msgs.iter().map(|m| m.wire_bits()).sum()
    }

    /// Exact serialized size in bytes, mirroring [`Encoder::server`].
    pub fn wire_len(&self) -> usize {
        // magic u32 | version u8 | seq u64 | round u64 | n_entries u32
        const HEADER: usize = 4 + 1 + 8 + 8 + 4;
        HEADER + self.msgs.iter().map(param_msg_len).sum::<usize>()
    }
}

// ---------------------------------------------------------------- encoder

/// Byte-stream writer.
#[derive(Debug)]
pub struct Encoder {
    buf: Vec<u8>,
}

impl Encoder {
    /// Serialize a message for `client_id` at `round` into a fresh,
    /// exactly-sized buffer.
    pub fn new(update: &ClientUpdate, client_id: u32, round: u64) -> Vec<u8> {
        let mut buf = Vec::new();
        Self::encode_into(update, client_id, round, &mut buf);
        buf
    }

    /// Serialize into `buf`, reusing its capacity (cleared first):
    /// repeated encodes through a persistent buffer allocate nothing
    /// once it has grown to the message size. The round loop itself
    /// uses [`Encoder::new`] — its output is moved into the upload, so
    /// it pays exactly one exact-size allocation per encode (see
    /// [`ClientUpdate::wire_len`]); this entry point is for callers
    /// that keep a buffer across encodes (benches, long-lived peers).
    //
    // The rest of this impl is the encode hot path: it may only grow
    // the target buffer (push/extend/reserve), never mint fresh
    // containers, so the reuse promise above stays honest.
    // qrr-audit: no-alloc
    pub fn encode_into(update: &ClientUpdate, client_id: u32, round: u64, buf: &mut Vec<u8>) {
        buf.clear();
        buf.reserve_exact(update.wire_len());
        let mut e = Encoder { buf: std::mem::take(buf) };
        e.write_update(update, client_id, round);
        debug_assert_eq!(e.buf.len(), update.wire_len(), "wire_len drifted from encoder");
        *buf = e.buf;
    }

    fn write_update(&mut self, update: &ClientUpdate, client_id: u32, round: u64) {
        let e = self;
        e.u32(MAGIC);
        e.u8(VERSION);
        e.u8(update.scheme_tag());
        e.u32(client_id);
        e.u64(round);
        match update {
            ClientUpdate::Sgd { grads } => {
                e.u32(grads.len() as u32);
                for g in grads {
                    e.u8(0);
                    e.dense(g);
                }
            }
            ClientUpdate::Slaq { msg } => {
                e.u32(msg.params.len() as u32);
                for q in &msg.params {
                    e.u8(1);
                    e.quantized(q);
                }
            }
            ClientUpdate::Qrr { msgs } => {
                e.u32(msgs.len() as u32);
                for m in msgs {
                    e.param_msg(m);
                }
            }
        }
    }

    /// Serialize a [`ServerUpdate`] into a fresh, exactly-sized buffer.
    pub fn server(update: &ServerUpdate) -> Vec<u8> {
        let mut e = Encoder { buf: Vec::with_capacity(update.wire_len()) };
        e.u32(if update.snapshot { SNAPSHOT_MAGIC } else { SERVER_MAGIC });
        e.u8(SERVER_VERSION);
        e.u64(update.seq);
        e.u64(update.round);
        e.u32(update.msgs.len() as u32);
        for m in &update.msgs {
            e.param_msg(m);
        }
        debug_assert_eq!(e.buf.len(), update.wire_len(), "wire_len drifted from encoder");
        e.buf
    }

    /// Serialize layer `layer` of `update` as one chunk frame
    /// (`"QRRC"`) into a fresh, exactly-sized buffer. The body is the
    /// layer's whole-message entry encoding, unchanged — reassembling
    /// every chunk reproduces [`Encoder::new`]'s update bit for bit.
    pub fn chunk(update: &ClientUpdate, layer: usize, client_id: u32, round: u64) -> Vec<u8> {
        let n_layers = update.n_layers();
        debug_assert!(layer < n_layers, "chunk layer out of range");
        let mut e = Encoder { buf: Vec::with_capacity(update.chunk_wire_len(layer)) };
        e.u32(CHUNK_MAGIC);
        e.u8(CHUNK_VERSION);
        e.u8(update.scheme_tag());
        e.u8(if layer + 1 == n_layers { CHUNK_FLAG_LAST } else { 0 });
        e.u32(client_id);
        e.u64(round);
        e.u32(layer as u32);
        e.u32(n_layers as u32);
        match update {
            ClientUpdate::Sgd { grads } => {
                e.u8(0);
                e.dense(&grads[layer]);
            }
            ClientUpdate::Slaq { msg } => {
                e.u8(1);
                e.quantized(&msg.params[layer]);
            }
            ClientUpdate::Qrr { msgs } => e.param_msg(&msgs[layer]),
        }
        debug_assert_eq!(
            e.buf.len(),
            update.chunk_wire_len(layer),
            "chunk_wire_len drifted from encoder"
        );
        e.buf
    }

    /// All per-layer chunk frames of `update` in layer order — the
    /// streaming uplink's send units. Each layer is serialized lazily
    /// inside the loop, so a caller transmitting frame *l* as it is
    /// returned overlaps the serialize of layer *l+1* with the send of
    /// layer *l* (see `compress::pipeline::PipelineClient::
    /// produce_chunked` for the emission seam).
    pub fn chunk_frames(update: &ClientUpdate, client_id: u32, round: u64) -> Vec<Vec<u8>> {
        let n = update.n_layers();
        let mut frames = Vec::with_capacity(n);
        for layer in 0..n {
            frames.push(Self::chunk(update, layer, client_id, round));
        }
        frames
    }

    fn param_msg(&mut self, m: &ParamMsg) {
        match m {
            ParamMsg::Dense { q } => {
                self.u8(1);
                self.quantized(q);
            }
            ParamMsg::Svd { u, s, v } => {
                self.u8(2);
                self.quantized(u);
                self.quantized(s);
                self.quantized(v);
            }
            ParamMsg::Tucker { core, factors } => {
                self.u8(3);
                self.quantized(core);
                self.u8(factors.len() as u8);
                for f in factors {
                    self.quantized(f);
                }
            }
            ParamMsg::RawDense { t } => {
                self.u8(0);
                self.dense(t);
            }
            ParamMsg::RawSvd { u, s, v } => {
                self.u8(4);
                self.dense(u);
                self.dense(s);
                self.dense(v);
            }
            ParamMsg::RawTucker { core, factors } => {
                self.u8(5);
                self.dense(core);
                self.u8(factors.len() as u8);
                for f in factors {
                    self.dense(f);
                }
            }
        }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn dense(&mut self, t: &Tensor) {
        self.u8(t.ndim() as u8);
        for &d in t.shape() {
            self.u32(d as u32);
        }
        for &v in t.data() {
            self.f32(v);
        }
    }

    fn quantized(&mut self, q: &Quantized) {
        self.f32(q.radius);
        self.u8(q.beta);
        self.u64(q.len as u64);
        // shape is carried by the codec state on both sides; the wire
        // needs only the flat length
        self.buf.extend_from_slice(&q.packed);
    }
    // qrr-audit: end
}

// ---------------------------------------------------------------- decoder

/// Byte-stream reader with position tracking.
#[derive(Debug)]
pub struct Decoder<'a> {
    buf: &'a [u8],
    pos: usize,
}

/// Decoded header + update.
#[derive(Debug)]
pub struct DecodedMsg {
    /// sending client
    pub client_id: u32,
    /// FL round index
    pub round: u64,
    /// the update itself
    pub update: ClientUpdate,
}

/// The fixed client-update header, validated without decoding the body.
///
/// This is the routing handle of the sharded server (DESIGN.md §10):
/// the session thread peeks `client_id`/`round` to admit and route a
/// frame, then the full body decode runs on the owning shard's lane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireHeader {
    /// scheme tag (0 = SGD, 1 = SLAQ, 2 = QRR)
    pub scheme: u8,
    /// sending client
    pub client_id: u32,
    /// FL round index
    pub round: u64,
    /// declared entry count (untrusted until the body decodes)
    pub n_entries: u32,
}

// The whole decode half runs on attacker-controlled bytes (the TCP
// server feeds it raw peer input and the contract is discard, never
// crash — see net::transport): every malformed input must surface as a
// `WireError`, so panicking constructs are banned here. Declared
// lengths are honored only after checked arithmetic proves the buffer
// can actually satisfy them, and preallocations are capped by the
// bytes that remain.
// qrr-audit: no-panic
impl<'a> Decoder<'a> {
    /// Decode a full message produced by [`Encoder::new`].
    pub fn decode(buf: &'a [u8]) -> Result<DecodedMsg, WireError> {
        let mut d = Decoder { buf, pos: 0 };
        if d.u32()? != MAGIC || d.u8()? != VERSION {
            return Err(WireError::BadHeader);
        }
        let scheme = d.u8()?;
        let client_id = d.u32()?;
        let round = d.u64()?;
        let n = d.u32()? as usize;
        let update = match scheme {
            0 => {
                let mut grads = Vec::with_capacity(n.min(d.remaining()));
                for _ in 0..n {
                    d.expect_kind(0)?;
                    grads.push(d.dense()?);
                }
                ClientUpdate::Sgd { grads }
            }
            1 => {
                let mut params = Vec::with_capacity(n.min(d.remaining()));
                for _ in 0..n {
                    d.expect_kind(1)?;
                    params.push(d.quantized()?);
                }
                ClientUpdate::Slaq { msg: SlaqMsg { params } }
            }
            2 => {
                let mut msgs = Vec::with_capacity(n.min(d.remaining()));
                for _ in 0..n {
                    msgs.push(d.param_msg()?);
                }
                ClientUpdate::Qrr { msgs }
            }
            s => return Err(WireError::UnknownScheme(s)),
        };
        Ok(DecodedMsg { client_id, round, update })
    }

    /// Validate and read the fixed header only, leaving the body
    /// untouched — the incremental entry point of the sharded server:
    /// header-level rejects (bad magic/version, unknown scheme, short
    /// buffer) cost a few byte reads on the session thread, while the
    /// expensive body decode is deferred to the owning shard.
    ///
    /// A frame whose header peeks clean may still fail [`Self::decode`]
    /// later; `n_entries` in particular is attacker data until then.
    pub fn peek_header(buf: &'a [u8]) -> Result<WireHeader, WireError> {
        let mut d = Decoder { buf, pos: 0 };
        if d.u32()? != MAGIC || d.u8()? != VERSION {
            return Err(WireError::BadHeader);
        }
        let scheme = d.u8()?;
        if scheme > 2 {
            return Err(WireError::UnknownScheme(scheme));
        }
        let client_id = d.u32()?;
        let round = d.u64()?;
        let n_entries = d.u32()?;
        Ok(WireHeader { scheme, client_id, round, n_entries })
    }

    /// Decode a server broadcast produced by [`Encoder::server`]:
    /// either a delta (`"QRRB"`) or a resync snapshot (`"QRRS"`) — the
    /// magic sets [`ServerUpdate::snapshot`], everything after it
    /// decodes identically.
    pub fn decode_server(buf: &'a [u8]) -> Result<ServerUpdate, WireError> {
        let mut d = Decoder { buf, pos: 0 };
        let snapshot = match d.u32()? {
            SERVER_MAGIC => false,
            SNAPSHOT_MAGIC => true,
            _ => return Err(WireError::BadHeader),
        };
        if d.u8()? != SERVER_VERSION {
            return Err(WireError::BadHeader);
        }
        let seq = d.u64()?;
        let round = d.u64()?;
        let n = d.u32()? as usize;
        let mut msgs = Vec::with_capacity(n.min(d.remaining()));
        for _ in 0..n {
            msgs.push(d.param_msg()?);
        }
        Ok(ServerUpdate { seq, round, msgs, snapshot })
    }

    /// Validate and read a chunk frame's fixed header only — the
    /// streaming analogue of [`Self::peek_header`], and like it the
    /// routing entry point: the session thread peeks
    /// `client_id`/`round`/`layer` to admit and route a chunk, then
    /// the body decode runs on the owning shard's lane.
    ///
    /// Internal consistency is enforced here so routing can trust the
    /// indices: unknown flag bits, a zero layer count, `layer ≥
    /// n_layers`, or a last-flag disagreeing with the indices are all
    /// typed rejects. The body (and `n_layers` against the model spec)
    /// stays untrusted until [`Self::decode_chunk`] and reassembly.
    pub fn peek_chunk_header(buf: &'a [u8]) -> Result<ChunkHeader, WireError> {
        let mut d = Decoder { buf, pos: 0 };
        if d.u32()? != CHUNK_MAGIC || d.u8()? != CHUNK_VERSION {
            return Err(WireError::BadHeader);
        }
        let scheme = d.u8()?;
        if scheme > 2 {
            return Err(WireError::UnknownScheme(scheme));
        }
        let flags = d.u8()?;
        let client_id = d.u32()?;
        let round = d.u64()?;
        let layer = d.u32()?;
        let n_layers = d.u32()?;
        if flags & !CHUNK_FLAG_LAST != 0 || n_layers == 0 || layer >= n_layers {
            return Err(WireError::BadChunk);
        }
        let last = flags & CHUNK_FLAG_LAST != 0;
        if last != (layer + 1 == n_layers) {
            return Err(WireError::BadChunk);
        }
        Ok(ChunkHeader { scheme, client_id, round, layer, n_layers, last })
    }

    /// Decode one chunk frame produced by [`Encoder::chunk`]: the
    /// validated header plus the single layer entry it carries, in
    /// whole-message entry encoding.
    pub fn decode_chunk(buf: &'a [u8]) -> Result<(ChunkHeader, ChunkBody), WireError> {
        let h = Self::peek_chunk_header(buf)?;
        let mut d = Decoder { buf, pos: CHUNK_HEADER_LEN };
        let body = match h.scheme {
            0 => {
                d.expect_kind(0)?;
                ChunkBody::Dense(d.dense()?)
            }
            1 => {
                d.expect_kind(1)?;
                ChunkBody::Quantized(d.quantized()?)
            }
            _ => ChunkBody::Msg(d.param_msg()?),
        };
        Ok((h, body))
    }

    /// Rebuild the whole-message [`ClientUpdate`] from every layer's
    /// decoded chunk body, in layer order. Bodies are the same
    /// per-entry decodes [`Self::decode`] performs, so the reassembled
    /// update — and its `payload_bits` — is bit-identical to decoding
    /// the sequential frame. A body whose kind disagrees with `scheme`
    /// (only reachable if the caller mixed schemes across one client's
    /// chunks) is a typed error, never a panic.
    pub fn assemble_update(scheme: u8, bodies: Vec<ChunkBody>) -> Result<ClientUpdate, WireError> {
        match scheme {
            0 => {
                let mut grads = Vec::with_capacity(bodies.len());
                for b in bodies {
                    match b {
                        ChunkBody::Dense(t) => grads.push(t),
                        _ => return Err(WireError::BadChunk),
                    }
                }
                Ok(ClientUpdate::Sgd { grads })
            }
            1 => {
                let mut params = Vec::with_capacity(bodies.len());
                for b in bodies {
                    match b {
                        ChunkBody::Quantized(q) => params.push(q),
                        _ => return Err(WireError::BadChunk),
                    }
                }
                Ok(ClientUpdate::Slaq { msg: SlaqMsg { params } })
            }
            2 => {
                let mut msgs = Vec::with_capacity(bodies.len());
                for b in bodies {
                    match b {
                        ChunkBody::Msg(m) => msgs.push(m),
                        _ => return Err(WireError::BadChunk),
                    }
                }
                Ok(ClientUpdate::Qrr { msgs })
            }
            s => Err(WireError::UnknownScheme(s)),
        }
    }

    fn param_msg(&mut self) -> Result<ParamMsg, WireError> {
        let kind = self.u8()?;
        Ok(match kind {
            0 => ParamMsg::RawDense { t: self.dense()? },
            1 => ParamMsg::Dense { q: self.quantized()? },
            2 => ParamMsg::Svd {
                u: self.quantized()?,
                s: self.quantized()?,
                v: self.quantized()?,
            },
            3 => {
                let core = self.quantized()?;
                let nf = self.u8()? as usize;
                let mut factors = Vec::with_capacity(nf);
                for _ in 0..nf {
                    factors.push(self.quantized()?);
                }
                ParamMsg::Tucker { core, factors }
            }
            4 => ParamMsg::RawSvd {
                u: self.dense()?,
                s: self.dense()?,
                v: self.dense()?,
            },
            5 => {
                let core = self.dense()?;
                let nf = self.u8()? as usize;
                let mut factors = Vec::with_capacity(nf);
                for _ in 0..nf {
                    factors.push(self.dense()?);
                }
                ParamMsg::RawTucker { core, factors }
            }
            k => return Err(WireError::UnknownKind(k)),
        })
    }

    /// Bytes not yet consumed (the cap for length-prefixed
    /// preallocations: every wire entry costs at least one byte, so no
    /// honest prefix can promise more entries than this).
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        // written as a subtraction from len (pos <= len always holds)
        // so a huge declared `n` cannot overflow `pos + n`
        if n > self.remaining() {
            return Err(WireError::Truncated(self.pos));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Fixed-width read as an array, for the `from_le_bytes` family.
    fn take_n<const N: usize>(&mut self) -> Result<[u8; N], WireError> {
        let mut out = [0u8; N];
        out.copy_from_slice(self.take(N)?);
        Ok(out)
    }

    /// Checked multiply for attacker-declared sizes; overflow means the
    /// declared payload cannot possibly fit the message, which is the
    /// same failure as a short buffer.
    fn sized(&self, a: usize, b: usize) -> Result<usize, WireError> {
        a.checked_mul(b).ok_or(WireError::Truncated(self.pos))
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take_n()?))
    }
    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take_n()?))
    }
    fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take_n()?))
    }

    fn expect_kind(&mut self, k: u8) -> Result<(), WireError> {
        let got = self.u8()?;
        if got != k {
            return Err(WireError::UnknownKind(got));
        }
        Ok(())
    }

    fn dense(&mut self) -> Result<Tensor, WireError> {
        let ndim = self.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(self.u32()? as usize);
        }
        let mut n = 1usize;
        for &d in &shape {
            n = self.sized(n, d)?;
        }
        let bytes = self.take(self.sized(n, 4)?)?;
        let mut data = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            let mut w = [0u8; 4];
            w.copy_from_slice(c);
            data.push(f32::from_le_bytes(w));
        }
        Ok(Tensor::from_vec(&shape, data))
    }

    fn quantized(&mut self) -> Result<Quantized, WireError> {
        let radius = self.f32()?;
        let beta = self.u8()?;
        let len64 = self.u64()?;
        let len = usize::try_from(len64).map_err(|_| WireError::Truncated(self.pos))?;
        // same count as quant::packed_len_bytes, but checked: the
        // declared code count is attacker data here
        let nbytes = self.sized(len, beta as usize)?.div_ceil(8);
        let packed = self.take(nbytes)?.to_vec();
        Ok(Quantized { radius, beta, len, packed })
    }
}
// qrr-audit: end

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qrr::{ClientCodec, QrrConfig};
    use crate::quant::quantize;
    use crate::util::Rng;

    #[test]
    fn sgd_roundtrip() {
        let mut rng = Rng::new(100);
        let grads = vec![
            Tensor::randn(&[4, 5], &mut rng),
            Tensor::randn(&[4], &mut rng),
        ];
        let up = ClientUpdate::Sgd { grads: grads.clone() };
        let bytes = Encoder::new(&up, 3, 17);
        let dec = Decoder::decode(&bytes).unwrap();
        assert_eq!(dec.client_id, 3);
        assert_eq!(dec.round, 17);
        match dec.update {
            ClientUpdate::Sgd { grads: g } => {
                assert_eq!(g.len(), 2);
                assert_eq!(g[0], grads[0]);
                assert_eq!(g[1], grads[1]);
            }
            _ => panic!("wrong scheme"),
        }
    }

    #[test]
    fn qrr_roundtrip_preserves_messages() {
        let mut rng = Rng::new(101);
        let shapes = vec![vec![20, 30], vec![20], vec![4, 3, 3, 3]];
        let mut codec = ClientCodec::new(&shapes, QrrConfig::with_p(0.3));
        let grads: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
        let msgs = codec.encode(&grads);
        let up = ClientUpdate::Qrr { msgs: msgs.clone() };
        let bytes = Encoder::new(&up, 1, 2);
        let dec = Decoder::decode(&bytes).unwrap();
        match dec.update {
            ClientUpdate::Qrr { msgs: back } => {
                assert_eq!(back.len(), msgs.len());
                for (a, b) in msgs.iter().zip(back.iter()) {
                    assert_eq!(a.wire_bits(), b.wire_bits());
                    match (a, b) {
                        (ParamMsg::Svd { u: a1, .. }, ParamMsg::Svd { u: b1, .. }) => {
                            assert_eq!(a1, b1)
                        }
                        (ParamMsg::Dense { q: a1 }, ParamMsg::Dense { q: b1 }) => {
                            assert_eq!(a1, b1)
                        }
                        (
                            ParamMsg::Tucker { core: a1, factors: fa },
                            ParamMsg::Tucker { core: b1, factors: fb },
                        ) => {
                            assert_eq!(a1, b1);
                            assert_eq!(fa, fb);
                        }
                        _ => panic!("kind mismatch"),
                    }
                }
            }
            _ => panic!("wrong scheme"),
        }
    }

    #[test]
    fn payload_bits_match_paper_accounting() {
        let mut rng = Rng::new(102);
        // SGD: 32 bits per element
        let g = Tensor::randn(&[10, 10], &mut rng);
        let up = ClientUpdate::Sgd { grads: vec![g] };
        assert_eq!(up.payload_bits(), 3200);
        // Quantized: 32 + beta*n
        let t = Tensor::randn(&[100], &mut rng);
        let (q, _) = quantize(&t, &Tensor::zeros(&[100]), 8);
        let up = ClientUpdate::Slaq { msg: SlaqMsg { params: vec![q] } };
        assert_eq!(up.payload_bits(), 32 + 800);
    }

    #[test]
    fn wire_overhead_is_small() {
        // serialized bytes ≈ payload_bits/8 + small header/meta
        let mut rng = Rng::new(103);
        let shapes = vec![vec![50, 60]];
        let mut codec = ClientCodec::new(&shapes, QrrConfig::with_p(0.2));
        let grads = vec![Tensor::randn(&[50, 60], &mut rng)];
        let up = ClientUpdate::Qrr { msgs: codec.encode(&grads) };
        let bytes = Encoder::new(&up, 0, 0);
        let payload_bytes = (up.payload_bits() / 8) as usize;
        assert!(bytes.len() >= payload_bytes);
        assert!(
            bytes.len() < payload_bytes + 128,
            "overhead too large: {} vs {}",
            bytes.len(),
            payload_bytes
        );
    }

    #[test]
    fn encode_into_reuses_buffer_and_matches_fresh_encode() {
        let mut rng = Rng::new(106);
        let shapes = vec![vec![20, 30], vec![20]];
        let mut codec = ClientCodec::new(&shapes, QrrConfig::with_p(0.3));
        let mut buf = Vec::new();
        for round in 0..5u64 {
            let grads: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
            let up = ClientUpdate::Qrr { msgs: codec.encode(&grads) };
            Encoder::encode_into(&up, 7, round, &mut buf);
            assert_eq!(buf, Encoder::new(&up, 7, round));
            let dec = Decoder::decode(&buf).unwrap();
            assert_eq!(dec.round, round);
        }
    }

    #[test]
    fn raw_entries_roundtrip_in_client_update() {
        let mut rng = Rng::new(107);
        let up = ClientUpdate::Qrr {
            msgs: vec![
                ParamMsg::RawDense { t: Tensor::randn(&[7], &mut rng) },
                ParamMsg::RawSvd {
                    u: Tensor::randn(&[6, 2], &mut rng),
                    s: Tensor::randn(&[2], &mut rng),
                    v: Tensor::randn(&[5, 2], &mut rng),
                },
                ParamMsg::RawTucker {
                    core: Tensor::randn(&[2, 2, 2], &mut rng),
                    factors: vec![
                        Tensor::randn(&[4, 2], &mut rng),
                        Tensor::randn(&[3, 2], &mut rng),
                        Tensor::randn(&[3, 2], &mut rng),
                    ],
                },
            ],
        };
        let bytes = Encoder::new(&up, 9, 3);
        assert_eq!(bytes.len(), up.wire_len());
        // raw payloads are 32 bits per f32 element
        assert_eq!(
            up.payload_bits(),
            32 * (7 + (12 + 2 + 10) + (8 + 8 + 6 + 6)) as u64
        );
        let dec = Decoder::decode(&bytes).unwrap();
        match dec.update {
            ClientUpdate::Qrr { msgs } => {
                match (&msgs[0], &msgs[1], &msgs[2]) {
                    (
                        ParamMsg::RawDense { t },
                        ParamMsg::RawSvd { u, s, v },
                        ParamMsg::RawTucker { core, factors },
                    ) => {
                        assert_eq!(t.shape(), &[7]);
                        assert_eq!(u.shape(), &[6, 2]);
                        assert_eq!(s.shape(), &[2]);
                        assert_eq!(v.shape(), &[5, 2]);
                        assert_eq!(core.shape(), &[2, 2, 2]);
                        assert_eq!(factors.len(), 3);
                    }
                    other => panic!("kinds changed across the wire: {other:?}"),
                }
            }
            _ => panic!("wrong scheme"),
        }
    }

    #[test]
    fn server_update_roundtrip_exact_wire_len() {
        let mut rng = Rng::new(108);
        let shapes = vec![vec![20, 30], vec![20]];
        let mut codec = ClientCodec::new(&shapes, QrrConfig::with_p(0.3));
        let deltas: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
        let upd = ServerUpdate { seq: 5, round: 41, msgs: codec.encode(&deltas), snapshot: false };
        let bytes = Encoder::server(&upd);
        assert_eq!(bytes.len(), upd.wire_len(), "server wire_len must be exact");
        let back = Decoder::decode_server(&bytes).unwrap();
        assert_eq!(back.seq, 5);
        assert_eq!(back.round, 41);
        assert_eq!(back.payload_bits(), upd.payload_bits());
        assert_eq!(back.msgs.len(), upd.msgs.len());
    }

    #[test]
    fn server_update_rejects_client_bytes_and_vice_versa() {
        let mut rng = Rng::new(109);
        let up = ClientUpdate::Sgd { grads: vec![Tensor::randn(&[3, 3], &mut rng)] };
        let client_bytes = Encoder::new(&up, 0, 0);
        assert!(matches!(
            Decoder::decode_server(&client_bytes),
            Err(WireError::BadHeader)
        ));
        let upd = ServerUpdate {
            seq: 0,
            round: 0,
            msgs: vec![ParamMsg::RawDense { t: Tensor::randn(&[3], &mut rng) }],
            snapshot: false,
        };
        let server_bytes = Encoder::server(&upd);
        assert!(matches!(
            Decoder::decode(&server_bytes),
            Err(WireError::BadHeader)
        ));
    }

    #[test]
    fn server_update_truncation_is_an_error() {
        let mut rng = Rng::new(110);
        let upd = ServerUpdate {
            seq: 2,
            round: 7,
            msgs: vec![ParamMsg::RawDense { t: Tensor::randn(&[16], &mut rng) }],
            snapshot: false,
        };
        let bytes = Encoder::server(&upd);
        for cut in [0, 4, 12, bytes.len() / 2, bytes.len() - 1] {
            assert!(Decoder::decode_server(&bytes[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn corrupted_magic_rejected() {
        let mut rng = Rng::new(104);
        let up = ClientUpdate::Sgd { grads: vec![Tensor::randn(&[2, 2], &mut rng)] };
        let mut bytes = Encoder::new(&up, 0, 0);
        bytes[0] ^= 0xFF;
        assert!(matches!(Decoder::decode(&bytes), Err(WireError::BadHeader)));
    }

    #[test]
    fn truncated_rejected() {
        let mut rng = Rng::new(105);
        let up = ClientUpdate::Sgd { grads: vec![Tensor::randn(&[8, 8], &mut rng)] };
        let bytes = Encoder::new(&up, 0, 0);
        for cut in [10, bytes.len() / 2, bytes.len() - 1] {
            assert!(Decoder::decode(&bytes[..cut]).is_err(), "cut={cut}");
        }
    }

    // ------------------------- hostile byte patterns -------------------
    // Each of these inputs panicked (debug overflow, `try_into`
    // unwrap, or capacity overflow/OOM abort) before the decode half
    // was hardened; they must stay typed `WireError`s forever.

    fn client_header(scheme: u8, n_entries: u32) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&MAGIC.to_le_bytes());
        b.push(VERSION);
        b.push(scheme);
        b.extend_from_slice(&7u32.to_le_bytes()); // client_id
        b.extend_from_slice(&1u64.to_le_bytes()); // round
        b.extend_from_slice(&n_entries.to_le_bytes());
        b
    }

    #[test]
    fn hostile_quantized_length_is_an_error_not_a_panic() {
        // declared code count of u64::MAX: the packed-byte computation
        // `len * beta / 8` used to overflow
        let mut b = client_header(1, 1);
        b.push(1); // kind: quantized
        b.extend_from_slice(&1.0f32.to_le_bytes()); // radius
        b.push(8); // beta
        b.extend_from_slice(&u64::MAX.to_le_bytes()); // len
        assert!(matches!(Decoder::decode(&b), Err(WireError::Truncated(_))));

        // a count that fits usize but whose bit total does not
        let mut b = client_header(1, 1);
        b.push(1);
        b.extend_from_slice(&1.0f32.to_le_bytes());
        b.push(12);
        b.extend_from_slice(&(u64::MAX / 2).to_le_bytes());
        assert!(Decoder::decode(&b).is_err());
    }

    #[test]
    fn hostile_dense_shape_is_an_error_not_a_panic() {
        // dim product overflows usize
        let mut b = client_header(0, 1);
        b.push(0); // kind: dense
        b.push(4); // ndim
        for _ in 0..4 {
            b.extend_from_slice(&0xFFFF_FFFFu32.to_le_bytes());
        }
        assert!(matches!(Decoder::decode(&b), Err(WireError::Truncated(_))));

        // dim product fits, f32 byte count does not (2^31 * 2^31 * 4)
        let mut b = client_header(0, 1);
        b.push(0);
        b.push(2);
        b.extend_from_slice(&0x8000_0000u32.to_le_bytes());
        b.extend_from_slice(&0x8000_0000u32.to_le_bytes());
        assert!(Decoder::decode(&b).is_err());
    }

    #[test]
    fn hostile_entry_count_errors_without_preallocating() {
        // u32::MAX declared entries with an empty body: the decoder
        // must not reserve u32::MAX tensors up front
        for scheme in [0u8, 1, 2] {
            let b = client_header(scheme, u32::MAX);
            assert!(matches!(Decoder::decode(&b), Err(WireError::Truncated(_))), "scheme {scheme}");
        }
        // server broadcast path has the same guard
        let mut s = Vec::new();
        s.extend_from_slice(&SERVER_MAGIC.to_le_bytes());
        s.push(SERVER_VERSION);
        s.extend_from_slice(&0u64.to_le_bytes());
        s.extend_from_slice(&0u64.to_le_bytes());
        s.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(Decoder::decode_server(&s), Err(WireError::Truncated(_))));
    }

    #[test]
    fn hostile_tucker_factor_count_is_bounded_by_the_buffer() {
        // kind 3 with max factor count and no factor bytes behind it
        let mut b = client_header(2, 1);
        b.push(3); // kind: tucker
        b.extend_from_slice(&1.0f32.to_le_bytes()); // core radius
        b.push(1); // core beta
        b.extend_from_slice(&0u64.to_le_bytes()); // core len = 0
        b.push(0xFF); // n_factors
        assert!(matches!(Decoder::decode(&b), Err(WireError::Truncated(_))));
    }

    // ------------------------- property sweeps (testing::prop) --------

    use crate::testing::{forall, Gen};

    /// A random `Quantized` payload as the codecs would produce one.
    fn gen_quantized(g: &mut Gen) -> Quantized {
        let n = g.usize_in(1, 64);
        let beta = *g.choose(&[1u8, 2, 4, 8, 12]);
        let x = Tensor::randn(&[n], g.rng());
        let (q, _) = quantize(&x, &Tensor::zeros(&[n]), beta);
        q
    }

    /// A random update exercising a chosen wire entry kind:
    /// 0 = dense f32, 1 = quantized, 2 = svd, 3 = tucker.
    fn gen_update_of_kind(g: &mut Gen, kind: u8) -> ClientUpdate {
        match kind {
            0 => {
                let n_params = g.usize_in(1, 3);
                let grads = (0..n_params)
                    .map(|_| {
                        let ndim = g.usize_in(1, 4);
                        g.tensor(ndim, 6)
                    })
                    .collect();
                ClientUpdate::Sgd { grads }
            }
            1 => {
                let n_params = g.usize_in(1, 3);
                let params = (0..n_params).map(|_| gen_quantized(g)).collect();
                ClientUpdate::Slaq { msg: SlaqMsg { params } }
            }
            2 => ClientUpdate::Qrr {
                msgs: vec![ParamMsg::Svd {
                    u: gen_quantized(g),
                    s: gen_quantized(g),
                    v: gen_quantized(g),
                }],
            },
            _ => {
                let nf = g.usize_in(1, 4);
                ClientUpdate::Qrr {
                    msgs: vec![ParamMsg::Tucker {
                        core: gen_quantized(g),
                        factors: (0..nf).map(|_| gen_quantized(g)).collect(),
                    }],
                }
            }
        }
    }

    fn assert_quantized_eq(a: &Quantized, b: &Quantized) {
        assert_eq!(a.radius, b.radius);
        assert_eq!(a.beta, b.beta);
        assert_eq!(a.len, b.len);
        assert_eq!(a.packed, b.packed);
    }

    fn assert_update_roundtrips(up: &ClientUpdate, client_id: u32, round: u64) {
        let bytes = Encoder::new(up, client_id, round);
        assert_eq!(bytes.len(), up.wire_len(), "wire_len must be exact");
        let dec = Decoder::decode(&bytes).unwrap();
        assert_eq!(dec.client_id, client_id);
        assert_eq!(dec.round, round);
        assert_eq!(dec.update.payload_bits(), up.payload_bits());
        match (up, &dec.update) {
            (ClientUpdate::Sgd { grads: a }, ClientUpdate::Sgd { grads: b }) => {
                assert_eq!(a, b);
            }
            (ClientUpdate::Slaq { msg: a }, ClientUpdate::Slaq { msg: b }) => {
                assert_eq!(a.params.len(), b.params.len());
                for (x, y) in a.params.iter().zip(b.params.iter()) {
                    assert_quantized_eq(x, y);
                }
            }
            (ClientUpdate::Qrr { msgs: a }, ClientUpdate::Qrr { msgs: b }) => {
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(b.iter()) {
                    match (x, y) {
                        (ParamMsg::Dense { q: qa }, ParamMsg::Dense { q: qb }) => {
                            assert_quantized_eq(qa, qb)
                        }
                        (
                            ParamMsg::Svd { u: ua, s: sa, v: va },
                            ParamMsg::Svd { u: ub, s: sb, v: vb },
                        ) => {
                            assert_quantized_eq(ua, ub);
                            assert_quantized_eq(sa, sb);
                            assert_quantized_eq(va, vb);
                        }
                        (
                            ParamMsg::Tucker { core: ca, factors: fa },
                            ParamMsg::Tucker { core: cb, factors: fb },
                        ) => {
                            assert_quantized_eq(ca, cb);
                            assert_eq!(fa.len(), fb.len());
                            for (qa, qb) in fa.iter().zip(fb.iter()) {
                                assert_quantized_eq(qa, qb);
                            }
                        }
                        _ => panic!("entry kind changed across the wire"),
                    }
                }
            }
            _ => panic!("scheme changed across the wire"),
        }
    }

    #[test]
    fn prop_roundtrip_every_entry_kind() {
        forall(
            0xB1,
            crate::testing::cases(60),
            |g| {
                let kind = g.usize_in(0, 3) as u8;
                let client_id = g.usize_in(0, 1000) as u32;
                let round = g.usize_in(0, 100_000) as u64;
                (gen_update_of_kind(g, kind), client_id, round)
            },
            |(up, client_id, round)| assert_update_roundtrips(&up, client_id, round),
        );
    }

    #[test]
    fn prop_any_truncation_is_a_decode_error_never_a_panic() {
        forall(
            0xB2,
            crate::testing::cases(60),
            |g| {
                let kind = g.usize_in(0, 3) as u8;
                let up = gen_update_of_kind(g, kind);
                let bytes = Encoder::new(&up, 1, 2);
                let cut = g.usize_in(0, bytes.len() - 1);
                (bytes, cut)
            },
            |(bytes, cut)| {
                assert!(
                    Decoder::decode(&bytes[..cut]).is_err(),
                    "cut {cut}/{} decoded",
                    bytes.len()
                );
            },
        );
    }

    #[test]
    fn prop_header_corruption_is_a_typed_error() {
        forall(
            0xB3,
            crate::testing::cases(40),
            |g| {
                let kind = g.usize_in(0, 3) as u8;
                (gen_update_of_kind(g, kind), g.usize_in(0, 2))
            },
            |(up, which)| {
                let mut bytes = Encoder::new(&up, 1, 2);
                match which {
                    0 => {
                        // magic
                        bytes[0] ^= 0xFF;
                        assert!(matches!(
                            Decoder::decode(&bytes),
                            Err(WireError::BadHeader)
                        ));
                    }
                    1 => {
                        // version
                        bytes[4] = bytes[4].wrapping_add(1);
                        assert!(matches!(
                            Decoder::decode(&bytes),
                            Err(WireError::BadHeader)
                        ));
                    }
                    _ => {
                        // scheme tag
                        bytes[5] = 0x7F;
                        assert!(matches!(
                            Decoder::decode(&bytes),
                            Err(WireError::UnknownScheme(0x7F))
                        ));
                    }
                }
            },
        );
    }

    #[test]
    fn peek_header_rejects_what_decode_rejects() {
        // bad magic
        let mut rng = Rng::new(111);
        let up = ClientUpdate::Sgd { grads: vec![Tensor::randn(&[2, 2], &mut rng)] };
        let mut bytes = Encoder::new(&up, 4, 9);
        bytes[0] ^= 0xFF;
        assert!(matches!(Decoder::peek_header(&bytes), Err(WireError::BadHeader)));
        // unknown scheme tag fails at peek time, not decode time
        let b = client_header(0x7F, 1);
        assert!(matches!(
            Decoder::peek_header(&b),
            Err(WireError::UnknownScheme(0x7F))
        ));
        // short header
        let b = client_header(0, 1);
        for cut in 0..b.len() - 1 {
            assert!(
                matches!(Decoder::peek_header(&b[..cut]), Err(WireError::Truncated(_))),
                "cut={cut}"
            );
        }
    }

    #[test]
    fn prop_peek_header_agrees_with_full_decode() {
        forall(
            0xB5,
            crate::testing::cases(60),
            |g| {
                let kind = g.usize_in(0, 3) as u8;
                let client_id = g.usize_in(0, 10_000) as u32;
                let round = g.usize_in(0, 1 << 20) as u64;
                (gen_update_of_kind(g, kind), client_id, round)
            },
            |(up, client_id, round)| {
                let bytes = Encoder::new(&up, client_id, round);
                let h = Decoder::peek_header(&bytes).unwrap();
                let dec = Decoder::decode(&bytes).unwrap();
                assert_eq!(h.client_id, dec.client_id);
                assert_eq!(h.round, dec.round);
                let want_scheme = match &dec.update {
                    ClientUpdate::Sgd { .. } => 0u8,
                    ClientUpdate::Slaq { .. } => 1,
                    ClientUpdate::Qrr { .. } => 2,
                };
                assert_eq!(h.scheme, want_scheme);
                let want_entries = match &dec.update {
                    ClientUpdate::Sgd { grads } => grads.len(),
                    ClientUpdate::Slaq { msg } => msg.params.len(),
                    ClientUpdate::Qrr { msgs } => msgs.len(),
                };
                assert_eq!(h.n_entries as usize, want_entries);
            },
        );
    }

    #[test]
    fn prop_bad_entry_kind_is_a_typed_error() {
        forall(
            0xB4,
            crate::testing::cases(30),
            |g| gen_update_of_kind(g, g.usize_in(0, 3) as u8),
            |up| {
                let mut bytes = Encoder::new(&up, 1, 2);
                // first entry's kind byte sits right after the fixed
                // header: magic u32 | ver u8 | scheme u8 | id u32 |
                // round u64 | n u32 = 22 bytes
                bytes[22] = 0x66;
                match Decoder::decode(&bytes) {
                    Err(WireError::UnknownKind(0x66)) => {}
                    other => panic!("expected UnknownKind, got {other:?}"),
                }
            },
        );
    }

    // ------------------------- snapshot frames -------------------------
    // The resync snapshot is a second attacker-reachable broadcast kind,
    // so it gets the same hostile-bytes treatment as the delta frames:
    // truncation sweep, random byte corruption, bad entry kind.

    /// A random broadcast as the downlink encoder would produce one:
    /// raw-dense entries for a snapshot, mixed entries for a delta.
    fn gen_server_update(g: &mut Gen, snapshot: bool) -> ServerUpdate {
        let n_params = g.usize_in(1, 3);
        let msgs = (0..n_params)
            .map(|_| {
                let ndim = g.usize_in(1, 3);
                ParamMsg::RawDense { t: g.tensor(ndim, 5) }
            })
            .collect();
        ServerUpdate {
            seq: g.usize_in(0, 1000) as u64,
            round: g.usize_in(0, 100_000) as u64,
            msgs,
            snapshot,
        }
    }

    #[test]
    fn snapshot_update_roundtrips_with_exact_wire_len() {
        let mut rng = Rng::new(112);
        let upd = ServerUpdate {
            seq: 9,
            round: 40,
            msgs: vec![
                ParamMsg::RawDense { t: Tensor::randn(&[6, 4], &mut rng) },
                ParamMsg::RawDense { t: Tensor::randn(&[6], &mut rng) },
            ],
            snapshot: true,
        };
        let bytes = Encoder::server(&upd);
        assert_eq!(bytes.len(), upd.wire_len(), "snapshot wire_len must be exact");
        let back = Decoder::decode_server(&bytes).unwrap();
        assert!(back.snapshot, "snapshot magic must survive the roundtrip");
        assert_eq!(back.seq, 9);
        assert_eq!(back.round, 40);
        assert_eq!(back.payload_bits(), upd.payload_bits());
        // the two broadcast families differ only in magic
        let delta_bytes = Encoder::server(&ServerUpdate { snapshot: false, ..upd.clone() });
        assert_eq!(bytes.len(), delta_bytes.len());
        assert!(!Decoder::decode_server(&delta_bytes).unwrap().snapshot);
        // and neither decodes as a client update
        assert!(matches!(Decoder::decode(&bytes), Err(WireError::BadHeader)));
    }

    #[test]
    fn prop_snapshot_truncation_is_an_error_never_a_panic() {
        forall(
            0xB6,
            crate::testing::cases(60),
            |g| {
                let bytes = Encoder::server(&gen_server_update(g, true));
                let cut = g.usize_in(0, bytes.len() - 1);
                (bytes, cut)
            },
            |(bytes, cut)| {
                assert!(
                    Decoder::decode_server(&bytes[..cut]).is_err(),
                    "cut {cut}/{} decoded",
                    bytes.len()
                );
            },
        );
    }

    #[test]
    fn prop_snapshot_random_byte_corruption_never_panics() {
        forall(
            0xB7,
            crate::testing::cases(60),
            |g| {
                let snapshot = g.usize_in(0, 1) == 1;
                let mut bytes = Encoder::server(&gen_server_update(g, snapshot));
                let pos = g.usize_in(0, bytes.len() - 1);
                let flip = g.usize_in(1, 255) as u8;
                bytes[pos] ^= flip;
                bytes
            },
            |bytes| {
                // a flipped byte may still decode (e.g. a payload f32
                // bit); the contract is a typed result, never a panic
                let _ = Decoder::decode_server(&bytes);
            },
        );
    }

    #[test]
    fn prop_snapshot_bad_entry_kind_is_a_typed_error() {
        forall(
            0xB8,
            crate::testing::cases(30),
            |g| gen_server_update(g, true),
            |upd| {
                let mut bytes = Encoder::server(&upd);
                // first entry's kind byte sits right after the fixed
                // server header: magic u32 | ver u8 | seq u64 |
                // round u64 | n u32 = 25 bytes
                bytes[25] = 0x66;
                match Decoder::decode_server(&bytes) {
                    Err(WireError::UnknownKind(0x66)) => {}
                    other => panic!("expected UnknownKind, got {other:?}"),
                }
            },
        );
    }

    // ------------------------- chunked per-layer frames ----------------
    // The streaming uplink's frame family ("QRRC"): one entry per
    // frame, validated under the same no-panic contract as the
    // whole-message decoder. The load-bearing property is
    // bit-identity: reassembling every chunk must reproduce the
    // sequential frame's update exactly, bits accounting included.

    /// Raw chunk header bytes, field by field — the hostile-input
    /// builder (the encoder can't emit inconsistent headers).
    fn chunk_header_bytes(scheme: u8, flags: u8, layer: u32, n_layers: u32) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&CHUNK_MAGIC.to_le_bytes());
        b.push(CHUNK_VERSION);
        b.push(scheme);
        b.push(flags);
        b.extend_from_slice(&7u32.to_le_bytes()); // client_id
        b.extend_from_slice(&1u64.to_le_bytes()); // round
        b.extend_from_slice(&layer.to_le_bytes());
        b.extend_from_slice(&n_layers.to_le_bytes());
        b
    }

    #[test]
    fn prop_chunk_frames_reassemble_bit_identical_to_whole_message() {
        forall(
            0xB9,
            crate::testing::cases(60),
            |g| {
                let kind = g.usize_in(0, 3) as u8;
                let client_id = g.usize_in(0, 1000) as u32;
                let round = g.usize_in(0, 100_000) as u64;
                (gen_update_of_kind(g, kind), client_id, round)
            },
            |(up, client_id, round)| {
                let whole = Encoder::new(&up, client_id, round);
                let frames = Encoder::chunk_frames(&up, client_id, round);
                assert_eq!(frames.len(), up.n_layers());
                let mut bodies = Vec::new();
                let mut chunk_bits = 0u64;
                for (i, f) in frames.iter().enumerate() {
                    assert_eq!(f.len(), up.chunk_wire_len(i), "chunk wire_len must be exact");
                    let h = Decoder::peek_chunk_header(f).unwrap();
                    assert_eq!(h.client_id, client_id);
                    assert_eq!(h.round, round);
                    assert_eq!(h.layer as usize, i);
                    assert_eq!(h.n_layers as usize, up.n_layers());
                    assert_eq!(h.last, i + 1 == up.n_layers());
                    let (h2, body) = Decoder::decode_chunk(f).unwrap();
                    assert_eq!(h, h2);
                    chunk_bits += body.payload_bits();
                    bodies.push(body);
                }
                assert_eq!(chunk_bits, up.payload_bits(), "chunk bits must sum to the total");
                let scheme = Decoder::peek_chunk_header(&frames[0]).unwrap().scheme;
                let back = Decoder::assemble_update(scheme, bodies).unwrap();
                // the reassembled update re-serializes to the exact
                // sequential frame — bit-identity, not just equivalence
                assert_eq!(Encoder::new(&back, client_id, round), whole);
            },
        );
    }

    #[test]
    fn prop_chunk_truncation_is_an_error_never_a_panic() {
        forall(
            0xBA,
            crate::testing::cases(60),
            |g| {
                let kind = g.usize_in(0, 3) as u8;
                let up = gen_update_of_kind(g, kind);
                let layer = g.usize_in(0, up.n_layers() - 1);
                let bytes = Encoder::chunk(&up, layer, 1, 2);
                let cut = g.usize_in(0, bytes.len() - 1);
                (bytes, cut)
            },
            |(bytes, cut)| {
                assert!(
                    Decoder::decode_chunk(&bytes[..cut]).is_err(),
                    "cut {cut}/{} decoded",
                    bytes.len()
                );
            },
        );
    }

    #[test]
    fn prop_chunk_random_byte_corruption_never_panics() {
        forall(
            0xBB,
            crate::testing::cases(60),
            |g| {
                let kind = g.usize_in(0, 3) as u8;
                let up = gen_update_of_kind(g, kind);
                let layer = g.usize_in(0, up.n_layers() - 1);
                let mut bytes = Encoder::chunk(&up, layer, 1, 2);
                let pos = g.usize_in(0, bytes.len() - 1);
                let flip = g.usize_in(1, 255) as u8;
                bytes[pos] ^= flip;
                bytes
            },
            |bytes| {
                // a flipped payload bit may still decode; the contract
                // is a typed result, never a panic
                let _ = Decoder::decode_chunk(&bytes);
            },
        );
    }

    #[test]
    fn chunk_header_consistency_is_enforced_at_peek() {
        // layer index out of range
        let b = chunk_header_bytes(0, 0, 3, 3);
        assert!(matches!(Decoder::peek_chunk_header(&b), Err(WireError::BadChunk)));
        // zero declared layers
        let b = chunk_header_bytes(0, CHUNK_FLAG_LAST, 0, 0);
        assert!(matches!(Decoder::peek_chunk_header(&b), Err(WireError::BadChunk)));
        // final layer without the last flag
        let b = chunk_header_bytes(0, 0, 2, 3);
        assert!(matches!(Decoder::peek_chunk_header(&b), Err(WireError::BadChunk)));
        // last flag on a non-final layer
        let b = chunk_header_bytes(0, CHUNK_FLAG_LAST, 0, 3);
        assert!(matches!(Decoder::peek_chunk_header(&b), Err(WireError::BadChunk)));
        // unknown flag bits
        let b = chunk_header_bytes(0, 0x02, 0, 3);
        assert!(matches!(Decoder::peek_chunk_header(&b), Err(WireError::BadChunk)));
        // unknown scheme fails at peek time
        let b = chunk_header_bytes(9, CHUNK_FLAG_LAST, 0, 1);
        assert!(matches!(
            Decoder::peek_chunk_header(&b),
            Err(WireError::UnknownScheme(9))
        ));
        // header truncation sweep
        let b = chunk_header_bytes(0, CHUNK_FLAG_LAST, 0, 1);
        assert_eq!(b.len(), CHUNK_HEADER_LEN);
        for cut in 0..b.len() {
            assert!(
                matches!(Decoder::peek_chunk_header(&b[..cut]), Err(WireError::Truncated(_))),
                "cut={cut}"
            );
        }
        // a consistent header peeks clean but has no body to decode
        assert!(Decoder::peek_chunk_header(&b).is_ok());
        assert!(Decoder::decode_chunk(&b).is_err());
    }

    #[test]
    fn chunk_and_whole_message_frames_do_not_cross_decode() {
        let mut rng = Rng::new(113);
        let up = ClientUpdate::Sgd { grads: vec![Tensor::randn(&[3, 2], &mut rng)] };
        let whole = Encoder::new(&up, 2, 5);
        let chunk = Encoder::chunk(&up, 0, 2, 5);
        // chunk bytes are not a whole-message frame…
        assert!(matches!(Decoder::peek_header(&chunk), Err(WireError::BadHeader)));
        assert!(matches!(Decoder::decode(&chunk), Err(WireError::BadHeader)));
        // …whole-message bytes are not a chunk…
        assert!(matches!(Decoder::peek_chunk_header(&whole), Err(WireError::BadHeader)));
        assert!(matches!(Decoder::decode_chunk(&whole), Err(WireError::BadHeader)));
        // …and neither family is a server broadcast
        assert!(matches!(Decoder::decode_server(&chunk), Err(WireError::BadHeader)));
    }

    #[test]
    fn assemble_update_rejects_scheme_body_mismatch() {
        let mut rng = Rng::new(114);
        let t = Tensor::randn(&[4], &mut rng);
        // a dense body under the SLAQ scheme is a typed error
        assert!(matches!(
            Decoder::assemble_update(1, vec![ChunkBody::Dense(t.clone())]),
            Err(WireError::BadChunk)
        ));
        // unknown scheme tag
        assert!(matches!(
            Decoder::assemble_update(7, vec![ChunkBody::Dense(t)]),
            Err(WireError::UnknownScheme(7))
        ));
    }
}
