//! Deterministic fault injection for transports (DESIGN.md §11).
//!
//! A [`FaultPlan`] is a seeded description of how the network
//! misbehaves — per-direction rates for drop / delay / duplicate /
//! corrupt / truncate / disconnect, an optional active round window,
//! and client partitions. A [`FaultyTransport`] wraps any
//! [`Transport`] (in-proc or TCP) and applies the plan to the uplink;
//! the session applies the plan's downlink half to broadcast bytes
//! itself (the broadcast never crosses a `Transport`).
//!
//! **Determinism.** Every fault decision is a pure function of
//! `(seed, direction, client_id, round)`, derived by peeking the frame
//! header ([`Decoder::peek_header`]) and hashing through `splitmix64`
//! into a private [`Rng`] stream. Uplink sends may be issued or
//! delivered in any thread order — the *set* of faulted
//! `(client, round)` pairs is identical for a given seed, so every
//! chaos run's `RoundMetrics` counters are byte-reproducible. Streamed
//! chunk frames (DESIGN.md §13) get their own chunk-granular decisions
//! keyed on `(seed, client, round, layer)` via
//! [`FaultPlan::chunk_action`], so a chaos seed faults individual
//! layers of a streamed upload just as reproducibly. Frames whose
//! header peeks as neither a whole client update nor a chunk pass
//! through unfaulted.
//!
//! Fault semantics on the uplink:
//!
//! * **drop** — the frame is swallowed; the server sees a timeout.
//! * **duplicate** — the frame is sent twice; the session's
//!   already-dispatched check discards the copy.
//! * **corrupt** — the first entry's kind byte is flipped, so the frame
//!   still routes (header intact) but the body decode fails on the
//!   shard lane and is counted as a decode failure.
//! * **truncate** — the frame is cut mid-body (header kept), same
//!   observable outcome as corrupt.
//! * **disconnect** — the send fails with [`TransportError::Closed`]
//!   exactly once per `(client, round)`; the session's
//!   reconnect-with-backoff retry then succeeds deterministically.
//! * **delay** — the frame is held and released only once a receive
//!   deadline has expired, so it arrives "late" (inside the quorum
//!   re-poll window) instead of on time.
//! * **partition** — all uplink traffic from the named clients drops
//!   for the window, regardless of rates.
//!
//! On the downlink (applied by the session, see
//! [`FaultPlan::down_action`]) the vocabulary folds to drop / corrupt:
//! a delayed or disconnected broadcast is a miss for that round (the
//! shared decoder resyncs via snapshot), and a duplicated broadcast is
//! rejected by the seq check with no further effect.

use std::collections::HashSet;
use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{bail, ensure, Result};

use crate::net::transport::{Transport, TransportError};
use crate::net::wire::{Decoder, CHUNK_HEADER_LEN};
use crate::util::rng::{splitmix64, Rng};

/// Per-direction fault probabilities, each in `[0, 1]`, summing to at
/// most 1 (the bands partition a single uniform draw).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FaultRates {
    /// frame silently swallowed
    pub drop: f64,
    /// frame delivered twice
    pub duplicate: f64,
    /// first entry's kind byte flipped (frame routes, body decode fails)
    pub corrupt: f64,
    /// frame cut mid-body (header kept)
    pub truncate: f64,
    /// send fails `Closed` once; the reconnect retry succeeds
    pub disconnect: f64,
    /// frame held until a receive deadline expires (arrives late)
    pub delay: f64,
}

impl FaultRates {
    /// Total fault probability (the complement is clean delivery).
    pub fn combined(&self) -> f64 {
        self.drop + self.duplicate + self.corrupt + self.truncate + self.disconnect + self.delay
    }

    /// Rates must be probabilities and jointly partition one draw.
    pub fn validate(&self) -> Result<()> {
        for (name, r) in [
            ("drop", self.drop),
            ("duplicate", self.duplicate),
            ("corrupt", self.corrupt),
            ("truncate", self.truncate),
            ("disconnect", self.disconnect),
            ("delay", self.delay),
        ] {
            ensure!(
                (0.0..=1.0).contains(&r),
                "fault rate {name}={r} outside [0, 1]"
            );
        }
        ensure!(
            self.combined() <= 1.0 + 1e-9,
            "fault rates sum to {} > 1",
            self.combined()
        );
        Ok(())
    }
}

/// A client partition: all uplink traffic from `clients` drops while
/// `rounds = [start, end)` is active.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    /// partitioned client ids
    pub clients: Vec<u32>,
    /// active window `[start, end)`
    pub rounds: (u64, u64),
}

/// A seeded, deterministic description of network misbehavior.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    /// chaos seed — same seed ⇒ same faulted `(client, round)` set
    pub seed: u64,
    /// client→server fault rates
    pub up: FaultRates,
    /// server→client (broadcast) fault rates
    pub down: FaultRates,
    /// optional active window `[start, end)`; `None` = every round
    pub rounds: Option<(u64, u64)>,
    /// client partitions (drop-all windows)
    pub partitions: Vec<Partition>,
}

/// The outcome of one fault decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// clean delivery
    Deliver,
    /// swallow the frame
    Drop,
    /// deliver twice
    Duplicate,
    /// flip the first entry's kind byte
    Corrupt,
    /// cut the frame mid-body
    Truncate,
    /// fail the send `Closed` once
    Disconnect,
    /// hold until a receive deadline expires
    Delay,
}

// domain-separation tags for the two directions
const UP_TAG: u64 = 0x5550;
const DOWN_TAG: u64 = 0x444F;
// chunked (streamed) uplink frames decide per layer under their own tag
const CHUNK_TAG: u64 = 0x4348;
const LAYER_MIX: u64 = 0xA24B_AED4_963E_E407;

impl FaultPlan {
    /// Parse the CLI grammar: a comma list of `key=rate` with keys
    /// `drop|dup|corrupt|truncate|disconnect|delay`, optionally
    /// prefixed `up.` (the default) or `down.`, plus `seed=N` and
    /// `rounds=LO..HI`. Example:
    /// `"drop=0.02,corrupt=0.01,down.drop=0.05,seed=7"`.
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let Some((key, val)) = part.split_once('=') else {
                bail!("bad chaos spec {part:?}: expected key=value");
            };
            let (key, val) = (key.trim(), val.trim());
            if key == "seed" {
                plan.seed = val.parse().map_err(|_| {
                    anyhow::anyhow!("bad chaos seed {val:?}")
                })?;
                continue;
            }
            if key == "rounds" {
                let Some((lo, hi)) = val.split_once("..") else {
                    bail!("bad chaos rounds {val:?}: expected LO..HI");
                };
                let lo: u64 = lo.parse().map_err(|_| anyhow::anyhow!("bad round {lo:?}"))?;
                let hi: u64 = hi.parse().map_err(|_| anyhow::anyhow!("bad round {hi:?}"))?;
                ensure!(lo < hi, "empty chaos round window {lo}..{hi}");
                plan.rounds = Some((lo, hi));
                continue;
            }
            let (dir, kind) = match key.split_once('.') {
                Some(("up", k)) => (&mut plan.up, k),
                Some(("down", k)) => (&mut plan.down, k),
                Some((d, _)) => bail!("bad chaos direction {d:?}: expected up or down"),
                None => (&mut plan.up, key),
            };
            let rate: f64 = val
                .parse()
                .map_err(|_| anyhow::anyhow!("bad chaos rate {val:?}"))?;
            match kind {
                "drop" => dir.drop = rate,
                "dup" | "duplicate" => dir.duplicate = rate,
                "corrupt" => dir.corrupt = rate,
                "truncate" => dir.truncate = rate,
                "disconnect" => dir.disconnect = rate,
                "delay" => dir.delay = rate,
                other => bail!(
                    "unknown chaos key {other:?} (drop|dup|corrupt|truncate|disconnect|delay)"
                ),
            }
        }
        plan.validate()?;
        Ok(plan)
    }

    /// Canonical spec string; `parse` round-trips it (partitions are
    /// JSON-only and not part of the CLI grammar).
    pub fn format(&self) -> String {
        let mut parts = vec![format!("seed={}", self.seed)];
        let push_rates = |prefix: &str, r: &FaultRates, parts: &mut Vec<String>| {
            for (name, v) in [
                ("drop", r.drop),
                ("dup", r.duplicate),
                ("corrupt", r.corrupt),
                ("truncate", r.truncate),
                ("disconnect", r.disconnect),
                ("delay", r.delay),
            ] {
                if v > 0.0 {
                    parts.push(format!("{prefix}{name}={v}"));
                }
            }
        };
        push_rates("", &self.up, &mut parts);
        push_rates("down.", &self.down, &mut parts);
        if let Some((lo, hi)) = self.rounds {
            parts.push(format!("rounds={lo}..{hi}"));
        }
        parts.join(",")
    }

    /// Validate both directions' rates and the windows.
    pub fn validate(&self) -> Result<()> {
        self.up.validate()?;
        self.down.validate()?;
        if let Some((lo, hi)) = self.rounds {
            ensure!(lo < hi, "empty chaos round window {lo}..{hi}");
        }
        for p in &self.partitions {
            ensure!(p.rounds.0 < p.rounds.1, "empty partition window");
            ensure!(!p.clients.is_empty(), "partition names no clients");
        }
        Ok(())
    }

    /// Total per-frame fault probability across both directions.
    pub fn combined_rate(&self) -> f64 {
        self.up.combined() + self.down.combined()
    }

    fn active(&self, round: u64) -> bool {
        match self.rounds {
            None => true,
            Some((lo, hi)) => (lo..hi).contains(&round),
        }
    }

    fn partitioned(&self, client: u32, round: u64) -> bool {
        self.partitions.iter().any(|p| {
            (p.rounds.0..p.rounds.1).contains(&round) && p.clients.contains(&client)
        })
    }

    /// A private stream that is a pure function of
    /// `(seed, direction, client, round)` — thread arrival order cannot
    /// perturb any decision.
    fn rng_for(&self, dir: u64, client: u64, round: u64) -> Rng {
        let mut s = self
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ dir
            ^ client.wrapping_mul(0xD134_2543_DE82_EF95)
            ^ round.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
        Rng::new(splitmix64(&mut s))
    }

    fn pick(rates: &FaultRates, u: f64) -> FaultAction {
        // fixed band order: a seed's outcome is stable across releases
        let bands = [
            (rates.drop, FaultAction::Drop),
            (rates.duplicate, FaultAction::Duplicate),
            (rates.corrupt, FaultAction::Corrupt),
            (rates.truncate, FaultAction::Truncate),
            (rates.disconnect, FaultAction::Disconnect),
            (rates.delay, FaultAction::Delay),
        ];
        let mut acc = 0.0;
        for (rate, action) in bands {
            acc += rate;
            if u < acc {
                return action;
            }
        }
        FaultAction::Deliver
    }

    /// The uplink decision for `(client, round)`.
    pub fn up_action(&self, client: u32, round: u64) -> FaultAction {
        if !self.active(round) {
            return FaultAction::Deliver;
        }
        if self.partitioned(client, round) {
            return FaultAction::Drop;
        }
        let mut rng = self.rng_for(UP_TAG, client as u64, round);
        Self::pick(&self.up, rng.f64())
    }

    /// The uplink decision for one streamed chunk
    /// `(client, round, layer)`. A pure function of the chunk's own
    /// identity — independent of the whole-frame stream and of every
    /// other layer — so streamed chaos runs reproduce their counters
    /// exactly like whole-message runs. Partition and round-window
    /// gating match [`up_action`](Self::up_action).
    pub fn chunk_action(&self, client: u32, round: u64, layer: u32) -> FaultAction {
        if !self.active(round) {
            return FaultAction::Deliver;
        }
        if self.partitioned(client, round) {
            return FaultAction::Drop;
        }
        let tag = CHUNK_TAG ^ (layer as u64).wrapping_mul(LAYER_MIX);
        let mut rng = self.rng_for(tag, client as u64, round);
        Self::pick(&self.up, rng.f64())
    }

    /// The downlink decision for `round`'s broadcast. The broadcast is
    /// shared (one frame for the whole cohort), so the decision keys on
    /// the round alone, and the vocabulary folds to what the in-memory
    /// broadcast path can express: delay/disconnect behave as a miss
    /// (`Drop` — the decoder resyncs next round), truncate as
    /// `Corrupt`, duplicate as `Deliver` (the seq check rejects the
    /// replay with no effect).
    pub fn down_action(&self, round: u64) -> FaultAction {
        if !self.active(round) {
            return FaultAction::Deliver;
        }
        let mut rng = self.rng_for(DOWN_TAG, u64::MAX, round);
        match Self::pick(&self.down, rng.f64()) {
            FaultAction::Delay | FaultAction::Disconnect => FaultAction::Drop,
            FaultAction::Truncate => FaultAction::Corrupt,
            FaultAction::Duplicate => FaultAction::Deliver,
            other => other,
        }
    }

    /// Deterministic detectable corruption: flip the first entry's kind
    /// byte (right after the `header_len`-byte fixed header), so the
    /// frame still routes but its body decode fails with a typed error.
    /// Frames too short to carry a body get their last byte flipped.
    // This runs on frames we may not have produced; stay panic-free.
    // qrr-audit: no-panic
    pub fn corrupt_in_place(bytes: &mut [u8], header_len: usize) {
        let Some(last) = bytes.len().checked_sub(1) else {
            return;
        };
        let pos = header_len.min(last);
        bytes[pos] ^= 0x40;
    }
    // qrr-audit: end
}

/// Counters of faults actually injected (observability + tests; the
/// session's `RoundMetrics` counters are derived independently from
/// what it observes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FaultStats {
    /// frames swallowed
    pub dropped: u64,
    /// extra copies sent
    pub duplicated: u64,
    /// kind bytes flipped
    pub corrupted: u64,
    /// frames cut short
    pub truncated: u64,
    /// sends failed `Closed`
    pub disconnects: u64,
    /// frames held for late delivery
    pub delayed: u64,
}

/// byte length of the fixed client-update header (`Decoder::peek_header`
/// reads exactly this much)
const CLIENT_HEADER_LEN: usize = 22;

/// A chaos wrapper over any [`Transport`]: applies the plan's uplink
/// half on [`send`](Transport::send), releases delayed frames on
/// receive-deadline expiry, and passes everything else through.
pub struct FaultyTransport {
    inner: Box<dyn Transport>,
    plan: FaultPlan,
    /// `(client, round)` pairs whose disconnect already fired — the
    /// retry after reconnect must succeed deterministically
    disconnected: Mutex<HashSet<(u32, u64)>>,
    /// frames held by delay faults, released one per expired deadline
    held: Mutex<VecDeque<Vec<u8>>>,
    stats: Mutex<FaultStats>,
}

impl FaultyTransport {
    /// Wrap `inner` under `plan`.
    pub fn new(inner: Box<dyn Transport>, plan: FaultPlan) -> Self {
        FaultyTransport {
            inner,
            plan,
            disconnected: Mutex::new(HashSet::new()),
            held: Mutex::new(VecDeque::new()),
            stats: Mutex::new(FaultStats::default()),
        }
    }

    /// Counters of faults injected so far.
    pub fn stats(&self) -> FaultStats {
        *self.stats.lock().expect("fault stats poisoned")
    }

    /// The plan this wrapper runs.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    fn bump(&self, f: impl FnOnce(&mut FaultStats)) {
        f(&mut self.stats.lock().expect("fault stats poisoned"));
    }

    /// The chunk-frame half of `send`: chunk frames get chunk-granular
    /// decisions from [`FaultPlan::chunk_action`]; anything that peeks
    /// as neither a whole client update nor a chunk passes through
    /// unfaulted. Corruption and truncation land in the chunk *body*
    /// (header intact) so the frame still routes and the reassembly
    /// path rejects the client's whole round as one decode failure.
    fn chunk_send(&self, payload: &[u8]) -> Result<()> {
        let Ok(h) = Decoder::peek_chunk_header(payload) else {
            return self.inner.send(payload);
        };
        match self.plan.chunk_action(h.client_id, h.round, h.layer) {
            FaultAction::Deliver => self.inner.send(payload),
            FaultAction::Drop => {
                self.bump(|s| s.dropped += 1);
                Ok(())
            }
            FaultAction::Duplicate => {
                self.bump(|s| s.duplicated += 1);
                self.inner.send(payload)?;
                self.inner.send(payload)
            }
            FaultAction::Corrupt => {
                self.bump(|s| s.corrupted += 1);
                let mut bytes = payload.to_vec();
                FaultPlan::corrupt_in_place(&mut bytes, CHUNK_HEADER_LEN);
                self.inner.send(&bytes)
            }
            FaultAction::Truncate => {
                if payload.len() <= CHUNK_HEADER_LEN + 1 {
                    // no body to cut: fold to drop
                    self.bump(|s| s.dropped += 1);
                    return Ok(());
                }
                self.bump(|s| s.truncated += 1);
                let tag = CHUNK_TAG ^ 0x7C ^ (h.layer as u64).wrapping_mul(LAYER_MIX);
                let mut rng = self.plan.rng_for(tag, h.client_id as u64, h.round);
                let body = payload.len() - CHUNK_HEADER_LEN - 1;
                let cut = CHUNK_HEADER_LEN + rng.below(body.max(1));
                self.inner.send(&payload[..cut])
            }
            FaultAction::Disconnect => {
                // one Closed per (client, round): the first faulted
                // chunk fires it, the re-sent stream then goes through
                let first = self
                    .disconnected
                    .lock()
                    .expect("disconnect set poisoned")
                    .insert((h.client_id, h.round));
                if first {
                    self.bump(|s| s.disconnects += 1);
                    Err(TransportError::Closed.into())
                } else {
                    self.inner.send(payload)
                }
            }
            FaultAction::Delay => {
                self.bump(|s| s.delayed += 1);
                self.held
                    .lock()
                    .expect("held queue poisoned")
                    .push_back(payload.to_vec());
                Ok(())
            }
        }
    }
}

impl Transport for FaultyTransport {
    fn send(&self, payload: &[u8]) -> Result<()> {
        // decisions key on the frame's own identity, not arrival order
        let Ok(h) = Decoder::peek_header(payload) else {
            return self.chunk_send(payload);
        };
        match self.plan.up_action(h.client_id, h.round) {
            FaultAction::Deliver => self.inner.send(payload),
            FaultAction::Drop => {
                self.bump(|s| s.dropped += 1);
                Ok(())
            }
            FaultAction::Duplicate => {
                self.bump(|s| s.duplicated += 1);
                self.inner.send(payload)?;
                self.inner.send(payload)
            }
            FaultAction::Corrupt => {
                self.bump(|s| s.corrupted += 1);
                let mut bytes = payload.to_vec();
                FaultPlan::corrupt_in_place(&mut bytes, CLIENT_HEADER_LEN);
                self.inner.send(&bytes)
            }
            FaultAction::Truncate => {
                if payload.len() <= CLIENT_HEADER_LEN + 1 {
                    // no body to cut: fold to drop
                    self.bump(|s| s.dropped += 1);
                    return Ok(());
                }
                self.bump(|s| s.truncated += 1);
                let mut rng = self.plan.rng_for(UP_TAG ^ 0x7C, h.client_id as u64, h.round);
                let body = payload.len() - CLIENT_HEADER_LEN - 1;
                let cut = CLIENT_HEADER_LEN + rng.below(body.max(1));
                self.inner.send(&payload[..cut])
            }
            FaultAction::Disconnect => {
                let first = self
                    .disconnected
                    .lock()
                    .expect("disconnect set poisoned")
                    .insert((h.client_id, h.round));
                if first {
                    self.bump(|s| s.disconnects += 1);
                    Err(TransportError::Closed.into())
                } else {
                    // the reconnect retry lands here and succeeds
                    self.inner.send(payload)
                }
            }
            FaultAction::Delay => {
                self.bump(|s| s.delayed += 1);
                self.held
                    .lock()
                    .expect("held queue poisoned")
                    .push_back(payload.to_vec());
                Ok(())
            }
        }
    }

    fn recv(&self) -> Result<Vec<u8>> {
        self.inner.recv()
    }

    fn recv_timeout(&self, timeout: Duration) -> std::result::Result<Vec<u8>, TransportError> {
        match self.inner.recv_timeout(timeout) {
            Err(TransportError::TimedOut(d)) => {
                // a deadline expired with nothing pending: release one
                // held frame per expiry so delayed traffic arrives
                // "late" — after the round's first deadline, inside the
                // quorum re-poll window
                match self.held.lock().expect("held queue poisoned").pop_front() {
                    Some(frame) => Ok(frame),
                    None => Err(TransportError::TimedOut(d)),
                }
            }
            other => other,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::transport::InProcTransport;
    use crate::net::wire::{ClientUpdate, Encoder};
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn frame(client: u32, round: u64) -> Vec<u8> {
        let mut rng = Rng::new(client as u64 + round);
        let up = ClientUpdate::Sgd { grads: vec![Tensor::randn(&[4, 3], &mut rng)] };
        Encoder::new(&up, client, round)
    }

    #[test]
    fn plan_grammar_round_trips_and_validates() {
        let plan =
            FaultPlan::parse("drop=0.02,corrupt=0.01,down.drop=0.05,seed=7,rounds=2..9")
                .unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.up.drop, 0.02);
        assert_eq!(plan.up.corrupt, 0.01);
        assert_eq!(plan.down.drop, 0.05);
        assert_eq!(plan.rounds, Some((2, 9)));
        assert_eq!(FaultPlan::parse(&plan.format()).unwrap(), plan);

        assert!(FaultPlan::parse("drop=1.5").is_err());
        assert!(FaultPlan::parse("drop=0.9,dup=0.9").is_err());
        assert!(FaultPlan::parse("sideways.drop=0.1").is_err());
        assert!(FaultPlan::parse("flood=0.1").is_err());
        assert!(FaultPlan::parse("rounds=9..2").is_err());
    }

    #[test]
    fn decisions_are_pure_in_seed_client_and_round() {
        let plan = FaultPlan {
            seed: 42,
            up: FaultRates { drop: 0.2, corrupt: 0.2, delay: 0.2, ..Default::default() },
            ..Default::default()
        };
        for client in 0..50u32 {
            for round in 0..20u64 {
                let a = plan.up_action(client, round);
                let b = plan.up_action(client, round);
                assert_eq!(a, b, "decision not pure at ({client}, {round})");
            }
        }
        // a different seed decides differently somewhere
        let other = FaultPlan { seed: 43, ..plan.clone() };
        let differs = (0..50u32).any(|c| {
            (0..20u64).any(|r| plan.up_action(c, r) != other.up_action(c, r))
        });
        assert!(differs, "seed does not influence decisions");
    }

    #[test]
    fn round_window_and_partition_gate_the_faults() {
        let plan = FaultPlan {
            seed: 1,
            up: FaultRates { drop: 1.0, ..Default::default() },
            rounds: Some((5, 6)),
            partitions: vec![Partition { clients: vec![3], rounds: (0, 100) }],
            ..Default::default()
        };
        assert_eq!(plan.up_action(0, 4), FaultAction::Deliver);
        assert_eq!(plan.up_action(0, 5), FaultAction::Drop);
        assert_eq!(plan.up_action(0, 6), FaultAction::Deliver);
        // partitions apply inside the window regardless of rates…
        assert_eq!(plan.up_action(3, 5), FaultAction::Drop);
        // …but are themselves windows over the *plan's* active range
        assert_eq!(plan.up_action(3, 7), FaultAction::Deliver);
    }

    #[test]
    fn faulty_transport_drops_duplicates_and_corrupts_deterministically() {
        // rate 1.0 of a single kind makes each behavior observable
        let run = |rates: FaultRates| {
            let t = FaultyTransport::new(
                Box::new(InProcTransport::new()),
                FaultPlan { seed: 9, up: rates, ..Default::default() },
            );
            t.send(&frame(1, 0)).unwrap();
            let mut got = Vec::new();
            while let Ok(f) = t.recv_timeout(Duration::from_millis(10)) {
                got.push(f);
            }
            (got, t.stats())
        };

        let (got, stats) = run(FaultRates { drop: 1.0, ..Default::default() });
        assert!(got.is_empty());
        assert_eq!(stats.dropped, 1);

        let (got, stats) = run(FaultRates { duplicate: 1.0, ..Default::default() });
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], got[1]);
        assert_eq!(stats.duplicated, 1);

        let (got, stats) = run(FaultRates { corrupt: 1.0, ..Default::default() });
        assert_eq!(got.len(), 1);
        assert_eq!(stats.corrupted, 1);
        // header still routes, body decode fails
        let h = Decoder::peek_header(&got[0]).unwrap();
        assert_eq!(h.client_id, 1);
        assert!(Decoder::decode(&got[0]).is_err());

        let (got, stats) = run(FaultRates { truncate: 1.0, ..Default::default() });
        assert_eq!(got.len(), 1);
        assert_eq!(stats.truncated, 1);
        assert!(Decoder::peek_header(&got[0]).is_ok());
        assert!(Decoder::decode(&got[0]).is_err());
    }

    #[test]
    fn disconnect_fails_once_then_the_retry_succeeds() {
        let t = FaultyTransport::new(
            Box::new(InProcTransport::new()),
            FaultPlan {
                seed: 3,
                up: FaultRates { disconnect: 1.0, ..Default::default() },
                ..Default::default()
            },
        );
        let f = frame(2, 1);
        let err = t.send(&f).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<TransportError>(),
            Some(TransportError::Closed)
        ));
        // the retry (same client, same round) goes through
        t.send(&f).unwrap();
        assert_eq!(t.recv_timeout(Duration::from_millis(50)).unwrap(), f);
        assert_eq!(t.stats().disconnects, 1);
    }

    #[test]
    fn delayed_frames_arrive_only_after_a_deadline_expires() {
        let t = FaultyTransport::new(
            Box::new(InProcTransport::new()),
            FaultPlan {
                seed: 4,
                up: FaultRates { delay: 1.0, ..Default::default() },
                ..Default::default()
            },
        );
        let f = frame(0, 2);
        t.send(&f).unwrap();
        // the frame is not in the live stream…
        let first = t.recv_timeout(Duration::from_millis(5));
        // …it is released by that expiry (or a later one)
        let got = match first {
            Ok(frame) => frame,
            Err(TransportError::TimedOut(_)) => {
                t.recv_timeout(Duration::from_millis(5)).unwrap()
            }
            Err(e) => panic!("unexpected transport error: {e}"),
        };
        assert_eq!(got, f);
        assert_eq!(t.stats().delayed, 1);
    }

    fn chunk_frames_for(client: u32, round: u64) -> Vec<Vec<u8>> {
        let mut rng = Rng::new(900 + client as u64);
        let up = ClientUpdate::Sgd {
            grads: vec![Tensor::randn(&[4, 3], &mut rng), Tensor::randn(&[4], &mut rng)],
        };
        Encoder::chunk_frames(&up, client, round)
    }

    #[test]
    fn chunk_decisions_are_pure_and_layer_granular() {
        let plan = FaultPlan {
            seed: 11,
            up: FaultRates { drop: 0.3, corrupt: 0.3, ..Default::default() },
            ..Default::default()
        };
        for client in 0..20u32 {
            for round in 0..10u64 {
                for layer in 0..4u32 {
                    let a = plan.chunk_action(client, round, layer);
                    let b = plan.chunk_action(client, round, layer);
                    assert_eq!(a, b, "chunk decision not pure at ({client}, {round}, {layer})");
                }
            }
        }
        // layers decide independently: somewhere two layers of the same
        // (client, round) disagree…
        let layer_differs = (0..20u32).any(|c| {
            (0..10u64).any(|r| plan.chunk_action(c, r, 0) != plan.chunk_action(c, r, 1))
        });
        assert!(layer_differs, "layer does not influence chunk decisions");
        // …and the chunk stream is independent of the whole-frame stream
        let stream_differs = (0..20u32).any(|c| {
            (0..10u64).any(|r| plan.chunk_action(c, r, 0) != plan.up_action(c, r))
        });
        assert!(stream_differs, "chunk stream shadows the whole-frame stream");
    }

    #[test]
    fn faulty_transport_faults_chunks_individually() {
        let run = |rates: FaultRates| {
            let t = FaultyTransport::new(
                Box::new(InProcTransport::new()),
                FaultPlan { seed: 13, up: rates, ..Default::default() },
            );
            for f in chunk_frames_for(1, 0) {
                t.send(&f).unwrap();
            }
            let mut got = Vec::new();
            while let Ok(f) = t.recv_timeout(Duration::from_millis(10)) {
                got.push(f);
            }
            (got, t.stats())
        };

        let (got, stats) = run(FaultRates { drop: 1.0, ..Default::default() });
        assert!(got.is_empty());
        assert_eq!(stats.dropped, 2, "each chunk dropped individually");

        let (got, stats) = run(FaultRates { duplicate: 1.0, ..Default::default() });
        assert_eq!(got.len(), 4);
        assert_eq!(stats.duplicated, 2, "each chunk duplicated individually");

        let (got, stats) = run(FaultRates { corrupt: 1.0, ..Default::default() });
        assert_eq!(got.len(), 2);
        assert_eq!(stats.corrupted, 2);
        for f in &got {
            // header still routes; the body decode fails
            assert!(Decoder::peek_chunk_header(f).is_ok());
            assert!(Decoder::decode_chunk(f).is_err());
        }

        let (got, stats) = run(FaultRates { truncate: 1.0, ..Default::default() });
        assert_eq!(got.len(), 2);
        assert_eq!(stats.truncated, 2);
        for f in &got {
            assert!(Decoder::peek_chunk_header(f).is_ok());
            assert!(Decoder::decode_chunk(f).is_err());
        }
    }

    #[test]
    fn non_client_frames_pass_through_unfaulted() {
        let t = FaultyTransport::new(
            Box::new(InProcTransport::new()),
            FaultPlan {
                seed: 5,
                up: FaultRates { drop: 1.0, ..Default::default() },
                ..Default::default()
            },
        );
        let raw = vec![1u8, 2, 3, 4];
        t.send(&raw).unwrap();
        assert_eq!(t.recv_timeout(Duration::from_millis(50)).unwrap(), raw);
    }
}
