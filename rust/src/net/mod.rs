//! Simulated network substrate.
//!
//! * [`wire`] — the exact byte-level serialization of every client→server
//!   update. The experiments' `#bits` column is the serialized payload
//!   size, so the paper's accounting (32 + βn bits per quantized tensor,
//!   factors only) is enforced by construction.
//! * [`link`] — per-client link models (bandwidth + latency) used to
//!   simulate transmission time and to drive the adaptive-p policy of
//!   experiment 3 ("p can be chosen based on the client's connection
//!   speed").
//! * [`transport`] — pluggable byte transports: in-process channels for
//!   the simulation loop and a real TCP transport (`qrr serve` /
//!   integration tests) proving the wire format round-trips across
//!   processes.
//! * [`faults`] — seeded, deterministic fault injection
//!   ([`FaultPlan`] / [`FaultyTransport`]): drop / delay / duplicate /
//!   corrupt / truncate / disconnect / partition, composable over any
//!   transport, byte-reproducible from a seed (DESIGN.md §11).

pub mod faults;
pub mod link;
pub mod transport;
pub mod wire;

pub use faults::{FaultAction, FaultPlan, FaultRates, FaultStats, FaultyTransport, Partition};
pub use link::LinkModel;
pub use transport::{
    Disconnect, FrameAssembler, FrameError, InProcTransport, TcpClient, TcpServerTransport,
    TcpTransport, Transport, TransportError, MAX_FRAME_BYTES,
};
pub use wire::{
    ChunkBody, ChunkHeader, ClientUpdate, Decoder, Encoder, ServerUpdate, WireError, WireHeader,
    CHUNK_HEADER_LEN,
};
