//! The Quantized Rank Reduction operator (paper §III-A, eq. (19)).
//!
//! Per parameter-tensor the client applies ℚ(ℂ(·)) and the server applies
//! ℂ⁻¹:
//!
//! * 2-D gradients (FC weights)      → truncated SVD, factors quantized
//!   (eq. (20)/(24)): messages carry Q(U), Q(Σ), Q(V).
//! * 4-D gradients (conv kernels)    → Tucker/HOSVD, factors quantized
//!   (eq. (21)/(25)): messages carry Q(𝔊), Q(F₁)…Q(F₄).
//! * 1-D gradients (biases)          → quantized only (eq. (26)).
//!
//! Both sides keep per-factor [`QuantState`]s (the client to center the
//! next grid, the server to apply innovations, eq. (17)), so the pair
//! [`ClientCodec`]/[`ServerCodec`] must stay in lock-step — an invariant
//! the property tests sweep.

mod codec;
pub mod error_feedback;

pub use codec::{ClientCodec, ParamMsg, ParamState, ServerCodec};
pub use error_feedback::EfClientCodec;

use crate::linalg::SvdMethod;

/// Static configuration of the QRR operator for one client.
#[derive(Debug, Clone, Copy)]
pub struct QrrConfig {
    /// Fraction of the original rank retained (paper's `p`, eq. (22)/(23)).
    pub p: f64,
    /// Quantization bits per element (paper's β).
    pub beta: u8,
    /// SVD engine used for ℂ.
    pub method: SvdMethod,
}

impl QrrConfig {
    /// Paper defaults: β = 8, Auto SVD engine.
    pub fn with_p(p: f64) -> Self {
        QrrConfig { p, beta: 8, method: SvdMethod::Auto }
    }
}

impl Default for QrrConfig {
    fn default() -> Self {
        Self::with_p(0.3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::Rng;

    fn shapes() -> Vec<Vec<usize>> {
        vec![
            vec![200, 784],      // MLP hidden weight
            vec![200],           // hidden bias
            vec![10, 200],       // output weight
            vec![10],            // output bias
            vec![16, 1, 3, 3],   // conv1 kernel
            vec![16],            // conv1 bias
            vec![32, 16, 3, 3],  // conv2 kernel
        ]
    }

    fn random_grads(rng: &mut Rng) -> Vec<Tensor> {
        shapes().iter().map(|s| Tensor::randn(s, rng)).collect()
    }

    #[test]
    fn client_server_roundtrip_reconstructs_approximately() {
        let mut rng = Rng::new(70);
        let shapes = shapes();
        let cfg = QrrConfig::with_p(0.5);
        let mut client = ClientCodec::new(&shapes, cfg);
        let mut server = ServerCodec::new(&shapes, cfg);
        let grads = random_grads(&mut rng);
        let msgs = client.encode(&grads);
        let rec = server.decode(&msgs);
        for (g, r) in grads.iter().zip(rec.iter()) {
            assert_eq!(g.shape(), r.shape());
            // random (full-rank) gradients at p=0.5: expect rough shape
            // agreement, not exactness
            assert!(g.rel_err(r) < 1.0, "err {}", g.rel_err(r));
        }
        // biases are quantize-only: near-exact at beta=8
        assert!(grads[1].rel_err(&rec[1]) < 0.02);
        assert!(grads[3].rel_err(&rec[3]) < 0.02);
    }

    #[test]
    fn lowrank_gradients_reconstruct_well() {
        let mut rng = Rng::new(71);
        // rank-3 matrix gradient, p=0.3 -> nu = 15 on a 50x80
        let u = Tensor::randn(&[50, 3], &mut rng);
        let v = Tensor::randn(&[3, 80], &mut rng);
        let g = crate::linalg::matmul(&u, &v);
        let shapes = vec![vec![50, 80]];
        let cfg = QrrConfig::with_p(0.3);
        let mut client = ClientCodec::new(&shapes, cfg);
        let mut server = ServerCodec::new(&shapes, cfg);
        let rec = server.decode(&client.encode(&[g.clone()]));
        assert!(g.rel_err(&rec[0]) < 0.05, "err {}", g.rel_err(&rec[0]));
    }

    #[test]
    fn states_stay_synchronized_over_rounds() {
        let mut rng = Rng::new(72);
        let shapes = shapes();
        let cfg = QrrConfig::with_p(0.2);
        let mut client = ClientCodec::new(&shapes, cfg);
        let mut server = ServerCodec::new(&shapes, cfg);
        for _round in 0..10 {
            let grads = random_grads(&mut rng);
            let msgs = client.encode(&grads);
            let _ = server.decode(&msgs);
            for (cs, ss) in client.states().iter().zip(server.states().iter()) {
                assert!(cs.states_close(ss, 1e-5), "client/server state diverged");
            }
        }
    }

    #[test]
    fn wire_bits_far_below_dense() {
        let mut rng = Rng::new(73);
        let shapes = shapes();
        let cfg = QrrConfig::with_p(0.1);
        let mut client = ClientCodec::new(&shapes, cfg);
        let grads = random_grads(&mut rng);
        let msgs = client.encode(&grads);
        let qrr_bits: u64 = msgs.iter().map(|m| m.wire_bits()).sum();
        let dense_bits: u64 = shapes
            .iter()
            .map(|s| 32 * s.iter().product::<usize>() as u64)
            .sum();
        // paper reports ~3% of SGD bits at p=0.1
        assert!(
            (qrr_bits as f64) < 0.10 * dense_bits as f64,
            "qrr {qrr_bits} vs dense {dense_bits}"
        );
    }

    #[test]
    fn per_param_kinds_assigned_by_ndim() {
        let shapes = vec![vec![4, 4], vec![4], vec![2, 2, 3, 3]];
        let cfg = QrrConfig::with_p(0.5);
        let client = ClientCodec::new(&shapes, cfg);
        let kinds: Vec<&str> = client.states().iter().map(|s| s.kind_name()).collect();
        assert_eq!(kinds, vec!["svd", "dense", "tucker"]);
    }

    #[test]
    fn pooled_encode_decode_match_serial() {
        let shapes = shapes();
        let cfg = QrrConfig::with_p(0.2);
        let mut rng = Rng::new(75);
        let grads: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
        let pool = crate::exec::ThreadPool::new(4);

        let mut c_serial = ClientCodec::new(&shapes, cfg);
        let mut c_pooled = ClientCodec::new(&shapes, cfg);
        let m1 = c_serial.encode(&grads);
        let m2 = c_pooled.encode_on(&grads, &pool);
        assert_eq!(m1.len(), m2.len());
        for (a, b) in m1.iter().zip(m2.iter()) {
            assert_eq!(a.wire_bits(), b.wire_bits());
        }
        for (cs, ps) in c_serial.states().iter().zip(c_pooled.states().iter()) {
            assert!(cs.states_close(ps, 1e-6), "pooled encode diverged from serial");
        }

        let mut s_serial = ServerCodec::new(&shapes, cfg);
        let mut s_pooled = ServerCodec::new(&shapes, cfg);
        let g1 = s_serial.decode(&m1);
        let g2 = s_pooled.decode_on(&m2, &pool);
        for (a, b) in g1.iter().zip(g2.iter()) {
            assert!(a.rel_err(b) < 1e-6, "pooled decode diverged from serial");
        }
    }

    #[test]
    fn repeated_same_gradient_refines() {
        // Feeding the same gradient repeatedly must reduce reconstruction
        // error: the differential grids shrink (same argument as LAQ).
        let mut rng = Rng::new(74);
        let u = Tensor::randn(&[30, 2], &mut rng);
        let v = Tensor::randn(&[2, 40], &mut rng);
        let g = crate::linalg::matmul(&u, &v);
        let shapes = vec![vec![30, 40]];
        let cfg = QrrConfig { p: 0.2, beta: 4, method: SvdMethod::Jacobi };
        let mut client = ClientCodec::new(&shapes, cfg);
        let mut server = ServerCodec::new(&shapes, cfg);
        let mut first = None;
        let mut last = 0f32;
        for _ in 0..8 {
            let rec = server.decode(&client.encode(&[g.clone()]));
            last = g.rel_err(&rec[0]);
            first.get_or_insert(last);
        }
        assert!(
            last <= first.unwrap() + 1e-6,
            "no refinement: first {:?} last {last}",
            first
        );
    }
}
