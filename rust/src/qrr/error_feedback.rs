//! Error-feedback QRR (EF-QRR) — the natural extension the compression
//! literature applies on top of biased compressors (Seide et al.;
//! Karimireddy et al.): each client keeps the residual of its previous
//! compressed update and adds it to the next gradient before compressing,
//!
//! ```text
//! m^k   = ∇f_c(θ^k) + e^{k−1}
//! msg   = ℚ(ℂ(m^k))
//! e^k   = m^k − reconstruct(msg)
//! ```
//!
//! so the compression error is re-injected rather than lost. This is the
//! "future work" knob for the accuracy gap the paper reports (QRR loses
//! 1–9 % accuracy); the `ablations` bench and `ef_qrr` tests quantify the
//! recovery.

use crate::tensor::Tensor;

use super::codec::{ClientCodec, ParamMsg, ServerCodec};
use super::QrrConfig;

/// Client codec with error feedback. Wire format is identical to plain
/// QRR — the server needs no changes (it still applies [`ServerCodec`]).
#[derive(Debug, Clone)]
pub struct EfClientCodec {
    inner: ClientCodec,
    /// mirror of the server's decoder, used to compute the residual
    mirror: ServerCodec,
    residual: Vec<Tensor>,
}

impl EfClientCodec {
    /// Build for a model's parameter shapes.
    pub fn new(shapes: &[Vec<usize>], cfg: QrrConfig) -> Self {
        EfClientCodec {
            inner: ClientCodec::new(shapes, cfg),
            mirror: ServerCodec::new(shapes, cfg),
            residual: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
        }
    }

    /// Wrap an externally planned codec pair (the
    /// [`compress::pipeline`](crate::compress::pipeline) `ef` stage).
    /// `inner` and `mirror` must share one plan over `shapes`.
    pub fn from_parts(inner: ClientCodec, mirror: ServerCodec, shapes: &[Vec<usize>]) -> Self {
        EfClientCodec {
            inner,
            mirror,
            residual: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
        }
    }

    /// Encode with error feedback; same message type as plain QRR. The
    /// residual updates are the SIMD `sum_into`/`axpy` kernels
    /// ([`crate::exec::simd`]) writing into the standing residual
    /// buffers — no per-round residual allocation.
    pub fn encode(&mut self, grads: &[Tensor]) -> Vec<ParamMsg> {
        assert_eq!(grads.len(), self.residual.len());
        // m = grad + residual
        let m: Vec<Tensor> = grads
            .iter()
            .zip(self.residual.iter())
            .map(|(g, e)| {
                let mut m = g.clone();
                crate::exec::simd::sum_into(m.data_mut(), e.data());
                m
            })
            .collect();
        let msgs = self.inner.encode(&m);
        // residual = m - reconstruction(msg), in place
        let rec = self.mirror.decode(&msgs);
        for ((e, mi), r) in self.residual.iter_mut().zip(m.iter()).zip(rec.iter()) {
            e.data_mut().copy_from_slice(mi.data());
            crate::exec::simd::axpy(e.data_mut(), -1.0, r.data());
        }
        msgs
    }

    /// Residual state memory (adds one gradient copy to QRR's footprint).
    pub fn mem_bytes(&self) -> usize {
        self.inner.mem_bytes()
            + self.mirror.mem_bytes()
            + self.residual.iter().map(|t| t.len() * 4).sum::<usize>()
    }

    /// ℓ2 norm of the accumulated residual (diagnostics).
    pub fn residual_norm(&self) -> f64 {
        self.residual
            .iter()
            .map(crate::tensor::sq_norm)
            .sum::<f64>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::util::Rng;

    /// EF must recover information plain QRR loses: feeding the SAME
    /// gradient repeatedly, the *accumulated* applied update converges to
    /// the true gradient even at tiny p, where plain QRR stays biased.
    #[test]
    fn error_feedback_removes_compression_bias() {
        let mut rng = Rng::new(300);
        let shapes = vec![vec![24usize, 36]];
        // full-rank gradient, heavily compressed (p -> rank 2)
        let g = Tensor::randn(&[24, 36], &mut rng);
        let cfg = QrrConfig { p: 0.05, beta: 8, method: crate::linalg::SvdMethod::Jacobi };

        let run = |ef: bool| {
            let mut plain = ClientCodec::new(&shapes, cfg);
            let mut ef_codec = EfClientCodec::new(&shapes, cfg);
            let mut server = ServerCodec::new(&shapes, cfg);
            let mut applied = Tensor::zeros(&[24, 36]);
            let rounds = 30;
            for _ in 0..rounds {
                let msgs = if ef {
                    ef_codec.encode(std::slice::from_ref(&g))
                } else {
                    plain.encode(std::slice::from_ref(&g))
                };
                let rec = server.decode(&msgs);
                applied.axpy(1.0, &rec[0]);
            }
            applied.scale(1.0 / rounds as f32);
            g.rel_err(&applied)
        };

        let err_plain = run(false);
        let err_ef = run(true);
        assert!(
            err_ef < 0.5 * err_plain,
            "EF should at least halve the bias: plain {err_plain} ef {err_ef}"
        );
        assert!(err_ef < 0.25, "EF residual error too large: {err_ef}");
    }

    #[test]
    fn low_rank_gradients_keep_small_residual() {
        let mut rng = Rng::new(301);
        let shapes = vec![vec![30usize, 40]];
        let u = Tensor::randn(&[30, 2], &mut rng);
        let v = Tensor::randn(&[2, 40], &mut rng);
        let g = matmul(&u, &v);
        let cfg = QrrConfig::with_p(0.2); // rank 6 >= true rank 2
        let mut ef = EfClientCodec::new(&shapes, cfg);
        for _ in 0..5 {
            let _ = ef.encode(std::slice::from_ref(&g));
        }
        // residual stays small relative to the signal
        assert!(
            ef.residual_norm() < 0.2 * g.fro_norm() as f64,
            "residual {} vs signal {}",
            ef.residual_norm(),
            g.fro_norm()
        );
    }

    #[test]
    fn wire_format_is_unchanged() {
        let mut rng = Rng::new(302);
        let shapes = vec![vec![10usize, 12], vec![10]];
        let cfg = QrrConfig::with_p(0.3);
        let mut ef = EfClientCodec::new(&shapes, cfg);
        let grads: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, &mut rng)).collect();
        let msgs = ef.encode(&grads);
        // serializes exactly like plain QRR
        let up = crate::net::ClientUpdate::Qrr { msgs };
        let bytes = crate::net::Encoder::new(&up, 0, 0);
        assert!(crate::net::Decoder::decode(&bytes).is_ok());
    }

    #[test]
    fn mem_accounting_includes_residual() {
        let shapes = vec![vec![50usize, 60]];
        let cfg = QrrConfig::with_p(0.1);
        let ef = EfClientCodec::new(&shapes, cfg);
        let plain = ClientCodec::new(&shapes, cfg);
        assert!(ef.mem_bytes() > plain.mem_bytes() + 50 * 60 * 4);
    }
}
