//! Client/server codecs implementing QRR_c (paper eq. (19)) and the
//! server-side inverse.
//!
//! Both directions come in a serial form (`encode`/`decode`) and a
//! pool-backed per-layer form (`encode_on`/`decode_on`) that fans the
//! independent parameter tensors out over an [`exec::ThreadPool`]
//! (DESIGN.md §5). The quantizer underneath reuses thread-local code
//! scratch, so neither form allocates intermediate code buffers in
//! steady state.
//!
//! [`exec::ThreadPool`]: crate::exec::ThreadPool

use std::sync::Mutex;

use crate::compress::{
    compress_svd, compress_tucker, decompress_svd, decompress_tucker, svd_rank, tucker_ranks,
    SvdCompressed, TuckerCompressed,
};
use crate::exec::ThreadPool;
use crate::linalg::SvdMethod;
use crate::quant::{QuantState, Quantized};
use crate::tensor::Tensor;

use super::QrrConfig;

/// Wire message for one parameter tensor.
#[derive(Debug, Clone)]
pub enum ParamMsg {
    /// Quantized truncated-SVD factors of a matrix gradient.
    Svd {
        /// Q(U_c^k) codes
        u: Quantized,
        /// Q(Σ_c^k) codes (diagonal only)
        s: Quantized,
        /// Q(V_c^k) codes
        v: Quantized,
    },
    /// Quantized Tucker factors of a 4-D (or N-D) gradient.
    Tucker {
        /// Q(𝔊_c^k) codes
        core: Quantized,
        /// Q((Fᵢ)_c^k) codes
        factors: Vec<Quantized>,
    },
    /// Quantize-only payload (biases / 1-D parameters).
    Dense {
        /// Q(∂J/∂b) codes
        q: Quantized,
    },
    /// Unquantized truncated-SVD factors (a `svd(p)` pipeline stage with
    /// the identity quantizer — see [`crate::compress::pipeline`]).
    RawSvd {
        /// U (m×ν), full precision
        u: Tensor,
        /// the ν singular values as a vector
        s: Tensor,
        /// V (n×ν), full precision
        v: Tensor,
    },
    /// Unquantized Tucker factors.
    RawTucker {
        /// core tensor, full precision
        core: Tensor,
        /// F₁…F_N, full precision
        factors: Vec<Tensor>,
    },
    /// Unreduced, unquantized tensor (identity reducer + identity
    /// quantizer inside a mixed pipeline).
    RawDense {
        /// the raw values
        t: Tensor,
    },
}

impl ParamMsg {
    /// Exact payload size in bits (32 + βn per quantized factor,
    /// eq. (16); 32 per f32 for unquantized factors).
    pub fn wire_bits(&self) -> u64 {
        match self {
            ParamMsg::Svd { u, s, v } => u.wire_bits() + s.wire_bits() + v.wire_bits(),
            ParamMsg::Tucker { core, factors } => {
                core.wire_bits() + factors.iter().map(|f| f.wire_bits()).sum::<u64>()
            }
            ParamMsg::Dense { q } => q.wire_bits(),
            ParamMsg::RawSvd { u, s, v } => 32 * (u.len() + s.len() + v.len()) as u64,
            ParamMsg::RawTucker { core, factors } => {
                32 * (core.len() + factors.iter().map(|f| f.len()).sum::<usize>()) as u64
            }
            ParamMsg::RawDense { t } => 32 * t.len() as u64,
        }
    }
}

/// Per-parameter quantizer state, mirrored on client and server.
#[derive(Debug, Clone)]
pub enum ParamState {
    /// Matrix parameter compressed by truncated SVD at rank ν.
    Svd {
        /// state for U (m×ν)
        u: QuantState,
        /// state for the ν singular values
        s: QuantState,
        /// state for V (n×ν)
        v: QuantState,
        /// retained rank ν
        nu: usize,
        /// original (m, n)
        shape: (usize, usize),
    },
    /// N-D parameter compressed by Tucker at per-mode ranks.
    Tucker {
        /// state for the core tensor
        core: QuantState,
        /// states for F₁…F_N
        factors: Vec<QuantState>,
        /// per-mode ranks
        ranks: Vec<usize>,
        /// original dims
        shape: Vec<usize>,
    },
    /// Quantize-only parameter.
    Dense {
        /// state for the raw values
        q: QuantState,
    },
}

impl ParamState {
    fn new(shape: &[usize], cfg: &QrrConfig) -> Self {
        match shape.len() {
            2 => Self::planned_svd(shape[0], shape[1], svd_rank(shape[0], shape[1], cfg.p)),
            d if d >= 3 => Self::planned_tucker(shape, tucker_ranks(shape, cfg.p)),
            _ => Self::planned_dense(shape),
        }
    }

    /// Quantize-only state for a parameter left unreduced.
    pub fn planned_dense(shape: &[usize]) -> Self {
        ParamState::Dense { q: QuantState::zeros(shape) }
    }

    /// State for an m×n matrix parameter truncated-SVD-reduced to rank ν.
    pub fn planned_svd(m: usize, n: usize, nu: usize) -> Self {
        ParamState::Svd {
            u: QuantState::zeros(&[m, nu]),
            s: QuantState::zeros(&[nu]),
            v: QuantState::zeros(&[n, nu]),
            nu,
            shape: (m, n),
        }
    }

    /// State for an N-D parameter Tucker-reduced at per-mode `ranks`.
    pub fn planned_tucker(shape: &[usize], ranks: Vec<usize>) -> Self {
        let factors = shape
            .iter()
            .zip(ranks.iter())
            .map(|(&dim, &r)| QuantState::zeros(&[dim, r]))
            .collect();
        ParamState::Tucker {
            core: QuantState::zeros(&ranks),
            factors,
            ranks,
            shape: shape.to_vec(),
        }
    }

    /// Human-readable compression kind.
    pub fn kind_name(&self) -> &'static str {
        match self {
            ParamState::Svd { .. } => "svd",
            ParamState::Tucker { .. } => "tucker",
            ParamState::Dense { .. } => "dense",
        }
    }

    /// Bytes of state memory held (the client-side overhead the paper
    /// measures in §III-B).
    pub fn mem_bytes(&self) -> usize {
        match self {
            ParamState::Svd { u, s, v, .. } => u.mem_bytes() + s.mem_bytes() + v.mem_bytes(),
            ParamState::Tucker { core, factors, .. } => {
                core.mem_bytes() + factors.iter().map(|f| f.mem_bytes()).sum::<usize>()
            }
            ParamState::Dense { q } => q.mem_bytes(),
        }
    }

    /// True when `msg` is exactly the kind and factor sizes this state
    /// expects — the precondition for [`decode`](ServerCodec::decode).
    /// Servers use it to discard wire-valid-but-mismatched frames (an
    /// external peer controls the bytes) instead of panicking mid-round.
    // qrr-audit: no-panic
    pub fn accepts(&self, msg: &ParamMsg) -> bool {
        match (self, msg) {
            (ParamState::Svd { u, s, v, .. }, ParamMsg::Svd { u: mu, s: ms, v: mv }) => {
                mu.wellformed(u.value().len())
                    && ms.wellformed(s.value().len())
                    && mv.wellformed(v.value().len())
            }
            (
                ParamState::Tucker { core, factors, .. },
                ParamMsg::Tucker { core: mc, factors: mf },
            ) => {
                mc.wellformed(core.value().len())
                    && factors.len() == mf.len()
                    && factors
                        .iter()
                        .zip(mf.iter())
                        .all(|(fs, m)| m.wellformed(fs.value().len()))
            }
            (ParamState::Dense { q }, ParamMsg::Dense { q: mq }) => {
                mq.wellformed(q.value().len())
            }
            _ => false,
        }
    }
    // qrr-audit: end

    /// True if two states agree elementwise within `tol` (test helper).
    pub fn states_close(&self, other: &ParamState, tol: f32) -> bool {
        match (self, other) {
            (ParamState::Svd { u: a, s: b, v: c, .. }, ParamState::Svd { u: x, s: y, v: z, .. }) => {
                close(a, x, tol) && close(b, y, tol) && close(c, z, tol)
            }
            (
                ParamState::Tucker { core: a, factors: fa, .. },
                ParamState::Tucker { core: b, factors: fb, .. },
            ) => {
                close(a, b, tol)
                    && fa.len() == fb.len()
                    && fa.iter().zip(fb.iter()).all(|(x, y)| close(x, y, tol))
            }
            (ParamState::Dense { q: a }, ParamState::Dense { q: b }) => close(a, b, tol),
            _ => false,
        }
    }
}

fn close(a: &QuantState, b: &QuantState, tol: f32) -> bool {
    a.value().sub(b.value()).max_norm() <= tol * (1.0 + a.value().max_norm())
}

/// Client-side QRR codec: ℚ ∘ ℂ with per-factor differential state.
#[derive(Debug, Clone)]
pub struct ClientCodec {
    cfg: QrrConfig,
    states: Vec<ParamState>,
}

impl ClientCodec {
    /// Build the codec for a model with the given parameter shapes.
    pub fn new(shapes: &[Vec<usize>], cfg: QrrConfig) -> Self {
        let states = shapes.iter().map(|s| ParamState::new(s, &cfg)).collect();
        ClientCodec { cfg, states }
    }

    /// Build from externally planned per-parameter states — the
    /// [`compress::pipeline`](crate::compress::pipeline) entry point,
    /// where the reducer stages decide each parameter's plan instead of
    /// the fixed ndim rules of [`Self::new`]. `cfg.p` is ignored (the
    /// plans already fix every rank); `cfg.beta`/`cfg.method` apply.
    pub fn from_states(states: Vec<ParamState>, cfg: QrrConfig) -> Self {
        ClientCodec { cfg, states }
    }

    /// Access per-parameter states (tests / overhead accounting).
    pub fn states(&self) -> &[ParamState] {
        &self.states
    }

    /// Total client-side state memory in bytes.
    pub fn mem_bytes(&self) -> usize {
        self.states.iter().map(|s| s.mem_bytes()).sum()
    }

    /// The configuration in use.
    pub fn config(&self) -> &QrrConfig {
        &self.cfg
    }

    /// Compress + quantize one gradient set. `grads[i]` must match the
    /// i-th shape the codec was built with.
    pub fn encode(&mut self, grads: &[Tensor]) -> Vec<ParamMsg> {
        assert_eq!(grads.len(), self.states.len(), "gradient count mismatch");
        let beta = self.cfg.beta;
        let method = self.cfg.method;
        self.states
            .iter_mut()
            .zip(grads.iter())
            .map(|(st, g)| encode_one(st, g, beta, method))
            .collect()
    }

    /// [`Self::encode`] with the per-parameter ℂ∘ℚ work (SVD/Tucker +
    /// quantize) fanned out over `pool`. Identical output in the same
    /// order; layers are independent, so this is a pure fan-out.
    pub fn encode_on(&mut self, grads: &[Tensor], pool: &ThreadPool) -> Vec<ParamMsg> {
        assert_eq!(grads.len(), self.states.len(), "gradient count mismatch");
        let beta = self.cfg.beta;
        let method = self.cfg.method;
        let n = self.states.len();
        let mut out: Vec<Option<ParamMsg>> = (0..n).map(|_| None).collect();
        {
            let slots: Vec<Mutex<&mut Option<ParamMsg>>> = out.iter_mut().map(Mutex::new).collect();
            let states: Vec<Mutex<&mut ParamState>> =
                self.states.iter_mut().map(Mutex::new).collect();
            pool.for_each(n, |i| {
                let mut st = states[i].lock().unwrap();
                let msg = encode_one(&mut **st, &grads[i], beta, method);
                **slots[i].lock().unwrap() = Some(msg);
            });
        }
        out.into_iter().map(|m| m.expect("encoded")).collect()
    }
}

/// Encode one parameter tensor against its mirrored state.
fn encode_one(st: &mut ParamState, g: &Tensor, beta: u8, method: SvdMethod) -> ParamMsg {
    match st {
        ParamState::Svd { u, s, v, nu, shape } => {
            debug_assert_eq!(g.shape(), &[shape.0, shape.1]);
            let SvdCompressed { u: cu, s: cs, v: cv, .. } = compress_svd(g, *nu, method);
            let mu = u.quantize_update(&cu, beta);
            let ms = s.quantize_update(&Tensor::vector(cs), beta);
            let mv = v.quantize_update(&cv, beta);
            ParamMsg::Svd { u: mu, s: ms, v: mv }
        }
        ParamState::Tucker { core, factors, ranks, shape } => {
            debug_assert_eq!(g.shape(), &shape[..]);
            let c: TuckerCompressed = compress_tucker(g, ranks, method);
            let mc = core.quantize_update(&c.core, beta);
            let mf = factors
                .iter_mut()
                .zip(c.factors.iter())
                .map(|(fs, f)| fs.quantize_update(f, beta))
                .collect();
            ParamMsg::Tucker { core: mc, factors: mf }
        }
        ParamState::Dense { q } => {
            let m = q.quantize_update(g, beta);
            ParamMsg::Dense { q: m }
        }
    }
}

/// Server-side QRR codec: applies innovations (eq. (17)) and reconstructs
/// gradients via ℂ⁻¹ (eq. (24)–(26)).
#[derive(Debug, Clone)]
pub struct ServerCodec {
    states: Vec<ParamState>,
}

impl ServerCodec {
    /// Build the mirror codec; must use the same shapes and config as the
    /// client's.
    pub fn new(shapes: &[Vec<usize>], cfg: QrrConfig) -> Self {
        let states = shapes.iter().map(|s| ParamState::new(s, &cfg)).collect();
        ServerCodec { states }
    }

    /// Mirror codec from externally planned states (must match the
    /// client's plans — see [`ClientCodec::from_states`]).
    pub fn from_states(states: Vec<ParamState>) -> Self {
        ServerCodec { states }
    }

    /// Access per-parameter states.
    pub fn states(&self) -> &[ParamState] {
        &self.states
    }

    /// Server-side state memory in bytes (held per client).
    pub fn mem_bytes(&self) -> usize {
        self.states.iter().map(|s| s.mem_bytes()).sum()
    }

    /// True when every message matches this codec's mirrored states —
    /// the precondition under which [`decode`](Self::decode) cannot
    /// panic on externally controlled input.
    // qrr-audit: no-panic
    pub fn accepts(&self, msgs: &[ParamMsg]) -> bool {
        msgs.len() == self.states.len()
            && self.states.iter().zip(msgs.iter()).all(|(st, m)| st.accepts(m))
    }
    // qrr-audit: end

    /// Decode one message set into reconstructed gradients.
    pub fn decode(&mut self, msgs: &[ParamMsg]) -> Vec<Tensor> {
        assert_eq!(msgs.len(), self.states.len(), "message count mismatch");
        self.states
            .iter_mut()
            .zip(msgs.iter())
            .map(|(st, msg)| decode_one(st, msg))
            .collect()
    }

    /// [`Self::decode`] with the per-parameter ℂ⁻¹ reconstruction (the
    /// SVD/Tucker matmuls) fanned out over `pool`. Identical output in
    /// the same order.
    pub fn decode_on(&mut self, msgs: &[ParamMsg], pool: &ThreadPool) -> Vec<Tensor> {
        assert_eq!(msgs.len(), self.states.len(), "message count mismatch");
        let n = self.states.len();
        let mut out: Vec<Option<Tensor>> = (0..n).map(|_| None).collect();
        {
            let slots: Vec<Mutex<&mut Option<Tensor>>> = out.iter_mut().map(Mutex::new).collect();
            let states: Vec<Mutex<&mut ParamState>> =
                self.states.iter_mut().map(Mutex::new).collect();
            pool.for_each(n, |i| {
                let mut st = states[i].lock().unwrap();
                let t = decode_one(&mut **st, &msgs[i]);
                **slots[i].lock().unwrap() = Some(t);
            });
        }
        out.into_iter().map(|t| t.expect("decoded")).collect()
    }
}

/// Decode one parameter message against its mirrored state.
fn decode_one(st: &mut ParamState, msg: &ParamMsg) -> Tensor {
    match (st, msg) {
        (ParamState::Svd { u, s, v, nu, shape }, ParamMsg::Svd { u: mu, s: ms, v: mv }) => {
            let qu = u.apply_update(mu).clone();
            let qs = s.apply_update(ms).data().to_vec();
            let qv = v.apply_update(mv).clone();
            let c = SvdCompressed {
                u: qu,
                s: qs,
                v: qv,
                shape: *shape,
            };
            debug_assert_eq!(c.rank(), *nu);
            decompress_svd(&c)
        }
        (
            ParamState::Tucker { core, factors, ranks: _, shape },
            ParamMsg::Tucker { core: mc, factors: mf },
        ) => {
            assert_eq!(factors.len(), mf.len(), "factor count mismatch");
            let qcore = core.apply_update(mc).clone();
            let qf: Vec<Tensor> = factors
                .iter_mut()
                .zip(mf.iter())
                .map(|(fs, m)| fs.apply_update(m).clone())
                .collect();
            let c = TuckerCompressed { core: qcore, factors: qf, shape: shape.clone() };
            decompress_tucker(&c)
        }
        (ParamState::Dense { q }, ParamMsg::Dense { q: mq }) => q.apply_update(mq).clone(),
        (st, _) => panic!("message kind does not match state kind {}", st.kind_name()),
    }
}
