//! # QRR — Quantized Rank Reduction for communication-efficient federated learning
//!
//! Reproduction of *"Quantized Rank Reduction: A Communications-Efficient
//! Federated Learning Scheme for Network-Critical Applications"*
//! (Kritsiolis & Kotropoulos, 2025).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * [`tensor`] / [`linalg`] — dense-tensor and factorization substrate
//!   (unfoldings, mode-n products, blocked matmul, QR, truncated SVD).
//! * [`quant`] — the LAQ β-bit grid quantizer with real bit-packing.
//! * [`compress`] — the ℂ/ℂ⁻¹ operators: truncated SVD for matrix
//!   gradients, Tucker (HOSVD) for 4-D convolution gradients.
//! * [`qrr`] — the paper's QRR operator (eq. 19): compress → quantize on
//!   the client, dequantize → reconstruct on the server.
//! * [`slaq`] — the SLAQ baseline (lazily aggregated quantized gradients).
//! * [`fl`] — federated-learning core: clients, server, update schemes,
//!   round loop, metrics.
//! * [`net`] — simulated network: wire format, bit accounting, link
//!   models, in-process and TCP transports.
//! * [`model`] — parameter schemas shared with the python build path and
//!   a pure-Rust reference implementation of the paper's models.
//! * [`runtime`] — PJRT runtime that loads the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and executes them from Rust.
//! * [`coordinator`] — round orchestration, parallel client execution,
//!   adaptive per-client rank selection.
//! * [`data`] — MNIST/CIFAR-10 loaders plus deterministic synthetic
//!   generators used when the real datasets are not on disk.
//!
//! Python (JAX + Pallas) runs only at **build time** (`make artifacts`);
//! the request path is pure Rust + PJRT.
//!
//! ## Quickstart
//!
//! ```no_run
//! use qrr::config::ExperimentConfig;
//! use qrr::coordinator::Coordinator;
//!
//! let cfg = ExperimentConfig::table1_default();
//! let mut coord = Coordinator::from_config(&cfg).unwrap();
//! let report = coord.run().unwrap();
//! println!("{}", report.markdown_table());
//! ```

pub mod bench_util;
pub mod cli;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod experiments;
pub mod fl;
pub mod linalg;
pub mod model;
pub mod net;
pub mod quant;
pub mod qrr;
pub mod runtime;
pub mod slaq;
pub mod tensor;
pub mod testing;
pub mod util;

pub use tensor::Tensor;
