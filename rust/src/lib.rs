//! # QRR — Quantized Rank Reduction for communication-efficient federated learning
//!
//! Reproduction of *"Quantized Rank Reduction: A Communications-Efficient
//! Federated Learning Scheme for Network-Critical Applications"*
//! (Kritsiolis & Kotropoulos, 2025).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * [`tensor`] / [`linalg`] — dense-tensor and factorization substrate
//!   (unfoldings, mode-n products, packed micro-kernel GEMM, blocked
//!   Householder QR, truncated SVD).
//! * [`quant`] — the LAQ β-bit grid quantizer with real bit-packing.
//! * [`compress`] — the ℂ/ℂ⁻¹ operators: truncated SVD for matrix
//!   gradients, Tucker (HOSVD) for 4-D convolution gradients — and
//!   [`compress::pipeline`], the composable
//!   rank-reduction × quantization × feedback pipeline API with its
//!   spec grammar and preset registry.
//! * [`qrr`] — the paper's QRR operator (eq. 19): compress → quantize on
//!   the client, dequantize → reconstruct on the server.
//! * [`slaq`] — the SLAQ baseline (lazily aggregated quantized gradients).
//! * [`fl`] — federated-learning core: clients, server, update schemes,
//!   round loop, metrics.
//! * [`control`] — the adaptive compression control plane: per-round
//!   policies mapping observed link telemetry to each client's
//!   `(p, beta)` pipeline spec.
//! * [`net`] — simulated network: wire format, bit accounting, link
//!   models, in-process and TCP transports.
//! * [`model`] — parameter schemas shared with the python build path and
//!   a pure-Rust reference implementation of the paper's models.
//! * [`runtime`] — PJRT runtime that loads the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and executes them from Rust.
//! * [`data`] — MNIST/CIFAR-10 loaders plus deterministic synthetic
//!   generators used when the real datasets are not on disk.
//!
//! Python (JAX + Pallas) runs only at **build time** (`make artifacts`);
//! the request path is pure Rust + PJRT.
//!
//! ## Quickstart
//!
//! ```no_run
//! use qrr::prelude::*;
//!
//! let cfg = ExperimentConfig::table1_default();
//! let mut session = FlSessionBuilder::new(&cfg).build().unwrap();
//! let report = session.run().unwrap();
//! println!("{}", report.markdown_table());
//! ```
//!
//! Every seam of the round loop is pluggable through the builder —
//! participation policy, aggregation rule, transport binding and metric
//! sinks; see [`fl::session`].
//!
//! The crate ships its own static-analysis gate, [`audit`] (`qrr audit
//! --check` in CI): SAFETY-comment and unsafe-allowlist enforcement,
//! allocation- and panic-free fenced regions, and environment-read
//! hygiene — see DESIGN.md §9.

#![deny(unsafe_op_in_unsafe_fn)]
#![warn(missing_debug_implementations)]

pub mod audit;
pub mod bench_util;
pub mod cli;
pub mod compress;
pub mod config;
pub mod control;
pub mod data;
pub mod exec;
pub mod experiments;
pub mod fl;
pub mod linalg;
pub mod model;
pub mod net;
pub mod quant;
pub mod qrr;
pub mod runtime;
pub mod slaq;
pub mod tensor;
pub mod testing;
pub mod util;

pub use tensor::Tensor;

/// One-stop imports for driving experiments through the session API.
pub mod prelude {
    pub use crate::compress::pipeline::{CompressionPipeline, PipelineSpec};
    pub use crate::config::{
        AggregationConfig, Backend, ExperimentConfig, PPolicy, ParticipationConfig, SchemeConfig,
        Sharding,
    };
    pub use crate::control::{ClientObservation, CompressionController, ControllerConfig, Outcome};
    pub use crate::data::DatasetKind;
    pub use crate::fl::session::{
        Aggregation, CsvSink, DeadlineCutoff, FlSession, FlSessionBuilder, FullSync, LinkDropout,
        LogSink, MetricsSink, ParticipationPolicy, RunReport, SumAggregation, UniformSampling,
        WeightedMeanAggregation,
    };
    pub use crate::fl::{History, SchemeKind};
    pub use crate::model::{ModelKind, ModelOps, ModelSpec};
    pub use crate::net::transport::{InProcTransport, TcpTransport, Transport, TransportError};
    pub use crate::net::LinkModel;
    pub use crate::tensor::Tensor;
}
