//! `qrr_audit` — standalone entry point for the static-analysis gate
//! (the same checker as `qrr audit`; CI runs this binary).
//!
//! ```text
//! qrr_audit [--check] [--list-rules] [--root DIR]
//! ```
//!
//! Without `--check` it reports findings and exits 0; with `--check`
//! any finding exits 1. See `qrr::audit` for the rules.

fn main() {
    let args = qrr::cli::Args::parse(std::env::args().skip(1));
    if let Err(e) = qrr::audit::run_cli(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
