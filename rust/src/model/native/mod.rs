//! Pure-Rust reference backend implementing [`ModelOps`](super::ModelOps)
//! for the paper's three architectures.
//!
//! Serves two roles:
//! 1. the default request-path backend (no artifacts needed), and
//! 2. the numeric oracle the PJRT/HLO path is cross-checked against
//!    (`rust/tests/pjrt_parity.rs`).

pub mod layers;

use crate::tensor::Tensor;

use super::{ModelKind, ModelOps, ModelSpec};
use layers::*;

/// Pure-Rust model. Construct via [`NativeModel::new`].
#[derive(Debug)]
pub struct NativeModel {
    spec: ModelSpec,
}

impl NativeModel {
    /// Build the native backend for an architecture.
    pub fn new(kind: ModelKind) -> Self {
        NativeModel { spec: ModelSpec::new(kind) }
    }

    fn forward_logits_mlp(&self, params: &[Tensor], x: &Tensor) -> (Tensor, MlpCtx) {
        let (w1, b1, w2, b2) = (&params[0], &params[1], &params[2], &params[3]);
        let z1 = dense_forward(x, w1, b1);
        let a1 = relu_forward(&z1);
        let logits = dense_forward(&a1, w2, b2);
        (logits, MlpCtx { z1, a1 })
    }

    fn forward_logits_cnn(&self, params: &[Tensor], x4: &Tensor) -> (Tensor, CnnCtx) {
        let (w1, b1, w2, b2, wf, bf) =
            (&params[0], &params[1], &params[2], &params[3], &params[4], &params[5]);
        let (z1, c1) = conv2d_forward(x4, w1, b1);
        let a1 = relu_forward(&z1);
        let (z2, c2) = conv2d_forward(&a1, w2, b2);
        let a2 = relu_forward(&z2);
        let (pooled, arg) = maxpool2_forward(&a2);
        let bsz = x4.shape()[0];
        let flat_dim = pooled.len() / bsz;
        let flat = pooled.clone().reshape(&[bsz, flat_dim]);
        let logits = dense_forward(&flat, wf, bf);
        let _ = a1; // consumed by conv2 forward; not needed in backward
        (logits, CnnCtx { z1, z2, a2, pooled_shape: pooled.shape().to_vec(), arg, flat, c1, c2 })
    }

    fn forward_logits_vgg(&self, params: &[Tensor], x4: &Tensor) -> (Tensor, VggCtx) {
        let bsz = x4.shape()[0];
        let mut cur = x4.clone();
        let mut blocks = Vec::with_capacity(3);
        for blk in 0..3 {
            let w = &params[blk * 2];
            let b = &params[blk * 2 + 1];
            let (z, cctx) = conv2d_forward(&cur, w, b);
            let a = relu_forward(&z);
            let (pooled, arg) = maxpool2_forward(&a);
            blocks.push(VggBlockCtx {
                z,
                a_shape: a.shape().to_vec(),
                arg,
                cctx,
            });
            cur = pooled;
        }
        let flat_dim = cur.len() / bsz;
        let flat = cur.clone().reshape(&[bsz, flat_dim]);
        let logits = dense_forward(&flat, &params[6], &params[7]);
        (logits, VggCtx { blocks, flat, pooled_shape: cur.shape().to_vec() })
    }

    fn input4(&self, x: &Tensor) -> Tensor {
        let bsz = x.shape()[0];
        let mut shape = vec![bsz];
        shape.extend_from_slice(&self.spec.input_shape);
        x.clone().reshape(&shape)
    }
}

struct MlpCtx {
    z1: Tensor,
    a1: Tensor,
}

struct CnnCtx {
    z1: Tensor,
    z2: Tensor,
    a2: Tensor,
    pooled_shape: Vec<usize>,
    arg: Vec<u32>,
    flat: Tensor,
    c1: ConvCtx,
    c2: ConvCtx,
}

struct VggBlockCtx {
    z: Tensor,
    a_shape: Vec<usize>,
    arg: Vec<u32>,
    cctx: ConvCtx,
}

struct VggCtx {
    blocks: Vec<VggBlockCtx>,
    flat: Tensor,
    pooled_shape: Vec<usize>,
}

impl ModelOps for NativeModel {
    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn loss_grad(&self, params: &[Tensor], x: &Tensor, y: &[u32]) -> (f32, Vec<Tensor>) {
        assert_eq!(params.len(), self.spec.params.len(), "param count");
        match self.spec.kind {
            ModelKind::Mlp => {
                let (logits, ctx) = self.forward_logits_mlp(params, x);
                let (loss, dlog) = softmax_xent(&logits, y);
                let (da1, dw2, db2) = dense_backward(&ctx.a1, &params[2], &dlog);
                let dz1 = relu_backward(&ctx.z1, &da1);
                let (_dx, dw1, db1) = dense_backward(x, &params[0], &dz1);
                (loss, vec![dw1, db1, dw2, db2])
            }
            ModelKind::Cnn => {
                let x4 = self.input4(x);
                let (logits, ctx) = self.forward_logits_cnn(params, &x4);
                let (loss, dlog) = softmax_xent(&logits, y);
                let (dflat, dwf, dbf) = dense_backward(&ctx.flat, &params[4], &dlog);
                let dpooled = dflat.reshape(&ctx.pooled_shape);
                let da2 = maxpool2_backward(&dpooled, &ctx.arg, ctx.a2.shape());
                let dz2 = relu_backward(&ctx.z2, &da2);
                let (da1, dw2, db2) = conv2d_backward(&ctx.c2, &params[2], &dz2);
                let dz1 = relu_backward(&ctx.z1, &da1);
                let (_dx, dw1, db1) = conv2d_backward(&ctx.c1, &params[0], &dz1);
                (loss, vec![dw1, db1, dw2, db2, dwf, dbf])
            }
            ModelKind::Vgg => {
                let x4 = self.input4(x);
                let (logits, ctx) = self.forward_logits_vgg(params, &x4);
                let (loss, dlog) = softmax_xent(&logits, y);
                let (dflat, dwf, dbf) = dense_backward(&ctx.flat, &params[6], &dlog);
                let mut dcur = dflat.reshape(&ctx.pooled_shape);
                let mut grads_rev: Vec<Tensor> = vec![dbf, dwf];
                for blk in (0..3).rev() {
                    let b = &ctx.blocks[blk];
                    let da = maxpool2_backward(&dcur, &b.arg, &b.a_shape);
                    let dz = relu_backward(&b.z, &da);
                    let (dx, dw, db) = conv2d_backward(&b.cctx, &params[blk * 2], &dz);
                    grads_rev.push(db);
                    grads_rev.push(dw);
                    dcur = dx;
                }
                grads_rev.reverse();
                (loss, grads_rev)
            }
        }
    }

    fn eval(&self, params: &[Tensor], x: &Tensor, y: &[u32]) -> (f32, usize) {
        let logits = match self.spec.kind {
            ModelKind::Mlp => self.forward_logits_mlp(params, x).0,
            ModelKind::Cnn => {
                let x4 = self.input4(x);
                self.forward_logits_cnn(params, &x4).0
            }
            ModelKind::Vgg => {
                let x4 = self.input4(x);
                self.forward_logits_vgg(params, &x4).0
            }
        };
        let (loss, _) = softmax_xent(&logits, y);
        (loss, count_correct(&logits, y))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelKind, ModelSpec};
    use crate::util::Rng;

    fn batch(spec: &ModelSpec, bsz: usize, rng: &mut Rng) -> (Tensor, Vec<u32>) {
        let x = Tensor::randn(&[bsz, spec.input_dim()], rng);
        let y: Vec<u32> = (0..bsz).map(|_| rng.below(spec.num_classes) as u32).collect();
        (x, y)
    }

    #[test]
    fn grads_match_spec_shapes_all_models() {
        for kind in [ModelKind::Mlp, ModelKind::Cnn, ModelKind::Vgg] {
            let model = NativeModel::new(kind);
            let spec = model.spec().clone();
            let params = spec.init_params(1);
            let mut rng = Rng::new(2);
            let (x, y) = batch(&spec, 3, &mut rng);
            let (loss, grads) = model.loss_grad(&params, &x, &y);
            assert!(loss.is_finite() && loss > 0.0, "{kind:?} loss {loss}");
            assert_eq!(grads.len(), spec.params.len());
            for (g, p) in grads.iter().zip(spec.params.iter()) {
                assert_eq!(g.shape(), &p.shape[..], "{kind:?} {}", p.name);
                assert!(g.fro_norm().is_finite());
            }
        }
    }

    #[test]
    fn sgd_reduces_loss_mlp() {
        let model = NativeModel::new(ModelKind::Mlp);
        let spec = model.spec().clone();
        let mut params = spec.init_params(3);
        let mut rng = Rng::new(4);
        let (x, y) = batch(&spec, 32, &mut rng);
        let (l0, _) = model.eval(&params, &x, &y);
        for _ in 0..30 {
            let (_, grads) = model.loss_grad(&params, &x, &y);
            for (p, g) in params.iter_mut().zip(grads.iter()) {
                p.axpy(-0.1, g);
            }
        }
        let (l1, correct) = model.eval(&params, &x, &y);
        assert!(l1 < l0 * 0.5, "loss did not drop: {l0} -> {l1}");
        assert!(correct >= 24, "training failed: {correct}/32 correct");
    }

    #[test]
    fn sgd_reduces_loss_cnn() {
        let model = NativeModel::new(ModelKind::Cnn);
        let spec = model.spec().clone();
        let mut params = spec.init_params(5);
        let mut rng = Rng::new(6);
        let (x, y) = batch(&spec, 8, &mut rng);
        let (l0, _) = model.eval(&params, &x, &y);
        for _ in 0..15 {
            let (_, grads) = model.loss_grad(&params, &x, &y);
            for (p, g) in params.iter_mut().zip(grads.iter()) {
                p.axpy(-0.05, g);
            }
        }
        let (l1, _) = model.eval(&params, &x, &y);
        assert!(l1 < l0, "loss did not drop: {l0} -> {l1}");
    }

    #[test]
    fn sgd_reduces_loss_vgg() {
        let model = NativeModel::new(ModelKind::Vgg);
        let spec = model.spec().clone();
        let mut params = spec.init_params(7);
        let mut rng = Rng::new(8);
        let (x, y) = batch(&spec, 4, &mut rng);
        let (l0, _) = model.eval(&params, &x, &y);
        for _ in 0..10 {
            let (_, grads) = model.loss_grad(&params, &x, &y);
            for (p, g) in params.iter_mut().zip(grads.iter()) {
                p.axpy(-0.05, g);
            }
        }
        let (l1, _) = model.eval(&params, &x, &y);
        assert!(l1 < l0, "loss did not drop: {l0} -> {l1}");
    }

    #[test]
    fn eval_counts_bounded_by_batch() {
        let model = NativeModel::new(ModelKind::Mlp);
        let spec = model.spec().clone();
        let params = spec.init_params(9);
        let mut rng = Rng::new(10);
        let (x, y) = batch(&spec, 16, &mut rng);
        let (_, correct) = model.eval(&params, &x, &y);
        assert!(correct <= 16);
    }

    #[test]
    fn loss_decreases_along_negative_gradient_direction() {
        // directional-derivative sanity for the full CNN backprop
        let model = NativeModel::new(ModelKind::Cnn);
        let spec = model.spec().clone();
        let params = spec.init_params(11);
        let mut rng = Rng::new(12);
        let (x, y) = batch(&spec, 4, &mut rng);
        let (l0, grads) = model.loss_grad(&params, &x, &y);
        let eps = 1e-5f32;
        let gnorm2: f64 = grads.iter().map(crate::tensor::sq_norm).sum();
        let stepped: Vec<Tensor> = params
            .iter()
            .zip(grads.iter())
            .map(|(p, g)| {
                let mut p = p.clone();
                p.axpy(-eps, g);
                p
            })
            .collect();
        let (l1, _) = model.eval(&stepped, &x, &y);
        let predicted_drop = eps * gnorm2 as f32;
        assert!(
            (l0 - l1) > 0.3 * predicted_drop,
            "drop {} vs predicted {}",
            l0 - l1,
            predicted_drop
        );
    }
}
