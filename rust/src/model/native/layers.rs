//! Differentiable layer primitives (pure Rust): dense, conv2d (same
//! padding, stride 1, via im2col), 2×2 max-pool, ReLU and
//! softmax-cross-entropy. Each primitive exposes `forward` and
//! `backward`; the backward functions are verified against numerical
//! differentiation in the module tests.

use crate::linalg::{gemm_acc_nt, matmul, matmul_nt, matmul_tn};
use crate::tensor::Tensor;

// ---------------------------------------------------------------- dense

/// y[B,O] = x[B,I] · Wᵀ + b, with W stored [O, I] (torch convention —
/// the layout the paper's D_out × D_in gradients use). The output
/// starts as the broadcast bias and the GEMM accumulates onto it — one
/// pass over y instead of a product tensor plus a bias fix-up.
pub fn dense_forward(x: &Tensor, w: &Tensor, b: &Tensor) -> Tensor {
    let bsz = x.shape()[0];
    let out = w.shape()[0];
    let mut y = Tensor::matrix(bsz, out, b.data().repeat(bsz));
    gemm_acc_nt(&mut y, x, w);
    y
}

/// Given dL/dy, return (dL/dx, dL/dW, dL/db).
pub fn dense_backward(x: &Tensor, w: &Tensor, dy: &Tensor) -> (Tensor, Tensor, Tensor) {
    let out = w.shape()[0];
    let dx = matmul(dy, w); // [B,I]
    let dw = matmul_tn(dy, x); // [O,I]
    let mut db = vec![0f32; out];
    let dyd = dy.data();
    let bsz = dy.shape()[0];
    for r in 0..bsz {
        for o in 0..out {
            db[o] += dyd[r * out + o];
        }
    }
    (dx, dw, Tensor::vector(db))
}

// ---------------------------------------------------------------- im2col

/// im2col for 3×3 same-padding stride-1 convolution (general k support).
/// x: [B, C, H, W] → cols: [B*H*W, C*k*k].
pub fn im2col(x: &Tensor, k: usize, pad: usize) -> Tensor {
    let (b, c, h, w) = dims4(x);
    let cols_w = c * k * k;
    let mut cols = Tensor::zeros(&[b * h * w, cols_w]);
    let xd = x.data();
    let cd = cols.data_mut();
    for bi in 0..b {
        for oy in 0..h {
            for ox in 0..w {
                let row = ((bi * h + oy) * w + ox) * cols_w;
                for ci in 0..c {
                    let x_base = ((bi * c) + ci) * h * w;
                    for ky in 0..k {
                        let iy = oy as isize + ky as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        let src = x_base + iy as usize * w;
                        let dst = row + ci * k * k + ky * k;
                        for kx in 0..k {
                            let ix = ox as isize + kx as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            cd[dst + kx] = xd[src + ix as usize];
                        }
                    }
                }
            }
        }
    }
    cols
}

/// Inverse of [`im2col`]: scatter-add column gradients back to an image.
pub fn col2im(dcols: &Tensor, b: usize, c: usize, h: usize, w: usize, k: usize, pad: usize) -> Tensor {
    let cols_w = c * k * k;
    assert_eq!(dcols.shape(), &[b * h * w, cols_w]);
    let mut dx = Tensor::zeros(&[b, c, h, w]);
    let dd = dcols.data();
    let xd = dx.data_mut();
    for bi in 0..b {
        for oy in 0..h {
            for ox in 0..w {
                let row = ((bi * h + oy) * w + ox) * cols_w;
                for ci in 0..c {
                    let x_base = ((bi * c) + ci) * h * w;
                    for ky in 0..k {
                        let iy = oy as isize + ky as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        let dst = x_base + iy as usize * w;
                        let src = row + ci * k * k + ky * k;
                        for kx in 0..k {
                            let ix = ox as isize + kx as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            xd[dst + ix as usize] += dd[src + kx];
                        }
                    }
                }
            }
        }
    }
    dx
}

// ---------------------------------------------------------------- conv2d

/// Cached forward state for the conv backward pass.
#[derive(Debug)]
pub struct ConvCtx {
    cols: Tensor,
    in_shape: [usize; 4],
}

/// Same-padding stride-1 conv: x[B,C,H,W] * w[O,C,k,k] + b → y[B,O,H,W].
/// Implemented as im2col + GEMM (the TPU-friendly formulation the Pallas
/// kernel mirrors — DESIGN.md §3).
pub fn conv2d_forward(x: &Tensor, w: &Tensor, b: &Tensor) -> (Tensor, ConvCtx) {
    let (bsz, c, h, wd) = dims4(x);
    let (o, cw, k, k2) = dims4(w);
    assert_eq!(c, cw, "conv channel mismatch");
    assert_eq!(k, k2, "square kernels only");
    let pad = k / 2;
    let cols = im2col(x, k, pad); // [B*H*W, C*k*k]
    let wmat = Tensor::matrix(o, c * k * k, w.data().to_vec());
    let y2 = matmul_nt(&cols, &wmat); // [B*H*W, O]
    // permute [B*H*W, O] -> [B, O, H, W] and add bias
    let mut y = Tensor::zeros(&[bsz, o, h, wd]);
    {
        let yd = y.data_mut();
        let y2d = y2.data();
        let bd = b.data();
        for bi in 0..bsz {
            for pos in 0..h * wd {
                let src = (bi * h * wd + pos) * o;
                for oi in 0..o {
                    yd[((bi * o) + oi) * h * wd + pos] = y2d[src + oi] + bd[oi];
                }
            }
        }
    }
    (y, ConvCtx { cols, in_shape: [bsz, c, h, wd] })
}

/// Backward pass: returns (dx, dw, db).
pub fn conv2d_backward(ctx: &ConvCtx, w: &Tensor, dy: &Tensor) -> (Tensor, Tensor, Tensor) {
    let [bsz, c, h, wd] = ctx.in_shape;
    let (o, _, k, _) = dims4(w);
    let pad = k / 2;
    // dy [B,O,H,W] -> dy2 [B*H*W, O]
    let mut dy2 = Tensor::zeros(&[bsz * h * wd, o]);
    {
        let dd = dy2.data_mut();
        let dyd = dy.data();
        for bi in 0..bsz {
            for oi in 0..o {
                let src = ((bi * o) + oi) * h * wd;
                for pos in 0..h * wd {
                    dd[(bi * h * wd + pos) * o + oi] = dyd[src + pos];
                }
            }
        }
    }
    // db: sum dy over B,H,W
    let mut db = vec![0f32; o];
    {
        let dd = dy2.data();
        for r in 0..bsz * h * wd {
            for oi in 0..o {
                db[oi] += dd[r * o + oi];
            }
        }
    }
    // dW = dy2ᵀ · cols -> [O, C*k*k]
    let dwmat = matmul_tn(&dy2, &ctx.cols);
    let dw = Tensor::from_vec(&[o, c, k, k], dwmat.into_vec());
    // dx = col2im(dy2 · wmat)
    let wmat = Tensor::matrix(o, c * k * k, w.data().to_vec());
    let dcols = matmul(&dy2, &wmat); // [B*H*W, C*k*k]
    let dx = col2im(&dcols, bsz, c, h, wd, k, pad);
    (dx, dw, Tensor::vector(db))
}

// ---------------------------------------------------------------- pool

/// 2×2 max-pool, stride 2. Returns pooled output and argmax indices.
pub fn maxpool2_forward(x: &Tensor) -> (Tensor, Vec<u32>) {
    let (b, c, h, w) = dims4(x);
    assert!(h % 2 == 0 && w % 2 == 0, "maxpool2 needs even H,W");
    let (oh, ow) = (h / 2, w / 2);
    let mut y = Tensor::zeros(&[b, c, oh, ow]);
    let mut arg = vec![0u32; b * c * oh * ow];
    let xd = x.data();
    let yd = y.data_mut();
    for bc in 0..b * c {
        let base = bc * h * w;
        let obase = bc * oh * ow;
        for oy in 0..oh {
            for ox in 0..ow {
                let mut best = f32::NEG_INFINITY;
                let mut bidx = 0usize;
                for dy in 0..2 {
                    for dx in 0..2 {
                        let idx = base + (oy * 2 + dy) * w + ox * 2 + dx;
                        if xd[idx] > best {
                            best = xd[idx];
                            bidx = idx;
                        }
                    }
                }
                yd[obase + oy * ow + ox] = best;
                arg[obase + oy * ow + ox] = bidx as u32;
            }
        }
    }
    (y, arg)
}

/// Backward: route each output gradient to its argmax input position.
pub fn maxpool2_backward(dy: &Tensor, arg: &[u32], in_shape: &[usize]) -> Tensor {
    let mut dx = Tensor::zeros(in_shape);
    let dd = dx.data_mut();
    for (g, &i) in dy.data().iter().zip(arg.iter()) {
        dd[i as usize] += g;
    }
    dx
}

// ---------------------------------------------------------------- relu

/// ReLU forward (new tensor).
pub fn relu_forward(x: &Tensor) -> Tensor {
    crate::tensor::map(x, |v| v.max(0.0))
}

/// ReLU backward: dy masked by x > 0.
pub fn relu_backward(x: &Tensor, dy: &Tensor) -> Tensor {
    crate::tensor::zip(x, dy, |xv, g| if xv > 0.0 { g } else { 0.0 })
}

// ------------------------------------------------------- softmax + xent

/// Mean cross-entropy over the batch and dL/dlogits.
/// logits: [B, K]; labels: one per row.
pub fn softmax_xent(logits: &Tensor, labels: &[u32]) -> (f32, Tensor) {
    let (b, k) = (logits.shape()[0], logits.shape()[1]);
    assert_eq!(labels.len(), b, "one label per row");
    let mut dl = Tensor::zeros(&[b, k]);
    let ld = logits.data();
    let dd = dl.data_mut();
    let mut loss = 0f64;
    for r in 0..b {
        let row = &ld[r * k..(r + 1) * k];
        let maxv = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
        let mut denom = 0f64;
        for &v in row {
            denom += ((v - maxv) as f64).exp();
        }
        let label = labels[r] as usize;
        assert!(label < k, "label {label} out of range");
        let logp = (row[label] - maxv) as f64 - denom.ln();
        loss -= logp;
        for j in 0..k {
            let p = ((row[j] - maxv) as f64).exp() / denom;
            dd[r * k + j] = (p as f32 - if j == label { 1.0 } else { 0.0 }) / b as f32;
        }
    }
    ((loss / b as f64) as f32, dl)
}

/// Accuracy helper: number of rows whose argmax equals the label.
pub fn count_correct(logits: &Tensor, labels: &[u32]) -> usize {
    crate::tensor::argmax_rows(logits)
        .iter()
        .zip(labels.iter())
        .filter(|(p, l)| **p == **l as usize)
        .count()
}

fn dims4(x: &Tensor) -> (usize, usize, usize, usize) {
    assert_eq!(x.ndim(), 4, "expected 4-D tensor, got {:?}", x.shape());
    (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Numerical gradient of a scalar function wrt one tensor.
    fn numgrad(f: &mut dyn FnMut(&Tensor) -> f32, x: &Tensor, eps: f32) -> Tensor {
        let mut g = Tensor::zeros(x.shape());
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp.data_mut()[i] += eps;
            let mut xm = x.clone();
            xm.data_mut()[i] -= eps;
            g.data_mut()[i] = (f(&xp) - f(&xm)) / (2.0 * eps);
        }
        g
    }

    #[test]
    fn dense_forward_values() {
        let x = Tensor::matrix(1, 2, vec![1.0, 2.0]);
        let w = Tensor::matrix(3, 2, vec![1., 0., 0., 1., 1., 1.]);
        let b = Tensor::vector(vec![0.5, 0.5, 0.5]);
        let y = dense_forward(&x, &w, &b);
        assert_eq!(y.data(), &[1.5, 2.5, 3.5]);
    }

    #[test]
    fn dense_backward_matches_numerical() {
        let mut rng = Rng::new(90);
        let x = Tensor::randn(&[4, 5], &mut rng);
        let w = Tensor::randn(&[3, 5], &mut rng);
        let b = Tensor::randn(&[3], &mut rng);
        let labels = vec![0u32, 2, 1, 0];
        // loss(x, w, b) = xent(dense(x,w,b))
        let loss = |xx: &Tensor, ww: &Tensor, bb: &Tensor| {
            softmax_xent(&dense_forward(xx, ww, bb), &labels).0
        };
        let y = dense_forward(&x, &w, &b);
        let (_, dy) = softmax_xent(&y, &labels);
        let (dx, dw, db) = dense_backward(&x, &w, &dy);
        let ndx = numgrad(&mut |t| loss(t, &w, &b), &x, 1e-2);
        let ndw = numgrad(&mut |t| loss(&x, t, &b), &w, 1e-2);
        let ndb = numgrad(&mut |t| loss(&x, &w, t), &b, 1e-2);
        assert!(dx.rel_err(&ndx) < 2e-2, "dx err {}", dx.rel_err(&ndx));
        assert!(dw.rel_err(&ndw) < 2e-2, "dw err {}", dw.rel_err(&ndw));
        assert!(db.rel_err(&ndb) < 2e-2, "db err {}", db.rel_err(&ndb));
    }

    #[test]
    fn im2col_col2im_adjoint() {
        // <im2col(x), c> == <x, col2im(c)> (they are adjoint linear maps)
        let mut rng = Rng::new(91);
        let x = Tensor::randn(&[2, 3, 4, 4], &mut rng);
        let cols = im2col(&x, 3, 1);
        let c = Tensor::randn(cols.shape(), &mut rng);
        let lhs = crate::tensor::dot(&cols, &c);
        let back = col2im(&c, 2, 3, 4, 4, 3, 1);
        let rhs = crate::tensor::dot(&x, &back);
        assert!((lhs - rhs).abs() / lhs.abs().max(1.0) < 1e-4);
    }

    #[test]
    fn conv_forward_identity_kernel() {
        // kernel = delta at center copies the input channel
        let mut rng = Rng::new(92);
        let x = Tensor::randn(&[1, 1, 5, 5], &mut rng);
        let mut w = Tensor::zeros(&[1, 1, 3, 3]);
        w.data_mut()[4] = 1.0; // center tap
        let b = Tensor::zeros(&[1]);
        let (y, _) = conv2d_forward(&x, &w, &b);
        assert!(x.rel_err(&y.clone().reshape(&[1, 1, 5, 5])) < 1e-6);
    }

    #[test]
    fn conv_backward_matches_numerical() {
        let mut rng = Rng::new(93);
        let x = Tensor::randn(&[2, 2, 4, 4], &mut rng);
        let w = Tensor::randn(&[3, 2, 3, 3], &mut rng);
        let b = Tensor::randn(&[3], &mut rng);
        let labels = vec![1u32, 0];
        let loss = |xx: &Tensor, ww: &Tensor, bb: &Tensor| {
            let (y, _) = conv2d_forward(xx, ww, bb);
            let flat = y.clone().reshape(&[2, 3 * 16]);
            // project to 10-dim via fixed slice to keep the test small:
            // use first 10 cols as logits
            let mut logits = Tensor::zeros(&[2, 10]);
            for r in 0..2 {
                for j in 0..10 {
                    logits.set2(r, j, flat.get2(r, j * 4 + 3));
                }
            }
            softmax_xent(&logits, &labels).0
        };
        // analytic: build dy routed through the same projection
        let (y, ctx) = conv2d_forward(&x, &w, &b);
        let flat = y.clone().reshape(&[2, 3 * 16]);
        let mut logits = Tensor::zeros(&[2, 10]);
        for r in 0..2 {
            for j in 0..10 {
                logits.set2(r, j, flat.get2(r, j * 4 + 3));
            }
        }
        let (_, dlog) = softmax_xent(&logits, &labels);
        let mut dflat = Tensor::zeros(&[2, 3 * 16]);
        for r in 0..2 {
            for j in 0..10 {
                dflat.set2(r, j * 4 + 3, dlog.get2(r, j));
            }
        }
        let dy = dflat.reshape(&[2, 3, 4, 4]);
        let (dx, dw, db) = conv2d_backward(&ctx, &w, &dy);
        let ndx = numgrad(&mut |t| loss(t, &w, &b), &x, 1e-2);
        let ndw = numgrad(&mut |t| loss(&x, t, &b), &w, 1e-2);
        let ndb = numgrad(&mut |t| loss(&x, &w, t), &b, 1e-2);
        assert!(dx.rel_err(&ndx) < 3e-2, "dx err {}", dx.rel_err(&ndx));
        assert!(dw.rel_err(&ndw) < 3e-2, "dw err {}", dw.rel_err(&ndw));
        assert!(db.rel_err(&ndb) < 3e-2, "db err {}", db.rel_err(&ndb));
    }

    #[test]
    fn maxpool_forward_and_routing() {
        let x = Tensor::from_vec(
            &[1, 1, 4, 4],
            vec![
                1., 2., 5., 6., //
                3., 4., 7., 8., //
                9., 10., 13., 14., //
                11., 12., 15., 16.,
            ],
        );
        let (y, arg) = maxpool2_forward(&x);
        assert_eq!(y.data(), &[4., 8., 12., 16.]);
        let dy = Tensor::from_vec(&[1, 1, 2, 2], vec![1., 1., 1., 1.]);
        let dx = maxpool2_backward(&dy, &arg, &[1, 1, 4, 4]);
        // gradient lands exactly on the max positions
        assert_eq!(crate::tensor::sum(&dx), 4.0);
        assert_eq!(dx.at(&[0, 0, 1, 1]), 1.0);
        assert_eq!(dx.at(&[0, 0, 3, 3]), 1.0);
        assert_eq!(dx.at(&[0, 0, 0, 0]), 0.0);
    }

    #[test]
    fn relu_masks() {
        let x = Tensor::vector(vec![-1.0, 2.0, 0.0]);
        let y = relu_forward(&x);
        assert_eq!(y.data(), &[0.0, 2.0, 0.0]);
        let dy = Tensor::vector(vec![5.0, 5.0, 5.0]);
        let dx = relu_backward(&x, &dy);
        assert_eq!(dx.data(), &[0.0, 5.0, 0.0]);
    }

    #[test]
    fn xent_uniform_logits() {
        let logits = Tensor::zeros(&[3, 10]);
        let (loss, _) = softmax_xent(&logits, &[0, 5, 9]);
        assert!((loss - (10f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn xent_gradient_sums_to_zero_per_row() {
        let mut rng = Rng::new(94);
        let logits = Tensor::randn(&[4, 6], &mut rng);
        let (_, d) = softmax_xent(&logits, &[0, 1, 2, 3]);
        for r in 0..4 {
            let s: f32 = (0..6).map(|j| d.get2(r, j)).sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn count_correct_works() {
        let logits = Tensor::matrix(2, 3, vec![0.9, 0.0, 0.0, 0.0, 0.0, 0.9]);
        assert_eq!(count_correct(&logits, &[0, 2]), 2);
        assert_eq!(count_correct(&logits, &[1, 2]), 1);
    }
}
